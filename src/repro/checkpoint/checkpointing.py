"""Async, mesh-independent checkpointing.

Layout (one directory per step):

    <dir>/step_000123/
        meta.json            step, pytree structure, shapes/dtypes,
                             format version, per-leaf crc32 checksums
        leaf_00000.npy ...   one file per pytree leaf

Design points for the 1000+ node posture:
- **Mesh independence / elastic restart**: leaves are written as *full*
  (unsharded) arrays; restore re-shards onto whatever mesh the restarted
  job has — a checkpoint taken on 2 pods restores on 1 or 4. (On a real
  multi-host fleet each host writes only the shards it owns —
  ``jax.experimental.multihost_utils`` / ocdbt-style; the addressing logic
  here is identical, the container is single-process.)
- **Async**: device→host transfer happens on the caller, file IO in a
  background thread; the train loop is blocked only for the transfer.
- **Atomicity**: written into ``.tmp`` and renamed, so a crash mid-write
  never corrupts the latest checkpoint (restart-safe).
- **Integrity**: every leaf's crc32 is recorded in ``meta.json`` and
  re-verified on restore (``verify=True``); a bit-rotted or truncated
  leaf, a missing file, or a format-version bump raises
  ``CheckpointCorrupt`` — callers that can rebuild the state from a
  different source (e.g. the serve journal) catch it and degrade to a
  cold start instead of loading wrong bytes.
- **Namespaces**: ``prefix`` separates checkpoint families inside one
  directory — training uses the default ``step_%08d``; the serving
  snapshots use ``serve_%08d`` indexed by snapshot ordinal, not a train
  step — each family rotates (``keep``) independently.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from concurrent.futures import ThreadPoolExecutor

import jax
import numpy as np

FORMAT_VERSION = 2


class CheckpointCorrupt(RuntimeError):
    """A checkpoint failed verification: checksum mismatch, missing or
    truncated leaf, or an incompatible format version. Restoring would
    hand back wrong bytes, so the restore refuses instead."""


def _crc32(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).view(np.uint8).data)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, prefix: str = "step"):
        self.dir = directory
        self.keep = keep
        self.prefix = prefix
        os.makedirs(directory, exist_ok=True)
        self._pool = ThreadPoolExecutor(max_workers=2)
        self._pending = None
        self._lock = threading.Lock()

    def _dirname(self, step: int) -> str:
        return os.path.join(self.dir, f"{self.prefix}_{step:08d}")

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree, extra: dict | None = None,
             blocking: bool = False):
        """Snapshot ``tree`` (any pytree of jax/np arrays) at ``step``."""
        leaves, treedef = jax.tree.flatten(tree)
        # np.array (not asarray): on the CPU backend asarray can alias
        # the device buffer zero-copy, and a caller that donates the
        # tree to its next dispatch (the serve loop donates its caches
        # every segment) would mutate the bytes between the checksum
        # below and the background write — a copy pins this call's view
        host_leaves = [np.array(l) for l in leaves]         # device->host
        meta = {
            "format_version": FORMAT_VERSION,
            "step": step,
            "treedef": str(treedef),
            "n_leaves": len(leaves),
            "extra": extra or {},
            "shapes": [list(l.shape) for l in host_leaves],
            "dtypes": [str(l.dtype) for l in host_leaves],
            "checksums": [_crc32(l) for l in host_leaves],
        }
        # serialize meta NOW, on the caller: ``extra`` may hold live
        # bookkeeping dicts (the serve loop's prefix index / pin ledger)
        # that keep mutating after save() returns — encoding on the
        # background thread would snapshot a racy future state of them
        meta_json = json.dumps(meta)
        fut = self._pool.submit(self._write, step, host_leaves, meta_json)
        with self._lock:
            self._pending = fut
        if blocking:
            fut.result()
        return fut

    def _write(self, step, host_leaves, meta_json):
        final = self._dirname(step)
        tmp = f"{final}.{os.getpid()}.{threading.get_ident()}.tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        for i, leaf in enumerate(host_leaves):
            np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), leaf)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            f.write(meta_json)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()
        return final

    def wait(self):
        with self._lock:
            fut = self._pending
        if fut is not None:
            fut.result()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self._dirname(s), ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def all_steps(self):
        out = []
        want = self.prefix + "_"
        for name in os.listdir(self.dir):
            if name.startswith(want) and not name.endswith(".tmp"):
                out.append(int(name[len(want):].split(".")[0]))
        return sorted(out)

    def latest_step(self):
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: int | None = None,
                shardings=None, verify: bool = True) -> tuple:
        """Restore into the structure of ``template``; re-shard with
        ``shardings`` (pytree of NamedSharding) when given — this is the
        elastic-restart path onto a different mesh. ``verify`` re-checks
        every leaf's crc32 against ``meta.json`` (v2 checkpoints) and
        raises ``CheckpointCorrupt`` on any mismatch."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = self._dirname(step)
        try:
            with open(os.path.join(path, "meta.json")) as f:
                meta = json.load(f)
        except (OSError, ValueError) as e:
            raise CheckpointCorrupt(f"{path}: unreadable meta.json: {e}")
        version = meta.get("format_version", 1)
        if version > FORMAT_VERSION:
            raise CheckpointCorrupt(
                f"{path}: format version {version} is newer than this "
                f"reader ({FORMAT_VERSION})")
        leaves, treedef = jax.tree.flatten(template)
        if len(leaves) != meta["n_leaves"]:
            raise CheckpointCorrupt(
                f"{path}: pytree structure changed "
                f"({len(leaves)} leaves vs {meta['n_leaves']} on disk)")
        checksums = meta.get("checksums")
        out = []
        shard_leaves = (jax.tree.leaves(shardings)
                        if shardings is not None else [None] * len(leaves))
        for i, (tmpl, sh) in enumerate(zip(leaves, shard_leaves, strict=True)):
            leaf_path = os.path.join(path, f"leaf_{i:05d}.npy")
            try:
                arr = np.load(leaf_path)
            except (OSError, ValueError) as e:
                raise CheckpointCorrupt(f"{leaf_path}: unreadable: {e}")
            if verify and checksums is not None:
                got = _crc32(arr)
                if got != checksums[i]:
                    raise CheckpointCorrupt(
                        f"{leaf_path}: crc32 {got:#010x} != recorded "
                        f"{checksums[i]:#010x}")
            # copy=True is load-bearing: on the CPU backend a plain
            # asarray/device_put can zero-copy alias the numpy buffer
            # np.load handed us, and callers feed restored leaves into
            # donating jitted functions (the serve restore releases
            # slots in place) — donation of an aliased buffer leaves
            # XLA and numpy each believing they own it (observed as
            # heap corruption + garbage leaf contents under the
            # persistent compilation cache's fast dispatch)
            owned = jax.numpy.array(arr, copy=True)
            if sh is not None:
                out.append(jax.device_put(owned, sh))
            else:
                out.append(owned)
        return jax.tree.unflatten(treedef, out), meta
