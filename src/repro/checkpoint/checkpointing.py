"""Async, mesh-independent checkpointing.

Layout (one directory per step):

    <dir>/step_000123/
        meta.json            step, pytree structure, shapes/dtypes
        leaf_00000.npy ...   one file per pytree leaf

Design points for the 1000+ node posture:
- **Mesh independence / elastic restart**: leaves are written as *full*
  (unsharded) arrays; restore re-shards onto whatever mesh the restarted
  job has — a checkpoint taken on 2 pods restores on 1 or 4. (On a real
  multi-host fleet each host writes only the shards it owns —
  ``jax.experimental.multihost_utils`` / ocdbt-style; the addressing logic
  here is identical, the container is single-process.)
- **Async**: device→host transfer happens on the caller, file IO in a
  background thread; the train loop is blocked only for the transfer.
- **Atomicity**: written into ``.tmp`` and renamed, so a crash mid-write
  never corrupts the latest checkpoint (restart-safe).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from concurrent.futures import ThreadPoolExecutor

import jax
import numpy as np


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pool = ThreadPoolExecutor(max_workers=2)
        self._pending = None
        self._lock = threading.Lock()

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree, extra: dict | None = None,
             blocking: bool = False):
        """Snapshot ``tree`` (any pytree of jax/np arrays) at ``step``."""
        leaves, treedef = jax.tree.flatten(tree)
        host_leaves = [np.asarray(l) for l in leaves]       # device->host
        meta = {
            "step": step,
            "treedef": str(treedef),
            "n_leaves": len(leaves),
            "extra": extra or {},
            "shapes": [list(l.shape) for l in host_leaves],
            "dtypes": [str(l.dtype) for l in host_leaves],
        }
        fut = self._pool.submit(self._write, step, host_leaves, meta)
        with self._lock:
            self._pending = fut
        if blocking:
            fut.result()
        return fut

    def _write(self, step, host_leaves, meta):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = f"{final}.{os.getpid()}.{threading.get_ident()}.tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        for i, leaf in enumerate(host_leaves):
            np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), leaf)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()
        return final

    def wait(self):
        with self._lock:
            fut = self._pending
        if fut is not None:
            fut.result()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1].split(".")[0]))
        return sorted(out)

    def latest_step(self):
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: int | None = None,
                shardings=None) -> tuple:
        """Restore into the structure of ``template``; re-shard with
        ``shardings`` (pytree of NamedSharding) when given — this is the
        elastic-restart path onto a different mesh."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        leaves, treedef = jax.tree.flatten(template)
        assert len(leaves) == meta["n_leaves"], "pytree structure changed"
        out = []
        shard_leaves = (jax.tree.leaves(shardings)
                        if shardings is not None else [None] * len(leaves))
        for i, (tmpl, sh) in enumerate(zip(leaves, shard_leaves, strict=True)):
            arr = np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree.unflatten(treedef, out), meta
