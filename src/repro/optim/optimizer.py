"""AdamW + schedules + global-norm clipping (pure JAX, optimizer state is
a pytree mirroring params — sharded identically, ZeRO-3 style, by the
launcher's sharding rules)."""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    mu: object
    nu: object
    master: object          # f32 master weights when params are bf16
    count: jax.Array


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    needs_master = any(p.dtype == jnp.bfloat16
                       for p in jax.tree.leaves(params))
    master = (jax.tree.map(lambda p: p.astype(jnp.float32), params)
              if needs_master else None)
    return OptState(mu=zeros,
                    nu=jax.tree.map(jnp.zeros_like, zeros),
                    master=master,
                    count=jnp.zeros((), jnp.int32))


def lr_schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(grads, opt_state: OptState, params, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, stats)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    count = opt_state.count + 1
    lr = lr_schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v, w32):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        base = w32 if w32 is not None else p.astype(jnp.float32)
        # no decay on scalar leaves (quant scales, gates): decaying a
        # calibrated scale toward 0 corrupts the integer serve path
        wd = cfg.weight_decay if p.ndim > 0 else 0.0
        step = lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + wd * base)
        new32 = base - step
        return new32.astype(p.dtype), m, v, new32

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state.mu)
    flat_v = jax.tree.leaves(opt_state.nu)
    flat_w = (jax.tree.leaves(opt_state.master)
              if opt_state.master is not None else [None] * len(flat_p))
    out = [upd(p, g, m, v, w) for p, g, m, v, w in
           zip(flat_p, flat_g, flat_m, flat_v, flat_w, strict=True)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_w = (jax.tree.unflatten(treedef, [o[3] for o in out])
             if opt_state.master is not None else None)
    return new_p, OptState(new_m, new_v, new_w, count), \
        {"grad_norm": gnorm, "lr": lr}
