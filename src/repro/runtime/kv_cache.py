"""Int8 KV-cache ring buffers for autoregressive decode.

The serving-side companion to the ITA kernels: K/V projections are stored
quantized (int8 + quantization scales), so the cache is 4x smaller than
f32 and feeds the integer attention path directly — no dequantize pass,
the int8 MXU consumes the cache bytes as stored (paper §III's
weight-stationary philosophy applied to the KV stream).

A cache is a plain dict pytree (scan/shard/donate friendly):

    {"k": (B, C, G, hd) int8,   "v": (B, C, G, hd) int8,
     "pos": () int32            # total tokens ever written
     [, "k_scale": (G,) f32, "v_scale": (G,) f32]}   # per-head scales

``C`` (capacity) is a ring: token ``t`` lives in slot ``t % C``.  For
global attention ``C >= max_len`` and the ring never wraps; for sliding-
window layers ``C = window`` and old tokens are evicted by overwrite.
``pos`` tracks the *logical* stream length, from which the valid prefix
(``kv_len``) and the logical position of new queries (``q_offset``) are
derived — the plumbing ``ita_attention`` needs for decode.

Per-head scales: per (kv-)head symmetric quantization of the cached K/V
(finer than the per-tensor QAT scale; the decode engine in
``repro.runtime.generate`` and ``benchmarks/bench_decode.py`` use it).
The model path (``repro.models.attention``) passes the QAT per-tensor
scales instead, so train/serve semantics stay aligned.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import INT8_MAX, INT8_MIN


def quantize_per_head(x: jax.Array, head_axis: int = 2):
    """Symmetric per-head int8 quantization.

    ``x`` (..., G, hd) float with heads on ``head_axis``. Returns
    ``(x_q int8, scale (G,) f32)``.
    """
    red = tuple(i for i in range(x.ndim) if i != head_axis)
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=red)
    scale = jnp.maximum(amax, 1e-8) / INT8_MAX
    sh = [1] * x.ndim
    sh[head_axis] = x.shape[head_axis]
    q = jnp.round(x.astype(jnp.float32) / scale.reshape(sh))
    return jnp.clip(q, INT8_MIN, INT8_MAX).astype(jnp.int8), scale


def quantize_with_scale(x: jax.Array, scale: jax.Array) -> jax.Array:
    """Quantize onto a fixed (per-tensor or broadcastable) scale."""
    q = jnp.round(x.astype(jnp.float32) / scale)
    return jnp.clip(q, INT8_MIN, INT8_MAX).astype(jnp.int8)


def init_cache(batch: int, capacity: int, n_kv_heads: int, head_dim: int,
               dtype=jnp.int8, per_head_scales: bool = False) -> dict:
    """Fresh (zeroed) ring-buffer cache."""
    capacity = max(capacity, 1)
    cache = {
        "k": jnp.zeros((batch, capacity, n_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, capacity, n_kv_heads, head_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }
    if per_head_scales:
        cache["k_scale"] = jnp.ones((n_kv_heads,), jnp.float32)
        cache["v_scale"] = jnp.ones((n_kv_heads,), jnp.float32)
    return cache


def capacity(cache: dict) -> int:
    return cache["k"].shape[1]


def valid_len(cache: dict) -> jax.Array:
    """Number of valid (non-evicted) entries in the ring."""
    return jnp.minimum(cache["pos"], capacity(cache))


def q_offset(cache: dict, s_new: int = 1) -> jax.Array:
    """Logical position of the first of the ``s_new`` query tokens *just
    appended* to the cache, in ring coordinates: ``valid_len - s_new``.
    While the ring has not wrapped this is the token's stream position;
    after wrap the oldest surviving token is redefined as position 0, so
    the newest query sits at ``C - s_new`` and the sliding-window mask
    ``(qi - kj) < window`` keeps exactly the last ``window`` slots visible.
    """
    return jnp.maximum(valid_len(cache) - s_new, 0)


def prefill_write(cache: dict, k_q: jax.Array, v_q: jax.Array) -> dict:
    """Bulk-write ``S`` prefill tokens, evicting beyond capacity.

    ``k_q``/``v_q`` (B, S, G, hd), already quantized. Token ``t`` lands in
    slot ``t % C`` (so a later ``decode_append`` continues the same ring);
    when ``S >= C`` only the last ``C`` tokens survive.
    """
    s = k_q.shape[1]
    cs = capacity(cache)
    if s >= cs:
        # keep the tail, rolled so slot (t % C) holds token t
        k_t = jnp.roll(k_q[:, s - cs:], s % cs, axis=1)
        v_t = jnp.roll(v_q[:, s - cs:], s % cs, axis=1)
    else:
        k_t = jax.lax.dynamic_update_slice(cache["k"], k_q, (0, 0, 0, 0))
        v_t = jax.lax.dynamic_update_slice(cache["v"], v_q, (0, 0, 0, 0))
    return dict(cache, k=k_t, v=v_t, pos=jnp.asarray(s, jnp.int32))


def decode_append(cache: dict, k_q: jax.Array, v_q: jax.Array) -> dict:
    """Append ``s_new`` decode tokens, token ``pos + i`` to slot
    ``(pos + i) % C``. Written per token because a blockwise
    ``dynamic_update_slice`` would *clamp* at the ring boundary instead of
    wrapping (silently overwriting the newest surviving entries);
    ``s_new`` is 1 in steady-state decode, ≤ 8 for speculative bursts.
    """
    cs = capacity(cache)
    k_t, v_t = cache["k"], cache["v"]
    for i in range(k_q.shape[1]):
        slot = (cache["pos"] + i) % cs
        k_t = jax.lax.dynamic_update_slice(k_t, k_q[:, i:i + 1],
                                           (0, slot, 0, 0))
        v_t = jax.lax.dynamic_update_slice(v_t, v_q[:, i:i + 1],
                                           (0, slot, 0, 0))
    return dict(cache, k=k_t, v=v_t, pos=cache["pos"] + k_q.shape[1])


# ---------------------------------------------------------------------------
# Kernel-level decode engine (one attention layer over one cache)
# ---------------------------------------------------------------------------

def prefill_attend(cache: dict, q_q: jax.Array, k_new: jax.Array,
                   v_new: jax.Array, s_q, s_out, *, causal: bool = True,
                   window: int = 0, block_q: int = 128, block_kv: int = 128,
                   interpret: bool = True):
    """Quantized prefill: per-head-quantize and cache K/V, run the fused
    ITA kernel over the prompt. ``q_q`` (B, Hq, S, D) int8 at scale
    ``s_q``; ``k_new``/``v_new`` (B, S, G, D) float. Returns
    ``(out int8 at s_out, new_cache)``."""
    from repro.kernels.ita_attention.ops import ita_attention
    k_q, k_scale = quantize_per_head(k_new)
    v_q, v_scale = quantize_per_head(v_new)
    cache = prefill_write(cache, k_q, v_q)
    cache = dict(cache, k_scale=k_scale, v_scale=v_scale)
    out = ita_attention(q_q, k_q.transpose(0, 2, 1, 3),
                        v_q.transpose(0, 2, 1, 3), s_q, k_scale, v_scale,
                        s_out, causal=causal, window=window, mode="onepass",
                        block_q=block_q, block_kv=block_kv,
                        interpret=interpret)
    return out, cache


def decode_attend(cache: dict, q_q: jax.Array, k_new: jax.Array,
                  v_new: jax.Array, s_q, s_out, *, causal: bool = True,
                  window: int = 0, block_kv: int = 128,
                  interpret: bool = True):
    """One incremental decode step through the cache.

    Appends the new token's K/V (quantized onto the cache's standing
    per-head scales — the scales are frozen after prefill so cached bytes
    never need rescaling) and attends the single query over the valid
    prefix via the fused decode-shaped kernel. ``q_q`` (B, Hq, 1, D) int8;
    ``k_new``/``v_new`` (B, 1, G, D) float. Returns ``(out, new_cache)``.
    """
    from repro.kernels.ita_attention.ops import ita_attention
    k_q = quantize_with_scale(k_new, cache["k_scale"][None, None, :, None])
    v_q = quantize_with_scale(v_new, cache["v_scale"][None, None, :, None])
    cache = decode_append(cache, k_q, v_q)
    # cache-native kv_layout: the ring buffers are consumed in place by
    # the decode kernel's index maps — no per-step transpose/broadcast
    out = ita_attention(q_q, cache["k"], cache["v"], s_q,
                        cache["k_scale"], cache["v_scale"], s_out,
                        q_offset=q_offset(cache, 1), kv_len=valid_len(cache),
                        causal=causal, window=window, mode="decode",
                        kv_layout="bsgd", block_kv=block_kv,
                        interpret=interpret)
    return out, cache
