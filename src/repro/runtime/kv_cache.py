"""Int8 KV-cache decode engine: quantization helpers + the kernel-level
prefill/decode loop over a ``repro.attention.KVCacheState`` ring buffer.

K/V projections are stored quantized (int8 + quantization scales), so the
cache is 4x smaller than f32 and feeds the integer attention path
directly — no dequantize pass, the int8 MXU consumes the cache bytes as
stored (paper §III's weight-stationary philosophy applied to the KV
stream). The ring/pool semantics (slot ``t % C``, logical ``pos``,
``valid_len``/``q_offset`` derivation, page tables + free stack) live on
the typed states in ``repro.attention.state``; this module adds the
*engine*: per-head symmetric quantization of the KV stream and the
prefill/decode attend steps, dispatched through the attention backend
registry (layout capabilities select the fused Pallas kernels — the
decode step consumes ring buffers cache-natively via ``bhsd_bsgd`` and
paged pools via ``bhsd_paged`` page-table index maps, no per-step
transpose, broadcast or gather copies).

Per-head scales are finer than the per-tensor QAT grid; the model path
(``repro.models.attention``) passes the QAT per-tensor scales instead, so
train/serve semantics stay aligned.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.attention import (AttentionSpec, KVCacheState, PagedKVState,
                             QuantScales, dispatch)
from repro.core.quant import INT8_MAX, INT8_MIN

__all__ = ["KVCacheState", "PagedKVState", "init_cache", "init_paged_cache",
           "quantize_per_head", "quantize_with_scale", "prefill_attend",
           "decode_attend"]


def quantize_per_head(x: jax.Array, head_axis: int = 2):
    """Symmetric per-head int8 quantization.

    ``x`` (..., G, hd) float with heads on ``head_axis``. Returns
    ``(x_q int8, scale (G,) f32)``.
    """
    red = tuple(i for i in range(x.ndim) if i != head_axis)
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=red)
    scale = jnp.maximum(amax, 1e-8) / INT8_MAX
    sh = [1] * x.ndim
    sh[head_axis] = x.shape[head_axis]
    q = jnp.round(x.astype(jnp.float32) / scale.reshape(sh))
    return jnp.clip(q, INT8_MIN, INT8_MAX).astype(jnp.int8), scale


def quantize_with_scale(x: jax.Array, scale: jax.Array) -> jax.Array:
    """Quantize onto a fixed (per-tensor or broadcastable) scale."""
    q = jnp.round(x.astype(jnp.float32) / scale)
    return jnp.clip(q, INT8_MIN, INT8_MAX).astype(jnp.int8)


def init_cache(batch: int, capacity: int, n_kv_heads: int, head_dim: int,
               dtype=jnp.int8, per_head_scales: bool = False) -> KVCacheState:
    """Fresh (zeroed) ring-buffer cache."""
    return KVCacheState.init(batch, capacity, n_kv_heads, head_dim,
                             dtype=dtype, per_head_scales=per_head_scales)


def init_paged_cache(batch: int, capacity: int, n_kv_heads: int,
                     head_dim: int, dtype=jnp.int8,
                     per_head_scales: bool = False, *, page_size: int = 128,
                     num_pages: int | None = None) -> PagedKVState:
    """Fresh paged KV pool (shared arena + per-sequence page tables).
    ``num_pages`` undersized vs ``batch * ceil(capacity/page_size)``
    oversubscribes the pool — pair with an admission scheduler."""
    return PagedKVState.init(batch, capacity, n_kv_heads, head_dim,
                             dtype=dtype, per_head_scales=per_head_scales,
                             page_size=page_size, num_pages=num_pages)


# ---------------------------------------------------------------------------
# Kernel-level decode engine (one attention layer over one cache)
# ---------------------------------------------------------------------------

def prefill_attend(cache: KVCacheState, q_q: jax.Array, k_new: jax.Array,
                   v_new: jax.Array, s_q, s_out, *, causal: bool = True,
                   window: int = 0, lengths: jax.Array | None = None,
                   block_q: int = 128, block_kv: int = 128,
                   interpret: bool | None = None):
    """Quantized prefill: per-head-quantize and cache K/V, run the fused
    ITA kernel over the prompt. ``q_q`` (B, Hq, S, D) int8 at scale
    ``s_q``; ``k_new``/``v_new`` (B, S, G, D) float. ``lengths`` (B,)
    declares a ragged batch of right-padded prompts (per-sequence valid
    prefixes; causal masking keeps each row's valid outputs exact).
    Returns ``(out int8 at s_out, new_cache)``.

    Dispatch note: the cache-native ``bhsd_bsgd`` layout + per-head
    scales make the streaming XLA backend ineligible, so the registry
    lands on ``ita_onepass_pallas``, which consumes the (B, S, G, D)
    K/V buffers in place through kernel index maps — the per-call
    ``transpose(0, 2, 1, 3)`` relayout copies this module used to make
    are gone, capability-driven like the decode layout.
    """
    k_q, k_scale = quantize_per_head(k_new)
    v_q, v_scale = quantize_per_head(v_new)
    cache = cache.prefill_write(k_q, v_q, lengths=lengths) \
                 .with_scales(k_scale, v_scale)
    # Paged or ring, the *prefill attention* streams the freshly projected
    # (B, S, G, D) tensors cache-natively — only decode re-reads the pool.
    spec = AttentionSpec(mode="prefill", impl="ita", causal=causal,
                         window=window, layout="bhsd_bsgd",
                         scale_kind="per_head", out_dtype="int8",
                         q_len=q_q.shape[2])
    out = dispatch(q_q, k_q, v_q, spec=spec,
                   scales=QuantScales(s_q, k_scale, v_scale, s_out),
                   kv_len=lengths, block_q=block_q, block_kv=block_kv,
                   interpret=interpret)
    return out, cache


def decode_attend(cache: KVCacheState, q_q: jax.Array, k_new: jax.Array,
                  v_new: jax.Array, s_q, s_out, *, causal: bool = True,
                  window: int = 0, block_kv: int = 128,
                  interpret: bool | None = None):
    """One incremental decode step through the cache.

    Appends the new token's K/V (quantized onto the cache's standing
    per-head scales — the scales are frozen after prefill so cached bytes
    never need rescaling) and attends the single query over the valid
    prefix via the fused decode-shaped kernel, consuming the ring buffers
    cache-natively (``bhsd_bsgd`` layout — no per-step transpose or head
    broadcast). The cache's per-sequence ``q_offset``/``valid_len``
    vectors ride into the kernel's per-row meta, so a ragged batch
    (mixed prompt lengths) decodes in this one call. ``q_q``
    (B, Hq, 1, D) int8; ``k_new``/``v_new`` (B, 1, G, D) float. Returns
    ``(out, new_cache)``.
    """
    k_q = quantize_with_scale(k_new, cache.k_scale[None, None, :, None])
    v_q = quantize_with_scale(v_new, cache.v_scale[None, None, :, None])
    cache = cache.decode_append(k_q, v_q)
    paged = isinstance(cache, PagedKVState)
    spec = AttentionSpec(mode="decode", impl="ita", causal=causal,
                         window=window,
                         layout="bhsd_paged" if paged else "bhsd_bsgd",
                         scale_kind="per_head", out_dtype="int8",
                         q_len=q_q.shape[2])
    out = dispatch(q_q, cache.k, cache.v, spec=spec,
                   scales=QuantScales(s_q, cache.k_scale, cache.v_scale,
                                      s_out),
                   q_offset=cache.q_offset(1), kv_len=cache.valid_len(),
                   page_table=cache.page_table if paged else None,
                   block_kv=block_kv, interpret=interpret)
    return out, cache
