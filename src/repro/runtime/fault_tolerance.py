"""Fault-tolerant training driver (1000+ node posture).

Mechanisms (each unit-tested; the container is single-process, so "node
failure" is injected, but every code path is the real one):

- **Checkpoint/restart**: async checkpoints every ``ckpt_every`` steps
  (params + optimizer + data-pipeline state); on (re)start the driver
  restores the latest checkpoint and replays the data pipeline to the
  exact step — bitwise-identical continuation (tested).
- **Elastic re-mesh**: checkpoints are mesh-independent; ``run()`` accepts
  any mesh, so a job checkpointed on 2 pods restarts on 1 (or 4) with the
  same model state (re-sharded on restore).
- **Straggler mitigation**: a step-time watchdog (the shared
  ``runtime.watchdog.StragglerWatchdog``, also run by the serve loop
  over its segment times) tracks a robust moving median; steps slower
  than ``straggler_factor``× median are logged and counted. On a real fleet this signal feeds the controller that evicts /
  re-shards around the slow host (here: surfaced in ``stats`` and the
  log). Persistent stragglers trigger a checkpoint so any subsequent
  eviction loses zero work.
- **Crash safety**: checkpoint writes are atomic (tmp+rename); SIGTERM-
  style preemption can be simulated with ``inject_failure_at``.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.checkpoint.checkpointing import Checkpointer
from repro.runtime.watchdog import StragglerWatchdog


@dataclasses.dataclass
class FTConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    straggler_factor: float = 2.0
    straggler_ckpt_threshold: int = 3     # consecutive slow steps
    inject_failure_at: int | None = None  # simulate preemption (tests)


# ---------------------------------------------------------------------------
# Serve-loop fault injection (the serving analogue of inject_failure_at)
# ---------------------------------------------------------------------------

class SimulatedCrash(RuntimeError):
    """Raised by ``serve_continuous`` at an injected crash point: the
    process "dies" with whatever the journal has durably recorded — all
    in-memory serve state (slots, pool, prefix index, pending queue) is
    abandoned exactly as a SIGKILL would abandon it. The recovery
    harness catches it and restarts with ``resume=True``."""

    def __init__(self, step: int, where: str):
        super().__init__(f"simulated crash at step {step} ({where})")
        self.step = step
        self.where = where


@dataclasses.dataclass(frozen=True)
class ServeFaultPlan:
    """Seeded fault-injection plan for ``serve_continuous``: which faults
    to force and when, in the scheduler's virtual clock (decode steps).

    Three fault families, each with a deterministic step list (tests:
    ``kill_steps=(12,)`` kills at the first boundary at or past step 12)
    and an independent per-round probability (soak runs):

    - **kills**: force-preempt one live resumable slot — the victim's
      pages release, its request re-enqueues carrying the generated
      prefix, and it resumes through the ordinary chunked re-prefill
      path. Exercises the preemption recovery machinery even with
      priority preemption disabled.
    - **page pressure**: subtract ``pressure_pages`` phantom pages from
      the admission budget for one round — the overload spike that
      drives victim selection and index eviction without needing a
      bigger trace.
    - **stragglers**: sleep ``straggle_s`` before a segment dispatch so
      the segment watchdog (the shared ``StragglerWatchdog``) sees a
      genuine outlier.
    - **crashes**: raise ``SimulatedCrash`` — process death, not
      preemption. ``crash_steps`` fires at the *top* of the first
      scheduling round at or past the listed step (an admission-round
      boundary: everything through the previous segment is journaled);
      ``crash_after_steps`` fires *after* the segment's device work and
      readback but **before** the journal flush (the mid-segment torn
      window: the device produced tokens the journal never saw, and
      recovery must regenerate them bit-identically). Each listed step
      fires once per injector — the restarted serve builds a fresh
      injector whose lists exclude already-fired points.
    """

    seed: int = 0
    kill_prob: float = 0.0
    kill_steps: tuple = ()
    pressure_prob: float = 0.0
    pressure_pages: int = 0
    pressure_steps: tuple = ()
    straggle_prob: float = 0.0
    straggle_s: float = 0.0
    straggle_steps: tuple = ()
    crash_steps: tuple = ()
    crash_after_steps: tuple = ()

    @property
    def may_kill(self) -> bool:
        return self.kill_prob > 0.0 or bool(self.kill_steps)

    @property
    def may_crash(self) -> bool:
        return bool(self.crash_steps) or bool(self.crash_after_steps)


class ServeFaultInjector:
    """Runtime side of a ``ServeFaultPlan``: one seeded RNG, one cursor
    per deterministic step list. The serve loop polls it once per
    scheduling round; the injector counts what it injected so tests can
    assert the faults actually fired (non-vacuous recovery coverage)."""

    def __init__(self, plan: ServeFaultPlan):
        self.plan = plan
        self.rng = np.random.default_rng(plan.seed)
        self._kills = sorted(plan.kill_steps)
        self._pressure = sorted(plan.pressure_steps)
        self._straggles = sorted(plan.straggle_steps)
        self._crashes = sorted(plan.crash_steps)
        self._crashes_after = sorted(plan.crash_after_steps)
        self.kills_requested = 0
        self.pressure_events = 0
        self.straggle_events = 0
        self.crashes_fired = 0

    @staticmethod
    def _due(pending: list, step: int) -> bool:
        hit = False
        while pending and pending[0] <= step:
            pending.pop(0)
            hit = True
        return hit

    def want_kill(self, step: int) -> bool:
        hit = self._due(self._kills, step)
        if self.plan.kill_prob > 0.0 \
                and self.rng.random() < self.plan.kill_prob:
            hit = True
        self.kills_requested += hit
        return hit

    def phantom_pages(self, step: int) -> int:
        """Pages to subtract from this round's admission budget."""
        hit = self._due(self._pressure, step)
        if self.plan.pressure_prob > 0.0 \
                and self.rng.random() < self.plan.pressure_prob:
            hit = True
        if not hit:
            return 0
        self.pressure_events += 1
        return int(self.plan.pressure_pages)

    def want_crash(self, step: int) -> bool:
        """True when a round-boundary crash is due (raise before any of
        this round's admission or journal writes)."""
        hit = self._due(self._crashes, step)
        self.crashes_fired += hit
        return hit

    def want_crash_after(self, step: int) -> bool:
        """True when a mid-segment crash is due (raise after the segment
        readback, before the journal flush — the torn-write window)."""
        hit = self._due(self._crashes_after, step)
        self.crashes_fired += hit
        return hit

    def straggle(self, step: int) -> float:
        """Seconds to stall before the next segment dispatch."""
        hit = self._due(self._straggles, step)
        if self.plan.straggle_prob > 0.0 \
                and self.rng.random() < self.plan.straggle_prob:
            hit = True
        if not hit:
            return 0.0
        self.straggle_events += 1
        return float(self.plan.straggle_s)


class TrainDriver:
    def __init__(self, ft: FTConfig, train_step, params, opt_state,
                 pipeline, param_shardings=None, opt_shardings=None):
        self.ft = ft
        self.step_fn = train_step
        self.params = params
        self.opt_state = opt_state
        self.pipeline = pipeline
        self.ckpt = Checkpointer(ft.ckpt_dir, keep=ft.keep)
        self.p_sh, self.o_sh = param_shardings, opt_shardings
        self.step = 0
        self.wd = StragglerWatchdog(
            factor=ft.straggler_factor,
            streak_threshold=ft.straggler_ckpt_threshold)
        self.step_times = self.wd.times        # same list, shared in place

    # -- restart ------------------------------------------------------------

    def maybe_restore(self) -> bool:
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        state = {"params": self.params, "opt": self.opt_state}
        shards = ({"params": self.p_sh, "opt": self.o_sh}
                  if self.p_sh is not None else None)
        restored, meta = self.ckpt.restore(state, step=latest,
                                           shardings=shards)
        self.params, self.opt_state = restored["params"], restored["opt"]
        self.pipeline.load_state_dict(meta["extra"]["pipeline"])
        self.step = meta["step"]
        return True

    # -- main loop ----------------------------------------------------------

    @property
    def straggler_events(self) -> int:
        return self.wd.events

    def _watchdog(self, dt: float):
        verdict = self.wd.observe(dt)
        if verdict.straggler:
            print(f"[ft] straggler: step {self.step} took {dt:.3f}s "
                  f"(median {verdict.median:.3f}s)", flush=True)
            if verdict.persistent:
                print("[ft] persistent straggler -> protective "
                      "checkpoint", flush=True)
                self._save()

    def _save(self, blocking: bool = False):
        if getattr(self, "_last_saved", None) == self.step:
            if blocking:
                self.ckpt.wait()
            return
        self._last_saved = self.step
        self.ckpt.save(self.step,
                       {"params": self.params, "opt": self.opt_state},
                       extra={"pipeline": self.pipeline.state_dict()},
                       blocking=blocking)

    def run(self, num_steps: int, log_every: int = 10):
        metrics = {}
        while self.step < num_steps:
            if self.ft.inject_failure_at is not None \
                    and self.step == self.ft.inject_failure_at:
                self.ckpt.wait()
                raise RuntimeError(f"injected node failure at step "
                                   f"{self.step}")
            batch = self.pipeline.next()
            t0 = time.time()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            self._watchdog(time.time() - t0)
            self.step += 1
            if self.step % self.ft.ckpt_every == 0:
                self._save()
            if log_every and self.step % log_every == 0:
                print(f"[train] step {self.step} "
                      f"loss {float(metrics['loss']):.4f} "
                      f"({self.step_times[-1]:.2f}s)", flush=True)
        self._save(blocking=True)
        self.ckpt.wait()
        return metrics
