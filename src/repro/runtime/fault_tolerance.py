"""Fault-tolerant training driver (1000+ node posture).

Mechanisms (each unit-tested; the container is single-process, so "node
failure" is injected, but every code path is the real one):

- **Checkpoint/restart**: async checkpoints every ``ckpt_every`` steps
  (params + optimizer + data-pipeline state); on (re)start the driver
  restores the latest checkpoint and replays the data pipeline to the
  exact step — bitwise-identical continuation (tested).
- **Elastic re-mesh**: checkpoints are mesh-independent; ``run()`` accepts
  any mesh, so a job checkpointed on 2 pods restarts on 1 (or 4) with the
  same model state (re-sharded on restore).
- **Straggler mitigation**: a step-time watchdog tracks a robust moving
  median; steps slower than ``straggler_factor``× median are logged and
  counted. On a real fleet this signal feeds the controller that evicts /
  re-shards around the slow host (here: surfaced in ``stats`` and the
  log). Persistent stragglers trigger a checkpoint so any subsequent
  eviction loses zero work.
- **Crash safety**: checkpoint writes are atomic (tmp+rename); SIGTERM-
  style preemption can be simulated with ``inject_failure_at``.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.checkpoint.checkpointing import Checkpointer


@dataclasses.dataclass
class FTConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    straggler_factor: float = 2.0
    straggler_ckpt_threshold: int = 3     # consecutive slow steps
    inject_failure_at: int | None = None  # simulate preemption (tests)


class TrainDriver:
    def __init__(self, ft: FTConfig, train_step, params, opt_state,
                 pipeline, param_shardings=None, opt_shardings=None):
        self.ft = ft
        self.step_fn = train_step
        self.params = params
        self.opt_state = opt_state
        self.pipeline = pipeline
        self.ckpt = Checkpointer(ft.ckpt_dir, keep=ft.keep)
        self.p_sh, self.o_sh = param_shardings, opt_shardings
        self.step = 0
        self.step_times: list[float] = []
        self.straggler_events = 0
        self._slow_streak = 0

    # -- restart ------------------------------------------------------------

    def maybe_restore(self) -> bool:
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        state = {"params": self.params, "opt": self.opt_state}
        shards = ({"params": self.p_sh, "opt": self.o_sh}
                  if self.p_sh is not None else None)
        restored, meta = self.ckpt.restore(state, step=latest,
                                           shardings=shards)
        self.params, self.opt_state = restored["params"], restored["opt"]
        self.pipeline.load_state_dict(meta["extra"]["pipeline"])
        self.step = meta["step"]
        return True

    # -- main loop ----------------------------------------------------------

    def _watchdog(self, dt: float):
        self.step_times.append(dt)
        hist = self.step_times[-32:]
        if len(hist) >= 8:
            med = float(np.median(hist[:-1]))
            if dt > self.ft.straggler_factor * med:
                self.straggler_events += 1
                self._slow_streak += 1
                print(f"[ft] straggler: step {self.step} took {dt:.3f}s "
                      f"(median {med:.3f}s)", flush=True)
                if self._slow_streak >= self.ft.straggler_ckpt_threshold:
                    print("[ft] persistent straggler -> protective "
                          "checkpoint", flush=True)
                    self._save()
                    self._slow_streak = 0
            else:
                self._slow_streak = 0

    def _save(self, blocking: bool = False):
        if getattr(self, "_last_saved", None) == self.step:
            if blocking:
                self.ckpt.wait()
            return
        self._last_saved = self.step
        self.ckpt.save(self.step,
                       {"params": self.params, "opt": self.opt_state},
                       extra={"pipeline": self.pipeline.state_dict()},
                       blocking=blocking)

    def run(self, num_steps: int, log_every: int = 10):
        metrics = {}
        while self.step < num_steps:
            if self.ft.inject_failure_at is not None \
                    and self.step == self.ft.inject_failure_at:
                self.ckpt.wait()
                raise RuntimeError(f"injected node failure at step "
                                   f"{self.step}")
            batch = self.pipeline.next()
            t0 = time.time()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            self._watchdog(time.time() - t0)
            self.step += 1
            if self.step % self.ft.ckpt_every == 0:
                self._save()
            if log_every and self.step % log_every == 0:
                print(f"[train] step {self.step} "
                      f"loss {float(metrics['loss']):.4f} "
                      f"({self.step_times[-1]:.2f}s)", flush=True)
        self._save(blocking=True)
        self.ckpt.wait()
        return metrics
