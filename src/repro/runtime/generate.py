"""Batched autoregressive generation: quantized prefill → fused on-device
decode through the int8 KV caches.

The serving loop the launchers and examples share: one jitted prefill
over the whole prompt batch (streaming ITA attention, caches written
once), then **one** jitted ``lax.scan`` over all decode steps — the
carry ``(caches, tok, pos, key, done)`` lives on device, sampling
(greedy or temperature) happens on device with a threaded PRNG, and the
whole ``(B, gen)`` token block returns in a single dispatch. No host
round-trip per generated token: ITA's streaming softmax minimizes data
movement inside the kernel, and the fused loop extends that to the
serving dataflow around it.

    from repro.runtime.generate import generate
    res = generate(params, cfg, prompts, gen=32)
    res.tokens          # (B, gen) int32
    res.decode_tok_s    # decode throughput (live sequences only)

Ragged batches: pass ``prompt_lengths`` (B,) for right-padded prompts —
each sequence prefills, positions and decodes at its own length through
the per-row kernel meta (no padding to the longest prompt's position).
``loop="stepwise"`` keeps the legacy per-step host loop (one dispatch
per token) as the parity/benchmark reference.
"""

from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

LOOPS = ("fused", "stepwise")


@functools.lru_cache(maxsize=32)
def _steps(cfg):
    """Jitted prefill/decode steps, cached per (hashable, frozen) config so
    repeated generate() calls reuse compilations."""
    from repro.launch.steps import make_decode_step, make_prefill_step
    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(2,))
    return prefill, decode


@functools.lru_cache(maxsize=32)
def _gen_loop(cfg, gen, sample, eos_id, pad_id, early_exit):
    """Jitted fused generation loop, cached per static shape of the loop.
    The caches carry is donated — the ring buffers update in place across
    the whole scan."""
    from repro.launch.steps import make_generate_loop
    loop = make_generate_loop(cfg, gen=gen, sample=sample, eos_id=eos_id,
                              pad_id=pad_id, early_exit=early_exit)
    return jax.jit(loop, donate_argnums=(2,))


@dataclasses.dataclass
class GenerateResult:
    tokens: jax.Array            # (B, gen) generated token ids
    prefill_s: float             # wall-clock of the prefill step
    decode_s: float              # wall-clock of all decode steps
    decode_steps: int            # steps actually run (< gen-1 on early exit)
    n_decode_tokens: int         # decode tokens from *live* sequences

    @property
    def decode_tok_s(self) -> float:
        return self.n_decode_tokens / max(self.decode_s, 1e-9)


def _validate_caches(caches, cfg, batch: int, max_len: int):
    """A reused ``caches=`` pytree must match what this call would have
    allocated — silently decoding into wrong-capacity rings corrupts
    positions/eviction."""
    from repro.models import init_caches
    expected = jax.eval_shape(functools.partial(init_caches, cfg, batch,
                                                max_len))
    exp_leaves, exp_tree = jax.tree_util.tree_flatten(expected)
    got_leaves, got_tree = jax.tree_util.tree_flatten(caches)
    if exp_tree != got_tree:
        raise ValueError(
            f"caches= structure does not match init_caches(cfg, batch="
            f"{batch}, max_len={max_len}) for {cfg.name!r} — pass the "
            f"max_len the caches were allocated with")
    for e, g in zip(exp_leaves, got_leaves):
        if e.shape != g.shape or e.dtype != g.dtype:
            raise ValueError(
                f"caches= leaf mismatch: expected {e.shape}/{e.dtype}, got "
                f"{g.shape}/{g.dtype} — reused caches must match this "
                f"call's batch ({batch}) and max_len ({max_len})")


def _validate_ragged(cfg, prompt_lengths, prompt_len: int):
    if not cfg.causal:
        raise ValueError("ragged prompts need causal attention (pad "
                         "columns must be invisible to valid rows)")
    kinds = {k for pat, _ in cfg.layer_groups for k in pat}
    recurrent = kinds - {"attn", "local", "swa", "enc", "cross",
                         "attn_cross"}
    if recurrent:
        raise ValueError(
            f"ragged prompts are attention-only (recurrent blocks "
            f"{sorted(recurrent)} would roll pad tokens into their state)")
    # every ring must hold the whole padded prompt (per-row eviction of a
    # padded prefill would need per-row rolls); window kinds cap capacity
    for kind, cap in (("swa", cfg.window), ("local", cfg.local_window)):
        if kind in kinds and cap < prompt_len:
            raise ValueError(
                f"ragged prompts need ring capacity >= the padded prompt "
                f"length; {kind!r} blocks cap it at {kind}-window {cap} < "
                f"prompt_len {prompt_len} — shorten/split the prompts")
    lengths = jnp.asarray(prompt_lengths, jnp.int32)
    if lengths.ndim != 1:
        raise ValueError("prompt_lengths must be a (B,) vector")
    lnp = np.asarray(lengths)
    if lnp.min() < 1 or lnp.max() > prompt_len:
        raise ValueError(f"prompt_lengths must lie in [1, {prompt_len}] "
                         f"(the padded prompt width); got {lnp.tolist()}")
    return lengths


def generate(params, cfg, prompts, gen: int, *, frontend=None,
             temperature: float = 0.0, key=None, max_len: int | None = None,
             caches=None, prompt_lengths=None, eos_id: int | None = None,
             pad_id: int = 0, loop: str = "fused",
             early_exit: bool = False) -> GenerateResult:
    """Prefill the prompt batch, then decode ``gen`` tokens on-device.

    ``prompts`` (B, S) int32, right-padded when ``prompt_lengths`` (B,)
    declares a ragged batch. ``max_len`` sizes the KV ring buffers
    (default S + gen; smaller values window-evict — a multiple of the
    decode kernel's 128-wide KV block avoids a per-step pad copy of the
    ring when capacity exceeds one block). Pass ``caches`` to reuse
    pre-allocated buffers across calls (validated against batch/max_len).
    ``eos_id``: sequences that emit it are masked to
    ``pad_id`` and stop counting toward ``decode_tok_s``; with
    ``early_exit=True`` decoding stops once every sequence finished
    (fused: a ``lax.while_loop`` instead of the scan; stepwise: a host
    check per step). ``loop="stepwise"`` runs the per-token host loop
    instead (parity/benchmark reference — bit-identical tokens to the
    fused loop).
    """
    from repro.launch.steps import advance_step, sample_token
    from repro.models import init_caches

    if loop not in LOOPS:
        raise ValueError(f"loop={loop!r} not in {LOOPS}")
    if early_exit and eos_id is None:
        raise ValueError("early_exit needs an eos_id to exit on")
    b, prompt_len = prompts.shape
    if gen <= 0:
        return GenerateResult(tokens=jnp.zeros((b, 0), jnp.int32),
                              prefill_s=0.0, decode_s=0.0, decode_steps=0,
                              n_decode_tokens=0)
    # A capacity > 128 that is not a block_kv multiple makes the kernel
    # plumbing pad-copy the ring per step; rounding up here is NOT free
    # either (bigger scan-carry copies cost more than the pad on CPU) —
    # callers chasing peak decode tok/s should pass a block-multiple
    # max_len and let the ring window-evict.
    max_len = max_len or prompt_len + gen
    prefill, decode = _steps(cfg)
    if caches is None:
        caches = init_caches(cfg, b, max_len=max_len)
    else:
        _validate_caches(caches, cfg, b, max_len)
    lengths = None
    if prompt_lengths is not None:
        lengths = _validate_ragged(cfg, prompt_lengths, prompt_len)

    sample = temperature > 0.0 and key is not None
    temperature = jnp.asarray(temperature if sample else 1.0, jnp.float32)

    t0 = time.perf_counter()
    logits, caches = prefill(params, prompts, caches, frontend, lengths)
    tok, key = sample_token(logits, key, temperature, sample=sample)
    jax.block_until_ready(tok)
    t_prefill = time.perf_counter() - t0

    # decode starts each sequence at its own stream position
    pos0 = lengths if lengths is not None \
        else jnp.full((b,), prompt_len, jnp.int32)

    t0 = time.perf_counter()
    if loop == "fused":
        run = _gen_loop(cfg, gen, sample, eos_id, pad_id, early_exit)
        rest, n_dec, steps_run, caches = run(params, tok, caches, pos0, key,
                                             temperature, frontend)
        tokens = jnp.concatenate([tok, rest], axis=1)
        jax.block_until_ready(tokens)
        n_decode, steps_run = int(n_dec), int(steps_run)
    else:                                   # stepwise host-loop reference
        done = (tok[:, 0] == eos_id) if eos_id is not None \
            else jnp.zeros((b,), jnp.bool_)
        out, pos, steps_run = [tok], pos0, 0
        n_dec = jnp.zeros((), jnp.int32)    # device-side (no per-step sync)
        for _ in range(gen - 1):
            if early_exit and bool(jnp.all(done)):   # opt-in per-step sync
                break
            steps_run += 1
            logits, caches = decode(params, tok, caches, pos, frontend)
            tok, key, done, n_dec = advance_step(
                logits, key, temperature, done, n_dec, sample=sample,
                eos_id=eos_id, pad_id=pad_id)
            out.append(tok)
            pos = pos + 1
        if len(out) < gen:                  # early exit: the rest is pad
            out.append(jnp.full((b, gen - len(out)), pad_id, jnp.int32))
        tokens = jnp.concatenate(out, axis=1)
        jax.block_until_ready(tokens)
        n_decode = int(n_dec)
    t_decode = time.perf_counter() - t0

    return GenerateResult(tokens=tokens, prefill_s=t_prefill,
                          decode_s=t_decode, decode_steps=steps_run,
                          n_decode_tokens=n_decode)
