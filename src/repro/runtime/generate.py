"""Batched autoregressive generation: quantized prefill → fused on-device
decode through the int8 KV caches.

The serving loop the launchers and examples share: one jitted prefill
over the whole prompt batch (streaming ITA attention, caches written
once), then **one** jitted ``lax.scan`` over all decode steps — the
carry ``(caches, tok, pos, key, done)`` lives on device, sampling
(greedy or temperature) happens on device with a threaded PRNG, and the
whole ``(B, gen)`` token block returns in a single dispatch. No host
round-trip per generated token: ITA's streaming softmax minimizes data
movement inside the kernel, and the fused loop extends that to the
serving dataflow around it.

    from repro.runtime.generate import generate
    res = generate(params, cfg, prompts, gen=32)
    res.tokens          # (B, gen) int32
    res.decode_tok_s    # decode throughput (live sequences only)

Ragged batches: pass ``prompt_lengths`` (B,) for right-padded prompts —
each sequence prefills, positions and decodes at its own length through
the per-row kernel meta (no padding to the longest prompt's position).
``loop="stepwise"`` keeps the legacy per-step host loop (one dispatch
per token) as the parity/benchmark reference. ``paged=True`` swaps the
per-sequence rings for shared paged KV pools (bit-identical tokens).

``serve_continuous`` is the continuous-batching server on top: a fixed-
slot batch over the paged pool, fused ``lax.scan`` segments with host
admission between them — finished sequences release their pages, and
arrived prompts enter via **chunked prefill** (default): admission only
enqueues token ids, the segments prefill them chunk-by-chunk straight
into pool pages, interleaved with decode under a decode-maximal token
budget. The stop-the-world PR-4 path survives as ``admission="stall"``.
``prefix_sharing=True`` adds copy-on-write KV prefix sharing: a host
``PrefixIndex`` maps page-aligned prompt chunks to the physical pages
already holding their bytes, admission adopts matching pages (+1
refcount, zero prefill) and chunked prefill starts at the first unshared
token. Throughput is sustained tok/s over the whole arrival trace
(DESIGN.md §Paged KV + continuous-batching dataflow, §Chunked-prefill
dataflow, §Prefix sharing + copy-on-write dataflow).
"""

from __future__ import annotations

import dataclasses
import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

LOOPS = ("fused", "stepwise")


@functools.lru_cache(maxsize=32)
def _steps(cfg):
    """Jitted prefill/decode steps, cached per (hashable, frozen) config so
    repeated generate() calls reuse compilations."""
    from repro.launch.steps import make_decode_step, make_prefill_step
    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(2,))
    return prefill, decode


@functools.lru_cache(maxsize=32)
def _gen_loop(cfg, gen, sample, eos_id, pad_id, early_exit):
    """Jitted fused generation loop, cached per static shape of the loop.
    The caches carry is donated — the ring buffers update in place across
    the whole scan."""
    from repro.launch.steps import make_generate_loop
    loop = make_generate_loop(cfg, gen=gen, sample=sample, eos_id=eos_id,
                              pad_id=pad_id, early_exit=early_exit)
    return jax.jit(loop, donate_argnums=(2,))


@dataclasses.dataclass
class GenerateResult:
    tokens: jax.Array            # (B, gen) generated token ids
    prefill_s: float             # wall-clock of the prefill step
    decode_s: float              # wall-clock of all decode steps
    decode_steps: int            # steps actually run (< gen-1 on early exit)
    n_decode_tokens: int         # decode tokens from *live* sequences

    @property
    def decode_tok_s(self) -> float:
        return self.n_decode_tokens / max(self.decode_s, 1e-9)


def _first_paged(caches):
    """First PagedKVState node in a cache pytree (period-stacked leaves),
    or None — how the serving stack sniffs the cache layout."""
    from repro.attention import PagedKVState
    for node in jax.tree.leaves(
            caches, is_leaf=lambda x: isinstance(x, PagedKVState)):
        if isinstance(node, PagedKVState):
            return node
    return None


def _paged_geometry(paged):
    """(batch, num_pages, page_size) of a period-stacked PagedKVState."""
    return (paged.page_table.shape[1], paged.k.shape[1], paged.k.shape[2])


def _validate_pool_provision(caches, batch: int, tokens_per_seq: int):
    """Lockstep generate() has no admission scheduler rationing pages, so
    an undersized pool would overdraw the on-device allocator mid-scan
    and silently double-book pages — refuse statically instead. The
    worst case is exact: every sequence grows to min(tokens, window)."""
    from repro.attention import PagedKVState
    for node in jax.tree.leaves(
            caches, is_leaf=lambda x: isinstance(x, PagedKVState)):
        if not isinstance(node, PagedKVState):
            continue
        num_pages, page = node.k.shape[1], node.k.shape[2]
        npps = node.page_table.shape[2]
        per_seq = min(-(-min(tokens_per_seq, npps * page) // page), npps)
        if batch * per_seq > num_pages - 1:
            raise ValueError(
                f"paged pool undersized for lockstep generate: {batch} "
                f"sequences x {per_seq} pages each > {num_pages - 1} "
                f"allocatable pages (num_pages={num_pages}, page_size="
                f"{page}) — raise num_pages, or serve through "
                f"serve_continuous, whose admission scheduler rations an "
                f"oversubscribed pool")


def _validate_caches(caches, cfg, batch: int, max_len: int):
    """A reused ``caches=`` pytree must match what this call would have
    allocated — silently decoding into wrong-capacity rings (or
    wrong-geometry page tables) corrupts positions/eviction/allocation.
    Paged caches are validated against the paged allocation of the same
    batch/max_len, with the mismatched field named (batch / pool size /
    page size / page-table width)."""
    from repro.models import init_caches
    paged = _first_paged(caches)
    kwargs = {}
    detail = f"batch ({batch}) and max_len ({max_len})"
    if paged is not None:
        pt_batch, num_pages, page_size = _paged_geometry(paged)
        if pt_batch != batch:
            raise ValueError(
                f"caches= batch mismatch: page tables hold {pt_batch} "
                f"slots but this call decodes batch={batch}")
        # pool size and page size are free choices (oversubscription /
        # granularity) — validate the rest of the tree against them
        kwargs = dict(paged=True, page_size=page_size, num_pages=num_pages)
        detail += (f", pool size ({num_pages} pages) and page size "
                   f"({page_size})")
    expected = jax.eval_shape(functools.partial(init_caches, cfg, batch,
                                                max_len, **kwargs))
    exp_leaves, exp_tree = jax.tree_util.tree_flatten(expected)
    got = jax.tree_util.tree_flatten_with_path(caches)[0]
    got_tree = jax.tree_util.tree_structure(caches)
    if exp_tree != got_tree:
        raise ValueError(
            f"caches= structure does not match init_caches(cfg, batch="
            f"{batch}, max_len={max_len}"
            + (", paged=True" if paged is not None else "") +
            f") for {cfg.name!r} — pass the max_len the caches were "
            f"allocated with")
    for e, (path, g) in zip(exp_leaves, got, strict=True):
        if e.shape != g.shape or e.dtype != g.dtype:
            field = jax.tree_util.keystr(path)
            raise ValueError(
                f"caches= leaf {field} mismatch: expected "
                f"{e.shape}/{e.dtype}, got {g.shape}/{g.dtype} — reused "
                f"caches must match this call's {detail}")


def _validate_ragged(cfg, prompt_lengths, prompt_len: int):
    if not cfg.causal:
        raise ValueError("ragged prompts need causal attention (pad "
                         "columns must be invisible to valid rows)")
    kinds = {k for pat, _ in cfg.layer_groups for k in pat}
    recurrent = kinds - {"attn", "local", "swa", "enc", "cross",
                         "attn_cross"}
    if recurrent:
        raise ValueError(
            f"ragged prompts are attention-only (recurrent blocks "
            f"{sorted(recurrent)} would roll pad tokens into their state)")
    # every ring must hold the whole padded prompt (per-row eviction of a
    # padded prefill would need per-row rolls); window kinds cap capacity
    for kind, cap in (("swa", cfg.window), ("local", cfg.local_window)):
        if kind in kinds and cap < prompt_len:
            raise ValueError(
                f"ragged prompts need ring capacity >= the padded prompt "
                f"length; {kind!r} blocks cap it at {kind}-window {cap} < "
                f"prompt_len {prompt_len} — shorten/split the prompts")
    lengths = jnp.asarray(prompt_lengths, jnp.int32)
    if lengths.ndim != 1:
        raise ValueError("prompt_lengths must be a (B,) vector")
    lnp = np.asarray(lengths)
    if lnp.min() < 1 or lnp.max() > prompt_len:
        raise ValueError(f"prompt_lengths must lie in [1, {prompt_len}] "
                         f"(the padded prompt width); got {lnp.tolist()}")
    return lengths


def generate(params, cfg, prompts, gen: int, *, frontend=None,
             temperature: float = 0.0, key=None, max_len: int | None = None,
             caches=None, paged: bool = False, page_size: int = 128,
             num_pages: int | None = None, prompt_lengths=None,
             eos_id: int | None = None, pad_id: int = 0,
             loop: str = "fused", early_exit: bool = False) -> GenerateResult:
    """Prefill the prompt batch, then decode ``gen`` tokens on-device.

    ``prompts`` (B, S) int32, right-padded when ``prompt_lengths`` (B,)
    declares a ragged batch. ``max_len`` sizes the KV caches (default
    S + gen; smaller values window-evict; ``KVCacheState.init``
    block-aligns capacities above one KV block, so the decode kernels'
    per-step ring pad is statically a no-op). ``paged=True`` allocates
    the KV as shared paged pools (``PagedKVState``; bit-identical tokens
    to the ring layout at ``page_size`` = the ring's KV block) — the
    continuous-batching layout, also accepted via ``caches=``. Pass
    ``caches`` to reuse pre-allocated buffers across calls (validated
    against batch/max_len and, for paged caches, the pool geometry).
    ``eos_id``: sequences that emit it are masked to
    ``pad_id`` and stop counting toward ``decode_tok_s``; with
    ``early_exit=True`` decoding stops once every sequence finished
    (fused: a ``lax.while_loop`` instead of the scan; stepwise: a host
    check per step). ``loop="stepwise"`` runs the per-token host loop
    instead (parity/benchmark reference — bit-identical tokens to the
    fused loop).
    """
    from repro.launch.steps import advance_step, sample_token
    from repro.models import init_caches

    if loop not in LOOPS:
        raise ValueError(f"loop={loop!r} not in {LOOPS}")
    if early_exit and eos_id is None:
        raise ValueError("early_exit needs an eos_id to exit on")
    b, prompt_len = prompts.shape
    if gen <= 0:
        return GenerateResult(tokens=jnp.zeros((b, 0), jnp.int32),
                              prefill_s=0.0, decode_s=0.0, decode_steps=0,
                              n_decode_tokens=0)
    max_len = max_len or prompt_len + gen
    prefill, decode = _steps(cfg)
    if caches is None:
        caches = init_caches(cfg, b, max_len=max_len, paged=paged,
                             page_size=page_size, num_pages=num_pages)
    else:
        _validate_caches(caches, cfg, b, max_len)
    _validate_pool_provision(caches, b, prompt_len + gen)
    lengths = None
    if prompt_lengths is not None:
        lengths = _validate_ragged(cfg, prompt_lengths, prompt_len)

    sample = temperature > 0.0 and key is not None
    temperature = jnp.asarray(temperature if sample else 1.0, jnp.float32)

    t0 = time.perf_counter()
    logits, caches = prefill(params, prompts, caches, frontend, lengths)
    tok, key = sample_token(logits, key, temperature, sample=sample)
    jax.block_until_ready(tok)
    t_prefill = time.perf_counter() - t0

    # decode starts each sequence at its own stream position
    pos0 = lengths if lengths is not None \
        else jnp.full((b,), prompt_len, jnp.int32)

    t0 = time.perf_counter()
    if loop == "fused":
        run = _gen_loop(cfg, gen, sample, eos_id, pad_id, early_exit)
        rest, n_dec, steps_run, caches = run(params, tok, caches, pos0, key,
                                             temperature, frontend)
        tokens = jnp.concatenate([tok, rest], axis=1)
        jax.block_until_ready(tokens)
        n_decode, steps_run = int(n_dec), int(steps_run)
    else:                                   # stepwise host-loop reference
        done = (tok[:, 0] == eos_id) if eos_id is not None \
            else jnp.zeros((b,), jnp.bool_)
        out, pos, steps_run = [tok], pos0, 0
        n_dec = jnp.zeros((), jnp.int32)    # device-side (no per-step sync)
        for _ in range(gen - 1):
            if early_exit and bool(jnp.all(done)):   # opt-in per-step sync
                break
            steps_run += 1
            logits, caches = decode(params, tok, caches, pos, frontend)
            tok, key, done, n_dec = advance_step(
                logits, key, temperature, done, n_dec, sample=sample,
                eos_id=eos_id, pad_id=pad_id)
            out.append(tok)
            pos = pos + 1
        if len(out) < gen:                  # early exit: the rest is pad
            out.append(jnp.full((b, gen - len(out)), pad_id, jnp.int32))
        tokens = jnp.concatenate(out, axis=1)
        jax.block_until_ready(tokens)
        n_decode = int(n_dec)
    t_decode = time.perf_counter() - t0

    return GenerateResult(tokens=tokens, prefill_s=t_prefill,
                          decode_s=t_decode, decode_steps=steps_run,
                          n_decode_tokens=n_decode)


# ---------------------------------------------------------------------------
# Continuous batching: paged pool + admission scheduler + fused segments
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServeRequest:
    """One serving request of an arrival trace. ``arrival`` is in virtual
    time units = decode steps (the scheduler's clock), ``gen`` counts all
    generated tokens including the one sampled from prefill.
    ``priority`` is the request's SLO class (higher = more urgent): it
    orders admission, steers the mixed segments' prompt-chunk budget and
    selects preemption victims (strictly lower classes only).
    ``request_id`` is a stable identity for journaling: re-submitting
    the same id after a crash recovery dedupes against the journal (a
    completed request replays instead of serving twice). Defaults to
    ``req-<trace index>`` when unset; ids must be unique per trace."""
    prompt: Any                      # (S,) int32 token ids
    gen: int
    arrival: int = 0
    priority: int = 0
    request_id: str | None = None


@dataclasses.dataclass
class CompletedRequest:
    index: int                       # position in the submitted trace
    arrival: int                     # virtual (step) arrival time
    admitted_step: int               # step count when FIRST admitted
    finished_step: int               # step count when the slot freed
    arrived_s: float                 # wall-clock when first admittable
    finished_s: float                # wall-clock at the freeing boundary
    tokens: Any                      # (gen,) int32 generated ids
    first_token_s: float = 0.0       # wall-clock of the first emitted token
    priority: int = 0                # the request's SLO class
    preemptions: int = 0             # times this request was evicted
    replayed: bool = False           # rebuilt from the journal, not served

    @property
    def latency_s(self) -> float:
        return self.finished_s - self.arrived_s

    @property
    def ttft_s(self) -> float:
        """Time to first token: queue wait + prompt processing."""
        return self.first_token_s - self.arrived_s


@dataclasses.dataclass
class ServeResult:
    completed: list                  # CompletedRequest, completion order
    wall_s: float                    # whole-trace wall clock
    steps: int                       # decode steps executed
    segments: int                    # fused segments dispatched
    admission_rounds: int            # admission dispatches
    page_util: list                  # (step, fraction of pool pages held)
    prefill_stall_s: float = 0.0     # wall spent in stop-the-world prefill
                                     # dispatches (0 under chunked admission)
    prefill_tokens: int = 0          # prompt tokens actually prefilled
    shared_prefix_tokens: int = 0    # prompt tokens skipped via adoption
    prefix_hits: int = 0             # admissions that adopted >= 1 page
    preemptions: int = 0             # victim evictions (incl. fault kills)
    straggler_segments: int = 0      # segments the watchdog flagged slow
    drained: bool = False            # graceful drain cut the serve short
    recovered: bool = False          # this serve resumed from a journal
    restored_from_snapshot: bool = False   # warm pool/index restore hit
    replayed_tokens: int = 0         # tokens recovered from the journal
    snapshot_bytes: int = 0          # last snapshot's on-disk leaf bytes
    recovery_s: float = 0.0          # wall spent in replay + restore
    aging_steps: int | None = None   # starvation-aging period (None = off)
    max_class: int = 0               # highest SLO class in the trace

    @property
    def total_tokens(self) -> int:
        return sum(int(np.asarray(c.tokens).size) for c in self.completed)

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of completed requests admitted with a shared prefix."""
        return self.prefix_hits / max(len(self.completed), 1)

    @property
    def tok_s(self) -> float:
        return self.total_tokens / max(self.wall_s, 1e-9)

    @property
    def prefill_stall_frac(self) -> float:
        return self.prefill_stall_s / max(self.wall_s, 1e-9)

    def _quantile(self, values, q: float) -> float:
        vals = sorted(values)
        if not vals:
            return 0.0
        return vals[min(int(q * len(vals)), len(vals) - 1)]

    def _of_class(self, priority):
        return (c for c in self.completed
                if priority is None or c.priority == priority)

    def latency_quantile(self, q: float, priority: int | None = None):
        return self._quantile(
            (c.latency_s for c in self._of_class(priority)), q)

    def ttft_quantile(self, q: float, priority: int | None = None):
        return self._quantile(
            (c.ttft_s for c in self._of_class(priority)), q)

    def admission_delay_quantile(self, q: float,
                                 priority: int | None = None):
        """Virtual-time TTFT proxy: decode steps from arrival to first
        admission. Deterministic (no wall clock), so SLO assertions on it
        are machine-independent — the bench smoke gate."""
        return self._quantile(
            (c.admitted_step - c.arrival for c in self._of_class(priority)),
            q)

    def class_summary(self) -> dict:
        """Per-SLO-class accounting: count, total preemptions suffered,
        p95 TTFT / latency / admission delay, the worst admission delay
        actually suffered, and — when starvation aging is on — the
        class's ``aging_bound_steps``: the virtual-step horizon at which
        a waiting request of this class reaches the priority cap and can
        no longer be overtaken by any newly arrived class (the aging
        guarantee property-tested in tests)."""
        out = {}
        for c in self.completed:
            d = out.setdefault(c.priority, {"n": 0, "preemptions": 0})
            d["n"] += 1
            d["preemptions"] += c.preemptions
        for prio, d in out.items():
            d["p95_ttft_s"] = self.ttft_quantile(0.95, priority=prio)
            d["p95_latency_s"] = self.latency_quantile(0.95, priority=prio)
            d["p95_admit_delay_steps"] = self.admission_delay_quantile(
                0.95, priority=prio)
            d["max_admit_delay_steps"] = max(
                (c.admitted_step - c.arrival for c in self._of_class(prio)),
                default=0)
            if self.aging_steps is not None:
                d["aging_bound_steps"] = self.aging_steps * (
                    self.max_class + 1 - prio)
        return out


@functools.lru_cache(maxsize=32)
def _serve_segment_fn(cfg, segment, sample, eos_id, pad_id, chunk=None,
                      budget=None, mixed_steps=None):
    from repro.launch.steps import make_serve_segment
    seg = make_serve_segment(cfg, segment=segment, sample=sample,
                             eos_id=eos_id, pad_id=pad_id, chunk=chunk,
                             budget=budget, mixed_steps=mixed_steps)
    return jax.jit(seg, donate_argnums=(1, 2))


def _is_kv_state(x):
    from repro.attention import KVCacheState, PagedKVState
    return isinstance(x, (KVCacheState, PagedKVState))


@functools.partial(jax.jit, donate_argnums=(0,))
def _release_slots(caches, finished):
    """Return every finished slot's pages (all layers) to the free
    stacks."""
    from repro.attention import PagedKVState

    def rel(node):
        if isinstance(node, PagedKVState):
            return jax.vmap(lambda p: p.release(finished))(node)
        return node

    return jax.tree.map(rel, caches, is_leaf=_is_kv_state)


def _admit_chunked(state, slot_ids, prompts, lengths, gens, req_keys,
                   shared=None, prios=None):
    """Chunked admission state write — lives in ``launch.steps`` next to
    ``ServeSlotState``; kept callable from here for the serve loop and
    its tests."""
    from repro.launch.steps import admit_chunked
    return admit_chunked(state, slot_ids, prompts, lengths, gens, req_keys,
                         shared, prios)


def _preempt_rows(state, mask):
    """One-dispatch victim eviction of every slot in ``mask`` — see
    ``launch.steps.preempt_rows``."""
    from repro.launch.steps import preempt_rows
    return preempt_rows(state, mask)


def _admit_stall(state, slot_ids, lengths, tok0, new_done, new_rem,
                 req_keys, prios=None):
    from repro.launch.steps import admit_stall
    return admit_stall(state, slot_ids, lengths, tok0, new_done, new_rem,
                       req_keys, prios)


@functools.partial(jax.jit, donate_argnums=(0,))
def _adopt_prefix_slots(caches, slot_ids, pages, n_pages, n_tokens):
    """Point freshly admitted slots' leading page-table entries at the
    shared prefix pages (every layer's pool — the allocators run in
    lockstep, so one page id is valid for all of them). Rows with
    ``slot_ids[i] < 0`` or ``n_pages[i] == 0`` are no-ops."""
    from repro.attention import PagedKVState

    def one(node):
        if isinstance(node, PagedKVState):
            return jax.vmap(lambda p: p.adopt_prefix(slot_ids, pages,
                                                     n_pages, n_tokens))(node)
        return node

    return jax.tree.map(one, caches, is_leaf=_is_kv_state)


@functools.partial(jax.jit, donate_argnums=(0,))
def _pin_pages(caches, pages):
    """+1 refcount on ``pages`` (flat, -1 padded) in every layer's pool —
    the prefix index's registration pin."""
    from repro.attention import PagedKVState

    def one(node):
        if isinstance(node, PagedKVState):
            return jax.vmap(lambda p: p.incref_pages(pages))(node)
        return node

    return jax.tree.map(one, caches, is_leaf=_is_kv_state)


@functools.partial(jax.jit, donate_argnums=(0,))
def _unpin_pages(caches, pages):
    """Drop the index pin on ``pages`` (flat, -1 padded); pages reaching
    refcount zero return to every layer's free stack."""
    from repro.attention import PagedKVState

    def one(node):
        if isinstance(node, PagedKVState):
            return jax.vmap(lambda p: p.decref_pages(pages))(node)
        return node

    return jax.tree.map(one, caches, is_leaf=_is_kv_state)


def _check_paged_invariants(caches, pins=None):
    """Debug-mode host check: run ``PagedKVState.check_invariants`` on
    every layer of every paged pool in the cache tree (``pins``: the
    host-side {page: count} pin ledger). Slow — device_get of the full
    bookkeeping state — gated behind ``debug_invariants`` / the
    ``ITA_PAGED_DEBUG`` env var in ``serve_continuous``."""
    import dataclasses as dc

    from repro.attention import PagedKVState
    for node in jax.tree.leaves(caches, is_leaf=_is_kv_state):
        if not isinstance(node, PagedKVState):
            continue
        layers = node.k.shape[0]
        for i in range(layers):
            layer = PagedKVState(**{
                f.name: (None if getattr(node, f.name) is None
                         else getattr(node, f.name)[i])
                for f in dc.fields(node)})
            layer.check_invariants(pins=pins)


@functools.partial(jax.jit, donate_argnums=(0,))
def _adopt_prompts(pool, temp, slot_ids, lengths):
    """Copy freshly prefilled (ring) K/V bytes into pool pages at the
    assigned slots — the admission hand-off. ``slot_ids`` (n,) int32,
    negative entries are padding rows of the fixed-width admission batch
    and are dropped. The ring holds exactly the quantized bytes decode
    will read, so adopted pages are bit-identical to having prefilled
    into the pool directly."""
    from repro.attention import PagedKVState

    def one(p, t):
        if isinstance(p, PagedKVState):
            return jax.vmap(
                lambda pp, tt: pp.write_prompts(tt.k, tt.v, lengths=lengths,
                                                slots=slot_ids))(p, t)
        return p

    return jax.tree.map(one, pool, temp, is_leaf=_is_kv_state)


def _validate_serve_cfg(cfg, admission: str = "stall", chunk: int = 1):
    from repro import attention as ATT
    from repro.models.attention import make_spec
    kinds = {k for pat, _ in cfg.layer_groups for k in pat}
    if not kinds <= {"attn", "local", "swa"}:
        raise ValueError(
            f"continuous batching serves decoder-only attention stacks "
            f"(got block kinds {sorted(kinds)})")
    if not cfg.causal:
        raise ValueError("continuous batching needs causal attention")
    specs = [("paged decode", dict(q_len=1))]
    if admission == "chunked":
        # the mixed segment's ragged chunked-prefill call must be servable
        specs.append(("ragged chunked-prefill paged decode",
                      dict(q_len=chunk, ragged_q=True)))
    for kind in kinds:
        window = {"attn": 0, "local": cfg.local_window,
                  "swa": cfg.window}[kind]
        for what, kw in specs:
            spec = make_spec(cfg, mode="decode", causal=True, window=window,
                             layout="bhsd_paged", **kw)
            if not ATT.list_backends(spec):
                reasons = "; ".join(f"{n}: {r}" for n, r in
                                    ATT.backend_reasons(spec).items())
                raise ValueError(
                    f"no attention backend serves the {what} spec for "
                    f"{kind!r} blocks of {cfg.name!r} — {reasons}")


ADMISSIONS = ("chunked", "stall")


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def serve_continuous(params, cfg, requests, *, slots: int,
                     segment: int = 16, max_len: int | None = None,
                     page_size: int = 128, num_pages: int | None = None,
                     temperature: float = 0.0, key=None,
                     eos_id: int | None = None, pad_id: int = 0,
                     admission: str = "chunked", chunk_size: int = 32,
                     token_budget: int | None = None,
                     prefix_sharing: bool = False,
                     preemption: bool = False, faults=None,
                     straggler_factor: float = 2.0,
                     debug_invariants: bool | None = None,
                     audit=None, journal_dir: str | None = None,
                     snapshot_every: int = 0, resume: bool = False,
                     drain=None, drain_timeout: float | None = None,
                     aging_steps: int | None = None) -> ServeResult:
    """Serve an arrival trace with continuous batching over a paged pool.

    A fixed-slot batch (``slots`` wide) runs fused ``lax.scan`` segments
    of ``segment`` steps; between segments the host scheduler (1)
    releases the pages of every finished sequence back to the shared
    pool, (2) admits arrived requests into freed slots, and (3) reads
    back the segment's tokens. Virtual time = decode steps (request
    ``arrival`` is in steps); throughput is **sustained**: total
    generated tokens over the whole trace wall clock.

    ``admission`` selects how prompts enter the batch:

    - ``"chunked"`` (default) — admission only *enqueues* the prompt's
      token ids into the slot's ``ServeSlotState`` (one tiny state
      dispatch) and reserves pages; the prompt is then prefilled in
      ``chunk_size``-token chunks *inside* the fused segments, written
      page-native via ``append_chunk``, interleaved with decode steps
      under a decode-maximal per-step ``token_budget`` (default
      ``slots - 1 + chunk_size``: every decoding slot advances every
      step, the leftover budget feeds prompt chunks). Decode throughput
      never stops for a long prompt and the ring scratch + bytes-copy
      adoption of the stall path never runs.
    - ``"stall"`` — the PR-4 stop-the-world path, kept for A/B parity:
      admission runs one fixed-shape ragged prefill over a ring scratch,
      bytes-copies the K/V into pool pages (``_adopt_prompts``), and all
      decode slots wait. Its stop time is reported as
      ``ServeResult.prefill_stall_s``.

    Admission reserves each request's worst-case page need
    (``ceil((len + gen) / page_size)``, capped at the per-slot window) up
    front, so the on-device allocator can never be overdrawn mid-segment
    — the invariant ``tests/test_paged.py`` property-checks. ``audit``
    (testing hook) is called after every admission round with the live
    cache pytree, the slot→request map and the host pin ledger.

    ``prefix_sharing=True`` (chunked admission only) shares identical
    prompt prefixes across requests through the paged pool: as prompts
    prefill, every *full* page of prompt tokens is registered in a host
    ``PrefixIndex`` (chain hash of page-aligned token chunks → physical
    page) and pinned (+1 refcount) so it outlives its request; a later
    admission whose prompt walks the same chain *adopts* those pages
    (``PagedKVState.adopt_prefix``) instead of reserving and prefilling
    them — near-zero prefill cost for the shared tokens and a smaller
    reservation, so more concurrent requests fit the same arena. At
    least one prompt token always prefills (the sampled first token
    needs live logits), and only requests that cannot wrap their window
    (``len + gen <= capacity``) share or donate, so adopted pages are
    never overwritten in serving — copy-on-write in the append paths
    still guards the general case at the state level. Under page
    pressure the index evicts idle pinned pages (LRU, active adopters
    protected) before stalling the head of the queue.

    **Overload survival** (DESIGN.md §Overload survival):
    ``preemption=True`` (chunked admission only) lets admission make
    room for a higher-priority arrival when the pool or the slots are
    exhausted: victim slots — strictly lower ``ServeRequest.priority``
    only, lowest class first, then most reserved pages — are evicted in
    one ``preempt_rows`` dispatch, their pages return to the pool
    (pinned prefix pages decref, never free), and their requests
    re-enqueue carrying the prompt *plus every token generated so far*.
    The resumed request re-prefills that stream through ordinary chunked
    admission (near-free when its pages are still registered in the
    prefix index), its slot PRNG stream is restored from a snapshot
    taken at eviction, and its remaining budget shrinks by what it
    already emitted — so greedy *and* sampled outputs are bit-identical
    to never having been preempted. Only requests whose full stream fits
    the per-slot window (``len + gen <= capacity``) are preemptable.
    ``faults`` (a ``runtime.fault_tolerance.ServeFaultPlan``) injects
    seeded overload: forced slot kills (the same eviction/resume path,
    regardless of ``preemption``), phantom page-pressure spikes
    subtracted from the admission budget, and sleeps before segment
    dispatches that the segment watchdog (``StragglerWatchdog`` at
    ``straggler_factor`` x median, shared with the train driver) must
    flag — counted in ``ServeResult.straggler_segments``.

    Bit-exactness:
    a page's K/V bytes are a pure function of its tokens and
    page-aligned position, and chunk boundaries don't change the fused
    kernels' arithmetic, so shared-path tokens are bit-identical to the
    unshared path (same conditions as chunked ≡ solo parity:
    ``page_size`` = fused ``block_kv`` 128 + fused-family prefill).
    ``debug_invariants`` (or env ``ITA_PAGED_DEBUG=1``) host-checks the
    allocator partition + refcount invariants after every admission
    round.

    **Crash safety** (DESIGN.md §Crash recovery): ``journal_dir``
    enables a write-ahead request journal (``runtime.journal``) —
    admissions, per-request emitted-token high-water marks and PRNG key
    snapshots flushed at every segment boundary, completions — plus,
    with ``snapshot_every=N``, a ``Checkpointer`` snapshot of the paged
    pool + prefix index every N segments. ``resume=True`` replays the
    journal first: completed requests (matched by
    ``ServeRequest.request_id``) return as replayed
    ``CompletedRequest``s without serving twice, and every unfinished
    request is rebuilt as a pending ``prompt ++ emitted`` stream with
    its journaled key snapshot and re-admitted through the ordinary
    preemption-resume path — greedy *and* sampled tokens bit-identical
    to a never-crashed serve. A usable snapshot (checksums, version and
    geometry verified; post-restore allocator invariants checked) warm-
    starts the prefix index so shared prompts skip re-prefilling; any
    snapshot problem degrades to a cold start from the journal alone —
    never to wrong tokens. ``drain`` (a ``journal.ServeDrain``) stops
    admission and finishes in-flight work — or, past ``drain_timeout``
    seconds, stops at the next boundary with progress journaled — then
    takes a final snapshot. ``aging_steps`` turns on starvation aging:
    a waiting request's effective class grows by one every
    ``aging_steps`` virtual steps, capped one above the trace's highest
    class, giving the low class a *bounded* worst-case admission delay
    (``class_summary()['aging_bound_steps']``).

    Requests decode greedily (or with temperature sampling when ``key``
    is given) until ``gen`` tokens or ``eos_id``. Greedy serving is
    bit-identical to generating each request alone under **both**
    admission modes (chunked-prefill bit-exactness needs the solo prefill
    on the same KV tile schedule: ``page_size`` equal to the fused
    prefill ``block_kv``, 128, and a fused-kernel prefill backend).
    Sampled serving draws each request's tokens from its own PRNG stream
    (``fold_in(key, request_index)``), so outputs are independent of
    admission interleaving and co-scheduled traffic.
    Returns ``ServeResult`` with per-request latency/TTFT and page-pool
    utilization samples.
    """
    from repro.launch.steps import ServeSlotState, aged_priority, \
        fold_keys, sample_token_rows
    from repro.models import init_caches

    if admission not in ADMISSIONS:
        raise ValueError(f"admission={admission!r} not in {ADMISSIONS}")
    if (preemption or faults is not None) and admission != "chunked":
        raise ValueError(
            "preemption / fault injection require admission='chunked' "
            "(victims resume through chunked re-prefill of their "
            "prompt + generated prefix)")
    _validate_serve_cfg(cfg, admission=admission,
                        chunk=max(1, chunk_size))
    requests = list(requests)
    if not requests:
        return ServeResult([], 0.0, 0, 0, 0, [])
    injector = None
    if faults is not None:
        from repro.runtime.fault_tolerance import (ServeFaultInjector,
                                                   SimulatedCrash)
        injector = ServeFaultInjector(faults)
    from repro.runtime.watchdog import StragglerWatchdog
    watchdog = StragglerWatchdog(factor=straggler_factor)
    may_preempt = preemption or (injector is not None
                                 and injector.plan.may_kill)
    prompt_pad = max(int(np.asarray(r.prompt).size) for r in requests)
    longest = max(int(np.asarray(r.prompt).size) + r.gen for r in requests)
    max_len = max_len or longest
    sample = temperature > 0.0 and key is not None
    temp_arr = jnp.asarray(temperature if sample else 1.0, jnp.float32)
    base_key = jax.random.PRNGKey(0) if key is None else key

    caches = init_caches(cfg, slots, max_len=max_len, paged=True,
                         page_size=page_size, num_pages=num_pages)
    geo = _first_paged(caches)
    pool_pages = geo.k.shape[1] - 1                # minus parking
    pages_per_seq = geo.page_table.shape[2]
    capacity = pages_per_seq * page_size

    # pending streams: what admission will actually prefill per request —
    # the original prompt, or (after a preemption) prompt + generated
    # prefix with the remaining token budget. Page need is invariant
    # across resumes (plen' + gen' == plen + gen), so only requests whose
    # whole stream fits the per-slot window are resumable, and the prompt
    # buffer must hold up to plen + gen - 1 tokens for them.
    pending = {i: (np.asarray(r.prompt, np.int32).reshape(-1), int(r.gen))
               for i, r in enumerate(requests)}
    prio_req = [int(getattr(r, "priority", 0)) for r in requests]
    resumable = [int(np.asarray(r.prompt).size) + r.gen <= capacity
                 for r in requests]
    max_class = max(prio_req, default=0)
    if aging_steps is not None and aging_steps <= 0:
        raise ValueError(f"aging_steps={aging_steps} must be positive")

    def eff_prio(i, at_step):
        return aged_priority(prio_req[i],
                             at_step - requests[i].arrival,
                             aging_steps, max_class)

    # -- write-ahead journal + replay (DESIGN.md §Crash recovery) --------
    journal = None
    fingerprint = None
    seed_emitted = {}                  # index -> journaled emitted tokens
    seed_keys = {}                     # index -> journaled PRNG snapshot
    replayed_completed = []            # CompletedRequest rebuilt, not served
    done_replayed = set()
    replayed_tokens = 0
    recovered = False
    recovery_s = 0.0
    rids = [r.request_id if r.request_id is not None else f"req-{i:06d}"
            for i, r in enumerate(requests)]
    if len(set(rids)) != len(rids):
        dup = sorted({r for r in rids if rids.count(r) > 1})
        raise ValueError(f"duplicate request_id(s): {dup} — journal "
                         f"dedupe needs ids unique per trace")
    if journal_dir is not None:
        from repro.runtime.journal import (ServeJournal, check_fingerprint,
                                           prompt_digest)
        t_rec = time.perf_counter()
        os.makedirs(journal_dir, exist_ok=True)
        jpath = os.path.join(journal_dir, "journal.jsonl")
        fingerprint = {
            "journal_version": 1, "arch": cfg.name,
            "page_size": int(page_size), "max_len": int(max_len),
            "temperature": float(temperature), "sample": bool(sample),
            "eos_id": eos_id, "pad_id": int(pad_id),
            "key": ([int(x) for x in
                     np.asarray(base_key).reshape(-1).tolist()]
                    if sample else None),
        }
        jreplay = None
        if resume and os.path.exists(jpath) and os.path.getsize(jpath):
            jreplay = ServeJournal.replay(jpath)
            if jreplay.header is None:
                raise ValueError(
                    f"{jpath}: no intact header record — not a serve "
                    f"journal (or its very first write was torn)")
            check_fingerprint(jreplay.header["fingerprint"], fingerprint)
            recovered = True
        journal = ServeJournal(jpath, fingerprint=fingerprint,
                               fresh=jreplay is None)
        for i, r in enumerate(requests):
            digest = prompt_digest(r.prompt)
            sub = jreplay.submits.get(rids[i]) if jreplay else None
            if sub is not None:
                # id dedupe: same id must mean the same request — a
                # digest/shape mismatch is id reuse, not a resume
                if (sub["digest"] != digest or sub["gen"] != int(r.gen)
                        or sub["i"] != i):
                    raise ValueError(
                        f"request_id {rids[i]!r} reused for a different "
                        f"request (journal has index {sub['i']}, gen "
                        f"{sub['gen']}, digest {sub['digest']})")
            else:
                journal.append({"t": "submit", "rid": rids[i], "i": i,
                                "digest": digest, "gen": int(r.gen),
                                "arrival": int(r.arrival),
                                "priority": prio_req[i]})
            if jreplay is None:
                continue
            toks = [int(x) for x in jreplay.emitted.get(rids[i], [])]
            comp = jreplay.completes.get(rids[i])
            # a torn flush can persist the complete record but lose the
            # same boundary's progress lines — so the journaled *token
            # count*, not the record's existence, decides: short streams
            # fall to the partial-resume path and regenerate the tail
            needed = int(comp["n"]) if comp is not None else int(r.gen)
            if len(toks) >= needed:
                # finished before the crash: replay, never serve twice
                comp = comp or {}
                replayed_tokens += needed
                replayed_completed.append(CompletedRequest(
                    index=i, arrival=int(r.arrival),
                    admitted_step=int(comp.get("admitted_step", 0)),
                    finished_step=int(comp.get("finished_step", 0)),
                    arrived_s=float(comp.get("arrived_s", 0.0)),
                    finished_s=float(comp.get("finished_s", 0.0)),
                    first_token_s=float(comp.get("first_token_s", 0.0)),
                    tokens=np.asarray(toks[:needed], np.int32),
                    priority=prio_req[i],
                    preemptions=int(comp.get("preemptions", 0)),
                    replayed=True))
                done_replayed.add(i)
            elif toks and resumable[i] \
                    and (not sample or rids[i] in jreplay.keys):
                # unfinished: resume exactly as if preempted at the last
                # journaled boundary — pending = prompt ++ emitted with
                # the leftover budget, PRNG stream from the snapshot
                prompt0 = np.asarray(r.prompt, np.int32).reshape(-1)
                pending[i] = (
                    np.concatenate([prompt0,
                                    np.asarray(toks, np.int32)]),
                    int(r.gen) - len(toks))
                seed_emitted[i] = toks
                replayed_tokens += len(toks)
                if sample:
                    seed_keys[i] = np.asarray(jreplay.keys[rids[i]],
                                              np.uint32)
            # else: nothing journaled (or stream not resumable) — the
            # request restarts from its original prompt; its fold_in
            # PRNG stream restarts too, so tokens still come out
            # bit-identical, just re-generated
        journal.flush()
        recovery_s = time.perf_counter() - t_rec
    if may_preempt or seed_emitted:
        prompt_pad = max(
            int(np.asarray(r.prompt).size) + (r.gen - 1 if resumable[i]
                                              else 0)
            for i, r in enumerate(requests))

    index = None
    if prefix_sharing:
        from repro.attention import PagedKVState, PrefixIndex
        if admission != "chunked":
            raise ValueError(
                "prefix_sharing requires admission='chunked' (stall-mode "
                "prefill bypasses the page-native write path)")
        geos = {(n.k.shape[1], n.k.shape[2], n.page_table.shape[2])
                for n in jax.tree.leaves(caches, is_leaf=_is_kv_state)
                if isinstance(n, PagedKVState)}
        if len(geos) > 1:
            raise ValueError(
                f"prefix_sharing needs one uniform pool geometry across "
                f"all attention layers (one physical page id must mean "
                f"the same logical page everywhere), got {sorted(geos)} — "
                f"window-capped layer groups (local/swa mixed with full "
                f"attention) break the layer-lockstep guarantee")
        index = PrefixIndex(page_size)
    debug = debug_invariants if debug_invariants is not None \
        else bool(os.environ.get("ITA_PAGED_DEBUG"))
    chunk = max(1, min(chunk_size, capacity))
    budget = token_budget if token_budget is not None \
        else slots - 1 + chunk
    if admission == "chunked" and budget < slots:
        raise ValueError(
            f"token_budget={budget} < slots={slots}: a decode-maximal "
            f"step must cover every decoding slot plus at least one "
            f"prefill token")
    prefill, _ = _steps(cfg)
    seg_decode = _serve_segment_fn(cfg, segment, sample, eos_id, pad_id)

    def seg_mixed(n_steps):
        # two-phase segment: chunk-wide mixed steps sized to the prompt
        # chunks actually outstanding (rounded up to a power of two to
        # bound compilation count), then 1-token decode steps for the
        # rest — one dispatch, one host round-trip per `segment` steps,
        # chunk-wide q width paid only where prefill happens
        return _serve_segment_fn(
            cfg, segment, sample, eos_id, pad_id, chunk, budget,
            min(segment, _next_pow2(n_steps)))

    def pages_for(req):
        n = int(np.asarray(req.prompt).size) + req.gen
        return min(-(-n // page_size), pages_per_seq)

    for idx, r in enumerate(requests):
        plen = int(np.asarray(r.prompt).size)
        if plen > capacity:
            raise ValueError(
                f"request {idx}: prompt length {plen} exceeds the per-slot "
                f"window {capacity}; raise max_len")
        if pages_for(r) > pool_pages:
            raise ValueError(
                f"request {idx} needs {pages_for(r)} pages but the pool "
                f"has {pool_pages}; raise num_pages")

    # stall mode: reusable ring scratch for admission prefills (fully
    # overwritten by every ragged prefill — allocated once, not per round)
    scratch = init_caches(cfg, slots, max_len=prompt_pad) \
        if admission == "stall" else None

    # scheduler state (host)
    order = sorted(range(len(requests)), key=lambda i: requests[i].arrival)
    queue = [i for i in order if i not in done_replayed]
    slot_req = [None] * slots                      # request index per slot
    reserved = [0] * slots                         # pages reserved per slot
    plen_host = [0] * slots                        # prompt length per slot
    cursor_host = [0] * slots                      # host mirror of cursor
    prefilling = [False] * slots                   # host mirror of phase
    slot_prompt = [None] * slots                   # admitted pending stream
    arrived_wall = {}
    first_tok = {}
    emitted = {i: list(seed_emitted.get(i, []))
               for i in range(len(requests))}
    jhw = {i: len(emitted[i]) for i in emitted}    # journaled high water
    admitted_step = {}
    preempt_count = {}                             # request -> evictions
    resume_keys = dict(seed_keys)                  # request -> PRNG snapshot
    n_preempts = 0
    completed = list(replayed_completed)
    page_util = []
    drain_since = None                             # wall time drain began
    snapshot_bytes = 0

    # prefix-sharing host state (all empty/zero when index is None)
    pins = {}                                      # page -> 1 (index pins)
    slot_shared = [[] for _ in range(slots)]       # adopted pages per slot
    slot_shareable = [False] * slots               # row may donate pages
    reg_done = [0] * slots                         # prompt pages registered
    prefill_tokens = 0
    shared_tokens = 0
    prefix_hits = 0

    # -- snapshot/restore of the pool + prefix index (§Crash recovery) ---
    restored_from_snapshot = False
    snap_ckpt = None
    snap_ord = 0
    snap_geo = {"arch": cfg.name, "slots": int(slots),
                "page_size": int(page_size),
                "num_pages": int(geo.k.shape[1]),
                "pages_per_seq": int(pages_per_seq)}
    if journal is not None and snapshot_every > 0:
        from repro.checkpoint.checkpointing import (Checkpointer,
                                                    CheckpointCorrupt)
        snap_ckpt = Checkpointer(os.path.join(journal_dir, "snapshots"),
                                 keep=2, prefix="serve")
        snap_ord = snap_ckpt.latest_step() or 0
    if recovered and snap_ckpt is not None and index is not None:
        t_rec = time.perf_counter()
        try:
            if snap_ckpt.latest_step() is None:
                raise FileNotFoundError("no serve snapshot on disk")
            loaded, snap_meta = snap_ckpt.restore(caches)
            extra = snap_meta["extra"]
            if extra.get("geometry") != snap_geo:
                raise CheckpointCorrupt(
                    f"snapshot geometry {extra.get('geometry')} != this "
                    f"serve's {snap_geo}")
            exp_shapes = [list(l.shape) for l in jax.tree.leaves(caches)]
            if snap_meta["shapes"] != exp_shapes:
                raise CheckpointCorrupt("snapshot leaf shapes changed")
            index.load_state_dict(extra["index"])
            new_pins = {int(p): int(c) for p, c in extra["pins"].items()}
            # the snapshot was taken mid-serve with rows holding pages;
            # none of those rows survive the crash, so release every row
            # — refcounts drop to exactly the index pins — then host-
            # check the allocator invariants before trusting any of it
            loaded = _release_slots(loaded, jnp.ones((slots,), bool))
            _check_paged_invariants(loaded, pins=dict(new_pins))
            caches = loaded
            pins = new_pins
            restored_from_snapshot = True
        except (CheckpointCorrupt, FileNotFoundError, AssertionError,
                KeyError, ValueError) as e:
            # graceful degradation: a missing/corrupt/mismatched
            # snapshot can cost re-prefill work, never correctness —
            # cold-start the pool and index, recover from the journal
            if not isinstance(e, FileNotFoundError):
                print(f"[serve] snapshot unusable ({e}); cold start "
                      f"from journal", flush=True)
            caches = init_caches(cfg, slots, max_len=max_len, paged=True,
                                 page_size=page_size, num_pages=num_pages)
            index = PrefixIndex(page_size)
            pins = {}
        recovery_s += time.perf_counter() - t_rec

    def save_snapshot():
        nonlocal snap_ord, snapshot_bytes
        snap_ord += 1
        snap_ckpt.save(snap_ord, caches, extra={
            "kind": "serve", "geometry": snap_geo,
            "fingerprint": fingerprint,
            "index": index.state_dict() if index is not None else None,
            "pins": {str(p): int(c) for p, c in pins.items()}})
        snapshot_bytes = sum(
            int(np.prod(l.shape)) * l.dtype.itemsize
            for l in jax.tree.leaves(caches))

    state = ServeSlotState.init(slots, prompt_pad, base_key)

    step = 0
    segments = 0
    rounds = 0
    stall_s = 0.0
    straggler_segs = 0
    t0 = time.perf_counter()

    def finish(slot, now_s):
        i = slot_req[slot]
        completed.append(CompletedRequest(
            index=i, arrival=requests[i].arrival,
            admitted_step=admitted_step[i], finished_step=step,
            arrived_s=arrived_wall[i], finished_s=now_s,
            first_token_s=first_tok.get(i, now_s),
            tokens=np.asarray(emitted[i][:requests[i].gen], np.int32),
            priority=prio_req[i],
            preemptions=preempt_count.get(i, 0)))
        if journal is not None:
            # "n" is the authoritative finished-token count: replay
            # trusts it over the record's mere existence (a torn flush
            # can drop this boundary's progress lines but keep this)
            journal.append({
                "t": "complete", "rid": rids[i],
                "n": len(emitted[i][:requests[i].gen]),
                "admitted_step": admitted_step[i], "finished_step": step,
                "arrival": int(requests[i].arrival),
                "arrived_s": arrived_wall[i], "finished_s": now_s,
                "first_token_s": first_tok.get(i, now_s),
                "priority": prio_req[i],
                "preemptions": preempt_count.get(i, 0)})
        slot_req[slot] = None
        reserved[slot] = 0
        prefilling[slot] = False
        slot_prompt[slot] = None
        slot_shared[slot] = []
        slot_shareable[slot] = False
        reg_done[slot] = 0

    to_release = []                                # slots freed, pages held

    def preempt_slot(slot):
        """Evict ``slot``'s request (host side): snapshot its PRNG
        stream, rebuild its pending entry as prompt + generated prefix
        with the leftover token budget, clear the slot's host mirrors and
        re-enqueue. The device-row clear (``preempt_rows``) and the page
        release are batched by the caller — one dispatch per round."""
        nonlocal n_preempts
        i = slot_req[slot]
        if sample:
            # the stream already advanced once per emitted token; resuming
            # from this snapshot is what keeps sampled outputs
            # bit-identical to an unpreempted serve (eager device_get:
            # a fault kill may re-admit this request in the same round)
            resume_keys[i] = np.asarray(jax.device_get(state.keys[slot]))
        g = len(emitted[i])
        prompt0 = np.asarray(requests[i].prompt, np.int32).reshape(-1)
        pending[i] = (
            np.concatenate([prompt0, np.asarray(emitted[i][:g], np.int32)]),
            requests[i].gen - g)
        preempt_count[i] = preempt_count.get(i, 0) + 1
        n_preempts += 1
        slot_req[slot] = None
        reserved[slot] = 0
        prefilling[slot] = False
        cursor_host[slot] = 0
        plen_host[slot] = 0
        slot_prompt[slot] = None
        slot_shared[slot] = []
        slot_shareable[slot] = False
        reg_done[slot] = 0
        queue.append(i)
        queue.sort(key=lambda j: (requests[j].arrival, j))
        to_release.append(slot)

    def _journal_progress(keys_np=None):
        """Journal every request's emitted-token delta since its last
        journaled high-water mark and, when sampling, its post-draw PRNG
        snapshot (from the segment readback for live slots, from the
        eviction snapshot for preempted ones) — all batched into ONE
        progress record per boundary, so the journal's per-record cost
        doesn't scale with slot count. The caller flushes — durability
        is per segment boundary, not per token."""
        slot_of = {slot_req[s]: s for s in range(slots)
                   if slot_req[s] is not None}
        deltas, keys = {}, {}
        for i, toks in emitted.items():
            if len(toks) <= jhw[i]:
                continue
            deltas[rids[i]] = [int(x) for x in toks[jhw[i]:]]
            if sample:
                if keys_np is not None and i in slot_of:
                    keys[rids[i]] = [int(x) for x in keys_np[slot_of[i]]]
                elif i in resume_keys:
                    keys[rids[i]] = [int(x) for x in
                                     np.asarray(resume_keys[i]).reshape(-1)]
            jhw[i] = len(toks)
        if deltas:
            rec = {"t": "progress", "d": deltas}
            if keys:
                rec["k"] = keys
            journal.append(rec)

    while queue or any(s is not None for s in slot_req):
        now_s = time.perf_counter() - t0
        if injector is not None and injector.want_crash(step):
            # process death at an admission-round boundary: everything
            # through the previous segment's flush is durable, all
            # in-memory state is abandoned (no flush, no cleanup).
            # In-flight async IO (journal group commit, snapshot write)
            # is settled first so the in-process simulation is
            # deterministic and the restarted serve never races a
            # "dead" writer thread — a real death mid-write leaves a
            # torn journal tail / a .tmp snapshot dir, both of which
            # replay and tmp+rename atomicity already make equivalent
            # to the write never starting
            if journal is not None:
                journal.wait()
            if snap_ckpt is not None:
                snap_ckpt.wait()
            raise SimulatedCrash(step, "round-boundary")
        draining = drain is not None and drain.poll(step)
        if draining:
            if drain_since is None:
                drain_since = time.perf_counter()
            if all(s is None for s in slot_req):
                break                  # nothing in flight: drain done
            if drain_timeout is not None and \
                    time.perf_counter() - drain_since >= drain_timeout:
                # timeout: stop here — in-flight progress is journaled
                # through the last boundary, a resume picks it up
                break
        for i in queue:
            if requests[i].arrival <= step:
                arrived_wall.setdefault(i, now_s)
        victims_round = []
        if injector is not None and injector.want_kill(step):
            # forced slot kill: seeded pick among live resumable slots,
            # evicted through the exact preemption recovery path (and a
            # candidate for re-admission this very round)
            live = [s for s in range(slots)
                    if slot_req[s] is not None and resumable[slot_req[s]]]
            if live:
                s = live[int(injector.rng.integers(len(live)))]
                preempt_slot(s)
                victims_round.append(s)
        # -- admission: arrived requests into free, page-backed slots ----
        # budget: reservations + index pins both count against the pool.
        # A pinned page inside an active donor's reservation is counted
        # twice — conservative, never overdrawn; the win comes from
        # adopters reserving `need - shared` pages. Fault-injected
        # pressure spikes subtract phantom pages for one round.
        free_slots = [s for s in range(slots) if slot_req[s] is None]
        phantom = injector.phantom_pages(step) if injector is not None \
            else 0
        page_budget = pool_pages - sum(reserved) - len(pins) - phantom
        adm = []
        adm_shared = {}                            # slot -> adopted pages
        evict_batch = []
        # candidate order = admission order: effective SLO class first
        # (aging-adjusted, so a starved low-class request eventually
        # outranks fresh high-class arrivals), then arrival, then trace
        # position (a snapshot — this round's victims re-enter the queue
        # but only become candidates next round, so preemption can never
        # livelock within a round). Draining: admit nothing.
        cand = [] if draining else sorted(
            (i for i in queue if requests[i].arrival <= step),
            key=lambda j: (-eff_prio(j, step), requests[j].arrival, j))
        for i in cand:
            if not free_slots and not preemption:
                break
            prompt_i, gen_i = pending[i]
            plen_i = int(prompt_i.size)
            sh_pages = []
            if index is not None and plen_i + gen_i <= capacity:
                # cap at plen-1: >= 1 prompt token must prefill live (the
                # first sampled token needs this request's last-position
                # logits); no sharing for window-wrapping requests (their
                # COW pops would need headroom the reservation lacks)
                sh_pages = index.lookup(prompt_i, max_tokens=plen_i - 1)
            need = min(-(-(plen_i + gen_i) // page_size),
                       pages_per_seq) - len(sh_pages)
            if need > page_budget and index is not None and len(index):
                # evict idle pinned prefixes (LRU) before preempting or
                # stalling the head; pages adopted by active slots (or
                # about to be, by this request) keep their pin
                protected = {p for lst in slot_shared for p in lst}
                protected |= set(sh_pages)
                evicted = index.evict_lru(need - page_budget, protected)
                for p in evicted:
                    pins.pop(p, None)
                evict_batch.extend(evicted)
                page_budget += len(evicted)
            if preemption and (need > page_budget or not free_slots):
                # page-pressure preemption: evict strictly-lower-class
                # victims — lowest class first, then most reserved pages
                # — until this candidate fits. All-or-nothing: a
                # candidate that still wouldn't fit evicts nobody.
                cast = sorted(
                    (s for s in range(slots)
                     if slot_req[s] is not None
                     and eff_prio(slot_req[s], step) < eff_prio(i, step)
                     and resumable[slot_req[s]]),
                    key=lambda s: (eff_prio(slot_req[s], step),
                                   -reserved[s], s))
                gain, picked = 0, []
                for s in cast:
                    if need <= page_budget + gain \
                            and (free_slots or picked):
                        break
                    picked.append(s)
                    gain += reserved[s]
                if need <= page_budget + gain and (free_slots or picked):
                    for s in picked:
                        preempt_slot(s)            # reserved[s] -> 0
                        victims_round.append(s)
                        free_slots.append(s)
                    page_budget += gain
            if not free_slots or need > page_budget:
                break                              # head-of-line: keep order
            slot = free_slots.pop(0)
            queue.remove(i)
            slot_req[slot] = i
            reserved[slot] = need
            page_budget -= need
            admitted_step.setdefault(i, step)      # first admission: TTFT
            adm.append((slot, i))
            adm_shared[slot] = sh_pages
            slot_prompt[slot] = prompt_i
            slot_shared[slot] = list(sh_pages)
            slot_shareable[slot] = (index is not None
                                    and plen_i + gen_i <= capacity)
            reg_done[slot] = len(sh_pages)         # adopted = already indexed
            sh_toks = len(sh_pages) * page_size
            prefill_tokens += plen_i - sh_toks
            shared_tokens += sh_toks
            prefix_hits += bool(sh_pages)
        if victims_round:
            # one-dispatch device-row clear: the victims' done flag
            # raises before any release/adopt/admit dispatch and before
            # the next segment, so the scan never touches freed pages
            vmask = np.zeros((slots,), bool)
            vmask[victims_round] = True
            state = _preempt_rows(state, jnp.asarray(vmask))
        if adm and to_release:
            # deferred page hand-back: freed slots accumulate across
            # segment boundaries and release in one dispatch right before
            # the pages are actually needed (host `reserved` accounting
            # keeps the budget exact in between)
            mask = np.zeros((slots,), bool)
            mask[to_release] = True
            caches = _release_slots(caches, jnp.asarray(mask))
            to_release = []
        if evict_batch:
            # unpin evicted index entries (dispatched even when the head
            # still didn't fit, so the host pin ledger and the device
            # refcounts never diverge); pages reaching refcount zero are
            # free the moment this lands
            pad = np.full((slots * pages_per_seq,), -1, np.int32)
            pad[:len(evict_batch)] = evict_batch
            caches = _unpin_pages(caches, jnp.asarray(pad))
        if adm:
            rounds += 1
            prompts = np.zeros((slots, prompt_pad), np.int32)
            lengths = np.ones((slots,), np.int32)
            gens = np.zeros((slots,), np.int32)
            prios = np.zeros((slots,), np.int32)
            slot_ids = np.full((slots,), -1, np.int32)
            row_req = np.zeros((slots,), np.int32)
            for row, (slot, i) in enumerate(adm):
                p, g = pending[i]
                prompts[row, :p.size] = p
                lengths[row] = p.size
                gens[row] = g
                prios[row] = eff_prio(i, step)
                slot_ids[row] = slot
                row_req[row] = i
                plen_host[slot] = p.size
            req_keys = fold_keys(base_key, jnp.asarray(row_req))
            if resume_keys:
                # resumed rows restore the PRNG snapshot taken at their
                # eviction instead of restarting the fold_in stream — the
                # draws continue exactly where the victim left off
                rk = np.asarray(req_keys).copy()
                for row, (slot, i) in enumerate(adm):
                    if i in resume_keys:
                        rk[row] = resume_keys.pop(i)
                req_keys = jnp.asarray(rk)
            lengths_d = jnp.asarray(lengths)
            slot_ids_d = jnp.asarray(slot_ids)
            if admission == "chunked":
                shared_rows = np.zeros((slots,), np.int32)
                if index is not None:
                    adopt_pages = np.zeros((slots, pages_per_seq), np.int32)
                    adopt_n = np.zeros((slots,), np.int32)
                    for row, (slot, i) in enumerate(adm):
                        sh = adm_shared.get(slot, [])
                        adopt_pages[row, :len(sh)] = sh
                        adopt_n[row] = len(sh)
                        shared_rows[row] = len(sh) * page_size
                    if adopt_n.any():
                        # point the new slots' leading table entries at
                        # the shared pages (+1 refcount, every layer)
                        caches = _adopt_prefix_slots(
                            caches, slot_ids_d, jnp.asarray(adopt_pages),
                            jnp.asarray(adopt_n),
                            jnp.asarray(shared_rows))
                # enqueue-only admission: prompt ids + phase state; the
                # segments do the prefill, page-native, starting at the
                # first unshared token
                state = _admit_chunked(state, slot_ids_d,
                                       jnp.asarray(prompts), lengths_d,
                                       jnp.asarray(gens), req_keys,
                                       jnp.asarray(shared_rows),
                                       jnp.asarray(prios))
                for row, (slot, i) in enumerate(adm):
                    prefilling[slot] = True
                    cursor_host[slot] = int(shared_rows[row])
            else:
                # stall admission: stop-the-world ragged prefill over the
                # ring scratch, bytes-copied into pool pages (no sharing:
                # every prompt token forwards)
                t_stall = time.perf_counter()
                logits, scratch = prefill(params, jnp.asarray(prompts),
                                          scratch, None, lengths_d)
                tok0, req_keys = sample_token_rows(
                    logits, req_keys, temp_arr, sample=sample)
                caches = _adopt_prompts(caches, scratch, slot_ids_d,
                                        lengths_d)
                tok0_np = np.asarray(tok0)
                new_done = np.zeros((slots,), bool)
                new_rem = np.zeros((slots,), np.int32)
                now_s = time.perf_counter() - t0
                for row, (slot, i) in enumerate(adm):
                    t0_tok = int(tok0_np[row, 0])
                    emitted[i].append(t0_tok)
                    first_tok.setdefault(i, now_s)
                    new_rem[row] = requests[i].gen - 1
                    new_done[row] = (requests[i].gen <= 1
                                     or (eos_id is not None
                                         and t0_tok == eos_id))
                state = _admit_stall(
                    state, slot_ids_d, lengths_d, tok0,
                    jnp.asarray(new_done), jnp.asarray(new_rem), req_keys,
                    jnp.asarray(prios))
                jax.block_until_ready(state.tok)
                stall_s += time.perf_counter() - t_stall
            if audit is not None:
                audit(caches, list(slot_req), dict(pins))
            if debug:
                _check_paged_invariants(caches, pins=dict(pins))
        if admission == "stall" and adm:
            # freshly admitted gen-1/EOS requests finish without decoding
            just_done = np.asarray(state.done)
            fin = [s for s in range(slots)
                   if slot_req[s] is not None and just_done[s]]
            if fin:
                now_s = time.perf_counter() - t0
                for s in fin:
                    finish(s, now_s)
                to_release.extend(fin)
                continue
        if all(s is None for s in slot_req):
            if not queue:
                break
            step += segment                        # idle: nothing admittable
            continue

        # -- fused segment: mixed while any slot is mid-prompt (sized to
        # the chunks actually left), pure decode otherwise — decode-only
        # phases never pay chunk-wide q width
        t_seg = time.perf_counter()
        if injector is not None:
            pause = injector.straggle(step)
            if pause > 0.0:
                time.sleep(pause)                  # injected straggler
        if admission == "chunked" and any(prefilling):
            # steps of mixed phase: bounded below by the largest single
            # prompt (one chunk per slot per step) and by total prefill
            # work over the per-step prefill token capacity (budget minus
            # the decoding slots it must keep fed)
            left = [plen_host[s] - cursor_host[s]
                    for s in range(slots) if prefilling[s]]
            n_dec = sum(1 for s in range(slots)
                        if slot_req[s] is not None and not prefilling[s])
            per_step = max(budget - n_dec, 1)
            need = max(-(-max(left) // chunk),
                       -(-sum(left) // per_step))
            fn = seg_mixed(max(need, 1))
        else:
            fn = seg_decode
        toks, emits, _, state, caches, _ = fn(params, state, caches,
                                              temp_arr)
        segments += 1
        step += segment
        # pool utilization from the host-side reservation ledger (exact
        # upper bound on device-held pages; no extra device sync),
        # sampled while the segment's occupants still hold their pages
        page_util.append((step, sum(reserved) / max(pool_pages, 1)))
        keys_np = None
        if journal is not None and sample:
            toks_np, emits_np, done_np, cursor_np, keys_np = \
                jax.device_get((toks, emits, state.done, state.cursor,
                                state.keys))               # one sync
        else:
            toks_np, emits_np, done_np, cursor_np = jax.device_get(
                (toks, emits, state.done, state.cursor))   # one sync
        if injector is not None and injector.want_crash_after(step):
            # mid-segment death: the device produced this segment's
            # tokens but the flush below never runs — the torn window.
            # Recovery resumes from the *previous* boundary and must
            # regenerate the lost tokens bit-identically
            if journal is not None:
                journal.wait()
            if snap_ckpt is not None:
                snap_ckpt.wait()
            raise SimulatedCrash(step, "mid-segment")
        straggler_segs += watchdog.observe(
            time.perf_counter() - t_seg).straggler
        now_s = time.perf_counter() - t0
        for s in range(slots):
            if slot_req[s] is None:
                continue
            i = slot_req[s]
            row = toks_np[s][emits_np[s]].tolist()
            if row:
                first_tok.setdefault(i, now_s)
                emitted[i].extend(row)
            cursor_host[s] = int(cursor_np[s])
            prefilling[s] = cursor_host[s] < plen_host[s]
        if index is not None:
            # register every freshly completed *full* page of prompt
            # tokens (bytes final: no-wrap donors never rewrite them) so
            # later arrivals can adopt it; runs before the finish/release
            # bookkeeping so a request that just completed still donates.
            # One small device_get of layer 0's page tables serves every
            # layer — the pools are in lockstep.
            reg_rows = []
            for s in range(slots):
                if slot_req[s] is None or not slot_shareable[s]:
                    continue
                full = min(cursor_host[s], plen_host[s]) // page_size
                if full > reg_done[s]:
                    reg_rows.append((s, full))
            if reg_rows:
                table = np.asarray(jax.device_get(
                    _first_paged(caches).page_table[0]))
                new_pins = []
                for s, full in reg_rows:
                    # the slot's *pending* stream, not the original
                    # prompt: a resumed slot prefills prompt + generated
                    # prefix, and those pages hash under that stream —
                    # which is also what makes a re-preemption's
                    # re-admission adopt them back nearly for free
                    got = index.register(slot_prompt[s],
                                         table[s, :full])
                    reg_done[s] = full
                    new_pins.extend(got)
                if new_pins:
                    pins.update((p, 1) for p in new_pins)
                    pad = np.full((slots * pages_per_seq,), -1, np.int32)
                    pad[:len(new_pins)] = new_pins
                    caches = _pin_pages(caches, jnp.asarray(pad))
        fin = [s for s in range(slots)
               if slot_req[s] is not None and done_np[s]]
        for s in fin:
            finish(s, now_s)
        to_release.extend(fin)
        if journal is not None:
            # the boundary's group-commit point: progress deltas + key
            # snapshots + any completes land in one written batch
            # (fsynced on the journal's bounded cadence); a crash before
            # the *next* flush loses at most a bounded suffix of
            # regenerable work
            _journal_progress(keys_np)
            journal.flush()
            if snap_ckpt is not None and segments % snapshot_every == 0:
                save_snapshot()

    if journal is not None:
        _journal_progress(None)
        journal.flush()
        if snap_ckpt is not None:
            # final snapshot: a clean restart (drain + resume, or a new
            # trace over the same prompts) warm-starts the prefix index
            save_snapshot()
            snap_ckpt.wait()
        journal.close()
    if debug:
        _check_paged_invariants(caches, pins=dict(pins))
    wall = time.perf_counter() - t0
    return ServeResult(completed=completed, wall_s=wall, steps=step,
                       segments=segments, admission_rounds=rounds,
                       page_util=page_util, prefill_stall_s=stall_s,
                       prefill_tokens=prefill_tokens,
                       shared_prefix_tokens=shared_tokens,
                       prefix_hits=prefix_hits, preemptions=n_preempts,
                       straggler_segments=straggler_segs,
                       drained=drain_since is not None,
                       recovered=recovered,
                       restored_from_snapshot=restored_from_snapshot,
                       replayed_tokens=replayed_tokens,
                       snapshot_bytes=snapshot_bytes,
                       recovery_s=recovery_s, aging_steps=aging_steps,
                       max_class=max_class)
