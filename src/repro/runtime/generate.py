"""Batched autoregressive generation: quantized prefill → incremental
decode through the int8 KV caches.

The serving loop the launchers and examples share: one jitted prefill over
the whole prompt batch (streaming ITA attention, caches written once),
then one jitted single-token decode step per generated position (direct
integer attention against the ring buffers — no full-context recompute,
the data-movement win ITA's streaming softmax exists for).

    from repro.runtime.generate import generate
    res = generate(params, cfg, prompts, gen=32)
    res.tokens          # (B, gen) int32
    res.decode_tok_s    # decode throughput
"""

from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp


@functools.lru_cache(maxsize=32)
def _steps(cfg):
    """Jitted prefill/decode steps, cached per (hashable, frozen) config so
    repeated generate() calls reuse compilations."""
    from repro.launch.steps import make_decode_step, make_prefill_step
    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(2,))
    return prefill, decode


@dataclasses.dataclass
class GenerateResult:
    tokens: jax.Array            # (B, gen) generated token ids
    prefill_s: float             # wall-clock of the prefill step
    decode_s: float              # wall-clock of all decode steps
    decode_steps: int

    @property
    def decode_tok_s(self) -> float:
        n = self.decode_steps * self.tokens.shape[0]
        return n / max(self.decode_s, 1e-9)


def _select(logits, temperature, key):
    """Greedy (temperature 0) or temperature sampling of the next token."""
    if temperature <= 0.0 or key is None:
        return jnp.argmax(logits, -1).astype(jnp.int32)
    tok = jax.random.categorical(key, logits / temperature, axis=-1)
    return tok.astype(jnp.int32)


def generate(params, cfg, prompts, gen: int, *, frontend=None,
             temperature: float = 0.0, key=None, max_len: int | None = None,
             caches=None) -> GenerateResult:
    """Prefill the prompt batch, then decode ``gen`` tokens incrementally.

    ``prompts`` (B, S) int32. ``max_len`` sizes the KV ring buffers
    (default S + gen; smaller values window-evict). Pass ``caches`` to
    reuse pre-allocated buffers across calls.
    """
    from repro.models import init_caches

    b, prompt_len = prompts.shape
    if gen <= 0:
        return GenerateResult(tokens=jnp.zeros((b, 0), jnp.int32),
                              prefill_s=0.0, decode_s=0.0, decode_steps=0)
    max_len = max_len or prompt_len + gen
    prefill, decode = _steps(cfg)
    if caches is None:
        caches = init_caches(cfg, b, max_len=max_len)

    t0 = time.perf_counter()
    logits, caches = prefill(params, prompts, caches, frontend)
    if key is not None:
        key, sub = jax.random.split(key)
    else:
        sub = None
    tok = _select(logits, temperature, sub)
    jax.block_until_ready(tok)
    t_prefill = time.perf_counter() - t0

    out = [tok]
    t0 = time.perf_counter()
    for i in range(gen - 1):
        logits, caches = decode(params, tok, caches,
                                jnp.asarray(prompt_len + i, jnp.int32),
                                frontend)
        if key is not None:
            key, sub = jax.random.split(key)
        tok = _select(logits, temperature, sub)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    return GenerateResult(tokens=jnp.concatenate(out, axis=1),
                          prefill_s=t_prefill, decode_s=t_decode,
                          decode_steps=gen - 1)
