"""Gradient compression for the cross-pod (DCN) data-parallel axis.

ITA's thesis — 8-bit integers with calibrated scales lose little — applies
to *gradient traffic* too: we reuse the same symmetric int8 machinery with
**error feedback** (the quantization residual is carried to the next step,
so compression error accumulates to zero instead of biasing the update).

Two layers:
- ``ef_compress / ef_decompress`` — pure pytree transforms usable inside
  any train step (compress -> (simulated) wire -> decompress), with the EF
  state threaded alongside the optimizer state.
- ``compressed_psum`` — a shard_map building block performing the actual
  int8 all-reduce on a named axis (all-gather int8 shards + local f32
  reduction, avoiding int8 overflow), demonstrating the wire-level
  collective for the ``pod`` axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.quant import INT8_MAX, INT8_MIN


def init_ef_state(grads):
    return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)


def ef_compress(grads, ef_state):
    """Returns (int8 pytree, scales pytree, new_ef_state)."""
    def one(g, e):
        g = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / INT8_MAX
        q = jnp.clip(jnp.round(g / scale), INT8_MIN, INT8_MAX)
        err = g - q * scale
        return q.astype(jnp.int8), scale, err

    flat, treedef = jax.tree.flatten(grads)
    eflat = jax.tree.leaves(ef_state)
    qs, scales, errs = zip(*[one(g, e) for g, e in zip(flat, eflat, strict=True)],
                             strict=True)
    return (jax.tree.unflatten(treedef, qs),
            jax.tree.unflatten(treedef, scales),
            jax.tree.unflatten(treedef, errs))


def ef_decompress(q_grads, scales):
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s,
                        q_grads, scales)


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8 all-reduce over ``axis_name`` (use inside shard_map):
    quantize locally -> all_gather the int8 shards (+f32 scales) ->
    dequantize-and-sum locally. Wire bytes: ~1/4 of f32 psum."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / INT8_MAX
    q = jnp.clip(jnp.round(x / scale), INT8_MIN, INT8_MAX).astype(jnp.int8)
    qs = jax.lax.all_gather(q, axis_name)            # (n, ...) int8 on wire
    ss = jax.lax.all_gather(scale, axis_name)
    return jnp.tensordot(ss, qs.astype(jnp.float32), axes=((0,), (0,)))


def make_compressed_grad_allreduce(mesh, axis: str = "pod"):
    """shard_map-wrapped compressed mean over the pod axis for a grad
    pytree already sharded over the in-pod mesh axes."""
    from jax.sharding import PartitionSpec as P

    def mean_tree(grads):
        n = mesh.shape[axis]

        def impl(g):
            return jax.tree.map(
                lambda t: compressed_psum(t, axis) / n, g)

        spec = jax.tree.map(lambda _: P(), grads)
        return jax.shard_map(impl, mesh=mesh, in_specs=(spec,),
                             out_specs=spec)(grads)

    return mean_tree
