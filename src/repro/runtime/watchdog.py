"""Reusable straggler watchdog: robust moving-median step timing.

Extracted from ``runtime.fault_tolerance.TrainDriver`` so the serve loop
can run the *same* detector over its segment wall times: one class, two
consumers (train step watchdog -> protective checkpoint; serve segment
watchdog -> ``ServeResult.straggler_segments`` + the fault-injection
harness's straggle assertions). Semantics are exactly the TrainDriver
seed's — the extraction must not move the trigger point:

- keep the last ``window`` observations;
- flag nothing until ``min_samples`` observations exist (cold caches and
  first-compile steps would all read as stragglers);
- the reference is the **median of the window excluding the newest
  sample** (a straggler must not dilute its own reference — with the
  newest sample included, a 10x step against a flat history shifts the
  median it is compared against);
- ``dt > factor * median`` flags a straggler; ``streak_threshold``
  consecutive flags additionally report *persistent* (the caller's cue
  for a protective action — checkpoint, eviction, re-shard) and reset
  the streak so one slow host triggers one action, not one per step.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class WatchdogVerdict:
    straggler: bool              # this observation exceeded factor * median
    persistent: bool             # streak_threshold consecutive stragglers
    median: float                # the reference median (0.0 during warmup)


class StragglerWatchdog:
    """Moving-median straggler detector; see module docstring for the
    exact trigger semantics (inherited unchanged from the TrainDriver)."""

    def __init__(self, factor: float = 2.0, window: int = 32,
                 min_samples: int = 8, streak_threshold: int = 3):
        if window < 2 or min_samples < 2:
            raise ValueError("watchdog needs >= 2 samples of history to "
                             "form a median reference")
        self.factor = float(factor)
        self.window = int(window)
        self.min_samples = int(min_samples)
        self.streak_threshold = int(streak_threshold)
        self.times: list[float] = []
        self.events = 0              # total straggler observations
        self._streak = 0

    def observe(self, dt: float) -> WatchdogVerdict:
        """Record one step/segment duration; returns the verdict."""
        self.times.append(float(dt))
        hist = self.times[-self.window:]
        if len(hist) < self.min_samples:
            return WatchdogVerdict(False, False, 0.0)
        med = float(np.median(hist[:-1]))
        if dt > self.factor * med:
            self.events += 1
            self._streak += 1
            persistent = self._streak >= self.streak_threshold
            if persistent:
                self._streak = 0
            return WatchdogVerdict(True, persistent, med)
        self._streak = 0
        return WatchdogVerdict(False, False, med)
