"""Crash-safe serving: the write-ahead request journal, graceful drain,
and the serve → crash → restore → continue harness.

The journal is the durability backbone of ``serve_continuous(
journal_dir=...)`` (DESIGN.md §Crash recovery). It is an append-only
JSONL write-ahead log: every line is one record wrapped with a crc32 of
its canonical encoding, written in order once per segment boundary and
fsynced on a bounded group-commit cadence. A crash can only lose the
*suffix* written after the last
flush — replay verifies each line's checksum and stops at the first
torn or corrupt line (classic WAL tail semantics), so recovery always
resumes from a prefix of true history, never from garbage.

Record types (one JSON object per line):

- ``header`` — journal format version + the serve *fingerprint* (arch,
  page size, temperature, sampling flag, eos/pad ids, base PRNG key).
  Resuming under a different fingerprint would silently change tokens,
  so ``serve_continuous`` refuses to resume against a mismatched header.
- ``submit`` — one per request: stable ``request_id``, trace index,
  prompt digest + length, ``gen``, arrival, priority. Re-submission
  after recovery dedupes on the id (idempotent re-admission); a digest
  mismatch means the id was reused for a different request and is an
  error, not a dedupe.
- ``progress`` — one per segment boundary: ``d`` maps each advanced
  request's id to the delta of emitted tokens since its last record,
  and (when sampling) ``k`` maps it to the request's PRNG key snapshot
  *after* those draws. The per-slot keys advance exactly once per
  emitted token, so the journaled key is precisely the state a resumed
  stream must continue from — what makes sampled recovery bit-exact.
  (Replay also accepts the single-request ``rid``/``toks``/``key``
  spelling — the natural shape for hand-authored journals in tests.)
- ``complete`` — the request finished: final token count and the
  timing/accounting fields its ``CompletedRequest`` is rebuilt from on
  replay.

Recovery = treat every unfinished journaled request as if it had been
*preempted* at its last flushed boundary: rebuild its pending stream as
``prompt ++ emitted`` with the leftover budget and its journaled key
snapshot, and let the ordinary PR-8 chunked resume path re-admit it.
Tokens the device produced after the last flush are simply regenerated
— bit-identically, because each request's token stream is a pure
function of (config, prompt, its own fold_in PRNG stream) and never of
co-scheduled traffic.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import zlib

import numpy as np

JOURNAL_VERSION = 1

# the fingerprint fields that determine token *values* (not just
# scheduling): resuming with any of these changed would produce
# different tokens than the crashed serve, so resume refuses.
TOKEN_FINGERPRINT_KEYS = ("journal_version", "arch", "page_size",
                          "max_len", "temperature", "sample", "eos_id",
                          "pad_id", "key")


def _canonical(rec: dict) -> str:
    return json.dumps(rec, sort_keys=True, separators=(",", ":"))


def prompt_digest(prompt) -> str:
    """Stable digest of a prompt's token ids — the submit record's
    identity check for request-id dedupe."""
    toks = np.ascontiguousarray(np.asarray(prompt, np.int32).reshape(-1))
    return hashlib.blake2b(toks.tobytes(), digest_size=12).hexdigest()


@dataclasses.dataclass
class JournalReplay:
    """Parsed journal state: everything recovery needs, keyed by
    request_id. ``truncated`` flags a torn/corrupt tail (records after
    it were dropped — WAL semantics, not an error)."""

    header: dict | None = None
    submits: dict = dataclasses.field(default_factory=dict)
    emitted: dict = dataclasses.field(default_factory=dict)
    keys: dict = dataclasses.field(default_factory=dict)
    completes: dict = dataclasses.field(default_factory=dict)
    n_records: int = 0
    truncated: bool = False


class ServeJournal:
    """Append-only JSONL WAL with **group commit**: ``append`` buffers
    on the host, ``flush`` encodes and appends the whole batch in one
    inline write — the serve loop flushes once per segment boundary, and
    a buffered write of a few records is microseconds, far below the
    journal-overhead gate in ``bench_serve``. Records therefore land in
    the file in exactly append order: a crash loses only a *suffix*, the
    same torn-tail window replay already tolerates.

    Durability follows the bounded-lag cadence of a production WAL:
    ``fsync`` runs every ``fsync_every``-th batch — on a lazily-created
    background thread, so its ~1 ms latency overlaps the next segment's
    device work instead of stalling the scheduler — and synchronously on
    ``close()``. Every batch is flushed to the OS immediately; the
    power-loss window is at most ``fsync_every`` segments of progress
    that recovery regenerates bit-identically anyway. ``wait()`` drains
    the in-flight fsync — the barrier the crash injector takes before
    simulating death, so the in-process restart sees a settled file."""

    def __init__(self, path: str, fingerprint: dict | None = None,
                 fresh: bool = False, fsync: bool = True,
                 fsync_every: int = 16):
        self.path = path
        self.fsync = fsync
        self.fsync_every = max(1, fsync_every)
        self._batches = 0
        self._buf: list = []
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._f = open(path, "w" if fresh else "a")
        self._pool = None
        self._pending = None
        if fingerprint is not None and self._f.tell() == 0:
            self.append({"t": "header", "version": JOURNAL_VERSION,
                         "fingerprint": fingerprint})
            self.flush()

    def append(self, rec: dict) -> None:
        self._buf.append(rec)

    def flush(self) -> None:
        """Write the buffered batch (group commit). Lines are encoded
        before the write so a record mutated after flush can't change
        what landed on disk; fsync is scheduled off-thread on the
        bounded cadence."""
        if not self._buf:
            return
        lines = []
        for rec in self._buf:
            canon = _canonical(rec)
            # splice the already-canonical record into the wrapper
            # instead of re-serializing it — the line is still exactly
            # _canonical({"crc":..., "rec": rec}) ("crc" < "rec" sorts
            # first), at half the encoding cost; replay re-canonicalizes
            # the parsed record, which round-trips to the same bytes
            lines.append('{"crc":%d,"rec":%s}'
                         % (zlib.crc32(canon.encode()), canon))
        self._buf = []
        self._f.write("\n".join(lines) + "\n")
        self._f.flush()
        self._batches += 1
        if self.fsync and self._batches % self.fsync_every == 0:
            if self._pool is None:
                from concurrent.futures import ThreadPoolExecutor
                self._pool = ThreadPoolExecutor(max_workers=1)
            self._pending = self._pool.submit(os.fsync, self._f.fileno())

    def wait(self) -> None:
        """Block until the in-flight background fsync (if any) is done;
        writes themselves are synchronous, so after this the file holds
        every flushed batch."""
        if self._pending is not None:
            self._pending.result()

    def close(self) -> None:
        self.flush()
        self.wait()
        if self.fsync:
            os.fsync(self._f.fileno())
        self._f.close()
        if self._pool is not None:
            self._pool.shutdown()

    # -- replay -------------------------------------------------------------

    @staticmethod
    def replay(path: str) -> JournalReplay:
        """Parse the journal, verifying each line's crc32; stop at the
        first unparsable or checksum-failing line (the torn tail a crash
        mid-write leaves behind) and return everything before it."""
        out = JournalReplay()
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    obj = json.loads(line)
                    rec = obj["rec"]
                    if zlib.crc32(_canonical(rec).encode()) != obj["crc"]:
                        raise ValueError("crc mismatch")
                except (ValueError, KeyError, TypeError):
                    out.truncated = True
                    break
                out.n_records += 1
                t = rec.get("t")
                if t == "header":
                    out.header = rec
                elif t == "submit":
                    out.submits[rec["rid"]] = rec
                elif t == "progress":
                    if "rid" in rec:        # single-request form
                        out.emitted.setdefault(rec["rid"], []).extend(
                            rec["toks"])
                        if "key" in rec:
                            out.keys[rec["rid"]] = rec["key"]
                    else:                   # batched: one rec per boundary
                        for rid, tk in rec["d"].items():
                            out.emitted.setdefault(rid, []).extend(tk)
                        for rid, key in rec.get("k", {}).items():
                            out.keys[rid] = key
                elif t == "complete":
                    out.completes[rec["rid"]] = rec
        return out


def check_fingerprint(journal_fp: dict, current_fp: dict) -> None:
    """Refuse to resume a journal whose token-affecting fingerprint
    differs from the current serve's — continuing would generate tokens
    the crashed serve never would have."""
    for k in TOKEN_FINGERPRINT_KEYS:
        if journal_fp.get(k) != current_fp.get(k):
            raise ValueError(
                f"journal fingerprint mismatch on {k!r}: journal has "
                f"{journal_fp.get(k)!r}, this serve has "
                f"{current_fp.get(k)!r} — resuming would change tokens; "
                f"start a fresh journal (resume=False) instead")


# ---------------------------------------------------------------------------
# Graceful drain
# ---------------------------------------------------------------------------

class ServeDrain:
    """Cooperative shutdown signal for ``serve_continuous``: once
    requested (SIGTERM handler, or deterministically at a virtual step
    via ``after_steps`` for tests), the loop stops admitting, lets
    in-flight requests finish — or, past ``drain_timeout``, stops at the
    next boundary with their progress safely journaled — then flushes
    the journal and takes a final snapshot."""

    def __init__(self, after_steps: int | None = None):
        self.after_steps = after_steps
        self._requested = False

    def request(self) -> None:
        self._requested = True

    def poll(self, step: int) -> bool:
        return self._requested or (self.after_steps is not None
                                   and step >= self.after_steps)


# ---------------------------------------------------------------------------
# Crash/restart harness
# ---------------------------------------------------------------------------

def serve_with_recovery(params, cfg, requests, *, journal_dir: str,
                        plans=(), max_restarts: int = 8, resume=False,
                        **kw):
    """Run ``serve_continuous`` under injected crashes until the trace
    completes: each ``SimulatedCrash`` abandons the serve's in-memory
    state (exactly what process death does) and restarts it with
    ``resume=True`` against the same journal directory.

    ``plans`` is one ``ServeFaultPlan`` per attempt — attempt ``k`` runs
    under ``plans[k]`` (``None`` past the end), so a test can crash the
    first attempt at a chosen point and let the restart run clean (or
    crash again). Returns ``(result, crashes)``: the final
    ``ServeResult`` (replayed completions included) and how many crashes
    were survived."""
    from repro.runtime.fault_tolerance import SimulatedCrash
    from repro.runtime.generate import serve_continuous

    crashes = 0
    while True:
        plan = plans[crashes] if crashes < len(plans) else None
        try:
            res = serve_continuous(params, cfg, requests,
                                   journal_dir=journal_dir, resume=resume,
                                   faults=plan, **kw)
            return res, crashes
        except SimulatedCrash:
            crashes += 1
            if crashes > max_restarts:
                raise
            resume = True
