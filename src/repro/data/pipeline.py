"""Sharded token data pipeline.

Two sources:
- ``SyntheticSource`` — deterministic tokens from (seed, step): infinitely
  repeatable, resumable by construction (used by examples/benchmarks and as
  the failure-free default).
- ``MemmapSource`` — a flat uint16/uint32 token file (e.g. tokenized corpus)
  read as (step, shard)-indexed windows without loading into RAM.

The pipeline produces *globally sharded* jax arrays for the mesh's batch
axes via ``jax.make_array_from_callback``: each host/device only
materializes its own shard — the multi-host pattern; on the single-process
container the callback just slices a host buffer.

State = an integer step: checkpointing the pipeline is checkpointing one
int (see repro/checkpoint), and elastic restarts on a different pod count
re-slice the same global step deterministically.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


class SyntheticSource:
    """Deterministic pseudo-corpus: token[i] = mix(seed, i) mod vocab."""

    def __init__(self, vocab_size: int, seed: int = 0):
        self.vocab = vocab_size
        self.seed = seed

    def window(self, start: int, n: int) -> np.ndarray:
        idx = (np.arange(start, start + n, dtype=np.uint64)
               + np.uint64(self.seed) * np.uint64(0x9E3779B97F4A7C15))
        idx ^= idx >> np.uint64(33)
        idx *= np.uint64(0xFF51AFD7ED558CCD)
        idx ^= idx >> np.uint64(33)
        return (idx % np.uint64(self.vocab)).astype(np.int32)


class MemmapSource:
    def __init__(self, path: str, vocab_size: int, dtype=np.uint16):
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.vocab = vocab_size

    def window(self, start: int, n: int) -> np.ndarray:
        start = start % max(len(self.tokens) - n, 1)
        return np.asarray(self.tokens[start:start + n], dtype=np.int32)


@dataclasses.dataclass
class PipelineState:
    step: int = 0


class DataPipeline:
    """Yields {"tokens": (B, S+1) int32 global array} batches."""

    def __init__(self, source, batch: int, seq_len: int, mesh,
                 frontend_shape=None):
        self.source = source
        self.batch = batch
        self.seq = seq_len
        self.mesh = mesh
        self.frontend_shape = frontend_shape
        bax = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
        nb = int(np.prod([mesh.shape[a] for a in bax]))
        self.spec = P(bax if batch % nb == 0 else None, None)
        self.state = PipelineState()

    def _host_batch(self, step: int) -> np.ndarray:
        span = self.batch * (self.seq + 1)
        flat = self.source.window(step * span, span)
        return flat.reshape(self.batch, self.seq + 1)

    def next(self) -> dict:
        step = self.state.step
        host = self._host_batch(step)
        sharding = NamedSharding(self.mesh, self.spec)
        arr = jax.make_array_from_callback(
            host.shape, sharding, lambda idx: host[idx])
        batch = {"tokens": arr}
        if self.frontend_shape is not None:
            fe = np.zeros((self.batch,) + tuple(self.frontend_shape),
                          np.float32)
            fe += np.linspace(0, 1, fe.shape[-1], dtype=np.float32)
            batch["frontend"] = jax.make_array_from_callback(
                fe.shape, NamedSharding(self.mesh,
                                        P(self.spec[0], None, None)),
                lambda idx: fe[idx])
        self.state.step += 1
        return batch

    # -- checkpointable state --------------------------------------------
    def state_dict(self) -> dict:
        return {"step": self.state.step}

    def load_state_dict(self, d: dict):
        self.state.step = int(d["step"])
