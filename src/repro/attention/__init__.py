"""Unified quantized-attention engine — the single public attention API.

One integer attention pipeline (int8 Q·Kᵀ → requant onto the ε-grid →
shift-only streaming softmax → int A·V), many implementations, one front
door:

    from repro import attention as ATT

    spec = ATT.AttentionSpec(mode="decode", impl="ita", causal=True,
                             window=0, q_len=1)
    scales = ATT.QuantScales.per_tensor(0.05, s_out=0.02)
    out = ATT.dispatch(q, k, v, spec=spec, scales=scales,
                       q_offset=off, kv_len=n)

    ATT.list_backends(spec)          # eligible backends, priority order
    ATT.backend_reasons(spec)        # every backend's verdict
    ATT.dispatch(..., backend="ita_onepass_pallas")   # explicit override

Pieces:

- ``AttentionSpec``: frozen, hashable description of the computation
  (mode/impl/causal/window/softcap/query-scale/softmax/layout/GQA).
- ``QuantScales``: pytree of the s_q/s_k/s_v/s_out quantization scales
  (per-tensor scalars or per-head vectors).
- ``KVCacheState``: typed int8 KV ring-buffer state (replaces the plain
  cache dicts).
- ``PagedKVState``: the continuous-batching allocator — one shared
  ``(num_pages, page_size, G, hd)`` arena, per-sequence page tables, an
  on-device free stack and per-page refcounts (prefix sharing +
  copy-on-write); logical ring semantics, O(live tokens) memory.
  Served by the fused kernels through the ``bhsd_paged`` layout +
  ``dispatch(..., page_table=...)``.
- ``PrefixIndex``: host-side chain-hash map from page-aligned prompt
  chunks to the physical pages already holding their bytes — the lookup
  structure behind serve-time KV prefix sharing.
- Backend registry: each implementation declares ``supports(spec)``;
  ``dispatch`` runs the first eligible backend (or an explicit
  ``backend=`` override). Adding a kernel = one ``register_backend``
  call, not another branch in a model if-ladder.
"""

from repro.attention.registry import (Backend, BackendUnsupported,  # noqa: F401
                                      all_backends, backend_reasons,
                                      dispatch, get_backend, list_backends,
                                      register_backend)
from repro.attention.spec import AttentionSpec, QuantScales  # noqa: F401
from repro.attention.state import (KVCacheState, PagedKVState,  # noqa: F401
                                   PrefixIndex)

# Importing the module registers the built-in backends.
from repro.attention import backends as _backends  # noqa: F401,E402

__all__ = [
    "AttentionSpec", "QuantScales", "KVCacheState", "PagedKVState",
    "PrefixIndex",
    "Backend", "BackendUnsupported", "dispatch", "list_backends",
    "backend_reasons", "register_backend", "get_backend", "all_backends",
]
