"""Direct (one-shot) XLA attention paths in model layout (B,S,H,hd).

These materialize the (Sq, Skv) logit matrix, so they serve the *short-q*
cases: decode steps over a KV cache and the integer serve specs the fused
Pallas kernels decline (logit softcap, custom query scale, long decode
bursts). GQA is native — KV heads are never broadcast.

Registered behind ``float_xla`` / ``ita_direct_xla`` / ``ibert_xla`` in
``repro.attention.backends``; call ``repro.attention.dispatch`` rather
than this module directly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import softmax as S
from repro.core.quant import EPS_MAX, INT8_MAX, INT8_MIN


def softcap(x, cap):
    return jnp.tanh(x / cap) * cap if cap else x


def quantize_to_int8(x, scale):
    """Quantize onto a fixed (per-tensor or broadcastable) scale."""
    q = jnp.round(x.astype(jnp.float32) / scale)
    return jnp.clip(q, INT8_MIN, INT8_MAX).astype(jnp.int8)


def mask(sq, skv, q_offset, causal, window, kv_len):
    """Validity mask. ``q_offset``/``kv_len`` may be scalars (dense) or
    (B,) per-sequence vectors (ragged batch); the result is (sq, skv) or
    (B, sq, skv) accordingly."""
    q_off = jnp.asarray(q_offset, jnp.int32)
    kvl = None if kv_len is None else jnp.asarray(kv_len, jnp.int32)
    if q_off.ndim or (kvl is not None and kvl.ndim):
        b = q_off.shape[0] if q_off.ndim else kvl.shape[0]
        q_off = jnp.broadcast_to(q_off.reshape(-1), (b,))[:, None, None]
        if kvl is not None:
            kvl = jnp.broadcast_to(kvl.reshape(-1), (b,))[:, None, None]
    qi = q_off + jnp.arange(sq, dtype=jnp.int32)[:, None]
    kj = jnp.arange(skv, dtype=jnp.int32)[None, :]
    m = jnp.ones(qi.shape[:-1] + (skv,), jnp.bool_)
    if causal or window > 0:
        m &= qi >= kj
    if window > 0:
        m &= (qi - kj) < window
    if kv_len is not None:
        m = m & (kj < kvl)
    return m


def _lift(m):
    """mask -> broadcastable against (B, G, M, Sq, Skv) logits."""
    return m[:, None, None] if m.ndim == 3 else m[None, None, None]


def gqa_logits(q, k):
    """q (B,Sq,H,hd), k (B,Skv,G,hd) -> logits (B,G,H/G,Sq,Skv) without
    materializing broadcast KV heads."""
    b, sq, h, hd = q.shape
    g = k.shape[2]
    qg = q.reshape(b, sq, g, h // g, hd)
    return jnp.einsum("bqgmd,bkgd->bgmqk", qg, k)


def gqa_out(p, v):
    """p (B,G,M,Sq,Skv), v (B,Skv,G,hd) -> (B,Sq,H,hd)."""
    out = jnp.einsum("bgmqk,bkgd->bqgmd", p, v)
    b, sq, g, m, hd = out.shape
    return out.reshape(b, sq, g * m, hd)


def direct_float(q, k, v, *, scale, cap=0.0, causal=True, window=0,
                 q_offset=0, kv_len=None):
    """Float softmax attention; q (B,Sq,H,hd), k/v (B,Skv,G,hd) float.
    Returns (B,Sq,H,hd) in v.dtype-ish precision."""
    m = _lift(mask(q.shape[1], k.shape[1], q_offset, causal, window,
                   kv_len))
    logits = gqa_logits(q, k) * scale
    logits = softcap(logits, cap)
    logits = jnp.where(m, logits, -jnp.inf)
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    p = jnp.where(m, p, 0.0).astype(v.dtype)
    return gqa_out(p, v)


def direct_int(q8, k8, v8, *, s_q, s_k, s_v, scale, impl="ita",
               softmax="adaptive", cap=0.0, causal=True, window=0,
               q_offset=0, kv_len=None):
    """Integer serve path: int8 Q·Kᵀ (int32 accum), requant onto the ITA
    logit grid (with optional float-side softcap), shift-only or I-BERT
    softmax, int A·V. q8 (B,Sq,H,hd), k8/v8 (B,Skv,G,hd) int8.
    Returns (B,Sq,H,hd) float32 (dequantized through s_v)."""
    sq_, skv = q8.shape[1], k8.shape[1]
    m = _lift(mask(sq_, skv, q_offset, causal, window, kv_len))

    acc = gqa_logits(q8.astype(jnp.int32), k8.astype(jnp.int32))     # int32
    logits_f = acc.astype(jnp.float32) * (s_q * s_k * scale)
    logits_f = softcap(logits_f, cap)
    lq = jnp.clip(jnp.round(logits_f / EPS_MAX), INT8_MIN, INT8_MAX
                  ).astype(jnp.int32)
    bmask = jnp.broadcast_to(m, lq.shape)

    if impl == "ibert":
        p = S.ibert_softmax(lq, mask=bmask)                 # f32 probs
        out = jnp.einsum("bgmqk,bkgd->bqgmd", p, v8.astype(jnp.float32))
        out = out * s_v
    else:                                                   # ITA
        if softmax == "paper":
            p_int, sigma, _ = S.ita_softmax_int(lq, mask=bmask)
            e_r = jnp.full_like(sigma, 8)
        else:                                               # adaptive
            p_int, e_r, _ = S.ita_softmax_adaptive_int(lq, mask=bmask)
        acc_o = jnp.einsum("bgmqk,bkgd->bqgmd", p_int,
                           v8.astype(jnp.int32))            # Σp·v, int32-safe
        out = acc_o.astype(jnp.float32) \
            * jnp.exp2(-e_r.astype(jnp.float32)).transpose(0, 3, 1, 2, 4) \
            * s_v
    b, sq2, g, mm, hd = out.shape
    return out.reshape(b, sq2, g * mm, hd)
