"""Streaming (chunked) attention — the paper's DA/DI/EN dataflow expressed
at the XLA level, so the S×S attention matrix is never materialized (at
train_4k on the assigned configs that matrix would be hundreds of TB).

One skeleton, three arithmetics:

- ``float``    — classic online softmax (exp rescale corrections).
- ``ita_ste``  — QAT forward: base-2, STE-floored exponent shifts and the
                 *same shift-based running-max correction the silicon
                 applies* (training sees deployed semantics).
- ``ita_int``  — serve path: int8 Q·Kᵀ chunks requantized onto the ITA
                 logit grid, integer DA (Σ >>= Δmax>>5), fused ``u=128>>k``
                 numerators, adaptive power-of-two DI — mirrors the Pallas
                 onepass kernel exactly (same semantics at chunk granularity).

Chunking: python loop over q chunks (static) × ``lax.scan`` over the
causally-reachable kv chunks per q chunk (so causal/windowed FLOPs are
~half of dense, matching the analytic roofline). ``scan_unroll`` unrolls
the kv scan for cost-true dry-run lowering.

Lives behind the ``float_xla`` / ``ita_chunked_xla`` registry backends —
call ``repro.attention.dispatch`` rather than this module directly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.attention.xla import softcap as _softcap
from repro.core.quant import EPS_MAX, SOFTMAX_SHIFT
from repro.core.softmax import _ste_floor, _ste_round

NEG = -1e30
Q_CHUNK = 512
KV_CHUNK = 512


def _chunk_mask(cq, ckv, q0, k0, causal, window, kv_len):
    qi = q0 + jax.lax.broadcasted_iota(jnp.int32, (cq, ckv), 0)
    kj = k0 + jax.lax.broadcasted_iota(jnp.int32, (cq, ckv), 1)
    valid = jnp.ones((cq, ckv), jnp.bool_)
    if causal or window > 0:
        valid &= qi >= kj
    if window > 0:
        valid &= (qi - kj) < window
    if kv_len is not None:
        valid &= kj < kv_len
    return valid[None, None, None]


def _gqa_chunk_logits(qc, kc):
    """qc (B,cq,G,M,hd) x kc (B,ckv,G,hd) -> (B,G,M,cq,ckv)."""
    return jnp.einsum("bqgmd,bkgd->bgmqk", qc, kc)


def streaming_attention(q, k, v, *, impl, scale, s_q=None, s_k=None,
                        s_v=None, causal=True, window=0, kv_len=None,
                        softcap=0.0, adaptive=True, q_chunk=Q_CHUNK,
                        kv_chunk=KV_CHUNK, scan_unroll=False):
    """q (B,Sq,H,hd); k/v (B,Skv,G,hd) (int8 for ita_int). Returns
    (B,Sq,H,hd) f32-ish output of softmax(QKᵀ)·V in the chosen arithmetic.
    Static q_offset=0 (decode uses the direct path)."""
    b, sq_in, h, hd = q.shape
    skv_in, g = k.shape[1], k.shape[2]
    m_ = h // g
    # pad sequences to chunk multiples; padded kv is masked via kv_len,
    # padded q rows are sliced off at the end
    cq = min(q_chunk, sq_in)
    ckv = min(kv_chunk, skv_in)
    pad_q, pad_kv = (-sq_in) % cq, (-skv_in) % ckv
    if pad_kv and kv_len is None:
        kv_len = skv_in
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    sq, skv = sq_in + pad_q, skv_in + pad_kv
    n_q = sq // cq
    unroll = bool(scan_unroll)

    if impl == "ita_int":
        # int8 operands stay int8: the dots carry preferred_element_type
        # int32 so XLA emits the MXU int8 path (v5e: 2x bf16 throughput)
        q_i = q.astype(jnp.int8).reshape(b, sq, g, m_, hd)
        k_i = k.astype(jnp.int8)
        v_f = v.astype(jnp.int8)
        lmult = jnp.asarray(s_q * s_k * scale / EPS_MAX, jnp.float32)
        fmult = jnp.asarray(s_q * s_k * scale, jnp.float32)
    elif impl == "ita_ste":
        qq = jnp.clip(_ste_round(q.astype(jnp.float32) / (s_q * 1.0)), -128,
                      127).reshape(b, sq, g, m_, hd)
        kq = jnp.clip(_ste_round(k.astype(jnp.float32) / s_k), -128, 127)
        v_f = v.astype(jnp.float32)
        lmult = s_q * s_k * scale / EPS_MAX
        fmult = s_q * s_k * scale
    else:
        qf = q.astype(jnp.float32).reshape(b, sq, g, m_, hd)
        kf = k.astype(jnp.float32)
        v_f = v.astype(jnp.float32)

    outs = []
    for iq in range(n_q):
        q0 = iq * cq
        # causally reachable kv chunk range (static)
        hi = (min(q0 + cq, skv) + ckv - 1) // ckv if causal \
            else skv // ckv
        lo = 0
        if window > 0:
            lo = max(0, (q0 - window + 1) // ckv)
        n_steps = max(hi - lo, 1)

        if impl == "ita_int":
            qc = q_i[:, q0:q0 + cq]
            carry = (jnp.full((b, g, m_, cq, 1), -256, jnp.int32),
                     jnp.zeros((b, g, m_, cq, 1), jnp.int32),
                     jnp.zeros((b, g, m_, cq, hd), jnp.float32))
        else:
            qc = (qq if impl == "ita_ste" else qf)[:, q0:q0 + cq]
            carry = (jnp.full((b, g, m_, cq, 1), NEG, jnp.float32),
                     jnp.zeros((b, g, m_, cq, 1), jnp.float32),
                     jnp.zeros((b, g, m_, cq, hd), jnp.float32))

        def body(carry, step, qc=qc, q0=q0, lo=lo):
            m, sig, acc = carry
            k0 = (lo + step) * ckv
            kc = jax.lax.dynamic_slice_in_dim(
                k_i if impl == "ita_int" else kf if impl == "float" else kq,
                k0, ckv, 1)
            vc = jax.lax.dynamic_slice_in_dim(v_f, k0, ckv, 1)
            valid = _chunk_mask(cq, ckv, q0, k0, causal, window, kv_len)

            if impl == "ita_int":
                acc32 = jnp.einsum("bqgmd,bkgd->bgmqk", qc, kc,
                                   preferred_element_type=jnp.int32)
                # softcap=0 keeps the pre-multiplied lmult formula —
                # bit-identical requant vs the fused Pallas kernels
                lf = (acc32.astype(jnp.float32) * lmult if not softcap
                      else _softcap(acc32.astype(jnp.float32) * fmult,
                                    softcap) / EPS_MAX)
                lg = jnp.clip(jnp.round(lf), -128, 127).astype(jnp.int32)
                x = jnp.where(valid, lg, -256)
                new_m = jnp.maximum(m, jnp.max(x, -1, keepdims=True))
                delta = jnp.minimum(jax.lax.shift_right_logical(
                    new_m - m, SOFTMAX_SHIFT), 31)
                kk = jax.lax.shift_right_logical(new_m - lg, SOFTMAX_SHIFT)
                kk = jnp.where(valid, jnp.minimum(kk, 31), 31)
                # u = 128>>k clipped to int8 (127) so the A·V product also
                # rides the int8 MXU; Σ uses the same clipped numerators so
                # normalization stays consistent (<=0.8% skew on the max
                # element; in silicon u is uint8 and 128 fits exactly).
                u = jnp.minimum(jax.lax.shift_right_logical(
                    jnp.int32(128), kk), 127)
                sig = jax.lax.shift_right_logical(sig, delta) \
                    + 2 * jnp.sum(u, -1, keepdims=True)
                pv = jnp.einsum("bgmqk,bkgd->bgmqd", u.astype(jnp.int8), vc,
                                preferred_element_type=jnp.int32)
                acc = acc * jnp.exp2(-delta.astype(jnp.float32)) \
                    + pv.astype(jnp.float32)
                return (new_m, sig, acc), None

            s = _gqa_chunk_logits(qc, kc)
            if impl == "ita_ste":
                lf = (s * lmult if not softcap
                      else _softcap(s * fmult, softcap) / EPS_MAX)
                lg = jnp.clip(_ste_round(lf), -128.0, 127.0)
                x = jnp.where(valid, lg, NEG)
                new_m = jnp.maximum(m, jnp.max(x, -1, keepdims=True))
                delta = _ste_floor(jnp.clip(
                    (new_m - m) / 2.0 ** SOFTMAX_SHIFT, 0.0, 1e4))
                kk = _ste_floor((new_m - lg) / 2.0 ** SOFTMAX_SHIFT)
                w = jnp.where(valid, jnp.exp2(-jnp.clip(kk, 0.0, 30.0)), 0.0)
                corr = jnp.exp2(-jnp.minimum(delta, 30.0))
            else:
                s = _softcap(s * scale, softcap)
                x = jnp.where(valid, s, NEG)
                new_m = jnp.maximum(m, jnp.max(x, -1, keepdims=True))
                w = jnp.where(valid, jnp.exp(s - new_m), 0.0)
                corr = jnp.exp(m - new_m)
            sig = sig * corr + jnp.sum(w, -1, keepdims=True)
            acc = acc * corr + jnp.einsum("bgmqk,bkgd->bgmqd", w, vc)
            return (new_m, sig, acc), None

        (m, sig, acc), _ = jax.lax.scan(
            body, carry, jnp.arange(n_steps),
            unroll=n_steps if unroll else 1)

        if impl == "ita_int":
            sig = jnp.maximum(sig, 1)
            if adaptive:
                e_r = 31 - jax.lax.clz(sig)
            else:                       # paper DI: e_r pinned to 8 (2^16/σ)
                e_r = jnp.full_like(sig, 8)
            pre = jnp.maximum(e_r + 8 - 30, 0)
            inv = (jnp.int32(1) << jnp.minimum(e_r + 8 - pre, 30)) \
                // jax.lax.shift_right_logical(sig, pre)
            o = acc * (2.0 * inv.astype(jnp.float32)
                       * jnp.exp2(-(e_r + 8).astype(jnp.float32))) \
                * jnp.asarray(s_v, jnp.float32)
        else:
            o = acc / jnp.maximum(sig, 1e-9)
        outs.append(o)                              # (B,G,M,cq,hd)

    out = jnp.concatenate(outs, axis=3) if n_q > 1 else outs[0]
    out = jnp.moveaxis(out, 3, 1)                   # (B,Sq,G,M,hd)
    return out.reshape(b, sq, h, hd)[:, :sq_in]
