"""Typed int8 KV-cache ring-buffer state.

``KVCacheState`` replaces the plain ``{"k", "v", "pos", ...}`` dicts the
serving stack used to pass around: same leaves, same scan/shard/donate
behaviour (it is a registered dataclass pytree), but the ring-buffer
invariants live on the type instead of in every caller's head.

Layout: ``k``/``v`` are ``(B, C, G, hd)`` with capacity ``C`` a ring —
token ``t`` lives in slot ``t % C``. ``pos`` is **per sequence**,
``(B,)`` int32: each row of the batch tracks its own logical stream
length, so a ragged batch (different prompt lengths) shares one cache
and one kernel call. The valid prefix (``valid_len``) and the logical
position of new queries (``q_offset``) derive from ``pos`` and are
``(B,)`` vectors that flow through ``dispatch`` into the per-row kernel
meta. ``k_scale``/``v_scale`` are optional per-(kv-)head quantization
scales ``(G,)`` (the decode engine's finer-than-QAT grid); ``None`` when
the cache rides the model's per-tensor QAT scales.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class KVCacheState:
    k: Any                      # (B, C, G, hd) int8 (or compute dtype)
    v: Any                      # (B, C, G, hd)
    pos: Any                    # (B,) int32 — tokens ever written, per seq
    k_scale: Any = None         # (G,) f32 per-head scales, optional
    v_scale: Any = None         # (G,) f32

    # -- construction -----------------------------------------------------

    @classmethod
    def init(cls, batch: int, capacity: int, n_kv_heads: int, head_dim: int,
             dtype=jnp.int8, per_head_scales: bool = False) -> "KVCacheState":
        """Fresh (zeroed) ring-buffer cache."""
        capacity = max(capacity, 1)
        shape = (batch, capacity, n_kv_heads, head_dim)
        scales = (jnp.ones((n_kv_heads,), jnp.float32)
                  if per_head_scales else None)
        return cls(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   pos=jnp.zeros((batch,), jnp.int32), k_scale=scales,
                   v_scale=scales)

    def with_scales(self, k_scale, v_scale) -> "KVCacheState":
        return dataclasses.replace(self, k_scale=k_scale, v_scale=v_scale)

    # -- ring geometry ----------------------------------------------------

    @property
    def capacity(self) -> int:
        return self.k.shape[1]

    def valid_len(self) -> jax.Array:
        """Per-sequence number of valid (non-evicted) ring entries, (B,)."""
        return jnp.minimum(self.pos, self.capacity)

    def q_offset(self, s_new: int = 1) -> jax.Array:
        """Logical position of the first of the ``s_new`` query tokens
        *just appended*, in ring coordinates: ``valid_len - s_new``, per
        sequence ``(B,)``. While a ring has not wrapped this is the
        token's stream position; after wrap the oldest surviving token is
        redefined as position 0, so the newest query sits at ``C - s_new``
        and the sliding-window mask ``(qi - kj) < window`` keeps exactly
        the last ``window`` slots visible."""
        return jnp.maximum(self.valid_len() - s_new, 0)

    # -- writes -----------------------------------------------------------

    def prefill_write(self, k_q: jax.Array, v_q: jax.Array,
                      lengths: jax.Array | None = None) -> "KVCacheState":
        """Bulk-write ``S`` prefill tokens, evicting beyond capacity.

        ``k_q``/``v_q`` (B, S, G, hd), already quantized. Token ``t``
        lands in slot ``t % C`` (so a later ``decode_append`` continues
        the same ring); when ``S >= C`` only the last ``C`` tokens
        survive. ``lengths`` (B,) declares a *ragged* batch of
        right-padded prompts: row ``b`` holds ``lengths[b] <= S`` real
        tokens, ``pos`` starts there and the pad slots are dead weight
        masked out by ``valid_len`` until decode appends overwrite them.
        Ragged prefill requires ``C >= S`` (per-sequence eviction of a
        padded prompt would need per-row rolls)."""
        b, s = k_q.shape[:2]
        cs = self.capacity
        if lengths is not None:
            if s > cs:
                raise ValueError(
                    f"ragged prefill needs capacity >= padded prompt length "
                    f"(got S={s} > C={cs}); grow the ring (max_len, or the "
                    f"window for window-capped caches) or drop lengths")
            pos = jnp.asarray(lengths, jnp.int32).reshape(b)
        else:
            pos = jnp.full((b,), s, jnp.int32)
        if s >= cs:
            # keep the tail, rolled so slot (t % C) holds token t
            k_t = jnp.roll(k_q[:, s - cs:], s % cs, axis=1)
            v_t = jnp.roll(v_q[:, s - cs:], s % cs, axis=1)
        else:
            k_t = jax.lax.dynamic_update_slice(self.k, k_q, (0, 0, 0, 0))
            v_t = jax.lax.dynamic_update_slice(self.v, v_q, (0, 0, 0, 0))
        return dataclasses.replace(self, k=k_t, v=v_t, pos=pos)

    def decode_append(self, k_q: jax.Array, v_q: jax.Array) -> "KVCacheState":
        """Append ``s_new`` decode tokens per sequence: row ``b``'s token
        ``pos[b] + i`` goes to slot ``(pos[b] + i) % C``. A batched
        scatter (``.at[batch, slots]``) rather than dynamic_update_slice:
        slots differ per row in a ragged batch, and a blockwise slice
        would *clamp* at the ring boundary instead of wrapping (silently
        overwriting the newest surviving entries). ``s_new`` is 1 in
        steady-state decode, <= 8 for speculative bursts; a burst longer
        than the ring writes only its last ``C`` tokens (the survivors) —
        scattering all of them would hit duplicate slots, whose winner
        JAX leaves unspecified."""
        b, s_new = k_q.shape[:2]
        cs = self.capacity
        start = max(s_new - cs, 0)
        slots = (self.pos[:, None] + start
                 + jnp.arange(s_new - start, dtype=jnp.int32)[None, :]) % cs
        bidx = jnp.arange(b, dtype=jnp.int32)[:, None]
        # unique_indices: consecutive slots mod C, count <= C — no
        # collisions, so XLA can emit the cheap unordered scatter
        k_t = self.k.at[bidx, slots].set(k_q[:, start:],
                                         unique_indices=True)
        v_t = self.v.at[bidx, slots].set(v_q[:, start:],
                                         unique_indices=True)
        return dataclasses.replace(self, k=k_t, v=v_t,
                                   pos=self.pos + s_new)


jax.tree_util.register_dataclass(
    KVCacheState, data_fields=("k", "v", "pos", "k_scale", "v_scale"),
    meta_fields=())
