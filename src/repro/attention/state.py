"""Typed int8 KV-cache state: the contiguous ring buffer and the paged pool.

``KVCacheState`` replaces the plain ``{"k", "v", "pos", ...}`` dicts the
serving stack used to pass around: same leaves, same scan/shard/donate
behaviour (it is a registered dataclass pytree), but the ring-buffer
invariants live on the type instead of in every caller's head.

Layout: ``k``/``v`` are ``(B, C, G, hd)`` with capacity ``C`` a ring —
token ``t`` lives in slot ``t % C``. ``pos`` is **per sequence**,
``(B,)`` int32: each row of the batch tracks its own logical stream
length, so a ragged batch (different prompt lengths) shares one cache
and one kernel call. The valid prefix (``valid_len``) and the logical
position of new queries (``q_offset``) derive from ``pos`` and are
``(B,)`` vectors that flow through ``dispatch`` into the per-row kernel
meta. ``k_scale``/``v_scale`` are optional per-(kv-)head quantization
scales ``(G,)`` (the decode engine's finer-than-QAT grid); ``None`` when
the cache rides the model's per-tensor QAT scales.

``PagedKVState`` is the continuous-batching allocator: **one** shared
``(num_pages, page_size, G, hd)`` int8 arena for the whole batch, a
per-sequence page table translating logical KV pages to physical arena
pages, and an on-device free stack. Logical semantics are *identical* to
a ring of capacity ``n_pages * page_size`` (slot ``t % C``, same
``pos``/``valid_len``/``q_offset``), so the fused kernels' paged layout
is bit-identical to the ring path — but physically a sequence only holds
``ceil(pos / page_size)`` pages, and ``release`` returns them to the
pool the moment the sequence finishes: KV memory is O(tokens live), not
O(B * max_len) reserved. Physical page 0 is the **parking page** — never
allocated, it absorbs masked writes (dead batch slots, right-pad tokens)
and backs unassigned page-table entries, so every scatter/gather stays
in bounds without branches.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels.common import MIN_BLOCK_KV


def _align_capacity(capacity: int) -> int:
    """Round a ring/pool capacity above one KV block up to a block
    multiple, so the fused kernels' `_pad_seq` is statically a no-op on
    the decode hot path (any block_kv dividing MIN_BLOCK_KV stays
    pad-free)."""
    capacity = max(capacity, 1)
    if capacity > MIN_BLOCK_KV:
        capacity = -(-capacity // MIN_BLOCK_KV) * MIN_BLOCK_KV
    return capacity


def _ceil_div(a, b):
    return (a + b - 1) // b


@dataclasses.dataclass(frozen=True)
class KVCacheState:
    k: Any                      # (B, C, G, hd) int8 (or compute dtype)
    v: Any                      # (B, C, G, hd)
    pos: Any                    # (B,) int32 — tokens ever written, per seq
    k_scale: Any = None         # (G,) f32 per-head scales, optional
    v_scale: Any = None         # (G,) f32

    # -- construction -----------------------------------------------------

    @classmethod
    def init(cls, batch: int, capacity: int, n_kv_heads: int, head_dim: int,
             dtype=jnp.int8, per_head_scales: bool = False) -> "KVCacheState":
        """Fresh (zeroed) ring-buffer cache. Capacities above one KV block
        are rounded up to a ``MIN_BLOCK_KV`` multiple so the per-step
        ``_pad_seq`` in the fused-attention plumbing is statically a
        no-op (it asserts as much on the decode path)."""
        capacity = _align_capacity(capacity)
        shape = (batch, capacity, n_kv_heads, head_dim)
        scales = (jnp.ones((n_kv_heads,), jnp.float32)
                  if per_head_scales else None)
        return cls(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   pos=jnp.zeros((batch,), jnp.int32), k_scale=scales,
                   v_scale=scales)

    def with_scales(self, k_scale, v_scale) -> "KVCacheState":
        return dataclasses.replace(self, k_scale=k_scale, v_scale=v_scale)

    # -- ring geometry ----------------------------------------------------

    @property
    def capacity(self) -> int:
        return self.k.shape[1]

    def valid_len(self) -> jax.Array:
        """Per-sequence number of valid (non-evicted) ring entries, (B,)."""
        return jnp.minimum(self.pos, self.capacity)

    def q_offset(self, s_new: int = 1) -> jax.Array:
        """Logical position of the first of the ``s_new`` query tokens
        *just appended*, in ring coordinates: ``valid_len - s_new``, per
        sequence ``(B,)``. While a ring has not wrapped this is the
        token's stream position; after wrap the oldest surviving token is
        redefined as position 0, so the newest query sits at ``C - s_new``
        and the sliding-window mask ``(qi - kj) < window`` keeps exactly
        the last ``window`` slots visible."""
        return jnp.maximum(self.valid_len() - s_new, 0)

    # -- writes -----------------------------------------------------------

    def prefill_write(self, k_q: jax.Array, v_q: jax.Array,
                      lengths: jax.Array | None = None) -> "KVCacheState":
        """Bulk-write ``S`` prefill tokens, evicting beyond capacity.

        ``k_q``/``v_q`` (B, S, G, hd), already quantized. Token ``t``
        lands in slot ``t % C`` (so a later ``decode_append`` continues
        the same ring); when ``S >= C`` only the last ``C`` tokens
        survive. ``lengths`` (B,) declares a *ragged* batch of
        right-padded prompts: row ``b`` holds ``lengths[b] <= S`` real
        tokens, ``pos`` starts there and the pad slots are dead weight
        masked out by ``valid_len`` until decode appends overwrite them.
        Ragged prefill requires ``C >= S`` (per-sequence eviction of a
        padded prompt would need per-row rolls)."""
        b, s = k_q.shape[:2]
        cs = self.capacity
        if lengths is not None:
            if s > cs:
                raise ValueError(
                    f"ragged prefill needs capacity >= padded prompt length "
                    f"(got S={s} > C={cs}); grow the ring (max_len, or the "
                    f"window for window-capped caches) or drop lengths")
            pos = jnp.asarray(lengths, jnp.int32).reshape(b)
        else:
            pos = jnp.full((b,), s, jnp.int32)
        if s >= cs:
            # keep the tail, rolled so slot (t % C) holds token t
            k_t = jnp.roll(k_q[:, s - cs:], s % cs, axis=1)
            v_t = jnp.roll(v_q[:, s - cs:], s % cs, axis=1)
        else:
            k_t = jax.lax.dynamic_update_slice(self.k, k_q, (0, 0, 0, 0))
            v_t = jax.lax.dynamic_update_slice(self.v, v_q, (0, 0, 0, 0))
        return dataclasses.replace(self, k=k_t, v=v_t, pos=pos)

    def decode_append(self, k_q: jax.Array, v_q: jax.Array,
                      live: jax.Array | None = None) -> "KVCacheState":
        """Append ``s_new`` decode tokens per sequence: row ``b``'s token
        ``pos[b] + i`` goes to slot ``(pos[b] + i) % C``. A batched
        scatter (``.at[batch, slots]``) rather than dynamic_update_slice:
        slots differ per row in a ragged batch, and a blockwise slice
        would *clamp* at the ring boundary instead of wrapping (silently
        overwriting the newest surviving entries). ``s_new`` is 1 in
        steady-state decode, <= 8 for speculative bursts; a burst longer
        than the ring writes only its last ``C`` tokens (the survivors) —
        scattering all of them would hit duplicate slots, whose winner
        JAX leaves unspecified. ``live`` (B,) bool masks dead batch slots
        (continuous batching): their writes are dropped and their ``pos``
        does not advance."""
        b, s_new = k_q.shape[:2]
        cs = self.capacity
        start = max(s_new - cs, 0)
        slots = (self.pos[:, None] + start
                 + jnp.arange(s_new - start, dtype=jnp.int32)[None, :]) % cs
        bidx = jnp.arange(b, dtype=jnp.int32)[:, None]
        if live is None:
            # unique_indices: consecutive slots mod C, count <= C — no
            # collisions, so XLA can emit the cheap unordered scatter
            k_t = self.k.at[bidx, slots].set(k_q[:, start:],
                                             unique_indices=True)
            v_t = self.v.at[bidx, slots].set(v_q[:, start:],
                                             unique_indices=True)
            pos = self.pos + s_new
        else:
            # dead rows: out-of-bounds slot + mode="drop" discards the
            # write without a branch (still unique within live rows)
            slots = jnp.where(live[:, None], slots, cs)
            k_t = self.k.at[bidx, slots].set(k_q[:, start:], mode="drop")
            v_t = self.v.at[bidx, slots].set(v_q[:, start:], mode="drop")
            pos = self.pos + s_new * live.astype(jnp.int32)
        return dataclasses.replace(self, k=k_t, v=v_t, pos=pos)


jax.tree_util.register_dataclass(
    KVCacheState, data_fields=("k", "v", "pos", "k_scale", "v_scale"),
    meta_fields=())


# ---------------------------------------------------------------------------
# Paged KV pool
# ---------------------------------------------------------------------------

PARKING_PAGE = 0        # physical page 0: write sink / unassigned entries


@dataclasses.dataclass(frozen=True)
class PagedKVState:
    """Shared paged int8 KV pool + per-sequence page tables + free stack.

    ``k``/``v``: ``(num_pages, page_size, G, hd)`` arena shared by every
    sequence (and, at the model level, one arena per layer).
    ``page_table``: ``(B, n_pages)`` int32 — logical KV page ``j`` of
    sequence ``b`` lives in physical page ``page_table[b, j]``
    (``PARKING_PAGE`` = unassigned). ``pos``: per-sequence stream length,
    exactly as in ``KVCacheState`` — logical slot ``t % capacity`` with
    ``capacity = n_pages * page_size``, so wrap/window semantics (and the
    kernels' view of the bytes) match the ring bit-for-bit.
    ``free_stack``/``free_top``: LIFO of free physical pages; entries
    ``free_stack[:free_top]`` are free. Allocation happens *inside* jit
    (a masked pop per page) so the fused generation scan never leaves the
    device to grow a sequence.
    """

    k: Any                      # (P, page, G, hd)
    v: Any                      # (P, page, G, hd)
    page_table: Any             # (B, n_pages) int32
    pos: Any                    # (B,) int32
    free_stack: Any             # (P,) int32
    free_top: Any               # () int32 — number of free pages
    k_scale: Any = None         # (G,) f32 per-head scales, optional
    v_scale: Any = None

    # -- construction -----------------------------------------------------

    @classmethod
    def init(cls, batch: int, capacity: int, n_kv_heads: int, head_dim: int,
             dtype=jnp.int8, per_head_scales: bool = False, *,
             page_size: int = MIN_BLOCK_KV,
             num_pages: int | None = None) -> "PagedKVState":
        """Fresh pool. ``capacity`` (per-sequence logical window) rounds
        up to a ``page_size`` multiple; ``num_pages`` sizes the shared
        arena (default: fully provisioned, ``B * pages_per_seq`` + the
        parking page — pass less to oversubscribe under an admission
        scheduler)."""
        capacity = max(capacity, 1)
        n_pages = _ceil_div(capacity, page_size)
        if num_pages is None:
            num_pages = batch * n_pages + 1
        if num_pages < 2:
            raise ValueError("num_pages must cover the parking page plus "
                             "at least one allocatable page")
        shape = (num_pages, page_size, n_kv_heads, head_dim)
        scales = (jnp.ones((n_kv_heads,), jnp.float32)
                  if per_head_scales else None)
        # free pages are 1..P-1 (0 is parking); stack[:free_top] free,
        # laid out so the first pop hands out page 1
        stack = jnp.concatenate([
            jnp.arange(num_pages - 1, 0, -1, dtype=jnp.int32),
            jnp.zeros((1,), jnp.int32)])
        return cls(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   page_table=jnp.zeros((batch, n_pages), jnp.int32),
                   pos=jnp.zeros((batch,), jnp.int32),
                   free_stack=stack,
                   free_top=jnp.asarray(num_pages - 1, jnp.int32),
                   k_scale=scales, v_scale=scales)

    def with_scales(self, k_scale, v_scale) -> "PagedKVState":
        return dataclasses.replace(self, k_scale=k_scale, v_scale=v_scale)

    # -- geometry ---------------------------------------------------------

    @property
    def page_size(self) -> int:
        return self.k.shape[1]

    @property
    def num_pages(self) -> int:
        return self.k.shape[0]

    @property
    def pages_per_seq(self) -> int:
        return self.page_table.shape[1]

    @property
    def capacity(self) -> int:
        return self.pages_per_seq * self.page_size

    @property
    def batch(self) -> int:
        return self.page_table.shape[0]

    def pages_held(self) -> jax.Array:
        """Physical pages currently backing each sequence, (B,) int32."""
        return jnp.minimum(_ceil_div(self.pos, self.page_size),
                           self.pages_per_seq)

    def valid_len(self) -> jax.Array:
        return jnp.minimum(self.pos, self.capacity)

    def q_offset(self, s_new: int = 1) -> jax.Array:
        return jnp.maximum(self.valid_len() - s_new, 0)

    # -- allocation -------------------------------------------------------

    def _alloc(self, need: jax.Array) -> "PagedKVState":
        """Pop ``need[b]`` pages per row off the free stack into each
        row's next unassigned page-table entries. Callers guarantee
        ``sum(need) <= free_top`` (the admission scheduler's invariant;
        ``tests/test_paged.py`` property-checks it) — an overdrawn pool
        drives ``free_top`` negative, which ``oversubscribed`` exposes."""
        b = need.shape[0]
        npps = self.pages_per_seq
        held = self.pages_held()
        offs = jnp.cumsum(need) - need                     # exclusive
        cols = jnp.arange(npps, dtype=jnp.int32)[None, :]
        take = cols < need[:, None]                        # (B, npps)
        sidx = self.free_top - 1 - (offs[:, None] + cols)
        phys = self.free_stack[jnp.clip(sidx, 0, self.num_pages - 1)]
        dest = jnp.where(take, held[:, None] + cols, npps)  # OOB -> drop
        bidx = jnp.arange(b, dtype=jnp.int32)[:, None]
        pt = self.page_table.at[bidx, dest].set(phys, mode="drop")
        top = self.free_top - jnp.sum(take.astype(jnp.int32))
        return dataclasses.replace(self, page_table=pt, free_top=top)

    def oversubscribed(self) -> jax.Array:
        """True when an allocation overdrew the pool (scheduler bug)."""
        return self.free_top < 0

    def release(self, finished: jax.Array) -> "PagedKVState":
        """Return the pages of every row with ``finished[b]`` to the free
        stack, clear those rows' tables and reset their ``pos`` to 0 —
        the continuous-batching hand-back that makes a freed slot's
        memory immediately admittable."""
        finished = jnp.asarray(finished, jnp.bool_)
        npps = self.pages_per_seq
        held = self.pages_held()
        give = finished[:, None] \
            & (jnp.arange(npps, dtype=jnp.int32)[None, :] < held[:, None])
        flat_give = give.reshape(-1)
        flat_pages = self.page_table.reshape(-1)
        rank = jnp.cumsum(flat_give.astype(jnp.int32)) - 1
        dest = jnp.where(flat_give, self.free_top + rank, self.num_pages)
        stack = self.free_stack.at[dest].set(flat_pages, mode="drop")
        top = self.free_top + jnp.sum(flat_give.astype(jnp.int32))
        pt = jnp.where(finished[:, None], PARKING_PAGE, self.page_table)
        pos = jnp.where(finished, 0, self.pos)
        return dataclasses.replace(self, page_table=pt, pos=pos,
                                   free_stack=stack, free_top=top)

    # -- writes -----------------------------------------------------------

    def prefill_write(self, k_q: jax.Array, v_q: jax.Array,
                      lengths: jax.Array | None = None) -> "PagedKVState":
        """Bulk-write right-padded prompts for the whole batch (rows must
        be fresh/released, ``pos == 0``). Same signature and logical
        outcome as the ring's ``prefill_write`` minus wrap-eviction: a
        prompt longer than ``capacity`` is refused (serving sizes the
        window first). Only ``ceil(len/page_size)`` pages are allocated
        per row — right-pad columns scatter into the parking page, so a
        ragged batch holds pages for its *tokens*, not its padding."""
        return self.write_prompts(k_q, v_q, lengths=lengths)

    def write_prompts(self, k_q: jax.Array, v_q: jax.Array,
                      lengths: jax.Array | None = None,
                      slots: jax.Array | None = None) -> "PagedKVState":
        """``prefill_write`` generalized to target batch ``slots``: row
        ``i`` of ``k_q``/``v_q`` (n, S, G, hd) lands in batch slot
        ``slots[i]`` (negative = dummy row, dropped entirely) — the
        admission path that prefills newly arrived requests into slots
        another sequence just released, with a fixed-width dispatch shape
        regardless of how many requests actually arrived."""
        n, s = k_q.shape[:2]
        b = self.batch
        ps = self.page_size
        if lengths is None:
            if s > self.capacity:
                raise ValueError(
                    f"paged prefill needs capacity >= prompt length "
                    f"(got S={s} > C={self.capacity}); grow max_len/window")
            new_pos = jnp.full((n,), s, jnp.int32)
        else:
            # Ragged: only the *valid* lengths must fit the window — the
            # source may be wider than the pool's capacity (e.g. a
            # block-aligned admission scratch); every column beyond a
            # row's length scatters into the parking page regardless.
            # Lengths are clamped so a misdeclared over-window row can
            # never push pos past capacity (callers validate upstream).
            new_pos = jnp.minimum(jnp.asarray(lengths, jnp.int32).reshape(n),
                                  self.capacity)
        if slots is None:
            if n != b:
                raise ValueError(f"full-batch prefill expects {b} rows, "
                                 f"got {n} (pass slots= for a partial one)")
            rows = jnp.arange(b, dtype=jnp.int32)
            valid = jnp.ones((n,), jnp.bool_)
        else:
            rows = jnp.asarray(slots, jnp.int32).reshape(n)
            valid = rows >= 0
            rows = jnp.where(valid, rows, b)               # OOB -> drop
        new_pos = new_pos * valid.astype(jnp.int32)

        need_rows = _ceil_div(new_pos, ps)
        need = jnp.zeros((b,), jnp.int32).at[rows].set(need_rows,
                                                       mode="drop")
        new = self._alloc(need)

        t = jnp.arange(s, dtype=jnp.int32)
        # rows == b clamps in the gather; the result is discarded below.
        # Columns past the window (S > capacity sources) clamp to the last
        # logical page — always pad columns, masked to parking below.
        cols = jnp.minimum(t // ps, self.pages_per_seq - 1)
        phys = new.page_table[jnp.minimum(rows, b - 1)][:, cols]     # (n, s)
        real = valid[:, None] & (t[None, :] < new_pos[:, None])
        phys = jnp.where(real, phys, PARKING_PAGE)
        slot = jnp.broadcast_to((t % ps)[None, :], (n, s))
        k_t = new.k.at[phys, slot].set(k_q)
        v_t = new.v.at[phys, slot].set(v_q)
        pos = self.pos.at[rows].set(new_pos, mode="drop")
        return dataclasses.replace(new, k=k_t, v=v_t, pos=pos)

    def decode_append(self, k_q: jax.Array, v_q: jax.Array,
                      live: jax.Array | None = None) -> "PagedKVState":
        """Append ``s_new`` decode tokens per sequence — the jit-safe hot
        path: rows crossing a page boundary pop a fresh page off the free
        stack *on device* (no host round-trip inside the fused scan);
        once a row has wrapped its logical window its existing pages are
        reused in place, exactly like the ring. ``live`` masks dead slots
        (writes park, ``pos`` frozen)."""
        b, s_new = k_q.shape[:2]
        ps, cs = self.page_size, self.capacity
        if live is None:
            live = jnp.ones((b,), jnp.bool_)
        live_i = live.astype(jnp.int32)
        held = self.pages_held()
        want = jnp.minimum(_ceil_div(self.pos + s_new, ps),
                           self.pages_per_seq)
        new = self._alloc((want - held) * live_i)

        start = max(s_new - cs, 0)
        n_eff = s_new - start
        toks = (self.pos[:, None] + start
                + jnp.arange(n_eff, dtype=jnp.int32)[None, :]) % cs
        bidx = jnp.arange(b, dtype=jnp.int32)[:, None]
        phys = new.page_table[bidx, toks // ps]            # (B, n_eff)
        phys = jnp.where(live[:, None], phys, PARKING_PAGE)
        k_t = new.k.at[phys, toks % ps].set(k_q[:, start:])
        v_t = new.v.at[phys, toks % ps].set(v_q[:, start:])
        return dataclasses.replace(new, k=k_t, v=v_t,
                                   pos=self.pos + s_new * live_i)

    def append_chunk(self, k_q: jax.Array, v_q: jax.Array,
                     n_new: jax.Array) -> "PagedKVState":
        """Append a *per-row ragged* chunk: row ``b`` writes its first
        ``n_new[b]`` of the ``S`` presented tokens at logical slots
        ``pos[b] .. pos[b] + n_new[b] - 1``, scattering across page
        boundaries and popping fresh pages off the free stack *inside
        jit* exactly like ``decode_append``. Columns beyond a row's count
        (decode rows in a mixed chunked-prefill batch present 1 real
        token; dead rows 0) scatter into the parking page and that row's
        ``pos`` advances by its own ``n_new`` only — the write primitive
        of the mixed serve step, where one dispatch carries decode rows
        next to prefill chunks with no ring scratch or host bytes-copy."""
        b, s = k_q.shape[:2]
        ps, cs = self.page_size, self.capacity
        if s > cs:
            raise ValueError(
                f"append_chunk width {s} exceeds the per-sequence window "
                f"{cs}; split the chunk (serving sizes chunk <= capacity)")
        n_new = jnp.clip(jnp.asarray(n_new, jnp.int32).reshape(b), 0, s)
        held = self.pages_held()
        want = jnp.minimum(_ceil_div(self.pos + n_new, ps),
                           self.pages_per_seq)
        new = self._alloc(want - held)

        cols = jnp.arange(s, dtype=jnp.int32)[None, :]
        toks = (self.pos[:, None] + cols) % cs             # (B, S)
        bidx = jnp.arange(b, dtype=jnp.int32)[:, None]
        real = cols < n_new[:, None]
        phys = jnp.where(real, new.page_table[bidx, toks // ps],
                         PARKING_PAGE)
        k_t = new.k.at[phys, toks % ps].set(k_q)
        v_t = new.v.at[phys, toks % ps].set(v_q)
        return dataclasses.replace(new, k=k_t, v=v_t, pos=self.pos + n_new)


jax.tree_util.register_dataclass(
    PagedKVState,
    data_fields=("k", "v", "page_table", "pos", "free_stack", "free_top",
                 "k_scale", "v_scale"),
    meta_fields=())
