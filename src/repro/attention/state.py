"""Typed int8 KV-cache state: the contiguous ring buffer and the paged pool.

``KVCacheState`` replaces the plain ``{"k", "v", "pos", ...}`` dicts the
serving stack used to pass around: same leaves, same scan/shard/donate
behaviour (it is a registered dataclass pytree), but the ring-buffer
invariants live on the type instead of in every caller's head.

Layout: ``k``/``v`` are ``(B, C, G, hd)`` with capacity ``C`` a ring —
token ``t`` lives in slot ``t % C``. ``pos`` is **per sequence**,
``(B,)`` int32: each row of the batch tracks its own logical stream
length, so a ragged batch (different prompt lengths) shares one cache
and one kernel call. The valid prefix (``valid_len``) and the logical
position of new queries (``q_offset``) derive from ``pos`` and are
``(B,)`` vectors that flow through ``dispatch`` into the per-row kernel
meta. ``k_scale``/``v_scale`` are optional per-(kv-)head quantization
scales ``(G,)`` (the decode engine's finer-than-QAT grid); ``None`` when
the cache rides the model's per-tensor QAT scales.

``PagedKVState`` is the continuous-batching allocator: **one** shared
``(num_pages, page_size, G, hd)`` int8 arena for the whole batch, a
per-sequence page table translating logical KV pages to physical arena
pages, and an on-device free stack. Logical semantics are *identical* to
a ring of capacity ``n_pages * page_size`` (slot ``t % C``, same
``pos``/``valid_len``/``q_offset``), so the fused kernels' paged layout
is bit-identical to the ring path — but physically a sequence only holds
``ceil(pos / page_size)`` pages, and ``release`` returns them to the
pool the moment the sequence finishes: KV memory is O(tokens live), not
O(B * max_len) reserved. Physical page 0 is the **parking page** — never
allocated and never written (masked writes scatter to an out-of-bounds
index and are dropped), it backs unassigned page-table entries so every
gather stays in bounds without branches, and its bytes stay zero for the
life of the pool.

Pages carry a **refcount** (``ref_count``, per physical page): rows
admitted with a shared prompt prefix point their leading page-table
entries at another row's pages (``adopt_prefix``, +1 each), the
serving-layer prefix index pins registered pages (``incref_pages``) so
they outlive their original row, and ``release``/``decref_pages`` only
push a page back onto the free stack when its count reaches zero. The
append paths copy-on-write: a write landing on a page with refcount > 1
first copies it to a freshly popped page, so sharers never observe each
other's bytes. Sharing is pure bookkeeping — the kernels read whatever
the page tables say, so the paged layout stays bit-identical to the
ring path whether or not pages are shared.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels.common import MIN_BLOCK_KV


def _align_capacity(capacity: int) -> int:
    """Round a ring/pool capacity above one KV block up to a block
    multiple, so the fused kernels' `_pad_seq` is statically a no-op on
    the decode hot path (any block_kv dividing MIN_BLOCK_KV stays
    pad-free)."""
    capacity = max(capacity, 1)
    if capacity > MIN_BLOCK_KV:
        capacity = -(-capacity // MIN_BLOCK_KV) * MIN_BLOCK_KV
    return capacity


def _ceil_div(a, b):
    return (a + b - 1) // b


@dataclasses.dataclass(frozen=True)
class KVCacheState:
    k: Any                      # (B, C, G, hd) int8 (or compute dtype)
    v: Any                      # (B, C, G, hd)
    pos: Any                    # (B,) int32 — tokens ever written, per seq
    k_scale: Any = None         # (G,) f32 per-head scales, optional
    v_scale: Any = None         # (G,) f32

    # -- construction -----------------------------------------------------

    @classmethod
    def init(cls, batch: int, capacity: int, n_kv_heads: int, head_dim: int,
             dtype=jnp.int8, per_head_scales: bool = False) -> "KVCacheState":
        """Fresh (zeroed) ring-buffer cache. Capacities above one KV block
        are rounded up to a ``MIN_BLOCK_KV`` multiple so the per-step
        ``_pad_seq`` in the fused-attention plumbing is statically a
        no-op (it asserts as much on the decode path)."""
        capacity = _align_capacity(capacity)
        shape = (batch, capacity, n_kv_heads, head_dim)
        scales = (jnp.ones((n_kv_heads,), jnp.float32)
                  if per_head_scales else None)
        return cls(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   pos=jnp.zeros((batch,), jnp.int32), k_scale=scales,
                   v_scale=scales)

    def with_scales(self, k_scale, v_scale) -> "KVCacheState":
        return dataclasses.replace(self, k_scale=k_scale, v_scale=v_scale)

    # -- ring geometry ----------------------------------------------------

    @property
    def capacity(self) -> int:
        return self.k.shape[1]

    def valid_len(self) -> jax.Array:
        """Per-sequence number of valid (non-evicted) ring entries, (B,)."""
        return jnp.minimum(self.pos, self.capacity)

    def q_offset(self, s_new: int = 1) -> jax.Array:
        """Logical position of the first of the ``s_new`` query tokens
        *just appended*, in ring coordinates: ``valid_len - s_new``, per
        sequence ``(B,)``. While a ring has not wrapped this is the
        token's stream position; after wrap the oldest surviving token is
        redefined as position 0, so the newest query sits at ``C - s_new``
        and the sliding-window mask ``(qi - kj) < window`` keeps exactly
        the last ``window`` slots visible."""
        return jnp.maximum(self.valid_len() - s_new, 0)

    # -- writes -----------------------------------------------------------

    def prefill_write(self, k_q: jax.Array, v_q: jax.Array,
                      lengths: jax.Array | None = None) -> "KVCacheState":
        """Bulk-write ``S`` prefill tokens, evicting beyond capacity.

        ``k_q``/``v_q`` (B, S, G, hd), already quantized. Token ``t``
        lands in slot ``t % C`` (so a later ``decode_append`` continues
        the same ring); when ``S >= C`` only the last ``C`` tokens
        survive. ``lengths`` (B,) declares a *ragged* batch of
        right-padded prompts: row ``b`` holds ``lengths[b] <= S`` real
        tokens, ``pos`` starts there and the pad slots are dead weight
        masked out by ``valid_len`` until decode appends overwrite them.
        Ragged prefill requires ``C >= S`` (per-sequence eviction of a
        padded prompt would need per-row rolls)."""
        b, s = k_q.shape[:2]
        cs = self.capacity
        if lengths is not None:
            if s > cs:
                raise ValueError(
                    f"ragged prefill needs capacity >= padded prompt length "
                    f"(got S={s} > C={cs}); grow the ring (max_len, or the "
                    f"window for window-capped caches) or drop lengths")
            pos = jnp.asarray(lengths, jnp.int32).reshape(b)
        else:
            pos = jnp.full((b,), s, jnp.int32)
        if s >= cs:
            # keep the tail, rolled so slot (t % C) holds token t
            k_t = jnp.roll(k_q[:, s - cs:], s % cs, axis=1)
            v_t = jnp.roll(v_q[:, s - cs:], s % cs, axis=1)
        else:
            k_t = jax.lax.dynamic_update_slice(self.k, k_q, (0, 0, 0, 0))
            v_t = jax.lax.dynamic_update_slice(self.v, v_q, (0, 0, 0, 0))
        return dataclasses.replace(self, k=k_t, v=v_t, pos=pos)

    def decode_append(self, k_q: jax.Array, v_q: jax.Array,
                      live: jax.Array | None = None) -> "KVCacheState":
        """Append ``s_new`` decode tokens per sequence: row ``b``'s token
        ``pos[b] + i`` goes to slot ``(pos[b] + i) % C``. A batched
        scatter (``.at[batch, slots]``) rather than dynamic_update_slice:
        slots differ per row in a ragged batch, and a blockwise slice
        would *clamp* at the ring boundary instead of wrapping (silently
        overwriting the newest surviving entries). ``s_new`` is 1 in
        steady-state decode, <= 8 for speculative bursts; a burst longer
        than the ring writes only its last ``C`` tokens (the survivors) —
        scattering all of them would hit duplicate slots, whose winner
        JAX leaves unspecified. ``live`` (B,) bool masks dead batch slots
        (continuous batching): their writes are dropped and their ``pos``
        does not advance."""
        b, s_new = k_q.shape[:2]
        cs = self.capacity
        start = max(s_new - cs, 0)
        slots = (self.pos[:, None] + start
                 + jnp.arange(s_new - start, dtype=jnp.int32)[None, :]) % cs
        bidx = jnp.arange(b, dtype=jnp.int32)[:, None]
        if live is None:
            # unique_indices: consecutive slots mod C, count <= C — no
            # collisions, so XLA can emit the cheap unordered scatter
            k_t = self.k.at[bidx, slots].set(k_q[:, start:],
                                             unique_indices=True)
            v_t = self.v.at[bidx, slots].set(v_q[:, start:],
                                             unique_indices=True)
            pos = self.pos + s_new
        else:
            # dead rows: out-of-bounds slot + mode="drop" discards the
            # write without a branch (still unique within live rows)
            slots = jnp.where(live[:, None], slots, cs)
            k_t = self.k.at[bidx, slots].set(k_q[:, start:], mode="drop")
            v_t = self.v.at[bidx, slots].set(v_q[:, start:], mode="drop")
            pos = self.pos + s_new * live.astype(jnp.int32)
        return dataclasses.replace(self, k=k_t, v=v_t, pos=pos)


jax.tree_util.register_dataclass(
    KVCacheState, data_fields=("k", "v", "pos", "k_scale", "v_scale"),
    meta_fields=())


# ---------------------------------------------------------------------------
# Paged KV pool
# ---------------------------------------------------------------------------

PARKING_PAGE = 0        # physical page 0: write sink / unassigned entries


@dataclasses.dataclass(frozen=True)
class PagedKVState:
    """Shared paged int8 KV pool + per-sequence page tables + free stack.

    ``k``/``v``: ``(num_pages, page_size, G, hd)`` arena shared by every
    sequence (and, at the model level, one arena per layer).
    ``page_table``: ``(B, n_pages)`` int32 — logical KV page ``j`` of
    sequence ``b`` lives in physical page ``page_table[b, j]``
    (``PARKING_PAGE`` = unassigned). ``pos``: per-sequence stream length,
    exactly as in ``KVCacheState`` — logical slot ``t % capacity`` with
    ``capacity = n_pages * page_size``, so wrap/window semantics (and the
    kernels' view of the bytes) match the ring bit-for-bit.
    ``free_stack``/``free_top``: LIFO of free physical pages; entries
    ``free_stack[:free_top]`` are free. Allocation happens *inside* jit
    (a masked pop per page) so the fused generation scan never leaves the
    device to grow a sequence.

    ``ref_count``: ``(P,)`` int32, references per physical page — one per
    page-table entry within a row's held prefix, plus one per prefix-index
    pin. Exclusively-held pages sit at 1; prefix sharing raises a page
    above 1, arming copy-on-write in the append paths. The allocator
    invariant (``check_invariants``): every page is on the free stack
    XOR referenced with count >= 1, and the count equals the number of
    page-table references plus pins.
    """

    k: Any                      # (P, page, G, hd)
    v: Any                      # (P, page, G, hd)
    page_table: Any             # (B, n_pages) int32
    pos: Any                    # (B,) int32
    free_stack: Any             # (P,) int32
    free_top: Any               # () int32 — number of free pages
    ref_count: Any = None       # (P,) int32 — references per physical page
    k_scale: Any = None         # (G,) f32 per-head scales, optional
    v_scale: Any = None

    # -- construction -----------------------------------------------------

    @classmethod
    def init(cls, batch: int, capacity: int, n_kv_heads: int, head_dim: int,
             dtype=jnp.int8, per_head_scales: bool = False, *,
             page_size: int = MIN_BLOCK_KV,
             num_pages: int | None = None) -> "PagedKVState":
        """Fresh pool. ``capacity`` (per-sequence logical window) rounds
        up to a ``page_size`` multiple; ``num_pages`` sizes the shared
        arena (default: fully provisioned, ``B * pages_per_seq`` + the
        parking page — pass less to oversubscribe under an admission
        scheduler)."""
        capacity = max(capacity, 1)
        n_pages = _ceil_div(capacity, page_size)
        if num_pages is None:
            num_pages = batch * n_pages + 1
        if num_pages < 2:
            raise ValueError("num_pages must cover the parking page plus "
                             "at least one allocatable page")
        shape = (num_pages, page_size, n_kv_heads, head_dim)
        scales = (jnp.ones((n_kv_heads,), jnp.float32)
                  if per_head_scales else None)
        # free pages are 1..P-1 (0 is parking); stack[:free_top] free,
        # laid out so the first pop hands out page 1
        stack = jnp.concatenate([
            jnp.arange(num_pages - 1, 0, -1, dtype=jnp.int32),
            jnp.zeros((1,), jnp.int32)])
        return cls(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   page_table=jnp.zeros((batch, n_pages), jnp.int32),
                   pos=jnp.zeros((batch,), jnp.int32),
                   free_stack=stack,
                   free_top=jnp.asarray(num_pages - 1, jnp.int32),
                   ref_count=jnp.zeros((num_pages,), jnp.int32),
                   k_scale=scales, v_scale=scales)

    def with_scales(self, k_scale, v_scale) -> "PagedKVState":
        return dataclasses.replace(self, k_scale=k_scale, v_scale=v_scale)

    # -- geometry ---------------------------------------------------------

    @property
    def page_size(self) -> int:
        return self.k.shape[1]

    @property
    def num_pages(self) -> int:
        return self.k.shape[0]

    @property
    def pages_per_seq(self) -> int:
        return self.page_table.shape[1]

    @property
    def capacity(self) -> int:
        return self.pages_per_seq * self.page_size

    @property
    def batch(self) -> int:
        return self.page_table.shape[0]

    def pages_held(self) -> jax.Array:
        """Physical pages currently backing each sequence, (B,) int32."""
        return jnp.minimum(_ceil_div(self.pos, self.page_size),
                           self.pages_per_seq)

    def valid_len(self) -> jax.Array:
        return jnp.minimum(self.pos, self.capacity)

    def q_offset(self, s_new: int = 1) -> jax.Array:
        return jnp.maximum(self.valid_len() - s_new, 0)

    # -- allocation -------------------------------------------------------

    def _alloc(self, need: jax.Array) -> "PagedKVState":
        """Pop ``need[b]`` pages per row off the free stack into each
        row's next unassigned page-table entries (refcount 1 — the row
        is the sole holder). Callers guarantee ``sum(need) <= free_top``
        (the admission scheduler's invariant; ``tests/test_paged.py``
        property-checks it) — an overdrawn pool drives ``free_top``
        negative, which ``oversubscribed`` exposes."""
        b = need.shape[0]
        npps = self.pages_per_seq
        held = self.pages_held()
        offs = jnp.cumsum(need) - need                     # exclusive
        cols = jnp.arange(npps, dtype=jnp.int32)[None, :]
        take = cols < need[:, None]                        # (B, npps)
        sidx = self.free_top - 1 - (offs[:, None] + cols)
        phys = self.free_stack[jnp.clip(sidx, 0, self.num_pages - 1)]
        dest = jnp.where(take, held[:, None] + cols, npps)  # OOB -> drop
        bidx = jnp.arange(b, dtype=jnp.int32)[:, None]
        pt = self.page_table.at[bidx, dest].set(phys, mode="drop")
        ref = self.ref_count.at[jnp.where(take, phys, self.num_pages)] \
            .set(1, mode="drop")
        top = self.free_top - jnp.sum(take.astype(jnp.int32))
        return dataclasses.replace(self, page_table=pt, ref_count=ref,
                                   free_top=top)

    def oversubscribed(self) -> jax.Array:
        """True when an allocation overdrew the pool (scheduler bug)."""
        return self.free_top < 0

    def _decref(self, dec: jax.Array) -> "PagedKVState":
        """Apply per-page refcount decrements ``dec`` (P,) int32, pushing
        pages whose count reaches zero back onto the free stack in
        ascending page-id order (a fixed, deterministic order regardless
        of which rows dropped them). Guarded against stray decrements:
        a page already at count 0 (free) can neither underflow nor be
        pushed a second time, which is what makes ``release`` and
        ``decref_pages`` idempotent at the allocator level."""
        freed = (dec > 0) & (self.ref_count > 0) & (self.ref_count <= dec)
        freed = freed.at[PARKING_PAGE].set(False)
        ref = jnp.maximum(self.ref_count - dec, 0)
        rank = jnp.cumsum(freed.astype(jnp.int32)) - 1
        dest = jnp.where(freed, self.free_top + rank, self.num_pages)
        pages = jnp.arange(self.num_pages, dtype=jnp.int32)
        stack = self.free_stack.at[dest].set(pages, mode="drop")
        top = self.free_top + jnp.sum(freed.astype(jnp.int32))
        return dataclasses.replace(self, ref_count=ref, free_stack=stack,
                                   free_top=top)

    def release(self, finished: jax.Array) -> "PagedKVState":
        """Drop one reference per page held by every row with
        ``finished[b]``, clear those rows' tables and reset their ``pos``
        to 0 — the continuous-batching hand-back. A page returns to the
        free stack only at refcount zero, so shared prefix pages survive
        until their last holder (row or index pin) lets go.

        Idempotent: a released (or never-admitted) row holds nothing —
        ``pos == 0`` and a parked table — so releasing it again, or
        releasing with overlapping masks, moves no pages and cannot
        double-enter the free stack. Two finished rows sharing a page
        decrement it twice through one per-page count, pushing it once.

        Preemption contract: the serve loop releases *victim* rows with
        this same call — a victim's pages that the prefix index pinned
        (``incref_pages``) decref to the pin's count and stay allocated,
        never freed, so the evicted request's re-admission can adopt
        them back while any later ``evict_lru`` unpin still frees them
        exactly once. Release never needs to know which pages are
        pinned; the refcount partition ``check_invariants`` enforces is
        the whole contract."""
        finished = jnp.asarray(finished, jnp.bool_)
        npps = self.pages_per_seq
        held = self.pages_held()
        give = finished[:, None] \
            & (jnp.arange(npps, dtype=jnp.int32)[None, :] < held[:, None]) \
            & (self.page_table != PARKING_PAGE)
        idx = jnp.where(give, self.page_table, self.num_pages)
        dec = jnp.zeros((self.num_pages,), jnp.int32) \
            .at[idx.reshape(-1)].add(1, mode="drop")
        new = self._decref(dec)
        pt = jnp.where(finished[:, None], PARKING_PAGE, new.page_table)
        pos = jnp.where(finished, 0, new.pos)
        return dataclasses.replace(new, page_table=pt, pos=pos)

    # -- prefix sharing ---------------------------------------------------

    def adopt_prefix(self, rows: jax.Array, pages: jax.Array,
                     n_pages: jax.Array, n_tokens: jax.Array
                     ) -> "PagedKVState":
        """Admission-side prefix adoption: point row ``rows[i]``'s first
        ``n_pages[i]`` page-table entries at the *existing* physical
        pages ``pages[i, :n_pages[i]]`` (+1 refcount each) and start the
        row's stream at ``pos = n_tokens[i]`` — the shared-prefix admit,
        where the leading prompt pages are another request's bytes and
        are never re-prefilled. Copy-on-write protects the donors if
        this row ever wraps onto the shared pages.

        ``rows[i] < 0`` marks a dropped dummy entry of a fixed-width
        admission batch. Target rows must be fresh (released: ``pos`` 0,
        table parked). ``n_tokens`` must equal ``n_pages * page_size`` —
        sharing is page-granular (the prefix index hashes page-aligned
        token chunks), so a partial page is never adopted."""
        b = self.batch
        rows = jnp.asarray(rows, jnp.int32).reshape(-1)
        n = rows.shape[0]
        pages = jnp.asarray(pages, jnp.int32).reshape(n, -1)
        n_pages = jnp.asarray(n_pages, jnp.int32).reshape(n)
        n_tokens = jnp.asarray(n_tokens, jnp.int32).reshape(n)
        valid = rows >= 0
        rowsq = jnp.where(valid, rows, b)
        cols = jnp.arange(pages.shape[1], dtype=jnp.int32)[None, :]
        take = valid[:, None] & (cols < n_pages[:, None]) \
            & (pages != PARKING_PAGE)
        dcol = jnp.where(take, cols, self.pages_per_seq)
        pt = self.page_table.at[rowsq[:, None], dcol].set(pages,
                                                          mode="drop")
        ref = self.ref_count.at[jnp.where(take, pages, self.num_pages)] \
            .add(1, mode="drop")
        pos = self.pos.at[rowsq].set(n_tokens * valid.astype(jnp.int32),
                                     mode="drop")
        return dataclasses.replace(self, page_table=pt, ref_count=ref,
                                   pos=pos)

    def incref_pages(self, pages: jax.Array) -> "PagedKVState":
        """+1 refcount per non-negative entry of ``pages`` (flat int32;
        negative = padding, dropped) — the prefix index's *pin*: a
        pinned page survives its original row's release, keeping a
        registered prefix adoptable until the index evicts it."""
        pages = jnp.asarray(pages, jnp.int32).reshape(-1)
        idx = jnp.where((pages > PARKING_PAGE) & (pages < self.num_pages),
                        pages, self.num_pages)
        return dataclasses.replace(
            self, ref_count=self.ref_count.at[idx].add(1, mode="drop"))

    def decref_pages(self, pages: jax.Array) -> "PagedKVState":
        """Drop one reference per non-negative entry of ``pages`` (the
        index unpin / eviction); pages reaching zero return to the free
        stack. Duplicate ids in one call decrement once each."""
        pages = jnp.asarray(pages, jnp.int32).reshape(-1)
        idx = jnp.where(pages >= 0, pages, self.num_pages)
        dec = jnp.zeros((self.num_pages,), jnp.int32) \
            .at[idx].add(1, mode="drop")
        return self._decref(dec)

    def _cow(self, first: jax.Array, n_new: jax.Array,
             max_width: int) -> "PagedKVState":
        """Copy-on-write the pages the rows are about to overwrite: any
        logical page holding write slots ``[first[b], first[b]+n_new[b])``
        (ring coordinates) whose physical page is shared (refcount > 1)
        is copied to a freshly popped page before the append lands — the
        diverging row repoints its table entry and drops its reference;
        the pristine page stays with the remaining holders, or returns to
        the free stack if every holder diverged in this same call.
        ``max_width`` is the static bound on ``n_new`` (the presented
        token-block width). Touched pages that are unassigned (parking)
        or exclusively held are untouched — the unshared path costs one
        refcount gather. Callers guarantee pop headroom the same way they
        do for ``_alloc``: total references (row holds + pins) never
        exceed the allocatable pool, and a COW swap keeps that sum
        constant."""
        ps, cs = self.page_size, self.capacity
        npps = self.pages_per_seq
        b = first.shape[0]
        maxp = min(_ceil_div(max_width + ps - 1, ps), npps)
        first = jnp.asarray(first, jnp.int32)
        n_new = jnp.asarray(n_new, jnp.int32)
        p0 = (first % cs) // ps
        npages = jnp.where(n_new > 0,
                           jnp.minimum(_ceil_div(first % ps + n_new, ps),
                                       npps), 0)
        cols = jnp.arange(maxp, dtype=jnp.int32)[None, :]
        jc = (p0[:, None] + cols) % npps                   # (B, maxp)
        bidx = jnp.arange(b, dtype=jnp.int32)[:, None]
        phys = self.page_table[bidx, jc]
        shared = (cols < npages[:, None]) & (phys != PARKING_PAGE) \
            & (self.ref_count[phys] > 1)
        # pop one fresh page per shared entry (row-major, like _alloc)
        flat = shared.reshape(-1)
        rank = jnp.cumsum(flat.astype(jnp.int32)) - 1
        sidx = self.free_top - 1 - rank
        fresh = self.free_stack[jnp.clip(sidx, 0, self.num_pages - 1)] \
            .reshape(b, maxp)
        src = jnp.where(shared, phys, PARKING_PAGE).reshape(-1)
        dst = jnp.where(shared, fresh, self.num_pages).reshape(-1)
        k = self.k.at[dst].set(self.k[src], mode="drop")
        v = self.v.at[dst].set(self.v[src], mode="drop")
        pt = self.page_table.at[bidx, jnp.where(shared, jc, npps)] \
            .set(fresh, mode="drop")
        ref = self.ref_count.at[dst].set(1, mode="drop")
        dec = jnp.zeros((self.num_pages,), jnp.int32) \
            .at[jnp.where(shared, phys, self.num_pages).reshape(-1)] \
            .add(1, mode="drop")
        top = self.free_top - jnp.sum(flat.astype(jnp.int32))
        cow = dataclasses.replace(self, k=k, v=v, page_table=pt,
                                  ref_count=ref, free_top=top)
        return cow._decref(dec)

    # -- writes -----------------------------------------------------------

    def prefill_write(self, k_q: jax.Array, v_q: jax.Array,
                      lengths: jax.Array | None = None) -> "PagedKVState":
        """Bulk-write right-padded prompts for the whole batch (rows must
        be fresh/released, ``pos == 0``). Same signature and logical
        outcome as the ring's ``prefill_write`` minus wrap-eviction: a
        prompt longer than ``capacity`` is refused (serving sizes the
        window first). Only ``ceil(len/page_size)`` pages are allocated
        per row — right-pad columns are dropped, so a ragged batch holds
        pages for its *tokens*, not its padding."""
        return self.write_prompts(k_q, v_q, lengths=lengths)

    def write_prompts(self, k_q: jax.Array, v_q: jax.Array,
                      lengths: jax.Array | None = None,
                      slots: jax.Array | None = None) -> "PagedKVState":
        """``prefill_write`` generalized to target batch ``slots``: row
        ``i`` of ``k_q``/``v_q`` (n, S, G, hd) lands in batch slot
        ``slots[i]`` (negative = dummy row, dropped entirely) — the
        admission path that prefills newly arrived requests into slots
        another sequence just released, with a fixed-width dispatch shape
        regardless of how many requests actually arrived."""
        n, s = k_q.shape[:2]
        b = self.batch
        ps = self.page_size
        if lengths is None:
            if s > self.capacity:
                raise ValueError(
                    f"paged prefill needs capacity >= prompt length "
                    f"(got S={s} > C={self.capacity}); grow max_len/window")
            new_pos = jnp.full((n,), s, jnp.int32)
        else:
            # Ragged: only the *valid* lengths must fit the window — the
            # source may be wider than the pool's capacity (e.g. a
            # block-aligned admission scratch); every column beyond a
            # row's length scatters into the parking page regardless.
            # Lengths are clamped so a misdeclared over-window row can
            # never push pos past capacity (callers validate upstream).
            new_pos = jnp.minimum(jnp.asarray(lengths, jnp.int32).reshape(n),
                                  self.capacity)
        if slots is None:
            if n != b:
                raise ValueError(f"full-batch prefill expects {b} rows, "
                                 f"got {n} (pass slots= for a partial one)")
            rows = jnp.arange(b, dtype=jnp.int32)
            valid = jnp.ones((n,), jnp.bool_)
        else:
            rows = jnp.asarray(slots, jnp.int32).reshape(n)
            valid = rows >= 0
            rows = jnp.where(valid, rows, b)               # OOB -> drop
        new_pos = new_pos * valid.astype(jnp.int32)

        need_rows = _ceil_div(new_pos, ps)
        need = jnp.zeros((b,), jnp.int32).at[rows].set(need_rows,
                                                       mode="drop")
        new = self._alloc(need)

        t = jnp.arange(s, dtype=jnp.int32)
        # rows == b clamps in the gather; the result is discarded below.
        # Columns past the window (S > capacity sources) clamp to the last
        # logical page — always pad columns, dropped below.
        cols = jnp.minimum(t // ps, self.pages_per_seq - 1)
        phys = new.page_table[jnp.minimum(rows, b - 1)][:, cols]     # (n, s)
        real = valid[:, None] & (t[None, :] < new_pos[:, None])
        # pad columns / dummy rows: OOB page index + mode="drop" discards
        # the write entirely — nothing ever scatters into the parking
        # page (its bytes stay zero), and with the duplicate parking
        # targets gone the scatter is duplicate-free, i.e. deterministic
        # rather than relying on an unspecified duplicate winner
        phys = jnp.where(real, phys, self.num_pages)
        slot = jnp.broadcast_to((t % ps)[None, :], (n, s))
        k_t = new.k.at[phys, slot].set(k_q, mode="drop")
        v_t = new.v.at[phys, slot].set(v_q, mode="drop")
        pos = self.pos.at[rows].set(new_pos, mode="drop")
        return dataclasses.replace(new, k=k_t, v=v_t, pos=pos)

    def decode_append(self, k_q: jax.Array, v_q: jax.Array,
                      live: jax.Array | None = None) -> "PagedKVState":
        """Append ``s_new`` decode tokens per sequence — the jit-safe hot
        path: rows crossing a page boundary pop a fresh page off the free
        stack *on device* (no host round-trip inside the fused scan);
        once a row has wrapped its logical window its existing pages are
        reused in place, exactly like the ring. A wrap onto a *shared*
        page (refcount > 1) copies it first (``_cow``) so the other
        holders keep the pristine bytes. ``live`` masks dead slots
        (writes dropped, ``pos`` frozen). Bursts longer than the window
        write only their surviving tail; the survivor slots are
        consecutive-mod-C and masked writes are dropped outright, so the
        scatter is duplicate-free — two runs produce identical bytes."""
        b, s_new = k_q.shape[:2]
        ps, cs = self.page_size, self.capacity
        if live is None:
            live = jnp.ones((b,), jnp.bool_)
        live_i = live.astype(jnp.int32)
        start = max(s_new - cs, 0)
        n_eff = s_new - start
        state = self._cow(self.pos + start, n_eff * live_i, n_eff)
        held = state.pages_held()
        want = jnp.minimum(_ceil_div(state.pos + s_new, ps),
                           state.pages_per_seq)
        new = state._alloc((want - held) * live_i)

        toks = (state.pos[:, None] + start
                + jnp.arange(n_eff, dtype=jnp.int32)[None, :]) % cs
        bidx = jnp.arange(b, dtype=jnp.int32)[:, None]
        phys = new.page_table[bidx, toks // ps]            # (B, n_eff)
        phys = jnp.where(live[:, None], phys, self.num_pages)  # drop dead
        k_t = new.k.at[phys, toks % ps].set(k_q[:, start:], mode="drop")
        v_t = new.v.at[phys, toks % ps].set(v_q[:, start:], mode="drop")
        return dataclasses.replace(new, k=k_t, v=v_t,
                                   pos=state.pos + s_new * live_i)

    def append_chunk(self, k_q: jax.Array, v_q: jax.Array,
                     n_new: jax.Array) -> "PagedKVState":
        """Append a *per-row ragged* chunk: row ``b`` writes its first
        ``n_new[b]`` of the ``S`` presented tokens at logical slots
        ``pos[b] .. pos[b] + n_new[b] - 1``, scattering across page
        boundaries and popping fresh pages off the free stack *inside
        jit* exactly like ``decode_append``. Columns beyond a row's count
        (decode rows in a mixed chunked-prefill batch present 1 real
        token; dead rows 0) are dropped and that row's ``pos`` advances
        by its own ``n_new`` only — the write primitive of the mixed
        serve step, where one dispatch carries decode rows next to
        prefill chunks with no ring scratch or host bytes-copy. Shared
        pages in the write range are copied first (``_cow``)."""
        b, s = k_q.shape[:2]
        ps, cs = self.page_size, self.capacity
        if s > cs:
            raise ValueError(
                f"append_chunk width {s} exceeds the per-sequence window "
                f"{cs}; split the chunk (serving sizes chunk <= capacity)")
        n_new = jnp.clip(jnp.asarray(n_new, jnp.int32).reshape(b), 0, s)
        state = self._cow(self.pos, n_new, s)
        held = state.pages_held()
        want = jnp.minimum(_ceil_div(state.pos + n_new, ps),
                           state.pages_per_seq)
        new = state._alloc(want - held)

        cols = jnp.arange(s, dtype=jnp.int32)[None, :]
        toks = (state.pos[:, None] + cols) % cs            # (B, S)
        bidx = jnp.arange(b, dtype=jnp.int32)[:, None]
        real = cols < n_new[:, None]
        phys = jnp.where(real, new.page_table[bidx, toks // ps],
                         self.num_pages)                   # pad -> drop
        k_t = new.k.at[phys, toks % ps].set(k_q, mode="drop")
        v_t = new.v.at[phys, toks % ps].set(v_q, mode="drop")
        return dataclasses.replace(new, k=k_t, v=v_t,
                                   pos=state.pos + n_new)

    # -- debug ------------------------------------------------------------

    def check_invariants(self, pins=None) -> None:
        """Host-side allocator invariant check (debug mode / tests — np
        round-trips the whole state, never the hot path):

        * every physical page is on the free stack XOR referenced (held
          by >= 1 page-table prefix entry or pinned) — no double-booking,
          no leaked pages;
        * each page's ``ref_count`` equals its page-table references plus
          its ``pins`` entry (the prefix index's host-side pin ledger:
          a ``(P,)`` array-like or ``{page: count}`` dict);
        * the parking page is never referenced, never free-listed, and
          no row's held prefix points at it after admission;
        * ``free_top`` stays within ``[0, num_pages - 1]`` and the free
          list holds no duplicates.

        Raises ``AssertionError`` naming the violated condition."""
        import numpy as np

        pt = np.asarray(self.page_table)
        ref = np.asarray(self.ref_count)
        held = np.asarray(self.pages_held())
        top = int(self.free_top)
        P = self.num_pages
        assert 0 <= top <= P - 1, f"free_top {top} outside [0, {P - 1}]"
        free = np.asarray(self.free_stack)[:top]
        free_set = set(free.tolist())
        assert len(free_set) == top, "free stack holds duplicate pages"
        assert PARKING_PAGE not in free_set, "parking page on free stack"

        counts = np.zeros(P, np.int64)
        for row in range(self.batch):
            pages = pt[row, :int(held[row])]
            assert PARKING_PAGE not in pages, (
                f"live row {row} points at the parking page: {pages}")
            np.add.at(counts, pages, 1)
        if pins is not None:
            if isinstance(pins, dict):
                for p, c in pins.items():
                    counts[p] += c
            else:
                counts += np.asarray(pins, np.int64)
        assert ref[PARKING_PAGE] == 0 and counts[PARKING_PAGE] == 0, \
            "parking page acquired a refcount"
        for p in range(1, P):
            assert ref[p] == counts[p], (
                f"page {p}: ref_count {ref[p]} != references {counts[p]}")
            assert (p in free_set) ^ (counts[p] >= 1), (
                f"page {p}: free={p in free_set}, references={counts[p]} "
                f"(every page must be free xor referenced)")


jax.tree_util.register_dataclass(
    PagedKVState,
    data_fields=("k", "v", "page_table", "pos", "free_stack", "free_top",
                 "ref_count", "k_scale", "v_scale"),
    meta_fields=())


# ---------------------------------------------------------------------------
# Prefix index (host side)
# ---------------------------------------------------------------------------

class PrefixIndex:
    """Host-side map from prompt prefixes to the physical pages already
    holding their K/V bytes — the lookup structure behind serve-time
    prefix sharing.

    Granularity is exactly one page: entry ``j`` keys on a *chain hash*
    of the prompt's ``j``-th ``page_size``-token chunk and chunk
    ``j-1``'s key, so a hit for page ``j`` implies the entire leading
    ``(j+1) * page_size`` tokens match — a lookup walks the chain and
    returns the longest registered prefix. One page id is valid for
    every layer's pool at once because the per-layer allocators run in
    lockstep (identical op sequence → identical tables and stacks),
    which the serving layer validates at startup.

    The index holds one *pin* (+1 refcount, via
    ``PagedKVState.incref_pages``) per registered page, so registered
    prefixes outlive their original request; ``evict_lru`` hands back
    the oldest unprotected pages for the caller to unpin
    (``decref_pages``) when the pool needs room. Why page bytes are
    reusable at all: a token's K/V depend only on (token id, stream
    position), so a page's bytes are a pure function of the chunk's
    tokens and its page-aligned position — exactly what the chain key
    encodes."""

    def __init__(self, page_size: int):
        self.page_size = int(page_size)
        self._entries: dict = {}        # chain key -> physical page id
        self._page_key: dict = {}       # physical page id -> chain key

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def pinned_pages(self):
        """Snapshot of every registered (pinned) physical page id."""
        return list(self._page_key)

    def _chain_keys(self, tokens, n_chunks: int):
        # blake2b, not Python hash(): hash() is salted per process, and
        # the index must survive a server restart (snapshot/restore) —
        # the same prompt must map to the same chain keys in the new
        # process or every restored entry would be unreachable
        import hashlib

        import numpy as np
        toks = np.ascontiguousarray(np.asarray(tokens, np.int64).reshape(-1))
        prev = b"prefix-chain-v1:%d" % self.page_size   # chain seed
        keys = []
        for j in range(n_chunks):
            chunk = toks[j * self.page_size:(j + 1) * self.page_size]
            prev = hashlib.blake2b(prev + chunk.tobytes(),
                                   digest_size=16).digest()
            keys.append(prev.hex())
        return keys

    def lookup(self, tokens, max_tokens: int | None = None):
        """Longest registered page-aligned prefix of ``tokens`` covering
        at most ``max_tokens`` tokens. Returns the physical page ids (a
        possibly empty list); a lookup refreshes the hit entries' LRU
        position."""
        import numpy as np
        n_tok = int(np.asarray(tokens).size)
        if max_tokens is not None:
            n_tok = min(n_tok, int(max_tokens))
        pages = []
        for key in self._chain_keys(tokens, n_tok // self.page_size):
            page = self._entries.get(key)
            if page is None:
                break
            del self._entries[key]                # LRU touch: re-insert
            self._entries[key] = page
            pages.append(page)
        return pages

    def register(self, tokens, page_ids):
        """Register the pages backing ``tokens``' leading full chunks:
        ``page_ids[j]`` holds chunk ``j``'s bytes. Chunks already
        registered (by any request) are skipped; registration stops at
        the first conflict so the chain stays walkable. Returns the
        newly indexed page ids — the caller must pin exactly those
        (``incref_pages``) before the donor row can release them."""
        import numpy as np
        page_ids = [int(p) for p in np.asarray(page_ids).reshape(-1)]
        new = []
        for key, page in zip(self._chain_keys(tokens, len(page_ids)),
                             page_ids, strict=True):
            if page == PARKING_PAGE:
                break
            have = self._entries.get(key)
            if have is not None:
                continue                          # chunk already indexed
            if page in self._page_key:
                break                             # page serves another key
            self._entries[key] = page
            self._page_key[page] = key
            new.append(page)
        return new

    # -- persistence ------------------------------------------------------

    def state_dict(self) -> dict:
        """JSON-serializable snapshot. Entries are listed oldest-first
        (dict insertion order *is* the LRU order), so a round trip
        preserves eviction behaviour exactly."""
        return {
            "page_size": self.page_size,
            "entries": [[key, int(page)]
                        for key, page in self._entries.items()],
        }

    def load_state_dict(self, state: dict) -> None:
        """Rebuild the index from ``state_dict()`` output. The chain keys
        are deterministic blake2b digests, so entries written by a dead
        process resolve the same prompts here. Raises ``ValueError`` on a
        page-size mismatch (the chain seed, and therefore every key,
        depends on it)."""
        if int(state["page_size"]) != self.page_size:
            raise ValueError(
                f"prefix index snapshot has page_size "
                f"{state['page_size']}, pool uses {self.page_size}")
        self._entries = {}
        self._page_key = {}
        for key, page in state["entries"]:
            self._entries[str(key)] = int(page)
            self._page_key[int(page)] = str(key)

    def evict_lru(self, n: int, protected=frozenset()):
        """Drop up to ``n`` least-recently-used entries whose page is not
        ``protected`` (pages currently adopted by an active request must
        keep their pin — the serving layer's budget accounting depends
        on it). Returns the evicted page ids for the caller to unpin.
        Evicting a chain's head orphans its tail entries (unreachable by
        lookup); they stay evictable and age out under the same LRU
        pressure, so their pins are reclaimed, just not instantly."""
        evicted = []
        for key in list(self._entries):
            if len(evicted) >= n:
                break
            page = self._entries[key]
            if page in protected:
                continue
            del self._entries[key]
            del self._page_key[page]
            evicted.append(page)
        return evicted
