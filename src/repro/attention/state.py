"""Typed int8 KV-cache ring-buffer state.

``KVCacheState`` replaces the plain ``{"k", "v", "pos", ...}`` dicts the
serving stack used to pass around: same leaves, same scan/shard/donate
behaviour (it is a registered dataclass pytree), but the ring-buffer
invariants live on the type instead of in every caller's head.

Layout: ``k``/``v`` are ``(B, C, G, hd)`` with capacity ``C`` a ring —
token ``t`` lives in slot ``t % C``. ``pos`` tracks the *logical* stream
length, from which the valid prefix (``valid_len``) and the logical
position of new queries (``q_offset``) derive. ``k_scale``/``v_scale``
are optional per-(kv-)head quantization scales ``(G,)`` (the decode
engine's finer-than-QAT grid); ``None`` when the cache rides the model's
per-tensor QAT scales.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class KVCacheState:
    k: Any                      # (B, C, G, hd) int8 (or compute dtype)
    v: Any                      # (B, C, G, hd)
    pos: Any                    # () int32 — tokens ever written
    k_scale: Any = None         # (G,) f32 per-head scales, optional
    v_scale: Any = None         # (G,) f32

    # -- construction -----------------------------------------------------

    @classmethod
    def init(cls, batch: int, capacity: int, n_kv_heads: int, head_dim: int,
             dtype=jnp.int8, per_head_scales: bool = False) -> "KVCacheState":
        """Fresh (zeroed) ring-buffer cache."""
        capacity = max(capacity, 1)
        shape = (batch, capacity, n_kv_heads, head_dim)
        scales = (jnp.ones((n_kv_heads,), jnp.float32)
                  if per_head_scales else None)
        return cls(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   pos=jnp.zeros((), jnp.int32), k_scale=scales,
                   v_scale=scales)

    def with_scales(self, k_scale, v_scale) -> "KVCacheState":
        return dataclasses.replace(self, k_scale=k_scale, v_scale=v_scale)

    # -- ring geometry ----------------------------------------------------

    @property
    def capacity(self) -> int:
        return self.k.shape[1]

    def valid_len(self) -> jax.Array:
        """Number of valid (non-evicted) entries in the ring."""
        return jnp.minimum(self.pos, self.capacity)

    def q_offset(self, s_new: int = 1) -> jax.Array:
        """Logical position of the first of the ``s_new`` query tokens
        *just appended*, in ring coordinates: ``valid_len - s_new``.
        While the ring has not wrapped this is the token's stream
        position; after wrap the oldest surviving token is redefined as
        position 0, so the newest query sits at ``C - s_new`` and the
        sliding-window mask ``(qi - kj) < window`` keeps exactly the last
        ``window`` slots visible."""
        return jnp.maximum(self.valid_len() - s_new, 0)

    # -- writes -----------------------------------------------------------

    def prefill_write(self, k_q: jax.Array, v_q: jax.Array) -> "KVCacheState":
        """Bulk-write ``S`` prefill tokens, evicting beyond capacity.

        ``k_q``/``v_q`` (B, S, G, hd), already quantized. Token ``t``
        lands in slot ``t % C`` (so a later ``decode_append`` continues
        the same ring); when ``S >= C`` only the last ``C`` tokens
        survive."""
        s = k_q.shape[1]
        cs = self.capacity
        if s >= cs:
            # keep the tail, rolled so slot (t % C) holds token t
            k_t = jnp.roll(k_q[:, s - cs:], s % cs, axis=1)
            v_t = jnp.roll(v_q[:, s - cs:], s % cs, axis=1)
        else:
            k_t = jax.lax.dynamic_update_slice(self.k, k_q, (0, 0, 0, 0))
            v_t = jax.lax.dynamic_update_slice(self.v, v_q, (0, 0, 0, 0))
        return dataclasses.replace(self, k=k_t, v=v_t,
                                   pos=jnp.asarray(s, jnp.int32))

    def decode_append(self, k_q: jax.Array, v_q: jax.Array) -> "KVCacheState":
        """Append ``s_new`` decode tokens, token ``pos + i`` to slot
        ``(pos + i) % C``. Written per token because a blockwise
        ``dynamic_update_slice`` would *clamp* at the ring boundary
        instead of wrapping (silently overwriting the newest surviving
        entries); ``s_new`` is 1 in steady-state decode, <= 8 for
        speculative bursts."""
        cs = self.capacity
        k_t, v_t = self.k, self.v
        for i in range(k_q.shape[1]):
            slot = (self.pos + i) % cs
            k_t = jax.lax.dynamic_update_slice(k_t, k_q[:, i:i + 1],
                                               (0, slot, 0, 0))
            v_t = jax.lax.dynamic_update_slice(v_t, v_q[:, i:i + 1],
                                               (0, slot, 0, 0))
        return dataclasses.replace(self, k=k_t, v=v_t,
                                   pos=self.pos + k_q.shape[1])


jax.tree_util.register_dataclass(
    KVCacheState, data_fields=("k", "v", "pos", "k_scale", "v_scale"),
    meta_fields=())
