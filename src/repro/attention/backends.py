"""The registered attention backends.

Seven implementations of the same pipeline (Q·Kᵀ → softmax → A·V), each
declaring what it can serve via a ``supports(spec)`` capability predicate
(see ``DESIGN.md`` for the full capability matrix):

- ``ita_decode_pallas``  — fused decode-shaped Pallas kernel (single query
  tile over an int8 KV ring buffer; skips invalid KV tiles).
- ``ita_chunked_xla``    — streaming DA/DI/EN at the XLA level (train QAT
  STE forward + integer prefill; the S×S matrix never materializes).
- ``ita_onepass_pallas`` — fused flash-style Pallas kernel (bit-identical
  to ``ita_decode_pallas`` row-for-row at equal block_kv).
- ``ita_twopass_pallas`` — paper-faithful dataflow (A matrix written to
  HBM; the §III analysis path).
- ``ita_direct_xla``     — one-shot integer XLA path; the decode fallback
  for specs the fused kernels decline (softcap, custom query scale, long
  bursts).
- ``ibert_xla``          — I-BERT 32-bit polynomial softmax (the paper's
  accuracy baseline) on the integer pipeline.
- ``float_xla``          — float softmax baseline (and the ibert QAT
  train forward).

Backends in the same ``family`` are bit-identical on the int8 output
grid; ``tests/test_attention_api.py`` sweeps ``list_backends(spec)`` and
enforces it.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.attention import xla as X
from repro.attention.chunked import streaming_attention
from repro.attention.registry import Backend, register_backend
from repro.attention.spec import AttentionSpec, QuantScales
from repro.core.quant import fake_quant
from repro.kernels.common import default_blocks
from repro.kernels.ita_attention.ops import fused_attention

_DEF_Q_CHUNK = 512
_DEF_KV_CHUNK = 512


def _qscale(spec: AttentionSpec, q):
    return spec.query_scale or q.shape[-1] ** -0.5


def _head_shape(ndim, head_axis):
    sh = [1] * ndim
    sh[head_axis] = -1
    return sh


def _quantize(x, scale, head_axis):
    """int8 passes through; float is quantized onto ``scale`` (scalar or
    per-head vector broadcast on ``head_axis``)."""
    if x.dtype == jnp.int8:
        return x
    s = jnp.asarray(scale, jnp.float32)
    if s.ndim:
        s = s.reshape(_head_shape(x.ndim, head_axis))
    return X.quantize_to_int8(x, s)


def _dequantize(x_i8, scale, head_axis):
    s = jnp.asarray(scale, jnp.float32)
    if s.ndim:
        s = s.reshape(_head_shape(x_i8.ndim, head_axis))
    return x_i8.astype(jnp.float32) * s


def _requant_out(out_f, spec: AttentionSpec, scales: QuantScales,
                 head_axis):
    """Float backend output -> the spec's out_dtype (int8 rides s_out)."""
    if spec.out_dtype != "int8":
        return out_f
    s = jnp.asarray(scales.require("s_out").s_out, jnp.float32)
    if s.ndim:
        s = s.reshape(_head_shape(out_f.ndim, head_axis))
    return X.quantize_to_int8(out_f, s)


# ---------------------------------------------------------------------------
# XLA backends
# ---------------------------------------------------------------------------

def _float_supports(spec: AttentionSpec):
    if not (spec.impl == "float"
            or (spec.impl == "ibert" and spec.mode == "train")):
        return ("float softmax serves impl='float' (plus the ibert QAT "
                "train forward, which the paper trains against)")
    if spec.ragged_q:
        return "ragged q_len rides the fused one-pass kernels"

    if spec.layout != "bshd":
        return "model layout (B,S,H,hd) only"
    if spec.out_dtype != "float":
        return "no s_out requant grid in the float path"
    return True


def _require_zero_q_offset(q_offset, name):
    """The streaming q-chunk loop derives its (static) chunk ranges from
    query position 0 — a nonzero q_offset must not be silently ignored.
    Dynamic (traced) offsets only arise on decode paths, which the
    streaming backends already decline via supports()."""
    if isinstance(q_offset, int) and q_offset == 0:
        return
    raise ValueError(
        f"{name} streams from query position 0; got q_offset={q_offset!r} "
        "(decode-style offsets ride the fused/direct backends)")


def _float_run(q, k, v, spec, scales, *, q_offset=0, kv_len=None, **opts):
    scale = _qscale(spec, q)
    if spec.mode != "decode" and q.shape[1] > 1:
        _require_zero_q_offset(q_offset, "float_xla")
        return streaming_attention(
            q, k, v, impl="float", scale=scale, causal=spec.causal,
            window=spec.window, kv_len=kv_len, softcap=spec.softcap,
            q_chunk=opts.get("q_chunk", _DEF_Q_CHUNK),
            kv_chunk=opts.get("kv_chunk", _DEF_KV_CHUNK),
            scan_unroll=opts.get("scan_unroll", False))
    return X.direct_float(q, k, v, scale=scale, cap=spec.softcap,
                          causal=spec.causal, window=spec.window,
                          q_offset=q_offset, kv_len=kv_len)


def _chunked_supports(spec: AttentionSpec):
    if spec.impl != "ita":
        return "streams the ITA integer/STE arithmetic only"
    if spec.ragged_q:
        return "ragged q_len rides the fused one-pass kernels"
    if spec.mode == "decode":
        return ("decode rides the fused/direct paths (the streaming "
                "q-chunk loop assumes q_offset=0)")
    if spec.layout != "bshd":
        return "model layout (B,S,H,hd) only"
    if spec.scale_kind != "per_tensor":
        return "per-head scales are not plumbed through the XLA streaming path"
    if spec.mode == "train" and spec.out_dtype == "int8":
        return ("the QAT forward is differentiable float (s_out fake-quant), "
                "not int8 on the s_out grid")
    return True


def _chunked_run(q, k, v, spec, scales, *, q_offset=0, kv_len=None, **opts):
    _require_zero_q_offset(q_offset, "ita_chunked_xla")
    scales.require("s_q", "s_k", "s_v")
    common = dict(scale=_qscale(spec, q), causal=spec.causal,
                  window=spec.window, kv_len=kv_len, softcap=spec.softcap,
                  s_q=scales.s_q, s_k=scales.s_k, s_v=scales.s_v,
                  q_chunk=opts.get("q_chunk", _DEF_Q_CHUNK),
                  kv_chunk=opts.get("kv_chunk", _DEF_KV_CHUNK),
                  scan_unroll=opts.get("scan_unroll", False))
    if spec.mode == "train":
        # QAT forward: STE round/floor through the deployed shift-only
        # semantics; the serve-time inter-block output requant (s_out)
        # is trained via fake-quant so decode deploys on a seen grid.
        out = streaming_attention(q, k, fake_quant(v, scales.s_v),
                                  impl="ita_ste", **common)
        if scales.s_out is not None:
            out = fake_quant(out, scales.s_out)
        return out
    q8 = _quantize(q, scales.s_q, 2)
    k8 = _quantize(k, scales.s_k, 2)
    v8 = _quantize(v, scales.s_v, 2)
    out = streaming_attention(q8, k8, v8, impl="ita_int",
                              adaptive=spec.softmax == "adaptive", **common)
    return _requant_out(out, spec, scales, 2)


def _direct_supports(spec: AttentionSpec):
    if spec.impl != "ita":
        return "one-shot ITA integer arithmetic only"
    if spec.ragged_q:
        return "ragged q_len rides the fused one-pass kernels"
    if spec.mode != "decode":
        return ("serve-side decode fallback only (train/prefill stream "
                "through ita_chunked_xla)")
    if spec.layout != "bshd":
        return "model layout (B,S,H,hd) only"
    if spec.scale_kind != "per_tensor":
        return "per-head scales are not plumbed through the direct XLA path"
    return True


def _direct_run(q, k, v, spec, scales, *, q_offset=0, kv_len=None, **opts):
    scales.require("s_q", "s_k", "s_v")
    q8 = _quantize(q, scales.s_q, 2)
    k8 = _quantize(k, scales.s_k, 2)
    v8 = _quantize(v, scales.s_v, 2)
    out = X.direct_int(q8, k8, v8, s_q=scales.s_q, s_k=scales.s_k,
                       s_v=scales.s_v, scale=_qscale(spec, q), impl="ita",
                       softmax=spec.softmax, cap=spec.softcap,
                       causal=spec.causal, window=spec.window,
                       q_offset=q_offset, kv_len=kv_len)
    return _requant_out(out, spec, scales, 2)


def _ibert_supports(spec: AttentionSpec):
    if spec.impl != "ibert":
        return "serves the I-BERT polynomial softmax pipeline only"
    if spec.ragged_q:
        return "ragged q_len rides the fused one-pass kernels"
    if spec.mode == "train":
        return ("the ibert QAT train forward uses the float softmax "
                "baseline (float_xla)")
    if spec.layout != "bshd":
        return "model layout (B,S,H,hd) only"
    if spec.scale_kind != "per_tensor":
        return "per-head scales are not plumbed through the I-BERT path"
    return True


def _ibert_run(q, k, v, spec, scales, *, q_offset=0, kv_len=None, **opts):
    scales.require("s_q", "s_k", "s_v")
    q8 = _quantize(q, scales.s_q, 2)
    k8 = _quantize(k, scales.s_k, 2)
    v8 = _quantize(v, scales.s_v, 2)
    out = X.direct_int(q8, k8, v8, s_q=scales.s_q, s_k=scales.s_k,
                       s_v=scales.s_v, scale=_qscale(spec, q), impl="ibert",
                       cap=spec.softcap, causal=spec.causal,
                       window=spec.window, q_offset=q_offset, kv_len=kv_len)
    return _requant_out(out, spec, scales, 2)


# ---------------------------------------------------------------------------
# Fused Pallas backends
# ---------------------------------------------------------------------------

def _fused_common_supports(spec: AttentionSpec):
    if spec.impl != "ita":
        return "fuses the ITA shift-only softmax only"
    if spec.softcap:
        return "logit softcap is not fused into the Pallas kernels"
    if spec.query_scale:
        return "the kernels hard-wire the 1/sqrt(d) query scale in logit_mult"
    if not spec.has_s_out:
        return ("the kernels requantize output through s_out (out_mult = "
                "s_v/s_out); legacy param sets without it ride the XLA "
                "paths")
    return True


def _onepass_supports(spec: AttentionSpec):
    ok = _fused_common_supports(spec)
    if ok is not True:
        return ok
    if spec.mode == "train":
        return "serve-path kernel (QAT train needs the differentiable STE "\
               "forward in ita_chunked_xla)"
    return True


def _twopass_supports(spec: AttentionSpec):
    ok = _fused_common_supports(spec)
    if ok is not True:
        return ok
    if spec.ragged_q:
        return ("the materialized A matrix assumes uniform query rows; "
                "ragged q_len rides the onepass kernels")
    if spec.layout == "bhsd_paged":
        return ("materializes/re-streams a contiguous A matrix; the paged "
                "KV pool serves the onepass/decode kernels")
    if spec.mode != "prefill":
        return ("paper-faithful analysis path — materializes the A matrix "
                "in HBM; decode rides the fused decode/onepass kernels")
    return True


def _decode_supports(spec: AttentionSpec):
    ok = _fused_common_supports(spec)
    if ok is not True:
        return ok
    if spec.mode != "decode":
        return "decode-shaped kernel (no q tiling; single query tile)"
    if spec.ragged_q:
        return ("mixed chunk-width rows need the q-tiled onepass kernel "
                "(the single decode tile caps at 8 queries)")
    if spec.q_len is None or spec.q_len > 8:
        return ("single query tile of at most 8 tokens (declare q_len in "
                "the spec); longer bursts ride onepass/direct")
    return True


def _fused_run(kind, q, k, v, spec, scales, q_offset, kv_len, opts):
    scales.require("s_q", "s_k", "s_v", "s_out")
    page_table = opts.get("page_table")
    q_lens = opts.get("q_lens")
    if spec.layout == "bshd":
        q8 = jnp.swapaxes(_quantize(q, scales.s_q, 2), 1, 2)
        k8 = _quantize(k, scales.s_k, 2)
        v8 = _quantize(v, scales.s_v, 2)
        kv_native = True
    else:             # bhsd / bhsd_bsgd / bhsd_paged: q already (B,H,S,D)
        q8 = _quantize(q, scales.s_q, 1)
        kv_native = spec.layout == "bhsd_bsgd"
        kv_axis = 1 if spec.layout == "bhsd" else 2
        k8 = _quantize(k, scales.s_k, kv_axis)
        v8 = _quantize(v, scales.s_v, kv_axis)
    if kv_native and kind == "twopass":
        # twopass consumes kernel-layout KV; one transpose (decode and
        # onepass read the (B,S,G,hd) buffers via cache-native index maps)
        k8 = k8.transpose(0, 2, 1, 3)
        v8 = v8.transpose(0, 2, 1, 3)
        kv_native = False
    dbq, dbkv = default_blocks(f"ita_{kind}_pallas")
    out = fused_attention(
        q8, k8, v8, scales.s_q, scales.s_k, scales.s_v, scales.s_out,
        q_offset=q_offset, kv_len=kv_len, q_lens=q_lens, causal=spec.causal,
        window=spec.window, kind=kind, adaptive=spec.softmax == "adaptive",
        block_q=opts.get("block_q", dbq or 128),
        block_kv=opts.get("block_kv", dbkv),
        kv_native=kv_native, page_table=page_table,
        interpret=opts.get("interpret"))
    if spec.layout == "bshd":
        out = jnp.swapaxes(out, 1, 2)                    # back to (B,S,H,D)
    if spec.out_dtype == "int8":
        return out
    return _dequantize(out, scales.s_out, 2 if spec.layout == "bshd" else 1)


def _onepass_run(q, k, v, spec, scales, *, q_offset=0, kv_len=None, **opts):
    return _fused_run("onepass", q, k, v, spec, scales, q_offset, kv_len,
                      opts)


def _twopass_run(q, k, v, spec, scales, *, q_offset=0, kv_len=None, **opts):
    return _fused_run("twopass", q, k, v, spec, scales, q_offset, kv_len,
                      opts)


def _decode_run(q, k, v, spec, scales, *, q_offset=0, kv_len=None, **opts):
    return _fused_run("decode", q, k, v, spec, scales, q_offset, kv_len,
                      opts)


# ---------------------------------------------------------------------------
# Registration — order is dispatch priority
# ---------------------------------------------------------------------------

register_backend(Backend(
    name="ita_decode_pallas", family="ita_fused",
    supports=_decode_supports, run=_decode_run,
    description="fused decode kernel over int8 KV ring buffers "
                "(cache-native index maps, skips invalid KV tiles)"))
register_backend(Backend(
    name="ita_chunked_xla", family="ita_stream_xla",
    supports=_chunked_supports, run=_chunked_run,
    description="streaming DA/DI/EN at XLA level; QAT STE train forward "
                "+ integer prefill (S×S never materializes)"))
register_backend(Backend(
    name="ita_onepass_pallas", family="ita_fused",
    supports=_onepass_supports, run=_onepass_run,
    description="fused flash-style kernel; bit-identical to "
                "ita_decode_pallas at equal block_kv"))
register_backend(Backend(
    name="ita_twopass_pallas", family="ita_twopass",
    supports=_twopass_supports, run=_twopass_run,
    description="paper-faithful two-pass dataflow (A matrix in HBM)"))
register_backend(Backend(
    name="ita_direct_xla", family="ita_direct",
    supports=_direct_supports, run=_direct_run,
    description="one-shot integer XLA decode fallback (softcap, custom "
                "query scale, long bursts)"))
register_backend(Backend(
    name="ibert_xla", family="ibert",
    supports=_ibert_supports, run=_ibert_run,
    description="I-BERT 32-bit polynomial softmax on the integer "
                "pipeline (accuracy baseline)"))
register_backend(Backend(
    name="float_xla", family="float",
    supports=_float_supports, run=_float_run,
    description="float softmax baseline (streaming for train/prefill, "
                "direct for decode)"))
