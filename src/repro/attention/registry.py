"""Capability-based backend registry + the single ``dispatch`` entry point.

Every attention implementation registers a ``Backend`` carrying a
``supports(spec) -> True | reason`` predicate. ``dispatch`` walks the
priority-ordered registry and runs the first eligible backend — replacing
the if-ladders that used to live in ``models/attention.py``,
``runtime/kv_cache.py`` and every test/benchmark. ``backend=`` overrides
the choice explicitly (still capability-checked); ``list_backends(spec)``
and ``backend_reasons(spec)`` expose the verdicts for tests, benchmarks
and serving introspection.

Backends also declare an exactness ``family``: two eligible backends with
the same family are **bit-identical** on the int8 output grid (the parity
sweep in ``tests/test_attention_api.py`` enforces it); different families
share the algorithm but not the rounding schedule.
"""

from __future__ import annotations

import dataclasses
from collections.abc import Callable
from typing import Any

from repro.attention.spec import AttentionSpec

SupportsFn = Callable[[AttentionSpec], bool | str]


class BackendUnsupported(ValueError):
    """Raised when a spec reaches a backend that declared it unsupported,
    or when no registered backend supports the spec."""


@dataclasses.dataclass(frozen=True)
class Backend:
    name: str
    family: str                 # exactness family (bit-identical within)
    supports: SupportsFn        # spec -> True | human-readable reason
    run: Callable[..., Any]     # (q, k, v, spec, scales, **opts) -> out
    description: str = ""


_REGISTRY: dict[str, Backend] = {}


def register_backend(backend: Backend) -> Backend:
    """Register (or replace) a backend. Registration order is priority
    order for automatic dispatch."""
    _REGISTRY[backend.name] = backend
    return backend


def get_backend(name: str) -> Backend:
    if name not in _REGISTRY:
        raise KeyError(f"unknown attention backend {name!r}; "
                       f"registered: {list(_REGISTRY)}")
    return _REGISTRY[name]


def all_backends() -> tuple[Backend, ...]:
    return tuple(_REGISTRY.values())


def backend_reasons(spec: AttentionSpec) -> dict[str, bool | str]:
    """Every backend's verdict for ``spec``: ``True`` or the reason why
    not — the introspection surface behind ``list_backends``."""
    return {b.name: b.supports(spec) for b in _REGISTRY.values()}


def list_backends(spec: AttentionSpec | None = None) -> list[str]:
    """Names of backends eligible for ``spec`` in priority order (all
    registered backends when ``spec`` is None). ``dispatch`` with no
    override runs the first entry."""
    if spec is None:
        return list(_REGISTRY)
    return [name for name, ok in backend_reasons(spec).items() if ok is True]


def _shapes(q, k, spec: AttentionSpec):
    """(sq, hq, skv, hkv, d) under the spec's layout."""
    if spec.layout == "bshd":
        sq, hq = q.shape[1], q.shape[2]
        skv, hkv = k.shape[1], k.shape[2]
    elif spec.layout == "bhsd":
        hq, sq = q.shape[1], q.shape[2]
        hkv, skv = k.shape[1], k.shape[2]
    elif spec.layout == "bhsd_paged":           # kv = (P, page, G, hd) pool
        hq, sq = q.shape[1], q.shape[2]
        skv, hkv = k.shape[1], k.shape[2]       # skv = one page here
    else:                                       # bhsd_bsgd: q bhsd, kv bsgd
        hq, sq = q.shape[1], q.shape[2]
        skv, hkv = k.shape[1], k.shape[2]
    return sq, hq, skv, hkv, q.shape[-1]


def _validate(q, k, v, spec: AttentionSpec, scales):
    if q.ndim != 4 or k.ndim != 4 or v.ndim != 4:
        raise ValueError(f"q/k/v must be rank-4, got "
                         f"{q.ndim}/{k.ndim}/{v.ndim}")
    sq, hq, skv, hkv, d = _shapes(q, k, spec)
    if hq % hkv != 0:
        raise ValueError(f"GQA requires kv heads | q heads under layout "
                         f"{spec.layout!r}, got hq={hq}, hkv={hkv} "
                         "(wrong layout declared?)")
    if spec.n_heads is not None and spec.n_heads != hq:
        raise ValueError(f"spec.n_heads={spec.n_heads} but q has {hq} "
                         f"heads under layout {spec.layout!r}")
    if spec.n_kv_heads is not None and spec.n_kv_heads != hkv:
        raise ValueError(f"spec.n_kv_heads={spec.n_kv_heads} but kv has "
                         f"{hkv} heads under layout {spec.layout!r}")
    if spec.q_len is not None and spec.q_len != sq:
        raise ValueError(f"spec.q_len={spec.q_len} but q length is {sq} "
                         f"under layout {spec.layout!r}")
    if spec.quantized and scales is None:
        raise ValueError(f"impl={spec.impl!r} needs QuantScales")


def dispatch(q, k, v, *, spec: AttentionSpec, scales=None,
             q_offset: Any = 0, kv_len: Any = None,
             page_table: Any = None, q_lens: Any = None,
             backend: str | None = None, **opts):
    """Run one attention computation through the registry.

    ``q``/``k``/``v``: rank-4 arrays in ``spec.layout``. Integer impls
    accept float tensors (quantized internally onto the matching scale)
    or pre-quantized int8 tensors (consumed as-is, e.g. int8 KV caches).
    ``q_offset``/``kv_len``: dynamic decode plumbing (logical position of
    query 0; valid KV prefix). ``page_table`` (B, n_pages) int32 —
    required by (and only by) the ``bhsd_paged`` layout, where ``k``/``v``
    are a shared paged pool. ``q_lens`` (B,) int32 — required by (and
    only by) ``spec.ragged_q``: each row's count of valid query rows in
    the mixed chunked-prefill/decode call. ``backend``: explicit override
    by name — still capability-checked, so an ineligible (spec, backend)
    pair raises ``BackendUnsupported`` with the backend's stated reason.
    ``opts``: tuning knobs forwarded to the backend (``block_q``,
    ``block_kv``, ``q_chunk``, ``kv_chunk``, ``interpret``,
    ``scan_unroll``); unknown knobs are ignored by backends that don't
    tune them.

    Returns the attention output in ``spec.layout``: float32 (to be cast
    by the caller) or int8 on the ``s_out`` grid per ``spec.out_dtype``.
    """
    # Capability check first (pure spec-level), shape validation second —
    # an ineligible (spec, backend) pair is the more fundamental error.
    if backend is not None:
        b = get_backend(backend)
        ok = b.supports(spec)
        if ok is not True:
            raise BackendUnsupported(
                f"backend {b.name!r} does not support this spec: {ok}")
    else:
        reasons = backend_reasons(spec)
        b = next((_REGISTRY[n] for n, ok in reasons.items() if ok is True),
                 None)
        if b is None:
            detail = "; ".join(f"{n}: {r}" for n, r in reasons.items())
            raise BackendUnsupported(
                f"no registered backend supports {spec}; "
                f"verdicts — {detail}")
    if (spec.layout == "bhsd_paged") != (page_table is not None):
        raise ValueError(
            "page_table= is required by exactly the 'bhsd_paged' layout "
            f"(layout={spec.layout!r}, page_table "
            f"{'missing' if page_table is None else 'given'})")
    if spec.ragged_q != (q_lens is not None):
        raise ValueError(
            "q_lens= is required by exactly ragged_q specs "
            f"(ragged_q={spec.ragged_q}, q_lens "
            f"{'missing' if q_lens is None else 'given'})")
    _validate(q, k, v, spec, scales)
    if page_table is not None:
        opts["page_table"] = page_table
    if q_lens is not None:
        opts["q_lens"] = q_lens
    return b.run(q, k, v, spec, scales, q_offset=q_offset, kv_len=kv_len,
                 **opts)
