"""Typed front door of the attention engine: ``AttentionSpec`` (what the
caller needs computed) and ``QuantScales`` (the quantization grid it lives
on).

``AttentionSpec`` is a frozen — therefore hashable — dataclass: it can be
a jit static argument, a dict key for compilation caches, and the sole
input of every backend's ``supports()`` capability predicate.
``QuantScales`` is a registered pytree: scale arrays flow through jit /
grad / scan like any other leaves, replacing the loose ``params["s_q"]``
dict keys and positional scale arguments of the pre-registry API.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax

MODES = ("train", "prefill", "decode")
IMPLS = ("float", "ita", "ibert")
SOFTMAXES = ("adaptive", "paper")
# q-layout[_kv-layout]: "bshd" (model: batch, seq, heads, dim), "bhsd"
# (kernel: batch, heads, seq, dim), "bhsd_bsgd" (decode engine: q in
# kernel layout, K/V consumed cache-natively as (B, C, G, hd) ring
# buffers via kernel index maps — no per-step transpose copies),
# "bhsd_paged" (continuous batching: q in kernel layout, K/V a shared
# (num_pages, page_size, G, hd) pool consumed through per-sequence page
# tables — dispatch requires the ``page_table=`` operand).
LAYOUTS = ("bshd", "bhsd", "bhsd_bsgd", "bhsd_paged")
SCALE_KINDS = ("per_tensor", "per_head")
OUT_DTYPES = ("float", "int8")


@dataclasses.dataclass(frozen=True)
class AttentionSpec:
    """Static description of one attention computation.

    Everything a backend's ``supports()`` predicate may gate on lives
    here; dynamic data (tensors, scale values, q_offset/kv_len) does not.

    ``query_scale``: 0.0 means the default ``head_dim ** -0.5``.
    ``q_len``: static query length when known (decode bursts gate the
    fused decode kernel on it); ``None`` = unspecified.
    ``has_s_out``: whether the caller's scales carry the inter-block
    output requant grid — the fused kernels require it (their out_mult is
    ``s_v / s_out``); legacy param sets without ``s_out`` stay eligible
    for the XLA paths only.
    ``n_heads`` / ``n_kv_heads``: optional GQA declaration — when set,
    ``dispatch`` validates tensor shapes against them.
    ``ragged_q``: the caller passes a per-row ``q_lens`` vector and each
    batch row treats only its first ``q_lens[b]`` query rows as real —
    the mixed chunked-prefill/decode serve step, where one call carries
    decode rows (1 query) next to prefill rows (``chunk`` queries). Only
    the fused one-pass kernels serve it.
    """

    mode: str = "prefill"            # train | prefill | decode
    impl: str = "ita"                # float | ita | ibert
    causal: bool = True
    window: int = 0                  # sliding window size; 0 = off
    softcap: float = 0.0             # tanh logit softcap; 0 = off
    query_scale: float = 0.0         # 0 -> head_dim ** -0.5
    softmax: str = "adaptive"        # adaptive | paper (ITA §III DI)
    layout: str = "bshd"             # bshd | bhsd | bhsd_bsgd
    scale_kind: str = "per_tensor"   # per_tensor | per_head
    out_dtype: str = "float"         # float | int8 (on the s_out grid)
    has_s_out: bool = True
    q_len: int | None = None
    n_heads: int | None = None
    n_kv_heads: int | None = None
    ragged_q: bool = False

    def __post_init__(self):
        for field, value, allowed in (
                ("mode", self.mode, MODES),
                ("impl", self.impl, IMPLS),
                ("softmax", self.softmax, SOFTMAXES),
                ("layout", self.layout, LAYOUTS),
                ("scale_kind", self.scale_kind, SCALE_KINDS),
                ("out_dtype", self.out_dtype, OUT_DTYPES)):
            if value not in allowed:
                raise ValueError(
                    f"AttentionSpec.{field}={value!r} not in {allowed}")
        if self.window < 0:
            raise ValueError(f"window must be >= 0, got {self.window}")
        if self.impl == "float" and self.out_dtype == "int8":
            raise ValueError("out_dtype='int8' requires a quantized impl "
                             "(the float pipeline has no s_out grid)")
        if self.out_dtype == "int8" and not self.has_s_out:
            raise ValueError("out_dtype='int8' needs the s_out grid "
                             "(has_s_out=False declares it absent)")
        if (self.n_heads is not None and self.n_kv_heads is not None
                and self.n_heads % self.n_kv_heads != 0):
            raise ValueError(
                f"GQA requires n_kv_heads | n_heads, got "
                f"{self.n_heads}/{self.n_kv_heads}")

    @property
    def quantized(self) -> bool:
        return self.impl != "float"

    def replace(self, **kw) -> "AttentionSpec":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class QuantScales:
    """Quantization scales for the four tensor roles of the pipeline.

    Per-tensor: 0-d arrays / python floats (the QAT-calibrated path).
    Per-head: ``s_q``/``s_out`` of shape (Hq,), ``s_k``/``s_v`` of shape
    (Hkv,) (per-head KV-cache quantization). ``None`` marks an absent
    scale (float impl needs none; legacy checkpoints may lack ``s_out``).
    """

    s_q: Any = None
    s_k: Any = None
    s_v: Any = None
    s_out: Any = None

    @classmethod
    def per_tensor(cls, s_q, s_k=None, s_v=None, s_out=None):
        """Convenience: one scalar per role (s_k/s_v default to s_q)."""
        return cls(s_q=s_q, s_k=s_k if s_k is not None else s_q,
                   s_v=s_v if s_v is not None else s_q, s_out=s_out)

    @classmethod
    def from_params(cls, params) -> "QuantScales":
        """Lift the QAT scale leaves out of an attention param dict."""
        return cls(s_q=params.get("s_q"), s_k=params.get("s_k"),
                   s_v=params.get("s_v"), s_out=params.get("s_out"))

    def require(self, *names: str) -> "QuantScales":
        missing = [n for n in names if getattr(self, n) is None]
        if missing:
            raise ValueError(f"QuantScales missing {missing} "
                             "(required by the selected backend)")
        return self


jax.tree_util.register_dataclass(
    QuantScales, data_fields=("s_q", "s_k", "s_v", "s_out"), meta_fields=())


# ---------------------------------------------------------------------------
# Declared operand ranges — the contract the static range verifier
# (``repro.analysis``) seeds its abstract interpretation from. These are
# *inputs to a proof*, not documentation: every kernel's no-overflow
# certificate in CI assumes exactly these bounds, so widening one here
# re-runs the proof against the wider domain.
# ---------------------------------------------------------------------------

# Quantized activations/KV live on the signed 8-bit grid.
INT8_RANGE = (-128, 127)

# Requantization multipliers are ratios of calibrated scales (s_v/s_out,
# s_q*s_k*query_scale, ...). QAT calibration clamps scales into
# [2^-8, 8.0]; any ratio of two such scales (optionally times the
# 1/sqrt(d) query scale, d >= 1) stays inside [2^-11, 2^11].
SCALE_BOUNDS = (2.0 ** -8, 8.0)
MULT_BOUNDS = (0.0, 2.0 ** 11)

# Logical positions (kv_len, q_offset) are bounded by the largest KV
# pool any config allocates; serve pools are page multiples well under
# this. Used when the caller does not pass a tighter capacity.
MAX_KV_CAPACITY = 1 << 20


def declared_ranges(spec: AttentionSpec, *, kv_capacity: int | None = None,
                    num_pages: int | None = None) -> dict:
    """Map operand roles to their declared ``(lo, hi)`` bounds for
    ``spec``. Roles: q/k/v (activations), scale (per-role quant scales),
    mult (folded requant multipliers), kv_len/q_offset/q_len (positions),
    page_table (physical page ids), bias/acc (int32 matmul epilogue)."""
    cap = kv_capacity if kv_capacity is not None else MAX_KV_CAPACITY
    act = INT8_RANGE if spec.impl != "float" else \
        (INT8_RANGE[0] * SCALE_BOUNDS[1], INT8_RANGE[1] * SCALE_BOUNDS[1])
    return {
        "q": act, "k": act, "v": act,
        "scale": SCALE_BOUNDS,
        "mult": MULT_BOUNDS,
        "kv_len": (0, cap),
        "q_offset": (0, cap),
        "q_len": (0, cap),
        "page_table": (0, (num_pages or 1) - 1),
    }
