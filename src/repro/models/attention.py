"""Multi-head attention layer: projections + RoPE + KV caching around the
unified attention engine (``repro.attention``).

``attention_impl``:
- ``float`` — bf16/f32 softmax attention (baseline).
- ``ita``   — 8-bit quantized pipeline with the ITA integer softmax:
              * serve (prefill/decode): true integer path — int8 Q·Kᵀ
                (int32 accum), requant onto the ITA logit grid, shift-only
                softmax (adaptive per-row scale by default), int A·V; the
                KV cache is stored int8 (halving cache bytes vs bf16).
              * train: differentiable QAT forward (STE round/floor) matching
                the deployed integer semantics — the paper's QAT-trained
                clipping in action.
- ``ibert`` — same quantized pipeline with I-BERT's 32-bit polynomial
              softmax (the paper's accuracy baseline).

This module owns the *layer*: weight init, projections, RoPE, sharding
hints and ring-buffer bookkeeping (``repro.attention.KVCacheState``). The
attention computation itself — which kernel/XLA path serves a given
(mode, features) combination — is entirely the registry's decision:
one ``AttentionSpec`` + ``QuantScales`` per call, ``dispatch`` picks the
backend (``cfg.attention_backend`` pins one explicitly). GQA is native
(no KV broadcast); sliding-window, logit softcap and cross-attention
(audio/vision memory) are supported — see DESIGN.md §Arch-applicability
for how each assigned architecture uses these, and DESIGN.md §Backends
for the capability matrix.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import attention as ATT
from repro.attention.xla import quantize_to_int8
from repro.launch import hints
from repro.models.layers import _normal, rope


def init_attention(key, cfg, cross: bool = False):
    d, h, g, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    # cross-attn consumes the frontend memory *after* projection to d_model
    kv_in = d
    p = {"wq": _normal(ks[0], (d, h * hd), d ** -0.5),
         "wk": _normal(ks[1], (kv_in, g * hd), kv_in ** -0.5),
         "wv": _normal(ks[2], (kv_in, g * hd), kv_in ** -0.5),
         "wo": _normal(ks[3], (h * hd, d), (h * hd) ** -0.5)}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), jnp.float32)
        p["bk"] = jnp.zeros((g * hd,), jnp.float32)
        p["bv"] = jnp.zeros((g * hd,), jnp.float32)
    if cfg.attention_impl != "float":
        # Calibrated quantization scales (QAT-trainable), one per tensor
        # role — the clipping thresholds the paper learns with QAT.
        # s_out requantizes the attention output onto an int8 grid between
        # blocks (the fused decode kernel's out_mult = s_v / s_out).
        for name in ("s_q", "s_k", "s_v", "s_out"):
            p[name] = jnp.asarray(0.05, jnp.float32)
    return p


def _split_heads(x, n, hd):
    return x.reshape(*x.shape[:-1], n, hd)


def make_spec(cfg, *, mode, causal, window, q_len=None,
              has_s_out=True, layout="bshd",
              ragged_q=False) -> ATT.AttentionSpec:
    """The layer's view of the engine: one spec per (cfg, call site).
    ``has_s_out=False`` declares a legacy param set without the output
    requant scale — the fused kernels then decline and the XLA paths
    serve (PR-1 fallback semantics, now a capability). ``layout``
    deviates from the model's ``bshd`` only for paged-pool decode
    (``bhsd_paged``), where the KV operand is the shared arena.
    ``ragged_q`` declares the mixed chunked-prefill/decode call (per-row
    valid query counts ride the ``q_lens`` dispatch operand)."""
    return ATT.AttentionSpec(
        mode=mode, impl=cfg.attention_impl, causal=causal, window=window,
        softcap=cfg.attn_softcap, query_scale=cfg.query_scale,
        softmax="paper" if cfg.softmax_impl == "ita_paper" else "adaptive",
        layout=layout, scale_kind="per_tensor", out_dtype="float",
        has_s_out=has_s_out, q_len=q_len, n_heads=cfg.n_heads,
        ragged_q=ragged_q)


def apply_attention(params, x, *, cfg, kind="global", positions=None,
                    mem=None, cache=None, mode="train", lengths=None,
                    live=None, q_lens=None):
    """Full attention layer: projections + RoPE + engine dispatch + output
    projection.

    ``kind``: global | local (cfg.local_window) | swa (cfg.window) | cross.
    ``cache`` (serve): ``KVCacheState`` ring buffer (int8 for quantized
    impls, compute dtype for float) or a ``PagedKVState`` pool
    (continuous batching — decode attends through the shared arena via
    the ``bhsd_paged`` capability), or a ``{"k8", "v8"}`` dict for the
    static cross-attention memory; returns (y, new_cache).
    ``lengths`` (B,): ragged prefill — per-sequence valid prompt lengths
    of a right-padded batch; the ring buffer records them as each row's
    stream position so decode continues raggedly (causal masking keeps
    valid rows exact; pad rows are garbage the caller never reads).
    ``live`` (B,): decode-time slot mask — dead slots (continuous
    batching) skip the cache write and position advance.
    ``q_lens`` (B,): the mixed chunked-prefill/decode step (paged caches
    only) — row ``b`` carries ``q_lens[b]`` real tokens of the presented
    width (decode rows 1, prefill rows a chunk, dead rows 0); K/V append
    page-natively via ``append_chunk`` and attention runs the ragged-q
    paged kernel, so prompt chunks never touch a ring scratch.
    """
    d, h, g, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = x.dtype
    cross = kind == "cross"
    window = {"global": 0, "cross": 0, "local": cfg.local_window,
              "swa": cfg.window}[kind]
    causal = not cross and cfg.causal

    q = x @ params["wq"].astype(dt)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
    q = _split_heads(q, h, hd)

    kv_src = mem if cross else x
    if cross and cache is not None and "k8" in cache and mode == "decode":
        k = v = None                               # static cross KV cached
    else:
        k = kv_src @ params["wk"].astype(dt)
        v = kv_src @ params["wv"].astype(dt)
        if cfg.qkv_bias:
            k = k + params["bk"].astype(dt)
            v = v + params["bv"].astype(dt)
        k, v = _split_heads(k, g, hd), _split_heads(v, g, hd)

    if positions is not None and not cross and cfg.rope_theta > 0:
        q = rope(q, positions, cfg.rope_theta)
        if k is not None:
            k = rope(k, positions, cfg.rope_theta)

    # TP hints: heads over 'model' when divisible, else sequence-parallel
    # attention (Sq over 'model'); KV heads likewise (replicated if small).
    if hints.heads_shardable(h):
        q = hints.constrain(q, "batch", None, "heads", None)
    else:
        q = hints.constrain(q, "batch", "seq", None, None)
    if k is not None:
        k = hints.constrain(k, "batch", None, "kv_heads", None)
        v = hints.constrain(v, "batch", None, "kv_heads", None)

    scales = ATT.QuantScales.from_params(params)
    quant_cache = cfg.attention_impl != "float"

    def run(qq, kk, vv, *, mode, causal=causal, window=window,
            q_offset=0, kv_len=None, layout="bshd", page_table=None,
            q_lens=None):
        q_len = qq.shape[2] if layout == "bhsd_paged" else qq.shape[1]
        spec = make_spec(cfg, mode=mode, causal=causal, window=window,
                         q_len=q_len, has_s_out=scales.s_out is not None,
                         layout=layout, ragged_q=q_lens is not None)
        # cfg.attention_backend is a *preference*: it pins the backend at
        # every call site it can serve (no backend serves all of
        # train/prefill/decode), and capability dispatch covers the rest.
        backend = cfg.attention_backend or None
        if backend is not None \
                and ATT.get_backend(backend).supports(spec) is not True:
            backend = None
        out = ATT.dispatch(qq, kk, vv, spec=spec, scales=scales,
                           q_offset=q_offset, kv_len=kv_len,
                           page_table=page_table, q_lens=q_lens,
                           backend=backend, q_chunk=cfg.attn_q_chunk,
                           kv_chunk=cfg.attn_kv_chunk,
                           scan_unroll=cfg.scan_unroll)
        return out.astype(dt)

    def _q(t, s):
        return quantize_to_int8(t, params[s]) if quant_cache else t

    new_cache = cache
    if cache is None:
        y = run(q, k, v, mode=mode)
    elif cross:
        if mode != "decode":                        # (re)compute at prefill
            cache = dict(cache, k8=_q(k, "s_k"), v8=_q(v, "s_v"))
        new_cache = cache
        y = run(q, cache["k8"], cache["v8"], mode=mode)
    elif mode == "prefill":
        # Full in-layer attention; then write the canonical ring-buffer
        # tail (token t lives at slot t % cache_size) so decode can append.
        y = run(q, k, v, mode=mode)
        new_cache = cache.prefill_write(_q(k, "s_k"), _q(v, "s_v"),
                                        lengths=lengths)
    elif q_lens is not None:                        # mixed chunk append
        # Chunked-prefill serve step: per-row ragged widths, K/V written
        # straight into pool pages (append_chunk), attention through the
        # ragged-q paged kernel — no ring scratch, no host bytes-copy.
        if not isinstance(cache, ATT.PagedKVState):
            raise ValueError(
                "q_lens= (mixed chunked prefill) requires paged KV caches; "
                "ring caches serve uniform decode/prefill only")
        n_new = jnp.asarray(q_lens, jnp.int32)
        new_cache = cache.append_chunk(_q(k, "s_k"), _q(v, "s_v"), n_new)
        y = run(jnp.swapaxes(q, 1, 2), new_cache.k, new_cache.v,
                mode=mode, q_offset=new_cache.q_offset(n_new),
                kv_len=new_cache.valid_len(), layout="bhsd_paged",
                page_table=new_cache.page_table, q_lens=n_new)
        y = jnp.swapaxes(y, 1, 2)
    else:                                           # decode append
        s_new = q.shape[1]
        new_cache = cache.decode_append(_q(k, "s_k"), _q(v, "s_v"),
                                        live=live)
        if isinstance(new_cache, ATT.PagedKVState):
            # paged pool: q in kernel layout, K/V = the shared arena read
            # through this layer's page table (bhsd_paged capability)
            y = run(jnp.swapaxes(q, 1, 2), new_cache.k, new_cache.v,
                    mode=mode, q_offset=new_cache.q_offset(s_new),
                    kv_len=new_cache.valid_len(), layout="bhsd_paged",
                    page_table=new_cache.page_table)
            y = jnp.swapaxes(y, 1, 2)
        else:
            y = run(q, new_cache.k, new_cache.v, mode=mode,
                    q_offset=new_cache.q_offset(s_new),
                    kv_len=new_cache.valid_len())

    y = y.reshape(*y.shape[:-2], h * hd) @ params["wo"].astype(dt)
    y = hints.constrain(y, "batch", "seq", None)
    return y, new_cache
