"""Multi-head attention with ITA quantized attention as a first-class
implementation choice.

``attention_impl``:
- ``float`` — bf16/f32 softmax attention (baseline).
- ``ita``   — 8-bit quantized pipeline with the ITA integer softmax:
              * serve (prefill/decode): true integer path — int8 Q·Kᵀ
                (int32 accum), requant onto the ITA logit grid, shift-only
                softmax (adaptive per-row scale by default), int A·V; the
                KV cache is stored int8 (halving cache bytes vs bf16).
              * train: differentiable QAT forward (STE round/floor) matching
                the deployed integer semantics — the paper's QAT-trained
                clipping in action.
- ``ibert`` — same quantized pipeline with I-BERT's 32-bit polynomial
              softmax (the paper's accuracy baseline).

GQA is native (no KV broadcast); sliding-window, logit softcap and
cross-attention (audio/vision memory) are supported — see DESIGN.md
§Arch-applicability for how each assigned architecture uses these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import softmax as S
from repro.core.quant import EPS_MAX, INT8_MAX, INT8_MIN
from repro.launch import hints
from repro.models.layers import _normal, rope, softcap
from repro.runtime import kv_cache as KV


def init_attention(key, cfg, cross: bool = False):
    d, h, g, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    # cross-attn consumes the frontend memory *after* projection to d_model
    kv_in = d
    p = {"wq": _normal(ks[0], (d, h * hd), d ** -0.5),
         "wk": _normal(ks[1], (kv_in, g * hd), kv_in ** -0.5),
         "wv": _normal(ks[2], (kv_in, g * hd), kv_in ** -0.5),
         "wo": _normal(ks[3], (h * hd, d), (h * hd) ** -0.5)}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), jnp.float32)
        p["bk"] = jnp.zeros((g * hd,), jnp.float32)
        p["bv"] = jnp.zeros((g * hd,), jnp.float32)
    if cfg.attention_impl != "float":
        # Calibrated quantization scales (QAT-trainable), one per tensor
        # role — the clipping thresholds the paper learns with QAT.
        # s_out requantizes the attention output onto an int8 grid between
        # blocks (the fused decode kernel's out_mult = s_v / s_out).
        for name in ("s_q", "s_k", "s_v", "s_out"):
            p[name] = jnp.asarray(0.05, jnp.float32)
    return p


def _split_heads(x, n, hd):
    return x.reshape(*x.shape[:-1], n, hd)


def _mask(sq, skv, q_offset, causal, window, kv_len):
    qi = q_offset + jnp.arange(sq, dtype=jnp.int32)[:, None]
    kj = jnp.arange(skv, dtype=jnp.int32)[None, :]
    m = jnp.ones((sq, skv), jnp.bool_)
    if causal or window > 0:
        m &= qi >= kj
    if window > 0:
        m &= (qi - kj) < window
    if kv_len is not None:
        m &= kj < kv_len
    return m


def _gqa_logits(q, k):
    """q (B,Sq,H,hd), k (B,Skv,G,hd) -> logits (B,G,H/G,Sq,Skv) without
    materializing broadcast KV heads."""
    b, sq, h, hd = q.shape
    g = k.shape[2]
    qg = q.reshape(b, sq, g, h // g, hd)
    return jnp.einsum("bqgmd,bkgd->bgmqk", qg, k)


def _gqa_out(p, v):
    """p (B,G,M,Sq,Skv), v (B,Skv,G,hd) -> (B,Sq,H,hd)."""
    out = jnp.einsum("bgmqk,bkgd->bqgmd", p, v)
    b, sq, g, m, hd = out.shape
    return out.reshape(b, sq, g * m, hd)


def _quantize_dyn(x, scale):
    return KV.quantize_with_scale(x, scale)


def attention_core(q, k, v, *, cfg, params, causal, window, q_offset=0,
                   kv_len=None, mode="train", k_quant=None, v_quant=None):
    """The paper's pipeline: Q·Kᵀ -> softmax -> A·V.

    q: (B,Sq,H,hd) float; k/v: (B,Skv,G,hd) float *or* pre-quantized int8
    (``k_quant``/``v_quant`` from an int8 KV cache).
    Returns (B,Sq,H,hd) float.

    Dispatch: decode (Sq small, traced q_offset) takes the *direct* path
    over the full KV cache; train/prefill take the *streaming chunked*
    path (repro.models.chunked_attention) so the S×S matrix never
    materializes — the paper's streaming-softmax dataflow at XLA level.
    """
    impl = cfg.attention_impl
    scale = cfg.query_scale or cfg.head_dim ** -0.5
    sq_, skv = q.shape[1], (k_quant if k_quant is not None else k).shape[1]
    chunked = mode != "decode" and sq_ > 1 and impl != "ibert"

    if chunked:
        from repro.models.chunked_attention import streaming_attention
        ck = dict(q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk)
        if impl == "float":
            out = streaming_attention(q, k, v, impl="float", cfg=cfg,
                                      scale=scale, causal=causal,
                                      window=window, kv_len=kv_len, **ck)
        else:
            s_q, s_k, s_v = params["s_q"], params["s_k"], params["s_v"]
            if mode == "train":
                from repro.core.quant import fake_quant
                out = streaming_attention(
                    q, k, fake_quant(v, s_v), impl="ita_ste", cfg=cfg,
                    scale=scale, s_q=s_q, s_k=s_k, s_v=s_v, causal=causal,
                    window=window, kv_len=kv_len, **ck)
                if "s_out" in params:
                    # QAT sees the serve-time inter-block output requant,
                    # training the s_out grid the decode kernel deploys on
                    out = fake_quant(out, params["s_out"])
            else:
                q8 = _quantize_dyn(q, s_q)
                k8 = k_quant if k_quant is not None else _quantize_dyn(k, s_k)
                v8 = v_quant if v_quant is not None else _quantize_dyn(v, s_v)
                out = streaming_attention(
                    q8, k8, v8, impl="ita_int", cfg=cfg, scale=scale,
                    s_q=s_q, s_k=s_k, s_v=s_v, causal=causal, window=window,
                    kv_len=kv_len, **ck)
        return out.astype(q.dtype if q.dtype != jnp.int8 else
                          cfg.compute_dtype())

    mask = _mask(sq_, skv, q_offset, causal, window, kv_len)[None, None, None]

    if impl == "float" or (mode == "train" and impl == "ibert"):
        logits = _gqa_logits(q, k) * scale
        logits = softcap(logits, cfg.attn_softcap)
        logits = jnp.where(mask, logits, -jnp.inf)
        p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        p = jnp.where(mask, p, 0.0).astype(v.dtype)
        return _gqa_out(p, v)

    s_q, s_k, s_v = params["s_q"], params["s_k"], params["s_v"]

    if mode == "train":                      # QAT forward (STE, float ops)
        from repro.core.quant import fake_quant
        qf = fake_quant(q, s_q)
        kf = fake_quant(k, s_k)
        vf = fake_quant(v, s_v)
        logits = _gqa_logits(qf, kf) * scale
        logits = softcap(logits, cfg.attn_softcap)
        p = S.ita_softmax_ste(logits.astype(jnp.float32),
                              mask=jnp.broadcast_to(mask, logits.shape))
        out = _gqa_out(p.astype(v.dtype), vf)
        if "s_out" in params:
            out = fake_quant(out, params["s_out"])
        return out

    # --- integer serve path (direct: decode / ibert) -------------------
    q8 = _quantize_dyn(q, s_q)
    k8 = k_quant if k_quant is not None else _quantize_dyn(k, s_k)
    v8 = v_quant if v_quant is not None else _quantize_dyn(v, s_v)

    # Single-token decode rides the fused decode-shaped Pallas kernel,
    # consuming the int8 ring buffers cache-natively (kv_layout="bsgd")
    # and requantizing the output onto the s_out grid. Falls back to the
    # XLA path for softcap / custom query scale (kernel-unsupported) or
    # legacy param sets without s_out.
    if (impl == "ita" and mode == "decode" and sq_ <= 8
            and not cfg.attn_softcap and not cfg.query_scale
            and "s_out" in params):
        from repro.kernels.ita_attention.ops import ita_attention
        s_o = params["s_out"]
        out_i8 = ita_attention(
            jnp.swapaxes(q8, 1, 2), k8, v8, s_q, s_k, s_v, s_o,
            q_offset=q_offset, kv_len=kv_len, causal=causal, window=window,
            mode="decode", adaptive=cfg.softmax_impl != "ita_paper",
            kv_layout="bsgd")
        out = jnp.swapaxes(out_i8, 1, 2).astype(jnp.float32) * s_o
        return out.astype(cfg.compute_dtype())

    acc = _gqa_logits(q8.astype(jnp.int32), k8.astype(jnp.int32))   # int32
    logits_f = acc.astype(jnp.float32) * (s_q * s_k * scale)
    logits_f = softcap(logits_f, cfg.attn_softcap)
    lq = jnp.clip(jnp.round(logits_f / EPS_MAX), INT8_MIN, INT8_MAX
                  ).astype(jnp.int32)
    bmask = jnp.broadcast_to(mask, lq.shape)

    if impl == "ibert":
        p = S.ibert_softmax(lq, mask=bmask)                 # f32 probs
        out = jnp.einsum("bgmqk,bkgd->bqgmd", p, v8.astype(jnp.float32))
        out = out * s_v
    else:                                                   # ITA
        if cfg.softmax_impl == "ita_paper":
            p_int, sigma, _ = S.ita_softmax_int(lq, mask=bmask)
            e_r = jnp.full_like(sigma, 8)
        else:                                               # adaptive (default)
            p_int, e_r, _ = S.ita_softmax_adaptive_int(lq, mask=bmask)
        acc_o = jnp.einsum("bgmqk,bkgd->bqgmd", p_int,
                           v8.astype(jnp.int32))            # Σp·v, int32-safe
        out = acc_o.astype(jnp.float32) \
            * jnp.exp2(-e_r.astype(jnp.float32)).transpose(0, 3, 1, 2, 4) \
            * s_v
    b, sq2, g, m, hd = out.shape
    return out.reshape(b, sq2, g * m, hd).astype(cfg.compute_dtype())


def apply_attention(params, x, *, cfg, kind="global", positions=None,
                    mem=None, cache=None, mode="train"):
    """Full attention layer: projections + RoPE + core + output proj.

    ``kind``: global | local (cfg.local_window) | swa (cfg.window) | cross.
    ``cache`` (serve): dict with int8 (ita) or compute-dtype K/V ring
    buffers and the current position; returns (y, new_cache).
    """
    d, h, g, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = x.dtype
    cross = kind == "cross"
    window = {"global": 0, "cross": 0, "local": cfg.local_window,
              "swa": cfg.window}[kind]
    causal = not cross and cfg.causal

    q = x @ params["wq"].astype(dt)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
    q = _split_heads(q, h, hd)

    kv_src = mem if cross else x
    if cross and cache is not None and "k8" in cache and mode == "decode":
        k = v = None                               # static cross KV cached
    else:
        k = kv_src @ params["wk"].astype(dt)
        v = kv_src @ params["wv"].astype(dt)
        if cfg.qkv_bias:
            k = k + params["bk"].astype(dt)
            v = v + params["bv"].astype(dt)
        k, v = _split_heads(k, g, hd), _split_heads(v, g, hd)

    if positions is not None and not cross and cfg.rope_theta > 0:
        q = rope(q, positions, cfg.rope_theta)
        if k is not None:
            k = rope(k, positions, cfg.rope_theta)

    # TP hints: heads over 'model' when divisible, else sequence-parallel
    # attention (Sq over 'model'); KV heads likewise (replicated if small).
    if hints.heads_shardable(h):
        q = hints.constrain(q, "batch", None, "heads", None)
    else:
        q = hints.constrain(q, "batch", "seq", None, None)
    if k is not None:
        k = hints.constrain(k, "batch", None, "kv_heads", None)
        v = hints.constrain(v, "batch", None, "kv_heads", None)

    new_cache = cache
    quant_cache = cfg.attention_impl != "float"

    def _q(t, s):
        return _quantize_dyn(t, params[s]) if quant_cache else t

    if cache is None:
        y = attention_core(q, k, v, cfg=cfg, params=params, causal=causal,
                           window=window, mode=mode)
    elif cross:
        if mode != "decode":                        # (re)compute at prefill
            cache = dict(cache, k8=_q(k, "s_k"), v8=_q(v, "s_v"))
        new_cache = cache
        kw = (dict(k_quant=cache["k8"], v_quant=cache["v8"])
              if quant_cache else {})
        y = attention_core(q, None if quant_cache else cache["k8"],
                           None if quant_cache else cache["v8"], cfg=cfg,
                           params=params, causal=False, window=0, mode=mode,
                           **kw)
    elif mode == "prefill":
        # Full in-layer attention; then write the canonical ring-buffer
        # tail (token t lives at slot t % cache_size) so decode can append.
        y = attention_core(q, k, v, cfg=cfg, params=params, causal=causal,
                           window=window, mode=mode)
        new_cache = KV.prefill_write(cache, _q(k, "s_k"), _q(v, "s_v"))
    else:                                           # decode append
        s_new = q.shape[1]
        new_cache = KV.decode_append(cache, _q(k, "s_k"), _q(v, "s_v"))
        kc, vc = new_cache["k"], new_cache["v"]
        kw = dict(k_quant=kc, v_quant=vc) if quant_cache else {}
        y = attention_core(q, None if quant_cache else kc,
                           None if quant_cache else vc, cfg=cfg,
                           params=params, causal=causal, window=window,
                           q_offset=KV.q_offset(new_cache, s_new),
                           kv_len=KV.valid_len(new_cache), mode=mode, **kw)

    y = y.reshape(*y.shape[:-2], h * hd) @ params["wo"].astype(dt)
    y = hints.constrain(y, "batch", "seq", None)
    return y, new_cache
