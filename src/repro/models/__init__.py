"""Model zoo: build any assigned architecture from its config."""
from repro.models.transformer import (forward, init_caches, init_model,  # noqa: F401
                                      loss_fn)
