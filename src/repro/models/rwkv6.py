"""RWKV6 "Finch" blocks (attention-free SSM with data-dependent decay).

Time-mix: per-head matrix-valued state S ∈ R^{dh×dh} with
    S_t = diag(w_t) S_{t-1} + k_tᵀ v_t
    o_t = r_t (S_{t-1} + diag(u) k_tᵀ v_t)
where the decay w_t is *data-dependent* (the Finch contribution) via a
low-rank ("ddlerp") projection, as are the token-shift interpolations.

The recurrence runs as a ``lax.scan`` over time (compact HLO for the
dry-run; a chunkwise-parallel formulation is a §Perf candidate). Decode
carries O(1) state: (token-shift tail, per-head S).

ITA applicability: RWKV6 has **no softmax attention** — the paper's softmax
accelerator has no site here (DESIGN.md §Arch-applicability); projections
can still use the int8 weight-stationary matmul path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import _normal

_LORA = 64        # ddlerp low-rank dim
_LORA_W = 64      # decay low-rank dim


def init_time_mix(key, cfg):
    d, nh = cfg.d_model, cfg.n_heads
    dh = d // nh
    ks = jax.random.split(key, 12)
    return {
        "mu": _normal(ks[0], (5, d), 0.1),             # r,k,v,w,g shift mixes
        "ddlerp_a": _normal(ks[1], (d, 5 * _LORA), d ** -0.5),
        "ddlerp_b": _normal(ks[2], (5, _LORA, d), _LORA ** -0.5),
        "w_r": _normal(ks[3], (d, d), d ** -0.5),
        "w_k": _normal(ks[4], (d, d), d ** -0.5),
        "w_v": _normal(ks[5], (d, d), d ** -0.5),
        "w_g": _normal(ks[6], (d, d), d ** -0.5),
        "w_o": _normal(ks[7], (d, d), d ** -0.5),
        "w0": _normal(ks[8], (d,), 0.5) - 6.0,         # decay bias
        "w_lora_a": _normal(ks[9], (d, _LORA_W), d ** -0.5),
        "w_lora_b": _normal(ks[10], (_LORA_W, d), _LORA_W ** -0.5),
        "u": _normal(ks[11], (d,), 0.5),               # current-token bonus
        "ln_scale": jnp.ones((nh, dh), jnp.float32),   # per-head groupnorm
        "ln_bias": jnp.zeros((nh, dh), jnp.float32),
    }


def _token_shift(x, prev):
    """prev: (B, d) last token of the previous chunk (zeros at start)."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _wkv_scan(r, k, v, w, u, s0):
    """r,k,v,w: (B, T, H, dh); u: (H, dh); s0: (B, H, dh, dh).
    Returns (o (B,T,H,dh), sT)."""

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                        # (B,H,dh)
        kv = k_t[..., :, None] * v_t[..., None, :]      # (B,H,dh,dh)
        o_t = jnp.einsum("bhi,bhij->bhj", r_t, s + u[..., None] * kv)
        s = w_t[..., None] * s + kv
        return s, o_t

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    sT, o = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(o, 0, 1), sT


def apply_time_mix(p, x, cfg, state=None):
    """x: (B,S,d); state: {"shift": (B,d), "s": (B,H,dh,dh)}."""
    b, s, d = x.shape
    nh = cfg.n_heads
    dh = d // nh
    dt = x.dtype
    prev = jnp.zeros((b, d), dt) if state is None else state["shift"].astype(dt)
    xs = _token_shift(x, prev)

    # ddlerp: data-dependent interpolation between x and shifted x.
    base = xs - x
    lora = jnp.tanh(x @ p["ddlerp_a"].astype(dt)).reshape(b, s, 5, _LORA)
    dyn = jnp.einsum("bsfl,fld->bsfd", lora, p["ddlerp_b"].astype(dt))
    mixed = x[:, :, None] + base[:, :, None] * (p["mu"].astype(dt) + dyn)
    xr, xk, xv, xw, xg = [mixed[:, :, i] for i in range(5)]

    r = (xr @ p["w_r"].astype(dt)).reshape(b, s, nh, dh)
    k = (xk @ p["w_k"].astype(dt)).reshape(b, s, nh, dh)
    v = (xv @ p["w_v"].astype(dt)).reshape(b, s, nh, dh)
    g = jax.nn.silu(xg @ p["w_g"].astype(dt))
    ww = p["w0"] + jnp.tanh(xw.astype(jnp.float32) @ p["w_lora_a"]) \
        @ p["w_lora_b"]
    w = jnp.exp(-jnp.exp(ww)).reshape(b, s, nh, dh)      # decay in (0,1)

    s0 = jnp.zeros((b, nh, dh, dh), jnp.float32) if state is None \
        else state["s"]
    o, sT = _wkv_scan(r.astype(jnp.float32), k.astype(jnp.float32),
                      v.astype(jnp.float32), w, p["u"].reshape(nh, dh), s0)

    # per-head group norm
    mu = o.mean(-1, keepdims=True)
    var = o.var(-1, keepdims=True)
    o = (o - mu) * jax.lax.rsqrt(var + 1e-5) * p["ln_scale"] + p["ln_bias"]
    y = (o.reshape(b, s, d).astype(dt) * g) @ p["w_o"].astype(dt)
    return y, {"shift": x[:, -1].astype(jnp.float32), "s": sT}


def init_channel_mix(key, cfg):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    return {"mu_k": _normal(ks[0], (d,), 0.1),
            "mu_r": _normal(ks[1], (d,), 0.1),
            "w_k": _normal(ks[2], (d, f), d ** -0.5),
            "w_v": _normal(ks[3], (f, d), f ** -0.5),
            "w_r": _normal(jax.random.fold_in(key, 9), (d, d), d ** -0.5)}


def apply_channel_mix(p, x, cfg, state=None):
    b, s, d = x.shape
    dt = x.dtype
    prev = jnp.zeros((b, d), dt) if state is None else state["shift"].astype(dt)
    xs = _token_shift(x, prev)
    xk = x + (xs - x) * p["mu_k"].astype(dt)
    xr = x + (xs - x) * p["mu_r"].astype(dt)
    k = jnp.square(jax.nn.relu(xk @ p["w_k"].astype(dt)))
    y = jax.nn.sigmoid(xr @ p["w_r"].astype(dt)) * (k @ p["w_v"].astype(dt))
    return y, {"shift": x[:, -1].astype(jnp.float32)}


def init_rwkv_state(batch, cfg):
    d, nh = cfg.d_model, cfg.n_heads
    dh = d // nh
    return {"tm": {"shift": jnp.zeros((batch, d), jnp.float32),
                   "s": jnp.zeros((batch, nh, dh, dh), jnp.float32)},
            "cm": {"shift": jnp.zeros((batch, d), jnp.float32)}}
