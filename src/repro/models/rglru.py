"""RG-LRU recurrent block (RecurrentGemma / Griffin).

The temporal mixer of recurrentgemma's 2-of-3 non-attention layers:
gate branch (GeLU) ⊙ (causal conv1d(4) → RG-LRU) → output projection.

RG-LRU (per channel, gates block-diagonal per head as in Griffin):
    r_t = sigmoid(W_a x_t + b_a)          recurrence gate
    i_t = sigmoid(W_x x_t + b_x)          input gate
    a_t = exp(-c * softplus(Λ) * r_t)     data-dependent decay (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

The recurrence is a first-order elementwise linear scan — evaluated with
``jax.lax.associative_scan`` (log-depth, TPU-friendly), which is this arch's
sub-quadratic claim to the ``long_500k`` shape. Decode keeps O(1) state:
(h, last-3 conv inputs).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import _normal

_C = 8.0
_CONV_K = 4


def init_rglru(key, cfg):
    d, dr, nh = cfg.d_model, cfg.rnn_width, cfg.n_heads
    dh = dr // nh
    ks = jax.random.split(key, 7)
    return {
        "w_gate_branch": _normal(ks[0], (d, dr), d ** -0.5),
        "w_in": _normal(ks[1], (d, dr), d ** -0.5),
        "conv_w": _normal(ks[2], (_CONV_K, dr), 0.1),
        "conv_b": jnp.zeros((dr,), jnp.float32),
        "w_a": _normal(ks[3], (nh, dh, dh), dh ** -0.5),   # block-diag gates
        "b_a": jnp.zeros((dr,), jnp.float32),
        "w_x": _normal(ks[4], (nh, dh, dh), dh ** -0.5),
        "b_x": jnp.zeros((dr,), jnp.float32),
        "lam": _normal(ks[5], (dr,), 1.0) + 4.0,           # Λ init: a ≈ 0.97
        "w_out": _normal(ks[6], (dr, d), dr ** -0.5),
    }


def _blockdiag(x, w, nh):
    b, s, dr = x.shape
    xh = x.reshape(b, s, nh, dr // nh)
    return jnp.einsum("bshi,hij->bshj", xh, w.astype(x.dtype)
                      ).reshape(b, s, dr)


def _conv1d_causal(x, w, bias, state=None):
    """Depthwise causal conv, kernel 4. ``state``: (B, K-1, dr) history."""
    if state is None:
        pad = jnp.zeros((x.shape[0], _CONV_K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
              for i in range(_CONV_K))
    new_state = xp[:, -(_CONV_K - 1):]
    return out + bias.astype(x.dtype), new_state


def _rglru_scan(x, a, h0=None):
    """h_t = a_t h_{t-1} + b_t via associative scan over time axis 1."""
    b_t = jnp.sqrt(jnp.maximum(1.0 - a * a, 0.0)) * x
    if h0 is not None:
        # Fold the carried state in as a virtual step 0.
        a = jnp.concatenate([jnp.zeros_like(a[:, :1]), a], axis=1)
        b_t = jnp.concatenate([h0[:, None].astype(b_t.dtype), b_t], axis=1)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(combine, (a, b_t), axis=1)
    return h[:, 1:] if h0 is not None else h


def apply_rglru(p, x, cfg, state=None):
    """x: (B, S, d). state (decode): {"h": (B,dr), "conv": (B,3,dr)}.
    Returns (y, new_state)."""
    nh = cfg.n_heads
    dt = x.dtype
    gate = jax.nn.gelu(x @ p["w_gate_branch"].astype(dt), approximate=True)
    u = x @ p["w_in"].astype(dt)
    u, conv_state = _conv1d_causal(u, p["conv_w"], p["conv_b"],
                                   None if state is None else state["conv"])

    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(_blockdiag(uf, p["w_a"], nh) + p["b_a"])
    i = jax.nn.sigmoid(_blockdiag(uf, p["w_x"], nh) + p["b_x"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    h = _rglru_scan(i * uf, a, None if state is None else state["h"])

    y = (h.astype(dt) * gate) @ p["w_out"].astype(dt)
    new_state = {"h": h[:, -1], "conv": conv_state}
    return y, new_state


def init_rglru_state(batch, cfg, dtype=jnp.float32):
    dr = cfg.rnn_width
    return {"h": jnp.zeros((batch, dr), jnp.float32),
            "conv": jnp.zeros((batch, _CONV_K - 1, dr), dtype)}
