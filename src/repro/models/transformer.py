"""Model assembly: blocks → scanned layer groups → full LM / enc-dec.

All parameters are plain dict pytrees. Layer stacks run as ``lax.scan`` over
period-stacked parameters (HLO stays compact for 100-layer × 512-device
lowering); heterogeneous patterns (gemma2 local/global, recurrentgemma
2×RG-LRU+attn, llama-vision 4×self+cross) unroll *inside* the scan body.

Modes: ``train`` (teacher-forced logits), ``prefill`` (logits + caches),
``decode`` (one step with caches). Caches are per-group pytrees stacked on
the period axis, scanned alongside parameters.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hints
from repro.models import attention as A
from repro.models import moe as MOE
from repro.models import rglru as RG
from repro.models import rwkv6 as RW
from repro.models.layers import (apply_mlp, apply_norm, embed, init_embedding,
                                 init_mlp, init_norm, sinusoidal_positions,
                                 unembed)

ATTN_KINDS = ("attn", "local", "swa", "enc")


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def init_block(key, cfg, kind: str):
    ks = jax.random.split(key, 8)
    p: dict[str, Any] = {}
    if kind in ATTN_KINDS:
        p["norm1"] = init_norm(ks[0], cfg.d_model, cfg.norm_type)
        p["attn"] = A.init_attention(ks[1], cfg)
        p["norm2"] = init_norm(ks[2], cfg.d_model, cfg.norm_type)
        p["mlp"] = (MOE.init_moe(ks[3], cfg) if cfg.mlp_type == "moe"
                    else init_mlp(ks[3], cfg.d_model, cfg.d_ff, cfg.mlp_type))
        if cfg.attn_softcap or cfg.name.startswith("gemma2"):
            p["post_norm1"] = init_norm(ks[4], cfg.d_model, cfg.norm_type)
            p["post_norm2"] = init_norm(ks[5], cfg.d_model, cfg.norm_type)
    elif kind == "cross":
        p["norm1"] = init_norm(ks[0], cfg.d_model, cfg.norm_type)
        p["attn"] = A.init_attention(ks[1], cfg, cross=True)
        p["norm2"] = init_norm(ks[2], cfg.d_model, cfg.norm_type)
        p["mlp"] = init_mlp(ks[3], cfg.d_model, cfg.d_ff,
                            "swiglu" if cfg.mlp_type == "moe" else cfg.mlp_type)
        p["gate_attn"] = jnp.zeros((), jnp.float32)
        p["gate_mlp"] = jnp.zeros((), jnp.float32)
    elif kind == "attn_cross":
        p["norm1"] = init_norm(ks[0], cfg.d_model, cfg.norm_type)
        p["attn"] = A.init_attention(ks[1], cfg)
        p["norm_x"] = init_norm(ks[2], cfg.d_model, cfg.norm_type)
        p["xattn"] = A.init_attention(ks[3], cfg, cross=True)
        p["norm2"] = init_norm(ks[4], cfg.d_model, cfg.norm_type)
        p["mlp"] = init_mlp(ks[5], cfg.d_model, cfg.d_ff, cfg.mlp_type)
    elif kind == "rglru":
        p["norm1"] = init_norm(ks[0], cfg.d_model, cfg.norm_type)
        p["mixer"] = RG.init_rglru(ks[1], cfg)
        p["norm2"] = init_norm(ks[2], cfg.d_model, cfg.norm_type)
        p["mlp"] = init_mlp(ks[3], cfg.d_model, cfg.d_ff, cfg.mlp_type)
    elif kind == "rwkv":
        p["norm1"] = init_norm(ks[0], cfg.d_model, cfg.norm_type)
        p["mixer"] = RW.init_time_mix(ks[1], cfg)
        p["norm2"] = init_norm(ks[2], cfg.d_model, cfg.norm_type)
        p["mlp"] = RW.init_channel_mix(ks[3], cfg)
    else:
        raise ValueError(kind)
    return p


def init_block_cache(cfg, kind: str, batch: int, max_len: int,
                     paged: bool = False, page_size: int = 128,
                     num_pages: int | None = None):
    """Zero cache template for one block (None entries where stateless).

    ``paged=True`` allocates attention KV as ``PagedKVState`` pools (one
    shared arena + page tables per layer) instead of per-sequence rings —
    the continuous-batching layout; ``num_pages`` sizes each layer's
    arena (None = fully provisioned)."""
    from repro.attention import KVCacheState, PagedKVState
    g, hd = cfg.n_kv_heads, cfg.head_dim
    quant = cfg.attention_impl != "float"
    kv_dt = jnp.int8 if quant else cfg.compute_dtype()

    def kv_cache(size):
        if paged:
            return PagedKVState.init(batch, size, g, hd, dtype=kv_dt,
                                     page_size=page_size,
                                     num_pages=num_pages)
        return KVCacheState.init(batch, size, g, hd, dtype=kv_dt)

    if kind in ("attn", "enc"):
        return {"mix": kv_cache(max_len)}
    if kind == "local":
        return {"mix": kv_cache(min(max_len, cfg.local_window))}
    if kind == "swa":
        return {"mix": kv_cache(min(max_len, cfg.window))}
    if kind == "cross":
        return {"mix": {
            "k8": jnp.zeros((batch, cfg.n_frontend_tokens, g, hd), kv_dt),
            "v8": jnp.zeros((batch, cfg.n_frontend_tokens, g, hd), kv_dt)}}
    if kind == "attn_cross":
        c = init_block_cache(cfg, "attn", batch, max_len, paged=paged,
                             page_size=page_size, num_pages=num_pages)
        c["cross"] = init_block_cache(cfg, "cross", batch, max_len)["mix"]
        return c
    if kind == "rglru":
        return {"mix": RG.init_rglru_state(batch, cfg, cfg.compute_dtype())}
    if kind == "rwkv":
        st = RW.init_rwkv_state(batch, cfg)
        return {"mix": st["tm"], "mlp": st["cm"]}
    raise ValueError(kind)


def apply_block(p, x, kind, cfg, *, positions, mem, cache, mode,
                lengths=None, live=None, q_lens=None):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    cm = None if cache is None else cache.get("mix")

    def residual(y, post_key):
        if post_key in p:
            return x + apply_norm(p[post_key], y, cfg.norm_type)
        return x + y

    if kind in ATTN_KINDS or kind == "cross":
        akind = {"attn": "global", "enc": "global", "local": "local",
                 "swa": "swa", "cross": "cross"}[kind]
        h = apply_norm(p["norm1"], x, cfg.norm_type)
        y, new_mix = A.apply_attention(p["attn"], h, cfg=cfg, kind=akind,
                                       positions=positions, mem=mem,
                                       cache=cm, mode=mode, lengths=lengths,
                                       live=live, q_lens=q_lens)
        if kind == "cross":
            y = y * jnp.tanh(p["gate_attn"]).astype(y.dtype)
        x = residual(y, "post_norm1")
        h = apply_norm(p["norm2"], x, cfg.norm_type)
        if cfg.mlp_type == "moe" and kind != "cross":
            y = MOE.apply_moe(p["mlp"], h, cfg)
            aux = MOE.moe_aux_loss(p["mlp"], h, cfg) if mode == "train" else aux
        else:
            y = apply_mlp(p["mlp"], h,
                          "swiglu" if cfg.mlp_type in ("moe", "rwkv")
                          else cfg.mlp_type)
        if kind == "cross":
            y = y * jnp.tanh(p["gate_mlp"]).astype(y.dtype)
        x = residual(y, "post_norm2")
        return x, (None if cache is None else dict(cache, mix=new_mix)), aux

    if kind == "attn_cross":                       # whisper decoder layer
        h = apply_norm(p["norm1"], x, cfg.norm_type)
        y, new_self = A.apply_attention(p["attn"], h, cfg=cfg, kind="global",
                                        positions=positions, cache=cm,
                                        mode=mode, lengths=lengths,
                                        live=live, q_lens=q_lens)
        x = x + y
        h = apply_norm(p["norm_x"], x, cfg.norm_type)
        y, new_cross = A.apply_attention(
            p["xattn"], h, cfg=cfg, kind="cross", positions=None, mem=mem,
            cache=None if cache is None else cache.get("cross"), mode=mode)
        x = x + y
        h = apply_norm(p["norm2"], x, cfg.norm_type)
        x = x + apply_mlp(p["mlp"], h, cfg.mlp_type)
        nc = None if cache is None else dict(cache, mix=new_self,
                                             cross=new_cross)
        return x, nc, aux

    if kind == "rglru":
        h = apply_norm(p["norm1"], x, cfg.norm_type)
        y, new_mix = RG.apply_rglru(p["mixer"], h, cfg,
                                    None if mode == "train" else cm)
        x = x + y
        h = apply_norm(p["norm2"], x, cfg.norm_type)
        x = x + apply_mlp(p["mlp"], h, cfg.mlp_type)
        return x, (None if cache is None else dict(cache, mix=new_mix)), aux

    if kind == "rwkv":
        h = apply_norm(p["norm1"], x, cfg.norm_type)
        y, new_tm = RW.apply_time_mix(p["mixer"], h, cfg,
                                      None if mode == "train" else cm)
        x = x + y
        h = apply_norm(p["norm2"], x, cfg.norm_type)
        y, new_cm = RW.apply_channel_mix(
            p["mlp"], h, cfg,
            None if mode == "train" or cache is None else cache.get("mlp"))
        x = x + y
        nc = None if cache is None else dict(cache, mix=new_tm, mlp=new_cm)
        return x, nc, aux

    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Scanned layer groups
# ---------------------------------------------------------------------------

def init_group(key, cfg, pattern, n_periods):
    """Stacked params: tuple over pattern positions, each (n_periods, ...)."""
    def one_period(k):
        ks = jax.random.split(k, len(pattern))
        return tuple(init_block(ks[i], cfg, kind)
                     for i, kind in enumerate(pattern))
    keys = jax.random.split(key, n_periods)
    per = [one_period(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per)


def init_group_cache(cfg, pattern, n_periods, batch, max_len, paged=False,
                     page_size=128, num_pages=None):
    # broadcast (not zero) the per-block template over the period axis:
    # ring leaves are all-zero either way, but the paged pool's free
    # stack / free_top initialization must survive the stacking
    tmpl = tuple(init_block_cache(cfg, kind, batch, max_len, paged=paged,
                                  page_size=page_size, num_pages=num_pages)
                 for kind in pattern)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_periods,) + a.shape), tmpl)


def apply_group(params, x, cfg, pattern, *, positions, mem, caches, mode,
                lengths=None, live=None, q_lens=None):
    """Scan the group over its periods. Returns (x, new_caches, aux_sum)."""

    def body(carry, xs):
        xc, aux = carry
        xc = hints.constrain(xc, "batch", "seq", None)   # seq-parallel
        pparams, pcache = xs
        new_caches = []
        for i, kind in enumerate(pattern):
            blk_cache = None if pcache is None else pcache[i]
            xc, nc, a = apply_block(pparams[i], xc, kind, cfg,
                                    positions=positions, mem=mem,
                                    cache=blk_cache, mode=mode,
                                    lengths=lengths, live=live,
                                    q_lens=q_lens)
            new_caches.append(nc)
            aux = aux + a
        ys = None if pcache is None else tuple(new_caches)
        return (xc, aux), ys

    if cfg.remat and mode == "train":
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots" else None)
        body = jax.checkpoint(body, prevent_cse=False, policy=policy)

    # scan_unroll: full unroll (scan semantics preserved) — used by the
    # dry-run so XLA cost analysis sees every layer (HloCostAnalysis does
    # not scale while-loop bodies by trip count) and by real TPU runs for
    # cross-layer collective pipelining.
    n_periods = jax.tree.leaves(params)[0].shape[0]
    unroll = n_periods if getattr(cfg, "scan_unroll", False) else 1

    aux0 = jnp.zeros((), jnp.float32)
    if caches is None:
        (x, aux), _ = jax.lax.scan(body, (x, aux0), (params, None),
                                   unroll=unroll)
        return x, None, aux
    (x, aux), new_caches = jax.lax.scan(body, (x, aux0), (params, caches),
                                        unroll=unroll)
    return x, new_caches, aux


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------

def init_model(key, cfg):
    ks = jax.random.split(key, 8)
    p: dict[str, Any] = {
        "embed": init_embedding(ks[0], cfg.vocab_size, cfg.d_model,
                                cfg.tie_embeddings),
        "groups": tuple(init_group(jax.random.fold_in(ks[1], i), cfg, pat, n)
                        for i, (pat, n) in enumerate(cfg.layer_groups)),
        "final_norm": init_norm(ks[2], cfg.d_model, cfg.norm_type),
    }
    if cfg.n_encoder_layers:
        enc_cfg = cfg
        p["encoder"] = {
            "groups": (init_group(ks[3], enc_cfg, ("enc",),
                                  cfg.n_encoder_layers),),
            "final_norm": init_norm(ks[4], cfg.d_model, cfg.norm_type),
        }
    if cfg.frontend_dim and cfg.frontend_dim != cfg.d_model:
        p["frontend_proj"] = jax.random.normal(
            ks[5], (cfg.frontend_dim, cfg.d_model), jnp.float32) \
            * cfg.frontend_dim ** -0.5
    if cfg.param_dtype == "bfloat16":
        p = jax.tree.map(lambda a: a.astype(jnp.bfloat16), p)
    return p


def _encode(params, cfg, frontend, mode):
    """Whisper encoder (frontend stub embeddings -> memory) or VLM
    projection of patch embeddings."""
    dt = cfg.compute_dtype()
    if frontend is None:
        return None
    mem = frontend.astype(dt)
    if "frontend_proj" in params:
        mem = mem @ params["frontend_proj"].astype(dt)
    if cfg.n_encoder_layers:
        import dataclasses
        if cfg.sinusoidal_pos:
            pos = sinusoidal_positions(mem.shape[1], cfg.d_model)
            mem = mem + jnp.asarray(pos, dt)
        enc_cfg = dataclasses.replace(cfg, causal=False)  # bidirectional
        x = mem
        for pat_params in params["encoder"]["groups"]:
            x, _, _ = apply_group(pat_params, x, enc_cfg, ("enc",),
                                  positions=jnp.arange(x.shape[1]),
                                  mem=None, caches=None, mode="train")
        mem = apply_norm(params["encoder"]["final_norm"], x, cfg.norm_type)
    return mem


def forward(params, tokens, cfg, *, mode="train", frontend=None, caches=None,
            pos0=None, lengths=None, live=None, q_lens=None,
            skip_unembed=False):
    """tokens (B, S) int32. Returns (logits, new_caches, aux).

    ``pos0``: first token's position — a scalar (lockstep decode) or a
    (B,) per-sequence vector (ragged batch decode). ``lengths`` (B,)
    marks a ragged *prefill* of right-padded prompts: the KV caches
    record per-sequence stream lengths so decode continues each row at
    its own position (pad columns are causally invisible to valid rows).
    ``live`` (B,) bool marks which batch slots are real sequences during
    decode (continuous batching): dead slots neither write their caches
    nor advance positions, so released pages are never touched.
    ``q_lens`` (B,) int32 marks a *mixed* decode step over paged caches
    (chunked prefill): row ``b`` holds ``q_lens[b]`` real tokens of the
    presented width — prompt chunks write pool pages directly and attend
    through the ragged-q kernel alongside 1-token decode rows.
    """
    dt = cfg.compute_dtype()
    x = embed(params["embed"], tokens, dt)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dt)
    s = tokens.shape[1]
    if pos0 is None:
        positions = jnp.arange(s, dtype=jnp.int32)
    else:
        pos0 = jnp.asarray(pos0, jnp.int32)
        # (s,) lockstep, or (B, s) per-sequence (ragged decode)
        positions = pos0[..., None] + jnp.arange(s, dtype=jnp.int32) \
            if pos0.ndim else pos0 + jnp.arange(s, dtype=jnp.int32)
    if cfg.sinusoidal_pos:
        # computed from (possibly dynamic, possibly batched) positions so
        # decode works
        d = cfg.d_model
        dim = jnp.arange(0, d, 2, dtype=jnp.float32) / d
        ang = positions[..., None].astype(jnp.float32) / (10000.0 ** dim)
        pe = jnp.zeros(ang.shape[:-1] + (d,), jnp.float32) \
            .at[..., 0::2].set(jnp.sin(ang)) \
            .at[..., 1::2].set(jnp.cos(ang))
        x = x + (pe if pe.ndim == 3 else pe[None]).astype(dt)

    mem = _encode(params, cfg, frontend, mode)

    aux_total = jnp.zeros((), jnp.float32)
    new_caches = [] if caches is not None else None
    for gi, (pattern, n) in enumerate(cfg.layer_groups):
        g_cache = None if caches is None else caches[gi]
        x, nc, aux = apply_group(params["groups"][gi], x, cfg, pattern,
                                 positions=positions, mem=mem,
                                 caches=g_cache, mode=mode, lengths=lengths,
                                 live=live, q_lens=q_lens)
        aux_total = aux_total + aux
        if new_caches is not None:
            new_caches.append(nc)
    x = apply_norm(params["final_norm"], x, cfg.norm_type)
    x = hints.constrain(x, "batch", None, None)
    if skip_unembed:
        return x, (tuple(new_caches) if new_caches is not None else None), \
            aux_total
    logits = unembed(params["embed"], x, cfg.logit_softcap)
    logits = hints.constrain(logits, "batch", None, "vocab")
    return logits, (tuple(new_caches) if new_caches is not None else None), \
        aux_total


def init_caches(cfg, batch: int, max_len: int, *, paged: bool = False,
                page_size: int = 128, num_pages: int | None = None):
    """Per-group cache pytrees. ``paged=True`` swaps the per-sequence KV
    rings for shared paged pools (continuous-batching layout; one arena
    per layer, sized by ``num_pages`` — None fully provisions)."""
    return tuple(init_group_cache(cfg, pat, n, batch, max_len, paged=paged,
                                  page_size=page_size, num_pages=num_pages)
                 for pat, n in cfg.layer_groups)


def _ce(logits, targets):
    logz = jax.nn.logsumexp(logits, axis=-1)
    vidx = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    gold = jnp.sum(jnp.where(vidx == targets[..., None], logits, 0.0),
                   axis=-1)
    return (logz - gold).sum()


def loss_fn(params, batch, cfg, aux_weight: float = 0.01):
    """Causal-LM cross entropy (tokens shifted inside); MoE aux added.

    The gold-logit pick uses an iota-compare-reduce (not take_along_axis)
    so it fuses under GSPMD with a model-axis-sharded vocab — a gather
    across the sharded vocab would all-gather the full logits per device
    (hundreds of GiB at 256k vocab).

    ``cfg.ce_chunks > 1`` evaluates the unembed+CE in sequence chunks
    (lax.scan) so the (B,S,V) f32 logits never fully materialize — the
    §Perf lever for 256k-vocab temp-memory (gemma2 at train_4k).
    """
    tokens = batch["tokens"]
    targets = tokens[:, 1:]
    if cfg.ce_chunks <= 1:
        logits, _, aux = forward(params, tokens[:, :-1], cfg, mode="train",
                                 frontend=batch.get("frontend"))
        nll = _ce(logits, targets) / targets.size
        return nll + aux_weight * aux, {"nll": nll, "aux": aux}

    x, _, aux = forward(params, tokens[:, :-1], cfg, mode="train",
                        frontend=batch.get("frontend"), skip_unembed=True)
    b, s, d = x.shape
    nc = cfg.ce_chunks
    while s % nc:
        nc -= 1
    xc = jnp.moveaxis(x.reshape(b, nc, s // nc, d), 1, 0)
    tc = jnp.moveaxis(targets.reshape(b, nc, s // nc), 1, 0)

    def body(tot, inp):
        xcc, tcc = inp
        logits = unembed(params["embed"], xcc, cfg.logit_softcap)
        logits = hints.constrain(logits, "batch", None, "vocab")
        return tot + _ce(logits, tcc), None

    tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, tc))
    nll = tot / targets.size
    return nll + aux_weight * aux, {"nll": nll, "aux": aux}
