"""Mixture-of-Experts layer (OLMoE 64e/top-8, Mixtral 8e/top-2).

GShard-style **grouped** sort-based capacity dispatch: tokens are split
into G groups (one per data shard under the production mesh), and all
routing machinery — top-k, the rank-within-expert argsort, the capacity
scatter — runs *inside* a group (vmapped over G, which GSPMD maps onto the
data axis, keeping sort/scatter shard-local). Only the expert FFN einsum
crosses shards: buffers are (G, E, C, d) with G→data and E→model (expert
parallelism), so the group↔expert exchange lowers to the MoE all-to-alls
visible in the §Roofline collective term.

Tokens beyond the per-group capacity ``C = cf · t_g·k/E`` are dropped
(combine weight 0, standard GShard semantics); small groups
(t_g·k ≤ 4096 — decode steps, smoke tests) use exact capacity so nothing
drops and serve outputs are batch-size independent.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch import hints
from repro.models.layers import _normal


def init_moe(key, cfg):
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    return {"router": _normal(ks[0], (d, e), d ** -0.5),
            "w_gate": _normal(ks[1], (e, d, f), d ** -0.5),
            "w_up": _normal(ks[2], (e, d, f), d ** -0.5),
            "w_down": _normal(ks[3], (e, f, d), f ** -0.5)}


def _dispatch_group(xg, probs, e, k, cap):
    """Per-group routing. xg (t,d), probs (t,e) -> (buf (e,cap,d),
    combine info)."""
    t = xg.shape[0]
    top_w, top_i = jax.lax.top_k(probs, k)                     # (t, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    e_flat = top_i.reshape(-1)
    w_flat = top_w.reshape(-1)
    order = jnp.argsort(e_flat)
    se = e_flat[order]
    starts = jnp.searchsorted(se, jnp.arange(e, dtype=se.dtype))
    pos = jnp.arange(t * k, dtype=jnp.int32) - starts[se]
    keep = pos < cap
    slot = jnp.where(keep, se * cap + pos, e * cap)
    src_tok = order // k
    buf = jnp.zeros((e * cap + 1, xg.shape[1]), xg.dtype).at[slot].set(
        xg[src_tok] * keep[:, None].astype(xg.dtype))
    return buf[:-1].reshape(e, cap, -1), (slot, src_tok, keep,
                                          w_flat[order])


def _combine_group(y_buf, info, t, dtype):
    slot, src_tok, keep, w_sorted = info
    e_cap = y_buf.shape[0] * y_buf.shape[1]
    y_flat = y_buf.reshape(e_cap, -1)
    gathered = jnp.where(keep[:, None],
                         y_flat[jnp.minimum(slot, e_cap - 1)], 0.0)
    return jnp.zeros((t, y_flat.shape[1]), dtype).at[src_tok].add(
        gathered * w_sorted[:, None].astype(dtype))


def apply_moe(p, x, cfg, capacity_factor: float = 1.25):
    """x: (B, S, d) -> (B, S, d)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.n_experts_active
    t = b * s
    g = hints.num_data_shards()
    if t % g or (t // g) < 1:
        g = 1
    tg = t // g

    xg = x.reshape(g, tg, d)
    xg = hints.constrain(xg, "batch", None, None)
    logits = (xg.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)

    if tg * k <= 4096:
        cap = tg * k          # exact: decode/smoke, no drops
    else:
        cap = min(max(int(capacity_factor * tg * k / e), 1), tg * k)

    buf, info = jax.vmap(
        lambda xx, pp: _dispatch_group(xx, pp, e, k, cap))(xg, probs)
    # Constraint sandwich: the scatter above must stay group-local (else
    # XLA emulates a cross-shard scatter with ~GiB all-reduces); the
    # group-local -> expert-sharded reshard below lowers to the MoE
    # all-to-all proper.
    buf = hints.constrain(buf, "batch", None, None, None)       # local
    buf = hints.constrain(buf, "batch", "experts", None, None)  # a2a (G,E,C,d)

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf,
                               p["w_gate"].astype(x.dtype))) \
        * jnp.einsum("gecd,edf->gecf", buf, p["w_up"].astype(x.dtype))
    y_buf = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(x.dtype))
    y_buf = hints.constrain(y_buf, "batch", "experts", None, None)
    y_buf = hints.constrain(y_buf, "batch", None, None, None)   # a2a back

    out = jax.vmap(lambda yb, inf: _combine_group(yb, inf, tg, x.dtype))(
        y_buf, info)
    out = hints.constrain(out, "batch", None, None)
    return out.reshape(b, s, d)


def moe_aux_loss(p, x, cfg):
    """Switch-Transformer load-balance loss: E * Σ_e f_e · p_e."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.n_experts_active
    xf = x.reshape(-1, d)
    probs = jax.nn.softmax((xf.astype(jnp.float32) @ p["router"]), axis=-1)
    _, top_i = jax.lax.top_k(probs, k)
    frac = jnp.mean(jax.nn.one_hot(top_i, e, dtype=jnp.float32), axis=(0, 1))
    return e * jnp.sum(frac * probs.mean(0))
