"""Shared neural-net layers (pure JAX, no flax): norms, RoPE, MLPs,
embeddings. Parameters are plain dict pytrees created by ``init_*``
functions driven by a threaded PRNG key."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _normal(key, shape, scale, dtype=jnp.float32):
    return scale * jax.random.normal(key, shape, dtype)


# ---------------------------------------------------------------------------
# Normalization
# ---------------------------------------------------------------------------

def init_norm(key, d, norm_type="rmsnorm"):
    if norm_type == "rmsnorm":
        return {"scale": jnp.zeros((d,), jnp.float32)}       # gemma-style 1+s
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def apply_norm(p, x, norm_type="rmsnorm", eps=1e-6):
    xf = x.astype(jnp.float32)
    if norm_type == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * (1.0 + p["scale"])
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq      # (..., S, half)
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> np.ndarray:
    """Whisper-style sinusoidal embeddings (frontend stub positions)."""
    pos = np.arange(seq)[:, None]
    dim = np.arange(0, d, 2)[None, :] / d
    ang = pos / (10000.0 ** dim)
    out = np.zeros((seq, d), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return out


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, d, f, mlp_type="swiglu"):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = d ** -0.5, f ** -0.5
    if mlp_type in ("swiglu", "geglu"):
        return {"w_gate": _normal(k1, (d, f), s_in),
                "w_up": _normal(k2, (d, f), s_in),
                "w_down": _normal(k3, (f, d), s_out)}
    return {"w_up": _normal(k1, (d, f), s_in),
            "b_up": jnp.zeros((f,), jnp.float32),
            "w_down": _normal(k2, (f, d), s_out),
            "b_down": jnp.zeros((d,), jnp.float32)}


def apply_mlp(p, x, mlp_type="swiglu"):
    dt = x.dtype
    if mlp_type in ("swiglu", "geglu"):
        act = jax.nn.silu if mlp_type == "swiglu" else \
            lambda v: jax.nn.gelu(v, approximate=True)
        h = act(x @ p["w_gate"].astype(dt)) * (x @ p["w_up"].astype(dt))
        return h @ p["w_down"].astype(dt)
    h = jax.nn.gelu(x @ p["w_up"].astype(dt) + p["b_up"].astype(dt),
                    approximate=True)
    return h @ p["w_down"].astype(dt) + p["b_down"].astype(dt)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embedding(key, vocab, d, tie=True):
    p = {"table": _normal(key, (vocab, d), d ** -0.5)}
    if not tie:
        p["unembed"] = _normal(jax.random.fold_in(key, 1), (d, vocab),
                               d ** -0.5)
    return p


def embed(p, ids, dtype):
    return p["table"].astype(dtype)[ids]


def unembed(p, x, softcap=0.0):
    if "unembed" in p:
        logits = x @ p["unembed"].astype(x.dtype)
    else:
        logits = x @ p["table"].T.astype(x.dtype)
    logits = logits.astype(jnp.float32)
    if softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits


def softcap(x, cap):
    return jnp.tanh(x / cap) * cap if cap > 0 else x
