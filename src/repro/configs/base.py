"""Model / shape configuration dataclasses.

Every assigned architecture is a ``ModelConfig`` (src/repro/configs/<id>.py)
whose layer stack is expressed as *layer groups*: ``(pattern, n_periods)``
pairs, each scanned with ``lax.scan`` over stacked per-period parameters
(compact HLO at 100-layer scale). Pattern elements name block kinds:

    attn   global self-attention          local  sliding window (local_window)
    swa    sliding window (window)        cross  cross-attention (+MLP)
    attn_cross  self+cross+MLP (whisper decoder)
    rglru  RG-LRU recurrent block         rwkv   RWKV6 time+channel mix
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense|moe|hybrid|ssm|audio|vlm
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    layer_groups: tuple[tuple[tuple[str, ...], int], ...]

    mlp_type: str = "swiglu"          # swiglu|geglu|gelu|moe|rwkv
    norm_type: str = "rmsnorm"
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    window: int = 0                   # swa kind
    local_window: int = 0             # local kind
    logit_softcap: float = 0.0
    attn_softcap: float = 0.0
    query_scale: float = 0.0          # 0 -> head_dim**-0.5
    causal: bool = True
    tie_embeddings: bool = True
    embed_scale: bool = False         # multiply embeddings by sqrt(d)
    sinusoidal_pos: bool = False      # whisper-style absolute positions

    # MoE
    n_experts: int = 0
    n_experts_active: int = 0

    # recurrent
    rnn_width: int = 0

    # modality frontend (stub: precomputed embeddings via input_specs)
    frontend_dim: int = 0
    n_frontend_tokens: int = 0
    n_encoder_layers: int = 0         # whisper encoder stack

    # ITA integration
    parallelism: str = "tp_fsdp"      # tp_fsdp | fsdp (pure DP/ZeRO-3)
    param_dtype: str = "float32"      # bfloat16 -> f32 master in opt state
    attention_impl: str = "float"     # float|ita|ibert
    attention_backend: str = ""       # preferred repro.attention backend
                                      # (used where capable; "" = auto)
    softmax_impl: str = "ita_adaptive"  # ita_paper|ita_adaptive
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"        # full | dots (save matmul outputs)
    ce_chunks: int = 1                # chunk the CE loss over sequence
    attn_q_chunk: int = 512           # streaming attention block sizes
    attn_kv_chunk: int = 512
    scan_unroll: bool = False         # unroll layer scans (dry-run costs)

    # distribution / long-context capability flags
    subquadratic: bool = False        # eligible for long_500k

    @property
    def n_layers(self) -> int:
        return sum(len(pat) * n for pat, n in self.layer_groups)

    def compute_dtype(self):
        import jax.numpy as jnp
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                          # train|prefill|decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# Smoke-test shape (reduced, CPU-friendly)
SMOKE_SHAPE = ShapeConfig("smoke", 64, 2, "train")
