"""Phi-3-mini-3.8B [arXiv:2404.14219; unverified] — dense MHA.

32 layers, d=3072, 32 heads (kv=32, hd 96), SwiGLU ff 8192, vocab 32064.
Full attention -> long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b", family="dense",
    d_model=3072, n_heads=32, n_kv_heads=32, head_dim=96,
    d_ff=8192, vocab_size=32064,
    layer_groups=((("attn",), 32),),
    rope_theta=10000.0, tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="phi3-smoke", family="dense",
    d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512,
    layer_groups=((("attn",), 2),), tie_embeddings=False, dtype="float32",
)
