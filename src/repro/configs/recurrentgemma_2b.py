"""RecurrentGemma-2B [arXiv:2402.19427; hf] — hybrid RG-LRU + local attn 1:2.

26 layers: (RG-LRU, RG-LRU, local-attn) x 8 + (RG-LRU, RG-LRU) tail.
MQA (kv=1), local window 2048, GeGLU MLP, embeddings scaled by sqrt(d).
Sub-quadratic (constant RG-LRU state + windowed attention) -> long_500k runs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size=256000,
    layer_groups=((("rglru", "rglru", "local"), 8), (("rglru", "rglru"), 1)),
    mlp_type="geglu", local_window=2048, rnn_width=2560,
    rope_theta=10000.0, embed_scale=True, subquadratic=True,
    # §Perf winner: 2.6B params / d=2560 favours pure ZeRO-3 (2.1x MFU).
    parallelism="fsdp", param_dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="recurrentgemma-smoke", family="hybrid",
    d_model=64, n_heads=2, n_kv_heads=1, head_dim=32,
    d_ff=128, vocab_size=512,
    layer_groups=((("rglru", "rglru", "local"), 1), (("rglru", "rglru"), 1)),
    mlp_type="geglu", local_window=16, rnn_width=64,
    embed_scale=True, subquadratic=True, dtype="float32",
)
