from repro.configs.base import SHAPES, SMOKE_SHAPE, ModelConfig, ShapeConfig  # noqa: F401
