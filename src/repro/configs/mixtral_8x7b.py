"""Mixtral-8x7B [arXiv:2401.04088; hf] — 8-expert top-2 MoE with SWA.

32 layers, d=4096, 32 heads / 8 KV (hd 128), 8 experts (ff 14336) top-2,
vocab 32000, sliding window 4096 (per the assignment). Sub-quadratic via
SWA -> long_500k runs (fixed 4096-entry ring KV cache).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000,
    layer_groups=((("swa",), 32),),
    mlp_type="moe", n_experts=8, n_experts_active=2, window=4096,
    rope_theta=1e6, tie_embeddings=False, subquadratic=True,
)

SMOKE = ModelConfig(
    name="mixtral-smoke", family="moe",
    d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, vocab_size=512,
    layer_groups=((("swa",), 2),),
    mlp_type="moe", n_experts=4, n_experts_active=2, window=16,
    tie_embeddings=False, subquadratic=True, dtype="float32",
)
