"""Gemma2-27B [arXiv:2408.00118; hf] — local+global alternating, softcaps.

46 layers alternating (local window 4096, global), d=4608, 32 heads /
16 KV (hd 128), GeGLU ff 36864, vocab 256000, attn softcap 50, final logit
softcap 30, query scale (d/h)^-0.5 = 144^-0.5, pre+post norms, embeddings
scaled. Global layers are full attention -> long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b", family="dense",
    d_model=4608, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=36864, vocab_size=256000,
    layer_groups=((("local", "attn"), 23),),
    mlp_type="geglu", local_window=4096,
    attn_softcap=50.0, logit_softcap=30.0, query_scale=144.0 ** -0.5,
    embed_scale=True,
)

SMOKE = ModelConfig(
    name="gemma2-smoke", family="dense",
    d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
    layer_groups=((("local", "attn"), 1),),
    mlp_type="geglu", local_window=16,
    attn_softcap=50.0, logit_softcap=30.0, query_scale=16.0 ** -0.5,
    embed_scale=True, dtype="float32",
)
