"""Architecture registry: --arch <id> -> (full config, smoke config)."""
from __future__ import annotations

import dataclasses

from repro.configs import (deepseek_coder_33b, gemma2_27b, llama32_vision_90b,
                           mixtral_8x7b, olmoe_1b_7b, phi3_mini_3_8b,
                           qwen2_7b, recurrentgemma_2b, rwkv6_7b,
                           whisper_large_v3)
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig

_MODULES = {
    "recurrentgemma-2b": recurrentgemma_2b,
    "whisper-large-v3": whisper_large_v3,
    "qwen2-7b": qwen2_7b,
    "phi3-mini-3.8b": phi3_mini_3_8b,
    "deepseek-coder-33b": deepseek_coder_33b,
    "gemma2-27b": gemma2_27b,
    "olmoe-1b-7b": olmoe_1b_7b,
    "mixtral-8x7b": mixtral_8x7b,
    "llama-3.2-vision-90b": llama32_vision_90b,
    "rwkv6-7b": rwkv6_7b,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str, smoke: bool = False, **overrides) -> ModelConfig:
    cfg = _MODULES[arch].SMOKE if smoke else _MODULES[arch].CONFIG
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells; long_500k only for sub-quadratic
    archs unless include_skipped."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if shape.name == "long_500k" and not cfg.subquadratic \
                    and not include_skipped:
                continue
            out.append((arch, shape.name))
    return out
