"""Qwen2-7B [arXiv:2407.10671; hf] — dense GQA with QKV bias.

28 layers, d=3584, 28 heads / 4 KV heads (hd 128), SwiGLU ff 18944,
vocab 152064, RoPE theta 1e6. Full attention -> long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b", family="dense",
    d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
    d_ff=18944, vocab_size=152064,
    layer_groups=((("attn",), 28),),
    qkv_bias=True, rope_theta=1e6, tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="qwen2-smoke", family="dense",
    d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
    layer_groups=((("attn",), 2),),
    qkv_bias=True, rope_theta=1e6, tie_embeddings=False, dtype="float32",
)
