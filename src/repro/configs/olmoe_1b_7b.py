"""OLMoE-1B-7B [arXiv:2409.02060; hf] — 64-expert top-8 MoE.

16 layers, d=2048, 16 heads (kv=16, hd 128), 64 experts (ff 1024 each)
top-8, vocab 50304. Full attention -> long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1024, vocab_size=50304,
    layer_groups=((("attn",), 16),),
    mlp_type="moe", n_experts=64, n_experts_active=8,
    rope_theta=10000.0, tie_embeddings=False,
    # §Perf winners: pure ZeRO-3 + bf16 params (12x MFU vs TP baseline;
    # grouped a2a dispatch is in the MoE layer itself).
    parallelism="fsdp", param_dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="olmoe-smoke", family="moe",
    d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=32, vocab_size=512,
    layer_groups=((("attn",), 2),),
    mlp_type="moe", n_experts=8, n_experts_active=2,
    tie_embeddings=False, dtype="float32",
)
