"""Whisper-large-v3 [arXiv:2212.04356; unverified] — audio enc-dec.

32 encoder + 32 decoder layers, d=1280, 20 heads (MHA), GELU MLP,
LayerNorm, sinusoidal positions (conv frontend STUBBED: input_specs()
supplies precomputed 1500-frame embeddings). Decoder layers: self-attn +
cross-attn + MLP. Full attention -> long_500k skipped (DESIGN.md).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="audio",
    d_model=1280, n_heads=20, n_kv_heads=20, head_dim=64,
    d_ff=5120, vocab_size=51866,
    layer_groups=((("attn_cross",), 32),),
    mlp_type="gelu", norm_type="layernorm", rope_theta=0.0,
    sinusoidal_pos=True, tie_embeddings=True,
    n_encoder_layers=32, frontend_dim=1280, n_frontend_tokens=1500,
    # §Perf winners: d_model=1280 is too narrow for TP-16 — pure ZeRO-3
    # data parallelism + bf16 params (f32 master) + dots-remat: 8x MFU.
    parallelism="fsdp", param_dtype="bfloat16", remat_policy="dots",
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="audio",
    d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
    d_ff=128, vocab_size=512,
    layer_groups=((("attn_cross",), 2),),
    mlp_type="gelu", norm_type="layernorm", rope_theta=0.0,
    sinusoidal_pos=True, n_encoder_layers=2, frontend_dim=64,
    n_frontend_tokens=16, dtype="float32",
)
