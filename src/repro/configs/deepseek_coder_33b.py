"""DeepSeek-Coder-33B [arXiv:2401.14196; hf] — llama-arch dense GQA.

62 layers, d=7168, 56 heads / 8 KV heads (hd 128), SwiGLU ff 19200,
vocab 32256. Full attention -> long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b", family="dense",
    d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=19200, vocab_size=32256,
    layer_groups=((("attn",), 62),),
    rope_theta=100000.0, tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="deepseek-smoke", family="dense",
    d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=160, vocab_size=512,
    layer_groups=((("attn",), 2),), tie_embeddings=False, dtype="float32",
)
