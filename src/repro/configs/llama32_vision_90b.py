"""Llama-3.2-Vision-90B [hf:meta-llama/Llama-3.2-11B-Vision scaled;
unverified] — VLM with cross-attn image layers.

100 layers = (4 self-attn + 1 gated cross-attn) x 20, d=8192, 64 heads /
8 KV (hd 128), SwiGLU ff 28672, vocab 128256. Vision frontend STUBBED:
input_specs() supplies precomputed patch embeddings (1601 tokens, dim
1280) projected into d_model. Full attention -> long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=128256,
    layer_groups=(
        (("attn", "attn", "attn", "attn", "cross"), 20),),
    rope_theta=500000.0, tie_embeddings=False,
    frontend_dim=1280, n_frontend_tokens=1601,
)

SMOKE = ModelConfig(
    name="llama-vision-smoke", family="vlm",
    d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
    layer_groups=((("attn", "attn", "attn", "attn", "cross"), 1),),
    tie_embeddings=False, frontend_dim=32, n_frontend_tokens=16,
    dtype="float32",
)
