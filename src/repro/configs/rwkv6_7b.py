"""RWKV6-7B "Finch" [arXiv:2404.05892; hf] — attention-free SSM with
data-dependent decay.

32 layers, d=4096 (64 heads x hd 64 in time-mix), channel-mix ff 14336,
vocab 65536. NO softmax attention anywhere: the paper's softmax
accelerator is inapplicable (DESIGN.md §Arch-applicability); int8
weight-stationary matmuls still apply to projections. Constant-state
recurrence -> long_500k runs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    d_model=4096, n_heads=64, n_kv_heads=64, head_dim=64,
    d_ff=14336, vocab_size=65536,
    layer_groups=((("rwkv",), 32),),
    mlp_type="rwkv", rope_theta=0.0, tie_embeddings=False,
    subquadratic=True,
)

SMOKE = ModelConfig(
    name="rwkv6-smoke", family="ssm",
    d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
    d_ff=128, vocab_size=512,
    layer_groups=((("rwkv",), 2),),
    mlp_type="rwkv", rope_theta=0.0, tie_embeddings=False,
    subquadratic=True, dtype="float32",
)
