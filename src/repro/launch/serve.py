"""Serving launcher: batched prefill + one-dispatch fused decode with the
ITA integer path.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
        --attention-impl ita --batch 4 --prompt-len 32 --gen 16

Demonstrates the production serving loop via ``repro.runtime.generate``:
quantized (int8) KV caches (``repro.runtime.kv_cache``), integer
streaming-softmax attention at prefill, then **one** jitted ``lax.scan``
over every decode step — sampling on device, no host round-trip per
token. ``--ragged`` serves a mixed-length batch (right-padded prompts,
per-sequence positions through the kernel meta); ``--paged`` swaps the
per-sequence rings for the shared paged KV pool (bit-identical tokens);
``--loop stepwise`` runs the legacy per-token host loop for comparison.

``--continuous`` is the full continuous-batching server: a Poisson
arrival trace (``--requests``/``--rate``) served through fixed decode
slots over the paged pool — finished sequences release their pages
between fused ``--segment``-step scan segments, the admission scheduler
prefills queued requests into the freed slots, and throughput is
reported as *sustained* tok/s over the whole trace.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import attention as ATT
from repro.configs.registry import ARCH_IDS, get_config
from repro.launch.hints import use_hints
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import init_model
from repro.models.attention import make_spec
from repro.runtime.generate import ServeRequest, generate, serve_continuous


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--attention-impl", default="ita",
                    choices=["float", "ita", "ibert"])
    ap.add_argument("--attention-backend", default="",
                    choices=[""] + ATT.list_backends(),
                    help="prefer a registry backend at every call site it "
                         "can serve (no backend covers all of prefill+"
                         "decode); capability dispatch fills the rest")
    ap.add_argument("--list-backends", action="store_true",
                    help="print every backend's verdict for this "
                         "arch/impl's decode spec, then exit")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--loop", default="fused", choices=["fused", "stepwise"],
                    help="fused = one scan dispatch for all decode steps; "
                         "stepwise = legacy per-token host loop")
    ap.add_argument("--ragged", action="store_true",
                    help="serve a mixed-length batch: random per-sequence "
                         "prompt lengths in [prompt_len/2, prompt_len]")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="mask sequences after this token, stop counting "
                         "them toward tok/s, and exit early once all "
                         "finished (fused: while_loop; stepwise: a host "
                         "check that adds a per-step device sync)")
    ap.add_argument("--paged", action="store_true",
                    help="allocate the KV caches as shared paged pools "
                         "(PagedKVState) instead of per-sequence rings — "
                         "bit-identical tokens, O(live tokens) memory")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching over a Poisson arrival "
                         "trace: --batch slots, paged pool, admission "
                         "between --segment-step fused scan segments")
    ap.add_argument("--requests", type=int, default=16,
                    help="trace length for --continuous")
    ap.add_argument("--rate", type=float, default=0.25,
                    help="mean arrivals per decode step for --continuous")
    ap.add_argument("--segment", type=int, default=16,
                    help="decode steps per fused segment (admission "
                         "granularity) for --continuous")
    ap.add_argument("--page-size", type=int, default=128,
                    help="KV pool page size (tokens per page)")
    ap.add_argument("--admission", default="chunked",
                    choices=["chunked", "stall"],
                    help="chunked = prompts prefill in chunks inside the "
                         "fused segments, interleaved with decode (page-"
                         "native writes, no stop-the-world); stall = "
                         "PR-4 stop-the-world padded prefill + adopt "
                         "(A/B reference)")
    ap.add_argument("--chunk-size", type=int, default=32,
                    help="prompt tokens prefilling per slot per step "
                         "under --admission chunked")
    ap.add_argument("--token-budget", type=int, default=None,
                    help="per-step token budget of the decode-maximal "
                         "scheduler (default slots - 1 + chunk_size)")
    ap.add_argument("--prefix-sharing", action="store_true",
                    help="share identical prompt prefixes across requests "
                         "through the paged pool (copy-on-write, chunked "
                         "admission only): matching page-aligned prefix "
                         "chunks adopt existing pages instead of "
                         "re-prefilling")
    ap.add_argument("--system-prompt-len", type=int, default=0,
                    help="prepend a common system prefix of this many "
                         "tokens to every --continuous request (makes "
                         "--prefix-sharing observable: >= page-size "
                         "tokens shared per request)")
    ap.add_argument("--priority-classes", type=int, default=1,
                    help="number of SLO classes for --continuous: each "
                         "request draws a random class in [0, N) (higher "
                         "= more urgent; orders admission, the chunked "
                         "token budget and victim selection); per-class "
                         "p95 TTFT/latency are reported")
    ap.add_argument("--preemption", action="store_true",
                    help="page-pressure preemption for --continuous: "
                         "under pool/slot exhaustion, lower-class victims "
                         "release their pages and re-enqueue carrying "
                         "their generated prefix (bit-identical outputs)")
    ap.add_argument("--overload", type=float, default=1.0,
                    help="multiply --rate by this factor (arrival rate > "
                         "service rate exercises --preemption; 1 = off)")
    ap.add_argument("--journal-dir", default=None,
                    help="write-ahead request journal + snapshots here "
                         "(--continuous): admissions, per-segment token "
                         "high-water marks and completions are journaled "
                         "at every segment boundary (group commit, "
                         "bounded fsync lag), so a crashed serve can be "
                         "resumed bit-identically with --resume")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="snapshot the paged pool + prefix index every N "
                         "segments into <journal-dir>/snapshots (0 = off); "
                         "a usable snapshot warm-starts --resume, a "
                         "corrupt one degrades to cold-start from the "
                         "journal")
    ap.add_argument("--resume", action="store_true",
                    help="replay <journal-dir>/journal.jsonl before "
                         "serving: finished requests return without being "
                         "served twice, unfinished ones resume from their "
                         "last journaled boundary (bit-identical tokens)")
    ap.add_argument("--drain-timeout", type=float, default=None,
                    help="on SIGTERM (or Ctrl-C posing as one), stop "
                         "admitting and let in-flight requests finish; "
                         "after this many seconds stop at the next segment "
                         "boundary with progress journaled for --resume")
    ap.add_argument("--aging-steps", type=int, default=None,
                    help="starvation aging for --priority-classes: a "
                         "waiting request's effective class grows by one "
                         "every N virtual steps (bounded worst-case "
                         "admission delay for the low class)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke,
                     attention_impl=args.attention_impl,
                     attention_backend=args.attention_backend)

    if args.list_backends:
        spec = make_spec(cfg, mode="decode", causal=cfg.causal,
                         window=cfg.window, q_len=1)
        print(f"[serve] decode spec for {cfg.name}: {spec}")
        for name, verdict in ATT.backend_reasons(spec).items():
            mark = "eligible" if verdict is True else f"no — {verdict}"
            print(f"[serve]   {name:20s} {mark}")
        return
    mesh = make_host_mesh() if args.smoke else make_production_mesh()
    key = jax.random.PRNGKey(args.seed)

    if args.continuous:
        rng = np.random.default_rng(args.seed)
        with mesh, use_hints(mesh):
            params = init_model(key, cfg)
            rate = max(args.rate, 1e-6) * max(args.overload, 1e-6)
            arrivals = np.cumsum(rng.exponential(1.0 / rate,
                                                 args.requests)).astype(int)
            system = rng.integers(0, cfg.vocab_size, args.system_prompt_len
                                  ).astype(np.int32)
            reqs = [ServeRequest(
                prompt=np.concatenate([system, rng.integers(
                    0, cfg.vocab_size, int(rng.integers(
                        max(1, args.prompt_len // 2), args.prompt_len + 1))
                    ).astype(np.int32)]),
                gen=int(rng.integers(max(2, args.gen // 4), args.gen + 1)),
                arrival=int(t),
                priority=int(rng.integers(0, max(1, args.priority_classes)))
            ) for t in arrivals]
            drain = None
            if args.journal_dir is not None:
                import signal

                from repro.runtime.journal import ServeDrain
                drain = ServeDrain()
                signal.signal(signal.SIGTERM,
                              lambda *_: drain.request())
            res = serve_continuous(
                params, cfg, reqs, slots=args.batch, segment=args.segment,
                max_len=args.system_prompt_len + args.prompt_len + args.gen,
                page_size=args.page_size, temperature=args.temperature,
                key=key if args.temperature > 0 else None,
                eos_id=args.eos_id, admission=args.admission,
                chunk_size=args.chunk_size, token_budget=args.token_budget,
                prefix_sharing=args.prefix_sharing,
                preemption=args.preemption,
                journal_dir=args.journal_dir,
                snapshot_every=args.snapshot_every, resume=args.resume,
                drain=drain, drain_timeout=args.drain_timeout,
                aging_steps=args.aging_steps)
        util = max((u for _, u in res.page_util), default=0.0)
        print(f"[serve] arch={cfg.name} continuous slots={args.batch} "
              f"segment={args.segment} page_size={args.page_size} "
              f"admission={args.admission}"
              + (f" chunk={args.chunk_size}"
                 if args.admission == "chunked" else ""))
        print(f"[serve] {len(res.completed)}/{args.requests} requests, "
              f"{res.steps} steps / {res.segments} segments / "
              f"{res.admission_rounds} admission rounds")
        print(f"[serve] {res.total_tokens} tokens in {res.wall_s:.2f} s "
              f"-> sustained {res.tok_s:.1f} tok/s; latency p50 "
              f"{res.latency_quantile(0.5)*1e3:.0f} ms p95 "
              f"{res.latency_quantile(0.95)*1e3:.0f} ms; TTFT p50 "
              f"{res.ttft_quantile(0.5)*1e3:.0f} ms p95 "
              f"{res.ttft_quantile(0.95)*1e3:.0f} ms; prefill-stall "
              f"{res.prefill_stall_frac:.0%}; peak page util {util:.0%}")
        if args.prefix_sharing:
            print(f"[serve] prefix sharing: {res.prefix_hits}/"
                  f"{len(res.completed)} hits "
                  f"({res.prefix_hit_rate:.0%}), "
                  f"{res.shared_prefix_tokens} prompt tokens adopted "
                  f"from shared pages ({res.prefill_tokens} prefilled)")
        if args.journal_dir is not None:
            n_rep = sum(1 for c in res.completed if c.replayed)
            print(f"[serve] journal: dir={args.journal_dir} "
                  f"recovered={res.recovered} "
                  f"snapshot_restore={res.restored_from_snapshot} "
                  f"replayed {n_rep} requests / {res.replayed_tokens} "
                  f"tokens, recovery {res.recovery_s*1e3:.0f} ms, "
                  f"snapshot {res.snapshot_bytes/2**20:.1f} MiB"
                  + (" [drained]" if res.drained else ""))
        if args.preemption or args.priority_classes > 1:
            print(f"[serve] preemptions: {res.preemptions}")
            for prio in sorted(res.class_summary(), reverse=True):
                d = res.class_summary()[prio]
                aging = (f", aging bound {d['aging_bound_steps']} steps"
                         if "aging_bound_steps" in d else "")
                print(f"[serve]   class {prio}: {d['n']} requests, "
                      f"{d['preemptions']} preemptions, p95 TTFT "
                      f"{d['p95_ttft_s']*1e3:.0f} ms, p95 latency "
                      f"{d['p95_latency_s']*1e3:.0f} ms, p95 admission "
                      f"delay {d['p95_admit_delay_steps']} steps, max "
                      f"{d['max_admit_delay_steps']}{aging}")
        return

    with mesh, use_hints(mesh):
        params = init_model(key, cfg)
        prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                     cfg.vocab_size)
        lengths = None
        if args.ragged:
            key, lk = jax.random.split(key)
            lengths = jax.random.randint(
                lk, (args.batch,), max(1, args.prompt_len // 2),
                args.prompt_len + 1)
        frontend = None
        if cfg.frontend_dim:
            frontend = jax.random.normal(
                key, (args.batch, cfg.n_frontend_tokens, cfg.frontend_dim),
                jnp.float32)
        key, sample_key = jax.random.split(key)
        res = generate(params, cfg, prompts, args.gen, frontend=frontend,
                       temperature=args.temperature, key=sample_key,
                       prompt_lengths=lengths, eos_id=args.eos_id,
                       paged=args.paged, page_size=args.page_size,
                       early_exit=args.eos_id is not None, loop=args.loop)

    print(f"[serve] arch={cfg.name} impl={cfg.attention_impl} "
          f"loop={args.loop}" + (" ragged" if args.ragged else "")
          + (" paged" if args.paged else ""))
    if lengths is not None:
        print(f"[serve] prompt lengths: {lengths.tolist()}")
    print(f"[serve] prefill {args.batch}x{args.prompt_len} tokens in "
          f"{res.prefill_s*1e3:.1f} ms")
    dispatches = 1 if args.loop == "fused" else res.decode_steps
    print(f"[serve] decoded {res.decode_steps} steps x{args.batch} "
          f"({res.n_decode_tokens} live tokens, {dispatches} device "
          f"dispatch{'es' if dispatches != 1 else ''}) in "
          f"{res.decode_s*1e3:.1f} ms ({res.decode_tok_s:.1f} tok/s)")
    print("[serve] sample:", res.tokens[0, :12].tolist())


if __name__ == "__main__":
    main()
