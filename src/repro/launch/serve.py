"""Serving launcher: batched prefill + decode with the ITA integer path.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
        --attention-impl ita --batch 4 --prompt-len 32 --gen 16

Demonstrates the production serving loop: quantized (int8) KV caches,
integer streaming-softmax attention at prefill, direct integer attention
at decode, continuous batch of requests.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_config
from repro.launch.hints import use_hints
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import init_caches, init_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--attention-impl", default="ita",
                    choices=["float", "ita", "ibert"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke,
                     attention_impl=args.attention_impl)
    mesh = make_host_mesh() if args.smoke else make_production_mesh()
    key = jax.random.PRNGKey(args.seed)

    with mesh, use_hints(mesh):
        params = init_model(key, cfg)
        prefill = jax.jit(make_prefill_step(cfg))
        decode = jax.jit(make_decode_step(cfg), donate_argnums=(2,))

        max_len = args.prompt_len + args.gen
        caches = init_caches(cfg, args.batch, max_len=max_len)
        prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                     cfg.vocab_size)
        frontend = None
        if cfg.frontend_dim:
            frontend = jax.random.normal(
                key, (args.batch, cfg.n_frontend_tokens, cfg.frontend_dim),
                jnp.float32)

        t0 = time.time()
        logits, caches = prefill(params, prompts, caches, frontend)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        jax.block_until_ready(tok)
        t_prefill = time.time() - t0

        out_tokens = [tok]
        t0 = time.time()
        for i in range(args.gen - 1):
            logits, caches = decode(params, tok, caches,
                                    jnp.asarray(args.prompt_len + i),
                                    frontend)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            out_tokens.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.time() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"[serve] arch={cfg.name} impl={cfg.attention_impl}")
    print(f"[serve] prefill {args.batch}x{args.prompt_len} tokens in "
          f"{t_prefill*1e3:.1f} ms")
    print(f"[serve] decoded {args.gen - 1} steps x{args.batch} in "
          f"{t_decode*1e3:.1f} ms "
          f"({(args.gen-1)*args.batch/max(t_decode,1e-9):.1f} tok/s)")
    print("[serve] sample:", gen[0, :12].tolist())


if __name__ == "__main__":
    main()
