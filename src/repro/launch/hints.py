"""Logical sharding hints for model code.

Model code never hard-codes mesh axes; it requests constraints through
logical roles (``batch``, ``seq``, ``heads``, ``kv_heads``, ``ff``).
The launcher installs the concrete mesh here (``use_hints``); without a
mesh every hint is a no-op, so smoke tests and single-device runs are
untouched. Divisibility is checked per call — a 28-head model on a
16-way model axis silently skips the heads hint and relies on the seq
hint instead (sequence-parallel attention).
"""

from __future__ import annotations

import contextlib
import threading

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

_STATE = threading.local()


def _mesh():
    return getattr(_STATE, "mesh", None)


@contextlib.contextmanager
def use_hints(mesh, parallelism: str = "tp_fsdp"):
    prev = (getattr(_STATE, "mesh", None), getattr(_STATE, "mode", "tp_fsdp"))
    _STATE.mesh = mesh
    _STATE.mode = parallelism
    try:
        yield
    finally:
        _STATE.mesh, _STATE.mode = prev


def _mode():
    return getattr(_STATE, "mode", "tp_fsdp")


def _batch_axes(mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _token_axes(mesh):
    """All axes token-level work parallelizes over (fsdp: + model)."""
    base = _batch_axes(mesh)
    return base + ("model",) if _mode() == "fsdp" else base


def _size(mesh, axes):
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


_ROLES = {
    "batch": lambda m: _batch_axes(m),
    "seq": lambda m: "model",
    "heads": lambda m: "model",
    "kv_heads": lambda m: "model",
    "ff": lambda m: "model",
    "vocab": lambda m: "model",
    "experts": lambda m: "model",
}


def constrain(x, *roles):
    """constrain(x, 'batch', None, 'heads', None) — roles per dim; any role
    that does not divide its dim is dropped."""
    mesh = _mesh()
    if mesh is None or x is None:
        return x
    if _mode() == "fsdp":
        # pure-DP: batch over every axis when divisible; otherwise batch
        # over (pod,data) with *sequence* over model (seq-DP fallback for
        # global batches smaller than the chip count, e.g. multi-pod).
        roles = tuple(r if r in ("batch", "seq") else None for r in roles)
        spec = []
        used_model = False
        for dim, role in zip(x.shape, roles, strict=False):
            if role == "batch":
                allax = _token_axes(mesh)
                if dim % _size(mesh, allax) == 0:
                    spec.append(allax)
                    used_model = True
                elif dim % _size(mesh, _batch_axes(mesh)) == 0:
                    spec.append(_batch_axes(mesh))
                else:
                    spec.append(None)
            elif role == "seq" and not used_model                     and dim % _size(mesh, "model") == 0:
                spec.append("model")
                used_model = True
            else:
                spec.append(None)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*spec)))
    spec = []
    for dim, role in zip(x.shape, roles, strict=False):
        if role is None:
            spec.append(None)
            continue
        axes = _ROLES[role](mesh)
        spec.append(axes if dim % _size(mesh, axes) == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def heads_shardable(n_heads: int) -> bool:
    mesh = _mesh()
    if mesh is None or _mode() == "fsdp":
        return mesh is not None and _mode() == "fsdp"  # skip seq-sharding too
    return n_heads % _size(mesh, "model") == 0


def num_data_shards() -> int:
    """Group count for MoE dispatch (1 when no mesh installed)."""
    mesh = _mesh()
    if mesh is None:
        return 1
    return _size(mesh, _token_axes(mesh))
