"""Sharding rules: Megatron-style tensor parallelism over ``model`` ×
ZeRO-3 parameter/optimizer sharding over ``data`` (and ``pod``) × data
parallelism for the batch — plus MoE expert parallelism and KV-cache
sharding (sequence-sharded when the batch axis can't be split, e.g. the
long_500k single-sequence decode).

Every rule validates divisibility and falls back to replication per axis,
so the same rules drive smoke configs (tiny dims) and the 90B production
configs.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, GetAttrKey

# column-parallel (in, out) -> (fsdp, model); row-parallel -> (model, fsdp)
_COL = {"wq", "wk", "wv", "w_gate", "w_up", "w_in", "w_gate_branch", "w_r",
        "w_k", "w_v", "w_g", "ddlerp_a", "w_lora_a", "router", "unembed",
        "frontend_proj"}
_ROW = {"wo", "w_down", "w_out", "w_o", "w_lora_b"}


def _axis_size(mesh, axes):
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fit(spec, shape, mesh):
    """Drop axes that don't divide the corresponding dim."""
    out = []
    for dim, ax in zip(shape, spec, strict=False):
        out.append(ax if ax is not None and dim % _axis_size(mesh, ax) == 0
                   else None)
    return tuple(out)


def _leaf_param_spec(path, leaf, mesh, parallelism="tp_fsdp"):
    names = [p.key for p in path if isinstance(p, DictKey)]
    name = names[-1] if names else ""
    fsdp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    if parallelism == "fsdp":
        fsdp = fsdp + ("model",)
    scanned = "groups" in names
    shape = leaf.shape[1:] if scanned else leaf.shape
    nd = len(shape)

    if nd == 0:
        base = ()
    elif name == "table":
        base = ("model", fsdp)
    elif nd == 3 and name in ("w_gate", "w_up", "w_down") \
            and parallelism == "fsdp":                 # experts, pure ZeRO-3
        base = (None, fsdp, None)
    elif nd == 3 and name in ("w_gate", "w_up"):       # MoE experts (E,d,f)
        e = shape[0]
        base = (("model", fsdp, None) if e % _axis_size(mesh, "model") == 0
                else (None, fsdp, "model"))
    elif nd == 3 and name == "w_down":                 # MoE experts (E,f,d)
        e = shape[0]
        base = (("model", None, fsdp) if e % _axis_size(mesh, "model") == 0
                else (None, "model", fsdp))
    elif nd == 3:                                      # blockdiag/LoRA stacks
        base = (None, None, "model")
    elif name in _COL and nd == 2:
        base = (fsdp, "model")
    elif name in _ROW and nd == 2:
        base = ("model", fsdp)
    elif name == "conv_w" or name == "mu":
        base = (None, "model")
    elif nd == 1:
        base = ("model",)
    else:                                              # norms etc.
        base = tuple(None for _ in shape)

    if parallelism == "fsdp":
        # pure ZeRO-3: replace TP dims with storage-only sharding
        base = tuple(fsdp if ax == "model" else ax for ax in base)
        # avoid double use of an axis in one spec
        seen = set()
        clean = []
        for ax in base:
            axs = (ax,) if isinstance(ax, str) else (ax or ())
            if any(a in seen for a in axs):
                clean.append(None)
            else:
                seen.update(axs)
                clean.append(ax)
        base = tuple(clean)
    base = _fit(base, shape, mesh)
    return P(*(((None,) + base) if scanned else base))


def param_shardings(params_shape, mesh, parallelism="tp_fsdp"):
    """pytree of NamedShardings matching a params (shape-)pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, _leaf_param_spec(path, leaf, mesh, parallelism)),
        params_shape)


def opt_state_shardings(params_shape, mesh, parallelism="tp_fsdp",
                        has_master=False):
    from repro.optim.optimizer import OptState
    ps = param_shardings(params_shape, mesh, parallelism)
    return OptState(mu=ps, nu=ps, master=ps if has_master else None,
                    count=NamedSharding(mesh, P()))


def _dp_axes(mesh, parallelism):
    bax = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    return bax + ("model",) if parallelism == "fsdp" else bax


def batch_shardings(batch_shape, mesh, parallelism="tp_fsdp"):
    bax = ("pod", "data") if "pod" in mesh.axis_names else ("data",)

    def spec(path, leaf):
        if parallelism == "fsdp":
            allax = bax + ("model",)
            if leaf.shape[0] % _axis_size(mesh, allax) == 0:
                s = (allax,) + (None,) * (leaf.ndim - 1)
            elif leaf.ndim >= 2:     # seq-DP fallback (small global batch)
                s = (bax, "model") + (None,) * (leaf.ndim - 2)
            else:
                s = (bax,) + (None,) * (leaf.ndim - 1)
        else:
            s = (bax,) + (None,) * (leaf.ndim - 1)
        return NamedSharding(mesh, P(*_fit(s, leaf.shape, mesh)))

    return jax.tree_util.tree_map_with_path(spec, batch_shape)


def _leaf_cache_spec(path, leaf, batch, mesh):
    """Cache leaves carry a leading scan-period axis; dispatch by name.
    Caches mix dict nodes and registered-dataclass nodes (KVCacheState),
    so both DictKey and GetAttrKey path entries name leaves."""
    names = [p.key if isinstance(p, DictKey) else p.name
             for p in path if isinstance(p, (DictKey, GetAttrKey))]
    name = names[-1] if names else ""
    bax = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    shape = leaf.shape
    nd = len(shape)
    # page_table/free_stack/free_top/ref_count: paged-pool bookkeeping —
    # tiny int32 vectors the on-device allocator indexes globally;
    # replicate.
    if nd <= 1 or name in ("pos", "k_scale", "v_scale", "page_table",
                           "free_stack", "free_top", "ref_count"):
        return P()
    b_ok = nd >= 2 and shape[1] == batch \
        and batch % _axis_size(mesh, bax) == 0
    b_ax = bax if b_ok else None
    m = _axis_size(mesh, "model")
    if name in ("k", "v", "k8", "v8"):                 # (P,B,S,G,hd)
        seq_ax = None if b_ok else "data"              # seq-shard if B small
        g_ax = "model" if shape[3] % m == 0 else None
        hd_ax = None if g_ax else ("model" if shape[4] % m == 0 else None)
        return P(None, b_ax, seq_ax, g_ax, hd_ax)
    if name == "s":                                    # rwkv (P,B,H,dh,dh)
        return P(None, b_ax, "model" if shape[2] % m == 0 else None,
                 None, None)
    # h / shift / conv: shard the channel (last) dim over model
    last = "model" if shape[-1] % m == 0 else None
    return P(None, b_ax, *((None,) * (nd - 3)), last)


def cache_shardings(cache_shape, batch, mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, _leaf_cache_spec(path, leaf, batch, mesh)), cache_shape)


def logits_sharding(mesh, batch, vocab, parallelism="tp_fsdp"):
    bax = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    b_ax = bax if batch % _axis_size(mesh, bax) == 0 else None
    v_ax = None if parallelism == "fsdp" else (
        "model" if vocab % _axis_size(mesh, "model") == 0 else None)
    return NamedSharding(mesh, P(b_ax, None, v_ax))


def replicated(mesh):
    return NamedSharding(mesh, P())
