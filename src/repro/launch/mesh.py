"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the leading ``pod``
axis is pure data parallelism whose gradient all-reduce rides DCN — the
axis generalizes to any pod count (1000+ node posture: grow ``pod``).

Defined as functions (never module-level constants) so importing this
module does not touch jax device state; the dry-run forces 512 host
devices *before* any jax import (see dryrun.py).
"""

from __future__ import annotations

import jax

DATA_AXIS = 16
MODEL_AXIS = 16


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, DATA_AXIS, MODEL_AXIS) if multi_pod else (DATA_AXIS, MODEL_AXIS)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int | None = None):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    m = model_axis or (2 if n % 2 == 0 and n > 1 else 1)
    return jax.make_mesh((n // m, m), ("data", "model"))


def fsdp_axes(mesh) -> tuple:
    """The axes params/optimizer state are ZeRO-3 sharded over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def batch_axes(mesh) -> tuple:
    return fsdp_axes(mesh)
