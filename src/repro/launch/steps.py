"""Jitted train / prefill / decode steps with production shardings, plus
``input_specs`` (ShapeDtypeStruct stand-ins — weak-type-correct, shardable,
no device allocation) used by the dry-run and launchers."""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.launch import sharding as SH
from repro.models import forward, init_caches, init_model, loss_fn
from repro.optim.optimizer import AdamWConfig, adamw_update, init_opt_state


# ---------------------------------------------------------------------------
# Shape stand-ins
# ---------------------------------------------------------------------------

def params_shape(cfg):
    return jax.eval_shape(functools.partial(init_model, cfg=cfg),
                          jax.random.PRNGKey(0))


def opt_state_shape(cfg):
    return jax.eval_shape(
        lambda: init_opt_state(
            jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                         params_shape(cfg))))


def caches_shape(cfg, batch, max_len):
    return jax.eval_shape(
        functools.partial(init_caches, cfg, batch, max_len))


def input_specs(cfg, shape) -> dict[str, Any]:
    """ShapeDtypeStructs for every model input of an (arch × shape) cell."""
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        batch = {"tokens": sds((b, s + 1), jnp.int32)}
        if cfg.frontend_dim:
            batch["frontend"] = sds(
                (b, cfg.n_frontend_tokens, cfg.frontend_dim), jnp.float32)
        return {"batch": batch}
    if shape.kind == "prefill":
        out = {"tokens": sds((b, s), jnp.int32),
               "caches": caches_shape(cfg, b, s)}
        if cfg.frontend_dim:
            out["frontend"] = sds(
                (b, cfg.n_frontend_tokens, cfg.frontend_dim), jnp.float32)
        return out
    # decode: one new token per sequence against a seq_len KV cache, at
    # per-sequence positions (ragged-capable — the production shape)
    out = {"tokens": sds((b, 1), jnp.int32),
           "caches": caches_shape(cfg, b, s),
           "pos0": sds((b,), jnp.int32)}
    if cfg.frontend_dim:
        out["frontend"] = sds(
            (b, cfg.n_frontend_tokens, cfg.frontend_dim), jnp.float32)
    return out


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------

def make_train_step(cfg, opt_cfg: AdamWConfig):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, cfg)
        params, opt_state, stats = adamw_update(grads, opt_state, params,
                                                opt_cfg)
        return params, opt_state, {"loss": loss, **metrics, **stats}
    return train_step


def make_prefill_step(cfg):
    def prefill_step(params, tokens, caches, frontend=None, lengths=None):
        logits, caches, _ = forward(params, tokens, cfg, mode="prefill",
                                    frontend=frontend, caches=caches,
                                    lengths=lengths)
        if lengths is None:
            return logits[:, -1:], caches
        # ragged: each sequence's next-token logits sit at its own last
        # valid position of the right-padded prompt
        idx = (jnp.asarray(lengths, jnp.int32) - 1)[:, None, None]
        return jnp.take_along_axis(logits, idx, axis=1), caches
    return prefill_step


def make_decode_step(cfg):
    def decode_step(params, tokens, caches, pos0, frontend=None, live=None):
        logits, caches, _ = forward(params, tokens, cfg, mode="decode",
                                    frontend=frontend, caches=caches,
                                    pos0=pos0, live=live)
        return logits, caches
    return decode_step


# ---------------------------------------------------------------------------
# Fused generation loop (decode without per-token host dispatch)
# ---------------------------------------------------------------------------

def sample_token(logits, key, temperature, *, sample: bool):
    """Next token from (B, 1, V) logits: greedy argmax or temperature
    sampling. Returns ``(tok (B, 1) int32, new_key)`` — the key is split
    exactly once per sampled step so the fused scan loop and the per-step
    host loop consume identical PRNG streams (bit-identical outputs)."""
    if not sample:
        return jnp.argmax(logits, -1).astype(jnp.int32), key
    key, sub = jax.random.split(key)
    tok = jax.random.categorical(sub, logits / temperature, axis=-1)
    return tok.astype(jnp.int32), key


def advance_step(logits, key, temperature, done, n, *, sample: bool,
                 eos_id: int | None, pad_id: int):
    """Per-step tail shared by the fused scan body and the stepwise host
    loop: sample the next token, pin finished sequences to ``pad_id``,
    count live decode tokens into ``n`` and fold new EOS hits into
    ``done``. Both loops calling this one function is what makes their
    documented bit-parity structural rather than merely test-caught.
    Returns ``(tok (B, 1), new_key, done, n)``."""
    nxt, key = sample_token(logits, key, temperature, sample=sample)
    if eos_id is not None:
        nxt = jnp.where(done[:, None], pad_id, nxt)
        n = n + jnp.sum(~done).astype(jnp.int32)
        done = done | (nxt[:, 0] == eos_id)
    else:
        n = n + nxt.shape[0]
    return nxt, key, done, n


def make_generate_loop(cfg, *, gen: int, sample: bool, eos_id: int | None,
                       pad_id: int, early_exit: bool):
    """One jitted on-device generation loop: ``gen - 1`` decode steps as a
    single dispatch instead of ``gen - 1`` host round-trips.

    The carry ``(caches, tok, pos, key, done, n)`` is scanned over decode
    steps: each step runs the decode forward, samples on-device (PRNG key
    threaded through the carry), advances the per-sequence positions, and
    — when ``eos_id`` is set — pins finished sequences to ``pad_id``
    while counting only live ones into ``n`` (the honest tok/s
    denominator). ``early_exit`` swaps the scan for a ``lax.while_loop``
    that stops as soon as every sequence has emitted EOS (same outputs:
    the steps it skips would have produced only pads).

    Returns ``loop(params, tok0, caches, pos0, key, temperature,
    frontend) -> (tokens (B, gen-1), n_decode_tokens, steps_run,
    caches)`` — ``steps_run < gen-1`` when ``early_exit`` fired; jit
    with ``donate_argnums=(2,)`` so the caches update in place.
    """
    decode = make_decode_step(cfg)
    steps = gen - 1

    def loop(params, tok0, caches, pos0, key, temperature, frontend=None):
        b = tok0.shape[0]
        done0 = (tok0[:, 0] == eos_id) if eos_id is not None \
            else jnp.zeros((b,), jnp.bool_)
        key = jax.random.PRNGKey(0) if key is None else key
        carry0 = (caches, tok0, jnp.asarray(pos0, jnp.int32), key, done0,
                  jnp.zeros((), jnp.int32))

        def step(carry):
            caches, tok, pos, key, done, n = carry
            logits, caches = decode(params, tok, caches, pos, frontend)
            nxt, key, done, n = advance_step(
                logits, key, temperature, done, n, sample=sample,
                eos_id=eos_id, pad_id=pad_id)
            return (caches, nxt, pos + 1, key, done, n)

        if early_exit:
            out0 = jnp.full((b, steps), pad_id, jnp.int32)

            def cond(st):
                i, carry = st[0], st[1]
                return (i < steps) & ~jnp.all(carry[4])

            def body(st):
                i, carry, out = st
                carry = step(carry)
                return (i + 1, carry, out.at[:, i].set(carry[1][:, 0]))

            i, carry, out = jax.lax.while_loop(
                cond, body, (jnp.zeros((), jnp.int32), carry0, out0))
            return out, carry[5], i, carry[0]

        def body(carry, _):
            carry = step(carry)
            return carry, carry[1][:, 0]

        carry, toks = jax.lax.scan(body, carry0, None, length=steps)
        return toks.T, carry[5], jnp.asarray(steps, jnp.int32), carry[0]

    return loop


# ---------------------------------------------------------------------------
# Continuous-batching decode segment
# ---------------------------------------------------------------------------

def make_serve_segment(cfg, *, segment: int, sample: bool,
                       eos_id: int | None, pad_id: int):
    """One fused continuous-batching decode segment: a ``lax.scan`` of
    ``segment`` steps over a fixed-slot batch, between two host admission
    points.

    Differences from ``make_generate_loop``: the carry tracks a per-slot
    ``done`` mask *given by the host* (slots the scheduler left empty
    start done) and a per-slot remaining-budget vector ``rem`` (each
    request decodes its own ``gen``); every step passes ``live = ~done``
    into the decode forward so finished/empty slots neither write their
    KV pages nor advance positions — which is what lets the host release
    a finished slot's pages at the segment boundary and hand them to a
    queued request without the scan ever touching freed memory.

    Returns ``seg(params, tok, caches, pos, key, temperature, done, rem,
    frontend) -> (tokens (B, segment), caches, tok, pos, key, done, rem,
    n_live)``; jit with ``donate_argnums=(2,)``.
    """
    decode = make_decode_step(cfg)

    def seg(params, tok, caches, pos, key, temperature, done, rem,
            frontend=None):
        def body(carry, _):
            caches, tok, pos, key, done, rem, n = carry
            live = ~done
            logits, caches = decode(params, tok, caches, pos, frontend,
                                    live)
            nxt, key = sample_token(logits, key, temperature, sample=sample)
            nxt = jnp.where(done[:, None], pad_id, nxt)
            n = n + jnp.sum(live).astype(jnp.int32)
            rem = rem - live.astype(jnp.int32)
            done = done | (rem <= 0)
            if eos_id is not None:
                done = done | (nxt[:, 0] == eos_id)
            pos = pos + live.astype(jnp.int32)
            return (caches, nxt, pos, key, done, rem, n), nxt[:, 0]

        carry0 = (caches, tok, jnp.asarray(pos, jnp.int32), key, done, rem,
                  jnp.zeros((), jnp.int32))
        carry, toks = jax.lax.scan(body, carry0, None, length=segment)
        caches, tok, pos, key, done, rem, n = carry
        return toks.T, caches, tok, pos, key, done, rem, n

    return seg


# ---------------------------------------------------------------------------
# Jit with shardings
# ---------------------------------------------------------------------------

def jit_train_step(cfg, mesh, opt_cfg: AdamWConfig):
    pshape = params_shape(cfg)
    p_sh = SH.param_shardings(pshape, mesh)
    o_sh = SH.opt_state_shardings(pshape, mesh)
    rep = SH.replicated(mesh)
    dummy_batch = input_specs(cfg, _TrainShape)["batch"]
    step = make_train_step(cfg, opt_cfg)
    return jax.jit(
        step,
        in_shardings=(p_sh, o_sh, None),
        out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1),
    ), p_sh, o_sh


class _TrainShape:                      # minimal duck-typed shape for jit
    kind = "train"
    global_batch = 8
    seq_len = 128


def lower_cell(cfg, shape, mesh, opt_cfg: AdamWConfig | None = None):
    """Lower (not compile) the step for one (arch × shape × mesh) cell,
    with all in/out shardings pinned. Returns the jax ``Lowered``."""
    from repro.launch.hints import use_hints
    opt_cfg = opt_cfg or AdamWConfig()
    par = getattr(cfg, "parallelism", "tp_fsdp")
    pshape = params_shape(cfg)
    p_sh = SH.param_shardings(pshape, mesh, par)
    specs = input_specs(cfg, shape)
    rep = SH.replicated(mesh)

    with mesh, use_hints(mesh, par):
        if shape.kind == "train":
            o_sh = SH.opt_state_shardings(
                pshape, mesh, par,
                has_master=cfg.param_dtype == "bfloat16")
            b_sh = SH.batch_shardings(specs["batch"], mesh, par)
            step = make_train_step(cfg, opt_cfg)
            jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                             out_shardings=(p_sh, o_sh, None),
                             donate_argnums=(0, 1))
            return jitted.lower(pshape, opt_state_shape(cfg), specs["batch"])

        c_sh = SH.cache_shardings(specs["caches"], shape.global_batch, mesh)
        lg_sh = SH.logits_sharding(mesh, shape.global_batch, cfg.vocab_size,
                                   par)
        if shape.kind == "prefill":
            b_sh = SH.batch_shardings(
                {"tokens": specs["tokens"]}, mesh, par)["tokens"]
            f_sh = (SH.batch_shardings({"f": specs["frontend"]}, mesh,
                                       par)["f"]
                    if "frontend" in specs else None)
            step = make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh, c_sh, f_sh),
                             out_shardings=(lg_sh, c_sh),
                             donate_argnums=(2,))
            return jitted.lower(pshape, specs["tokens"], specs["caches"],
                                specs.get("frontend"))

        b_sh = SH.batch_shardings({"tokens": specs["tokens"]},
                                  mesh, par)["tokens"]
        f_sh = (SH.batch_shardings({"f": specs["frontend"]}, mesh, par)["f"]
                if "frontend" in specs else None)
        step = make_decode_step(cfg)
        jitted = jax.jit(step, in_shardings=(p_sh, b_sh, c_sh, rep, f_sh),
                         out_shardings=(lg_sh, c_sh), donate_argnums=(2,))
        return jitted.lower(pshape, specs["tokens"], specs["caches"],
                            specs["pos0"], specs.get("frontend"))
