"""Jitted train / prefill / decode steps with production shardings, plus
``input_specs`` (ShapeDtypeStruct stand-ins — weak-type-correct, shardable,
no device allocation) used by the dry-run and launchers."""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.launch import sharding as SH
from repro.models import forward, init_caches, init_model, loss_fn
from repro.models.layers import unembed
from repro.optim.optimizer import AdamWConfig, adamw_update, init_opt_state


# ---------------------------------------------------------------------------
# Shape stand-ins
# ---------------------------------------------------------------------------

def params_shape(cfg):
    return jax.eval_shape(functools.partial(init_model, cfg=cfg),
                          jax.random.PRNGKey(0))


def opt_state_shape(cfg):
    return jax.eval_shape(
        lambda: init_opt_state(
            jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                         params_shape(cfg))))


def caches_shape(cfg, batch, max_len):
    return jax.eval_shape(
        functools.partial(init_caches, cfg, batch, max_len))


def input_specs(cfg, shape) -> dict[str, Any]:
    """ShapeDtypeStructs for every model input of an (arch × shape) cell."""
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        batch = {"tokens": sds((b, s + 1), jnp.int32)}
        if cfg.frontend_dim:
            batch["frontend"] = sds(
                (b, cfg.n_frontend_tokens, cfg.frontend_dim), jnp.float32)
        return {"batch": batch}
    if shape.kind == "prefill":
        out = {"tokens": sds((b, s), jnp.int32),
               "caches": caches_shape(cfg, b, s)}
        if cfg.frontend_dim:
            out["frontend"] = sds(
                (b, cfg.n_frontend_tokens, cfg.frontend_dim), jnp.float32)
        return out
    # decode: one new token per sequence against a seq_len KV cache, at
    # per-sequence positions (ragged-capable — the production shape)
    out = {"tokens": sds((b, 1), jnp.int32),
           "caches": caches_shape(cfg, b, s),
           "pos0": sds((b,), jnp.int32)}
    if cfg.frontend_dim:
        out["frontend"] = sds(
            (b, cfg.n_frontend_tokens, cfg.frontend_dim), jnp.float32)
    return out


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------

def make_train_step(cfg, opt_cfg: AdamWConfig):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, cfg)
        params, opt_state, stats = adamw_update(grads, opt_state, params,
                                                opt_cfg)
        return params, opt_state, {"loss": loss, **metrics, **stats}
    return train_step


def make_prefill_step(cfg):
    def prefill_step(params, tokens, caches, frontend=None, lengths=None):
        logits, caches, _ = forward(params, tokens, cfg, mode="prefill",
                                    frontend=frontend, caches=caches,
                                    lengths=lengths)
        if lengths is None:
            return logits[:, -1:], caches
        # ragged: each sequence's next-token logits sit at its own last
        # valid position of the right-padded prompt
        idx = (jnp.asarray(lengths, jnp.int32) - 1)[:, None, None]
        return jnp.take_along_axis(logits, idx, axis=1), caches
    return prefill_step


def make_decode_step(cfg):
    def decode_step(params, tokens, caches, pos0, frontend=None, live=None):
        logits, caches, _ = forward(params, tokens, cfg, mode="decode",
                                    frontend=frontend, caches=caches,
                                    pos0=pos0, live=live)
        return logits, caches
    return decode_step


# ---------------------------------------------------------------------------
# Fused generation loop (decode without per-token host dispatch)
# ---------------------------------------------------------------------------

def sample_token(logits, key, temperature, *, sample: bool):
    """Next token from (B, 1, V) logits: greedy argmax or temperature
    sampling. Returns ``(tok (B, 1) int32, new_key)`` — the key is split
    exactly once per sampled step so the fused scan loop and the per-step
    host loop consume identical PRNG streams (bit-identical outputs)."""
    if not sample:
        return jnp.argmax(logits, -1).astype(jnp.int32), key
    key, sub = jax.random.split(key)
    tok = jax.random.categorical(sub, logits / temperature, axis=-1)
    return tok.astype(jnp.int32), key


def advance_step(logits, key, temperature, done, n, *, sample: bool,
                 eos_id: int | None, pad_id: int):
    """Per-step tail shared by the fused scan body and the stepwise host
    loop: sample the next token, pin finished sequences to ``pad_id``,
    count live decode tokens into ``n`` and fold new EOS hits into
    ``done``. Both loops calling this one function is what makes their
    documented bit-parity structural rather than merely test-caught.
    Returns ``(tok (B, 1), new_key, done, n)``."""
    nxt, key = sample_token(logits, key, temperature, sample=sample)
    if eos_id is not None:
        nxt = jnp.where(done[:, None], pad_id, nxt)
        n = n + jnp.sum(~done).astype(jnp.int32)
        done = done | (nxt[:, 0] == eos_id)
    else:
        n = n + nxt.shape[0]
    return nxt, key, done, n


def make_generate_loop(cfg, *, gen: int, sample: bool, eos_id: int | None,
                       pad_id: int, early_exit: bool):
    """One jitted on-device generation loop: ``gen - 1`` decode steps as a
    single dispatch instead of ``gen - 1`` host round-trips.

    The carry ``(caches, tok, pos, key, done, n)`` is scanned over decode
    steps: each step runs the decode forward, samples on-device (PRNG key
    threaded through the carry), advances the per-sequence positions, and
    — when ``eos_id`` is set — pins finished sequences to ``pad_id``
    while counting only live ones into ``n`` (the honest tok/s
    denominator). ``early_exit`` swaps the scan for a ``lax.while_loop``
    that stops as soon as every sequence has emitted EOS (same outputs:
    the steps it skips would have produced only pads).

    Returns ``loop(params, tok0, caches, pos0, key, temperature,
    frontend) -> (tokens (B, gen-1), n_decode_tokens, steps_run,
    caches)`` — ``steps_run < gen-1`` when ``early_exit`` fired; jit
    with ``donate_argnums=(2,)`` so the caches update in place.
    """
    decode = make_decode_step(cfg)
    steps = gen - 1

    def loop(params, tok0, caches, pos0, key, temperature, frontend=None):
        b = tok0.shape[0]
        done0 = (tok0[:, 0] == eos_id) if eos_id is not None \
            else jnp.zeros((b,), jnp.bool_)
        key = jax.random.PRNGKey(0) if key is None else key
        carry0 = (caches, tok0, jnp.asarray(pos0, jnp.int32), key, done0,
                  jnp.zeros((), jnp.int32))

        def step(carry):
            caches, tok, pos, key, done, n = carry
            logits, caches = decode(params, tok, caches, pos, frontend)
            nxt, key, done, n = advance_step(
                logits, key, temperature, done, n, sample=sample,
                eos_id=eos_id, pad_id=pad_id)
            return (caches, nxt, pos + 1, key, done, n)

        if early_exit:
            out0 = jnp.full((b, steps), pad_id, jnp.int32)

            def cond(st):
                i, carry = st[0], st[1]
                return (i < steps) & ~jnp.all(carry[4])

            def body(st):
                i, carry, out = st
                carry = step(carry)
                return (i + 1, carry, out.at[:, i].set(carry[1][:, 0]))

            i, carry, out = jax.lax.while_loop(
                cond, body, (jnp.zeros((), jnp.int32), carry0, out0))
            return out, carry[5], i, carry[0]

        def body(carry, _):
            carry = step(carry)
            return carry, carry[1][:, 0]

        carry, toks = jax.lax.scan(body, carry0, None, length=steps)
        return toks.T, carry[5], jnp.asarray(steps, jnp.int32), carry[0]

    return loop


# ---------------------------------------------------------------------------
# Continuous-batching serve segments (pure decode + mixed chunked prefill)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServeSlotState:
    """Per-slot device state of the continuous-batching serve loop.

    One fixed-width pytree the fused segments carry and the (tiny)
    admission dispatch updates — admission is *just* this state write
    plus the host's page reservation: prompt token ids are enqueued here
    and prefilled chunk-by-chunk inside the segments (``cursor`` <
    ``plen`` marks the prefill phase), so there is no stop-the-world
    prompt dispatch and no ring-scratch bytes-copy on the chunked path.
    A prefix-sharing admit starts ``cursor``/``pos`` at the shared token
    count instead of 0 (the leading prompt pages were adopted from the
    pool, never re-prefilled); the mixed segment body needs no change —
    it simply sees fewer prompt tokens left. ``keys`` is a per-slot PRNG
    stream (``fold_in`` of the serve key by request id), making sampled
    outputs independent of admission interleaving. ``prio`` is the
    slot's SLO class (higher = more urgent): it orders the mixed body's
    prompt-chunk grants, so under budget contention high-priority
    prefills finish first. ``pgen`` is the slot's preemption generation
    — bumped by every ``preempt_rows`` so host-side readbacks can tell a
    re-admitted slot from the victim it replaced."""

    tok: Any                  # (B, 1) int32 — last sampled token
    pos: Any                  # (B,) int32 — stream position (cache pos)
    keys: Any                 # (B, 2) uint32 — per-slot PRNG streams
    done: Any                 # (B,) bool — finished / empty slots
    rem: Any                  # (B,) int32 — tokens left to emit
    cursor: Any               # (B,) int32 — prompt tokens prefilled so far
    plen: Any                 # (B,) int32 — prompt length
    prompt_buf: Any           # (B, prompt_pad) int32 — queued prompt ids
    prio: Any                 # (B,) int32 — SLO class (higher = urgent)
    pgen: Any                 # (B,) int32 — preemption generation counter

    @classmethod
    def init(cls, slots: int, prompt_pad: int, key=None) -> "ServeSlotState":
        key = jax.random.PRNGKey(0) if key is None else key
        return cls(
            tok=jnp.zeros((slots, 1), jnp.int32),
            pos=jnp.zeros((slots,), jnp.int32),
            keys=fold_keys(key, jnp.arange(slots, dtype=jnp.int32)),
            done=jnp.ones((slots,), jnp.bool_),
            rem=jnp.zeros((slots,), jnp.int32),
            cursor=jnp.zeros((slots,), jnp.int32),
            plen=jnp.zeros((slots,), jnp.int32),
            prompt_buf=jnp.zeros((slots, max(prompt_pad, 1)), jnp.int32),
            prio=jnp.zeros((slots,), jnp.int32),
            pgen=jnp.zeros((slots,), jnp.int32))


jax.tree_util.register_dataclass(
    ServeSlotState,
    data_fields=("tok", "pos", "keys", "done", "rem", "cursor", "plen",
                 "prompt_buf", "prio", "pgen"),
    meta_fields=())


def aged_priority(prio: int, waited: int, aging_steps: int | None,
                  max_class: int) -> int:
    """Starvation aging (host scheduler helper): a waiting request's
    effective SLO class grows by one every ``aging_steps`` virtual steps,
    capped at ``max_class + 1`` — one above the trace's highest real
    class, so a fully aged request outranks *every* fresh arrival but
    capped requests tie with each other (FIFO within the cap) and can
    never be preemption victims of one another. The cap is what bounds
    the worst-case admission delay: a class-``c`` request reaches the
    cap after ``aging_steps * (max_class + 1 - c)`` steps of waiting
    (``ServeResult.class_summary()['aging_bound_steps']``). ``None`` or
    non-positive ``aging_steps`` disables aging (identity on ``prio``)."""
    if aging_steps is None or aging_steps <= 0:
        return prio
    return min(prio + max(int(waited), 0) // int(aging_steps),
               max_class + 1)


@jax.jit
def fold_keys(key, ids):
    """One PRNG stream per id: ``fold_in(key, ids[i])`` — request-id
    derived streams make each served request's draws a function of its
    own id, not of admission interleaving."""
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(
        jnp.asarray(ids, jnp.int32))


def admit_rows(state, slot_ids):
    """OOB-drop row indices for a fixed-width admission batch (padding
    rows carry slot_id -1 and drop out of every scatter)."""
    return jnp.where(slot_ids >= 0, slot_ids, state.done.shape[0])


@functools.partial(jax.jit, donate_argnums=(0,))
def admit_chunked(state, slot_ids, prompts, lengths, gens, req_keys,
                  shared=None, prios=None):
    """Chunked admission is *only* this state write (plus the host's page
    reservation): enqueue the prompt token ids and arm the slot's phase
    state — the segments prefill chunk-by-chunk, page-native. No prompt
    forward, no ring scratch, no bytes-copy. ``shared`` (n,) int32 is the
    per-row count of prompt tokens already covered by adopted prefix
    pages (``PagedKVState.adopt_prefix`` ran in the same admission
    round): ``cursor`` and ``pos`` start there, so chunked prefill picks
    up at the first unshared token and the skipped tokens are never
    forwarded at all. ``prios`` (n,) int32 sets the slot's SLO class
    (``None`` = class 0 — the write still happens, so a slot freed by a
    high-priority victim never leaks its stale class)."""
    rows = admit_rows(state, slot_ids)
    start = jnp.zeros_like(lengths) if shared is None \
        else jnp.asarray(shared, jnp.int32)
    prio = jnp.zeros_like(lengths) if prios is None \
        else jnp.asarray(prios, jnp.int32)
    return dataclasses.replace(
        state,
        prompt_buf=state.prompt_buf.at[rows].set(prompts, mode="drop"),
        plen=state.plen.at[rows].set(lengths, mode="drop"),
        cursor=state.cursor.at[rows].set(start, mode="drop"),
        pos=state.pos.at[rows].set(start, mode="drop"),
        tok=state.tok.at[rows].set(0, mode="drop"),
        done=state.done.at[rows].set(False, mode="drop"),
        rem=state.rem.at[rows].set(gens, mode="drop"),
        keys=state.keys.at[rows].set(req_keys, mode="drop"),
        prio=state.prio.at[rows].set(prio, mode="drop"))


@functools.partial(jax.jit, donate_argnums=(0,))
def admit_stall(state, slot_ids, lengths, tok0, new_done, new_rem,
                req_keys, prios=None):
    """Stall-mode admission state write, after the stop-the-world prefill
    sampled ``tok0``: the slot enters directly in the decode phase
    (``cursor == plen``)."""
    rows = admit_rows(state, slot_ids)
    prio = jnp.zeros_like(lengths) if prios is None \
        else jnp.asarray(prios, jnp.int32)
    return dataclasses.replace(
        state,
        tok=state.tok.at[rows].set(tok0, mode="drop"),
        pos=state.pos.at[rows].set(lengths, mode="drop"),
        plen=state.plen.at[rows].set(lengths, mode="drop"),
        cursor=state.cursor.at[rows].set(lengths, mode="drop"),
        done=state.done.at[rows].set(new_done, mode="drop"),
        rem=state.rem.at[rows].set(new_rem, mode="drop"),
        keys=state.keys.at[rows].set(req_keys, mode="drop"),
        prio=state.prio.at[rows].set(prio, mode="drop"))


@functools.partial(jax.jit, donate_argnums=(0,))
def preempt_rows(state, mask):
    """One-dispatch victim release: evict every slot in ``mask`` (B,)
    bool from the batch. The victims' phase state zeroes and ``done``
    raises — the next segment's bodies mask them out exactly like
    finished slots, so their (host-released) pages are never touched —
    while ``pgen`` bumps so readbacks attribute in-flight segment output
    to the old occupant, not a future re-admission. ``keys`` is left
    as-is: the host snapshots the victim's stream *before* preempting
    and restores it at re-admission, which is what makes a resumed
    sampled request's draws bit-identical to never having been
    preempted."""
    mask = jnp.asarray(mask, jnp.bool_)
    keep = ~mask
    zero = jnp.zeros_like(state.pos)
    return dataclasses.replace(
        state,
        tok=jnp.where(mask[:, None], 0, state.tok),
        pos=jnp.where(keep, state.pos, zero),
        done=state.done | mask,
        rem=jnp.where(keep, state.rem, zero),
        cursor=jnp.where(keep, state.cursor, zero),
        plen=jnp.where(keep, state.plen, zero),
        prio=jnp.where(keep, state.prio, zero),
        pgen=state.pgen + mask.astype(jnp.int32))


def advance_step_rows(logits, keys, temperature, done, rem, n, active, *,
                      sample: bool, eos_id: int | None, pad_id: int):
    """Per-row serve-step tail shared by the pure-decode and mixed segment
    bodies — the per-slot-PRNG analogue of ``advance_step``: sample each
    ``active`` row from its own stream, pad everything else, count active
    emissions into ``n``, charge them against ``rem`` and fold budget
    exhaustion / EOS into ``done``. Both bodies calling this one function
    keeps their emission bookkeeping structurally identical (the
    chunked ≡ stall bit-parity guarantee), not merely test-caught.
    Returns ``(tok (B, 1), keys, done, rem, n)``."""
    nxt, keys = sample_token_rows(logits, keys, temperature, sample=sample,
                                  advance=active)
    nxt = jnp.where(active[:, None], nxt, pad_id)
    n = n + jnp.sum(active).astype(jnp.int32)
    rem = rem - active.astype(jnp.int32)
    done = done | (active & (rem <= 0))
    if eos_id is not None:
        done = done | (active & (nxt[:, 0] == eos_id))
    return nxt, keys, done, rem, n


def sample_token_rows(logits, keys, temperature, *, sample: bool,
                      advance=None):
    """Per-row ``sample_token``: row ``b`` draws from its own stream
    ``keys[b]`` with the exact solo-generate split schedule (``key, sub =
    split(key)`` once per sampled token), so a request served through any
    admission interleaving consumes the same stream as generating it
    alone with ``fold_in``-derived keys. ``advance`` (B,) masks which
    rows actually consume randomness this step (rows mid-prompt draw
    nothing). Greedy (``sample=False``) is a plain argmax."""
    if not sample:
        return jnp.argmax(logits, -1).astype(jnp.int32), keys
    pair = jax.vmap(jax.random.split)(keys)          # (B, 2, key)
    subs = pair[:, 1]
    tok = jax.vmap(
        lambda s, lg: jax.random.categorical(s, lg / temperature, axis=-1)
    )(subs, logits)
    new_keys = pair[:, 0]
    if advance is not None:
        new_keys = jnp.where(advance[:, None], new_keys, keys)
    return tok.astype(jnp.int32), new_keys


def make_serve_segment(cfg, *, segment: int, sample: bool,
                       eos_id: int | None, pad_id: int,
                       chunk: int | None = None, budget: int | None = None,
                       mixed_steps: int | None = None):
    """One fused continuous-batching segment: a ``lax.scan`` of
    ``segment`` steps over a fixed-slot ``ServeSlotState``, between two
    host admission points.

    ``chunk=None`` — pure decode: every live slot advances one token per
    step through the paged decode kernel (``live = ~done`` masks
    finished/empty slots out of cache writes and position advances, so
    the host can release a finished slot's pages at the boundary without
    the scan ever touching freed memory).

    ``chunk=N`` — **mixed** chunked-prefill + decode: each step, every
    live slot processes either one decode token or one prompt chunk of up
    to ``N`` tokens written *directly into pool pages*
    (``PagedKVState.append_chunk`` + the ragged-q paged kernel — no ring
    scratch, no separate prefill dispatch). The per-step token budget is
    decode-maximal (Sarathi-style): every decoding slot gets its token
    first, then prompt chunks fill the leftover ``budget - n_decode``
    greedily in slot order — so decode throughput never stops for a
    prompt, and with ``budget >= slots`` the head prefilling slot always
    progresses. A slot whose chunk completes its prompt samples its first
    token that same step (the logits of the prompt's last token), exactly
    as a one-shot prefill would.

    ``mixed_steps=k`` runs a **two-phase** segment in one dispatch: the
    first ``k`` steps execute the mixed (chunk-wide) body, the remaining
    ``segment - k`` the 1-token decode body — the scheduler sizes ``k``
    to the prompt chunks actually outstanding, so segments stay long
    (one host round-trip per ``segment`` steps) while chunk-wide q width
    is paid only where prefill happens. ``None`` = all ``segment`` steps
    mixed.

    Returns ``seg(params, state, caches, temperature, frontend) ->
    (tokens (B, segment), emitted (B, segment), grants (B, segment),
    state, caches, n_live)`` — ``emitted`` masks which step-tokens are
    real (a prefilling slot emits nothing until its prompt completes);
    ``grants`` records per-slot granted token counts (the budget
    invariant ``sum(grants[:, t]) <= budget`` is property-tested). Jit
    with ``donate_argnums=(1, 2)``.
    """
    decode = make_decode_step(cfg)
    if chunk is not None:
        assert chunk >= 1, chunk
        assert budget is not None and budget >= 1, budget

    def decode_body(params, frontend, temperature, carry, _):
        caches, st, n = carry
        # slots still mid-prompt (a two-phase segment whose mixed steps
        # underestimated budget contention) pause rather than decode
        # from a token they never sampled
        live = ~st.done & (st.cursor >= st.plen)
        logits, caches = decode(params, st.tok, caches, st.pos, frontend,
                                live)
        nxt, keys, done, rem, n = advance_step_rows(
            logits, st.keys, temperature, st.done, st.rem, n, live,
            sample=sample, eos_id=eos_id, pad_id=pad_id)
        pos = st.pos + live.astype(jnp.int32)
        st = dataclasses.replace(
            st, tok=jnp.where(live[:, None], nxt, st.tok), pos=pos,
            keys=keys, done=done, rem=rem)
        return (caches, st, n), (nxt[:, 0], live, live.astype(jnp.int32))

    def mixed_body(params, frontend, temperature, carry, _):
        caches, st, n = carry
        live = ~st.done
        prefilling = live & (st.cursor < st.plen)
        decoding = live & (st.cursor >= st.plen)
        # decode-maximal budget: decode slots first, prompt chunks fill
        # the leftover greedily in priority order (stable argsort — equal
        # priorities keep slot order, so an all-class-0 batch grants
        # exactly as before)
        want = jnp.where(prefilling,
                         jnp.minimum(chunk, st.plen - st.cursor), 0)
        order = jnp.argsort(-st.prio, stable=True)
        want_o = want[order]
        cum_o = jnp.cumsum(want_o) - want_o              # exclusive
        left = budget - jnp.sum(decoding.astype(jnp.int32))
        grant = jnp.zeros_like(want).at[order].set(
            jnp.clip(left - cum_o, 0, want_o))
        n_new = grant + decoding.astype(jnp.int32)
        # token block: prompt chunk at the cursor, or [tok, pad...]
        cols = st.cursor[:, None] + jnp.arange(chunk, dtype=jnp.int32)
        ptoks = jnp.take_along_axis(
            st.prompt_buf, jnp.clip(cols, 0, st.prompt_buf.shape[1] - 1),
            axis=1)
        first = jnp.arange(chunk, dtype=jnp.int32)[None, :] == 0
        tokens = jnp.where(prefilling[:, None], ptoks,
                           jnp.where(first, st.tok, pad_id))
        x, caches, _ = forward(params, tokens, cfg, mode="decode",
                               frontend=frontend, caches=caches,
                               pos0=st.pos, q_lens=n_new, skip_unembed=True)
        # next-token logits sit at each row's last granted column; only
        # that (B, 1, d) slice is unembedded — mid-prompt rows discard it
        sel = jnp.take_along_axis(
            x, jnp.maximum(n_new - 1, 0)[:, None, None], axis=1)
        logits = unembed(params["embed"], sel, cfg.logit_softcap)
        completes = prefilling & (st.cursor + n_new >= st.plen)
        emits = decoding | completes
        nxt, keys, done, rem, n = advance_step_rows(
            logits, st.keys, temperature, st.done, st.rem, n, emits,
            sample=sample, eos_id=eos_id, pad_id=pad_id)
        st = dataclasses.replace(
            st, tok=jnp.where(emits[:, None], nxt, st.tok),
            pos=st.pos + n_new, keys=keys, done=done, rem=rem,
            cursor=st.cursor + jnp.where(prefilling, n_new, 0))
        return (caches, st, n), (nxt[:, 0], emits, n_new)

    k = 0 if chunk is None else \
        (segment if mixed_steps is None else min(mixed_steps, segment))

    def seg(params, state, caches, temperature, frontend=None):
        carry = (caches, state, jnp.zeros((), jnp.int32))
        outs = []
        if k > 0:
            carry, out = jax.lax.scan(
                functools.partial(mixed_body, params, frontend,
                                  temperature), carry, None, length=k)
            outs.append(out)
        if k < segment:
            carry, out = jax.lax.scan(
                functools.partial(decode_body, params, frontend,
                                  temperature), carry, None,
                length=segment - k)
            outs.append(out)
        caches, state, n = carry
        toks, emits, grants = (
            jnp.concatenate(parts, axis=0) if len(outs) > 1 else parts[0]
            for parts in zip(*outs, strict=True))
        return toks.T, emits.T, grants.T, state, caches, n

    return seg


# ---------------------------------------------------------------------------
# Jit with shardings
# ---------------------------------------------------------------------------

def jit_train_step(cfg, mesh, opt_cfg: AdamWConfig):
    pshape = params_shape(cfg)
    p_sh = SH.param_shardings(pshape, mesh)
    o_sh = SH.opt_state_shardings(pshape, mesh)
    rep = SH.replicated(mesh)
    dummy_batch = input_specs(cfg, _TrainShape)["batch"]
    step = make_train_step(cfg, opt_cfg)
    return jax.jit(
        step,
        in_shardings=(p_sh, o_sh, None),
        out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1),
    ), p_sh, o_sh


class _TrainShape:                      # minimal duck-typed shape for jit
    kind = "train"
    global_batch = 8
    seq_len = 128


def lower_cell(cfg, shape, mesh, opt_cfg: AdamWConfig | None = None):
    """Lower (not compile) the step for one (arch × shape × mesh) cell,
    with all in/out shardings pinned. Returns the jax ``Lowered``."""
    from repro.launch.hints import use_hints
    opt_cfg = opt_cfg or AdamWConfig()
    par = getattr(cfg, "parallelism", "tp_fsdp")
    pshape = params_shape(cfg)
    p_sh = SH.param_shardings(pshape, mesh, par)
    specs = input_specs(cfg, shape)
    rep = SH.replicated(mesh)

    with mesh, use_hints(mesh, par):
        if shape.kind == "train":
            o_sh = SH.opt_state_shardings(
                pshape, mesh, par,
                has_master=cfg.param_dtype == "bfloat16")
            b_sh = SH.batch_shardings(specs["batch"], mesh, par)
            step = make_train_step(cfg, opt_cfg)
            jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                             out_shardings=(p_sh, o_sh, None),
                             donate_argnums=(0, 1))
            return jitted.lower(pshape, opt_state_shape(cfg), specs["batch"])

        c_sh = SH.cache_shardings(specs["caches"], shape.global_batch, mesh)
        lg_sh = SH.logits_sharding(mesh, shape.global_batch, cfg.vocab_size,
                                   par)
        if shape.kind == "prefill":
            b_sh = SH.batch_shardings(
                {"tokens": specs["tokens"]}, mesh, par)["tokens"]
            f_sh = (SH.batch_shardings({"f": specs["frontend"]}, mesh,
                                       par)["f"]
                    if "frontend" in specs else None)
            step = make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh, c_sh, f_sh),
                             out_shardings=(lg_sh, c_sh),
                             donate_argnums=(2,))
            return jitted.lower(pshape, specs["tokens"], specs["caches"],
                                specs.get("frontend"))

        b_sh = SH.batch_shardings({"tokens": specs["tokens"]},
                                  mesh, par)["tokens"]
        f_sh = (SH.batch_shardings({"f": specs["frontend"]}, mesh, par)["f"]
                if "frontend" in specs else None)
        step = make_decode_step(cfg)
        jitted = jax.jit(step, in_shardings=(p_sh, b_sh, c_sh, rep, f_sh),
                         out_shardings=(lg_sh, c_sh), donate_argnums=(2,))
        return jitted.lower(pshape, specs["tokens"], specs["caches"],
                            specs["pos0"], specs.get("frontend"))
