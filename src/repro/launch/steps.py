"""Jitted train / prefill / decode steps with production shardings, plus
``input_specs`` (ShapeDtypeStruct stand-ins — weak-type-correct, shardable,
no device allocation) used by the dry-run and launchers."""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.launch import sharding as SH
from repro.models import forward, init_caches, init_model, loss_fn
from repro.optim.optimizer import AdamWConfig, adamw_update, init_opt_state


# ---------------------------------------------------------------------------
# Shape stand-ins
# ---------------------------------------------------------------------------

def params_shape(cfg):
    return jax.eval_shape(functools.partial(init_model, cfg=cfg),
                          jax.random.PRNGKey(0))


def opt_state_shape(cfg):
    return jax.eval_shape(
        lambda: init_opt_state(
            jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                         params_shape(cfg))))


def caches_shape(cfg, batch, max_len):
    return jax.eval_shape(
        functools.partial(init_caches, cfg, batch, max_len))


def input_specs(cfg, shape) -> dict[str, Any]:
    """ShapeDtypeStructs for every model input of an (arch × shape) cell."""
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        batch = {"tokens": sds((b, s + 1), jnp.int32)}
        if cfg.frontend_dim:
            batch["frontend"] = sds(
                (b, cfg.n_frontend_tokens, cfg.frontend_dim), jnp.float32)
        return {"batch": batch}
    if shape.kind == "prefill":
        out = {"tokens": sds((b, s), jnp.int32),
               "caches": caches_shape(cfg, b, s)}
        if cfg.frontend_dim:
            out["frontend"] = sds(
                (b, cfg.n_frontend_tokens, cfg.frontend_dim), jnp.float32)
        return out
    # decode: one new token against a seq_len KV cache
    out = {"tokens": sds((b, 1), jnp.int32),
           "caches": caches_shape(cfg, b, s),
           "pos0": sds((), jnp.int32)}
    if cfg.frontend_dim:
        out["frontend"] = sds(
            (b, cfg.n_frontend_tokens, cfg.frontend_dim), jnp.float32)
    return out


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------

def make_train_step(cfg, opt_cfg: AdamWConfig):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, cfg)
        params, opt_state, stats = adamw_update(grads, opt_state, params,
                                                opt_cfg)
        return params, opt_state, {"loss": loss, **metrics, **stats}
    return train_step


def make_prefill_step(cfg):
    def prefill_step(params, tokens, caches, frontend=None):
        logits, caches, _ = forward(params, tokens, cfg, mode="prefill",
                                    frontend=frontend, caches=caches)
        return logits[:, -1:], caches
    return prefill_step


def make_decode_step(cfg):
    def decode_step(params, tokens, caches, pos0, frontend=None):
        logits, caches, _ = forward(params, tokens, cfg, mode="decode",
                                    frontend=frontend, caches=caches,
                                    pos0=pos0)
        return logits, caches
    return decode_step


# ---------------------------------------------------------------------------
# Jit with shardings
# ---------------------------------------------------------------------------

def jit_train_step(cfg, mesh, opt_cfg: AdamWConfig):
    pshape = params_shape(cfg)
    p_sh = SH.param_shardings(pshape, mesh)
    o_sh = SH.opt_state_shardings(pshape, mesh)
    rep = SH.replicated(mesh)
    dummy_batch = input_specs(cfg, _TrainShape)["batch"]
    step = make_train_step(cfg, opt_cfg)
    return jax.jit(
        step,
        in_shardings=(p_sh, o_sh, None),
        out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1),
    ), p_sh, o_sh


class _TrainShape:                      # minimal duck-typed shape for jit
    kind = "train"
    global_batch = 8
    seq_len = 128


def lower_cell(cfg, shape, mesh, opt_cfg: AdamWConfig | None = None):
    """Lower (not compile) the step for one (arch × shape × mesh) cell,
    with all in/out shardings pinned. Returns the jax ``Lowered``."""
    from repro.launch.hints import use_hints
    opt_cfg = opt_cfg or AdamWConfig()
    par = getattr(cfg, "parallelism", "tp_fsdp")
    pshape = params_shape(cfg)
    p_sh = SH.param_shardings(pshape, mesh, par)
    specs = input_specs(cfg, shape)
    rep = SH.replicated(mesh)

    with mesh, use_hints(mesh, par):
        if shape.kind == "train":
            o_sh = SH.opt_state_shardings(
                pshape, mesh, par,
                has_master=cfg.param_dtype == "bfloat16")
            b_sh = SH.batch_shardings(specs["batch"], mesh, par)
            step = make_train_step(cfg, opt_cfg)
            jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                             out_shardings=(p_sh, o_sh, None),
                             donate_argnums=(0, 1))
            return jitted.lower(pshape, opt_state_shape(cfg), specs["batch"])

        c_sh = SH.cache_shardings(specs["caches"], shape.global_batch, mesh)
        lg_sh = SH.logits_sharding(mesh, shape.global_batch, cfg.vocab_size,
                                   par)
        if shape.kind == "prefill":
            b_sh = SH.batch_shardings(
                {"tokens": specs["tokens"]}, mesh, par)["tokens"]
            f_sh = (SH.batch_shardings({"f": specs["frontend"]}, mesh,
                                       par)["f"]
                    if "frontend" in specs else None)
            step = make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh, c_sh, f_sh),
                             out_shardings=(lg_sh, c_sh),
                             donate_argnums=(2,))
            return jitted.lower(pshape, specs["tokens"], specs["caches"],
                                specs.get("frontend"))

        b_sh = SH.batch_shardings({"tokens": specs["tokens"]},
                                  mesh, par)["tokens"]
        f_sh = (SH.batch_shardings({"f": specs["frontend"]}, mesh, par)["f"]
                if "frontend" in specs else None)
        step = make_decode_step(cfg)
        jitted = jax.jit(step, in_shardings=(p_sh, b_sh, c_sh, rep, f_sh),
                         out_shardings=(lg_sh, c_sh), donate_argnums=(2,))
        return jitted.lower(pshape, specs["tokens"], specs["caches"],
                            specs["pos0"], specs.get("frontend"))
