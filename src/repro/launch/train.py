"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --smoke \
        --steps 50 --batch 8 --seq 128 [--attention-impl ita] \
        [--ckpt-dir /tmp/ckpt] [--resume]

Full-scale configs use the production mesh (run under a real TPU fleet or
with XLA_FLAGS=--xla_force_host_platform_device_count=... for rehearsal);
``--smoke`` runs the reduced config on host devices end to end.
"""

from __future__ import annotations

import argparse

import jax

from repro.configs.registry import ARCH_IDS, get_config
from repro.data.pipeline import DataPipeline, SyntheticSource
from repro.launch import sharding as SH
from repro.launch.hints import use_hints
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import make_train_step, params_shape
from repro.models import init_model
from repro.optim.optimizer import AdamWConfig, init_opt_state
from repro.runtime.fault_tolerance import FTConfig, TrainDriver


def build(args):
    cfg = get_config(args.arch, smoke=args.smoke,
                     **({"attention_impl": args.attention_impl}
                        if args.attention_impl else {}))
    mesh = (make_host_mesh() if args.smoke or args.host_mesh
            else make_production_mesh(multi_pod=args.multi_pod))
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=min(100, args.steps // 10 + 1))

    pshape = params_shape(cfg)
    p_sh = SH.param_shardings(pshape, mesh)
    o_sh = SH.opt_state_shardings(pshape, mesh)

    with mesh, use_hints(mesh):
        init = jax.jit(lambda k: init_model(k, cfg), out_shardings=p_sh)
        params = init(jax.random.PRNGKey(args.seed))
        opt_state = jax.jit(init_opt_state, out_shardings=o_sh)(params)
        step = jax.jit(make_train_step(cfg, opt_cfg),
                       in_shardings=(p_sh, o_sh, None),
                       out_shardings=(p_sh, o_sh, None),
                       donate_argnums=(0, 1))

    pipe = DataPipeline(
        SyntheticSource(cfg.vocab_size, seed=args.seed),
        batch=args.batch, seq_len=args.seq, mesh=mesh,
        frontend_shape=((cfg.n_frontend_tokens, cfg.frontend_dim)
                        if cfg.frontend_dim else None))
    return cfg, mesh, params, opt_state, step, pipe, p_sh, o_sh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--host-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--attention-impl", default=None,
                    choices=["float", "ita", "ibert"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg, mesh, params, opt_state, step, pipe, p_sh, o_sh = build(args)
    ft = FTConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    driver = TrainDriver(ft, step, params, opt_state, pipe,
                         param_shardings=p_sh, opt_shardings=o_sh)
    if args.resume and driver.maybe_restore():
        print(f"[train] resumed from step {driver.step}")
    with mesh, use_hints(mesh):
        metrics = driver.run(args.steps, log_every=args.log_every)
    print(f"[train] done: loss {float(metrics['loss']):.4f}, "
          f"stragglers {driver.straggler_events}")


if __name__ == "__main__":
    main()
