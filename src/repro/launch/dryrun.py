"""Multi-pod dry-run: lower + compile every (architecture × input shape)
cell on the production meshes and extract roofline terms.

Usage (CPU container; 512 placeholder host devices are forced below):

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b \
        --shape train_4k [--multi-pod] [--all] [--out results.json]

This is the proof that the distribution config is coherent: a sharding
mismatch, OOM-at-compile or unsupported collective fails here.
"""

# The VERY FIRST lines — before ANY other import (jax locks the device
# count on first init):
import os  # noqa: E402

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("REPRO_EXTRA_XLA_FLAGS", ""))

import argparse            # noqa: E402
import json                # noqa: E402
import time                # noqa: E402
import traceback           # noqa: E402

import jax                 # noqa: E402

from repro.configs.base import SHAPES                     # noqa: E402
from repro.configs.registry import ARCH_IDS, cells, get_config  # noqa: E402
from repro.launch.mesh import make_production_mesh        # noqa: E402
from repro.launch.steps import lower_cell                 # noqa: E402
from repro.roofline import analysis as RA                 # noqa: E402


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: dict | None = None, verbose: bool = True) -> dict:
    cfg = get_config(arch, **(overrides or {}))
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "x".join(map(str, mesh.devices.shape)),
           "kind": shape.kind, "status": "ok"}
    t0 = time.time()
    try:
        lowered = lower_cell(cfg, shape, mesh)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        arg_b = int(getattr(mem, "argument_size_in_bytes", 0))
        out_b = int(getattr(mem, "output_size_in_bytes", 0))
        tmp_b = int(getattr(mem, "temp_size_in_bytes", 0))
        rec["memory"] = {
            "argument_bytes": arg_b, "output_bytes": out_b,
            "temp_bytes": tmp_b, "peak_bytes": arg_b + out_b + tmp_b,
        }
        # loop-aware per-device costs (HloCostAnalysis ignores while trip
        # counts — see repro/roofline/hlo_costs.py)
        from repro.roofline.hlo_costs import analyze as hlo_analyze
        la = hlo_analyze(compiled.as_text())
        raw_flops, raw_bytes = RA.cost_analysis_terms(compiled)
        # HBM-byte estimate: unique argument+output traffic plus the
        # loop-aware dot operand/result traffic (post-fusion proxy).
        hbm_bytes = max(la["dot_bytes"], arg_b + out_b + tmp_b)
        mf = RA.model_flops(cfg, shape)
        roof = RA.Roofline(flops=la["flops"], hbm_bytes=hbm_bytes,
                           coll_bytes=la["collective_total"],
                           model_flops=mf, chips=chips,
                           flops_int8=la.get("flops_int8", 0.0))
        rec["cost"] = {"flops": la["flops"],
                       "flops_int8": la.get("flops_int8", 0.0),
                       "hbm_bytes": hbm_bytes,
                       "raw_cost_analysis_flops": raw_flops,
                       "raw_cost_analysis_bytes": raw_bytes,
                       "dot_bytes": la["dot_bytes"]}
        rec["collectives"] = {
            "bytes": la["collective_bytes"],
            "counts": la["collective_counts"],
            "total_bytes": la["collective_total"]}
        rec["roofline"] = roof.row()
        if verbose:
            print(f"[{rec['mesh']}] {arch} × {shape_name}: "
                  f"compile {rec['compile_s']}s, "
                  f"args {arg_b/2**30:.2f} GiB/dev, "
                  f"temp {tmp_b/2**30:.2f} GiB/dev, "
                  f"flops/dev {la['flops']:.3e}, "
                  f"coll {la['collective_total']:.3e} B, "
                  f"useful {roof.useful_ratio:.2f}, "
                  f"bottleneck={roof.bottleneck}", flush=True)
    except Exception as e:  # noqa: BLE001 — dry-run reports failures
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[{rec['mesh']}] {arch} × {shape_name}: FAIL {rec['error']}",
                  flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch × shape) cell")
    ap.add_argument("--archs", default=None,
                    help="comma-separated arch filter for --all")
    ap.add_argument("--attention-impl", default=None,
                    choices=["float", "ita", "ibert"])
    ap.add_argument("--out", default=None, help="append JSONL here")
    args = ap.parse_args()

    overrides = {}
    if args.attention_impl:
        overrides["attention_impl"] = args.attention_impl

    todo = (cells() if args.all else [(args.arch, args.shape)])
    if args.archs:
        keep = set(args.archs.split(","))
        todo = [(a, s) for a, s in todo if a in keep]
    results = []
    for arch, shape_name in todo:
        rec = run_cell(arch, shape_name, args.multi_pod, overrides)
        results.append(rec)
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
    n_ok = sum(r["status"] == "ok" for r in results)
    print(f"\n{n_ok}/{len(results)} cells OK")
    if n_ok < len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
