"""No-overflow certificates for every registered quantized kernel.

Builds the (backend x spec x geometry) matrix from the attention
registry's own capability verdicts, traces each case to a jaxpr
(interpret-mode for the Pallas kernels, so the kernel *body* is in the
trace), seeds the inputs from the declared operand ranges in
``attention/spec.py``, and runs the interval analyzer. A case passes
when the walk produces zero findings: every integer op's proven
interval fits its dtype, every narrowing convert is proven in range,
every shift amount is proven legal.

Geometries are chosen so interval bounds are *representative of the
production shapes*: the full geometry runs a 2048-token KV at the
shipped 128-wide kv tile — the per-tile reduction widths (which is what
the accumulators see) match production exactly, and longer sequences
only add more grid trips of the same proven-in-range tile math.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro import attention as ATT
from repro.analysis.intervals import INF, Interval
from repro.analysis.ranges import AnalysisResult, analyze_jaxpr
from repro.attention.spec import declared_ranges

REPORT_SCHEMA = "ita-range-report-v1"


@dataclasses.dataclass(frozen=True)
class Geometry:
    b: int
    hq: int
    hkv: int
    sq: int
    skv: int
    d: int
    bq: int
    bkv: int
    page: int

    def to_json(self):
        return dataclasses.asdict(self)


SMOKE_GEOMETRY = Geometry(b=1, hq=2, hkv=2, sq=32, skv=128, d=32,
                          bq=16, bkv=32, page=32)
FULL_GEOMETRY = Geometry(b=1, hq=4, hkv=2, sq=128, skv=2048, d=64,
                         bq=64, bkv=128, page=128)


@dataclasses.dataclass
class Case:
    """One traceable closure + seeded inputs to certify."""

    name: str
    backend: str
    desc: str
    fn: object                    # closure over static config
    args: list                    # ShapeDtypeStructs / concrete leaves
    seeds: list                   # Interval | None per flattened arg

    def trace(self):
        return jax.make_jaxpr(self.fn)(*self.args)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _iv(bounds) -> Interval:
    return Interval(bounds[0], bounds[1])


# ---------------------------------------------------------------------------
# Case builders
# ---------------------------------------------------------------------------

def _softmax_cases(g: Geometry) -> list:
    from repro.core import softmax as SM
    from repro.kernels.ita_softmax.kernel import ita_softmax_pallas
    x = _sds((g.sq, g.skv), jnp.int8)
    m = _sds((g.sq, g.skv), jnp.bool_)
    seeds = [Interval(-128, 127), Interval(0, 1)]
    cases = []
    for adaptive in (False, True):
        mode = "adaptive" if adaptive else "paper"

        def pallas_fn(x, mask, *, _a=adaptive):
            return ita_softmax_pallas(x, mask, block_r=g.bq, block_c=g.bkv,
                                      adaptive=_a, interpret=True)

        cases.append(Case(
            name=f"ita_softmax_pallas/{mode}", backend="ita_softmax",
            desc=f"Pallas DA/DI/EN softmax, {mode} inverse, "
                 f"({g.sq},{g.skv}) tiles ({g.bq},{g.bkv})",
            fn=pallas_fn, args=[x, m], seeds=list(seeds)))

        def ref_fn(x, mask, *, _a=adaptive):
            if _a:
                return SM.ita_softmax_adaptive_int(x, mask)
            return SM.ita_softmax_int(x, mask)

        cases.append(Case(
            name=f"ita_softmax_ref/{mode}", backend="ita_softmax",
            desc=f"one-shot jnp reference softmax, {mode} inverse",
            fn=ref_fn, args=[x, m], seeds=list(seeds)))
    return cases


def _matmul_cases(g: Geometry) -> list:
    from repro.kernels.int8_matmul.ops import int8_matmul
    mdim, kdim, ndim = 4 * g.bq, 4 * g.bkv, 2 * g.bkv
    x = _sds((mdim, kdim), jnp.int8)
    w = _sds((kdim, ndim), jnp.int8)
    bias = _sds((ndim,), jnp.int32)
    mult = _sds((ndim,), jnp.float32)
    spec = ATT.AttentionSpec(mode="prefill", impl="ita")
    r = declared_ranges(spec)
    # bias rides the int32 accumulator: |bias| <= kdim * 127 * 127 keeps
    # acc + bias inside the certified budget (serve checkpoints are far
    # below this)
    bias_seed = Interval(-(1 << 20), 1 << 20)
    seeds = [_iv(r["q"]), _iv(r["k"]), bias_seed, Interval(0.0, 1.0)]
    cases = []
    for use_pallas in (True, False):
        eng = "pallas" if use_pallas else "xla"

        def fn(x, w, bias, mult, *, _p=use_pallas):
            return int8_matmul(x, w, bias, mult, block_m=g.bq * 2,
                               block_n=g.bkv, block_k=g.bkv,
                               use_pallas=_p, interpret=True)

        cases.append(Case(
            name=f"int8_matmul/{eng}", backend="int8_matmul",
            desc=f"int8 GEMM + bias + requant, {eng}, "
                 f"({mdim},{kdim})x({kdim},{ndim})",
            fn=fn, args=[x, w, bias, mult], seeds=seeds))
    return cases


def _scales_args(spec, g, r):
    """(args, seeds, n) for the QuantScales leaves of ``spec``."""
    siv = _iv(r["scale"])
    if spec.scale_kind == "per_head":
        shapes = [(g.hq,), (g.hkv,), (g.hkv,), (g.hq,)]
    else:
        shapes = [(), (), (), ()]
    return ([_sds(s, jnp.float32) for s in shapes], [siv] * 4)


def _attention_case(name, backend, spec, g: Geometry, *, desc,
                    kv_len=False, q_offset=False, paged=False,
                    ragged=False, opts=None) -> Case:
    npages = (g.b * g.skv) // g.page + 1
    npps = g.skv // g.page
    r = declared_ranges(spec, kv_capacity=g.skv, num_pages=npages)
    qlen = spec.q_len if spec.q_len else g.sq
    if spec.layout == "bshd":
        q = _sds((g.b, qlen, g.hq, g.d), jnp.int8)
        k = v = _sds((g.b, g.skv, g.hkv, g.d), jnp.int8)
    elif spec.layout == "bhsd":
        q = _sds((g.b, g.hq, qlen, g.d), jnp.int8)
        k = v = _sds((g.b, g.hkv, g.skv, g.d), jnp.int8)
    elif spec.layout == "bhsd_bsgd":
        q = _sds((g.b, g.hq, qlen, g.d), jnp.int8)
        k = v = _sds((g.b, g.skv, g.hkv, g.d), jnp.int8)
    else:                                           # bhsd_paged
        q = _sds((g.b, g.hq, qlen, g.d), jnp.int8)
        k = v = _sds((npages, g.page, g.hkv, g.d), jnp.int8)
    if spec.impl == "float":
        q = _sds(q.shape, jnp.float32)
        k = v = _sds(k.shape, jnp.float32)

    args = [q, k, v]
    seeds = [_iv(r["q"]), _iv(r["k"]), _iv(r["v"])]
    extra_names = []
    if spec.impl != "float":
        s_args, s_seeds = _scales_args(spec, g, r)
        args += s_args
        seeds += s_seeds
    if kv_len:
        args.append(_sds((g.b,), jnp.int32))
        seeds.append(_iv(r["kv_len"]))
        extra_names.append("kv_len")
    if q_offset:
        args.append(_sds((g.b,), jnp.int32))
        seeds.append(_iv(r["q_offset"]))
        extra_names.append("q_offset")
    if paged:
        args.append(_sds((g.b, npps), jnp.int32))
        seeds.append(_iv(r["page_table"]))
        extra_names.append("page_table")
    if ragged:
        args.append(_sds((g.b,), jnp.int32))
        seeds.append(Interval(0, qlen))
        extra_names.append("q_lens")

    call_opts = dict(opts or {})
    call_opts.setdefault("interpret", True)

    def fn(q, k, v, *rest):
        if spec.impl == "float":
            scales, extras = None, rest
        else:
            scales = ATT.QuantScales(*rest[:4])
            extras = rest[4:]
        kw = dict(zip(extra_names, extras, strict=True))
        return ATT.dispatch(q, k, v, spec=spec, scales=scales,
                            backend=backend, **kw, **call_opts)

    return Case(name=name, backend=backend, desc=desc, fn=fn,
                args=args, seeds=seeds)


def build_matrix(*, smoke: bool = False, backends=None) -> list:
    """The certification matrix. ``smoke`` runs the small geometry only
    (CI gate); the full run re-certifies at production tile widths."""
    g = SMOKE_GEOMETRY if smoke else FULL_GEOMETRY
    S = ATT.AttentionSpec
    cases = _softmax_cases(g) + _matmul_cases(g)

    fused_kw = dict(out_dtype="int8")
    cases += [
        _attention_case(
            "float_xla/prefill", "float_xla",
            S(mode="prefill", impl="float", causal=True), g,
            desc="float oracle, streaming prefill",
            opts={"q_chunk": g.bq * 2, "kv_chunk": g.bkv * 2}),
        _attention_case(
            "ita_chunked_xla/prefill-paper", "ita_chunked_xla",
            S(mode="prefill", impl="ita", causal=True, softmax="paper",
              out_dtype="int8"),
            g, desc="streaming ITA int path, paper inverse",
            opts={"q_chunk": g.bq * 2, "kv_chunk": g.bkv * 2}),
        _attention_case(
            "ita_chunked_xla/prefill-adaptive", "ita_chunked_xla",
            S(mode="prefill", impl="ita", causal=True, softmax="adaptive",
              out_dtype="int8"),
            g, desc="streaming ITA int path, adaptive inverse",
            opts={"q_chunk": g.bq * 2, "kv_chunk": g.bkv * 2}),
        _attention_case(
            "ita_direct_xla/decode-paper", "ita_direct_xla",
            S(mode="decode", impl="ita", causal=True, q_len=8,
              softmax="paper", out_dtype="int8"), g,
            desc="one-shot XLA decode fallback, paper inverse",
            kv_len=True, q_offset=True),
        _attention_case(
            "ita_direct_xla/decode-adaptive", "ita_direct_xla",
            S(mode="decode", impl="ita", causal=True, q_len=8,
              softmax="adaptive", out_dtype="int8"), g,
            desc="one-shot XLA decode fallback, adaptive inverse",
            kv_len=True, q_offset=True),
        _attention_case(
            "ibert_xla/decode", "ibert_xla",
            S(mode="decode", impl="ibert", causal=True, q_len=1), g,
            desc="I-BERT polynomial softmax decode baseline",
            kv_len=True, q_offset=True),
        _attention_case(
            "ita_onepass_pallas/prefill-paper", "ita_onepass_pallas",
            S(mode="prefill", impl="ita", causal=True, layout="bhsd",
              softmax="paper", **fused_kw), g,
            desc="fused one-pass kernel, causal prefill, paper inverse",
            opts={"block_q": g.bq, "block_kv": g.bkv}),
        _attention_case(
            "ita_onepass_pallas/serve-ragged-paged", "ita_onepass_pallas",
            S(mode="decode", impl="ita", causal=True, layout="bhsd_paged",
              q_len=g.bq, ragged_q=True, softmax="adaptive",
              scale_kind="per_head", **fused_kw), g,
            desc="the serve path: ragged chunked-prefill+decode rows over "
                 "paged KV, adaptive inverse, per-head scales",
            kv_len=True, q_offset=True, paged=True, ragged=True,
            opts={"block_q": g.bq}),
        _attention_case(
            "ita_twopass_pallas/prefill-paper", "ita_twopass_pallas",
            S(mode="prefill", impl="ita", causal=True, layout="bhsd",
              softmax="paper", **fused_kw), g,
            desc="two-pass QK->DA + AV->EN kernels, paper inverse",
            opts={"block_q": g.bq, "block_kv": g.bkv}),
        _attention_case(
            "ita_twopass_pallas/prefill-adaptive", "ita_twopass_pallas",
            S(mode="prefill", impl="ita", causal=True, layout="bhsd",
              softmax="adaptive", **fused_kw), g,
            desc="two-pass kernels, adaptive inverse (needs the "
                 "SIGMA_INV_MAX identity clamp to certify)",
            opts={"block_q": g.bq, "block_kv": g.bkv}),
        _attention_case(
            "ita_decode_pallas/ring", "ita_decode_pallas",
            S(mode="decode", impl="ita", causal=True, layout="bhsd_bsgd",
              q_len=1, scale_kind="per_head", **fused_kw), g,
            desc="single-token decode kernel over the ring layout, "
                 "per-head scales",
            kv_len=True, q_offset=True, opts={"block_kv": g.bkv}),
        _attention_case(
            "ita_decode_pallas/paged-adaptive", "ita_decode_pallas",
            S(mode="decode", impl="ita", causal=True, layout="bhsd_paged",
              q_len=1, softmax="adaptive", **fused_kw), g,
            desc="decode kernel over paged KV via scalar-prefetched page "
                 "table, adaptive inverse",
            kv_len=True, q_offset=True, paged=True),
    ]
    if backends:
        cases = [c for c in cases if c.backend in backends]
    return cases


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------

def _bound_json(v):
    if v == INF:
        return "inf"
    if v == -INF:
        return "-inf"
    return v


def run_case(case: Case) -> dict:
    t0 = time.monotonic()
    try:
        closed = case.trace()
        res: AnalysisResult = analyze_jaxpr(closed, case.seeds)
    except Exception as e:  # noqa: BLE001 — a crash is a failed certificate
        return {
            "name": case.name, "backend": case.backend, "desc": case.desc,
            "ok": False, "error": f"{type(e).__name__}: {e}",
            "elapsed_s": round(time.monotonic() - t0, 3),
        }
    outs = [o for o in res.outvals if isinstance(o, Interval)]
    return {
        "name": case.name,
        "backend": case.backend,
        "desc": case.desc,
        "ok": res.ok,
        "n_ops": len(res.records),
        "n_unproven": res.n_unproven,
        "max_int_magnitude": res.max_int_magnitude,
        "int32_headroom_bits": _headroom_bits(res.max_int_magnitude),
        "out": [[_bound_json(o.lo), _bound_json(o.hi)] for o in outs],
        "findings": [f.to_json() for f in res.findings],
        "notes": [n.to_json() for n in res.notes],
        "elapsed_s": round(time.monotonic() - t0, 3),
    }


def _headroom_bits(mag: int) -> int:
    """How many doublings the widest proven int value has before int32."""
    if mag <= 0:
        return 31
    bits = 0
    while mag < (1 << 31) and bits < 31:
        mag <<= 1
        bits += 1
    return bits - 1 if bits else 0


def run_verification(*, smoke: bool = False, backends=None) -> dict:
    g = SMOKE_GEOMETRY if smoke else FULL_GEOMETRY
    cases = build_matrix(smoke=smoke, backends=backends)
    results = [run_case(c) for c in cases]
    certified = sorted({r["backend"] for r in results if r["ok"]})
    failed = sorted({r["backend"] for r in results if not r["ok"]})
    return {
        "schema": REPORT_SCHEMA,
        "mode": "smoke" if smoke else "full",
        "geometry": g.to_json(),
        "n_cases": len(results),
        "n_failed": sum(1 for r in results if not r["ok"]),
        "certified_backends": certified,
        "failed_backends": failed,
        "ok": all(r["ok"] for r in results),
        "cases": results,
    }
