"""``python -m repro.analysis`` — run the no-overflow certification
matrix and the jit-hygiene lints; print a human report and optionally
write the JSON artifact CI uploads next to the BENCH_*.json files.

Exit status is 0 iff every certificate and lint passed.
"""

from __future__ import annotations

import argparse
import json
import sys


def _fmt_case(c: dict) -> str:
    mark = "PASS" if c["ok"] else "FAIL"
    if "error" in c:
        return f"  {mark}  {c['name']:44s} ERROR {c['error']}"
    line = (f"  {mark}  {c['name']:44s} ops={c['n_ops']:>6} "
            f"max|int|={c['max_int_magnitude']:>12} "
            f"headroom={c['int32_headroom_bits']:>2}b")
    if c["n_unproven"]:
        line += f" unproven={c['n_unproven']}"
    return line


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Integer-range no-overflow certificates for every "
                    "registered quantized kernel + jit-hygiene lints "
                    "for the fused serve loops.")
    ap.add_argument("--smoke", action="store_true",
                    help="small geometry, short lint trace (the CI gate)")
    ap.add_argument("--all-backends", action="store_true",
                    help="accepted for CI-invocation clarity; the full "
                         "registry matrix is already the default")
    ap.add_argument("--backend", action="append", default=None,
                    help="restrict the matrix to this backend name "
                         "(repeatable)")
    ap.add_argument("--no-lints", action="store_true",
                    help="skip the serve-loop lints (range matrix only)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the machine-readable report here")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="print per-finding / per-note detail")
    args = ap.parse_args(argv)

    from repro.analysis.lints import run_lints
    from repro.analysis.verify import run_verification

    report = run_verification(smoke=args.smoke, backends=args.backend)

    g = report["geometry"]
    print(f"integer-range certification matrix [{report['mode']}] — "
          f"kv={g['skv']} q={g['sq']} d={g['d']} "
          f"tiles=({g['bq']},{g['bkv']}) page={g['page']}")
    for c in report["cases"]:
        print(_fmt_case(c))
        if args.verbose or not c["ok"]:
            for f in c.get("findings", []):
                print(f"        finding[{f['kind']}] {f['prim']} "
                      f"{f['ival']} at {f['path']}")
            for n in c.get("notes", []):
                print(f"        note[{n['kind']}] {n['message']}")
    print(f"  {report['n_cases'] - report['n_failed']}/{report['n_cases']} "
          f"certificates; backends certified: "
          f"{', '.join(report['certified_backends']) or 'none'}")

    if not args.no_lints:
        lint_report = run_lints(smoke=args.smoke)
        report["lints"] = lint_report["lints"]
        report["ok"] = report["ok"] and lint_report["ok"]
        print("jit-hygiene lints")
        for lint in lint_report["lints"]:
            mark = "PASS" if lint["ok"] else "FAIL"
            print(f"  {mark}  {lint['name']:28s} {lint['detail']}")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
        print(f"report written to {args.json}")

    print("analysis:", "OK" if report["ok"] else "FAILED")
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
