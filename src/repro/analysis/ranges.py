"""Jaxpr-walking abstract interpreter over the integer-interval domain.

``analyze_jaxpr`` walks a closed jaxpr with every input seeded to a
declared interval (int8 tensors to [-128, 127], scale scalars to the
``attention.spec`` declared bounds, kv_len to the pool capacity, ...)
and propagates per-primitive transfer functions. Three checks turn the
propagation into a no-overflow certificate:

- **overflow**: the result of integer add/sub/mul/dot_general/
  reduce_sum/shift_left, computed in unbounded integers, must fit the
  op's dtype;
- **narrowing**: ``convert_element_type`` to an integer dtype requires
  the operand interval to already sit inside the target range — this is
  what catches a dropped requant clip (the int32 logits would no longer
  provably fit the int8 store);
- **shift_range**: shift amounts must be proven within ``[0, bits-1]``
  (an unclamped ``k = (max - x) >> 5`` on a masked row reaches 2^27,
  which is UB for the lowered shift).

Structured control flow is walked, not approximated away: ``pjit`` and
custom-derivative calls recurse; ``cond`` evaluates the taken branch
when the predicate interval is a point and joins all branches
otherwise; ``scan``/``while`` unroll up to a budget and then widen the
carry to the dtype range; ``pallas_call`` maps operand intervals onto
the kernel body's refs and *simulates the grid*: the innermost (last)
grid axis runs concretely for two full sweeps — scratch accumulators
(the DA ``sigma``) reach their true per-row bound on sweep one, and
sweep two re-runs every read against the converged state so
cross-pass dependencies (the softmax kernel's EN pass reading DA
stats) see post-reduction values. Outer grid axes stay abstract; their
``program_id`` is the whole ``[0, n-1]`` interval.

Unknown primitives produce the full dtype range and a ``note`` (the
report counts them as *unproven*, never silently as proven).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import numpy as np

from repro.analysis import intervals as iv
from repro.analysis.intervals import (BOOL, INF, TOP, Interval, dtype_bits,
                                      dtype_range, fits, is_bool_dtype,
                                      is_int_dtype, join_all, point)

# Unroll budgets. The verify matrix uses small geometries on purpose —
# interval bounds are geometry-monotone (larger kv_len only scales the
# reduction counts), so a certificate at the registered geometry plus
# the analytic scaling note covers the family.
MAX_GRID_TRIPS = 512
MAX_SCAN_TRIPS = 64
PALLAS_SWEEPS = 2


@dataclasses.dataclass
class Finding:
    """A failed check — the interval could not be proven in range."""

    kind: str          # overflow | narrowing | shift_range | budget
    prim: str
    path: str
    dtype: str
    ival: str
    bound: str
    message: str

    def to_json(self):
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Note:
    """Non-failing diagnostics (unproven prims, possible zero divisors)."""

    kind: str          # unproven | zero_divisor | uninit_read | join_init
    path: str
    message: str

    def to_json(self):
        return dataclasses.asdict(self)


@dataclasses.dataclass
class OpRecord:
    prim: str
    path: str
    dtype: str
    lo: float
    hi: float


class RefCell:
    """Abstract state of one pallas ref (input block / output / scratch).

    ``ival is None`` = uninitialized (never written). Output refs join
    on write (each grid step writes a different block of the same
    array); scratch refs strong-update (whole-ref writes, persisted
    across the simulated grid sweep); input refs are read-only views of
    the operand interval.
    """

    __slots__ = ("kind", "ival", "dtype")

    def __init__(self, kind: str, ival, dtype):
        self.kind = kind
        self.ival = ival
        self.dtype = dtype

    def __repr__(self):
        return f"RefCell({self.kind}, {self.ival})"


class _PallasFrame:
    """Grid position during body simulation: trailing axes run
    concretely (their current trip value is known exactly — this is
    what makes ``j == 0`` init predicates and ``pass == 1`` cross-pass
    reads decide to a point), leading axes stay abstract."""

    __slots__ = ("grid", "concrete")

    def __init__(self, grid):
        self.grid = tuple(grid)
        self.concrete: dict[int, int] = {}

    def program_id(self, axis: int) -> Interval:
        if axis in self.concrete:
            return point(self.concrete[axis])
        n = self.grid[axis] if axis < len(self.grid) else 1
        return Interval(0, max(n - 1, 0))


@dataclasses.dataclass
class AnalysisResult:
    findings: list
    notes: list
    records: list
    outvals: list

    @property
    def ok(self) -> bool:
        return not self.findings

    @property
    def n_unproven(self) -> int:
        return sum(1 for n in self.notes if n.kind == "unproven")

    @property
    def max_int_magnitude(self) -> int:
        """Largest |bound| proven over every integer-dtype op — the
        headline of a certificate (how close the pipeline comes to the
        int32 rail)."""
        m = 0
        for r in self.records:
            if is_int_dtype(r.dtype) and abs(r.lo) != INF and abs(r.hi) != INF:
                m = max(m, int(abs(r.lo)), int(abs(r.hi)))
        return m

    def findings_by_kind(self):
        out: dict[str, int] = {}
        for f in self.findings:
            out[f.kind] = out.get(f.kind, 0) + 1
        return out


def _aval(v):
    a = v.aval
    return getattr(a, "inner_aval", a)


def _literal_interval(val) -> Interval:
    arr = np.asarray(val)
    lo, hi = arr.min(), arr.max()
    if np.issubdtype(arr.dtype, np.integer) or arr.dtype == np.bool_:
        return Interval(int(lo), int(hi))
    if not (np.isfinite(lo) and np.isfinite(hi)):
        return TOP
    return Interval(float(lo), float(hi))


def _seed_for(avl) -> Interval:
    """Default seed when the caller declared nothing: the dtype range."""
    return dtype_range(avl.dtype)


class Interp:
    """One analysis run. Not reentrant; build a fresh one per jaxpr."""

    def __init__(self):
        self.findings: list[Finding] = []
        self.notes: list[Note] = []
        self.records: list[OpRecord] = []
        self.cells: list[RefCell] = []          # every live pallas ref
        self.frames: list[_PallasFrame] = []
        self._noted: set[tuple] = set()
        self._found: set[tuple] = set()
        self.mute = False       # True during pallas warm-up sweeps

    # -- env plumbing -------------------------------------------------------

    def read(self, env, atom):
        if hasattr(atom, "val"):                # Literal
            return _literal_interval(atom.val)
        return env[atom]

    def note_once(self, kind, path, message):
        if self.mute:
            return
        key = (kind, message)
        if key not in self._noted:
            self._noted.add(key)
            self.notes.append(Note(kind, path, message))

    def add_finding(self, finding: Finding):
        if self.mute:
            return
        # the same op fires once per simulated grid trip — keep the first
        key = (finding.kind, finding.prim, finding.path)
        if key not in self._found:
            self._found.add(key)
            self.findings.append(finding)

    def check_fit(self, kind, prim, path, dtype, ival: Interval) -> Interval:
        if is_int_dtype(dtype) and not fits(ival, dtype):
            self.add_finding(Finding(
                kind=kind, prim=prim, path=path, dtype=str(dtype),
                ival=repr(ival), bound=repr(dtype_range(dtype)),
                message=f"{prim}: proven interval {ival!r} exceeds "
                        f"{dtype} range {dtype_range(dtype)!r}"))
            return ival.meet(dtype_range(dtype))
        return ival

    def check_shift(self, prim, path, dtype, sh: Interval):
        bits = dtype_bits(dtype) or 32
        ok = Interval(0, bits - 1)
        if not ok.contains(sh):
            self.add_finding(Finding(
                kind="shift_range", prim=prim, path=path, dtype=str(dtype),
                ival=repr(sh), bound=repr(ok),
                message=f"{prim}: shift amount {sh!r} not proven within "
                        f"{ok!r} (shift >= width is undefined)"))

    # -- jaxpr walking ------------------------------------------------------

    def run_closed(self, closed_jaxpr, seeds, path="") -> list:
        jaxpr = closed_jaxpr.jaxpr
        consts = [_literal_interval(c) if not isinstance(c, RefCell) else c
                  for c in closed_jaxpr.consts]
        return self.run_jaxpr(jaxpr, consts, seeds, path)

    def run_jaxpr(self, jaxpr, consts, args, path) -> list:
        env: dict[Any, Any] = {}
        assert len(jaxpr.constvars) == len(consts), \
            (len(jaxpr.constvars), len(consts))
        for v, c in zip(jaxpr.constvars, consts, strict=True):
            env[v] = c
        assert len(jaxpr.invars) == len(args), \
            (path, len(jaxpr.invars), len(args))
        for v, a in zip(jaxpr.invars, args, strict=True):
            env[v] = a
        for i, eqn in enumerate(jaxpr.eqns):
            self.eqn(eqn, env, f"{path}/{i}:{eqn.primitive.name}")
        return [self.read(env, v) for v in jaxpr.outvars]

    def eqn(self, eqn, env, path):
        name = eqn.primitive.name
        handler = _STRUCTURAL.get(name)
        if handler is not None:
            outs = handler(self, eqn, env, path)
        else:
            invals = [self.read(env, a) for a in eqn.invars]
            fn = _TRANSFER.get(name)
            if fn is None:
                outs = []
                for ov in eqn.outvars:
                    outs.append(dtype_range(_aval(ov).dtype))
                self.note_once("unproven", path,
                               f"no transfer function for '{name}' "
                               "(result widened to dtype range)")
            else:
                outs = fn(self, eqn, invals, path)
                if not isinstance(outs, list):
                    outs = [outs]
        for ov, out in zip(eqn.outvars, outs, strict=True):
            if type(ov).__name__ == "DropVar":
                continue        # unused result (e.g. a store's old value)
            env[ov] = out
            if isinstance(out, Interval) and not self.mute:
                a = _aval(ov)
                self.records.append(OpRecord(
                    prim=name, path=path, dtype=str(a.dtype),
                    lo=out.lo, hi=out.hi))

    # -- pallas simulation --------------------------------------------------

    def run_pallas(self, eqn, env, path):
        params = eqn.params
        body = params["jaxpr"]
        gm = params["grid_mapping"]
        grid = tuple(gm.grid)
        n_index = gm.num_index_operands
        n_in = gm.num_inputs
        n_out = gm.num_outputs
        n_scratch = gm.num_scratch_operands
        invals = [self.read(env, a) for a in eqn.invars]
        kname = params.get("name", "") or "body"
        bpath = f"{path}[{kname}]"

        cells = []
        for k in range(n_index + n_in):
            a = _aval(body.invars[k])
            cells.append(RefCell("input", invals[k], a.dtype))
        for k in range(n_out):
            a = _aval(body.invars[n_index + n_in + k])
            cells.append(RefCell("output", None, a.dtype))
        for k in range(n_scratch):
            a = _aval(body.invars[n_index + n_in + n_out + k])
            cells.append(RefCell("scratch", None, a.dtype))
        assert len(body.invars) == len(cells), \
            (bpath, len(body.invars), len(cells))
        self.cells.extend(cells)

        # Concretize as many *trailing* grid axes as fit the trip budget
        # (trailing axes iterate fastest and carry the reduction /
        # multi-pass structure — init-at-first-trip and finalize /
        # cross-pass predicates only decide when those axes are points).
        # Leading axes are independent program instances and stay
        # abstract. The reduction axis itself must be concrete or the
        # certificate is refused (budget finding), because an abstract
        # accumulator never converges.
        n_axes = len(grid)
        first_concrete = n_axes
        trips = 1
        while first_concrete > 0 and trips * grid[first_concrete - 1] \
                <= MAX_GRID_TRIPS:
            first_concrete -= 1
            trips *= grid[first_concrete]
        if n_axes and first_concrete == n_axes:
            self.add_finding(Finding(
                kind="budget", prim="pallas_call", path=bpath, dtype="",
                ival="", bound=str(MAX_GRID_TRIPS),
                message=f"innermost grid axis {grid[-1]} exceeds the "
                        f"{MAX_GRID_TRIPS}-trip simulation budget; "
                        "analyze a smaller geometry"))
            trips = 0

        frame = _PallasFrame(grid)
        self.frames.append(frame)
        concrete_axes = list(range(first_concrete, n_axes))
        concrete_sizes = [grid[a] for a in concrete_axes]
        saved_mute = self.mute
        try:
            # Sweep 0 warms scratch to its converged state with
            # reporting muted (cross-sweep reads of not-yet-written
            # scratch would otherwise pollute the report); sweep 1
            # replays from the converged state and records.
            for sweep in range(PALLAS_SWEEPS):
                self.mute = saved_mute or sweep < PALLAS_SWEEPS - 1
                if sweep == PALLAS_SWEEPS - 1:
                    for c in cells:
                        if c.kind == "output":
                            c.ival = None
                for t in range(trips):
                    rem = t
                    for a, n in zip(reversed(concrete_axes),
                                    reversed(concrete_sizes), strict=True):
                        frame.concrete[a] = rem % n
                        rem //= n
                    self.run_jaxpr(body, [], list(cells), bpath)
        finally:
            self.mute = saved_mute
            self.frames.pop()
            for c in cells:
                self.cells.remove(c)

        outs = []
        for k in range(n_out):
            c = cells[n_index + n_in + k]
            if c.ival is None:
                self.note_once("uninit_read", bpath,
                               "pallas output never written during the "
                               "simulated sweep")
                outs.append(dtype_range(c.dtype))
            else:
                outs.append(c.ival)
        return outs

    # -- ref state ----------------------------------------------------------

    def cell_read(self, cell: RefCell, path) -> Interval:
        if cell.ival is None:
            self.note_once("uninit_read", path,
                           "read of uninitialized scratch (widened to "
                           "dtype range)")
            return dtype_range(cell.dtype)
        return cell.ival

    def cell_write(self, cell: RefCell, val: Interval):
        if cell.kind == "output":
            cell.ival = val if cell.ival is None else cell.ival.join(val)
        else:
            cell.ival = val

    def snapshot_cells(self):
        return [(c, c.ival) for c in self.cells]

    def restore_cells(self, snap):
        for c, ival in snap:
            c.ival = ival


# ---------------------------------------------------------------------------
# Structural handlers (control flow, refs) — signature (interp, eqn, env,
# path) -> list of out values
# ---------------------------------------------------------------------------

def _h_pjit(self: Interp, eqn, env, path):
    invals = [self.read(env, a) for a in eqn.invars]
    inner = eqn.params["jaxpr"]
    name = eqn.params.get("name", "")
    return self.run_closed(inner, invals, f"{path}({name})")


def _h_custom_call(self: Interp, eqn, env, path):
    invals = [self.read(env, a) for a in eqn.invars]
    inner = eqn.params.get("call_jaxpr") or eqn.params.get("fun_jaxpr")
    num_consts = eqn.params.get("num_consts", 0)
    return self.run_closed(inner, invals[num_consts:], path) \
        if num_consts else self.run_closed(inner, invals, path)


def _h_cond(self: Interp, eqn, env, path):
    invals = [self.read(env, a) for a in eqn.invars]
    pred, ops = invals[0], invals[1:]
    branches = eqn.params["branches"]
    if isinstance(pred, Interval) and pred.is_point:
        idx = min(max(int(pred.lo), 0), len(branches) - 1)
        return self.run_closed(branches[idx], ops, f"{path}#b{idx}")
    # unknown predicate: evaluate every branch from the same ref state,
    # join outputs and ref post-states
    snap = self.snapshot_cells()
    all_outs, post_states = [], []
    for idx, br in enumerate(branches):
        self.restore_cells(snap)
        all_outs.append(self.run_closed(br, ops, f"{path}#b{idx}"))
        post_states.append([c.ival for c, _ in snap])
    for k, (c, _) in enumerate(snap):
        posts = [st[k] for st in post_states if st[k] is not None]
        c.ival = join_all(posts) if posts else None
    outs = []
    for vals in zip(*all_outs, strict=True):
        if all(isinstance(v, Interval) for v in vals):
            outs.append(join_all(vals))
        else:                               # refs pass through unchanged
            outs.append(vals[0])
    return outs


def _h_scan(self: Interp, eqn, env, path):
    invals = [self.read(env, a) for a in eqn.invars]
    p = eqn.params
    inner, nc, ncarry = p["jaxpr"], p["num_consts"], p["num_carry"]
    length = p["length"]
    consts, carry, xs = invals[:nc], invals[nc:nc + ncarry], \
        invals[nc + ncarry:]
    trips = min(length, MAX_SCAN_TRIPS)
    ys = None
    for t in range(trips):
        outs = self.run_closed(inner, consts + carry + xs, f"{path}@{t}")
        new_carry, y = outs[:ncarry], outs[ncarry:]
        if t == trips - 1 and length > trips:
            # budget exceeded: widen the carry to its dtype range and
            # run one final sound iteration
            self.note_once("unproven", path,
                           f"scan length {length} > unroll budget "
                           f"{MAX_SCAN_TRIPS}; carry widened")
            widened = [dtype_range(_aval(v).dtype)
                       for v in inner.jaxpr.outvars[:ncarry]]
            outs = self.run_closed(inner, consts + widened + xs,
                                   f"{path}@w")
            new_carry, y = outs[:ncarry], outs[ncarry:]
        carry = new_carry
        ys = y if ys is None else [a.join(b) if isinstance(a, Interval)
                                   else a for a, b in zip(ys, y, strict=True)]
    if ys is None:                          # length == 0
        ys = [dtype_range(_aval(v).dtype)
              for v in inner.jaxpr.outvars[ncarry:]]
    return list(carry) + list(ys)


def _h_while(self: Interp, eqn, env, path):
    invals = [self.read(env, a) for a in eqn.invars]
    p = eqn.params
    cn, bn = p["cond_nconsts"], p["body_nconsts"]
    body = p["body_jaxpr"]
    bconsts = invals[cn:cn + bn]
    carry = invals[cn + bn:]
    for t in range(MAX_SCAN_TRIPS):
        new = self.run_closed(body, bconsts + carry, f"{path}@{t}")
        joined = [a.join(b) if isinstance(a, Interval) else b
                  for a, b in zip(carry, new, strict=True)]
        if all(not isinstance(a, Interval) or (a.lo == b.lo and a.hi == b.hi)
               for a, b in zip(carry, joined, strict=True)):
            return joined
        carry = joined
    self.note_once("unproven", path,
                   "while_loop did not converge within budget; carry "
                   "widened")
    return [dtype_range(_aval(v).dtype) for v in eqn.outvars]


def _h_pallas(self: Interp, eqn, env, path):
    return self.run_pallas(eqn, env, path)


def _h_get(self: Interp, eqn, env, path):
    cell = env[eqn.invars[0]]
    return [self.cell_read(cell, path)]


def _h_swap(self: Interp, eqn, env, path):
    cell = env[eqn.invars[0]]
    old = cell.ival if cell.ival is not None else dtype_range(cell.dtype)
    val = self.read(env, eqn.invars[1])
    self.cell_write(cell, val)
    return [old]


def _h_addupdate(self: Interp, eqn, env, path):
    cell = env[eqn.invars[0]]
    val = self.read(env, eqn.invars[1])
    old = self.cell_read(cell, path)
    self.cell_write(cell, old + val)
    return []


def _h_program_id(self: Interp, eqn, env, path):
    axis = eqn.params["axis"]
    if not self.frames:
        return [TOP]
    return [self.frames[-1].program_id(axis)]


def _h_num_programs(self: Interp, eqn, env, path):
    axis = eqn.params["axis"]
    if not self.frames:
        return [TOP]
    grid = self.frames[-1].grid
    return [point(grid[axis] if axis < len(grid) else 1)]


_STRUCTURAL = {
    "pjit": _h_pjit,
    "closed_call": _h_custom_call,
    "custom_jvp_call": _h_custom_call,
    "custom_vjp_call": _h_custom_call,
    "custom_vjp_call_jaxpr": _h_custom_call,
    "remat2": _h_custom_call,
    "cond": _h_cond,
    "scan": _h_scan,
    "while": _h_while,
    "pallas_call": _h_pallas,
    "get": _h_get,
    "swap": _h_swap,
    "addupdate": _h_addupdate,
    "program_id": _h_program_id,
    "num_programs": _h_num_programs,
}


# ---------------------------------------------------------------------------
# Transfer functions — signature (interp, eqn, invals, path) -> Interval
# or list of Intervals
# ---------------------------------------------------------------------------

def _odtype(eqn):
    return _aval(eqn.outvars[0]).dtype


def _t_add(self, eqn, invals, path):
    return self.check_fit("overflow", "add", path, _odtype(eqn),
                          invals[0] + invals[1])


def _t_sub(self, eqn, invals, path):
    return self.check_fit("overflow", "sub", path, _odtype(eqn),
                          invals[0] - invals[1])


def _t_mul(self, eqn, invals, path):
    return self.check_fit("overflow", "mul", path, _odtype(eqn),
                          invals[0] * invals[1])


def _t_neg(self, eqn, invals, path):
    return self.check_fit("overflow", "neg", path, _odtype(eqn), -invals[0])


def _t_abs(self, eqn, invals, path):
    return self.check_fit("overflow", "abs", path, _odtype(eqn),
                          invals[0].abs())


def _t_max(self, eqn, invals, path):
    a, b = invals
    return Interval(max(a.lo, b.lo), max(a.hi, b.hi))


def _t_min(self, eqn, invals, path):
    a, b = invals
    return Interval(min(a.lo, b.lo), min(a.hi, b.hi))


def _t_clamp(self, eqn, invals, path):
    lo, x, hi = invals
    return Interval(max(min(x.lo, hi.hi), lo.lo), min(max(x.hi, lo.lo),
                                                      hi.hi))


def _t_div(self, eqn, invals, path):
    dt = _odtype(eqn)
    if is_int_dtype(dt):
        out, had_zero = iv.div_int(invals[0], invals[1])
        if had_zero:
            self.note_once("zero_divisor", path,
                           f"integer divisor {invals[1]!r} may contain 0 "
                           "(quotient widened)")
        return out.meet(dtype_range(dt))
    return iv.div_float(invals[0], invals[1])


def _t_rem(self, eqn, invals, path):
    out, had_zero = iv.rem_int(invals[0], invals[1])
    if had_zero:
        self.note_once("zero_divisor", path,
                       f"rem divisor {invals[1]!r} may contain 0")
    dt = _odtype(eqn)
    return out.meet(dtype_range(dt)) if is_int_dtype(dt) else out


def _t_dot_general(self, eqn, invals, path):
    (lhs_c, _rhs_c), _ = eqn.params["dimension_numbers"]
    lhs_shape = _aval(eqn.invars[0]).shape
    n = 1
    for d in lhs_c:
        n *= lhs_shape[d]
    elem = invals[0] * invals[1]
    out = Interval(iv._mul(elem.lo, n), iv._mul(elem.hi, n))
    return self.check_fit("overflow", "dot_general", path, _odtype(eqn), out)


def _t_reduce_sum(self, eqn, invals, path):
    shape = _aval(eqn.invars[0]).shape
    n = 1
    for a in eqn.params["axes"]:
        n *= shape[a]
    x = invals[0]
    out = Interval(iv._mul(x.lo, n), iv._mul(x.hi, n))
    return self.check_fit("overflow", "reduce_sum", path, _odtype(eqn), out)


def _t_cumsum(self, eqn, invals, path):
    shape = _aval(eqn.invars[0]).shape
    n = shape[eqn.params["axis"]]
    x = invals[0]
    out = Interval(iv._mul(x.lo, n), iv._mul(x.hi, n))
    return self.check_fit("overflow", "cumsum", path, _odtype(eqn), out)


def _t_identity(self, eqn, invals, path):
    return invals[0]


def _t_reduce_bool(self, eqn, invals, path):
    return BOOL


def _t_pad(self, eqn, invals, path):
    return invals[0].join(invals[1])


def _t_concat(self, eqn, invals, path):
    return join_all(invals)


def _t_dus(self, eqn, invals, path):
    return invals[0].join(invals[1])


def _t_select_n(self, eqn, invals, path):
    pred, cases = invals[0], invals[1:]
    if pred.is_point:
        idx = min(max(int(pred.lo), 0), len(cases) - 1)
        return cases[idx]
    return join_all(cases)


def _t_iota(self, eqn, invals, path):
    shape = _aval(eqn.outvars[0]).shape
    dim = eqn.params["dimension"]
    return Interval(0, max(shape[dim] - 1, 0))


def _t_convert(self, eqn, invals, path):
    dt = _odtype(eqn)
    x = invals[0]
    if is_bool_dtype(dt):
        return BOOL
    if is_int_dtype(dt):
        lo = x.lo if x.lo in (-INF, INF) else math.floor(x.lo)
        hi = x.hi if x.hi in (-INF, INF) else math.ceil(x.hi)
        return self.check_fit("narrowing", "convert_element_type", path,
                              dt, Interval(lo, hi))
    return x


def _t_cmp_factory(op):
    def t(self, eqn, invals, path):
        a, b = invals
        if op == "eq":
            if a.is_point and b.is_point:
                return point(int(a.lo == b.lo))
            if a.hi < b.lo or b.hi < a.lo:
                return point(0)
        elif op == "ne":
            if a.is_point and b.is_point:
                return point(int(a.lo != b.lo))
            if a.hi < b.lo or b.hi < a.lo:
                return point(1)
        elif op == "lt":
            if a.hi < b.lo:
                return point(1)
            if a.lo >= b.hi:
                return point(0)
        elif op == "le":
            if a.hi <= b.lo:
                return point(1)
            if a.lo > b.hi:
                return point(0)
        elif op == "gt":
            if a.lo > b.hi:
                return point(1)
            if a.hi <= b.lo:
                return point(0)
        elif op == "ge":
            if a.lo >= b.hi:
                return point(1)
            if a.hi < b.lo:
                return point(0)
        return BOOL
    return t


def _t_and(self, eqn, invals, path):
    a, b = invals
    if not is_bool_dtype(_odtype(eqn)):
        return dtype_range(_odtype(eqn)).meet(
            Interval(0, max(a.hi, b.hi)) if a.lo >= 0 and b.lo >= 0
            else dtype_range(_odtype(eqn)))
    if (a.is_point and a.lo == 0) or (b.is_point and b.lo == 0):
        return point(0)
    if a.is_point and b.is_point:
        return point(int(bool(a.lo) and bool(b.lo)))
    return BOOL


def _t_or(self, eqn, invals, path):
    a, b = invals
    if not is_bool_dtype(_odtype(eqn)):
        return dtype_range(_odtype(eqn))
    if (a.is_point and a.lo == 1) or (b.is_point and b.lo == 1):
        return point(1)
    if a.is_point and b.is_point:
        return point(int(bool(a.lo) or bool(b.lo)))
    return BOOL


def _t_not(self, eqn, invals, path):
    a = invals[0]
    if not is_bool_dtype(_odtype(eqn)):
        return dtype_range(_odtype(eqn))
    if a.is_point:
        return point(int(not a.lo))
    return BOOL


def _t_xor(self, eqn, invals, path):
    if not is_bool_dtype(_odtype(eqn)):
        return dtype_range(_odtype(eqn))
    a, b = invals
    if a.is_point and b.is_point:
        return point(int(bool(a.lo) != bool(b.lo)))
    return BOOL


def _t_shift_left(self, eqn, invals, path):
    dt = _odtype(eqn)
    self.check_shift("shift_left", path, dt, invals[1])
    out = iv.shift_left(invals[0], invals[1].meet(
        Interval(0, max(dtype_bits(dt) - 1, 0))))
    return self.check_fit("overflow", "shift_left", path, dt, out)


def _t_shift_right_logical(self, eqn, invals, path):
    dt = _odtype(eqn)
    self.check_shift("shift_right_logical", path, dt, invals[1])
    bits = dtype_bits(dt) or 32
    sh = invals[1].meet(Interval(0, bits - 1))
    return iv.shift_right_logical(invals[0], sh, bits)


def _t_shift_right_arith(self, eqn, invals, path):
    dt = _odtype(eqn)
    self.check_shift("shift_right_arithmetic", path, dt, invals[1])
    sh = invals[1].meet(Interval(0, max(dtype_bits(dt) - 1, 0)))
    return iv.shift_right_arith(invals[0], sh)


def _t_clz(self, eqn, invals, path):
    bits = dtype_bits(_odtype(eqn)) or 32
    return iv.clz(invals[0], bits)


def _t_sign(self, eqn, invals, path):
    x = invals[0]
    lo = -1 if x.lo < 0 else (0 if x.lo == 0 else 1)
    hi = 1 if x.hi > 0 else (0 if x.hi == 0 else -1)
    return Interval(min(lo, hi), max(lo, hi))


def _mono(fn, guard=None):
    def t(self, eqn, invals, path):
        x = invals[0]
        def g(v, side):
            if guard is not None:
                v = guard(v, side)
            return v
        try:
            lo = g(fn(x.lo) if x.lo not in (-INF, INF) else
                   (0.0 if x.lo == -INF and fn is _exp_like else -INF),
                   "lo")
            hi = g(fn(x.hi) if x.hi not in (-INF, INF) else INF, "hi")
        except (OverflowError, ValueError):
            return TOP
        return Interval(lo, hi)
    return t


_exp_like = object()    # sentinel used by _mono's -inf handling


def _t_exp(self, eqn, invals, path):
    x = invals[0]
    lo = 0.0 if x.lo == -INF else (INF if x.lo > 700 else math.exp(x.lo))
    hi = INF if x.hi > 700 or x.hi == INF else math.exp(x.hi)
    return Interval(lo, hi)


def _t_exp2(self, eqn, invals, path):
    x = invals[0]
    lo = 0.0 if x.lo == -INF else (INF if x.lo > 1000 else 2.0 ** x.lo)
    hi = INF if x.hi > 1000 or x.hi == INF else 2.0 ** x.hi
    return Interval(lo, hi)


def _t_round(self, eqn, invals, path):
    x = invals[0]
    lo = x.lo if x.lo in (-INF, INF) else float(np.round(x.lo))
    hi = x.hi if x.hi in (-INF, INF) else float(np.round(x.hi))
    return Interval(lo, hi)


def _t_floor(self, eqn, invals, path):
    x = invals[0]
    return Interval(x.lo if x.lo in (-INF, INF) else math.floor(x.lo),
                    x.hi if x.hi in (-INF, INF) else math.floor(x.hi))


def _t_ceil(self, eqn, invals, path):
    x = invals[0]
    return Interval(x.lo if x.lo in (-INF, INF) else math.ceil(x.lo),
                    x.hi if x.hi in (-INF, INF) else math.ceil(x.hi))


def _t_integer_pow(self, eqn, invals, path):
    x, y = invals[0], eqn.params["y"]
    if y < 0:
        return TOP
    cands = [x.lo ** y, x.hi ** y]
    if x.lo < 0 < x.hi:
        cands.append(0)
    out = Interval(min(cands), max(cands))
    return self.check_fit("overflow", "integer_pow", path, _odtype(eqn), out)


def _t_sqrt(self, eqn, invals, path):
    x = invals[0]
    lo = math.sqrt(max(x.lo, 0.0)) if x.lo != INF else INF
    hi = INF if x.hi == INF else math.sqrt(max(x.hi, 0.0))
    return Interval(lo, hi)


def _t_logistic(self, eqn, invals, path):
    return Interval(0.0, 1.0)


def _t_tanh(self, eqn, invals, path):
    return Interval(-1.0, 1.0)


def _t_stop_gradient(self, eqn, invals, path):
    return invals[0]


_TRANSFER = {
    "add": _t_add,
    "sub": _t_sub,
    "mul": _t_mul,
    "neg": _t_neg,
    "abs": _t_abs,
    "max": _t_max,
    "min": _t_min,
    "clamp": _t_clamp,
    "div": _t_div,
    "rem": _t_rem,
    "dot_general": _t_dot_general,
    "reduce_sum": _t_reduce_sum,
    "cumsum": _t_cumsum,
    "reduce_max": _t_identity,
    "reduce_min": _t_identity,
    "reduce_and": _t_reduce_bool,
    "reduce_or": _t_reduce_bool,
    "broadcast_in_dim": _t_identity,
    "reshape": _t_identity,
    "transpose": _t_identity,
    "squeeze": _t_identity,
    "slice": _t_identity,
    "rev": _t_identity,
    "copy": _t_identity,
    "dynamic_slice": _t_identity,
    "dynamic_update_slice": _t_dus,
    "gather": _t_identity,
    "pad": _t_pad,
    "concatenate": _t_concat,
    "select_n": _t_select_n,
    "iota": _t_iota,
    "convert_element_type": _t_convert,
    "eq": _t_cmp_factory("eq"),
    "ne": _t_cmp_factory("ne"),
    "lt": _t_cmp_factory("lt"),
    "le": _t_cmp_factory("le"),
    "gt": _t_cmp_factory("gt"),
    "ge": _t_cmp_factory("ge"),
    "and": _t_and,
    "or": _t_or,
    "not": _t_not,
    "xor": _t_xor,
    "shift_left": _t_shift_left,
    "shift_right_logical": _t_shift_right_logical,
    "shift_right_arithmetic": _t_shift_right_arith,
    "clz": _t_clz,
    "sign": _t_sign,
    "exp": _t_exp,
    "exp2": _t_exp2,
    "round": _t_round,
    "floor": _t_floor,
    "ceil": _t_ceil,
    "integer_pow": _t_integer_pow,
    "sqrt": _t_sqrt,
    "rsqrt": _t_sqrt,          # conservative: non-negative, unbounded above
    "logistic": _t_logistic,
    "tanh": _t_tanh,
    "stop_gradient": _t_stop_gradient,
}


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------

def analyze_jaxpr(closed_jaxpr, seeds) -> AnalysisResult:
    """Run the abstract interpreter over ``closed_jaxpr`` with the given
    per-input seed intervals (``None`` entries default to the input's
    dtype range)."""
    interp = Interp()
    invars = closed_jaxpr.jaxpr.invars
    assert len(seeds) == len(invars), (len(seeds), len(invars))
    seeded = []
    for s, v in zip(seeds, invars, strict=True):
        seeded.append(_seed_for(v.aval) if s is None
                      else s.meet(dtype_range(v.aval.dtype)))
    outvals = interp.run_closed(closed_jaxpr, seeded)
    return AnalysisResult(findings=interp.findings, notes=interp.notes,
                          records=interp.records, outvals=outvals)
