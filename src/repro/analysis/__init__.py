"""Static analysis for the quantized serving stack.

- ``repro.analysis.ranges``: jaxpr-walking integer-interval abstract
  interpreter (the no-overflow verifier).
- ``repro.analysis.verify``: the backend x spec x geometry certification
  matrix, seeded from the declared operand ranges in
  ``repro.attention.spec``.
- ``repro.analysis.lints``: jit-hygiene lints for the fused loops
  (bounded recompilation, donation actually used).

CLI: ``python -m repro.analysis`` (see ``--help``).
"""

from repro.analysis.intervals import Interval
from repro.analysis.lints import run_lints
from repro.analysis.ranges import AnalysisResult, analyze_jaxpr
from repro.analysis.verify import build_matrix, run_case, run_verification

__all__ = [
    "AnalysisResult",
    "Interval",
    "analyze_jaxpr",
    "build_matrix",
    "run_case",
    "run_lints",
    "run_verification",
]
