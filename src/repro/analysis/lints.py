"""Jit-hygiene lints for the fused serve/generate loops.

Two perf invariants from PRs 3 and 5 that nothing else guards:

- **Recompilation is bounded.** ``serve_continuous`` keys its jitted
  segment on ``mixed_steps = min(segment, next_pow2(n_steps))`` so a
  trace with arbitrary per-segment step counts compiles at most
  ``floor(log2(segment)) + 2`` variants — the pow2-rounding contract.
  And each variant must compile exactly *once*: a python scalar or
  weak-typed leaf leaking into the jit boundary retraces the same
  variant per call, which shows up here as ``_cache_size() > 1``.

- **Donation is used.** The segment/generate carries are donated
  (``donate_argnums``) so the KV pools update in place; XLA emits a
  "Some donated buffers were not usable" warning at compile time when a
  donated buffer cannot be aliased — on this invariant that warning is
  a failure, not a note.

- **Preemption does not retrace.** The overload path's victim eviction
  (``launch.steps.preempt_rows``) runs once per round with a host-built
  bool mask; a dtype or weak-type leak there would recompile the
  dispatch every eviction under sustained overload — exactly when the
  scheduler can least afford it. The lint drives a deterministic
  preempt→release→re-admit trace twice and requires exactly one trace
  of the dispatch (and that preemption actually fired, so the check
  can't go vacuous).

All lints drive the *real* loops (a tiny config, a mixed
chunked-prefill trace) rather than re-deriving the contracts, so any
refactor that silently changes the cache keying or breaks aliasing
fails the gate.
"""

from __future__ import annotations

import math
import warnings

import numpy as np

LINT_CONFIG = dict(
    d_model=32, n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64,
    vocab_size=64, n_layers=1)

SERVE_SEGMENT = 8
_DONATION_WARNING = "donated buffers were not usable"


def expected_variant_bound(segment: int) -> int:
    """Max distinct ``mixed_steps`` values: the powers of two up to
    ``segment`` plus ``segment`` itself (when not a power of two) plus
    the initial prefill segment — the PR-5 pow2-rounding contract."""
    return int(math.floor(math.log2(segment))) + 2


def lint_pow2_contract(segment: int = SERVE_SEGMENT,
                       max_steps: int = 1024) -> dict:
    """Closed-form check: the variant key is bounded over *every*
    possible per-segment step count, not just the ones a sample trace
    happens to produce."""
    from repro.runtime.generate import _next_pow2
    variants = {min(segment, _next_pow2(n)) for n in range(1, max_steps + 1)}
    bound = expected_variant_bound(segment)
    ok = len(variants) <= bound
    return {
        "name": "pow2-variant-contract",
        "ok": ok,
        "detail": f"{len(variants)} distinct mixed_steps variants over "
                  f"n_steps in [1, {max_steps}] at segment={segment} "
                  f"(bound {bound}): {sorted(variants)}",
    }


def _tiny_cfg():
    from repro.configs.base import ModelConfig
    c = LINT_CONFIG
    return ModelConfig(
        name="analysis-lint", family="dense", d_model=c["d_model"],
        n_heads=c["n_heads"], n_kv_heads=c["n_kv_heads"],
        head_dim=c["head_dim"], d_ff=c["d_ff"],
        vocab_size=c["vocab_size"],
        layer_groups=((("attn",), c["n_layers"]),), dtype="float32",
        attention_impl="ita", attention_backend="ita_onepass_pallas")


def _lint_trace(n_requests: int, vocab: int, seed: int = 7):
    from repro.runtime.generate import ServeRequest
    prng = np.random.default_rng(seed)
    reqs, step = [], 0
    for _ in range(n_requests):
        plen = int(prng.integers(3, 14))
        reqs.append(ServeRequest(
            prompt=prng.integers(0, vocab, plen).astype(np.int32),
            gen=int(prng.integers(1, 10)), arrival=step))
        step += int(prng.integers(0, 4))
    return reqs


def _run_instrumented_serve(n_requests: int):
    """Run ``serve_continuous`` over a mixed chunked trace with the
    segment factory wrapped to record every (variant key -> jitted fn),
    capturing compile-time warnings. Returns (variants, warnings)."""
    import jax

    from repro.models import init_model
    from repro.runtime import generate as GEN

    cfg = _tiny_cfg()
    params = init_model(jax.random.PRNGKey(0), cfg)
    reqs = _lint_trace(n_requests, cfg.vocab_size)

    GEN._serve_segment_fn.cache_clear()
    seen = {}
    orig = GEN._serve_segment_fn

    def recording(*key):
        fn = orig(*key)
        seen[key] = fn
        return fn

    GEN._serve_segment_fn = recording
    try:
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            GEN.serve_continuous(params, cfg, reqs, slots=2,
                                 segment=SERVE_SEGMENT, max_len=128,
                                 page_size=128, admission="chunked",
                                 chunk_size=5)
    finally:
        GEN._serve_segment_fn = orig
        GEN._serve_segment_fn.cache_clear()
    return seen, caught


def _run_overload_serve():
    """Drive the preemption recovery path twice on one deterministic
    overload trace: a low-class request holds 2 of the pool's 3
    allocatable pages when a high-class arrival needs 2 — victim
    eviction, page release, re-admission with the longer resumed prompt.
    Returns (per-run preemption counts, preempt_rows trace count)."""
    import jax

    from repro.launch import steps as STEPS
    from repro.models import init_model
    from repro.runtime import generate as GEN

    cfg = _tiny_cfg()
    params = init_model(jax.random.PRNGKey(0), cfg)
    prng = np.random.default_rng(11)

    def req(arrival, priority):
        return GEN.ServeRequest(
            prompt=prng.integers(0, cfg.vocab_size, 130).astype(np.int32),
            gen=20, arrival=arrival, priority=priority)

    reqs = [req(0, 0), req(2, 1)]
    STEPS.preempt_rows.clear_cache()
    preempts = []
    for _ in range(2):
        res = GEN.serve_continuous(
            params, cfg, reqs, slots=2, segment=SERVE_SEGMENT,
            max_len=256, page_size=128, num_pages=4,
            admission="chunked", chunk_size=64, preemption=True)
        preempts.append(res.preemptions)
    return preempts, STEPS.preempt_rows._cache_size()


def _run_instrumented_generate():
    """Run the fused ``generate()`` loop (donated caches carry),
    capturing compile-time warnings."""
    import jax
    import jax.numpy as jnp

    from repro.models import init_model
    from repro.runtime import generate as GEN

    cfg = _tiny_cfg()
    params = init_model(jax.random.PRNGKey(0), cfg)
    prompts = jnp.asarray(
        np.arange(2 * 6, dtype=np.int32).reshape(2, 6) % cfg.vocab_size)
    GEN._gen_loop.cache_clear()
    seen = {}
    orig = GEN._gen_loop

    def recording(*key):
        fn = orig(*key)
        seen[key] = fn
        return fn

    GEN._gen_loop = recording
    try:
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            GEN.generate(params, cfg, prompts, 5, max_len=64)
    finally:
        GEN._gen_loop = orig
        GEN._gen_loop.cache_clear()
    return seen, caught


def run_lints(*, smoke: bool = False) -> dict:
    """Run every lint; returns {"ok": bool, "lints": [...]}.

    ``smoke`` shortens the serve trace (CI gate); the contracts checked
    are identical.
    """
    results = [lint_pow2_contract()]

    seg_variants, serve_warnings = _run_instrumented_serve(
        6 if smoke else 12)
    gen_variants, gen_warnings = _run_instrumented_generate()

    bound = expected_variant_bound(SERVE_SEGMENT)
    n_var = len(seg_variants)
    results.append({
        "name": "serve-recompile-bound",
        "ok": n_var <= bound,
        "detail": f"{n_var} serve-segment variants compiled over the "
                  f"trace (bound {bound} at segment={SERVE_SEGMENT}): "
                  f"mixed_steps={sorted(k[-1] for k in seg_variants)}",
    })

    retraced = {
        f"segment{tuple(k[1:])}": fn._cache_size()
        for k, fn in seg_variants.items() if fn._cache_size() != 1}
    retraced.update({
        f"gen_loop{tuple(k[1:])}": fn._cache_size()
        for k, fn in gen_variants.items() if fn._cache_size() != 1})
    results.append({
        "name": "no-retrace-per-variant",
        "ok": not retraced,
        "detail": "every jitted variant compiled exactly once"
        if not retraced else
        f"variants retraced (python-scalar/weak-type leak into the jit "
        f"boundary?): {retraced}",
    })

    preempts, preempt_traces = _run_overload_serve()
    results.append({
        "name": "preemption-no-retrace",
        "ok": min(preempts) >= 1 and preempt_traces == 1,
        "detail": f"victim eviction fired {preempts} times over two "
                  f"identical overload runs; preempt_rows compiled "
                  f"{preempt_traces}x (must be exactly 1)",
    })

    donation_msgs = sorted({
        str(w.message).splitlines()[0]
        for w in (*serve_warnings, *gen_warnings)
        if _DONATION_WARNING in str(w.message)})
    results.append({
        "name": "donation-used",
        "ok": not donation_msgs,
        "detail": "every donated carry buffer was aliased by XLA"
        if not donation_msgs else
        f"XLA could not use donated buffers: {donation_msgs[:3]}",
    })

    return {"ok": all(r["ok"] for r in results), "lints": results}
