"""Integer/real interval domain for the jaxpr range analyzer.

One abstract value: a closed interval ``[lo, hi]`` over the extended
reals. Integer-dtype values carry exact python-int bounds (unbounded —
overflow is *detected*, never silently wrapped); float-dtype values
carry float bounds. ``TOP`` is ``[-inf, inf]``; the empty/uninitialized
state (scratch memory before its first write) is represented by ``None``
at the ref-cell layer, not here.

The domain is non-relational: it cannot prove facts that need a
correlation between two values (e.g. ``2^(e_r+8) // sigma <= 256``
requires knowing ``2^e_r <= sigma``). Kernels make such bounds
structural with identity clamps (see ``kernels/common.py``) so the
analyzer stays simple and sound.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

INF = math.inf


@dataclasses.dataclass(frozen=True)
class Interval:
    """Closed interval [lo, hi]; bounds are python ints, floats or ±inf."""

    lo: float
    hi: float

    def __post_init__(self):
        assert self.lo <= self.hi, (self.lo, self.hi)

    # -- structure ----------------------------------------------------------

    @property
    def is_point(self) -> bool:
        return self.lo == self.hi

    def join(self, other: "Interval") -> "Interval":
        return Interval(min(self.lo, other.lo), max(self.hi, other.hi))

    def meet(self, other: "Interval") -> "Interval":
        """Intersection; collapses to the nearer bound if disjoint."""
        lo, hi = max(self.lo, other.lo), min(self.hi, other.hi)
        if lo > hi:                       # disjoint — keep a sound point
            return Interval(lo, lo) if self.hi < other.lo else Interval(hi, hi)
        return Interval(lo, hi)

    def contains(self, other: "Interval") -> bool:
        return self.lo <= other.lo and other.hi <= self.hi

    def __repr__(self):
        def f(v):
            if v == INF:
                return "inf"
            if v == -INF:
                return "-inf"
            if isinstance(v, float) and v == int(v) and abs(v) < 2 ** 63:
                return str(int(v))
            return str(v)
        return f"[{f(self.lo)}, {f(self.hi)}]"

    # -- arithmetic ---------------------------------------------------------

    def __add__(self, other: "Interval") -> "Interval":
        return Interval(self.lo + other.lo, self.hi + other.hi)

    def __sub__(self, other: "Interval") -> "Interval":
        return Interval(self.lo - other.hi, self.hi - other.lo)

    def __mul__(self, other: "Interval") -> "Interval":
        cands = [_mul(a, b) for a in (self.lo, self.hi)
                 for b in (other.lo, other.hi)]
        return Interval(min(cands), max(cands))

    def __neg__(self) -> "Interval":
        return Interval(-self.hi, -self.lo)

    def scale(self, n) -> "Interval":
        """Multiply by a non-negative constant (e.g. a reduction count)."""
        assert n >= 0, n
        return Interval(_mul(self.lo, n), _mul(self.hi, n))

    def abs(self) -> "Interval":
        if self.lo >= 0:
            return self
        if self.hi <= 0:
            return -self
        return Interval(0, max(-self.lo, self.hi))


def _mul(a, b):
    """inf-safe product with 0 * inf = 0 (interval corners)."""
    if a == 0 or b == 0:
        return 0
    return a * b


TOP = Interval(-INF, INF)
ZERO = Interval(0, 0)
ONE = Interval(1, 1)
BOOL = Interval(0, 1)


def point(v) -> Interval:
    return Interval(v, v)


def join_all(ivals) -> Interval:
    out = None
    for iv in ivals:
        out = iv if out is None else out.join(iv)
    assert out is not None
    return out


# -- dtype ranges -----------------------------------------------------------

_INT_RANGES = {
    "int8": (-(1 << 7), (1 << 7) - 1),
    "int16": (-(1 << 15), (1 << 15) - 1),
    "int32": (-(1 << 31), (1 << 31) - 1),
    "int64": (-(1 << 63), (1 << 63) - 1),
    "uint8": (0, (1 << 8) - 1),
    "uint16": (0, (1 << 16) - 1),
    "uint32": (0, (1 << 32) - 1),
    "uint64": (0, (1 << 64) - 1),
}


def _dtype_name(dtype) -> str:
    """Canonical name: accepts np.dtype instances (jaxpr avals), dtype
    classes like ``jnp.int8``, and plain strings."""
    try:
        return np.dtype(dtype).name
    except TypeError:
        return str(dtype)


def is_int_dtype(dtype) -> bool:
    return _dtype_name(dtype) in _INT_RANGES


def is_bool_dtype(dtype) -> bool:
    return _dtype_name(dtype) == "bool"


def dtype_bits(dtype) -> int:
    name = _dtype_name(dtype)
    return int(name.lstrip("uint").lstrip("int") or 0) \
        if name in _INT_RANGES else 0


def dtype_range(dtype) -> Interval:
    """The representable interval of ``dtype`` (TOP for floats)."""
    name = _dtype_name(dtype)
    if name in _INT_RANGES:
        lo, hi = _INT_RANGES[name]
        return Interval(lo, hi)
    if name == "bool":
        return BOOL
    return TOP


def fits(ival: Interval, dtype) -> bool:
    return dtype_range(dtype).contains(ival)


# -- transfer helpers shared by ranges.py -----------------------------------

def div_int(num: Interval, den: Interval) -> tuple[Interval, bool]:
    """lax.div on ints (truncation toward zero). Returns (result, had_zero):
    a divisor interval containing 0 makes the result TOP (flagged as a
    note by the caller, not an overflow finding)."""
    if den.lo <= 0 <= den.hi:
        return TOP, True
    cands = [_trunc_div(a, b) for a in (num.lo, num.hi)
             for b in (den.lo, den.hi)]
    # quotient is monotone between corners for a fixed-sign divisor, but
    # truncation means the extrema can sit at mixed corners; corners are
    # sufficient because trunc-div is monotone in the numerator and
    # anti-monotone in |divisor|.
    if num.lo <= 0 <= num.hi:
        cands.append(0)
    return Interval(min(cands), max(cands)), False


def _trunc_div(a, b):
    if a in (INF, -INF) or b in (INF, -INF):
        if b in (INF, -INF):
            return 0
        return INF if (a > 0) == (b > 0) else -INF
    q = abs(int(a)) // abs(int(b))
    return q if (a >= 0) == (b >= 0) else -q


def div_float(num: Interval, den: Interval) -> Interval:
    if den.lo <= 0 <= den.hi:
        return TOP
    cands = [a / b for a in (num.lo, num.hi) for b in (den.lo, den.hi)
             if b not in (INF, -INF)] or [0.0]
    if num.lo <= 0 <= num.hi:
        cands.append(0.0)
    return Interval(min(cands), max(cands))


def rem_int(num: Interval, den: Interval) -> tuple[Interval, bool]:
    """lax.rem (sign follows the numerator). TOP when 0 in divisor."""
    if den.lo <= 0 <= den.hi:
        return TOP, True
    m = max(abs(den.lo), abs(den.hi)) - 1
    lo = 0 if num.lo >= 0 else -m
    hi = 0 if num.hi <= 0 else m
    return Interval(lo, hi), False


def shift_right_logical(val: Interval, sh: Interval, bits: int) -> Interval:
    """Bit-pattern right shift on a ``bits``-wide integer. For shift >= 1
    the result is a non-negative value < 2^(bits - shift); shift == 0 is
    the identity (a negative stays negative)."""
    if sh.hi <= 0:                        # shift is exactly 0: identity
        return val
    cands = []
    sh_lo = max(int(sh.lo), 0)
    if sh.lo <= 0:                        # shift 0 possible: identity
        cands += [val.lo, val.hi]
    s = max(sh_lo, 1)
    if val.hi >= 0:                       # non-negative part, shifted
        cands.append(max(val.lo, 0) >> min(int(sh.hi), bits - 1)
                     if sh.hi < bits else 0)
        cands.append(int(val.hi) >> s)
    if val.lo < 0:                        # negative bit patterns go huge
        cands.append(((1 << bits) - 1) >> s)
        cands.append(0)
    if not cands:
        cands = [0]
    return Interval(min(cands), max(cands))


def shift_right_arith(val: Interval, sh: Interval) -> Interval:
    """Arithmetic right shift (python ``>>`` semantics on ints)."""
    cands = []
    for v in (val.lo, val.hi):
        for s in (int(max(sh.lo, 0)), int(max(sh.hi, 0))):
            cands.append(int(v) >> s if v not in (INF, -INF)
                         else (0 if v == INF else -1))
    return Interval(min(cands), max(cands))


def shift_left(val: Interval, sh: Interval) -> Interval:
    """Unbounded left shift (the caller applies the dtype-fit check)."""
    cands = []
    for v in (val.lo, val.hi):
        for s in (int(max(sh.lo, 0)), int(max(sh.hi, 0))):
            cands.append(int(v) << s if v not in (INF, -INF) else v)
    return Interval(min(cands), max(cands))


def clz(val: Interval, bits: int) -> Interval:
    """Count-leading-zeros over a ``bits``-wide integer."""
    def one(v):
        if v < 0:
            return 0
        if v == 0:
            return bits
        return bits - int(v).bit_length()
    if val.hi < 0:
        return point(0)                 # sign bit always set
    lo_c = 0 if val.lo < 0 else one(val.hi)
    hi_c = one(max(val.lo, 0))
    return Interval(min(lo_c, hi_c), max(lo_c, hi_c))
