"""ITA integer streaming softmax + baselines (I-BERT, Softermax, float).

The paper's key algorithm (§IV): with the *maximum meaningful* quantization
scale ``eps = B/(2**B * log2 e)`` (B = 8), the softmax exponent in base 2
becomes a pure right shift::

    e^(eps * x_q) = 2^(eps' * x_q),  eps' = B/2**B = 2**-5
    2^(eps' (x_q - max)) = 2^(-((max - x_q) >> 5))

so each denominator term is ``256 >> k`` with ``k = (max - x_q) >> 5`` (the
top 3 bits of the 8-bit difference), and normalization is a shift of the
inverted denominator: ``p_i = sigma_inv >> k_i`` (paper eq. 5).

Three phases map onto the attention dataflow:

- **DA** (denominator accumulation): running row max + running sum while the
  ``Q K^T`` tiles stream by; a late max update corrects the accumulated sum
  with ``sigma >>= (delta_max >> 5)`` — the paper's multi-part row update.
- **DI** (denominator inversion): once per row, ``sigma_inv = 2^16 // sigma``
  (two serial dividers in silicon; one integer divide per row here).
- **EN** (element normalization): fused into the ``A V`` pass, pure shifts.

Modes implemented here (pure jnp references; Pallas kernels in
``repro/kernels`` are validated against these):

- ``ita_softmax``            one-shot, paper semantics, int32 accumulators
                             ("wide mode" — the 15-bit HW accumulator is a
                             gate-count constraint, not algorithmic).
- ``ita_softmax_streaming``  tiled DA/DI/EN with the paper's max-correction.
- ``ita_softmax_bitexact``   15-bit sigma / 16-bit sigma_inv silicon
                             semantics (validates the paper's MAE claim).
- ``ita_softmax_adaptive``   beyond-paper: per-row power-of-two output scale
                             (still shift-only) so rows of length >> 256
                             don't underflow the fixed 2^-8 output grid.
- ``ibert_softmax``          I-BERT 32-bit integer softmax (accuracy
                             baseline the paper compares against).
- ``softermax``              base-2 fixed-point softmax (Softermax/Keller).
- ``softmax_float``          float oracle.
- ``ita_softmax_ste``        differentiable QAT forward with straight-
                             through floors (the paper trains the clipping
                             range with QAT incorporating this softmax).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import B_BITS, EPS_MAX, SOFTMAX_SHIFT

# 2**8 — the unit in which denominator terms are accumulated.
_UNIT = 1 << B_BITS
# Paper's denominator-inversion width: sigma_inv = 2**16 // sigma.
_W_INV = 2 * B_BITS
# Shift amount for masked-out elements: forces the term/probability to 0.
_MASK_K = 31


def _k_of(x_q: jax.Array, row_max: jax.Array) -> jax.Array:
    """Exponent shift k = (max - x) >> 5 (top-3-bits of the 8-bit diff)."""
    diff = row_max.astype(jnp.int32) - x_q.astype(jnp.int32)
    return jax.lax.shift_right_logical(diff, SOFTMAX_SHIFT)


def _apply_mask_k(k: jax.Array, mask: jax.Array | None) -> jax.Array:
    if mask is None:
        return k
    return jnp.where(mask, k, _MASK_K)


# Sentinel below any int8 value; small enough that (max - sentinel) cannot
# overflow int32 (unlike INT32_MIN).
_NEG_SENTINEL = -(2 ** B_BITS)


def _masked_max(x_q: jax.Array, mask: jax.Array | None, axis: int) -> jax.Array:
    x = x_q.astype(jnp.int32)
    if mask is not None:
        x = jnp.where(mask, x, jnp.int32(_NEG_SENTINEL))
    return jnp.max(x, axis=axis, keepdims=True)


def ita_softmax_int(x_q: jax.Array, mask: jax.Array | None = None,
                    axis: int = -1):
    """One-shot ITA softmax. Returns ``(p, sigma, row_max)`` where ``p`` is
    the int32 probability in units of 2^-8 (i.e. ``p/256 ~= softmax``).

    ``p`` fits in 9 bits (max 256 when one element dominates); the uint8 HW
    representation clips 256 -> 255 which callers apply when packing.
    """
    row_max = _masked_max(x_q, mask, axis)
    k = _apply_mask_k(_k_of(x_q, row_max), mask)
    terms = jax.lax.shift_right_logical(jnp.int32(_UNIT), jnp.minimum(k, 31))
    sigma = jnp.sum(terms, axis=axis, keepdims=True)           # DA
    sigma = jnp.maximum(sigma, 1)
    sigma_inv = (jnp.int32(1) << _W_INV) // sigma              # DI
    p = jax.lax.shift_right_logical(sigma_inv, jnp.minimum(k, 31))  # EN
    # Identity on every reachable value (a live row has sigma >= 256 >> k_i
    # for each of its elements, so sigma_inv >> k_i <= 256; a fully masked
    # row shifts by _MASK_K and gets 0) — stated structurally so the range
    # verifier can bound the downstream p*V accumulator non-relationally.
    p = jnp.minimum(p, _UNIT)
    return p, sigma, row_max


def ita_softmax(x_q: jax.Array, mask: jax.Array | None = None,
                axis: int = -1) -> jax.Array:
    """ITA softmax as float probabilities (p * 2^-8)."""
    p, _, _ = ita_softmax_int(x_q, mask=mask, axis=axis)
    return p.astype(jnp.float32) * (2.0 ** -B_BITS)


# ---------------------------------------------------------------------------
# Streaming (DA across row parts) — the paper's multi-part update
# ---------------------------------------------------------------------------

def ita_da_update(carry_max: jax.Array, carry_sigma: jax.Array,
                  part_q: jax.Array, part_mask: jax.Array | None = None,
                  axis: int = -1):
    """One DA step: fold a new row part into (running max, running sigma).

    Matches the silicon behaviour exactly: when the max grows, the *already
    accumulated* sigma is corrected with a single shift ``(delta_max >> 5)``
    — the floor interacts with previously floored terms, so streaming sigma
    can overestimate the one-shot sigma by at most ``2**(number of max
    updates)`` (typically it is equal; bounded-error property is tested).
    """
    part_max = _masked_max(part_q, part_mask, axis)
    new_max = jnp.maximum(carry_max, part_max)
    delta = jax.lax.shift_right_logical(
        (new_max - carry_max).astype(jnp.int32), SOFTMAX_SHIFT)
    corrected = jax.lax.shift_right_logical(carry_sigma, jnp.minimum(delta, 31))
    k = _apply_mask_k(_k_of(part_q, new_max), part_mask)
    terms = jax.lax.shift_right_logical(jnp.int32(_UNIT), jnp.minimum(k, 31))
    part_sigma = jnp.sum(terms, axis=axis, keepdims=True)
    return new_max, corrected + part_sigma


def ita_softmax_streaming(x_q: jax.Array, num_parts: int,
                          mask: jax.Array | None = None) -> jax.Array:
    """Full DA -> DI -> EN over ``num_parts`` chunks of the last axis."""
    *lead, n = x_q.shape
    assert n % num_parts == 0, (n, num_parts)
    part = n // num_parts
    run_max = jnp.full((*lead, 1), _NEG_SENTINEL, jnp.int32)
    run_sigma = jnp.zeros((*lead, 1), jnp.int32)
    for i in range(num_parts):                                   # DA
        sl = slice(i * part, (i + 1) * part)
        m = None if mask is None else mask[..., sl]
        run_max, run_sigma = ita_da_update(run_max, run_sigma, x_q[..., sl], m)
    sigma = jnp.maximum(run_sigma, 1)
    sigma_inv = (jnp.int32(1) << _W_INV) // sigma                # DI
    k = _apply_mask_k(_k_of(x_q, run_max), mask)                 # EN
    p = jax.lax.shift_right_logical(sigma_inv, jnp.minimum(k, 31))
    return p.astype(jnp.float32) * (2.0 ** -B_BITS)


# ---------------------------------------------------------------------------
# Bit-exact silicon mode (15-bit sigma, 16-bit sigma_inv)
# ---------------------------------------------------------------------------

def ita_softmax_bitexact(x_q: jax.Array, num_parts: int = 1,
                         mask: jax.Array | None = None) -> jax.Array:
    """Paper-silicon semantics: sigma saturates at 2^15-1, sigma_inv at
    2^16-1. Valid for rows up to ~128 max-valued elements (the compact-
    transformer regime the paper targets); used to validate the MAE claim."""
    *lead, n = x_q.shape
    part = n // num_parts
    run_max = jnp.full((*lead, 1), _NEG_SENTINEL, jnp.int32)
    run_sigma = jnp.zeros((*lead, 1), jnp.int32)
    for i in range(num_parts):
        sl = slice(i * part, (i + 1) * part)
        m = None if mask is None else mask[..., sl]
        run_max, run_sigma = ita_da_update(run_max, run_sigma, x_q[..., sl], m)
        run_sigma = jnp.minimum(run_sigma, (1 << 15) - 1)        # 15-bit sat
    sigma = jnp.maximum(run_sigma, 1)
    sigma_inv = jnp.minimum((jnp.int32(1) << _W_INV) // sigma, (1 << 16) - 1)
    k = _apply_mask_k(_k_of(x_q, run_max), mask)
    p = jax.lax.shift_right_logical(sigma_inv, jnp.minimum(k, 31))
    return p.astype(jnp.float32) * (2.0 ** -B_BITS)


# ---------------------------------------------------------------------------
# Beyond-paper: adaptive per-row power-of-two scale (still shift-only)
# ---------------------------------------------------------------------------

def ita_softmax_adaptive_int(x_q: jax.Array, mask: jax.Array | None = None,
                             axis: int = -1):
    """ITA softmax with a per-row power-of-two output scale.

    The paper's fixed ``sigma_inv = 2^16/sigma`` underflows to 0 when
    ``sigma >= 2^16`` (rows longer than ~256 with flat scores) — an inherent
    8-bit-probability limitation. We pick the row exponent
    ``e_r = floor(log2 sigma)`` and compute ``sigma_inv = 2^(e_r+8)/sigma``
    in (128, 256], so ``softmax ~= p * 2^-e_r``. All operations remain
    shifts + one divide; the per-row 2^-e_r folds into the A.V output
    requant as a row shift. Returns ``(p, e_r, row_max)``.
    """
    row_max = _masked_max(x_q, mask, axis)
    k = _apply_mask_k(_k_of(x_q, row_max), mask)
    terms = jax.lax.shift_right_logical(jnp.int32(_UNIT), jnp.minimum(k, 31))
    sigma = jnp.maximum(jnp.sum(terms, axis=axis, keepdims=True), 1)
    e_r = 31 - jax.lax.clz(sigma)                         # floor(log2 sigma)
    # 2^(e_r+8)/sigma without 64-bit: pre-shift sigma so the dividend fits.
    pre = jnp.maximum(e_r + B_BITS - 30, 0)
    sigma_inv = (jnp.int32(1) << jnp.minimum(e_r + B_BITS - pre, 30)) \
        // jax.lax.shift_right_logical(sigma, pre)
    # Identity clamp: 2^e_r <= sigma forces the quotient into (128, 256],
    # but that bound is relational — state it structurally for the range
    # verifier (mirrors kernels/common.py::adaptive_inverse).
    sigma_inv = jnp.minimum(sigma_inv, _UNIT)
    p = jax.lax.shift_right_logical(sigma_inv, jnp.minimum(k, 31))
    return p, e_r, row_max


def ita_softmax_adaptive(x_q: jax.Array, mask: jax.Array | None = None,
                         axis: int = -1) -> jax.Array:
    p, e_r, _ = ita_softmax_adaptive_int(x_q, mask=mask, axis=axis)
    return p.astype(jnp.float32) * jnp.exp2(-e_r.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------

def softmax_float(x_q: jax.Array, eps: float = EPS_MAX,
                  mask: jax.Array | None = None, axis: int = -1) -> jax.Array:
    """Float oracle: softmax of the dequantized inputs."""
    x = x_q.astype(jnp.float32) * eps
    if mask is not None:
        x = jnp.where(mask, x, -jnp.inf)
    return jax.nn.softmax(x, axis=axis)


# I-BERT (Kim et al., ICML'21) integer softmax — the paper's accuracy
# baseline (MAE 0.35% vs ITA's 0.46%). Faithful port of the reference
# implementation, which stores integer values in float tensors.
_IBERT_COEF = (0.35815147, 0.96963238, 1.0)   # a(x+b)^2 + c, normalized
_IBERT_N = 30
_IBERT_X0 = -0.6931471805599453               # -ln 2


def _ibert_int_polynomial(x_int, scale):
    b_int = np.floor(_IBERT_COEF[1] / _IBERT_COEF[0] / scale)
    c_int = np.floor(_IBERT_COEF[2] / _IBERT_COEF[0] / scale ** 2)
    z = (x_int + b_int) * x_int + c_int
    return z, _IBERT_COEF[0] * scale ** 2


def _ibert_int_exp(x_int, scale):
    x0_int = np.floor(_IBERT_X0 / scale)
    x_int = jnp.maximum(x_int, _IBERT_N * x0_int)
    q = jnp.floor_divide(x_int, x0_int)
    r = x_int - x0_int * q
    exp_int, exp_scale = _ibert_int_polynomial(r, scale)
    exp_int = jnp.clip(jnp.floor(exp_int * jnp.exp2(_IBERT_N - q)), 0, None)
    return exp_int, exp_scale / 2 ** _IBERT_N


def ibert_softmax(x_q: jax.Array, eps: float = EPS_MAX,
                  mask: jax.Array | None = None, axis: int = -1,
                  output_bit: int = 8) -> jax.Array:
    """I-BERT IntSoftmax. Inputs int8 (cast up); internals 32-bit integers
    held in f32 (as in the reference implementation).

    Includes the reference code's 16-bit ``QuantAct`` requantization of the
    exponent before summation (``self.act``) — without it the 2^32/sum
    inversion underflows. Since ``exp(x - max) <= 1`` the 16-bit scale is
    the constant ``1/(2^15 - 1)``.
    """
    x_int = x_q.astype(jnp.float32)
    if mask is not None:
        x_int = jnp.where(mask, x_int, jnp.min(x_int) - 1e4)
    x_int = x_int - jnp.max(x_int, axis=axis, keepdims=True)
    exp_int, exp_scale = _ibert_int_exp(x_int, eps)
    # QuantAct(16): requantize exp to 16-bit symmetric (max real value is 1).
    exp16 = jnp.floor(exp_int * exp_scale * (2.0 ** 15 - 1))
    if mask is not None:
        exp16 = jnp.where(mask, exp16, 0.0)
    exp_sum = jnp.sum(exp16, axis=axis, keepdims=True)
    factor = jnp.floor(2.0 ** 32 / jnp.maximum(exp_sum, 1.0))
    out = jnp.floor(exp16 * factor / 2.0 ** (32 - output_bit))
    return out / 2.0 ** output_bit


def ibert_softmax_np(x_q: np.ndarray, eps: float = EPS_MAX,
                     output_bit: int = 8) -> np.ndarray:
    """Exact int64 version (numpy) of I-BERT softmax for MAE tables."""
    x_int = x_q.astype(np.int64)
    x_int = x_int - x_int.max(axis=-1, keepdims=True)
    x0_int = np.int64(np.floor(_IBERT_X0 / eps))
    x_int = np.maximum(x_int, _IBERT_N * x0_int)
    q = np.floor_divide(x_int, x0_int)
    r = x_int - x0_int * q
    b_int = np.int64(np.floor(_IBERT_COEF[1] / _IBERT_COEF[0] / eps))
    c_int = np.int64(np.floor(_IBERT_COEF[2] / _IBERT_COEF[0] / eps ** 2))
    poly = (r + b_int) * r + c_int
    exp_int = np.clip(poly * (np.int64(1) << (_IBERT_N - q).astype(np.int64)
                              ).astype(np.int64), 0, None)
    # QuantAct(16) requant (see jnp version); exact integer arithmetic here.
    exp_scale = _IBERT_COEF[0] * eps ** 2 / 2 ** _IBERT_N
    exp16 = np.floor(exp_int.astype(np.float64) * exp_scale * (2.0 ** 15 - 1)
                     ).astype(np.int64)
    exp_sum = exp16.sum(axis=-1, keepdims=True)
    factor = (np.int64(1) << 32) // np.maximum(exp_sum, 1)
    out = (exp16 * factor) >> np.int64(32 - output_bit)
    return out.astype(np.float64) / 2.0 ** output_bit


def softermax(x_q: jax.Array, eps: float = EPS_MAX, frac_bits: int = 8,
              mask: jax.Array | None = None, axis: int = -1) -> jax.Array:
    """Softermax (Stevens et al., DAC'21): base-2 softmax with running max
    in fixed point. Re-implemented here as a related-work baseline: exponent
    ``2^(eps' * (x - max))`` evaluated in Q(frac_bits) fixed point."""
    eps_p = eps * np.log2(np.e)
    x = x_q.astype(jnp.float32)
    if mask is not None:
        x = jnp.where(mask, x, -jnp.inf)
    z = (x - jnp.max(x, axis=axis, keepdims=True)) * eps_p
    pow2 = jnp.floor(jnp.exp2(z) * 2 ** frac_bits)        # fixed-point 2^z
    denom = jnp.maximum(jnp.sum(pow2, axis=axis, keepdims=True), 1.0)
    return pow2 / denom


# ---------------------------------------------------------------------------
# Differentiable QAT forward (straight-through floors)
# ---------------------------------------------------------------------------

def _ste_round(x):
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def _ste_floor(x):
    return x + jax.lax.stop_gradient(jnp.floor(x) - x)


def ita_softmax_ste(logits: jax.Array, eps: float = EPS_MAX,
                    mask: jax.Array | None = None, axis: int = -1) -> jax.Array:
    """QAT forward matching the deployed integer pipeline.

    Quantizes logits to the int8 grid (STE round + clip), floors the
    exponent shift (STE), and normalizes in float. Training through this
    forward learns the clipping range the paper obtains via QAT.
    """
    q = jnp.clip(_ste_round(logits / eps), -128, 127)
    if mask is not None:
        # keep everything finite for clean STE gradients; masked elements
        # are zeroed multiplicatively below
        qm = jnp.where(mask, q, jax.lax.stop_gradient(
            jnp.min(q, axis=axis, keepdims=True)))
    else:
        qm = q
    kf = _ste_floor((jnp.max(qm, axis=axis, keepdims=True) - qm)
                    / 2.0 ** SOFTMAX_SHIFT)
    w = jnp.exp2(-jnp.clip(kf, 0.0, 30.0))
    if mask is not None:
        w = w * mask.astype(w.dtype)
    return w / jnp.maximum(jnp.sum(w, axis=axis, keepdims=True), 1e-9)
