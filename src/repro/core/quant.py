"""Symmetric int8 quantization + integer requantization (ITA-style).

ITA (Islamoglu et al., ISLPED'23) computes every attention matmul on 8-bit
integer operands with D-bit (24 in silicon) accumulators, and converts
accumulators back to int8 with *ReQuant* modules whose clipping thresholds
come from quantization-aware training.

This module provides the TPU-native equivalents:

- per-tensor / per-channel symmetric int8 quantization,
- requantization ``int32 -> int8`` (f32 VPU multiply + round-to-nearest on
  TPU; a TFLite-style fixed-point oracle lives in ``tests`` to bound the
  difference to <= 1 LSB),
- QAT fake-quantization with straight-through estimators, so models can be
  trained with the exact clipping behaviour of the deployed integer path.

Scale conventions: ``x_real ~= scale * x_q`` with ``x_q`` int8 in
[-128, 127]. ITA's softmax input uses the *maximum meaningful scale*
``EPS_MAX = B / (2**B * log2(e))`` (paper eq. 3) so that the softmax
exponent becomes a pure right-shift; see :mod:`repro.core.softmax`.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

# Bit width used throughout ITA.
B_BITS = 8
INT8_MIN = -(2 ** (B_BITS - 1))          # -128
INT8_MAX = 2 ** (B_BITS - 1) - 1         # 127
ACC_BITS = 24                            # ITA's D (dot-product accumulator)

# Maximum meaningful softmax-input scale (paper eq. 3):
#   eps = B / (2**B * log2 e);  eps' = log2(e) * eps = B / 2**B = 2**-5.
EPS_MAX = B_BITS / (2.0 ** B_BITS * np.log2(np.e))
EPS_PRIME = B_BITS / 2.0 ** B_BITS       # = 1/32; exponent shift = 5 bits
SOFTMAX_SHIFT = B_BITS - int(np.log2(B_BITS))  # = 5


class QTensor(NamedTuple):
    """An int8 tensor plus its (f32) dequantization scale.

    ``scale`` is scalar for per-tensor quantization or broadcastable to the
    quantized axis for per-channel quantization.
    """

    values: jax.Array   # int8
    scale: jax.Array    # f32, x_real ~= scale * values

    def dequantize(self) -> jax.Array:
        return self.values.astype(jnp.float32) * self.scale


def compute_scale(x: jax.Array, axis=None, keepdims: bool = False) -> jax.Array:
    """Symmetric calibration scale: max(|x|)/127 (never zero)."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=keepdims)
    return jnp.maximum(amax, 1e-8) / INT8_MAX


def quantize(x: jax.Array, scale: jax.Array) -> jax.Array:
    """Real -> int8 with round-to-nearest-even and saturation."""
    q = jnp.round(x / scale)
    return jnp.clip(q, INT8_MIN, INT8_MAX).astype(jnp.int8)


def quantize_tensor(x: jax.Array, axis=None) -> QTensor:
    scale = compute_scale(x, axis=axis, keepdims=axis is not None)
    return QTensor(quantize(x, scale), scale.astype(jnp.float32))


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def requantize(acc: jax.Array, scale_ratio: jax.Array,
               out_min: int = INT8_MIN, out_max: int = INT8_MAX,
               out_dtype=jnp.int8) -> jax.Array:
    """ITA ReQuant: int32 accumulator -> int8 at a new scale.

    ``scale_ratio = s_in / s_out`` (for a matmul: ``s_x * s_w / s_y``).
    On TPU this lowers to a VPU f32 multiply + round; the ASIC uses a
    fixed-point multiplier+shift — the two agree to <= 1 LSB (tested).
    """
    y = jnp.round(acc.astype(jnp.float32) * scale_ratio)
    return jnp.clip(y, out_min, out_max).astype(out_dtype)


# ---------------------------------------------------------------------------
# TFLite/ASIC-style fixed-point requant oracle (numpy, int64) — used by tests
# to show the f32 path matches the hardware fixed-point path to <= 1 LSB.
# ---------------------------------------------------------------------------

def quantize_multiplier(scale_ratio: float) -> tuple[int, int]:
    """Decompose ``scale_ratio`` as ``M * 2**-shift`` with M in [2^30, 2^31)."""
    if scale_ratio <= 0:
        raise ValueError("scale_ratio must be positive")
    mant, exp = np.frexp(scale_ratio)           # scale = mant * 2**exp, mant in [0.5, 1)
    m = int(np.round(mant * (1 << 31)))
    if m == (1 << 31):
        m //= 2
        exp += 1
    return m, 31 - exp                           # right-shift amount


def requantize_fixedpoint_np(acc: np.ndarray, scale_ratio: float) -> np.ndarray:
    """Bit-accurate ASIC requant: (acc * M + rnd) >> shift, saturated.
    ``quantize_multiplier`` returns the *total* right shift (31 - exp)."""
    m, shift = quantize_multiplier(scale_ratio)
    assert shift > 0, (m, shift)
    prod = acc.astype(np.int64) * np.int64(m)
    rnd = np.int64(1) << np.int64(shift - 1)
    y = (prod + rnd) >> np.int64(shift)
    return np.clip(y, INT8_MIN, INT8_MAX).astype(np.int8)


# ---------------------------------------------------------------------------
# QAT fake quantization (straight-through estimator)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def fake_quant(x: jax.Array, scale: jax.Array) -> jax.Array:
    """Quantize-dequantize with STE. Gradients are passed through inside the
    clipping range and zeroed outside (matching the deployed saturation)."""
    q = jnp.clip(jnp.round(x / scale), INT8_MIN, INT8_MAX)
    return q * scale


def _fake_quant_fwd(x, scale):
    y = fake_quant(x, scale)
    in_range = (x >= scale * INT8_MIN) & (x <= scale * INT8_MAX)
    return y, (in_range, jnp.shape(scale))


def _fake_quant_bwd(res, g):
    in_range, scale_shape = res
    dx = jnp.where(in_range, g, 0.0)
    # LSQ-style scale gradient omitted (scales are calibration-updated);
    # return a structural zero of the right shape.
    return dx, jnp.zeros(scale_shape, g.dtype)


fake_quant.defvjp(_fake_quant_fwd, _fake_quant_bwd)


@functools.partial(jax.jit, static_argnames=("momentum",))
def update_running_amax(running: jax.Array, x: jax.Array,
                        momentum: float = 0.99) -> jax.Array:
    """EMA absolute-max tracker used for QAT calibration of ReQuant clips."""
    return momentum * running + (1.0 - momentum) * jnp.max(jnp.abs(x))


def int8_matmul_ref(x_q: jax.Array, w_q: jax.Array,
                    bias_q: jax.Array | None = None) -> jax.Array:
    """int8 x int8 -> int32 matmul (the PE-array contract, jnp reference).

    On TPU the MXU executes this natively at 2x bf16 throughput (v5e:
    394 TOPS int8). ``bias_q`` follows the paper: biases are added to the
    accumulator before requantization.
    """
    acc = jax.lax.dot_general(
        x_q, w_q,
        dimension_numbers=(((x_q.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    if bias_q is not None:
        acc = acc + bias_q.astype(jnp.int32)
    return acc


def quantized_linear(x: jax.Array, w_q: QTensor,
                     bias: jax.Array | None = None,
                     out_scale: jax.Array | None = None):
    """Full quantized linear layer: quantize act -> int8 matmul -> requant.

    Returns ``(QTensor out, int32 acc)``; if ``out_scale`` is None the output
    scale is calibrated on the fly from the accumulator (post-training
    quantization mode).
    """
    xq = quantize_tensor(x)
    acc = int8_matmul_ref(xq.values, w_q.values)
    acc_scale = xq.scale * w_q.scale
    if bias is not None:
        acc = acc + jnp.round(bias / acc_scale).astype(jnp.int32)
    if out_scale is None:
        out_scale = compute_scale(acc.astype(jnp.float32) * acc_scale)
    out = requantize(acc, acc_scale / out_scale)
    return QTensor(out, out_scale), acc
