"""Loop-aware cost extraction from compiled (SPMD-partitioned) HLO text.

XLA's ``HloCostAnalysis`` (and hence ``compiled.cost_analysis()``) counts
each ``while`` body **once**, ignoring trip counts — with layer stacks as
``lax.scan`` this undercounts FLOPs/bytes/collective traffic by ~n_layers.
This module walks the HLO call graph with per-computation multipliers:

- computations are parsed into (ops, called-computation references),
- each ``while`` body/condition inherits ``multiplier × trip_count``,
  where the trip count is recovered from the loop condition's constant
  bound (scan lowers to ``compare(counter, constant)``),
- ``dot`` FLOPs are ``2 × numel(result) × prod(contracted dims)``,
- collective bytes are operand sizes × multiplier,
- HBM-byte proxy: dot operand+result bytes (the MXU-relevant traffic;
  elementwise fusions are bandwidth-free in the roofline sense when fused
  with dots, and are dominated by them at these shapes).

Validated against a fully-unrolled lowering of the same cell (see
EXPERIMENTS.md §Roofline methodology).
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+)\s*\(.*\)\s*->", re.M)
_CALL_REF = re.compile(
    r"(?:calls=|body=|condition=|to_apply=|branch_computations=\{)%?"
    r"([\w\.\-]+)")
_CALL_REF_MULTI = re.compile(r"branch_computations=\{([^}]*)\}")
_WHILE_RE = re.compile(
    r"while\(.*?\)[^\n]*?condition=%?([\w\.\-]+)[^\n]*?body=%?([\w\.\-]+)"
    r"|while\(.*?\)[^\n]*?body=%?([\w\.\-]+)[^\n]*?condition=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _numel(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _shape_bytes(dtype: str, dims: str) -> int:
    return _numel(dims) * _DTYPE_BYTES[dtype]


def parse_computations(hlo: str) -> dict:
    """Split HLO text into named computation bodies."""
    comps = {}
    name, lines = None, []
    for line in hlo.splitlines():
        stripped = line.strip()
        if (line.startswith("ENTRY") or line.startswith("%")
                or stripped.startswith("ENTRY")) and "->" in line \
                and "{" in line:
            m = _COMP_HDR.search(line)
            if m:
                name = m.group(1)
                comps[name] = []
                # register parameter shapes as synthetic defs
                for pm in re.finditer(
                        r"([\w\.\-]+): (" + "|".join(_DTYPE_BYTES)
                        + r")\[([0-9,]*)\]", line):
                    comps[name].append(
                        f"%{pm.group(1)} = {pm.group(2)}[{pm.group(3)}] "
                        f"parameter(0)")
                if line.startswith("ENTRY") or stripped.startswith("ENTRY"):
                    comps["__entry__"] = comps[name]
                continue
        if name is not None:
            if stripped == "}":
                name = None
            else:
                comps[name].append(stripped)
    return comps


def _trip_count(cond_lines) -> int:
    """Largest integer constant in the loop condition ≙ scan bound."""
    best = 1
    for line in cond_lines:
        if "constant(" in line:
            for c in _CONST_RE.findall(line):
                best = max(best, int(c))
    return best


_TRIP_BC = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_DEF_RE = re.compile(
    r"^(?:ROOT )?%([\w\.\-]+) = \(?(" + "|".join(_DTYPE_BYTES)
    + r")\[([0-9,]*)\]")
_OPERANDS_RE = re.compile(r"%([\w\.\-]+)")


def _symbol_tables(comps):
    """op name -> (dtype, dims) per computation + global fallback."""
    local = {}
    glob = {}
    for name, lines in comps.items():
        tab = {}
        for line in lines:
            m = _DEF_RE.match(line)
            if m:
                tab[m.group(1)] = (m.group(2), m.group(3))
        local[name] = tab
        glob.update(tab)
    return local, glob


def analyze(hlo: str) -> dict:
    comps = parse_computations(hlo)
    entry = comps.get("__entry__")
    if entry is None:                      # fallback: biggest computation
        entry = max(comps.values(), key=len)

    local_tab, glob_tab = _symbol_tables(comps)

    def shape_of(comp_name, op_name):
        tab = local_tab.get(comp_name, {})
        return tab.get(op_name) or glob_tab.get(op_name)

    # multipliers via BFS over the call graph
    mult = defaultdict(float)
    seen_entry = [k for k, v in comps.items()
                  if v is entry and k != "__entry__"][0]
    mult[seen_entry] = 1.0
    order = [seen_entry]
    visited = {seen_entry}
    while order:
        cur = order.pop(0)
        m = mult[cur]
        for line in comps[cur]:
            trip = 1.0
            if " while(" in line or line.startswith("while("):
                bc = _TRIP_BC.search(line)
                if bc:
                    trip = float(bc.group(1))
                else:
                    refs = _CALL_REF.findall(line)
                    cond = next((r for r in refs if r in comps
                                 and any("compare" in l for l in comps[r])),
                                None)
                    if cond is not None:
                        trip = float(_trip_count(comps[cond]))
            for ref in set(_CALL_REF.findall(line)):
                if ref not in comps:
                    continue
                is_body = f"body=%{ref}" in line or f"body={ref}," in line
                add = m * (trip if is_body else 1.0)
                mult[ref] += add
                if ref not in visited:
                    visited.add(ref)
                    order.append(ref)
            mm = _CALL_REF_MULTI.search(line)
            if mm:
                for ref in re.findall(r"%?([\w\.\-]+)", mm.group(1)):
                    if ref in comps and ref not in visited:
                        mult[ref] += m
                        visited.add(ref)
                        order.append(ref)

    flops = 0.0
    flops_int8 = 0.0          # dots with both operands s8/u8 (MXU int8 path)
    dot_bytes = 0.0
    coll = {k: 0.0 for k in _COLLECTIVES}
    coll_counts = {k: 0.0 for k in _COLLECTIVES}
    for name, lines in comps.items():
        if name == "__entry__" or mult[name] == 0:
            continue
        m = mult[name]
        for line in lines:
            if " dot(" in line:
                dm = _DEF_RE.match(line)
                if not dm:
                    continue
                res = (dm.group(2), dm.group(3))
                args = line[line.index(" dot(") + 5:]
                args = args[:args.index(")")]
                names = _OPERANDS_RE.findall(args)
                if len(names) < 2:
                    continue
                lhs = shape_of(name, names[0])
                rhs = shape_of(name, names[1])
                cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
                contracted = 1
                if cm and cm.group(1) and lhs:
                    ldims = lhs[1].split(",")
                    for ci in cm.group(1).split(","):
                        contracted *= int(ldims[int(ci)])
                f = m * 2.0 * _numel(res[1]) * contracted
                flops += f
                if lhs and rhs and lhs[0] in ("s8", "u8") \
                        and rhs[0] in ("s8", "u8"):
                    flops_int8 += f
                dot_bytes += m * (_shape_bytes(*res)
                                  + (_shape_bytes(*lhs) if lhs else 0)
                                  + (_shape_bytes(*rhs) if rhs else 0))
                continue
            for kind in _COLLECTIVES:
                token = f" {kind}(" if f" {kind}(" in line \
                    else (f" {kind}-start(" if f" {kind}-start(" in line
                          else None)
                if token is None:
                    continue
                args = line[line.index(token) + len(token):]
                depth, end = 1, 0
                for i, ch in enumerate(args):
                    depth += ch == "("
                    depth -= ch == ")"
                    if depth == 0:
                        end = i
                        break
                names = _OPERANDS_RE.findall(args[:end])
                b = sum(_shape_bytes(*shape_of(name, nm))
                        for nm in names if shape_of(name, nm))
                if b == 0:                       # fallback: result bytes
                    dm = _DEF_RE.match(line)
                    if dm:
                        b = _shape_bytes(dm.group(2), dm.group(3))
                coll[kind] += m * b
                coll_counts[kind] += m
                break
    return {"flops": flops, "flops_int8": flops_int8,
            "dot_bytes": dot_bytes,
            "collective_bytes": coll,
            "collective_total": sum(coll.values()),
            "collective_counts": coll_counts}
