"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch × shape × mesh), all in seconds:

    compute    = HLO_FLOPs / peak_FLOP/s          (per-device program)
    memory     = HLO_bytes / HBM_bw
    collective = collective_bytes / link_bw

``cost_analysis()`` supplies FLOPs and bytes for the per-device SPMD
program; collective bytes are parsed from the compiled HLO text (operand
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute).

Hardware model: TPU v5e — 197 TFLOP/s bf16 (394 int8) per chip, 819 GB/s
HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re

HW = {
    "peak_bf16": 197e12,
    "peak_int8": 394e12,
    "hbm_bw": 819e9,
    "link_bw": 50e9,
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)\s*)?[a-z0-9_\[\],\s]*?"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_stats(hlo_text: str) -> dict:
    """Sum operand bytes per collective kind from (compiled) HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m or "-done(" in line:
            continue
        kind = m.group(1)
        rhs = line.split("=", 1)[1]
        paren = rhs.find("(")
        operand_str = rhs[paren:]
        shapes = _SHAPE_RE.findall(operand_str)
        if not shapes:
            continue
        counts[kind] += 1
        out[kind] += sum(_shape_bytes(d, s) for d, s in shapes)
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device bytes accessed
    coll_bytes: float            # per-device collective operand bytes
    model_flops: float           # analytic useful flops (global)
    chips: int
    flops_int8: float = 0.0      # subset of flops on the int8 MXU path

    @property
    def compute_s(self):
        return (self.flops - self.flops_int8) / HW["peak_bf16"] \
            + self.flops_int8 / HW["peak_int8"]

    @property
    def compute_int8_s(self):
        return self.flops / HW["peak_int8"]

    @property
    def memory_s(self):
        return self.hbm_bytes / HW["hbm_bw"]

    @property
    def collective_s(self):
        return self.coll_bytes / HW["link_bw"]

    @property
    def bottleneck(self):
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self):
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self):
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    def row(self):
        return dict(flops=self.flops, hbm_bytes=self.hbm_bytes,
                    coll_bytes=self.coll_bytes,
                    compute_s=self.compute_s, memory_s=self.memory_s,
                    collective_s=self.collective_s,
                    bottleneck=self.bottleneck,
                    model_flops=self.model_flops,
                    useful_ratio=self.useful_ratio)


def cost_analysis_terms(compiled) -> tuple[float, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    bytes_acc = float(ca.get("bytes accessed", 0.0))
    return flops, bytes_acc


# ---------------------------------------------------------------------------
# Analytic "useful" FLOPs (MODEL_FLOPS): 6·N·D dense / 6·N_active·D MoE,
# plus attention terms (not captured by 6ND).
# ---------------------------------------------------------------------------

def param_counts(cfg) -> dict:
    """Analytic parameter counts (matches init_model to ~1%)."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    h, g, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    attn = d * h * hd + 2 * d * g * hd + h * hd * d
    mlp = {"swiglu": 3 * d * f, "geglu": 3 * d * f, "gelu": 2 * d * f,
           "moe": 0, "rwkv": 0}[cfg.mlp_type]
    moe = 3 * d * f * cfg.n_experts + d * cfg.n_experts
    moe_active = 3 * d * f * cfg.n_experts_active + d * cfg.n_experts
    dr = cfg.rnn_width or d
    # 2 input branches + out proj + block-diag gates + conv + lambda
    rglru = 3 * d * dr + 2 * dr * (dr // max(cfg.n_heads, 1)) + 5 * dr
    rwkv_tm = 5 * d * d + d * (5 * 64) + 5 * 64 * d + 2 * d * 64
    rwkv_cm = 2 * d * f + d * d

    total = active = 0
    for pattern, n in cfg.layer_groups:
        for kind in pattern:
            if kind in ("attn", "local", "swa", "enc"):
                blk = attn + (moe if cfg.mlp_type == "moe" else mlp)
                blk_a = attn + (moe_active if cfg.mlp_type == "moe" else mlp)
            elif kind == "cross":
                blk = blk_a = attn + 3 * d * f
            elif kind == "attn_cross":
                blk = blk_a = 2 * attn + 2 * d * f
            elif kind == "rglru":
                blk = blk_a = rglru + 3 * d * f
            elif kind == "rwkv":
                blk = blk_a = rwkv_tm + rwkv_cm
            total += blk * n
            active += blk_a * n
    if cfg.n_encoder_layers:
        total += cfg.n_encoder_layers * (attn + 2 * d * f)
        active += cfg.n_encoder_layers * (attn + 2 * d * f)
    emb = v * d * (1 if cfg.tie_embeddings else 2)
    return {"backbone": total, "backbone_active": active, "embedding": emb,
            "total": total + emb,
            "total_active": active + emb}


def attention_flops(cfg, seq, batch, kind="train", kv_len=None):
    """QK^T + AV flops across all attention layers (2·2·S·Skv·H·hd each,
    causal halving for self-attn in train/prefill)."""
    h, hd = cfg.n_heads, cfg.head_dim
    total = 0.0
    for pattern, n in cfg.layer_groups:
        for k in pattern:
            if k in ("attn", "enc"):
                skv = kv_len if kind == "decode" else seq
                sq = 1 if kind == "decode" else seq
                causal_f = 0.5 if kind != "decode" else 1.0
                total += n * 4 * sq * skv * h * hd * causal_f
            elif k in ("local", "swa"):
                w = cfg.local_window if k == "local" else cfg.window
                skv = min(kv_len or seq, w) if kind == "decode" \
                    else min(seq, w)
                sq = 1 if kind == "decode" else seq
                total += n * 4 * sq * skv * h * hd \
                    * (0.5 if kind != "decode" and seq <= w else 1.0)
            elif k == "cross":
                sq = 1 if kind == "decode" else seq
                total += n * 4 * sq * cfg.n_frontend_tokens * h * hd
            elif k == "attn_cross":
                skv = kv_len if kind == "decode" else seq
                sq = 1 if kind == "decode" else seq
                causal_f = 0.5 if kind != "decode" else 1.0
                total += n * (4 * sq * skv * h * hd * causal_f
                              + 4 * sq * cfg.n_frontend_tokens * h * hd)
    if cfg.n_encoder_layers and kind != "decode":
        total += cfg.n_encoder_layers * 4 * cfg.n_frontend_tokens ** 2 \
            * h * hd
    return total * batch


def model_flops(cfg, shape) -> float:
    """Analytic useful FLOPs for one step of the given shape (global)."""
    counts = param_counts(cfg)
    n_active = counts["backbone_active"] + (
        0 if cfg.tie_embeddings else cfg.vocab_size * cfg.d_model)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mm = 6 * n_active * tokens \
            + 6 * cfg.vocab_size * cfg.d_model * tokens  # unembed fwd+bwd
        attn = 3 * attention_flops(cfg, shape.seq_len, shape.global_batch,
                                   "train")
        return mm + attn
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2 * n_active * tokens \
            + 2 * cfg.vocab_size * cfg.d_model * tokens \
            + attention_flops(cfg, shape.seq_len, shape.global_batch,
                              "prefill")
    tokens = shape.global_batch                      # decode: 1 token each
    # at decode the encoder does not run and cross-attention K/V come from
    # the prefill-time cache — exclude those parameters
    d, h, g, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    n_dec = n_active
    if cfg.n_encoder_layers:
        n_dec -= cfg.n_encoder_layers * (
            d * h * hd + 2 * d * g * hd + h * hd * d + 2 * d * cfg.d_ff)
    n_cross = sum(n * pattern.count("cross") + n * pattern.count("attn_cross")
                  for pattern, n in cfg.layer_groups)
    n_dec -= n_cross * 2 * d * g * hd                # cached cross K/V proj
    return 2 * n_dec * tokens \
        + 2 * cfg.vocab_size * cfg.d_model * tokens \
        + attention_flops(cfg, 1, shape.global_batch, "decode",
                          kv_len=shape.seq_len)
