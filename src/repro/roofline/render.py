"""Render EXPERIMENTS.md tables from dry-run JSONL artifacts."""

from __future__ import annotations

import json


def load(path):
    with open(path) as f:
        return [json.loads(l) for l in f if l.strip()]


def _fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}µs"


def dryrun_table(recs):
    hdr = ("| arch | shape | mesh | status | compile | args/dev | "
           "temp/dev | collectives (ag/ar/rs/a2a/cp) |\n"
           "|---|---|---|---|---|---|---|---|\n")
    rows = []
    for r in recs:
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"FAIL: {r.get('error','')[:60]} | | | | |")
            continue
        m = r["memory"]
        c = r["collectives"]["counts"]
        cc = "/".join(str(int(c.get(k, 0))) for k in
                      ("all-gather", "all-reduce", "reduce-scatter",
                       "all-to-all", "collective-permute"))
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{r['compile_s']}s | {m['argument_bytes']/2**30:.2f} GiB | "
            f"{m['temp_bytes']/2**30:.2f} GiB | {cc} |")
    return hdr + "\n".join(rows) + "\n"


def _fresh_model_flops(arch, shape_name):
    """Recompute analytic MODEL_FLOPS with the current formulas."""
    try:
        from repro.configs.base import SHAPES
        from repro.configs.registry import get_config
        from repro.roofline.analysis import model_flops
        return model_flops(get_config(arch), SHAPES[shape_name])
    except Exception:   # noqa: BLE001
        return None


def roofline_table(recs, chips=256):
    hdr = ("| arch | shape | compute | memory | collective | bottleneck | "
           "MODEL_FLOPS | useful | roofline-MFU |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for r in recs:
        if r["status"] != "ok":
            continue
        ro = r["roofline"]
        mf = _fresh_model_flops(r["arch"], r["shape"]) or ro["model_flops"]
        useful = mf / (ro["flops"] * chips) if ro["flops"] else 0
        step = max(ro["compute_s"], ro["memory_s"], ro["collective_s"])
        mfu = mf / (chips * 197e12 * step) if step else 0
        rows.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(ro['compute_s'])} | "
            f"{_fmt_s(ro['memory_s'])} | {_fmt_s(ro['collective_s'])} | "
            f"**{ro['bottleneck']}** | {mf:.2e} | "
            f"{useful:.2f} | {mfu:.3f} |")
    return hdr + "\n".join(rows) + "\n"


def main():
    import sys
    recs = load(sys.argv[1])
    print("### Dry-run\n")
    print(dryrun_table(recs))
    print("\n### Roofline\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
