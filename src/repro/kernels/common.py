"""Shared helpers for the ITA Pallas kernels (mask/index math, DA update,
interpret-mode resolution)."""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core.quant import SOFTMAX_SHIFT

# --- Declared integer bounds of the ITA softmax pipeline -------------------
# These are the named facts the jaxpr range verifier (``repro.analysis``)
# consumes; every bound below is re-proven per kernel on every CI run, so
# changing one without updating the kernels fails the analysis gate.
#
# NEG_SENTINEL: the masked-logit fill. One below INT8_MIN*2, so it is
#   (a) strictly below any real requantized logit (int8 grid), and
#   (b) small enough that ``new_max - x <= 127 - (-256) = 383`` keeps the
#   DA shift argument ``k = 383 >> SOFTMAX_SHIFT = 11`` well inside
#   [0, 31] *before* the explicit min(k, 31) clamp — the subtraction can
#   never approach int32 overflow.
NEG_SENTINEL = -256
# MASK_K: shift applied to masked elements; 128 >> 31 == 0, so a masked
#   element contributes exactly nothing to sigma. Also the largest legal
#   int32 shift, which is why every DA shift amount is clamped to it.
MASK_K = 31
# U_MAX: the DA numerator ``u = 128 >> k`` is at most 128 (k == 0, the
#   row max itself). A (bq, bkv) tile therefore adds at most
#   ``2 * bkv * U_MAX`` to sigma per DA step.
U_MAX = 128
# SIGMA_INV_MAX: both DI variants produce a reciprocal in [0, 256]:
#   paper:    2^16 // sigma with sigma >= 2*U_MAX = 256 once any element
#             is live (the row max contributes u = 128, doubled), so
#             2^16 // 256 = 256 = SIGMA_INV_MAX; an all-masked row has
#             sigma == 0 -> max(sigma, 1) -> 65536, which the EN pass
#             never uses (its p is multiplied by an all-zero mask) but
#             *is* the true paper_inverse range — see PAPER_INV_MAX.
#   adaptive: 2^(e_r+8) // sigma with 2^e_r <= sigma (e_r = floor(log2
#             sigma)) gives a quotient in (128, 256]. The bound is
#             *relational* (it needs 2^e_r <= sigma), which a
#             non-relational interval analyzer cannot derive, so
#             ``adaptive_inverse`` carries an identity ``clip(.., 0,
#             SIGMA_INV_MAX)`` to make it structural.
SIGMA_INV_MAX = 256
# PAPER_INV_MAX: the raw paper DI range before the EN shift, reached only
#   on all-masked rows (sigma clamped to 1): 2^16. The EN pass bound
#   ``p = sigma_inv >> k <= PAPER_INV_MAX`` is what sizes the p*V int8
#   accumulator: bkv * PAPER_INV_MAX * 127 < 2^31 holds for bkv <= 256.
PAPER_INV_MAX = 1 << 16

# Per-backend block-size defaults, chosen by the
# ``benchmarks/bench_kernels.py --sweep`` grid (VMEM working set stays
# within one core's budget at d<=128 while the kv tile amortizes the DA
# bookkeeping; the decode kernel has no q tiling — block_q is None).
# Attention backends record (block_q, block_kv); ``int8_matmul`` records
# (block_m, block_n, block_k) — its sweep column of the same grid run.
# These replace the hardcoded defaults that used to live in
# ``attention/backends.py`` / ``int8_matmul/ops.py``; explicit
# ``block_*=`` call arguments still override per call.
BLOCK_DEFAULTS = {
    "ita_onepass_pallas": (128, 128),
    "ita_twopass_pallas": (128, 128),
    "ita_decode_pallas": (None, 128),
    "int8_matmul": (256, 128, 128),
}

# Rings/pools allocated at a multiple of this never hit the `_pad_seq`
# per-step pad-copy in the fused-attention plumbing (any block_kv that
# divides it stays pad-free). ``KVCacheState.init`` block-aligns
# capacities above one block to it.
MIN_BLOCK_KV = 128


def default_blocks(backend: str) -> tuple:
    """(block_q, block_kv) defaults for a fused *attention* backend name
    (the matmul entry records three sizes — use ``default_matmul_blocks``)."""
    blocks = BLOCK_DEFAULTS.get(backend, (128, 128))
    assert len(blocks) == 2, \
        f"{backend!r} records {len(blocks)} block sizes, not (bq, bkv); " \
        f"use default_matmul_blocks() for the matmul kernel"
    return blocks


def default_matmul_blocks() -> tuple:
    """(block_m, block_n, block_k) defaults for the int8 matmul kernel."""
    return BLOCK_DEFAULTS["int8_matmul"]

# Platforms with a compiled Pallas lowering; everything else (CPU CI
# containers) runs the kernels in interpret mode.
_COMPILED_PALLAS_PLATFORMS = ("tpu", "gpu")


def resolve_interpret(interpret: bool | None = None) -> bool:
    """Resolve the Pallas ``interpret`` flag.

    ``None`` (the default everywhere) means *auto*: interpret only when
    the detected JAX backend has no compiled Pallas lowering — so the
    fused kernels never silently run in slow interpret mode on capable
    hardware. The ``ITA_PALLAS_INTERPRET`` env var (``1``/``0``,
    ``true``/``false``) overrides auto-detection; an explicit bool
    argument wins over both.
    """
    if interpret is not None:
        return bool(interpret)
    env = os.environ.get("ITA_PALLAS_INTERPRET")
    if env is not None:
        return env.strip().lower() not in ("0", "false", "no", "")
    return jax.default_backend() not in _COMPILED_PALLAS_PLATFORMS


def tile_mask(q_tile: jax.Array, kv_tile: jax.Array, bq: int, bkv: int,
              causal: bool, window: int, kv_len: jax.Array | None,
              q_offset: jax.Array | int = 0,
              q_len: jax.Array | int | None = None):
    """Validity mask (bq, bkv) for a (q_tile, kv_tile) grid cell, computed
    from indices so the EN pass never relies on sentinel logit values.

    ``window > 0`` selects sliding-window attention (Mixtral/Gemma-local):
    key j is visible from query i iff ``i - window < j <= i``.
    ``q_offset`` shifts the queries' logical positions (decode: the new
    token lives at position ``kv_len - 1``, not 0).
    ``q_len`` masks *query rows* beyond a row's valid count (ragged
    q_len: a mixed chunked-prefill/decode batch where one kernel call
    carries rows with different real query widths — pad rows come out
    all-masked, sigma 0, output 0).
    """
    qli = q_tile * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    qi = q_offset + qli
    kj = kv_tile * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    valid = jnp.ones((bq, bkv), jnp.bool_)
    if causal or window > 0:
        valid &= qi >= kj
    if window > 0:
        valid &= (qi - kj) < window
    if kv_len is not None:
        valid &= kj < kv_len
    if q_len is not None:
        valid &= qli < q_len
    return valid


def da_update(m_ref, sigma_ref, logits_i32: jax.Array, valid: jax.Array):
    """One streaming DA step over a (bq, bkv) logits tile.

    Updates the per-row running max and running denominator stored in the
    (bq, 1) scratch refs and returns ``(u8 numerator tile, delta_shift)``
    where ``u = 128 >> k`` (int32, fits int8 for the MXU) and
    ``delta_shift`` is the correction shift the caller must apply to any
    value accumulated under the previous max (paper's multi-part update).
    """
    x = jnp.where(valid, logits_i32, NEG_SENTINEL)
    part_max = jnp.max(x, axis=1, keepdims=True)
    new_max = jnp.maximum(m_ref[...], part_max)
    delta = jnp.minimum(
        jax.lax.shift_right_logical(new_max - m_ref[...], SOFTMAX_SHIFT), 31)
    k = jax.lax.shift_right_logical(new_max - logits_i32, SOFTMAX_SHIFT)
    k = jnp.where(valid, jnp.minimum(k, 31), MASK_K)
    u = jax.lax.shift_right_logical(jnp.int32(128), k)       # 128 >> k
    # sigma accumulates the paper's 2^(8-k) = 2*u terms.
    sigma_ref[...] = jax.lax.shift_right_logical(sigma_ref[...], delta) \
        + 2 * jnp.sum(u, axis=1, keepdims=True)
    m_ref[...] = new_max
    return u, delta


def adaptive_inverse(sigma: jax.Array):
    """DI with per-row power-of-two scaling: returns (sigma_inv, e_r) with
    ``sigma_inv ~= 2^(e_r+8)/sigma`` in (128, 256] and ``e_r = floor(log2
    sigma)``. With e_r pinned to 8 this reduces to the paper's 2^16/sigma.

    The final clip is an identity on every reachable value — ``2^e_r <=
    sigma`` forces the quotient into (128, 256] — but the bound is
    relational, so the clip is what lets the non-relational range
    verifier prove ``sigma_inv <= SIGMA_INV_MAX`` structurally.
    """
    sigma = jnp.maximum(sigma, 1)
    e_r = 31 - jax.lax.clz(sigma)
    pre = jnp.maximum(e_r + 8 - 30, 0)
    sigma_inv = (jnp.int32(1) << jnp.minimum(e_r + 8 - pre, 30)) \
        // jax.lax.shift_right_logical(sigma, pre)
    return jnp.clip(sigma_inv, 0, SIGMA_INV_MAX), e_r


def paper_inverse(sigma: jax.Array):
    """DI exactly as in silicon: sigma_inv = 2^16 // sigma (16-bit),
    i.e. ``PAPER_INV_MAX // sigma`` — at most PAPER_INV_MAX (all-masked
    row, sigma clamped to 1), at most SIGMA_INV_MAX on any live row."""
    return jnp.int32(PAPER_INV_MAX) // jnp.maximum(sigma, 1)
