"""Pure-jnp oracles for the fused ITA attention kernels.

Three references:

- ``ita_attention_ref``        one-shot, paper EN semantics (p = Σ_inv >> k
                               then p·V). The twopass kernel must match this
                               exactly when given a single kv tile, and match
                               ``ita_attention_stream_ref`` exactly always.
- ``ita_attention_fused_ref``  one-shot, fused semantics (u = 128>>k, u·V,
                               Σ_inv folded into the output requant) — the
                               onepass kernel's single-tile oracle.
- ``ita_attention_stream_ref`` tile-by-tile mirror of the kernels' streaming
                               DA (and accumulator corrections), for exact
                               equality at any tiling.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import INT8_MAX, INT8_MIN, SOFTMAX_SHIFT
from repro.kernels.common import MASK_K, NEG_SENTINEL


def _full_mask(sq, skv, causal, window, kv_len, q_offset=0):
    qi = q_offset + jnp.arange(sq, dtype=jnp.int32)[:, None]
    kj = jnp.arange(skv, dtype=jnp.int32)[None, :]
    valid = jnp.ones((sq, skv), jnp.bool_)
    if causal or window > 0:
        valid &= qi >= kj
    if window > 0:
        valid &= (qi - kj) < window
    valid &= kj < kv_len
    return valid


def _logits(q_q, k_q, lmult):
    acc = jnp.einsum("bqd,bkd->bqk", q_q.astype(jnp.int32),
                     k_q.astype(jnp.int32))
    y = jnp.round(acc.astype(jnp.float32) * lmult)
    return jnp.clip(y, INT8_MIN, INT8_MAX).astype(jnp.int32)


def _k_and_sigma(logits, valid):
    x = jnp.where(valid, logits, NEG_SENTINEL)
    row_max = jnp.max(x, axis=-1, keepdims=True)
    k = jax.lax.shift_right_logical(row_max - logits, SOFTMAX_SHIFT)
    k = jnp.where(valid, jnp.minimum(k, 31), MASK_K)
    sigma = jnp.sum(2 * jax.lax.shift_right_logical(jnp.int32(128), k),
                    axis=-1, keepdims=True)
    return k, sigma, row_max


def _inverse(sigma, adaptive):
    sigma = jnp.maximum(sigma, 1)
    if adaptive:
        e_r = 31 - jax.lax.clz(sigma)
        pre = jnp.maximum(e_r + 8 - 30, 0)
        inv = (jnp.int32(1) << jnp.minimum(e_r + 8 - pre, 30)) \
            // jax.lax.shift_right_logical(sigma, pre)
    else:
        inv = (jnp.int32(1) << 16) // sigma
        e_r = jnp.full_like(inv, 8)
    return inv, e_r


def ita_attention_ref(q_q, k_q, v_q, lmult, omult, kv_len, *, causal,
                      window=0, adaptive=False, q_offset=0):
    """One-shot paper-EN reference. Returns (out int8, a int8)."""
    sq, skv = q_q.shape[1], k_q.shape[1]
    valid = _full_mask(sq, skv, causal, window, kv_len, q_offset)[None]
    logits = _logits(q_q, k_q, lmult)
    k, sigma, _ = _k_and_sigma(logits, valid)
    inv, e_r = _inverse(sigma, adaptive)
    p = jax.lax.shift_right_logical(inv, k)                       # EN
    acc = jnp.einsum("bqk,bkd->bqd", p, v_q.astype(jnp.int32))
    y = jnp.round(acc.astype(jnp.float32)
                  * jnp.exp2(-e_r.astype(jnp.float32)) * omult)
    out = jnp.clip(y, INT8_MIN, INT8_MAX).astype(jnp.int8)
    return out, logits.astype(jnp.int8)


def ita_attention_fused_ref(q_q, k_q, v_q, lmult, omult, kv_len, *, causal,
                            window=0, adaptive=True, q_offset=0):
    """One-shot fused-EN reference (u = 128>>k numerators)."""
    sq, skv = q_q.shape[1], k_q.shape[1]
    valid = _full_mask(sq, skv, causal, window, kv_len, q_offset)[None]
    logits = _logits(q_q, k_q, lmult)
    k, sigma, _ = _k_and_sigma(logits, valid)
    inv, e_r = _inverse(sigma, adaptive)
    u = jax.lax.shift_right_logical(jnp.int32(128), k)
    acc = jnp.einsum("bqk,bkd->bqd", u, v_q.astype(jnp.int32)
                     ).astype(jnp.float32)
    scale = 2.0 * inv.astype(jnp.float32) * jnp.exp2(
        -(e_r + 8).astype(jnp.float32)) * omult
    y = jnp.round(acc * scale)
    return jnp.clip(y, INT8_MIN, INT8_MAX).astype(jnp.int8)


def ita_attention_stream_ref(q_q, k_q, v_q, lmult, omult, kv_len, *, causal,
                             window=0, adaptive=True, block_kv=128,
                             kind="onepass", q_offset=0):
    """Tile-by-tile mirror of the kernels (exact-match oracle)."""
    bh, sq, d = q_q.shape
    skv = k_q.shape[1]
    n_kv = -(-skv // block_kv)
    valid_full = _full_mask(sq, skv, causal, window, kv_len, q_offset)[None]
    logits = _logits(q_q, k_q, lmult)

    run_max = jnp.full((bh, sq, 1), NEG_SENTINEL, jnp.int32)
    run_sigma = jnp.zeros((bh, sq, 1), jnp.int32)
    acc = jnp.zeros((bh, sq, d), jnp.float32)
    for j in range(n_kv):
        sl = slice(j * block_kv, min((j + 1) * block_kv, skv))
        lg, vd = logits[..., sl], valid_full[..., sl]
        x = jnp.where(vd, lg, NEG_SENTINEL)
        part_max = jnp.max(x, axis=-1, keepdims=True)
        new_max = jnp.maximum(run_max, part_max)
        delta = jnp.minimum(jax.lax.shift_right_logical(
            new_max - run_max, SOFTMAX_SHIFT), 31)
        k = jax.lax.shift_right_logical(new_max - lg, SOFTMAX_SHIFT)
        k = jnp.where(vd, jnp.minimum(k, 31), MASK_K)
        u = jax.lax.shift_right_logical(jnp.int32(128), k)
        run_sigma = jax.lax.shift_right_logical(run_sigma, delta) \
            + 2 * jnp.sum(u, axis=-1, keepdims=True)
        run_max = new_max
        if kind == "onepass":
            pv = jnp.einsum("bqk,bkd->bqd", u, v_q[:, sl].astype(jnp.int32))
            acc = acc * jnp.exp2(-delta.astype(jnp.float32)) \
                + pv.astype(jnp.float32)

    inv, e_r = _inverse(run_sigma, adaptive)
    if kind == "onepass":
        scale = 2.0 * inv.astype(jnp.float32) * jnp.exp2(
            -(e_r + 8).astype(jnp.float32)) * omult
        y = jnp.round(acc * scale)
        return jnp.clip(y, INT8_MIN, INT8_MAX).astype(jnp.int8)

    # twopass: EN with the final streamed stats (numerators exact).
    k = jax.lax.shift_right_logical(run_max - logits, SOFTMAX_SHIFT)
    k = jnp.where(valid_full, jnp.minimum(k, 31), MASK_K)
    p = jax.lax.shift_right_logical(inv, k)
    acc2 = jnp.einsum("bqk,bkd->bqd", p, v_q.astype(jnp.int32))
    y = jnp.round(acc2.astype(jnp.float32)
                  * jnp.exp2(-e_r.astype(jnp.float32)) * omult)
    return jnp.clip(y, INT8_MIN, INT8_MAX).astype(jnp.int8)


def float_attention_ref(q, k, v, *, causal, window=0, kv_len=None,
                        q_offset=0):
    """f32 attention oracle for end-to-end accuracy comparisons."""
    d = q.shape[-1]
    kv_len = k.shape[1] if kv_len is None else kv_len
    valid = _full_mask(q.shape[1], k.shape[1], causal, window, kv_len,
                       q_offset)[None]
    s = jnp.einsum("bqd,bkd->bqk", q, k) / np.sqrt(d)
    s = jnp.where(valid, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(valid, p, 0.0)
    return jnp.einsum("bqk,bkd->bqd", p, v)
