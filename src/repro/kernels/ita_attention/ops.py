"""Jitted public wrapper for the fused ITA attention kernels.

Handles (batch, heads, seq, dim) layouts, GQA head-group broadcast, padding
to block multiples and the quantization-scale plumbing:

    logit_mult = s_q * s_k / (sqrt(d) * EPS_MAX)   (requant onto ITA's grid)
    out_mult   = s_v / s_out
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import EPS_MAX
from repro.kernels.ita_attention.kernel import (ita_attention_onepass,
                                                ita_attention_twopass)


def _pad_seq(x, mult):
    pad = (-x.shape[1]) % mult
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    return x


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "mode", "adaptive", "block_q", "block_kv",
    "interpret"))
def ita_attention(q_q: jax.Array, k_q: jax.Array, v_q: jax.Array,
                  s_q: jax.Array | float, s_k: jax.Array | float,
                  s_v: jax.Array | float, s_out: jax.Array | float, *,
                  q_offset: jax.Array | int = 0, kv_len: jax.Array | int | None = None,
                  causal: bool = True, window: int = 0, mode: str = "onepass",
                  adaptive: bool = True, block_q: int = 128,
                  block_kv: int = 128, interpret: bool = True) -> jax.Array:
    """Quantized multi-head attention with the ITA integer softmax.

    ``q_q``: (B, Hq, Sq, D) int8; ``k_q``/``v_q``: (B, Hkv, Skv, D) int8.
    GQA: Hkv must divide Hq; KV heads are broadcast per group.
    ``q_offset``: logical position of query 0 (decode: valid_kv - Sq).
    ``kv_len``: valid prefix of the KV cache (defaults to Skv).
    Returns (B, Hq, Sq, D) int8 at scale ``s_out``.
    """
    b, hq, sq, d = q_q.shape
    hkv, skv = k_q.shape[1], k_q.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    if hkv != hq:
        rep = hq // hkv
        k_q = jnp.repeat(k_q, rep, axis=1)
        v_q = jnp.repeat(v_q, rep, axis=1)

    qf = q_q.reshape(b * hq, sq, d)
    kf = k_q.reshape(b * hq, skv, d)
    vf = v_q.reshape(b * hq, skv, d)

    bq = min(block_q, max(8, sq))
    bkv = min(block_kv, max(128, skv)) if skv >= 128 else skv
    qf = _pad_seq(qf, bq)
    kf = _pad_seq(kf, bkv)
    vf = _pad_seq(vf, bkv)

    lmult = jnp.asarray(s_q, jnp.float32) * jnp.asarray(s_k, jnp.float32) \
        / (np.sqrt(d) * EPS_MAX)
    omult = jnp.asarray(s_v, jnp.float32) / jnp.asarray(s_out, jnp.float32)

    kv_len = skv if kv_len is None else kv_len
    if mode == "onepass":
        out = ita_attention_onepass(
            qf, kf, vf, lmult, omult, kv_len, q_offset=q_offset,
            causal=causal, window=window, adaptive=adaptive, block_q=bq,
            block_kv=bkv, interpret=interpret)
    else:
        out, _ = ita_attention_twopass(
            qf, kf, vf, lmult, omult, kv_len, q_offset=q_offset,
            causal=causal, window=window, adaptive=adaptive, block_q=bq,
            block_kv=bkv, interpret=interpret)
    return out[:, :sq].reshape(b, hq, sq, d)
