"""Jitted plumbing for the fused ITA attention kernels.

This module is the thin compute layer behind the Pallas-backed entries of
the ``repro.attention`` backend registry (``ita_onepass_pallas``,
``ita_twopass_pallas``, ``ita_decode_pallas``) — there is no public
attention entry point here; call ``repro.attention.dispatch``.

``fused_attention`` handles (batch, heads, seq, dim) layouts, GQA
head-group sharing (via kernel index maps — no broadcast copies), padding
to block multiples and the quantization-scale plumbing:

    logit_mult = s_q * s_k / (sqrt(d) * EPS_MAX)   (requant onto ITA's grid)
    out_mult   = s_v / s_out

Scales may be scalars (per-tensor, the QAT-calibrated path) or per-head
vectors — ``s_q``/``s_out`` of shape (Hq,), ``s_k``/``s_v`` of shape (Hkv,)
(per-head KV-cache quantization); the multipliers are resolved to one
value per (batch·head) kernel row.

Kinds: ``onepass`` (flash-style), ``twopass`` (paper-faithful A matrix in
HBM), ``decode`` (onepass specialised to a single query tile against a KV
ring buffer — skips q-tiling and invalid KV tiles).

``interpret=None`` auto-resolves via ``repro.kernels.common.
resolve_interpret`` — compiled on TPU/GPU, interpret elsewhere,
``ITA_PALLAS_INTERPRET`` env override.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import EPS_MAX
from repro.kernels.common import resolve_interpret
from repro.kernels.ita_attention.kernel import (ita_attention_decode,
                                                ita_attention_decode_paged,
                                                ita_attention_onepass,
                                                ita_attention_onepass_paged,
                                                ita_attention_twopass)

KINDS = ("onepass", "twopass", "decode")


def _pad_seq(x, mult, hot: bool = False):
    """Zero-pad the seq axis (axis 1, any rank) to a multiple of ``mult``.

    ``hot=True`` marks the decode KV ring: padding there would be a
    per-step copy of the whole ring, so it is *statically forbidden* —
    ``KVCacheState.init`` block-aligns ring capacities (MIN_BLOCK_KV),
    making the pad a guaranteed no-op on the decode hot path, and this
    assert keeps it that way."""
    pad = (-x.shape[1]) % mult
    if pad and hot:
        raise ValueError(
            f"decode KV ring capacity {x.shape[1]} is not a block_kv="
            f"{mult} multiple — a per-step pad-copy of the whole ring; "
            f"allocate through KVCacheState.init (block-aligned) or pass "
            f"a block_kv that divides the capacity")
    if pad:
        x = jnp.pad(x, [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2))
    return x


def _per_head(s, h):
    """Scalar -> (h,); (h,) passes through."""
    s = jnp.asarray(s, jnp.float32).reshape(-1)
    if s.shape[0] == 1:
        return jnp.broadcast_to(s, (h,))
    assert s.shape[0] == h, (s.shape, h)
    return s


def _per_row(x, b, h):
    """Expand a dynamic decode offset to one value per (batch·head) kernel
    row (b-major, head-minor): scalars broadcast, (B,) per-sequence
    vectors (the ragged path) repeat per head."""
    x = jnp.asarray(x, jnp.int32).reshape(-1)
    if x.shape[0] == 1:
        return jnp.broadcast_to(x, (b * h,))
    assert x.shape[0] == b, (x.shape, b)
    return jnp.repeat(x, h)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "kind", "adaptive", "block_q", "block_kv",
    "kv_native", "interpret"))
def _fused(q_q, k_q, v_q, s_q, s_k, s_v, s_out, *, q_offset, kv_len,
           causal, window, kind, adaptive, block_q, block_kv, kv_native,
           interpret, page_table=None, q_lens=None):
    b, hq, sq, d = q_q.shape
    if page_table is not None:                  # paged pool (P, page, G, hd)
        hkv = k_q.shape[2]
    elif kv_native:
        skv, hkv = k_q.shape[1], k_q.shape[2]
    else:
        hkv, skv = k_q.shape[1], k_q.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    rep = hq // hkv

    # per-(batch*head) requant multipliers (rows are b-major, head-minor)
    sk_h = jnp.repeat(_per_head(s_k, hkv), rep)
    sv_h = jnp.repeat(_per_head(s_v, hkv), rep)
    lmult = _per_head(s_q, hq) * sk_h / (np.sqrt(d) * EPS_MAX)
    omult = sv_h / _per_head(s_out, hq)
    lmult = jnp.tile(lmult, b)
    omult = jnp.tile(omult, b)

    if page_table is not None:
        # Pages are blocks: block_kv == page_size by construction, so the
        # pool is never padded/copied — tiles stream straight from the
        # arena through the page-table index maps.
        bq = min(block_q, max(8, sq))
        qf = _pad_seq(q_q.reshape(b * hq, sq, d), bq)
        skv = page_table.shape[1] * k_q.shape[1]
        kv_len = _per_row(skv if kv_len is None else kv_len, b, hq)
        q_offset = _per_row(q_offset, b, hq)
        q_len = None if q_lens is None else _per_row(q_lens, b, hq)
        common = dict(q_offset=q_offset, q_len=q_len, causal=causal,
                      window=window, adaptive=adaptive, kv_rep=rep, hq=hq,
                      interpret=interpret)
        if kind == "decode":
            out = ita_attention_decode_paged(
                qf, k_q, v_q, page_table, lmult, omult, kv_len, **common)
        else:
            out = ita_attention_onepass_paged(
                qf, k_q, v_q, page_table, lmult, omult, kv_len, block_q=bq,
                **common)
        return out[:, :sq].reshape(b, hq, sq, d)

    bq = min(block_q, max(8, sq))
    bkv = min(block_kv, max(128, skv)) if skv >= 128 else skv
    qf = _pad_seq(q_q.reshape(b * hq, sq, d), bq)
    if kv_native:
        kf = _pad_seq(k_q, bkv, hot=kind == "decode")
        vf = _pad_seq(v_q, bkv, hot=kind == "decode")
    else:
        kf = _pad_seq(k_q.reshape(b * hkv, skv, d), bkv,
                      hot=kind == "decode")
        vf = _pad_seq(v_q.reshape(b * hkv, skv, d), bkv,
                      hot=kind == "decode")

    kv_len = _per_row(skv if kv_len is None else kv_len, b, hq)
    q_offset = _per_row(q_offset, b, hq)
    q_len = None if q_lens is None else _per_row(q_lens, b, hq)
    if kind == "decode":
        out = ita_attention_decode(
            qf, kf, vf, lmult, omult, kv_len, q_offset=q_offset,
            q_len=q_len, causal=causal, window=window, adaptive=adaptive,
            block_kv=bkv, kv_rep=rep,
            hq=hq if kv_native else None, interpret=interpret)
    elif kind == "onepass":
        out = ita_attention_onepass(
            qf, kf, vf, lmult, omult, kv_len, q_offset=q_offset,
            q_len=q_len, causal=causal, window=window, adaptive=adaptive,
            block_q=bq, block_kv=bkv, kv_rep=rep,
            hq=hq if kv_native else None, interpret=interpret)
    else:
        out, _ = ita_attention_twopass(
            qf, kf, vf, lmult, omult, kv_len, q_offset=q_offset,
            causal=causal, window=window, adaptive=adaptive, block_q=bq,
            block_kv=bkv, kv_rep=rep, interpret=interpret)
    return out[:, :sq].reshape(b, hq, sq, d)


def fused_attention(q_q: jax.Array, k_q: jax.Array, v_q: jax.Array,
                    s_q, s_k, s_v, s_out, *,
                    q_offset: jax.Array | int = 0,
                    kv_len: jax.Array | int | None = None,
                    q_lens: jax.Array | None = None,
                    causal: bool = True, window: int = 0,
                    kind: str = "onepass", adaptive: bool = True,
                    block_q: int = 128, block_kv: int = 128,
                    kv_native: bool = False,
                    page_table: jax.Array | None = None,
                    interpret: bool | None = None) -> jax.Array:
    """Quantized multi-head attention with the ITA integer softmax.

    ``q_q``: (B, Hq, Sq, D) int8; ``k_q``/``v_q``: (B, Hkv, Skv, D) int8
    or, with ``kv_native=True`` (``kind`` decode or onepass), cache-native
    (B, Skv, Hkv, D) ring buffers (consumed in place via kernel index
    maps, no transpose/broadcast copies). GQA: Hkv must divide Hq; KV
    heads are shared per group via index maps — the broadcast never
    materializes.
    ``page_table`` (B, n_pages) int32 switches K/V to a shared **paged
    pool** ``(num_pages, page_size, Hkv, D)``: logical KV tile ``j`` of
    sequence ``b`` streams from physical page ``page_table[b, j]``
    (scalar-prefetch index maps; ``block_kv`` is the page size — the
    ``block_kv`` argument is ignored). Bit-identical to the contiguous
    ring path when ``page_size`` equals the ring's ``block_kv``.
    ``q_offset``: logical position of query 0 (decode: valid_kv - Sq).
    ``kv_len``: valid prefix of the KV cache (defaults to Skv).
    Both accept (B,) per-sequence vectors — the ragged batch path: each
    (batch·head) kernel row masks/tile-skips against its own prefix.
    ``q_lens`` (B,) extends the raggedness to the query axis: row ``b``
    treats only its first ``q_lens[b]`` of the ``Sq`` query rows as real
    (the rest emit zeros) — one mixed call serves decode rows (1 query)
    next to chunked-prefill rows (``chunk`` queries).
    Returns (B, Hq, Sq, D) int8 at scale ``s_out``.
    """
    assert kind in KINDS, kind
    assert not (kv_native and kind == "twopass"), \
        "cache-native KV layout serves the onepass/decode kernels only"
    assert not (page_table is not None and kind == "twopass"), \
        "the paged pool serves the onepass/decode kernels only"
    assert not (q_lens is not None and kind == "twopass"), \
        "ragged q_len serves the onepass/decode kernels only"
    return _fused(q_q, k_q, v_q, s_q, s_k, s_v, s_out, q_offset=q_offset,
                  kv_len=kv_len, causal=causal, window=window, kind=kind,
                  adaptive=adaptive, block_q=block_q, block_kv=block_kv,
                  kv_native=kv_native, page_table=page_table,
                  q_lens=q_lens, interpret=resolve_interpret(interpret))
