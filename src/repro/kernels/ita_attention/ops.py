"""Jitted public wrapper for the fused ITA attention kernels.

Handles (batch, heads, seq, dim) layouts, GQA head-group sharing (via
kernel index maps — no broadcast copies), padding to block multiples and
the quantization-scale plumbing:

    logit_mult = s_q * s_k / (sqrt(d) * EPS_MAX)   (requant onto ITA's grid)
    out_mult   = s_v / s_out

Scales may be scalars (per-tensor, the QAT-calibrated path) or per-head
vectors — ``s_q``/``s_out`` of shape (Hq,), ``s_k``/``s_v`` of shape (Hkv,)
(per-head KV-cache quantization, see ``repro.runtime.kv_cache``); the
multipliers are resolved to one value per (batch·head) kernel row.

Modes: ``onepass`` (flash-style, default), ``twopass`` (paper-faithful A
matrix in HBM), ``decode`` (onepass specialised to a single query tile
against a KV ring buffer — skips q-tiling and invalid KV tiles).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import EPS_MAX
from repro.kernels.ita_attention.kernel import (ita_attention_decode,
                                                ita_attention_onepass,
                                                ita_attention_twopass)


def _pad_seq(x, mult):
    """Zero-pad the seq axis (axis 1, any rank) to a multiple of ``mult``."""
    pad = (-x.shape[1]) % mult
    if pad:
        x = jnp.pad(x, [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2))
    return x


def _per_head(s, h):
    """Scalar -> (h,); (h,) passes through."""
    s = jnp.asarray(s, jnp.float32).reshape(-1)
    if s.shape[0] == 1:
        return jnp.broadcast_to(s, (h,))
    assert s.shape[0] == h, (s.shape, h)
    return s


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "mode", "adaptive", "block_q", "block_kv",
    "kv_layout", "interpret"))
def ita_attention(q_q: jax.Array, k_q: jax.Array, v_q: jax.Array,
                  s_q: jax.Array | float, s_k: jax.Array | float,
                  s_v: jax.Array | float, s_out: jax.Array | float, *,
                  q_offset: jax.Array | int = 0, kv_len: jax.Array | int | None = None,
                  causal: bool = True, window: int = 0, mode: str = "onepass",
                  adaptive: bool = True, block_q: int = 128,
                  block_kv: int = 128, kv_layout: str = "bhsd",
                  interpret: bool = True) -> jax.Array:
    """Quantized multi-head attention with the ITA integer softmax.

    ``q_q``: (B, Hq, Sq, D) int8; ``k_q``/``v_q``: (B, Hkv, Skv, D) int8
    (``kv_layout="bhsd"``) or, for ``mode="decode"``, cache-native
    (B, Skv, Hkv, D) ring buffers (``kv_layout="bsgd"`` — consumed in
    place via kernel index maps, no transpose/broadcast copies).
    GQA: Hkv must divide Hq; KV heads are shared per group via index
    maps — the broadcast never materializes.
    ``q_offset``: logical position of query 0 (decode: valid_kv - Sq).
    ``kv_len``: valid prefix of the KV cache (defaults to Skv).
    Returns (B, Hq, Sq, D) int8 at scale ``s_out``.
    """
    b, hq, sq, d = q_q.shape
    if kv_layout == "bsgd":
        assert mode == "decode", "bsgd layout is decode-only"
        skv, hkv = k_q.shape[1], k_q.shape[2]
    else:
        hkv, skv = k_q.shape[1], k_q.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    rep = hq // hkv

    # per-(batch*head) requant multipliers (rows are b-major, head-minor)
    sk_h = jnp.repeat(_per_head(s_k, hkv), rep)
    sv_h = jnp.repeat(_per_head(s_v, hkv), rep)
    lmult = _per_head(s_q, hq) * sk_h / (np.sqrt(d) * EPS_MAX)
    omult = sv_h / _per_head(s_out, hq)
    lmult = jnp.tile(lmult, b)
    omult = jnp.tile(omult, b)

    bq = min(block_q, max(8, sq))
    bkv = min(block_kv, max(128, skv)) if skv >= 128 else skv
    qf = _pad_seq(q_q.reshape(b * hq, sq, d), bq)
    if kv_layout == "bsgd":
        kf = _pad_seq(k_q, bkv)
        vf = _pad_seq(v_q, bkv)
    else:
        kf = _pad_seq(k_q.reshape(b * hkv, skv, d), bkv)
        vf = _pad_seq(v_q.reshape(b * hkv, skv, d), bkv)

    kv_len = skv if kv_len is None else kv_len
    if mode == "decode":
        out = ita_attention_decode(
            qf, kf, vf, lmult, omult, kv_len, q_offset=q_offset,
            causal=causal, window=window, adaptive=adaptive,
            block_kv=bkv, kv_rep=rep,
            hq=hq if kv_layout == "bsgd" else None, interpret=interpret)
    elif mode == "onepass":
        out = ita_attention_onepass(
            qf, kf, vf, lmult, omult, kv_len, q_offset=q_offset,
            causal=causal, window=window, adaptive=adaptive, block_q=bq,
            block_kv=bkv, kv_rep=rep, interpret=interpret)
    else:
        out, _ = ita_attention_twopass(
            qf, kf, vf, lmult, omult, kv_len, q_offset=q_offset,
            causal=causal, window=window, adaptive=adaptive, block_q=bq,
            block_kv=bkv, kv_rep=rep, interpret=interpret)
    return out[:, :sq].reshape(b, hq, sq, d)
