"""Fused ITA attention Pallas kernels: Q·Kᵀ → streaming integer softmax → A·V.

Two dataflows, both with the ITA integer softmax:

- ``onepass`` (beyond-paper, flash-style): the int8 attention tile never
  leaves VMEM. Per (q-tile, kv-tile): int8 Q·Kᵀ on the MXU → requant to the
  ITA logit grid → DA update of the per-row (max, Σ) stats → the *unnormal-
  ized* numerators ``u = 128 >> k`` (int8!) multiply V on the MXU and add
  into a running accumulator which is shift-corrected when the row max
  grows (the same correction silicon applies to Σ). DI happens once per row
  at the final kv tile and folds into the output requant as a per-row
  multiplier. HBM traffic for the S×S matrix: zero.

- ``twopass`` (paper-faithful): pass 1 streams Q·Kᵀ tiles, writes the int8
  attention matrix A to HBM exactly once and accumulates the (max, Σ) row
  stats on the fly (DA); DI inverts Σ per row; pass 2 re-streams A, norma-
  lizes each element with a pure shift (EN, ``p = Σ_inv >> k``) and feeds
  the MXU for A·V. This reproduces ITA's memory traffic: A written once,
  read once, softmax adds **no** extra passes.

Integer semantics notes:
- ``Σ p ≤ 2^(e_r)``... for paper mode (e_r = 8): ``Σ p ≤ 256`` so the A·V
  accumulator is bounded by 2^15 — f32 scratch holds it exactly (ints are
  exact in f32 below 2^24), so paper mode remains bit-exact integer.
- onepass uses ``u = 128 >> k`` so the numerator operand fits int8 for the
  MXU; the missing factor 2 folds into the output requant.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant import INT8_MAX, INT8_MIN, SOFTMAX_SHIFT
from repro.kernels.common import (MASK_K, NEG_SENTINEL, adaptive_inverse,
                                  da_update, paper_inverse, tile_mask)


def _qk_logits(q_tile, k_tile, mult):
    """int8 Q (bq,d) x int8 K (bkv,d)^T -> int32 -> requant to int8 logit
    grid (returned widened to int32)."""
    acc = jax.lax.dot_general(q_tile, k_tile, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.int32)
    y = jnp.round(acc.astype(jnp.float32) * mult)
    return jnp.clip(y, INT8_MIN, INT8_MAX).astype(jnp.int32)


def onepass_kernel(q_ref, k_ref, v_ref, lmult_ref, omult_ref, meta_ref,
                   o_ref, m_ref, sigma_ref, acc_ref,
                   *, causal: bool, window: int, adaptive: bool,
                   bq: int, bkv: int):
    i, j = pl.program_id(1), pl.program_id(2)
    last_j = pl.num_programs(2) - 1

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_SENTINEL)
        sigma_ref[...] = jnp.zeros_like(sigma_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    logits = _qk_logits(q_ref[0], k_ref[0], lmult_ref[0, 0])
    valid = tile_mask(i, j, bq, bkv, causal, window, meta_ref[0, 0],
                      meta_ref[0, 1])
    u, delta = da_update(m_ref, sigma_ref, logits, valid)
    # Correct the running A·V accumulator for the max update (exact in f32:
    # multiplying by 2^-delta loses nothing, unlike the integer Σ shift).
    corr = jnp.exp2(-delta.astype(jnp.float32))
    # u in [0, 128] — packs into uint8 on the MXU (int32 here: interpret
    # mode validates semantics; XLA emits the s8/u8 MXU path on TPU).
    pv = jax.lax.dot_general(u, v_ref[0].astype(jnp.int32),
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.int32)
    acc_ref[...] = acc_ref[...] * corr + pv.astype(jnp.float32)

    @pl.when(j == last_j)
    def _finalize():
        if adaptive:
            inv, e_r = adaptive_inverse(sigma_ref[...])
        else:
            inv = paper_inverse(sigma_ref[...])
            e_r = jnp.full_like(inv, 8)
        # out = acc * 2 * inv * 2^-(e_r+8) * (s_v/s_out); the 2 restores the
        # halved numerator unit (u = 128>>k vs the paper's 256>>k).
        scale = 2.0 * inv.astype(jnp.float32) * jnp.exp2(
            -(e_r + 8).astype(jnp.float32)) * omult_ref[0, 0]
        y = jnp.round(acc_ref[...] * scale)
        o_ref[0] = jnp.clip(y, INT8_MIN, INT8_MAX).astype(jnp.int8)


def qk_da_kernel(q_ref, k_ref, lmult_ref, meta_ref, a_ref, max_o_ref,
                 sigma_o_ref, m_ref, sigma_ref,
                 *, causal: bool, window: int, bq: int, bkv: int):
    """Two-pass, pass 1: logits to HBM once + DA stats."""
    i, j = pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_SENTINEL)
        sigma_ref[...] = jnp.zeros_like(sigma_ref)

    logits = _qk_logits(q_ref[0], k_ref[0], lmult_ref[0, 0])
    valid = tile_mask(i, j, bq, bkv, causal, window, meta_ref[0, 0],
                      meta_ref[0, 1])
    da_update(m_ref, sigma_ref, logits, valid)
    a_ref[0] = logits.astype(jnp.int8)

    @pl.when(j == pl.num_programs(2) - 1)
    def _emit_stats():
        max_o_ref[0] = m_ref[...][:, 0]
        sigma_o_ref[0] = sigma_ref[...][:, 0]


def av_en_kernel(a_ref, inv_ref, er_ref, max_ref, v_ref, omult_ref,
                 meta_ref, o_ref, acc_ref,
                 *, causal: bool, window: int, bq: int, bkv: int):
    """Two-pass, pass 2: re-stream A, EN by pure shifts, A·V on the MXU."""
    i, j = pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[0].astype(jnp.int32)
    row_max = max_ref[0][:, None]
    valid = tile_mask(i, j, bq, bkv, causal, window, meta_ref[0, 0],
                      meta_ref[0, 1])
    k = jax.lax.shift_right_logical(row_max - a, SOFTMAX_SHIFT)
    k = jnp.where(valid, jnp.minimum(k, 31), MASK_K)
    p = jax.lax.shift_right_logical(inv_ref[0][:, None], k)   # EN: p ≤ 256
    pv = jax.lax.dot_general(p, v_ref[0].astype(jnp.int32),
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.int32)
    acc_ref[...] += pv.astype(jnp.float32)       # exact: |acc| < 2^24

    @pl.when(j == pl.num_programs(2) - 1)
    def _finalize():
        e_r = er_ref[0][:, None].astype(jnp.float32)
        y = jnp.round(acc_ref[...] * jnp.exp2(-e_r) * omult_ref[0, 0])
        o_ref[0] = jnp.clip(y, INT8_MIN, INT8_MAX).astype(jnp.int8)


def _specs_bh(block, index):
    return pl.BlockSpec(block, index)


def ita_attention_onepass(q_q, k_q, v_q, logit_mult, out_mult, kv_len, *,
                          q_offset=0, causal: bool, window: int = 0,
                          adaptive: bool = True, block_q: int = 128,
                          block_kv: int = 128, interpret: bool = True):
    """q (BH, Sq, D) int8; k/v (BH, Skv, D) int8; returns (BH, Sq, D) int8."""
    bh, sq, d = q_q.shape
    skv = k_q.shape[1]
    bq, bkv = min(block_q, sq), min(block_kv, skv)
    assert sq % bq == 0 and skv % bkv == 0
    kern = functools.partial(onepass_kernel, causal=causal, window=window,
                             adaptive=adaptive, bq=bq, bkv=bkv)
    lmult = jnp.asarray(logit_mult, jnp.float32).reshape(1, 1)
    omult = jnp.asarray(out_mult, jnp.float32).reshape(1, 1)
    meta = jnp.stack([jnp.asarray(kv_len, jnp.int32),
                      jnp.asarray(q_offset, jnp.int32)]).reshape(1, 2)
    return pl.pallas_call(
        kern,
        grid=(bh, sq // bq, skv // bkv),
        in_specs=[
            _specs_bh((1, bq, d), lambda b, i, j: (b, i, 0)),
            _specs_bh((1, bkv, d), lambda b, i, j: (b, j, 0)),
            _specs_bh((1, bkv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, 1), lambda b, i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda b, i, j: (0, 0)),
            pl.BlockSpec((1, 2), lambda b, i, j: (0, 0)),
        ],
        out_specs=_specs_bh((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), jnp.int8),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.int32),
                        pltpu.VMEM((bq, 1), jnp.int32),
                        pltpu.VMEM((bq, d), jnp.float32)],
        interpret=interpret,
    )(q_q, k_q, v_q, lmult, omult, meta)


def ita_attention_twopass(q_q, k_q, v_q, logit_mult, out_mult, kv_len, *,
                          q_offset=0, causal: bool, window: int = 0,
                          adaptive: bool = False, block_q: int = 128,
                          block_kv: int = 128, interpret: bool = True):
    """Paper-faithful dataflow. Returns (out int8, a_mat int8) — A is the
    materialized int8 attention matrix (written once, read once)."""
    bh, sq, d = q_q.shape
    skv = k_q.shape[1]
    bq, bkv = min(block_q, sq), min(block_kv, skv)
    assert sq % bq == 0 and skv % bkv == 0
    lmult = jnp.asarray(logit_mult, jnp.float32).reshape(1, 1)
    omult = jnp.asarray(out_mult, jnp.float32).reshape(1, 1)
    meta = jnp.stack([jnp.asarray(kv_len, jnp.int32),
                      jnp.asarray(q_offset, jnp.int32)]).reshape(1, 2)

    k1 = functools.partial(qk_da_kernel, causal=causal, window=window,
                           bq=bq, bkv=bkv)
    a_mat, row_max, sigma = pl.pallas_call(
        k1,
        grid=(bh, sq // bq, skv // bkv),
        in_specs=[
            _specs_bh((1, bq, d), lambda b, i, j: (b, i, 0)),
            _specs_bh((1, bkv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, 1), lambda b, i, j: (0, 0)),
            pl.BlockSpec((1, 2), lambda b, i, j: (0, 0)),
        ],
        out_specs=[
            _specs_bh((1, bq, bkv), lambda b, i, j: (b, i, j)),
            _specs_bh((1, bq), lambda b, i, j: (b, i)),
            _specs_bh((1, bq), lambda b, i, j: (b, i)),
        ],
        out_shape=[jax.ShapeDtypeStruct((bh, sq, skv), jnp.int8),
                   jax.ShapeDtypeStruct((bh, sq), jnp.int32),
                   jax.ShapeDtypeStruct((bh, sq), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.int32),
                        pltpu.VMEM((bq, 1), jnp.int32)],
        interpret=interpret,
    )(q_q, k_q, lmult, meta)

    # DI — one integer inversion per row (two serial dividers in silicon,
    # a vectorized integer divide here), overlapped by XLA with pass 2 setup.
    if adaptive:
        sigma_inv, e_r = adaptive_inverse(sigma)
    else:
        sigma_inv = paper_inverse(sigma)
        e_r = jnp.full_like(sigma_inv, 8)

    k2 = functools.partial(av_en_kernel, causal=causal, window=window,
                           bq=bq, bkv=bkv)
    out = pl.pallas_call(
        k2,
        grid=(bh, sq // bq, skv // bkv),
        in_specs=[
            _specs_bh((1, bq, bkv), lambda b, i, j: (b, i, j)),
            _specs_bh((1, bq), lambda b, i, j: (b, i)),
            _specs_bh((1, bq), lambda b, i, j: (b, i)),
            _specs_bh((1, bq), lambda b, i, j: (b, i)),
            _specs_bh((1, bkv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, 1), lambda b, i, j: (0, 0)),
            pl.BlockSpec((1, 2), lambda b, i, j: (0, 0)),
        ],
        out_specs=_specs_bh((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), jnp.int8),
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)],
        interpret=interpret,
    )(a_mat, sigma_inv, e_r, row_max, v_q, omult, meta)
    return out, a_mat
