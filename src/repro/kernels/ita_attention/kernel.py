"""Fused ITA attention Pallas kernels: Q·Kᵀ → streaming integer softmax → A·V.

Two dataflows, both with the ITA integer softmax:

- ``onepass`` (beyond-paper, flash-style): the int8 attention tile never
  leaves VMEM. Per (q-tile, kv-tile): int8 Q·Kᵀ on the MXU → requant to the
  ITA logit grid → DA update of the per-row (max, Σ) stats → the *unnormal-
  ized* numerators ``u = 128 >> k`` (int8!) multiply V on the MXU and add
  into a running accumulator which is shift-corrected when the row max
  grows (the same correction silicon applies to Σ). DI happens once per row
  at the final kv tile and folds into the output requant as a per-row
  multiplier. HBM traffic for the S×S matrix: zero.

- ``twopass`` (paper-faithful): pass 1 streams Q·Kᵀ tiles, writes the int8
  attention matrix A to HBM exactly once and accumulates the (max, Σ) row
  stats on the fly (DA); DI inverts Σ per row; pass 2 re-streams A, norma-
  lizes each element with a pure shift (EN, ``p = Σ_inv >> k``) and feeds
  the MXU for A·V. This reproduces ITA's memory traffic: A written once,
  read once, softmax adds **no** extra passes.

Integer semantics notes:
- ``Σ p ≤ 2^(e_r)``... for paper mode (e_r = 8): ``Σ p ≤ 256`` so the A·V
  accumulator is bounded by 2^15 — f32 scratch holds it exactly (ints are
  exact in f32 below 2^24), so paper mode remains bit-exact integer.
- onepass uses ``u = 128 >> k`` so the numerator operand fits int8 for the
  MXU; the missing factor 2 folds into the output requant.

- ``decode`` (serving): the onepass dataflow specialised to incremental
  decode against a KV-cache ring buffer. The q grid dimension disappears
  (one tile holds all ``sq <= 8`` queries), KV tiles wholly beyond the
  cache's valid prefix are *skipped* — with a max_len ring only
  ``ceil(kv_len/bkv)`` of the tiles do work — and the requant multipliers
  are per-(batch·head) rows so per-head cache quantization scales flow
  straight into the kernel.

Ragged batches: ``kv_len``/``q_offset``/``q_len`` are per-(batch·head)
rows of the ``meta`` operand — every kernel row masks (and tile-skips)
against *its own* valid KV prefix, so a batch of sequences at different
positions decodes in one call with no padding to the longest. ``q_len``
extends the raggedness to the *query* axis: a row only treats its first
``q_len`` query rows as real (the rest emit zeros), which is how one
mixed serve call carries decode rows (q_len 1) next to chunked-prefill
rows (q_len = chunk). Scalars broadcast to all rows (the dense case).

Paged KV pool: the ``*_paged`` entry points consume one shared
``(num_pages, page_size, G, hd)`` int8 arena through a **page table**
delivered as a scalar-prefetch operand — the KV BlockSpec index map reads
``page_table[b, j]`` to translate logical KV tile ``j`` of sequence ``b``
into a physical arena page, so scattered pages stream through the very
same kernel bodies (``decode_kernel``/``onepass_kernel``) tile-for-tile.
With ``block_kv == page_size`` the DA tile schedule is identical to the
contiguous ring path, which is what keeps paged decode bit-identical to
the ring (the ``ita_fused`` family invariant).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant import INT8_MAX, INT8_MIN, SOFTMAX_SHIFT
from repro.kernels.common import (MASK_K, NEG_SENTINEL, adaptive_inverse,
                                  da_update, paper_inverse, tile_mask)


def _qk_logits(q_tile, k_tile, mult):
    """int8 Q (bq,d) x int8 K (bkv,d)^T -> int32 -> requant to int8 logit
    grid (returned widened to int32)."""
    acc = jax.lax.dot_general(q_tile, k_tile, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.int32)
    y = jnp.round(acc.astype(jnp.float32) * mult)
    return jnp.clip(y, INT8_MIN, INT8_MAX).astype(jnp.int32)


def onepass_kernel(q_ref, k_ref, v_ref, lmult_ref, omult_ref, meta_ref,
                   o_ref, m_ref, sigma_ref, acc_ref,
                   *, causal: bool, window: int, adaptive: bool,
                   bq: int, bkv: int, kv_4d: bool = False):
    i, j = pl.program_id(1), pl.program_id(2)
    last_j = pl.num_programs(2) - 1
    kv_len = meta_ref[0, 0]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_SENTINEL)
        sigma_ref[...] = jnp.zeros_like(sigma_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # KV tiles wholly beyond this row's valid prefix are fully masked —
    # DA/acc no-ops — so skip their MXU work: chunked-prefill rows stream
    # only their occupied pages, not the whole pool.
    @pl.when(j * bkv < kv_len)
    def _tile():
        # kv_4d: cache-native (1, bkv, 1, d) blocks sliced straight out of
        # a (B, S, G, hd) buffer by the index map — no host-side transpose.
        k_tile = k_ref[0, :, 0] if kv_4d else k_ref[0]
        v_tile = v_ref[0, :, 0] if kv_4d else v_ref[0]
        logits = _qk_logits(q_ref[0], k_tile, lmult_ref[0, 0])
        valid = tile_mask(i, j, bq, bkv, causal, window, kv_len,
                          meta_ref[0, 1], meta_ref[0, 2])
        u, delta = da_update(m_ref, sigma_ref, logits, valid)
        # Correct the running A·V accumulator for the max update (exact in
        # f32: multiplying by 2^-delta loses nothing, unlike the integer Σ
        # shift).
        corr = jnp.exp2(-delta.astype(jnp.float32))
        # u in [0, 128] — packs into uint8 on the MXU (int32 here:
        # interpret mode validates semantics; XLA emits the s8/u8 MXU path
        # on TPU).
        pv = jax.lax.dot_general(u, v_tile.astype(jnp.int32),
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.int32)
        acc_ref[...] = acc_ref[...] * corr + pv.astype(jnp.float32)

    @pl.when(j == last_j)
    def _finalize():
        if adaptive:
            inv, e_r = adaptive_inverse(sigma_ref[...])
        else:
            inv = paper_inverse(sigma_ref[...])
            e_r = jnp.full_like(inv, 8)
        # out = acc * 2 * inv * 2^-(e_r+8) * (s_v/s_out); the 2 restores the
        # halved numerator unit (u = 128>>k vs the paper's 256>>k).
        scale = 2.0 * inv.astype(jnp.float32) * jnp.exp2(
            -(e_r + 8).astype(jnp.float32)) * omult_ref[0, 0]
        y = jnp.round(acc_ref[...] * scale)
        o_ref[0] = jnp.clip(y, INT8_MIN, INT8_MAX).astype(jnp.int8)


def qk_da_kernel(q_ref, k_ref, lmult_ref, meta_ref, a_ref, max_o_ref,
                 sigma_o_ref, m_ref, sigma_ref,
                 *, causal: bool, window: int, bq: int, bkv: int):
    """Two-pass, pass 1: logits to HBM once + DA stats."""
    i, j = pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_SENTINEL)
        sigma_ref[...] = jnp.zeros_like(sigma_ref)

    logits = _qk_logits(q_ref[0], k_ref[0], lmult_ref[0, 0])
    valid = tile_mask(i, j, bq, bkv, causal, window, meta_ref[0, 0],
                      meta_ref[0, 1], meta_ref[0, 2])
    da_update(m_ref, sigma_ref, logits, valid)
    a_ref[0] = logits.astype(jnp.int8)

    @pl.when(j == pl.num_programs(2) - 1)
    def _emit_stats():
        max_o_ref[0] = m_ref[...][:, 0]
        sigma_o_ref[0] = sigma_ref[...][:, 0]


def av_en_kernel(a_ref, inv_ref, er_ref, max_ref, v_ref, omult_ref,
                 meta_ref, o_ref, acc_ref,
                 *, causal: bool, window: int, bq: int, bkv: int):
    """Two-pass, pass 2: re-stream A, EN by pure shifts, A·V on the MXU."""
    i, j = pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[0].astype(jnp.int32)
    row_max = max_ref[0][:, None]
    valid = tile_mask(i, j, bq, bkv, causal, window, meta_ref[0, 0],
                      meta_ref[0, 1], meta_ref[0, 2])
    k = jax.lax.shift_right_logical(row_max - a, SOFTMAX_SHIFT)
    k = jnp.where(valid, jnp.minimum(k, 31), MASK_K)
    p = jax.lax.shift_right_logical(inv_ref[0][:, None], k)   # EN: p ≤ 256
    pv = jax.lax.dot_general(p, v_ref[0].astype(jnp.int32),
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.int32)
    acc_ref[...] += pv.astype(jnp.float32)       # exact: |acc| < 2^24

    @pl.when(j == pl.num_programs(2) - 1)
    def _finalize():
        e_r = er_ref[0][:, None].astype(jnp.float32)
        y = jnp.round(acc_ref[...] * jnp.exp2(-e_r) * omult_ref[0, 0])
        o_ref[0] = jnp.clip(y, INT8_MIN, INT8_MAX).astype(jnp.int8)


def decode_kernel(q_ref, k_ref, v_ref, lmult_ref, omult_ref, meta_ref,
                  o_ref, m_ref, sigma_ref, acc_ref,
                  *, causal: bool, window: int, adaptive: bool,
                  bq: int, bkv: int, kv_4d: bool):
    """Onepass dataflow without a q grid axis (decode: sq <= one tile).

    ``kv_4d``: K/V refs carry cache-native (1, bkv, 1, d) blocks sliced
    straight out of a (B, C, G, hd) ring buffer — no host-side transpose
    or GQA head broadcast ever materializes.
    """
    j = pl.program_id(1)
    last_j = pl.num_programs(1) - 1
    kv_len = meta_ref[0, 0]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_SENTINEL)
        sigma_ref[...] = jnp.zeros_like(sigma_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Ring buffers are allocated at max_len; tiles wholly beyond the valid
    # prefix are fully masked (max/sigma/acc all no-ops) — skip the MXU work.
    @pl.when(j * bkv < kv_len)
    def _tile():
        k_tile = k_ref[0, :, 0] if kv_4d else k_ref[0]
        v_tile = v_ref[0, :, 0] if kv_4d else v_ref[0]
        logits = _qk_logits(q_ref[0], k_tile, lmult_ref[0, 0])
        valid = tile_mask(0, j, bq, bkv, causal, window, kv_len,
                          meta_ref[0, 1], meta_ref[0, 2])
        u, delta = da_update(m_ref, sigma_ref, logits, valid)
        corr = jnp.exp2(-delta.astype(jnp.float32))
        pv = jax.lax.dot_general(u, v_tile.astype(jnp.int32),
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.int32)
        acc_ref[...] = acc_ref[...] * corr + pv.astype(jnp.float32)

    @pl.when(j == last_j)
    def _finalize():
        if adaptive:
            inv, e_r = adaptive_inverse(sigma_ref[...])
        else:
            inv = paper_inverse(sigma_ref[...])
            e_r = jnp.full_like(inv, 8)
        scale = 2.0 * inv.astype(jnp.float32) * jnp.exp2(
            -(e_r + 8).astype(jnp.float32)) * omult_ref[0, 0]
        y = jnp.round(acc_ref[...] * scale)
        o_ref[0] = jnp.clip(y, INT8_MIN, INT8_MAX).astype(jnp.int8)


def _specs_bh(block, index):
    return pl.BlockSpec(block, index)


def _row_mults(logit_mult, out_mult, bh):
    """Broadcast scalar or per-row requant multipliers to (bh, 1) f32."""
    lm = jnp.broadcast_to(jnp.asarray(logit_mult, jnp.float32).reshape(-1),
                          (bh,)).reshape(bh, 1)
    om = jnp.broadcast_to(jnp.asarray(out_mult, jnp.float32).reshape(-1),
                          (bh,)).reshape(bh, 1)
    return lm, om


def _row_meta(kv_len, q_offset, q_len, bh):
    """Per-row ``[kv_len, q_offset, q_len]`` meta (bh, 3) int32. Scalars
    (the dense case) broadcast to every row; (bh,) vectors pass through —
    the ragged path, one valid KV prefix / query position / query count
    per (batch·head) row. ``q_len`` is the row's count of *valid query
    rows* (ragged q_len: a mixed chunked-prefill/decode call); pass the
    static query width for the dense case."""
    kv = jnp.asarray(kv_len, jnp.int32).reshape(-1)
    off = jnp.asarray(q_offset, jnp.int32).reshape(-1)
    qn = jnp.asarray(q_len, jnp.int32).reshape(-1)
    assert kv.shape[0] in (1, bh), (kv.shape, bh)
    assert off.shape[0] in (1, bh), (off.shape, bh)
    assert qn.shape[0] in (1, bh), (qn.shape, bh)
    return jnp.stack([jnp.broadcast_to(kv, (bh,)),
                      jnp.broadcast_to(off, (bh,)),
                      jnp.broadcast_to(qn, (bh,))], axis=1)


def ita_attention_onepass(q_q, k_q, v_q, logit_mult, out_mult, kv_len, *,
                          q_offset=0, q_len=None, causal: bool,
                          window: int = 0,
                          adaptive: bool = True, block_q: int = 128,
                          block_kv: int = 128, kv_rep: int = 1,
                          hq: int | None = None, interpret: bool = True):
    """q (BH, Sq, D) int8; k/v (BH/kv_rep, Skv, D) int8; returns (BH, Sq, D)
    int8. GQA: q row r reads kv row r // kv_rep via the index map — the KV
    head broadcast never materializes.

    K/V layouts (chosen by shape, as in ``ita_attention_decode``):
    - 3D ``(BH/kv_rep, Skv, D)``: kernel layout.
    - 4D ``(B, Skv, G, D)``: cache-native layout (requires ``hq``) —
      prefill straight out of a KV ring buffer, no host-side transpose.
    """
    bh, sq, d = q_q.shape
    kv_4d = k_q.ndim == 4
    skv = k_q.shape[1]
    bq, bkv = min(block_q, sq), min(block_kv, skv)
    assert sq % bq == 0 and skv % bkv == 0
    kern = functools.partial(onepass_kernel, causal=causal, window=window,
                             adaptive=adaptive, bq=bq, bkv=bkv, kv_4d=kv_4d)
    lmult, omult = _row_mults(logit_mult, out_mult, bh)
    meta = _row_meta(kv_len, q_offset, sq if q_len is None else q_len, bh)
    if kv_4d:
        assert hq is not None and bh % hq == 0
        # q row r = batch * hq + head  ->  (batch, kv tile, kv head)
        kv_spec = _specs_bh(
            (1, bkv, 1, d),
            lambda r, i, j: (r // hq, j, (r % hq) // kv_rep, 0))
    else:
        assert k_q.shape[0] * kv_rep == bh, (k_q.shape, kv_rep, bh)
        kv_spec = _specs_bh((1, bkv, d), lambda b, i, j: (b // kv_rep, j, 0))
    return pl.pallas_call(
        kern,
        grid=(bh, sq // bq, skv // bkv),
        in_specs=[
            _specs_bh((1, bq, d), lambda b, i, j: (b, i, 0)),
            kv_spec,
            kv_spec,
            pl.BlockSpec((1, 1), lambda b, i, j: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, i, j: (b, 0)),
            pl.BlockSpec((1, 3), lambda b, i, j: (b, 0)),
        ],
        out_specs=_specs_bh((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), jnp.int8),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.int32),
                        pltpu.VMEM((bq, 1), jnp.int32),
                        pltpu.VMEM((bq, d), jnp.float32)],
        interpret=interpret,
    )(q_q, k_q, v_q, lmult, omult, meta)


def ita_attention_twopass(q_q, k_q, v_q, logit_mult, out_mult, kv_len, *,
                          q_offset=0, causal: bool, window: int = 0,
                          adaptive: bool = False, block_q: int = 128,
                          block_kv: int = 128, kv_rep: int = 1,
                          interpret: bool = True):
    """Paper-faithful dataflow. Returns (out int8, a_mat int8) — A is the
    materialized int8 attention matrix (written once, read once).
    GQA via ``kv_rep`` index maps as in onepass."""
    bh, sq, d = q_q.shape
    skv = k_q.shape[1]
    bq, bkv = min(block_q, sq), min(block_kv, skv)
    assert sq % bq == 0 and skv % bkv == 0
    assert k_q.shape[0] * kv_rep == bh, (k_q.shape, kv_rep, bh)
    lmult, omult = _row_mults(logit_mult, out_mult, bh)
    meta = _row_meta(kv_len, q_offset, sq, bh)

    k1 = functools.partial(qk_da_kernel, causal=causal, window=window,
                           bq=bq, bkv=bkv)
    a_mat, row_max, sigma = pl.pallas_call(
        k1,
        grid=(bh, sq // bq, skv // bkv),
        in_specs=[
            _specs_bh((1, bq, d), lambda b, i, j: (b, i, 0)),
            _specs_bh((1, bkv, d), lambda b, i, j: (b // kv_rep, j, 0)),
            pl.BlockSpec((1, 1), lambda b, i, j: (b, 0)),
            pl.BlockSpec((1, 3), lambda b, i, j: (b, 0)),
        ],
        out_specs=[
            _specs_bh((1, bq, bkv), lambda b, i, j: (b, i, j)),
            _specs_bh((1, bq), lambda b, i, j: (b, i)),
            _specs_bh((1, bq), lambda b, i, j: (b, i)),
        ],
        out_shape=[jax.ShapeDtypeStruct((bh, sq, skv), jnp.int8),
                   jax.ShapeDtypeStruct((bh, sq), jnp.int32),
                   jax.ShapeDtypeStruct((bh, sq), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.int32),
                        pltpu.VMEM((bq, 1), jnp.int32)],
        interpret=interpret,
    )(q_q, k_q, lmult, meta)

    # DI — one integer inversion per row (two serial dividers in silicon,
    # a vectorized integer divide here), overlapped by XLA with pass 2 setup.
    if adaptive:
        sigma_inv, e_r = adaptive_inverse(sigma)
    else:
        sigma_inv = paper_inverse(sigma)
        e_r = jnp.full_like(sigma_inv, 8)

    k2 = functools.partial(av_en_kernel, causal=causal, window=window,
                           bq=bq, bkv=bkv)
    out = pl.pallas_call(
        k2,
        grid=(bh, sq // bq, skv // bkv),
        in_specs=[
            _specs_bh((1, bq, bkv), lambda b, i, j: (b, i, j)),
            _specs_bh((1, bq), lambda b, i, j: (b, i)),
            _specs_bh((1, bq), lambda b, i, j: (b, i)),
            _specs_bh((1, bq), lambda b, i, j: (b, i)),
            _specs_bh((1, bkv, d), lambda b, i, j: (b // kv_rep, j, 0)),
            pl.BlockSpec((1, 1), lambda b, i, j: (b, 0)),
            pl.BlockSpec((1, 3), lambda b, i, j: (b, 0)),
        ],
        out_specs=_specs_bh((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), jnp.int8),
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)],
        interpret=interpret,
    )(a_mat, sigma_inv, e_r, row_max, v_q, omult, meta)
    return out, a_mat


def ita_attention_decode(q_q, k_q, v_q, logit_mult, out_mult, kv_len, *,
                         q_offset=0, q_len=None, causal: bool = True,
                         window: int = 0,
                         adaptive: bool = True, block_kv: int = 128,
                         kv_rep: int = 1, hq: int | None = None,
                         interpret: bool = True):
    """Fused decode step: q (BH, Sq<=8, D) int8 against an int8 KV ring
    buffer with ``kv_len`` valid entries. Single q tile (no q grid axis);
    KV tiles past ``kv_len`` are skipped inside the kernel, so cost scales
    with the *occupied* prefix, not the ring capacity — per row:
    ``kv_len``/``q_offset`` may be (BH,) vectors (ragged batch), each row
    masking and tile-skipping against its own prefix. Streaming DA
    semantics are identical to ``onepass`` at equal ``block_kv`` — decode
    outputs are bit-identical to the matching prefill rows.

    K/V layouts (chosen by shape):
    - 3D ``(BH/kv_rep, C, D)``: kernel layout; GQA via row index map.
    - 4D ``(B, C, G, D)``: cache-native ring-buffer layout (requires
      ``hq``); blocks are gathered by index map — the per-step transpose
      and head broadcast a host-side relayout would cost never happen.
    """
    bh, sq, d = q_q.shape
    kv_4d = k_q.ndim == 4
    skv = k_q.shape[1]                      # seq axis in both layouts
    bkv = min(block_kv, skv)
    assert skv % bkv == 0, (skv, bkv)
    kern = functools.partial(decode_kernel, causal=causal, window=window,
                             adaptive=adaptive, bq=sq, bkv=bkv, kv_4d=kv_4d)
    lmult, omult = _row_mults(logit_mult, out_mult, bh)
    meta = _row_meta(kv_len, q_offset, sq if q_len is None else q_len, bh)
    if kv_4d:
        assert hq is not None and bh % hq == 0
        # q row r = batch * hq + head  ->  (batch, kv tile, kv head)
        kv_spec = _specs_bh(
            (1, bkv, 1, d),
            lambda r, j: (r // hq, j, (r % hq) // kv_rep, 0))
    else:
        assert k_q.shape[0] * kv_rep == bh, (k_q.shape, kv_rep, bh)
        kv_spec = _specs_bh((1, bkv, d), lambda r, j: (r // kv_rep, j, 0))
    return pl.pallas_call(
        kern,
        grid=(bh, skv // bkv),
        in_specs=[
            _specs_bh((1, sq, d), lambda b, j: (b, 0, 0)),
            kv_spec,
            kv_spec,
            pl.BlockSpec((1, 1), lambda b, j: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, j: (b, 0)),
            pl.BlockSpec((1, 3), lambda b, j: (b, 0)),
        ],
        out_specs=_specs_bh((1, sq, d), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), jnp.int8),
        scratch_shapes=[pltpu.VMEM((sq, 1), jnp.int32),
                        pltpu.VMEM((sq, 1), jnp.int32),
                        pltpu.VMEM((sq, d), jnp.float32)],
        interpret=interpret,
    )(q_q, k_q, v_q, lmult, omult, meta)


# ---------------------------------------------------------------------------
# Paged-pool variants: same kernel bodies, page-table-indexed KV blocks
# ---------------------------------------------------------------------------

def _swallow_pt(kern):
    """Scalar-prefetch calling convention hands the page-table ref to the
    kernel body as its first argument; the compute bodies never touch it
    (all translation happens in the index maps), so drop it here — the
    paged kernels stay byte-for-byte the ring kernels."""
    def wrapped(pt_ref, *refs):
        return kern(*refs)
    return wrapped


def ita_attention_decode_paged(q_q, k_pool, v_pool, page_table, logit_mult,
                               out_mult, kv_len, *, q_offset=0, q_len=None,
                               causal: bool = True, window: int = 0,
                               adaptive: bool = True, kv_rep: int = 1,
                               hq: int = 1, interpret: bool = True):
    """Fused decode step over a paged KV pool.

    ``q_q`` (BH, Sq<=8, D) int8; ``k_pool``/``v_pool``
    ``(num_pages, page_size, G, D)`` int8 shared arena; ``page_table``
    ``(B, n_pages)`` int32 maps each sequence's logical KV page to a
    physical arena page (entries beyond the valid prefix may point
    anywhere — those tiles are skipped/masked via ``kv_len``).

    ``block_kv`` is the page size: logical tile ``j`` of kernel row ``r``
    is DMA'd from ``pool[page_table[r // hq, j]]`` by a scalar-prefetch
    index map, and the DA streaming schedule is identical to
    ``ita_attention_decode`` at ``block_kv == page_size`` — paged decode
    is bit-identical to the contiguous ring path (family ``ita_fused``).
    """
    bh, sq, d = q_q.shape
    page = k_pool.shape[1]
    n_pages = page_table.shape[1]
    assert bh % hq == 0 and page_table.shape[0] * hq == bh, \
        (bh, hq, page_table.shape)
    kern = functools.partial(decode_kernel, causal=causal, window=window,
                             adaptive=adaptive, bq=sq, bkv=page, kv_4d=True)
    lmult, omult = _row_mults(logit_mult, out_mult, bh)
    meta = _row_meta(kv_len, q_offset, sq if q_len is None else q_len, bh)
    kv_spec = pl.BlockSpec(
        (1, page, 1, d),
        lambda r, j, pt: (pt[r // hq, j], 0, (r % hq) // kv_rep, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bh, n_pages),
        in_specs=[
            pl.BlockSpec((1, sq, d), lambda b, j, pt: (b, 0, 0)),
            kv_spec,
            kv_spec,
            pl.BlockSpec((1, 1), lambda b, j, pt: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, j, pt: (b, 0)),
            pl.BlockSpec((1, 3), lambda b, j, pt: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, sq, d), lambda b, j, pt: (b, 0, 0)),
        scratch_shapes=[pltpu.VMEM((sq, 1), jnp.int32),
                        pltpu.VMEM((sq, 1), jnp.int32),
                        pltpu.VMEM((sq, d), jnp.float32)],
    )
    return pl.pallas_call(
        _swallow_pt(kern),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), jnp.int8),
        interpret=interpret,
    )(page_table, q_q, k_pool, v_pool, lmult, omult, meta)


def ita_attention_onepass_paged(q_q, k_pool, v_pool, page_table, logit_mult,
                                out_mult, kv_len, *, q_offset=0, q_len=None,
                                causal: bool, window: int = 0,
                                adaptive: bool = True, block_q: int = 128,
                                kv_rep: int = 1, hq: int = 1,
                                interpret: bool = True):
    """Flash-style onepass over a paged KV pool (prefill-from-pool, decode
    bursts longer than the decode kernel's single tile, and the mixed
    chunked-prefill/decode serve step). Grid and page translation as in
    ``ita_attention_decode_paged``, with the q tiling axis of
    ``ita_attention_onepass`` restored. ``q_len`` (scalar or per-row)
    marks each row's count of valid query rows — ragged q_len: one call
    serves rows with q widths in {1, chunk} (pad rows emit zeros)."""
    bh, sq, d = q_q.shape
    page = k_pool.shape[1]
    n_pages = page_table.shape[1]
    bq = min(block_q, sq)
    assert sq % bq == 0, (sq, bq)
    assert bh % hq == 0 and page_table.shape[0] * hq == bh, \
        (bh, hq, page_table.shape)
    kern = functools.partial(onepass_kernel, causal=causal, window=window,
                             adaptive=adaptive, bq=bq, bkv=page, kv_4d=True)
    lmult, omult = _row_mults(logit_mult, out_mult, bh)
    meta = _row_meta(kv_len, q_offset, sq if q_len is None else q_len, bh)
    kv_spec = pl.BlockSpec(
        (1, page, 1, d),
        lambda r, i, j, pt: (pt[r // hq, j], 0, (r % hq) // kv_rep, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bh, sq // bq, n_pages),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j, pt: (b, i, 0)),
            kv_spec,
            kv_spec,
            pl.BlockSpec((1, 1), lambda b, i, j, pt: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, i, j, pt: (b, 0)),
            pl.BlockSpec((1, 3), lambda b, i, j, pt: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j, pt: (b, i, 0)),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.int32),
                        pltpu.VMEM((bq, 1), jnp.int32),
                        pltpu.VMEM((bq, d), jnp.float32)],
    )
    return pl.pallas_call(
        _swallow_pt(kern),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), jnp.int8),
        interpret=interpret,
    )(page_table, q_q, k_pool, v_pool, lmult, omult, meta)
