"""Jitted wrapper for the standalone ITA softmax kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ita_softmax.kernel import ita_softmax_pallas


@functools.partial(jax.jit, static_argnames=("block_r", "block_c", "adaptive",
                                             "interpret"))
def ita_softmax(x_q: jax.Array, mask: jax.Array | None = None, *,
                block_r: int = 128, block_c: int = 128,
                adaptive: bool = False, interpret: bool = True) -> jax.Array:
    """Streaming integer softmax over the last axis of int8 logits.

    Accepts any leading shape; pads rows/cols to block multiples (padded
    columns are masked out and return probability 0).
    """
    *lead, n = x_q.shape
    x2 = x_q.reshape(-1, n)
    r = x2.shape[0]
    if mask is None:
        m2 = jnp.ones((r, n), jnp.int8)
    else:
        m2 = mask.reshape(-1, n).astype(jnp.int8)
    br = min(block_r, max(8, r))
    pad_r = (-r) % br
    pad_c = (-n) % block_c
    if pad_r or pad_c:
        x2 = jnp.pad(x2, ((0, pad_r), (0, pad_c)))
        m2 = jnp.pad(m2, ((0, pad_r), (0, pad_c)))
    out = ita_softmax_pallas(x2, m2, block_r=br, block_c=min(block_c, n + pad_c),
                             adaptive=adaptive, interpret=interpret)
    return out[:r, :n].reshape(*lead, n)
