"""Pure-jnp oracle for the standalone ITA softmax kernel.

``ita_softmax_streaming`` in :mod:`repro.core.softmax` already implements
the part-wise DA semantics; the kernel must match it *exactly* (integer
equality of the underlying p values) when given the same part size.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import softmax as S


def ita_softmax_ref(x_q: jax.Array, mask: jax.Array, num_parts: int,
                    adaptive: bool = False) -> jax.Array:
    m = mask != 0
    if adaptive:
        # streaming DA first, then adaptive DI/EN on the streamed stats
        *lead, n = x_q.shape
        part = n // num_parts
        run_max = jnp.full((*lead, 1), -256, jnp.int32)
        run_sigma = jnp.zeros((*lead, 1), jnp.int32)
        for i in range(num_parts):
            sl = slice(i * part, (i + 1) * part)
            run_max, run_sigma = S.ita_da_update(
                run_max, run_sigma, x_q[..., sl], m[..., sl])
        sigma = jnp.maximum(run_sigma, 1)
        e_r = 31 - jax.lax.clz(sigma)
        pre = jnp.maximum(e_r + 8 - 30, 0)
        sigma_inv = (jnp.int32(1) << jnp.minimum(e_r + 8 - pre, 30)) \
            // jax.lax.shift_right_logical(sigma, pre)
        k = jnp.where(m, jnp.minimum(jax.lax.shift_right_logical(
            run_max - x_q.astype(jnp.int32), 5), 31), 31)
        p = jax.lax.shift_right_logical(sigma_inv, k)
        return p.astype(jnp.float32) * jnp.exp2(-e_r.astype(jnp.float32))
    return S.ita_softmax_streaming(x_q, num_parts, mask=m)
