from repro.kernels.ita_softmax.ops import *  # noqa: F401,F403
