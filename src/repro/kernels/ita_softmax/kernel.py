"""Standalone ITA streaming softmax Pallas kernel.

Mirrors the silicon module (paper Fig. 4) on a TPU grid: the row dimension
is tiled like ITA's M-row tiles (MAX/Σ buffers hold one entry per row of the
tile), and the column dimension streams in parts. The grid's middle axis is
the *pass*: pass 0 performs DA (+DI on the last part), pass 1 re-streams the
logits and performs EN — exactly the paper's dataflow where the attention
row is seen twice (once from Q·Kᵀ, once as the A·V operand) and never more.

VMEM footprint per grid step: one (block_r, block_c) int8 logits tile +
3 × (block_r, 1) int32 stat buffers (the paper's MAX/Σ buffers + Σ_inv).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant import SOFTMAX_SHIFT
from repro.kernels.common import (MASK_K, NEG_SENTINEL, adaptive_inverse,
                                  da_update, paper_inverse)


def softmax_kernel(x_ref, mask_ref, o_ref, m_ref, sigma_ref, inv_ref, er_ref,
                   *, adaptive: bool):
    pass_ax, c = pl.program_id(1), pl.program_id(2)
    last_c = pl.num_programs(2) - 1

    @pl.when((pass_ax == 0) & (c == 0))
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_SENTINEL)
        sigma_ref[...] = jnp.zeros_like(sigma_ref)

    @pl.when(pass_ax == 0)
    def _da():
        x = x_ref[...].astype(jnp.int32)
        valid = mask_ref[...] != 0
        da_update(m_ref, sigma_ref, x, valid)
        o_ref[...] = jnp.zeros_like(o_ref)          # overwritten in pass 1

        @pl.when(c == last_c)
        def _di():
            if adaptive:
                inv, e_r = adaptive_inverse(sigma_ref[...])
            else:
                inv, e_r = paper_inverse(sigma_ref[...]), \
                    jnp.full_like(sigma_ref[...], 8)
            inv_ref[...] = inv
            er_ref[...] = e_r

    @pl.when(pass_ax == 1)
    def _en():
        x = x_ref[...].astype(jnp.int32)
        valid = mask_ref[...] != 0
        k = jax.lax.shift_right_logical(m_ref[...] - x, SOFTMAX_SHIFT)
        k = jnp.where(valid, jnp.minimum(k, 31), MASK_K)
        p = jax.lax.shift_right_logical(inv_ref[...], k)
        # Probabilities as f32 * 2^-e_r (paper mode: e_r == 8, p/256).
        o_ref[...] = p.astype(jnp.float32) * jnp.exp2(-er_ref[...].astype(jnp.float32))


def ita_softmax_pallas(x_q: jax.Array, mask: jax.Array, *, block_r: int = 128,
                       block_c: int = 128, adaptive: bool = False,
                       interpret: bool = True) -> jax.Array:
    """x_q (R, C) int8 logits, mask (R, C) int8 (0 = masked). Returns f32
    probabilities (R, C)."""
    r, c = x_q.shape
    br, bc = min(block_r, r), min(block_c, c)
    assert r % br == 0 and c % bc == 0, (r, c, br, bc)
    import functools
    kern = functools.partial(softmax_kernel, adaptive=adaptive)
    return pl.pallas_call(
        kern,
        grid=(r // br, 2, c // bc),
        in_specs=[
            pl.BlockSpec((br, bc), lambda i, p, j: (i, j)),
            pl.BlockSpec((br, bc), lambda i, p, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((br, bc), lambda i, p, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.float32),
        scratch_shapes=[pltpu.VMEM((br, 1), jnp.int32),
                        pltpu.VMEM((br, 1), jnp.int32),
                        pltpu.VMEM((br, 1), jnp.int32),
                        pltpu.VMEM((br, 1), jnp.int32)],
        interpret=interpret,
    )(x_q, mask)
