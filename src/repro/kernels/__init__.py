# Pallas TPU kernels for the compute hot-spots ITA optimizes in silicon:
# the quantized attention pipeline (Q.K^T -> integer streaming softmax ->
# A.V) and the weight-stationary int8 linear layers. Validated against the
# pure-jnp oracles in each subpackage's ref.py (interpret=True on CPU).
from repro.kernels.int8_matmul.ops import int8_matmul  # noqa: F401
from repro.kernels.ita_softmax.ops import ita_softmax  # noqa: F401
from repro.kernels.ita_attention.ops import fused_attention  # noqa: F401
