from repro.kernels.int8_matmul.ops import *  # noqa: F401,F403
