"""Pallas TPU kernels for ITA's quantized linear layer (the PE array).

Two schedules:

- ``matmul_kernel`` — TPU-native: grid ``(m, n, k)`` with ``k`` innermost and
  an int32 VMEM accumulator; bias-add + requantization fused on the final
  ``k`` step. The paper's *weight reuse* (each weight fetched once per M
  input rows) maps to the ``block_m`` extent: weight-tile HBM traffic is
  ``K*N * ceil(M/block_m)`` bytes, so large ``block_m`` ≙ ITA's M-fold reuse.

- ``matmul_ws_kernel`` — paper-faithful *weight-stationary* schedule: grid
  ``(n, k, m)`` with ``m`` innermost, so each weight tile stays resident in
  VMEM while all input rows stream past it (the W1/W2 double buffer is
  Pallas's automatic pipelining of the streamed x blocks). Partial sums
  stream to/from HBM (aliased in/out), exactly the ``2·N·D`` bits/cycle
  partial-sum term in the paper's bandwidth equation. Used by the dataflow
  benchmark to reproduce the paper's §III bandwidth comparison.

All matmuls are int8 x int8 -> int32 (MXU-native on TPU; v5e runs int8 at
2x bf16 throughput).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant import INT8_MAX, INT8_MIN


def _dot_i32(a, b):
    return jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.int32)


def matmul_kernel(x_ref, w_ref, bias_ref, mult_ref, o_ref, acc_ref):
    """grid = (m, n, k); k innermost (reduction in VMEM scratch)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _dot_i32(x_ref[...], w_ref[...])

    @pl.when(k == pl.num_programs(2) - 1)
    def _finalize():
        acc = acc_ref[...] + bias_ref[...].astype(jnp.int32)
        y = jnp.round(acc.astype(jnp.float32) * mult_ref[...])
        o_ref[...] = jnp.clip(y, INT8_MIN, INT8_MAX).astype(jnp.int8)


def matmul_ws_kernel(x_ref, w_ref, bias_ref, mult_ref, psum_ref,
                     psum_out_ref, o_ref, *, final: bool):
    """grid = (n, m); one call per k tile — weight tile stationary in VMEM
    while all input rows stream past it (m is the inner grid axis).

    Partial sums stream HBM->VMEM->HBM between calls (aliased buffers),
    matching ITA's ``2·N·D`` partial-sum bits/cycle bandwidth term.
    """
    acc = psum_ref[...] + _dot_i32(x_ref[...], w_ref[...])
    psum_out_ref[...] = acc
    if final:
        full = acc + bias_ref[...].astype(jnp.int32)
        y = jnp.round(full.astype(jnp.float32) * mult_ref[...])
        o_ref[...] = jnp.clip(y, INT8_MIN, INT8_MAX).astype(jnp.int8)
    else:
        o_ref[...] = jnp.zeros_like(o_ref)


def int8_matmul_pallas(x_q: jax.Array, w_q: jax.Array, bias: jax.Array,
                       mult: jax.Array, *, block_m: int = 256,
                       block_n: int = 128, block_k: int = 128,
                       schedule: str = "tpu", interpret: bool = True):
    """Launch the quantized matmul. Shapes: x (M,K) int8, w (K,N) int8,
    bias (N,) int32 (pre-scaled to accumulator units), mult (N,) f32
    (per-channel requant multipliers; broadcast a scalar for per-tensor).
    Returns int8 (M,N)."""
    m, kdim = x_q.shape
    _, n = w_q.shape
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, kdim)
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0, (m, n, kdim)
    bias2 = jnp.broadcast_to(bias.astype(jnp.int32), (1, n))
    mult2 = jnp.broadcast_to(mult.astype(jnp.float32), (1, n))

    if schedule == "tpu":
        return pl.pallas_call(
            matmul_kernel,
            grid=(m // bm, n // bn, kdim // bk),
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
                pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
                pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
                pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            out_shape=jax.ShapeDtypeStruct((m, n), jnp.int8),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
            interpret=interpret,
        )(x_q, w_q, bias2, mult2)

    assert schedule == "weight_stationary", schedule
    import functools
    psum = jnp.zeros((m, n), jnp.int32)
    out_q = None
    n_k = kdim // bk
    for kt in range(n_k):                       # k outer: weights stationary
        x_sl = jax.lax.slice_in_dim(x_q, kt * bk, (kt + 1) * bk, axis=1)
        w_sl = jax.lax.slice_in_dim(w_q, kt * bk, (kt + 1) * bk, axis=0)
        kern = functools.partial(matmul_ws_kernel, final=kt == n_k - 1)
        psum, out_q = pl.pallas_call(
            kern,
            grid=(n // bn, m // bm),            # m innermost: W tile reused
            in_specs=[
                pl.BlockSpec((bm, bk), lambda j, i: (i, 0)),
                pl.BlockSpec((bk, bn), lambda j, i: (0, j)),  # const in m
                pl.BlockSpec((1, bn), lambda j, i: (0, j)),
                pl.BlockSpec((1, bn), lambda j, i: (0, j)),
                pl.BlockSpec((bm, bn), lambda j, i: (i, j)),  # psum stream
            ],
            out_specs=[
                pl.BlockSpec((bm, bn), lambda j, i: (i, j)),
                pl.BlockSpec((bm, bn), lambda j, i: (i, j)),
            ],
            out_shape=[jax.ShapeDtypeStruct((m, n), jnp.int32),
                       jax.ShapeDtypeStruct((m, n), jnp.int8)],
            input_output_aliases={4: 0},
            interpret=interpret,
        )(x_sl, w_sl, bias2, mult2, psum)
    return out_q
