"""Jitted public wrapper for the int8 matmul kernel (handles batching,
padding to block multiples, and backend selection)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import default_matmul_blocks
from repro.kernels.int8_matmul.kernel import int8_matmul_pallas
from repro.kernels.int8_matmul.ref import int8_matmul_ref


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "schedule", "use_pallas",
                     "interpret"))
def int8_matmul(x_q: jax.Array, w_q: jax.Array, bias: jax.Array | None = None,
                mult: jax.Array | float = 1.0, *, block_m: int | None = None,
                block_n: int | None = None, block_k: int | None = None,
                schedule: str = "tpu", use_pallas: bool = True,
                interpret: bool = True) -> jax.Array:
    """Quantized linear: int8 x int8 -> int32 -> requant int8.

    ``x_q``: (..., K) int8; ``w_q``: (K, N) int8; ``bias``: (N,) int32 in
    accumulator units; ``mult``: per-channel (N,) or scalar f32 requant
    multiplier. Leading dims are flattened for the kernel. Block sizes
    default to ``kernels.common.BLOCK_DEFAULTS["int8_matmul"]`` — the
    grid the ``bench_kernels.py --sweep`` run records; explicit
    ``block_*=`` arguments override per call.
    """
    dm, dn, dk = default_matmul_blocks()
    block_m = dm if block_m is None else block_m
    block_n = dn if block_n is None else block_n
    block_k = dk if block_k is None else block_k
    *lead, kdim = x_q.shape
    n = w_q.shape[1]
    if bias is None:
        bias = jnp.zeros((n,), jnp.int32)
    mult = jnp.broadcast_to(jnp.asarray(mult, jnp.float32), (n,))

    x2 = x_q.reshape(-1, kdim)
    if not use_pallas:
        out = int8_matmul_ref(x2, w_q, bias, mult)
        return out.reshape(*lead, n)

    m = x2.shape[0]
    bm = min(block_m, max(8, m))
    x2p = _pad_to(x2, bm, 0)
    x2p = _pad_to(x2p, block_k, 1)
    w_p = _pad_to(_pad_to(w_q, block_k, 0), block_n, 1)
    bias_p = _pad_to(bias, block_n, 0)
    mult_p = _pad_to(mult, block_n, 0)
    out = int8_matmul_pallas(x2p, w_p, bias_p, mult_p, block_m=bm,
                             block_n=block_n, block_k=block_k,
                             schedule=schedule, interpret=interpret)
    return out[:m, :n].reshape(*lead, n)
