"""Pure-jnp oracle for the int8 weight-stationary matmul kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import INT8_MAX, INT8_MIN


def int8_matmul_ref(x_q: jax.Array, w_q: jax.Array, bias: jax.Array,
                    mult: jax.Array) -> jax.Array:
    """x (M,K) int8 @ w (K,N) int8 + bias (N,) int32, requantized by the
    per-channel f32 multipliers ``mult`` (N,) -> int8."""
    acc = jax.lax.dot_general(x_q, w_q, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    acc = acc + bias.astype(jnp.int32)[None, :]
    y = jnp.round(acc.astype(jnp.float32) * mult.astype(jnp.float32)[None, :])
    return jnp.clip(y, INT8_MIN, INT8_MAX).astype(jnp.int8)
