import os
import pathlib

import numpy as np
import pytest

# Suite wall-clock is dominated by XLA compiles (~1-3 s each across ~90
# tests). Persist compiled executables across runs — first run pays full
# compile cost, repeat tier-1 runs are several times faster. Must be set
# before any test module imports jax.
os.environ.setdefault(
    "JAX_COMPILATION_CACHE_DIR",
    str(pathlib.Path(__file__).resolve().parent.parent / ".jax_cache"))
os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "0.3")
os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_ENTRY_SIZE_BYTES", "0")


def pytest_addoption(parser):
    parser.addoption(
        "--runslow", action="store_true", default=False,
        help="also run tests marked slow (big shapes, full arch sweep)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip = pytest.mark.skip(reason="slow case — enable with --runslow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)


@pytest.fixture
def rng():
    return np.random.default_rng(0)
