"""Optional-``hypothesis`` shim so tier-1 collects from a clean checkout.

When hypothesis is installed (see ``requirements-dev.txt``) the real
``given``/``settings``/``strategies`` are re-exported and property tests
run with full random search. When it is missing, a small deterministic
fallback runs each ``@given`` test over a fixed case set (bounds,
midpoints and a few seeded draws) — weaker than hypothesis, but the
properties still execute instead of the suite failing at import time.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False
    _N_FALLBACK = 5

    class _Strategy:
        def __init__(self, pick):
            self._pick = pick

        def example(self, i):
            return self._pick(i)

    class _St:
        @staticmethod
        def integers(lo=0, hi=2 ** 31 - 1):
            span = hi - lo
            vals = [lo, hi, lo + span // 2, lo + span // 3,
                    lo + (2 * span) // 3]
            return _Strategy(lambda i: vals[i % len(vals)])

        @staticmethod
        def floats(lo=0.0, hi=1.0, **_kw):
            vals = [lo, hi, (lo + hi) / 2, lo + (hi - lo) * 0.1,
                    lo + (hi - lo) * 0.9]
            return _Strategy(lambda i: vals[i % len(vals)])

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(lambda i: options[i % len(options)])

    st = _St()

    def settings(**_kw):
        return lambda fn: fn

    def given(*strategies):
        def deco(fn):
            # NB: no functools.wraps — the wrapper must NOT inherit fn's
            # signature or pytest would resolve the drawn params as fixtures
            def run():
                for i in range(_N_FALLBACK):
                    fn(*(s.example(i) for s in strategies))
            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            return run
        return deco
