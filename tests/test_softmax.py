"""ITA integer softmax: unit + property tests (paper §IV claims)."""

import jax.numpy as jnp
import numpy as np
import pytest
from compat_hypothesis import given, settings, st

from repro.core import softmax as S
from repro.core.quant import EPS_MAX


def _quantize(x):
    return np.clip(np.round(x / EPS_MAX), -128, 127).astype(np.int8)


def test_oneshot_matches_formula():
    """p = (2^16 // sigma) >> k, sigma = sum 256 >> k (paper eq. 4/5)."""
    x = np.array([[10, -20, 100, 127, -128]], np.int8)
    p, sigma, mx = S.ita_softmax_int(jnp.asarray(x))
    k = (int(x.max()) - x.astype(np.int64)) >> 5
    sig = int((256 >> k).sum())
    assert int(sigma[0, 0]) == sig
    inv = (1 << 16) // sig
    np.testing.assert_array_equal(np.asarray(p)[0], inv >> k[0])


def test_rowsums_bounded():
    rng = np.random.default_rng(1)
    x = _quantize(rng.normal(0, 1.2, (64, 128)))
    p = np.asarray(S.ita_softmax(jnp.asarray(x)))
    sums = p.sum(-1)
    assert np.all(sums <= 1.0 + 1e-6)        # floor-only arithmetic
    assert np.all(sums > 0.05)


def test_shift_invariance():
    """ITA softmax is exactly invariant to a common shift of all inputs
    (k_i depends only on max - x_i)."""
    rng = np.random.default_rng(2)
    x = _quantize(rng.normal(0, 1.0, (8, 64)) - 1.0)
    x = np.clip(x, -100, 90)
    p1 = np.asarray(S.ita_softmax(jnp.asarray(x)))
    p2 = np.asarray(S.ita_softmax(jnp.asarray((x + 30).astype(np.int8))))
    np.testing.assert_array_equal(p1, p2)


def test_monotonicity():
    x = np.arange(-128, 127, 2, np.int8)[None]
    p = np.asarray(S.ita_softmax(jnp.asarray(x)))[0]
    assert np.all(np.diff(p) >= 0)


def test_streaming_equals_oneshot_when_sorted_desc():
    """If the global max arrives in the first part, no correction is ever
    needed and streaming == one-shot exactly."""
    rng = np.random.default_rng(3)
    x = rng.normal(0, 1.0, (16, 256))
    xq = np.sort(_quantize(x), axis=-1)[:, ::-1].copy()
    a = np.asarray(S.ita_softmax(jnp.asarray(xq)))
    b = np.asarray(S.ita_softmax_streaming(jnp.asarray(xq), num_parts=8))
    np.testing.assert_array_equal(a, b)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([2, 4, 8]))
def test_streaming_bounded_error(seed, parts):
    """The paper's multi-part Σ correction can only *overestimate* the
    one-shot Σ, by at most 2^(#max-updates); probabilities stay in [0,1]
    and the MAE to float stays small."""
    rng = np.random.default_rng(seed)
    xq = _quantize(rng.normal(0, 1.0, (8, 128)))
    ps = np.asarray(S.ita_softmax_streaming(jnp.asarray(xq), parts))
    pf = np.asarray(S.softmax_float(jnp.asarray(xq)))
    assert ps.min() >= 0 and ps.max() <= 1.0 + 1e-6
    assert np.abs(ps - pf).mean() < 0.02


def test_mask_zeroes_probabilities():
    rng = np.random.default_rng(4)
    xq = _quantize(rng.normal(0, 1, (8, 64)))
    mask = rng.random((8, 64)) > 0.3
    for fn in (S.ita_softmax, S.ita_softmax_adaptive,
               lambda x, mask: S.ita_softmax_streaming(x, 4, mask=mask)):
        p = np.asarray(fn(jnp.asarray(xq), mask=jnp.asarray(mask)))
        assert np.all(p[~mask] == 0)


def test_fully_masked_row_is_zero():
    xq = jnp.asarray(np.ones((2, 32), np.int8))
    mask = jnp.zeros((2, 32), bool)
    p = np.asarray(S.ita_softmax(xq, mask=mask))
    assert np.all(p == 0)


def test_adaptive_beats_paper_mode_on_long_rows():
    """Beyond-paper: per-row power-of-two scaling fixes the Σ>=2^16
    underflow and improves MAE on long rows."""
    rng = np.random.default_rng(5)
    xq = _quantize(rng.normal(0, 0.6, (16, 2048)))
    pf = np.asarray(S.softmax_float(jnp.asarray(xq)))
    mae_paper = np.abs(np.asarray(S.ita_softmax(jnp.asarray(xq))) - pf).mean()
    mae_adapt = np.abs(
        np.asarray(S.ita_softmax_adaptive(jnp.asarray(xq))) - pf).mean()
    assert mae_adapt < mae_paper


def test_mae_vs_float_in_paper_ballpark():
    """Paper §V-C: ITA MAE 0.46%, I-BERT 0.35% (on CCT activations).
    On a matched synthetic logit distribution both must land < 1% and
    I-BERT must not be wildly different from ITA."""
    rng = np.random.default_rng(6)
    xq = _quantize(rng.normal(0, 1.0, (256, 256)))
    pf = np.asarray(S.softmax_float(jnp.asarray(xq)))
    mae_ita = np.abs(np.asarray(S.ita_softmax(jnp.asarray(xq))) - pf).mean()
    mae_ib = np.abs(S.ibert_softmax_np(xq) - pf).mean()
    assert mae_ita < 0.01
    assert mae_ib < 0.01


def test_ibert_jnp_matches_np():
    rng = np.random.default_rng(7)
    xq = _quantize(rng.normal(0, 1.0, (32, 128)))
    a = np.asarray(S.ibert_softmax(jnp.asarray(xq)))
    b = S.ibert_softmax_np(xq)
    np.testing.assert_allclose(a, b, atol=1e-7)


def test_bitexact_saturation():
    """15-bit Σ saturation: long rows of identical values saturate Σ at
    2^15-1 — probabilities then overestimate (HW-accepted behaviour)."""
    xq = jnp.asarray(np.zeros((1, 512), np.int8))
    p = np.asarray(S.ita_softmax_bitexact(xq, num_parts=4))
    # one-shot wide mode: sigma = 512*256 = 2^17 -> p = (2^16//2^17)=0
    p_wide = np.asarray(S.ita_softmax(xq))
    assert p.sum() > p_wide.sum()


def test_ste_grads_flow():
    import jax
    x = jnp.linspace(-2, 2, 64).reshape(2, 32)
    g = jax.grad(lambda l: S.ita_softmax_ste(l)[0, 0])(x)
    assert bool(jnp.all(jnp.isfinite(g)))
    assert float(jnp.abs(g).sum()) > 0


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_softermax_close_to_float(seed):
    rng = np.random.default_rng(seed)
    xq = _quantize(rng.normal(0, 1.0, (4, 64)))
    pf = np.asarray(S.softmax_float(jnp.asarray(xq)))
    ps = np.asarray(S.softermax(jnp.asarray(xq)))
    assert np.abs(ps - pf).mean() < 5e-3
