"""Crash-safe serving: journal replay, snapshot/restore, drain, and
bit-exact recovery (ISSUE 9).

Acceptance properties:
- **Crash-point sweep**: killing the serve at *every* admission-round
  boundary — and mid-segment, after the device produced tokens but
  before the journal flush (the torn-write window) — then restarting
  with ``resume=True`` yields token streams **bit-identical** to a serve
  that never crashed, greedy and sampled, with and without prefix
  sharing / preemption / snapshots, with allocator invariants checked
  every round (``debug_invariants=True``).
- **Journal WAL semantics**: every record is crc32-wrapped; replay stops
  at the first torn/corrupt line and recovers from the durable prefix.
  A ``complete`` record is only trusted when its token count is actually
  present (a torn flush can keep the complete but lose the boundary's
  progress lines — the stream then falls back to partial resume).
- **Idempotent re-admission**: resuming over a finished journal replays
  every request (``CompletedRequest.replayed``) without serving any of
  them twice (``steps == 0``); reusing a ``request_id`` for a
  *different* request is an error, not a silent dedupe.
- **Snapshot degradation**: a corrupt snapshot (bit-flipped leaf) is
  detected by its checksum and degrades to a cold start from the
  journal — recovery still bit-exact, never wrong tokens.
- **Graceful drain**: stop admitting, finish (or journal) in-flight
  work; a later ``resume`` serves exactly the remainder.
- **Starvation aging**: with ``aging_steps``, the low class's worst-case
  admission delay is bounded by ``aging_bound_steps`` plus one in-flight
  residency; without aging the same trace starves it for far longer —
  and aging changes scheduling only, never tokens.

Bit-parity requires the fused-kernel tile schedule (page_size = 128 +
fused one-pass backend), same as the chunked ≡ solo parity tests.
"""

import json
import os
import zlib

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import init_model
from repro.runtime.fault_tolerance import ServeFaultPlan, SimulatedCrash
from repro.runtime.generate import ServeRequest, serve_continuous
from repro.runtime.journal import (ServeDrain, ServeJournal,
                                   check_fingerprint, prompt_digest,
                                   serve_with_recovery)

KEY = jax.random.PRNGKey(0)

CFG = ModelConfig(name="recovery-smoke", family="dense", d_model=64,
                  n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=128, layer_groups=((("attn",), 2),),
                  dtype="float32", attention_impl="ita",
                  attention_backend="ita_onepass_pallas")
MAX_LEN = 128

KW = dict(slots=3, segment=4, max_len=MAX_LEN, page_size=128,
          chunk_size=5, debug_invariants=True)


@pytest.fixture(scope="module")
def params():
    return init_model(KEY, CFG)


def _trace(n=7, seed=3):
    prng = np.random.default_rng(seed)
    reqs, step = [], 0
    for _ in range(n):
        plen = int(prng.integers(3, 13))
        reqs.append(ServeRequest(
            prompt=prng.integers(0, CFG.vocab_size, plen).astype(np.int32),
            gen=int(prng.integers(1, 10)), arrival=step))
        step += int(prng.integers(0, 4))
    return reqs


def _tokens(res):
    return {c.index: np.asarray(c.tokens) for c in res.completed}


def _assert_same_tokens(res, want, msg=""):
    got = _tokens(res)
    assert set(got) == set(want), (msg, sorted(got), sorted(want))
    for i in got:
        np.testing.assert_array_equal(
            got[i], want[i], err_msg=f"{msg}: request {i} diverged")


# ---------------------------------------------------------------------------
# Journal unit tests (no model)
# ---------------------------------------------------------------------------

def test_journal_roundtrip_and_torn_tail(tmp_path):
    path = str(tmp_path / "j.jsonl")
    fp = {"journal_version": 1, "arch": "x", "sample": False}
    j = ServeJournal(path, fingerprint=fp)
    j.append({"t": "submit", "rid": "a", "i": 0, "digest": "d",
              "gen": 4, "arrival": 0, "priority": 0})
    j.append({"t": "progress", "rid": "a", "toks": [1, 2]})
    j.flush()
    j.append({"t": "progress", "rid": "a", "toks": [3, 4],
              "key": [7, 8]})
    j.append({"t": "complete", "rid": "a", "n": 4})
    j.close()

    rep = ServeJournal.replay(path)
    assert not rep.truncated
    assert rep.header["fingerprint"] == fp
    assert rep.submits["a"]["gen"] == 4
    assert rep.emitted["a"] == [1, 2, 3, 4]
    assert rep.keys["a"] == [7, 8]
    assert rep.completes["a"]["n"] == 4

    # a torn tail (half-written line, then garbage) stops replay at the
    # durable prefix — earlier records survive untouched
    with open(path) as f:
        lines = f.read().splitlines()
    with open(path, "w") as f:
        f.write("\n".join(lines[:3]) + "\n")
        f.write(lines[3][: len(lines[3]) // 2])     # torn mid-record
    rep = ServeJournal.replay(path)
    assert rep.truncated
    assert rep.emitted["a"] == [1, 2]
    assert "a" not in rep.completes

    # a bit-flipped (but syntactically valid) record fails its crc
    rec = json.loads(lines[2])
    rec["rec"]["toks"] = [9, 9]                     # payload tampered
    with open(path, "w") as f:
        f.write("\n".join(lines[:2]) + "\n")
        f.write(json.dumps(rec) + "\n")
    rep = ServeJournal.replay(path)
    assert rep.truncated and "a" not in rep.emitted


def test_journal_append_is_buffered_until_flush(tmp_path):
    path = str(tmp_path / "j.jsonl")
    j = ServeJournal(path, fingerprint={"journal_version": 1})
    j.wait()
    sz0 = os.path.getsize(path)
    j.append({"t": "progress", "rid": "a", "toks": [1]})
    assert os.path.getsize(path) == sz0             # not durable yet
    j.flush()
    j.wait()                                        # group-commit barrier
    assert os.path.getsize(path) > sz0
    j.close()


def test_fingerprint_mismatch_refuses_resume():
    fp = {"journal_version": 1, "arch": "a", "page_size": 128,
          "max_len": 64, "temperature": 0.0, "sample": False,
          "eos_id": None, "pad_id": 0, "key": None}
    check_fingerprint(fp, dict(fp))                 # identical: fine
    with pytest.raises(ValueError, match="temperature"):
        check_fingerprint(fp, dict(fp, temperature=0.5))
    with pytest.raises(ValueError, match="key"):
        check_fingerprint(fp, dict(fp, key=[1, 2]))


def test_crc_line_format_stable(tmp_path):
    """The on-disk line is crc32-over-canonical-json — the format the
    replay (and any external tooling) depends on."""
    path = str(tmp_path / "j.jsonl")
    j = ServeJournal(path)
    j.append({"t": "progress", "rid": "r", "toks": [5]})
    j.close()
    line = json.loads(open(path).read().splitlines()[0])
    canon = json.dumps(line["rec"], sort_keys=True,
                       separators=(",", ":"))
    assert line["crc"] == zlib.crc32(canon.encode())


# ---------------------------------------------------------------------------
# Crash-point sweep: bit-exact recovery at every boundary
# ---------------------------------------------------------------------------

def test_crash_sweep_greedy_every_boundary(params, tmp_path):
    """Kill at every admission-round boundary AND at every mid-segment
    (post-readback, pre-flush) point; each restart must complete the
    trace bit-identically to the calm run."""
    reqs = _trace()
    calm = serve_continuous(params, CFG, reqs, **KW)
    want = _tokens(calm)
    boundaries = list(range(KW["segment"], calm.steps, KW["segment"]))
    assert len(boundaries) >= 3                    # sweep is non-vacuous
    for kind in ("crash_steps", "crash_after_steps"):
        for at in boundaries:
            d = str(tmp_path / f"{kind}-{at}")
            res, crashes = serve_with_recovery(
                params, CFG, reqs, journal_dir=d,
                plans=(ServeFaultPlan(**{kind: (at,)}),), **KW)
            assert crashes == 1, (kind, at)
            assert res.recovered
            _assert_same_tokens(res, want, f"{kind}@{at}")


def test_crash_recovery_sampled_bit_exact(params, tmp_path):
    """Sampled serving resumes from the journaled per-request PRNG
    snapshots — draws continue exactly where the crashed serve left
    off, for both crash kinds."""
    reqs = _trace(seed=5)
    kw = dict(KW, temperature=0.8, key=jax.random.PRNGKey(7))
    calm = serve_continuous(params, CFG, reqs, **kw)
    want = _tokens(calm)
    for kind in ("crash_steps", "crash_after_steps"):
        d = str(tmp_path / kind)
        res, crashes = serve_with_recovery(
            params, CFG, reqs, journal_dir=d,
            plans=(ServeFaultPlan(**{kind: (8,)}),), **kw)
        assert crashes == 1
        _assert_same_tokens(res, want, f"sampled {kind}")


def test_crash_recovery_double_crash(params, tmp_path):
    """Two crashes in one trace (boundary then mid-segment) still
    converge to the calm tokens — each restart recovers the previous
    restart's journal."""
    reqs = _trace(seed=9)
    calm = serve_continuous(params, CFG, reqs, **KW)
    res, crashes = serve_with_recovery(
        params, CFG, reqs, journal_dir=str(tmp_path / "j"),
        plans=(ServeFaultPlan(crash_steps=(4,)),
               ServeFaultPlan(crash_after_steps=(12,))), **KW)
    assert crashes == 2
    _assert_same_tokens(res, _tokens(calm), "double crash")


def test_max_restarts_reraises(params, tmp_path):
    """A crash loop that exceeds the restart budget surfaces the
    SimulatedCrash instead of spinning forever."""
    reqs = _trace(n=3)
    plans = tuple(ServeFaultPlan(crash_steps=(0,)) for _ in range(4))
    with pytest.raises(SimulatedCrash):
        serve_with_recovery(params, CFG, reqs,
                            journal_dir=str(tmp_path / "j"),
                            plans=plans, max_restarts=2, **KW)


def test_crash_recovery_prefix_preemption_snapshot(params, tmp_path):
    """The full stack at once: prefix sharing + priority preemption +
    per-segment snapshots; the restart restores the pool + prefix index
    from the snapshot (warm start asserted) and still matches the calm
    run token-for-token."""
    shared = (np.arange(200, dtype=np.int32) % CFG.vocab_size)
    reqs = [ServeRequest(
        prompt=np.concatenate([shared[:140],
                               np.full(4, i, np.int32)]),
        gen=6, arrival=i * 2, priority=i % 2) for i in range(5)]
    kw = dict(slots=3, segment=4, max_len=256, page_size=128,
              chunk_size=48, prefix_sharing=True, preemption=True,
              debug_invariants=True)
    calm = serve_continuous(params, CFG, reqs, **kw)
    assert calm.prefix_hits > 0                    # sharing non-vacuous
    d = str(tmp_path / "j")
    res, crashes = serve_with_recovery(
        params, CFG, reqs, journal_dir=d, snapshot_every=1,
        plans=(ServeFaultPlan(crash_steps=(12,)),), **kw)
    assert crashes == 1
    assert res.restored_from_snapshot              # warm start happened
    assert res.snapshot_bytes > 0
    _assert_same_tokens(res, _tokens(calm), "prefix+preempt+snapshot")


def test_corrupt_snapshot_degrades_to_cold_start(params, tmp_path):
    """Flip a byte in the newest snapshot's first leaf: the checksum
    catches it, the restart cold-starts from the journal alone, and the
    tokens are still bit-identical — corruption costs warm-start time,
    never correctness."""
    shared = (np.arange(200, dtype=np.int32) % CFG.vocab_size)
    reqs = [ServeRequest(
        prompt=np.concatenate([shared[:140],
                               np.full(4, i, np.int32)]),
        gen=6, arrival=i * 2) for i in range(4)]
    kw = dict(slots=3, segment=4, max_len=256, page_size=128,
              chunk_size=48, prefix_sharing=True,
              debug_invariants=True)
    calm = serve_continuous(params, CFG, reqs, **kw)
    d = str(tmp_path / "j")
    with pytest.raises(SimulatedCrash):
        serve_continuous(params, CFG, reqs, journal_dir=d,
                         snapshot_every=1,
                         faults=ServeFaultPlan(crash_steps=(12,)), **kw)
    snaps = sorted(os.listdir(os.path.join(d, "snapshots")))
    assert snaps, "crash before any snapshot — test is vacuous"
    leaf = os.path.join(d, "snapshots", snaps[-1], "leaf_00000.npy")
    raw = bytearray(open(leaf, "rb").read())
    raw[-1] ^= 0xFF
    open(leaf, "wb").write(bytes(raw))
    res = serve_continuous(params, CFG, reqs, journal_dir=d,
                           resume=True, snapshot_every=1, **kw)
    assert res.recovered and not res.restored_from_snapshot
    _assert_same_tokens(res, _tokens(calm), "corrupt snapshot")


# ---------------------------------------------------------------------------
# Idempotent re-admission / request ids
# ---------------------------------------------------------------------------

def test_resume_finished_journal_replays_everything(params, tmp_path):
    """Resuming over a completed journal serves nothing: every request
    comes back as a replayed CompletedRequest with its original tokens,
    zero decode steps run."""
    reqs = _trace()
    d = str(tmp_path / "j")
    first = serve_continuous(params, CFG, reqs, journal_dir=d, **KW)
    again = serve_continuous(params, CFG, reqs, journal_dir=d,
                             resume=True, **KW)
    assert again.steps == 0 and again.segments == 0
    assert again.recovered
    assert all(c.replayed for c in again.completed)
    assert not any(c.replayed for c in first.completed)
    _assert_same_tokens(again, _tokens(first), "idempotent replay")
    assert again.replayed_tokens == sum(len(c.tokens)
                                        for c in first.completed)


def test_request_id_reuse_for_different_request_is_error(params,
                                                         tmp_path):
    d = str(tmp_path / "j")
    prng = np.random.default_rng(0)
    reqs = [ServeRequest(prompt=prng.integers(0, 128, 5).astype(np.int32),
                         gen=3, arrival=0, request_id="fixed-id")]
    serve_continuous(params, CFG, reqs, journal_dir=d, **KW)
    other = [ServeRequest(
        prompt=prng.integers(0, 128, 7).astype(np.int32),
        gen=3, arrival=0, request_id="fixed-id")]
    with pytest.raises(ValueError, match="reused"):
        serve_continuous(params, CFG, other, journal_dir=d,
                         resume=True, **KW)


def test_duplicate_request_ids_in_trace_rejected(params, tmp_path):
    prng = np.random.default_rng(0)
    reqs = [ServeRequest(prompt=prng.integers(0, 128, 5).astype(np.int32),
                         gen=2, arrival=0, request_id="dup")
            for _ in range(2)]
    with pytest.raises(ValueError, match="duplicate"):
        serve_continuous(params, CFG, reqs,
                         journal_dir=str(tmp_path / "j"), **KW)


def test_torn_complete_without_progress_falls_back_to_resume(params,
                                                             tmp_path):
    """The flush-ordering trap: craft a journal whose complete record
    survived but whose final progress lines were lost (torn flush).
    Replay must NOT trust the complete record — the request resumes
    partially and regenerates the missing tail bit-identically."""
    reqs = _trace(n=3, seed=11)
    calm = serve_continuous(params, CFG, reqs, **KW)
    d = str(tmp_path / "j")
    serve_continuous(params, CFG, reqs, journal_dir=d, **KW)
    jpath = os.path.join(d, "journal.jsonl")
    lines = open(jpath).read().splitlines()
    # strip request 0 out of every (batched) progress record but keep
    # its complete record — the shape a torn flush leaves behind when
    # the complete was buffered before the boundary's progress record;
    # re-wrap each edited record with a fresh crc so only the *content*
    # is torn, not the line framing
    kept, tore = [], False
    for ln in lines:
        rec = json.loads(ln)["rec"]
        if rec.get("t") == "progress" and "req-000000" in rec.get("d", {}):
            tore = True
            del rec["d"]["req-000000"]
            rec.get("k", {}).pop("req-000000", None)
            if not rec["d"]:
                continue
        canon = json.dumps(rec, sort_keys=True, separators=(",", ":"))
        kept.append(json.dumps({"crc": zlib.crc32(canon.encode()),
                                "rec": rec}))
    assert tore                                    # actually tore it
    with open(jpath, "w") as f:
        f.write("\n".join(kept) + "\n")
    res = serve_continuous(params, CFG, reqs, journal_dir=d,
                           resume=True, **KW)
    assert res.steps > 0                           # had to re-serve
    _assert_same_tokens(res, _tokens(calm), "torn complete")


def test_prompt_digest_is_content_addressed():
    a = np.asarray([1, 2, 3], np.int32)
    assert prompt_digest(a) == prompt_digest([1, 2, 3])
    assert prompt_digest(a) != prompt_digest([1, 2, 4])


# ---------------------------------------------------------------------------
# Graceful drain
# ---------------------------------------------------------------------------

def test_drain_finishes_inflight_then_resume_serves_rest(params,
                                                         tmp_path):
    """Drain with no timeout: admission stops, in-flight requests run to
    completion. A later resume serves exactly the remainder; union of
    the two runs == the calm run, token-for-token."""
    reqs = _trace()
    calm = serve_continuous(params, CFG, reqs, **KW)
    want = _tokens(calm)
    d = str(tmp_path / "j")
    drained = serve_continuous(params, CFG, reqs, journal_dir=d,
                               drain=ServeDrain(after_steps=8), **KW)
    assert drained.drained
    done = _tokens(drained)
    assert 0 < len(done) < len(reqs)               # split is non-trivial
    for i in done:                                 # finished cleanly
        np.testing.assert_array_equal(done[i], want[i])
    rest = serve_continuous(params, CFG, reqs, journal_dir=d,
                            resume=True, **KW)
    _assert_same_tokens(rest, want, "post-drain resume")
    served_again = {c.index for c in rest.completed if not c.replayed}
    assert served_again.isdisjoint(done)           # never served twice


def test_drain_timeout_stops_midflight_progress_journaled(params,
                                                          tmp_path):
    """Drain with a zero timeout stops at the next boundary even with
    work in flight; the journaled progress lets a resume complete the
    interrupted requests bit-identically."""
    reqs = _trace(seed=13)
    calm = serve_continuous(params, CFG, reqs, **KW)
    d = str(tmp_path / "j")
    drained = serve_continuous(params, CFG, reqs, journal_dir=d,
                               drain=ServeDrain(after_steps=8),
                               drain_timeout=0.0, **KW)
    assert drained.drained
    assert len(drained.completed) < len(reqs)
    rest = serve_continuous(params, CFG, reqs, journal_dir=d,
                            resume=True, **KW)
    _assert_same_tokens(rest, _tokens(calm), "timeout drain resume")


# ---------------------------------------------------------------------------
# Starvation aging
# ---------------------------------------------------------------------------

def _starvation_trace():
    prng = np.random.default_rng(0)
    highs = [ServeRequest(
        prompt=prng.integers(0, 128, 6).astype(np.int32),
        gen=8, arrival=i, priority=1) for i in range(14)]
    low = ServeRequest(prompt=prng.integers(0, 128, 6).astype(np.int32),
                       gen=4, arrival=4, priority=0)
    return highs + [low], len(highs)


def test_aging_bounds_low_class_admission_delay(params):
    """A high-class flood starves the low class without aging; with
    ``aging_steps`` its admission delay is bounded by the advertised
    ``aging_bound_steps`` plus one in-flight residency (nothing is
    preempted, so a fully aged request still waits for a slot to free).
    Aging reorders admissions only — tokens are untouched."""
    reqs, li = _starvation_trace()
    kw = dict(slots=2, segment=4, max_len=MAX_LEN, page_size=128,
              chunk_size=5, debug_invariants=True)
    off = serve_continuous(params, CFG, reqs, **kw)
    on = serve_continuous(params, CFG, reqs, aging_steps=8, **kw)
    delay_off = next(c for c in off.completed if c.index == li)
    delay_on = next(c for c in on.completed if c.index == li)
    d_off = delay_off.admitted_step - delay_off.arrival
    d_on = delay_on.admitted_step - delay_on.arrival
    bound = on.class_summary()[0]["aging_bound_steps"]
    assert bound == 8 * (1 + 1 - 0)
    # one in-flight residency: ceil((prefill + gen)/segment) segments,
    # plus the admission round that actually picks the aged request up
    residency = 4 * -(-(2 + 8) // 4) + 4
    assert d_on <= bound + residency, (d_on, bound)
    assert d_off > d_on + residency, (d_off, d_on)
    assert "aging_bound_steps" not in off.class_summary()[0]
    assert off.class_summary()[0]["max_admit_delay_steps"] == d_off
    _assert_same_tokens(on, _tokens(off), "aging changed tokens")


def test_aged_priority_properties():
    """Pure-helper property test: identity when off, monotone in wait,
    +1 per aging_steps, capped at max_class + 1, never below prio."""
    from repro.launch.steps import aged_priority
    prng = np.random.default_rng(0)
    for _ in range(200):
        prio = int(prng.integers(0, 4))
        max_class = int(prng.integers(prio, 5))
        aging = int(prng.integers(1, 20))
        w = int(prng.integers(0, 200))
        eff = aged_priority(prio, w, aging, max_class)
        assert aged_priority(prio, w, None, max_class) == prio
        assert aged_priority(prio, w, 0, max_class) == prio
        assert eff == min(prio + w // aging, max_class + 1)
        assert prio <= eff <= max_class + 1
        assert aged_priority(prio, w + aging, aging, max_class) >= eff
        # the bound: after aging*(max_class+1-prio) steps, capped
        assert aged_priority(prio, aging * (max_class + 1 - prio),
                             aging, max_class) == max_class + 1
    assert aged_priority(2, -5, 3, 2) == 2         # pre-arrival clamps


def test_aging_requires_positive_steps(params):
    reqs, _ = _starvation_trace()
    with pytest.raises(ValueError, match="aging_steps"):
        serve_continuous(params, CFG, reqs, aging_steps=-1, slots=2,
                         segment=4, max_len=MAX_LEN, page_size=128)
