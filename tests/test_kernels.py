"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode; integer results must match EXACTLY). Kernels are driven
through the public engine (``repro.attention.dispatch`` with explicit
``backend=`` overrides) — the registry is the only entry point."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro import attention as ATT
from repro.core.quant import EPS_MAX
from repro.kernels.int8_matmul.ops import int8_matmul
from repro.kernels.int8_matmul.ref import int8_matmul_ref
from repro.kernels.ita_attention import ref as AR
from repro.kernels.ita_softmax.ops import ita_softmax
from repro.kernels.ita_softmax.ref import ita_softmax_ref

rng = np.random.default_rng(0)


def _i8(*shape):
    return rng.integers(-128, 128, shape, dtype=np.int8)


def fused(q, k, v, s_q, s_k, s_v, s_out, *, kind, causal=True, window=0,
          q_offset=0, kv_len=None, adaptive=True, block_q=128,
          block_kv=128):
    """Drive one fused Pallas backend via the registry (kernel layout,
    int8 in / int8-at-s_out out)."""
    spec = ATT.AttentionSpec(
        mode="decode" if kind == "decode" else "prefill", impl="ita",
        causal=causal, window=window,
        softmax="adaptive" if adaptive else "paper", layout="bhsd",
        out_dtype="int8",
        q_len=q.shape[2] if kind == "decode" else None)
    return ATT.dispatch(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), spec=spec,
        scales=ATT.QuantScales(s_q, s_k, s_v, s_out), q_offset=q_offset,
        kv_len=kv_len, backend=f"ita_{kind}_pallas", block_q=block_q,
        block_kv=block_kv)


# ---------------------------------------------------------------------------
# int8 weight-stationary matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [
    (8, 32, 16), (100, 200, 96),
    pytest.param(256, 128, 128, marks=pytest.mark.slow),
    (33, 65, 17)])
@pytest.mark.parametrize("schedule", ["tpu", "weight_stationary"])
def test_int8_matmul_sweep(m, k, n, schedule):
    x, w = _i8(m, k), _i8(k, n)
    b = rng.integers(-1000, 1000, (n,), dtype=np.int32)
    mult = np.float32(0.002)
    ref = int8_matmul_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
                          jnp.broadcast_to(mult, (n,)))
    out = int8_matmul(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), mult,
                      block_m=32, block_n=16, block_k=32, schedule=schedule)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_int8_matmul_per_channel_and_batched():
    x = _i8(2, 3, 40)                       # leading batch dims
    w = _i8(40, 24)
    mult = rng.uniform(1e-4, 1e-2, (24,)).astype(np.float32)
    out = int8_matmul(jnp.asarray(x), jnp.asarray(w), None,
                      jnp.asarray(mult), block_m=8, block_n=8, block_k=8)
    ref = int8_matmul_ref(jnp.asarray(x.reshape(6, 40)), jnp.asarray(w),
                          jnp.zeros((24,), jnp.int32), jnp.asarray(mult))
    np.testing.assert_array_equal(np.asarray(out).reshape(6, 24),
                                  np.asarray(ref))


# ---------------------------------------------------------------------------
# standalone streaming softmax kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("r,c,bc", [
    (16, 128, 64), (48, 300, 128), (8, 64, 64),
    pytest.param(128, 512, 128, marks=pytest.mark.slow)])
@pytest.mark.parametrize("adaptive", [False, True])
def test_ita_softmax_kernel_sweep(r, c, bc, adaptive):
    x = _i8(r, c)
    mask = (rng.random((r, c)) > 0.2).astype(np.int8)
    out = ita_softmax(jnp.asarray(x), jnp.asarray(mask), block_r=16,
                      block_c=bc, adaptive=adaptive)
    pad = (-c) % bc
    xp = np.pad(x, ((0, 0), (0, pad)))
    mp = np.pad(mask, ((0, 0), (0, pad)))
    ref = ita_softmax_ref(jnp.asarray(xp), jnp.asarray(mp),
                          num_parts=(c + pad) // bc, adaptive=adaptive)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref)[:, :c])


# ---------------------------------------------------------------------------
# fused attention kernels
# ---------------------------------------------------------------------------

SQ = np.float32(0.05)
SO = np.float32(0.02)


def _attn_ref(q, k, v, causal, window, mode, adaptive, bkv, q_offset=0):
    b, h, sq, d = q.shape
    skv = k.shape[2]
    lmult = np.float32(SQ * SQ / (np.sqrt(d) * EPS_MAX))
    omult = np.float32(SQ / SO)
    return AR.ita_attention_stream_ref(
        jnp.asarray(q.reshape(b * h, sq, d)),
        jnp.asarray(k.reshape(b * h, skv, d)),
        jnp.asarray(v.reshape(b * h, skv, d)),
        lmult, omult, skv, causal=causal, window=window, adaptive=adaptive,
        block_kv=bkv, kind=mode, q_offset=q_offset)


@pytest.mark.parametrize("mode", ["onepass", "twopass"])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 48)])
@pytest.mark.parametrize("sq,skv", [
    (64, 192), (32, 32),
    pytest.param(128, 256, marks=pytest.mark.slow)])
def test_ita_attention_sweep(mode, causal, window, sq, skv):
    b, h, d = 2, 2, 64
    q, k, v = _i8(b, h, sq, d), _i8(b, h, skv, d), _i8(b, h, skv, d)
    out = fused(q, k, v, SQ, SQ, SQ, SO, causal=causal, window=window,
                kind=mode, adaptive=True, block_q=32, block_kv=64)
    ref = _attn_ref(q, k, v, causal, window, mode, True, 64)
    np.testing.assert_array_equal(
        np.asarray(out).reshape(b * h, sq, d), np.asarray(ref))


def test_ita_attention_gqa_and_decode():
    b, hq, hkv, d, skv = 1, 8, 2, 64, 512
    q, k, v = _i8(b, hq, 1, d), _i8(b, hkv, skv, d), _i8(b, hkv, skv, d)
    out = fused(q, k, v, SQ, SQ, SQ, SO, q_offset=skv - 1, causal=True,
                kind="onepass", block_q=8, block_kv=128)
    kr = np.repeat(k, 4, axis=1)
    vr = np.repeat(v, 4, axis=1)
    ref = _attn_ref(q, kr, vr, True, 0, "onepass", True, 128,
                    q_offset=skv - 1)
    np.testing.assert_array_equal(
        np.asarray(out).reshape(b * hq, 1, d), np.asarray(ref))


def test_twopass_matches_paper_oneshot_single_tile():
    """Single kv tile -> streaming == one-shot paper semantics exactly."""
    b, h, s, d = 1, 2, 64, 64
    q, k, v = _i8(b, h, s, d), _i8(b, h, s, d), _i8(b, h, s, d)
    out = fused(q, k, v, SQ, SQ, SQ, SO, causal=True, kind="twopass",
                adaptive=False, block_q=64, block_kv=64)
    lmult = np.float32(SQ * SQ / (np.sqrt(d) * EPS_MAX))
    ref, _ = AR.ita_attention_ref(
        jnp.asarray(q.reshape(b * h, s, d)), jnp.asarray(k.reshape(b * h, s, d)),
        jnp.asarray(v.reshape(b * h, s, d)), lmult, np.float32(SQ / SO), s,
        causal=True, adaptive=False)
    np.testing.assert_array_equal(np.asarray(out).reshape(b * h, s, d),
                                  np.asarray(ref))


# ---------------------------------------------------------------------------
# cross-implementation parity: Pallas kernels ≡ jnp oracle ≡ chunked XLA path
# ---------------------------------------------------------------------------

PARITY_CASES = [
    # hq, hkv, causal, window, kv_len   (skv=128, block_kv=64: 2 kv tiles)
    (4, 4, True, 0, None),              # causal MHA
    (4, 2, True, 0, None),              # GQA
    (4, 2, True, 48, None),             # GQA + sliding window
    pytest.param(2, 2, False, 0, None,  # bidirectional (encoder)
                 marks=pytest.mark.slow),
    (4, 4, True, 0, 100),               # masked tail (padded-seq serving)
]


@pytest.mark.parametrize("hq,hkv,causal,window,kv_len", PARITY_CASES)
def test_kernel_ref_chunked_parity(hq, hkv, causal, window, kv_len):
    """onepass ≡ twopass' stream oracle ≡ chunked ``ita_int`` across
    causal/window/GQA/masked shapes.

    - onepass / twopass: exact (bit-identical to the streaming oracle at
      matching tile size).
    - chunked ``ita_int`` (repro.attention.chunked): same DA/DI at
      chunk granularity but clips the ``u = 128>>k`` numerator to 127 so
      A·V rides the int8 MXU — max-element terms differ by ≤ 1/128, so
      parity there is near-exact on the int8 output grid, not bitwise.
    """
    from repro.attention.chunked import streaming_attention

    b, sq, skv, d, bkv = 2, 64, 128, 32, 64
    q = _i8(b, hq, sq, d)
    k = _i8(b, hkv, skv, d)
    v = _i8(b, hkv, skv, d)
    eff_kv = skv if kv_len is None else kv_len
    lmult = np.float32(SQ * SQ / (np.sqrt(d) * EPS_MAX))
    omult = np.float32(SQ / SO)

    kr = np.repeat(k, hq // hkv, axis=1)
    vr = np.repeat(v, hq // hkv, axis=1)
    ref = np.asarray(AR.ita_attention_stream_ref(
        jnp.asarray(q.reshape(b * hq, sq, d)),
        jnp.asarray(kr.reshape(b * hq, skv, d)),
        jnp.asarray(vr.reshape(b * hq, skv, d)),
        lmult, omult, eff_kv, causal=causal, window=window, adaptive=True,
        block_kv=bkv, kind="onepass")).reshape(b, hq, sq, d)

    for mode in ("onepass", "twopass"):
        out = np.asarray(fused(
            q, k, v, SQ, SQ, SQ, SO, kv_len=eff_kv, causal=causal,
            window=window, kind=mode, adaptive=True, block_q=32,
            block_kv=bkv))
        if mode == "onepass":
            np.testing.assert_array_equal(out, ref, err_msg=mode)
        else:
            ref2 = np.asarray(AR.ita_attention_stream_ref(
                jnp.asarray(q.reshape(b * hq, sq, d)),
                jnp.asarray(kr.reshape(b * hq, skv, d)),
                jnp.asarray(vr.reshape(b * hq, skv, d)),
                lmult, omult, eff_kv, causal=causal, window=window,
                adaptive=True, block_kv=bkv,
                kind="twopass")).reshape(b, hq, sq, d)
            np.testing.assert_array_equal(out, ref2, err_msg=mode)

    # chunked XLA path (model layout (B,S,H,hd)); requant to the s_out grid
    chunk = streaming_attention(
        jnp.asarray(q.transpose(0, 2, 1, 3)),
        jnp.asarray(k.transpose(0, 2, 1, 3)),
        jnp.asarray(v.transpose(0, 2, 1, 3)),
        impl="ita_int", scale=d ** -0.5, s_q=SQ, s_k=SQ, s_v=SQ,
        causal=causal, window=window, kv_len=eff_kv, q_chunk=32,
        kv_chunk=bkv)
    chunk_i8 = np.clip(np.round(np.asarray(chunk) / SO), -128, 127
                       ).transpose(0, 2, 1, 3).astype(np.int64)
    diff = np.abs(chunk_i8 - ref.astype(np.int64))
    assert diff.max() <= 1, diff.max()          # u-clip skew: ≤ 1 LSB
    assert (diff > 0).mean() < 0.12, (diff > 0).mean()


def test_attention_accuracy_vs_float():
    """End-to-end: ITA integer attention approximates float attention on
    realistically-scaled inputs (the paper's Fig. 5 effect)."""
    b, h, s, d = 2, 4, 128, 64
    qf = rng.normal(0, 1, (b, h, s, d)).astype(np.float32)
    kf = rng.normal(0, 1, (b, h, s, d)).astype(np.float32)
    vf = rng.normal(0, 1, (b, h, s, d)).astype(np.float32)
    s_act = np.float32(3.0 / 127)
    q8 = np.clip(np.round(qf / s_act), -128, 127).astype(np.int8)
    k8 = np.clip(np.round(kf / s_act), -128, 127).astype(np.int8)
    v8 = np.clip(np.round(vf / s_act), -128, 127).astype(np.int8)
    out8 = fused(q8, k8, v8, s_act, s_act, s_act, np.float32(2.0 / 127),
                 causal=True, kind="onepass")
    out = np.asarray(out8).astype(np.float32) * (2.0 / 127)
    ref = np.asarray(AR.float_attention_ref(
        jnp.asarray(qf.reshape(b * h, s, d)),
        jnp.asarray(kf.reshape(b * h, s, d)),
        jnp.asarray(vf.reshape(b * h, s, d)), causal=True))
    rel = np.abs(out.reshape(b * h, s, d) - ref).mean() / np.abs(ref).mean()
    assert rel < 0.25, rel
