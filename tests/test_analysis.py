"""Static analysis: the integer-range verifier and the jit-hygiene lints.

Coverage contract (ISSUE 7 acceptance):
- the interval domain's transfer helpers are exact on their corner
  cases (truncating division, logical shifts of negative bit patterns,
  count-leading-zeros);
- the analyzer proves no-overflow for the certified softmax cases and
  the proven bounds match a golden snapshot — the certificate is a
  regression artifact, not just a boolean;
- the verifier has teeth: seeded mutants (a dropped requant clip, a
  dropped shift clamp, a widened softmax numerator) each flip their
  case to FAIL with the expected finding kind;
- ``serve_continuous`` over a mixed chunked trace compiles a bounded,
  asserted number of segment variants, each exactly once, with every
  donated carry actually aliased (the PR-5 pow2-rounding and PR-3
  donation contracts).
"""

import json

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import analyze_jaxpr, build_matrix, run_case
from repro.analysis.intervals import (Interval, clz, div_int, dtype_range,
                                      fits, point, shift_right_logical)

# ---------------------------------------------------------------------------
# Interval domain corner cases
# ---------------------------------------------------------------------------

def test_div_int_truncation_corners():
    # trunc-toward-zero: -7 // 2 == -3 (lax.div), not python's -4
    out, had_zero = div_int(Interval(-7, -7), Interval(2, 2))
    assert not had_zero and (out.lo, out.hi) == (-3, -3)
    out, had_zero = div_int(Interval(-10, 9), Interval(3, 5))
    assert not had_zero
    assert out.contains(point(-10 // 3 + 1))    # -3 (truncated)
    assert out.contains(point(3)) and out.lo == -3 and out.hi == 3
    out, had_zero = div_int(Interval(1, 8), Interval(-2, 2))
    assert had_zero                             # divisor straddles zero


def test_shift_right_logical_negative_patterns():
    # shift 0 is the identity even for negatives
    out = shift_right_logical(Interval(-5, 7), Interval(0, 0), 32)
    assert (out.lo, out.hi) == (-5, 7)
    # shift >= 1 reinterprets the sign bit: bound is (2^32-1) >> s
    out = shift_right_logical(Interval(-1, -1), Interval(1, 1), 32)
    assert out.hi == (1 << 31) - 1              # 0xFFFFFFFF >> 1
    assert out.lo == 0
    # non-negative values shift exactly
    out = shift_right_logical(Interval(128, 128), Interval(2, 5), 32)
    assert (out.lo, out.hi) == (4, 32)


def test_clz_bounds():
    assert clz(point(1), 32) == point(31)
    assert clz(point(0), 32) == point(32)
    out = clz(Interval(1, 1 << 20), 32)
    assert (out.lo, out.hi) == (11, 31)
    assert clz(Interval(-5, -1), 32) == point(0)   # sign bit set


def test_dtype_fit():
    assert fits(Interval(-128, 127), jnp.int8)
    assert not fits(Interval(-129, 0), jnp.int8)
    assert dtype_range(jnp.int32).hi == (1 << 31) - 1


# ---------------------------------------------------------------------------
# Analyzer end-to-end on synthetic jaxprs
# ---------------------------------------------------------------------------

def test_analyzer_proves_clipped_matmul_and_flags_unclipped():
    def clipped(x, y):
        acc = jax.lax.dot_general(x, y, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.int32)
        return jnp.clip(acc, -128, 127).astype(jnp.int8)

    def unclipped(x, y):
        acc = jax.lax.dot_general(x, y, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.int32)
        return acc.astype(jnp.int8)

    args = (jax.ShapeDtypeStruct((4, 64), jnp.int8),
            jax.ShapeDtypeStruct((64, 4), jnp.int8))
    seeds = [Interval(-128, 127), Interval(-128, 127)]

    res = analyze_jaxpr(jax.make_jaxpr(clipped)(*args), seeds)
    assert res.ok and res.max_int_magnitude == 64 * 128 * 128

    res = analyze_jaxpr(jax.make_jaxpr(unclipped)(*args), seeds)
    assert not res.ok
    assert [f.kind for f in res.findings] == ["narrowing"]


def test_analyzer_flags_int32_product_overflow():
    def f(x):
        return x * x                            # (2^20)^2 >> int32

    res = analyze_jaxpr(
        jax.make_jaxpr(f)(jax.ShapeDtypeStruct((8,), jnp.int32)),
        [Interval(-(1 << 20), 1 << 20)])
    assert not res.ok
    assert res.findings[0].kind == "overflow"
    assert res.findings[0].prim == "mul"


# ---------------------------------------------------------------------------
# Golden range-report snapshot: the ita_softmax certificates
# ---------------------------------------------------------------------------

# (ok, proven output intervals, widest |int| bound, unproven-op count)
# for the smoke geometry. The 2^27-1 max is the *unclamped* DA exponent
# k = (max - x) >> 5 before its min(k, 31) clamp — the analyzer cannot
# know max >= x (relational), so the logical shift of a possibly-
# negative diff spans [0, (2^32-1) >> 5]; everything downstream of the
# clamp is tight. Changing any of these numbers means the proven range
# behaviour of the softmax changed — that is a semantics review, not a
# snapshot refresh.
SOFTMAX_GOLDEN = {
    "ita_softmax_pallas/paper": (True, [[0.0, 256.0]], (1 << 27) - 1, 0),
    "ita_softmax_pallas/adaptive": (True, [[0.0, 256.0]], (1 << 27) - 1, 0),
    "ita_softmax_ref/paper": (
        True, [[0, 256], [1, 32768], [-256, 127]], (1 << 27) - 1, 0),
    "ita_softmax_ref/adaptive": (
        True, [[0, 256], [0, 15], [-256, 127]], (1 << 27) - 1, 0),
}


def _case(name, smoke=True):
    matches = [c for c in build_matrix(smoke=smoke) if c.name == name]
    assert len(matches) == 1, name
    return matches[0]


@pytest.mark.parametrize("name", sorted(SOFTMAX_GOLDEN))
def test_softmax_range_report_matches_golden(name):
    r = run_case(_case(name))
    ok, out, mag, unproven = SOFTMAX_GOLDEN[name]
    assert r["ok"] == ok, r.get("findings", r.get("error"))
    assert r["out"] == out
    assert r["max_int_magnitude"] == mag
    assert r["n_unproven"] == unproven
    assert json.dumps(r)                        # JSON-serializable artifact


# ---------------------------------------------------------------------------
# Teeth: seeded mutants must flip their certificate to FAIL
# ---------------------------------------------------------------------------

def test_mutant_dropped_requant_clip_is_flagged(monkeypatch):
    """Remove the int8 clip from the QK requant: the two-pass kernel's
    int8 logit store is no longer proven in range -> narrowing."""
    import repro.kernels.ita_attention.kernel as K

    def qk_noclip(q_tile, k_tile, mult):
        acc = jax.lax.dot_general(q_tile, k_tile, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.int32)
        return jnp.round(acc.astype(jnp.float32) * mult).astype(jnp.int32)

    monkeypatch.setattr(K, "_qk_logits", qk_noclip)
    r = run_case(_case("ita_twopass_pallas/prefill-paper"))
    assert not r["ok"]
    assert "narrowing" in {f["kind"] for f in r["findings"]}


def test_mutant_dropped_shift_clamp_is_flagged(monkeypatch):
    """Remove min(k, 31) from the DA update: the masked-row exponent
    reaches 2^27 and the 128 >> k shift is no longer proven legal."""
    import repro.kernels.ita_attention.kernel as K
    from repro.core.quant import SOFTMAX_SHIFT
    from repro.kernels.common import MASK_K, NEG_SENTINEL

    def da_noclamp(m_ref, sigma_ref, logits_i32, valid):
        x = jnp.where(valid, logits_i32, NEG_SENTINEL)
        new_max = jnp.maximum(m_ref[...],
                              jnp.max(x, axis=1, keepdims=True))
        delta = jnp.minimum(jax.lax.shift_right_logical(
            new_max - m_ref[...], SOFTMAX_SHIFT), 31)
        k = jax.lax.shift_right_logical(new_max - logits_i32,
                                        SOFTMAX_SHIFT)
        k = jnp.where(valid, k, MASK_K)         # min(k, 31) dropped
        u = jax.lax.shift_right_logical(jnp.int32(128), k)
        sigma_ref[...] = jax.lax.shift_right_logical(
            sigma_ref[...], delta) + 2 * jnp.sum(u, axis=1, keepdims=True)
        m_ref[...] = new_max
        return u, delta

    monkeypatch.setattr(K, "da_update", da_noclamp)
    r = run_case(_case("ita_onepass_pallas/prefill-paper"))
    assert not r["ok"]
    assert "shift_range" in {f["kind"] for f in r["findings"]}


def test_mutant_widened_softmax_numerator_is_flagged(monkeypatch):
    """Remove the p <= 256 identity clamp from the reference softmax:
    at production kv length the p*V int32 accumulator (65536 * 127 *
    2048) is no longer proven in range -> overflow."""
    from repro.core import quant as Q
    from repro.core import softmax as SM

    def noclamp(x_q, mask=None, axis=-1):
        row_max = SM._masked_max(x_q, mask, axis)
        k = SM._apply_mask_k(SM._k_of(x_q, row_max), mask)
        terms = jax.lax.shift_right_logical(
            jnp.int32(SM._UNIT), jnp.minimum(k, 31))
        sigma = jnp.maximum(jnp.sum(terms, axis=axis, keepdims=True), 1)
        sigma_inv = (jnp.int32(1) << SM._W_INV) // sigma
        p = jax.lax.shift_right_logical(sigma_inv, jnp.minimum(k, 31))
        return p, sigma, row_max                # p <= _UNIT clamp dropped

    monkeypatch.setattr(SM, "ita_softmax_int", noclamp)
    r = run_case(_case("ita_direct_xla/decode-paper", smoke=False))
    assert not r["ok"]
    kinds = {f["kind"] for f in r["findings"]}
    assert kinds & {"overflow", "narrowing"}, r["findings"]
    assert Q  # keep the import exercised (quant constants stay loaded)


# ---------------------------------------------------------------------------
# Jit hygiene: recompile count + donation over a real mixed trace
# ---------------------------------------------------------------------------

def test_serve_recompile_count_bounded_and_donation_used():
    from repro.analysis.lints import (expected_variant_bound,
                                      run_lints)

    report = run_lints(smoke=True)
    by_name = {lint["name"]: lint for lint in report["lints"]}
    assert by_name["pow2-variant-contract"]["ok"], by_name
    assert by_name["serve-recompile-bound"]["ok"], by_name
    assert by_name["no-retrace-per-variant"]["ok"], by_name
    assert by_name["preemption-no-retrace"]["ok"], by_name
    assert by_name["donation-used"]["ok"], by_name
    assert expected_variant_bound(8) == 5


# ---------------------------------------------------------------------------
# CLI artifact
# ---------------------------------------------------------------------------

def test_cli_writes_schema_checked_report(tmp_path, capsys):
    from repro.analysis.__main__ import main
    from repro.analysis.verify import REPORT_SCHEMA

    out = tmp_path / "range_report.json"
    rc = main(["--smoke", "--backend", "ita_softmax", "--no-lints",
               "--json", str(out)])
    assert rc == 0
    rep = json.loads(out.read_text())
    assert rep["schema"] == REPORT_SCHEMA
    assert rep["ok"] and rep["n_failed"] == 0
    assert rep["certified_backends"] == ["ita_softmax"]
    assert {c["name"] for c in rep["cases"]} == set(SOFTMAX_GOLDEN)
    assert "certificates" in capsys.readouterr().out
