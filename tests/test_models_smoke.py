"""Per-architecture smoke tests (deliverable f): reduced config of each
family, one forward/train step on CPU, output shapes + no NaNs; plus
decode-vs-teacher-forced consistency and the ITA quantized path."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config
from repro.models import forward, init_caches, init_model, loss_fn

KEY = jax.random.PRNGKey(0)

# big/exotic stacks and duplicate-family configs dominate suite wall-clock —
# default tier-1 keeps one arch per family (qwen2 dense, mixtral moe+swa,
# phi3 dense, rwkv6 recurrent), the rest run under --runslow (nightly lane)
_HEAVY = {"recurrentgemma-2b", "llama-3.2-vision-90b", "whisper-large-v3",
          "gemma2-27b", "olmoe-1b-7b", "deepseek-coder-33b"}


def _arch_params(archs):
    return [pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY else a
            for a in archs]


@functools.lru_cache(maxsize=None)
def _cfg_params(arch, impl="float"):
    """Share configs + initialized params across the per-arch tests."""
    cfg = get_config(arch, smoke=True, attention_impl=impl)
    return cfg, init_model(KEY, cfg)


def _batch(cfg, b=2, s=16):
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.frontend_dim:
        batch["frontend"] = jax.random.normal(
            KEY, (b, cfg.n_frontend_tokens, cfg.frontend_dim), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", _arch_params(ARCH_IDS))
def test_forward_and_train_step(arch):
    cfg, params = _cfg_params(arch)
    batch = _batch(cfg)
    logits, _, _ = forward(params, batch["tokens"], cfg, mode="train",
                           frontend=batch.get("frontend"))
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, batch, cfg)
    assert bool(jnp.isfinite(loss))
    gsq = jax.tree.reduce(lambda a, b: a + b,
                          jax.tree.map(lambda g: jnp.sum(jnp.square(g)),
                                       grads))
    assert bool(jnp.isfinite(gsq))


@pytest.mark.parametrize("arch", _arch_params(ARCH_IDS))
def test_decode_matches_teacher_forcing(arch):
    cfg, params = _cfg_params(arch)
    b, s = 2, 24
    batch = _batch(cfg, b, s)
    fe = batch.get("frontend")
    full, _, _ = forward(params, batch["tokens"], cfg, mode="train",
                         frontend=fe)
    caches = init_caches(cfg, b, max_len=s + 4)
    lp, caches, _ = forward(params, batch["tokens"][:, :s - 1], cfg,
                            mode="prefill", frontend=fe, caches=caches)
    ld, _, _ = forward(params, batch["tokens"][:, s - 1:s], cfg,
                       mode="decode", frontend=fe, caches=caches, pos0=s - 1)
    np.testing.assert_allclose(np.asarray(ld[:, 0]),
                               np.asarray(full[:, -1]), atol=2e-3)
    np.testing.assert_allclose(np.asarray(lp[:, -1]),
                               np.asarray(full[:, -2]), atol=2e-3)


@pytest.mark.parametrize("arch", [
    "qwen2-7b",
    pytest.param("gemma2-27b", marks=pytest.mark.slow),
    pytest.param("mixtral-8x7b", marks=pytest.mark.slow),
    pytest.param("whisper-large-v3", marks=pytest.mark.slow),
    pytest.param("recurrentgemma-2b", marks=pytest.mark.slow)])
def test_ita_quantized_path(arch):
    """QAT train grads finite + integer serve path finite with int8 cache."""
    cfg, params = _cfg_params(arch, "ita")
    batch = _batch(cfg)
    (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, batch, cfg)
    gsq = jax.tree.reduce(lambda a, b: a + b,
                          jax.tree.map(lambda g: jnp.sum(jnp.square(g)),
                                       grads))
    assert bool(jnp.isfinite(loss)) and bool(jnp.isfinite(gsq))

    caches = init_caches(cfg, 2, max_len=20)
    lp, caches, _ = forward(params, batch["tokens"], cfg, mode="prefill",
                            frontend=batch.get("frontend"), caches=caches)
    ld, _, _ = forward(params, batch["tokens"][:, -1:], cfg, mode="decode",
                       frontend=batch.get("frontend"), caches=caches,
                       pos0=16)
    assert bool(jnp.all(jnp.isfinite(ld)))
    kv_dtypes = {l.dtype for path, l in
                 jax.tree_util.tree_flatten_with_path(caches)[0]
                 if any(getattr(k, "key", getattr(k, "name", None))
                        in ("k", "v", "k8", "v8") for k in path)}
    assert kv_dtypes == {jnp.dtype(jnp.int8)}, kv_dtypes


def test_ita_vs_float_logits_close():
    """End to end: ITA integer serving approximates the float model on a
    QAT-consistent checkpoint (same random params here)."""
    cfg_f = get_config("phi3-mini-3.8b", smoke=True)
    cfg_q = get_config("phi3-mini-3.8b", smoke=True, attention_impl="ita")
    params = init_model(KEY, cfg_f)
    from repro.models.transformer import init_model as im
    params_q = im(KEY, cfg_q)
    # share the float weights
    for k in ("embed", "final_norm"):
        params_q[k] = params[k]
    batch = _batch(cfg_f)
    lf, _, _ = forward(params, batch["tokens"], cfg_f, mode="train")
    caches = init_caches(cfg_q, 2, max_len=25)
    lq, _, _ = forward(params_q, batch["tokens"], cfg_q, mode="prefill",
                       caches=caches)
    # same argmax on most positions (quantization-consistent behaviour)
    agree = (jnp.argmax(lf, -1) == jnp.argmax(lq, -1)).mean()
    assert float(agree) > 0.5, float(agree)


def test_swa_ring_buffer_long_decode():
    """Sliding-window ring cache: decoding past the window keeps only the
    last `window` tokens and matches teacher forcing."""
    cfg, params = _cfg_params("mixtral-8x7b")      # window 16
    b, s = 1, 40                                    # 2.5x window
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    full, _, _ = forward(params, tokens, cfg, mode="train")
    caches = init_caches(cfg, b, max_len=s)
    _, caches, _ = forward(params, tokens[:, :s - 1], cfg, mode="prefill",
                           caches=caches)
    ld, _, _ = forward(params, tokens[:, s - 1:], cfg, mode="decode",
                       caches=caches, pos0=s - 1)
    np.testing.assert_allclose(np.asarray(ld[:, 0]), np.asarray(full[:, -1]),
                               atol=2e-3)
