"""Int8 KV-cache decode engine: parity + ring-buffer semantics.

The acceptance property: token-by-token decode through the int8 ring
buffer (``repro.attention.KVCacheState`` + the decode-shaped Pallas
kernel behind ``ita_decode_pallas``) is **bit-identical** to the matching
rows of one-shot ``ita_onepass_pallas`` prefill — causal, sliding-window
and GQA — because the decode kernel replays the exact streaming-DA tile
schedule of the onepass kernel over the same block boundaries.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import attention as ATT
from repro.runtime import kv_cache as KV

rng = np.random.default_rng(0)

S, PREFILL, BKV = 128, 96, 64
S_Q, S_OUT = np.float32(0.05), np.float32(0.02)


def _i8(*shape):
    return rng.integers(-128, 128, shape, dtype=np.int8)


def _fused(q, k, v, s_k, s_v, *, kind, causal, window, q_offset=0,
           kv_len=None, block_q=128, block_kv=BKV):
    """int8 kernel-layout dispatch through the registry."""
    spec = ATT.AttentionSpec(
        mode="decode" if kind == "decode" else "prefill", impl="ita",
        causal=causal, window=window, layout="bhsd",
        scale_kind="per_head", out_dtype="int8",
        q_len=q.shape[2] if kind == "decode" else None)
    return ATT.dispatch(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), spec=spec,
        scales=ATT.QuantScales(S_Q, s_k, s_v, S_OUT), q_offset=q_offset,
        kv_len=kv_len, backend=f"ita_{kind}_pallas", block_q=block_q,
        block_kv=block_kv)


@pytest.mark.parametrize("hq,hkv,causal,window", [
    (4, 4, True, 0),        # MHA causal
    (4, 2, True, 0),        # GQA
    (4, 2, True, 48),       # GQA + sliding window (crosses tile boundary)
])
def test_decode_bit_identical_to_oneshot_prefill(hq, hkv, causal, window):
    b, d = 2, 32
    q = _i8(b, hq, S, d)
    k = _i8(b, hkv, S, d)          # (B, H, S, D) kernel layout
    v = _i8(b, hkv, S, d)
    sk = rng.uniform(0.03, 0.07, (hkv,)).astype(np.float32)
    sv = rng.uniform(0.03, 0.07, (hkv,)).astype(np.float32)

    full = np.asarray(_fused(q, k, v, jnp.asarray(sk), jnp.asarray(sv),
                             kind="onepass", causal=causal, window=window,
                             block_q=32))

    # ring cache in (B, S, G, hd) layout, sized to the full sequence
    cache = KV.init_cache(b, S, hkv, d, per_head_scales=True)
    cache = cache.with_scales(jnp.asarray(sk), jnp.asarray(sv))
    cache = cache.prefill_write(
        jnp.asarray(k[:, :, :PREFILL].transpose(0, 2, 1, 3)),
        jnp.asarray(v[:, :, :PREFILL].transpose(0, 2, 1, 3)))

    for t in range(PREFILL, S):
        cache = cache.decode_append(
            jnp.asarray(k[:, :, t:t + 1].transpose(0, 2, 1, 3)),
            jnp.asarray(v[:, :, t:t + 1].transpose(0, 2, 1, 3)))
        out = _fused(q[:, :, t:t + 1],
                     np.asarray(cache.k.transpose(0, 2, 1, 3)),
                     np.asarray(cache.v.transpose(0, 2, 1, 3)),
                     cache.k_scale, cache.v_scale, kind="decode",
                     causal=causal, window=window,
                     q_offset=cache.q_offset(1), kv_len=cache.valid_len())
        np.testing.assert_array_equal(np.asarray(out)[:, :, 0],
                                      full[:, :, t],
                                      err_msg=f"decode step t={t}")


def test_decode_attend_engine_matches_oneshot():
    """The float-in/int8-out engine path (per-head quantization inside
    ``prefill_attend``/``decode_attend``) is bit-identical to one-shot
    attention over the same quantized tensors and scales."""
    b, hq, hkv, d = 1, 4, 2, 32
    qf = rng.normal(0, 1, (b, hq, S, d)).astype(np.float32)
    kf = rng.normal(0, 1, (b, S, hkv, d)).astype(np.float32)
    vf = rng.normal(0, 1, (b, S, hkv, d)).astype(np.float32)
    q8 = KV.quantize_with_scale(jnp.asarray(qf), S_Q)

    cache = KV.init_cache(b, S, hkv, d, per_head_scales=True)
    _, cache = KV.prefill_attend(cache, q8[:, :, :PREFILL],
                                 jnp.asarray(kf[:, :PREFILL]),
                                 jnp.asarray(vf[:, :PREFILL]), S_Q, S_OUT,
                                 block_q=32, block_kv=BKV)
    outs = []
    for t in range(PREFILL, S):
        out, cache = KV.decode_attend(cache, q8[:, :, t:t + 1],
                                      jnp.asarray(kf[:, t:t + 1]),
                                      jnp.asarray(vf[:, t:t + 1]),
                                      S_Q, S_OUT, block_kv=BKV)
        outs.append(np.asarray(out)[:, :, 0])

    # one-shot over the cache's own int8 contents + frozen scales
    full = np.asarray(_fused(
        np.asarray(q8), np.asarray(cache.k.transpose(0, 2, 1, 3)),
        np.asarray(cache.v.transpose(0, 2, 1, 3)), cache.k_scale,
        cache.v_scale, kind="onepass", causal=True, window=0, block_q=32))
    np.testing.assert_array_equal(np.stack(outs, axis=2),
                                  full[:, :, PREFILL:])


def test_decode_mode_matches_onepass_same_call():
    """ita_decode_pallas ≡ ita_onepass_pallas for a single query at any
    prefix — the family invariant the registry's parity sweep rests on."""
    b, h, d, cap = 2, 4, 32, 128
    q = _i8(b, h, 1, d)
    k, v = _i8(b, h, cap, d), _i8(b, h, cap, d)
    for kv_len in (1, 63, 64, 65, 128):
        kw = dict(causal=True, window=0, q_offset=kv_len - 1, kv_len=kv_len)
        a = _fused(q, k, v, S_Q, S_Q, kind="decode", **kw)
        b_ = _fused(q, k, v, S_Q, S_Q, kind="onepass", block_q=8, **kw)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))


@pytest.mark.parametrize("hq,hkv,window", [(4, 2, 0), (4, 4, 48)])
def test_ragged_batched_decode_matches_per_sequence(hq, hkv, window):
    """One batched decode call with per-sequence (B,) q_offset/kv_len is
    bit-identical to decoding each sequence alone with scalar offsets —
    mixed prefix lengths, including one past the ring wrap (kv_len ==
    capacity, q_offset == capacity - 1)."""
    b, d, cap = 3, 32, 128
    kv_lens = [40, 128, 97]                    # row 1 is fully wrapped
    q = _i8(b, hq, 1, d)
    k, v = _i8(b, hkv, cap, d), _i8(b, hkv, cap, d)
    sk = rng.uniform(0.03, 0.07, (hkv,)).astype(np.float32)
    sv = rng.uniform(0.03, 0.07, (hkv,)).astype(np.float32)

    ragged = _fused(q, k, v, jnp.asarray(sk), jnp.asarray(sv), kind="decode",
                    causal=True, window=window,
                    q_offset=jnp.asarray([n - 1 for n in kv_lens]),
                    kv_len=jnp.asarray(kv_lens))
    for row, n in enumerate(kv_lens):
        dense = _fused(q[row:row + 1], k[row:row + 1], v[row:row + 1],
                       jnp.asarray(sk), jnp.asarray(sv), kind="decode",
                       causal=True, window=window, q_offset=n - 1, kv_len=n)
        np.testing.assert_array_equal(np.asarray(ragged)[row],
                                      np.asarray(dense)[0],
                                      err_msg=f"row {row} kv_len={n}")


def test_ragged_decode_attend_engine_matches_per_sequence():
    """Engine-level ragged decode: one shared cache with per-sequence
    positions decodes every row bit-identically to running that row in
    its own B=1 cache (same frozen scales, mixed prompt lengths, decode
    continuing past the shortest row's prompt)."""
    b, hq, hkv, d, cap = 3, 4, 2, 32, 64
    lens = [17, 48, 33]
    pad = max(lens)
    steps = 6
    qf = rng.normal(0, 1, (b, hq, pad + steps, d)).astype(np.float32)
    kf = rng.normal(0, 1, (b, pad + steps, hkv, d)).astype(np.float32)
    vf = rng.normal(0, 1, (b, pad + steps, hkv, d)).astype(np.float32)
    sk = jnp.asarray(rng.uniform(0.03, 0.07, (hkv,)).astype(np.float32))
    sv = jnp.asarray(rng.uniform(0.03, 0.07, (hkv,)).astype(np.float32))
    q8 = KV.quantize_with_scale(jnp.asarray(qf), S_Q)
    k8 = KV.quantize_with_scale(jnp.asarray(kf), sk[None, None, :, None])
    v8 = KV.quantize_with_scale(jnp.asarray(vf), sv[None, None, :, None])

    # batched ragged cache: padded prefill + per-sequence lengths
    cache = KV.init_cache(b, cap, hkv, d, per_head_scales=True) \
        .with_scales(sk, sv) \
        .prefill_write(k8[:, :pad], v8[:, :pad],
                       lengths=jnp.asarray(lens, jnp.int32))
    outs = []
    for t in range(steps):
        # row b's step-t query/kv live at its own stream position len_b + t
        idx = jnp.asarray([ln + t for ln in lens], jnp.int32)
        qt = jnp.take_along_axis(q8, idx[:, None, None, None], axis=2)
        kt = jnp.take_along_axis(k8, idx[:, None, None, None], axis=1)
        vt = jnp.take_along_axis(v8, idx[:, None, None, None], axis=1)
        cache = cache.decode_append(kt, vt)
        out = ATT.dispatch(
            qt, cache.k, cache.v,
            spec=ATT.AttentionSpec(mode="decode", impl="ita",
                                   layout="bhsd_bsgd",
                                   scale_kind="per_head", out_dtype="int8",
                                   q_len=1),
            scales=ATT.QuantScales(S_Q, sk, sv, S_OUT),
            q_offset=cache.q_offset(1), kv_len=cache.valid_len(),
            block_kv=BKV)
        outs.append(np.asarray(out))

    for row, ln in enumerate(lens):
        solo = KV.init_cache(1, cap, hkv, d, per_head_scales=True) \
            .with_scales(sk, sv) \
            .prefill_write(k8[row:row + 1, :ln], v8[row:row + 1, :ln])
        for t in range(steps):
            p = ln + t
            solo = solo.decode_append(k8[row:row + 1, p:p + 1],
                                      v8[row:row + 1, p:p + 1])
            out = ATT.dispatch(
                q8[row:row + 1, :, p:p + 1], solo.k, solo.v,
                spec=ATT.AttentionSpec(mode="decode", impl="ita",
                                       layout="bhsd_bsgd",
                                       scale_kind="per_head",
                                       out_dtype="int8", q_len=1),
                scales=ATT.QuantScales(S_Q, sk, sv, S_OUT),
                q_offset=solo.q_offset(1), kv_len=solo.valid_len(),
                block_kv=BKV)
            np.testing.assert_array_equal(
                outs[t][row], np.asarray(out)[0],
                err_msg=f"row {row} (len {ln}) step {t}")


def test_prefill_attend_cache_native_no_transpose():
    """The bsgd prefill layout (onepass kernel via index maps) is
    bit-identical to the transposed bhsd dispatch it replaced."""
    b, hq, hkv, d = 2, 4, 2, 32
    qf = rng.normal(0, 1, (b, hq, S, d)).astype(np.float32)
    kf = rng.normal(0, 1, (b, S, hkv, d)).astype(np.float32)
    vf = rng.normal(0, 1, (b, S, hkv, d)).astype(np.float32)
    q8 = KV.quantize_with_scale(jnp.asarray(qf), S_Q)

    cache = KV.init_cache(b, S, hkv, d, per_head_scales=True)
    out, cache = KV.prefill_attend(cache, q8, jnp.asarray(kf),
                                   jnp.asarray(vf), S_Q, S_OUT,
                                   block_q=32, block_kv=BKV)
    ref = _fused(np.asarray(q8), np.asarray(cache.k.transpose(0, 2, 1, 3)),
                 np.asarray(cache.v.transpose(0, 2, 1, 3)), cache.k_scale,
                 cache.v_scale, kind="onepass", causal=True, window=0,
                 block_q=32)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_ring_buffer_eviction_and_tracking():
    """Slot layout, pos/valid_len/q_offset across prefill + wrap-around.
    ``pos`` is per-sequence (B,) — scalar reads go through ``.item()``."""
    b, g, hd, cap = 1, 2, 4, 16
    toks = _i8(b, 24, g, hd)

    cache = KV.init_cache(b, cap, g, hd)
    cache = cache.prefill_write(jnp.asarray(toks[:, :12]),
                                jnp.asarray(toks[:, :12]))
    assert cache.pos.shape == (b,)
    assert int(cache.pos[0]) == 12
    assert int(cache.valid_len()[0]) == 12
    assert int(cache.q_offset(1)[0]) == 11
    np.testing.assert_array_equal(np.asarray(cache.k[:, :12]),
                                  toks[:, :12])

    for t in range(12, 24):
        cache = cache.decode_append(jnp.asarray(toks[:, t:t + 1]),
                                    jnp.asarray(toks[:, t:t + 1]))
    assert int(cache.pos[0]) == 24
    assert int(cache.valid_len()[0]) == cap
    assert int(cache.q_offset(1)[0]) == cap - 1
    # token t lives in slot t % cap; tokens 8..23 survive
    for t in range(8, 24):
        np.testing.assert_array_equal(np.asarray(cache.k[:, t % cap]),
                                      toks[:, t])

    # long prefill (> capacity) keeps only the tail, same slot rule
    cache2 = KV.init_cache(b, cap, g, hd).prefill_write(
        jnp.asarray(toks), jnp.asarray(toks))
    assert int(cache2.pos[0]) == 24
    for t in range(8, 24):
        np.testing.assert_array_equal(np.asarray(cache2.k[:, t % cap]),
                                      toks[:, t])


def test_ragged_ring_buffer_tracking():
    """Per-sequence pos: a ragged prefill starts each row at its own
    length; appends advance and wrap each row independently."""
    b, g, hd, cap = 3, 2, 4, 16
    toks = _i8(b, 12, g, hd)
    lengths = jnp.asarray([5, 12, 9], jnp.int32)
    cache = KV.init_cache(b, cap, g, hd).prefill_write(
        jnp.asarray(toks), jnp.asarray(toks), lengths=lengths)
    np.testing.assert_array_equal(np.asarray(cache.pos), [5, 12, 9])
    np.testing.assert_array_equal(np.asarray(cache.valid_len()), [5, 12, 9])
    np.testing.assert_array_equal(np.asarray(cache.q_offset(1)), [4, 11, 8])

    # 8 appends: row 0 reaches 13, row 1 wraps past cap=16 to 20, row 2: 17
    steps = _i8(b, 8, g, hd)
    for t in range(8):
        cache = cache.decode_append(jnp.asarray(steps[:, t:t + 1]),
                                    jnp.asarray(steps[:, t:t + 1]))
    np.testing.assert_array_equal(np.asarray(cache.pos), [13, 20, 17])
    np.testing.assert_array_equal(np.asarray(cache.valid_len()),
                                  [13, 16, 16])
    # each row's appended token t landed in its own slot (len_b + t) % cap
    for row, ln in enumerate([5, 12, 9]):
        for t in range(8):
            np.testing.assert_array_equal(
                np.asarray(cache.k[row, (ln + t) % cap]), steps[row, t],
                err_msg=f"row {row} token {t}")

    # ragged prefill longer than capacity is a per-row roll we refuse
    with np.testing.assert_raises(ValueError):
        KV.init_cache(b, 8, g, hd).prefill_write(
            jnp.asarray(toks), jnp.asarray(toks), lengths=lengths)


def test_multi_token_append_wraps_ring_boundary():
    """A burst append straddling the ring boundary must wrap to slot 0,
    not clamp (dynamic_update_slice clamps; the append is per-token)."""
    b, g, hd, cap = 1, 2, 4, 16
    toks = _i8(b, 19, g, hd)
    cache = KV.init_cache(b, cap, g, hd).prefill_write(
        jnp.asarray(toks[:, :15]), jnp.asarray(toks[:, :15]))
    # 4-token burst from pos=15: slots 15, 0, 1, 2
    cache = cache.decode_append(jnp.asarray(toks[:, 15:19]),
                                jnp.asarray(toks[:, 15:19]))
    assert int(cache.pos[0]) == 19
    for t in range(3, 19):          # tokens 3..18 survive
        np.testing.assert_array_equal(np.asarray(cache.k[:, t % cap]),
                                      toks[:, t], err_msg=f"token {t}")


def test_burst_append_longer_than_capacity_is_deterministic():
    """A burst longer than the ring writes only its last C tokens —
    scattering all of them would hit duplicate slots (unspecified winner
    in JAX scatter semantics)."""
    b, g, hd, cap = 1, 2, 4, 4
    toks = _i8(b, 9, g, hd)
    cache = KV.init_cache(b, cap, g, hd).prefill_write(
        jnp.asarray(toks[:, :3]), jnp.asarray(toks[:, :3]))
    cache = cache.decode_append(jnp.asarray(toks[:, 3:]),
                                jnp.asarray(toks[:, 3:]))   # 6-token burst
    assert int(cache.pos[0]) == 9
    for t in range(5, 9):           # survivors: tokens 5..8 at slot t % 4
        np.testing.assert_array_equal(np.asarray(cache.k[:, t % cap]),
                                      toks[:, t], err_msg=f"token {t}")


def test_kv_cache_state_is_pytree():
    """KVCacheState flows through tree ops / eval_shape / jit like the
    dicts it replaced (scan/shard/donate-compatible)."""
    cache = KV.init_cache(2, 8, 2, 4, per_head_scales=True)
    leaves = jax.tree.leaves(cache)
    assert len(leaves) == 5            # k, v, pos, k_scale, v_scale
    stacked = jax.tree.map(lambda a: jnp.zeros((3,) + a.shape, a.dtype),
                           cache)
    assert isinstance(stacked, KV.KVCacheState)
    assert stacked.k.shape == (3, 2, 8, 2, 4)
    shp = jax.eval_shape(lambda: KV.init_cache(2, 8, 2, 4))
    assert isinstance(shp, KV.KVCacheState) and shp.k_scale is None

    @jax.jit
    def step(c, t):
        return c.decode_append(t, t)

    tok = jnp.ones((2, 1, 2, 4), jnp.int8)
    out = step(cache, tok)
    assert isinstance(out, KV.KVCacheState)
    np.testing.assert_array_equal(np.asarray(out.pos), [1, 1])


def test_per_head_quantization_roundtrip():
    x = rng.normal(0, 1, (2, 8, 4, 16)).astype(np.float32) \
        * np.array([0.1, 1.0, 3.0, 10.0], np.float32)[None, None, :, None]
    q, scale = KV.quantize_per_head(jnp.asarray(x))
    assert q.dtype == jnp.int8 and scale.shape == (4,)
    err = np.abs(np.asarray(q) * np.asarray(scale)[None, None, :, None] - x)
    assert float(err.max()) <= float(np.asarray(scale).max()) / 2 + 1e-6


def test_generate_loop_smoke():
    """End-to-end generate(): quantized prefill + incremental decode."""
    from repro.configs.base import ModelConfig
    from repro.models import init_model
    from repro.runtime.generate import generate

    cfg = ModelConfig(name="gen-smoke", family="dense", d_model=64,
                      n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=128, layer_groups=((("attn",), 2),),
                      dtype="float32", attention_impl="ita")
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg)
    prompts = jax.random.randint(key, (2, 12), 0, cfg.vocab_size)

    res = generate(params, cfg, prompts, gen=6, max_len=18)
    assert res.tokens.shape == (2, 6)
    assert res.tokens.dtype == jnp.int32
    assert bool(jnp.all((res.tokens >= 0) & (res.tokens < cfg.vocab_size)))
    assert res.decode_steps == 5 and res.decode_tok_s > 0

    # sampling path: same prompts, nonzero temperature, still valid ids;
    # same max_len so the cached jitted steps are reused (no recompile)
    res_t = generate(params, cfg, prompts, gen=4, temperature=1.0, key=key,
                     max_len=18)
    assert res_t.tokens.shape == (2, 4)
    assert bool(jnp.all((res_t.tokens >= 0) & (res_t.tokens < cfg.vocab_size)))
