"""Continuous-batching serve loop: correctness vs solo generation,
scheduler safety properties, and the paged generate() path.

Acceptance properties (ISSUE 4 + ISSUE 5):
- every request served through the continuous loop gets **bit-identical**
  tokens to generating it alone, under BOTH admission modes — the
  chunked-prefill default (prompts prefilled in chunks inside the fused
  segments, page-native) and the stop-the-world ``admission="stall"``
  reference (slot reuse, page realloc, chunking and admission order
  change nothing about a sequence's arithmetic);
- the decode-maximal mixed scheduler never exceeds its per-step token
  budget and never starves a prefilling slot (seeded property test on
  the segment's ``grants`` output);
- sampled serving draws each request's tokens from its own
  ``fold_in(key, request_index)`` stream: outputs are independent of
  arrival order and bit-identical to solo generation with the folded key;
- the admission scheduler never double-books a physical page or a slot
  (seeded property test over random traces via the audit hook);
- ``generate(paged=True)`` is bit-identical to the ring layout;
- reused ``caches=`` of the wrong paged geometry fail validation with
  the mismatched field named.

Bit-parity across chunked ≡ stall ≡ solo requires the three paths to
stream the same KV tile schedule: ``page_size`` equal to the fused
prefill ``block_kv`` (128) and the solo/stall prefill pinned to the
fused one-pass kernel (``attention_backend``) rather than the streaming
XLA family.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.attention import PagedKVState
from repro.configs.base import ModelConfig
from repro.models import init_caches, init_model
from repro.runtime.generate import (ServeRequest, generate, serve_continuous)

KEY = jax.random.PRNGKey(0)

CFG = ModelConfig(name="serveloop-smoke", family="dense", d_model=64,
                  n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=128, layer_groups=((("attn",), 2),),
                  dtype="float32", attention_impl="ita",
                  attention_backend="ita_onepass_pallas")
MAX_LEN = 128                   # one 128-page per slot: ring bkv == page

# sliding-window variant: two pages per slot, prompts can straddle the
# page boundary mid-chunk. The window (144) sits between the longest
# prompt (140) and the longest stream (140 + 24 gen), so the window mask
# actually *binds* during decode — swa serving requires window >= the
# prompt (the window caps the cache), so it can never bind mid-prefill.
CFG_SWA = dataclasses.replace(
    CFG, name="serveloop-swa", layer_groups=((("swa",), 1),), window=144)


def _params(cfg=CFG):
    return init_model(KEY, cfg)


def _trace(n, prng, max_prompt=12, max_gen=9, spread=3):
    reqs = []
    step = 0
    for _ in range(n):
        plen = int(prng.integers(3, max_prompt + 1))
        reqs.append(ServeRequest(
            prompt=prng.integers(0, CFG.vocab_size, plen).astype(np.int32),
            gen=int(prng.integers(1, max_gen + 1)), arrival=step))
        step += int(prng.integers(0, spread + 1))
    return reqs


# ---------------------------------------------------------------------------
# Correctness: continuous serving == solo generation, token for token
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("admission", ["chunked", "stall"])
def test_serve_continuous_matches_solo_generate(admission):
    params = _params()
    prng = np.random.default_rng(3)
    reqs = _trace(7, prng)
    res = serve_continuous(params, CFG, reqs, slots=3, segment=4,
                           max_len=MAX_LEN, page_size=128,
                           admission=admission, chunk_size=5)
    assert len(res.completed) == len(reqs)
    assert res.steps > 0 and res.total_tokens == sum(r.gen for r in reqs)
    if admission == "chunked":
        assert res.prefill_stall_s == 0.0   # no stop-the-world dispatch
    else:
        assert res.prefill_stall_s > 0.0
    for c in res.completed:
        r = reqs[c.index]
        assert c.first_token_s >= c.arrived_s
        solo = generate(params, CFG, jnp.asarray(r.prompt)[None], r.gen,
                        max_len=MAX_LEN)
        np.testing.assert_array_equal(
            np.asarray(c.tokens), np.asarray(solo.tokens)[0],
            err_msg=f"request {c.index} (gen={r.gen}, {admission}) "
                    f"diverged from solo generation")


@pytest.mark.parametrize("cfg,prompt_lens,gen,chunk", [
    (CFG, (9, 60, 33), 4, 16),         # causal GQA, chunk < page
    (CFG_SWA, (140, 130, 70), 24, 48),  # window binds in decode; chunks
                                        # straddle the 128-token page
                                        # boundary
])
def test_chunked_equals_stall_equals_solo_across_specs(cfg, prompt_lens,
                                                       gen, chunk):
    """The ISSUE-5 parity sweep: chunked ≡ stall ≡ solo `generate()` for
    causal / sliding-window / GQA paged specs, including prompt chunks
    that straddle page boundaries and window masks that cut keys."""
    params = _params(cfg)
    prng = np.random.default_rng(11)
    max_len = 256
    reqs = [ServeRequest(
        prompt=prng.integers(0, cfg.vocab_size, n).astype(np.int32),
        gen=gen, arrival=2 * i) for i, n in enumerate(prompt_lens)]
    outs = {}
    for admission in ("chunked", "stall"):
        res = serve_continuous(params, cfg, reqs, slots=2, segment=5,
                               max_len=max_len, page_size=128,
                               admission=admission, chunk_size=chunk)
        assert len(res.completed) == len(reqs)
        outs[admission] = {c.index: np.asarray(c.tokens)
                           for c in res.completed}
    for i, r in enumerate(reqs):
        solo = generate(params, cfg, jnp.asarray(r.prompt)[None], r.gen,
                        max_len=max_len)
        want = np.asarray(solo.tokens)[0]
        np.testing.assert_array_equal(outs["chunked"][i], want,
                                      err_msg=f"chunked req {i}")
        np.testing.assert_array_equal(outs["stall"][i], want,
                                      err_msg=f"stall req {i}")


def test_serve_continuous_eos_cuts_sequences():
    """EOS mid-budget frees the slot early and the request's tokens stop
    at (and include) the EOS — matching solo generate with the same
    eos_id."""
    params = _params()
    prng = np.random.default_rng(4)
    reqs = _trace(4, prng, max_gen=8)
    base = serve_continuous(params, CFG, reqs, slots=2, segment=4,
                            max_len=MAX_LEN, page_size=128)
    # pick an eos that actually occurs mid-stream somewhere
    all_toks = np.concatenate([np.asarray(c.tokens) for c in base.completed])
    eos = int(all_toks[len(all_toks) // 2])
    res = serve_continuous(params, CFG, reqs, slots=2, segment=4,
                           max_len=MAX_LEN, page_size=128, eos_id=eos)
    for c in res.completed:
        r = reqs[c.index]
        toks = np.asarray(c.tokens)
        solo = np.asarray(generate(params, CFG, jnp.asarray(r.prompt)[None],
                                   r.gen, max_len=MAX_LEN).tokens)[0]
        hits = np.flatnonzero(solo == eos)
        want = solo[:hits[0] + 1] if hits.size else solo
        np.testing.assert_array_equal(toks, want,
                                      err_msg=f"request {c.index}")


# ---------------------------------------------------------------------------
# Sampled serving: per-request PRNG streams (fold_in by request id)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("admission", ["chunked", "stall"])
def test_sampled_serving_independent_of_arrival_order(admission):
    """Same seed, two arrival orders -> identical per-request tokens, and
    each request's draws equal solo generation with the fold_in key."""
    params = _params()
    prng = np.random.default_rng(5)
    prompts = [prng.integers(0, CFG.vocab_size,
                             int(prng.integers(3, 12))).astype(np.int32)
               for _ in range(5)]
    gens = [int(prng.integers(2, 7)) for _ in range(5)]
    key = jax.random.PRNGKey(42)

    def run(arrivals):
        reqs = [ServeRequest(prompt=prompts[i], gen=gens[i],
                             arrival=arrivals[i]) for i in range(5)]
        res = serve_continuous(params, CFG, reqs, slots=2, segment=4,
                               max_len=MAX_LEN, page_size=128,
                               admission=admission, chunk_size=6,
                               temperature=0.8, key=key)
        return {c.index: np.asarray(c.tokens) for c in res.completed}

    a = run([0, 0, 1, 5, 9])
    b = run([9, 4, 0, 0, 2])
    for i in range(5):
        np.testing.assert_array_equal(
            a[i], b[i], err_msg=f"request {i} draws depended on arrival "
                                f"order ({admission})")
        solo = generate(params, CFG, jnp.asarray(prompts[i])[None], gens[i],
                        max_len=MAX_LEN, temperature=0.8,
                        key=jax.random.fold_in(key, i))
        np.testing.assert_array_equal(
            a[i], np.asarray(solo.tokens)[0],
            err_msg=f"request {i} diverged from solo fold_in generation")


# ---------------------------------------------------------------------------
# Decode-maximal scheduler: budget + no-starvation (seeded property)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_mixed_scheduler_budget_and_progress(seed):
    """The mixed segment's per-step grants: (1) never exceed the token
    budget, (2) give every decoding live slot exactly one token, (3)
    always advance at least one prefilling slot while any is live, and
    (4) never push a cursor past its prompt length."""
    from repro.runtime.generate import _admit_chunked, _serve_segment_fn
    from repro.launch.steps import ServeSlotState, fold_keys

    prng = np.random.default_rng(seed)
    slots, chunk, segment = 4, 5, 6
    budget = slots - 1 + chunk
    params = _params()
    prompt_pad = 24
    plens = prng.integers(1, prompt_pad + 1, slots).astype(np.int32)
    gens = prng.integers(1, 6, slots).astype(np.int32)
    prompts = prng.integers(0, CFG.vocab_size,
                            (slots, prompt_pad)).astype(np.int32)

    caches = init_caches(CFG, slots, max_len=MAX_LEN, paged=True,
                         page_size=128)
    state = ServeSlotState.init(slots, prompt_pad, KEY)
    state = _admit_chunked(
        state, jnp.arange(slots, dtype=jnp.int32), jnp.asarray(prompts),
        jnp.asarray(plens), jnp.asarray(gens),
        fold_keys(KEY, jnp.arange(slots)))
    seg = _serve_segment_fn(CFG, segment, False, None, 0, chunk, budget)

    cursor = np.zeros(slots, np.int64)
    for _ in range(6):                       # enough segments to drain
        done_before = np.asarray(state.done).copy()
        toks, emits, grants, state, caches, n = seg(params, state, caches,
                                                    jnp.asarray(1.0))
        grants = np.asarray(grants)          # (slots, segment)
        emits = np.asarray(emits)
        for t in range(segment):
            g = grants[:, t]
            assert g.sum() <= budget, (t, g, budget)
            live_pre = cursor < plens
            if live_pre.any() and not done_before.all():
                # decode-maximal leaves >= 1 token of budget for the head
                # prefilling slot every step
                assert g[live_pre].sum() >= 1, (t, g, cursor, plens)
            decoding = (cursor >= plens) & ~done_before
            assert np.all(g[decoding] <= 1)
            cursor = np.minimum(cursor + np.where(cursor < plens, g, 0),
                                plens.astype(np.int64))
            # done slots emitted this step finish; track via emits only
            # for the live check above (coarse: done_before per segment)
        assert np.all(cursor <= plens)
        if np.asarray(state.done).all():
            break
    assert np.asarray(state.done).all(), "segments did not drain the batch"
    np.testing.assert_array_equal(np.asarray(state.cursor), plens,
                                  err_msg="a prefilling slot starved")


# ---------------------------------------------------------------------------
# Scheduler safety: no page / slot double-booking (seeded property)
# ---------------------------------------------------------------------------

def _audit_partition(caches, slot_req, pins, shared=False):
    """Every layer's pool satisfies the allocator invariant
    (``check_invariants``): each page on the free stack XOR referenced,
    each refcount equal to its page-table references plus index
    ``pins``, parking page never held or free-listed. Without prefix
    sharing additionally no page backs two slots, and no request ever
    occupies two slots."""
    def check(node):
        if not isinstance(node, PagedKVState):
            return node
        for period in range(node.k.shape[0]):
            p = jax.tree.map(lambda a: a[period], node)
            p.check_invariants(pins=pins)
            if not shared:
                pt = np.asarray(p.page_table)
                held_counts = np.asarray(p.pages_held())
                held = []
                for row in range(p.batch):
                    held.extend(pt[row, :held_counts[row]].tolist())
                assert len(set(held)) == len(held), \
                    f"page double-booked across slots: {held}"
        return node

    jax.tree.map(check, caches,
                 is_leaf=lambda x: isinstance(x, PagedKVState))
    live = [i for i in slot_req if i is not None]
    assert len(set(live)) == len(live), f"request in two slots: {slot_req}"


@pytest.mark.parametrize("seed,admission", [(0, "chunked"), (1, "chunked"),
                                            (2, "stall")])
def test_scheduler_never_double_books_page_or_slot(seed, admission):
    params = _params()
    prng = np.random.default_rng(seed)
    reqs = _trace(8, prng, max_gen=7, spread=4)
    audits = []

    def audit(caches, slot_req, pins):
        audits.append(1)
        _audit_partition(caches, slot_req, pins)

    # page_size 32 -> up to 4 pages per sequence, pool undersized to
    # 3 slots' worth + 1 so admission actually gates on pages
    res = serve_continuous(params, CFG, reqs, slots=3, segment=4,
                           max_len=MAX_LEN, page_size=32,
                           num_pages=3 * 4 + 2, admission=admission,
                           chunk_size=8, audit=audit)
    assert audits, "audit hook never ran"
    assert len(res.completed) == len(reqs)


# ---------------------------------------------------------------------------
# Prefix sharing: shared system prompts over the paged pool (ISSUE 6)
# ---------------------------------------------------------------------------

def test_prefix_sharing_bit_exact_and_saves_prefill():
    """A common 128-token system prompt across the trace: serving with
    prefix sharing on is token-for-token identical to sharing off and to
    solo ``generate()``, while strictly reducing prefilled tokens —
    shared-prefix chunks skip prefill and adopt the donor's pages. The
    audit + debug invariant checks run with the live pin ledger."""
    params = _params()
    prng = np.random.default_rng(23)
    sys_toks = prng.integers(0, CFG.vocab_size, 128).astype(np.int32)
    reqs = []
    for i in range(5):
        tail = prng.integers(0, CFG.vocab_size,
                             int(prng.integers(4, 20))).astype(np.int32)
        reqs.append(ServeRequest(prompt=np.concatenate([sys_toks, tail]),
                                 gen=int(prng.integers(3, 8)),
                                 arrival=6 * i))
    audits = []

    def audit(caches, slot_req, pins):
        audits.append(1)
        _audit_partition(caches, slot_req, pins, shared=True)

    kw = dict(slots=3, segment=4, max_len=256, page_size=128,
              admission="chunked", chunk_size=48)
    off = serve_continuous(params, CFG, reqs, **kw)
    on = serve_continuous(params, CFG, reqs, prefix_sharing=True,
                          debug_invariants=True, audit=audit, **kw)
    assert audits, "audit hook never ran"
    assert off.prefix_hits == 0 and off.shared_prefix_tokens == 0
    assert on.prefix_hits >= 1 and on.shared_prefix_tokens >= 128
    assert on.prefill_tokens < off.prefill_tokens, \
        (on.prefill_tokens, off.prefill_tokens)
    got_on = {c.index: np.asarray(c.tokens) for c in on.completed}
    got_off = {c.index: np.asarray(c.tokens) for c in off.completed}
    assert len(got_on) == len(got_off) == len(reqs)
    for i, r in enumerate(reqs):
        solo = generate(params, CFG, jnp.asarray(r.prompt)[None], r.gen,
                        max_len=256)
        want = np.asarray(solo.tokens)[0]
        np.testing.assert_array_equal(got_off[i], want,
                                      err_msg=f"sharing-off req {i}")
        np.testing.assert_array_equal(got_on[i], want,
                                      err_msg=f"sharing-on req {i}")


def test_prefix_sharing_eviction_under_page_pressure():
    """An undersized pool (room for two pinned prefix families) forces
    the index to evict LRU pins when a third family arrives: every
    request still completes bit-exactly against solo generation and
    same-family followers still hit the index."""
    params = _params()
    prng = np.random.default_rng(29)
    fams = [prng.integers(0, CFG.vocab_size, 128).astype(np.int32)
            for _ in range(3)]
    reqs = []
    t = 0
    for fam in fams:
        for _ in range(2):
            tail = prng.integers(0, CFG.vocab_size,
                                 int(prng.integers(3, 10))).astype(np.int32)
            reqs.append(ServeRequest(prompt=np.concatenate([fam, tail]),
                                     gen=3, arrival=t))
            t += 8
    res = serve_continuous(params, CFG, reqs, slots=1, segment=4,
                           max_len=256, page_size=128, num_pages=4,
                           admission="chunked", chunk_size=48,
                           prefix_sharing=True, debug_invariants=True)
    assert len(res.completed) == len(reqs)
    assert res.prefix_hits >= 3          # each family's second request
    for c in res.completed:
        r = reqs[c.index]
        solo = generate(params, CFG, jnp.asarray(r.prompt)[None], r.gen,
                        max_len=256)
        np.testing.assert_array_equal(np.asarray(c.tokens),
                                      np.asarray(solo.tokens)[0],
                                      err_msg=f"req {c.index}")


def test_prefix_sharing_rejects_incompatible_modes():
    """Sharing requires chunked admission (stall's scratch-ring adopt
    bypasses the index) and uniform paged geometry across layer groups
    (the page-id-per-layer lockstep argument breaks when a window caps
    one group's pool)."""
    params = _params()
    reqs = [ServeRequest(prompt=np.zeros(4, np.int32), gen=2)]
    with pytest.raises(ValueError, match="prefix_sharing"):
        serve_continuous(params, CFG, reqs, slots=2, segment=4,
                         max_len=MAX_LEN, admission="stall",
                         prefix_sharing=True)
    mixed = dataclasses.replace(
        CFG, layer_groups=((("attn",), 1), (("swa",), 1)), window=128)
    params_mixed = _params(mixed)
    # max_len 256 = 2 pages for the full-attention group but the swa
    # pool is capped at window 128 = 1 page: geometries diverge
    with pytest.raises(ValueError, match="uniform"):
        serve_continuous(params_mixed, mixed, reqs, slots=2, segment=4,
                         max_len=256, prefix_sharing=True)


def test_serve_small_pages_wide_scratch():
    """Stall admission with page_size < the ring block: the admission
    scratch ring is block-aligned wider than the prompt pad, and adopt
    must bound the *lengths* against the window, not the padded scratch
    width — long prompts spanning several small pages still serve
    bit-exactly (vs solo paged generation on the same page size)."""
    params = _params()
    prng = np.random.default_rng(9)
    reqs = [ServeRequest(prompt=prng.integers(0, CFG.vocab_size,
                                              130 + 8 * i).astype(np.int32),
                         gen=3, arrival=0) for i in range(3)]
    res = serve_continuous(params, CFG, reqs, slots=2, segment=4,
                           max_len=192, page_size=64, admission="stall")
    assert len(res.completed) == len(reqs)
    for c in res.completed:
        r = reqs[c.index]
        solo = generate(params, CFG, jnp.asarray(r.prompt)[None], r.gen,
                        max_len=192, paged=True, page_size=64)
        np.testing.assert_array_equal(np.asarray(c.tokens),
                                      np.asarray(solo.tokens)[0],
                                      err_msg=f"request {c.index}")


def test_generate_refuses_undersized_paged_pool():
    """Lockstep generate() has no admission scheduler: a pool that could
    overdraw mid-scan (silent page double-booking) is refused up front."""
    params = _params()
    prompts = jax.random.randint(KEY, (2, 12), 0, CFG.vocab_size)
    with pytest.raises(ValueError, match="num_pages"):
        generate(params, CFG, prompts, 16, max_len=64, paged=True,
                 page_size=16, num_pages=4)
    # adequately provisioned passes (2 seqs x 1 page of 16 for 12+4 tokens)
    res = generate(params, CFG, prompts, 4, max_len=32, paged=True,
                   page_size=16, num_pages=5)
    assert res.tokens.shape == (2, 4)


def test_serve_rejects_unservable_requests_and_configs():
    params = _params()
    big = [ServeRequest(prompt=np.zeros(8, np.int32), gen=500, arrival=0)]
    with pytest.raises(ValueError, match="pages"):
        # pool of 1 allocatable page < the 2 pages one window needs
        serve_continuous(params, CFG, big, slots=2, segment=4,
                         max_len=64, page_size=32, num_pages=2)
    with pytest.raises(ValueError, match="prompt length"):
        serve_continuous(params, CFG,
                         [ServeRequest(prompt=np.zeros(80, np.int32),
                                       gen=2)],
                         slots=2, segment=4, max_len=64, page_size=32)
    with pytest.raises(ValueError, match="token_budget"):
        serve_continuous(params, CFG,
                         [ServeRequest(prompt=np.zeros(4, np.int32), gen=2)],
                         slots=4, segment=4, max_len=MAX_LEN,
                         token_budget=2)
    with pytest.raises(ValueError, match="admission"):
        serve_continuous(params, CFG,
                         [ServeRequest(prompt=np.zeros(4, np.int32), gen=2)],
                         slots=2, segment=4, max_len=MAX_LEN,
                         admission="bogus")
    softcap_cfg = dataclasses.replace(CFG, attn_softcap=30.0,
                                      attention_backend="")
    with pytest.raises(ValueError, match="paged decode"):
        serve_continuous(params, softcap_cfg,
                         [ServeRequest(prompt=np.zeros(4, np.int32), gen=2)],
                         slots=2, segment=4, max_len=MAX_LEN)
    rec_cfg = dataclasses.replace(CFG, layer_groups=((("rglru",), 1),))
    with pytest.raises(ValueError, match="attention"):
        serve_continuous(params, rec_cfg,
                         [ServeRequest(prompt=np.zeros(4, np.int32), gen=2)],
                         slots=2, segment=4, max_len=MAX_LEN)


# ---------------------------------------------------------------------------
# Overload survival: preemption, SLO classes, fault injection (ISSUE 8)
# ---------------------------------------------------------------------------

def _overload_trace(cfg, prng, n=6, plen_lo=110, plen_hi=141, gen_lo=10,
                    gen_hi=17):
    """Two-page requests against an undersized pool: four low-class
    requests arrive first and saturate the pool, two high-class requests
    arrive while they are mid-flight — admission must preempt."""
    reqs = []
    for i in range(n):
        plen = int(prng.integers(plen_lo, plen_hi))
        reqs.append(ServeRequest(
            prompt=prng.integers(0, cfg.vocab_size, plen).astype(np.int32),
            gen=int(prng.integers(gen_lo, gen_hi)), arrival=2 * i,
            priority=1 if i >= n - 2 else 0))
    return reqs


OVERLOAD_KW = dict(slots=3, segment=4, max_len=256, page_size=128,
                   num_pages=5, admission="chunked", chunk_size=48,
                   preemption=True, debug_invariants=True)


@pytest.mark.parametrize("cfg,plen_hi,gen_hi", [
    (CFG, 141, 17),       # causal GQA
    (CFG_SWA, 129, 16),   # sliding window: plen + gen <= window (144) so
                          # a resumed prompt (prompt + generated prefix)
                          # never exceeds what swa prefill can serve
])
def test_preemption_resume_bit_exact_vs_solo(cfg, plen_hi, gen_hi):
    """The ISSUE-8 parity sweep: page-pressure preemption evicts live
    low-class victims mid-stream; every request — including every
    preempted-and-resumed one — still gets tokens bit-identical to solo
    ``generate()``, with allocator invariants checked every round."""
    params = _params(cfg)
    prng = np.random.default_rng(17)
    reqs = _overload_trace(cfg, prng, plen_hi=plen_hi, gen_hi=gen_hi)
    res = serve_continuous(params, cfg, reqs, **OVERLOAD_KW)
    assert len(res.completed) == len(reqs)
    assert res.preemptions >= 1
    preempted = {c.index for c in res.completed if c.preemptions}
    assert preempted, "no request was actually evicted and resumed"
    # victims are strictly lower class than the candidate that evicted
    assert all(reqs[i].priority == 0 for i in preempted)
    for c in res.completed:
        r = reqs[c.index]
        solo = generate(params, cfg, jnp.asarray(r.prompt)[None], r.gen,
                        max_len=256)
        np.testing.assert_array_equal(
            np.asarray(c.tokens), np.asarray(solo.tokens)[0],
            err_msg=f"request {c.index} "
                    f"({'preempted' if c.index in preempted else 'clean'}) "
                    f"diverged from solo generation")
    # SLO steering: the high class is admitted ahead of the backlog
    cs = res.class_summary()
    assert set(cs) == {0, 1}
    assert cs[0]["preemptions"] == res.preemptions and \
        cs[1]["preemptions"] == 0
    assert cs[1]["p95_admit_delay_steps"] < cs[0]["p95_admit_delay_steps"]


def test_preemption_sampled_resume_bit_exact():
    """Sampled overload serving: a victim's PRNG stream is snapshotted at
    eviction and restored at re-admission, so its draws are bit-identical
    to solo generation with the fold_in key — as if never preempted."""
    params = _params()
    prng = np.random.default_rng(17)
    reqs = _overload_trace(CFG, prng)
    key = jax.random.PRNGKey(42)
    res = serve_continuous(params, CFG, reqs, temperature=0.8, key=key,
                           **OVERLOAD_KW)
    assert len(res.completed) == len(reqs)
    assert res.preemptions >= 1
    assert any(c.preemptions for c in res.completed)
    for c in res.completed:
        r = reqs[c.index]
        solo = generate(params, CFG, jnp.asarray(r.prompt)[None], r.gen,
                        max_len=256, temperature=0.8,
                        key=jax.random.fold_in(key, c.index))
        np.testing.assert_array_equal(
            np.asarray(c.tokens), np.asarray(solo.tokens)[0],
            err_msg=f"request {c.index} ({c.preemptions} preemptions) "
                    f"diverged from solo fold_in generation")


def test_preemption_with_prefix_sharing_decrefs_not_frees():
    """The preemption / prefix-sharing seam: victims whose prompt pages
    are registered (pinned) in the index release their rows, which must
    *decref* the pinned pages, not free them — the resumed admission then
    adopts them back. Invariants (refcount = table refs + pins) are
    host-checked after every round; outputs stay bit-exact."""
    params = _params()
    prng = np.random.default_rng(31)
    # one shared 128-token family for the low class: its first page gets
    # registered + pinned before the high-class arrivals force eviction
    fam = prng.integers(0, CFG.vocab_size, 128).astype(np.int32)
    reqs = []
    for i in range(6):
        if i < 4:
            tail = prng.integers(0, CFG.vocab_size,
                                 int(prng.integers(4, 13))).astype(np.int32)
            p, prio = np.concatenate([fam, tail]), 0
        else:
            p, prio = prng.integers(0, CFG.vocab_size,
                                    130 + i).astype(np.int32), 1
        reqs.append(ServeRequest(prompt=p, gen=int(prng.integers(10, 17)),
                                 arrival=2 * i, priority=prio))
    kw = dict(OVERLOAD_KW, num_pages=6, prefix_sharing=True)
    res = serve_continuous(params, CFG, reqs, **kw)
    assert len(res.completed) == len(reqs)
    assert res.preemptions >= 1
    assert res.prefix_hits >= 1
    for c in res.completed:
        r = reqs[c.index]
        solo = generate(params, CFG, jnp.asarray(r.prompt)[None], r.gen,
                        max_len=256)
        np.testing.assert_array_equal(
            np.asarray(c.tokens), np.asarray(solo.tokens)[0],
            err_msg=f"request {c.index}")


def test_fault_injection_kill_mid_prompt_and_straggler():
    """Seeded fault harness: a forced slot kill lands while the victim is
    still mid-prompt-chunk (zero tokens emitted — it resumes its original
    prompt from scratch), a phantom page-pressure spike delays one
    admission round, and an injected sleep is flagged by the segment
    watchdog. All recovery paths keep outputs bit-identical to solo."""
    from repro.runtime.fault_tolerance import ServeFaultPlan

    params = _params()
    prng = np.random.default_rng(7)
    # prompt long enough (200 tokens, chunk 16, segment 4 -> 64
    # prefill tokens per segment) that step-4's kill is mid-prompt
    reqs = [
        ServeRequest(prompt=prng.integers(0, CFG.vocab_size,
                                          200).astype(np.int32),
                     gen=32, arrival=0),
        ServeRequest(prompt=prng.integers(0, CFG.vocab_size,
                                          40).astype(np.int32),
                     gen=24, arrival=8),
    ]
    plan = ServeFaultPlan(seed=3, kill_steps=(4,), pressure_steps=(8,),
                          pressure_pages=4, straggle_steps=(40,),
                          straggle_s=0.25)
    res = serve_continuous(params, CFG, reqs, slots=2, segment=4,
                           max_len=256, page_size=128,
                           admission="chunked", chunk_size=16,
                           faults=plan, debug_invariants=True)
    assert len(res.completed) == len(reqs)
    assert res.preemptions >= 1
    killed = {c.index: c.preemptions for c in res.completed}
    assert killed[0] >= 1, "the step-4 kill must hit the mid-prompt slot"
    assert res.straggler_segments >= 1, \
        "the injected 250 ms sleep was not flagged by the watchdog"
    for c in res.completed:
        r = reqs[c.index]
        solo = generate(params, CFG, jnp.asarray(r.prompt)[None], r.gen,
                        max_len=256)
        np.testing.assert_array_equal(
            np.asarray(c.tokens), np.asarray(solo.tokens)[0],
            err_msg=f"request {c.index}")


def test_preemption_and_faults_require_chunked_admission():
    """Victims resume through chunked re-prefill of prompt + generated
    prefix; the stall path has no such seam."""
    from repro.runtime.fault_tolerance import ServeFaultPlan

    params = _params()
    reqs = [ServeRequest(prompt=np.zeros(4, np.int32), gen=2)]
    with pytest.raises(ValueError, match="chunked"):
        serve_continuous(params, CFG, reqs, slots=2, segment=4,
                         max_len=MAX_LEN, admission="stall",
                         preemption=True)
    with pytest.raises(ValueError, match="chunked"):
        serve_continuous(params, CFG, reqs, slots=2, segment=4,
                         max_len=MAX_LEN, admission="stall",
                         faults=ServeFaultPlan(kill_steps=(1,)))


# ---------------------------------------------------------------------------
# Paged generate(): ring parity + caches= validation
# ---------------------------------------------------------------------------

def test_paged_generate_bit_identical_to_ring():
    params = _params()
    prompts = jax.random.randint(KEY, (3, 12), 0, CFG.vocab_size)
    lens = jnp.asarray([5, 12, 9], jnp.int32)
    ring = generate(params, CFG, prompts, 8, max_len=MAX_LEN,
                    prompt_lengths=lens)
    paged = generate(params, CFG, prompts, 8, max_len=MAX_LEN,
                     prompt_lengths=lens, paged=True, page_size=128)
    np.testing.assert_array_equal(np.asarray(ring.tokens),
                                  np.asarray(paged.tokens))


def test_paged_caches_validation_names_fields():
    params = _params()
    prompts = jax.random.randint(KEY, (2, 12), 0, CFG.vocab_size)
    good = init_caches(CFG, 2, max_len=MAX_LEN, paged=True, page_size=64)
    res = generate(params, CFG, prompts, 4, max_len=MAX_LEN, caches=good)
    assert res.tokens.shape == (2, 4)
    # batch mismatch: named explicitly
    with pytest.raises(ValueError, match="batch"):
        generate(params, CFG, prompts, 4, max_len=MAX_LEN,
                 caches=init_caches(CFG, 3, max_len=MAX_LEN, paged=True,
                                    page_size=64))
    # wrong max_len -> page-table width mismatch, leaf named in the error
    with pytest.raises(ValueError, match="page_table"):
        generate(params, CFG, prompts, 4, max_len=MAX_LEN,
                 caches=init_caches(CFG, 2, max_len=MAX_LEN + 64,
                                    paged=True, page_size=64))
    # pool size / page size ride the provided caches (oversubscription is
    # a caller choice): a custom pool passes as long as geometry is
    # self-consistent
    small_pool = init_caches(CFG, 2, max_len=MAX_LEN, paged=True,
                             page_size=64, num_pages=5)
    res = generate(params, CFG, prompts, 4, max_len=MAX_LEN,
                   caches=small_pool)
    assert res.tokens.shape == (2, 4)
