"""Continuous-batching serve loop: correctness vs solo generation,
scheduler safety properties, and the paged generate() path.

Acceptance properties (ISSUE 4):
- every request served through the continuous loop gets **bit-identical**
  tokens to generating it alone (slot reuse, page realloc and admission
  order change nothing about a sequence's arithmetic);
- the admission scheduler never double-books a physical page or a slot
  (seeded property test over random traces via the audit hook);
- ``generate(paged=True)`` is bit-identical to the ring layout;
- reused ``caches=`` of the wrong paged geometry fail validation with
  the mismatched field named.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.attention import PagedKVState
from repro.configs.base import ModelConfig
from repro.models import init_caches, init_model
from repro.runtime.generate import (ServeRequest, generate, serve_continuous)

KEY = jax.random.PRNGKey(0)

CFG = ModelConfig(name="serveloop-smoke", family="dense", d_model=64,
                  n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=128, layer_groups=((("attn",), 2),),
                  dtype="float32", attention_impl="ita")
MAX_LEN = 128                   # one 128-page per slot: ring bkv == page


def _params():
    return init_model(KEY, CFG)


def _trace(n, prng, max_prompt=12, max_gen=9, spread=3):
    reqs = []
    step = 0
    for _ in range(n):
        plen = int(prng.integers(3, max_prompt + 1))
        reqs.append(ServeRequest(
            prompt=prng.integers(0, CFG.vocab_size, plen).astype(np.int32),
            gen=int(prng.integers(1, max_gen + 1)), arrival=step))
        step += int(prng.integers(0, spread + 1))
    return reqs


# ---------------------------------------------------------------------------
# Correctness: continuous serving == solo generation, token for token
# ---------------------------------------------------------------------------

def test_serve_continuous_matches_solo_generate():
    params = _params()
    prng = np.random.default_rng(3)
    reqs = _trace(7, prng)
    res = serve_continuous(params, CFG, reqs, slots=3, segment=4,
                           max_len=MAX_LEN, page_size=128)
    assert len(res.completed) == len(reqs)
    assert res.steps > 0 and res.total_tokens == sum(r.gen for r in reqs)
    for c in res.completed:
        r = reqs[c.index]
        solo = generate(params, CFG, jnp.asarray(r.prompt)[None], r.gen,
                        max_len=MAX_LEN)
        np.testing.assert_array_equal(
            np.asarray(c.tokens), np.asarray(solo.tokens)[0],
            err_msg=f"request {c.index} (gen={r.gen}) diverged from solo "
                    f"generation")


def test_serve_continuous_eos_cuts_sequences():
    """EOS mid-budget frees the slot early and the request's tokens stop
    at (and include) the EOS — matching solo generate with the same
    eos_id."""
    params = _params()
    prng = np.random.default_rng(4)
    reqs = _trace(4, prng, max_gen=8)
    base = serve_continuous(params, CFG, reqs, slots=2, segment=4,
                            max_len=MAX_LEN, page_size=128)
    # pick an eos that actually occurs mid-stream somewhere
    all_toks = np.concatenate([np.asarray(c.tokens) for c in base.completed])
    eos = int(all_toks[len(all_toks) // 2])
    res = serve_continuous(params, CFG, reqs, slots=2, segment=4,
                           max_len=MAX_LEN, page_size=128, eos_id=eos)
    for c in res.completed:
        r = reqs[c.index]
        toks = np.asarray(c.tokens)
        solo = np.asarray(generate(params, CFG, jnp.asarray(r.prompt)[None],
                                   r.gen, max_len=MAX_LEN).tokens)[0]
        hits = np.flatnonzero(solo == eos)
        want = solo[:hits[0] + 1] if hits.size else solo
        np.testing.assert_array_equal(toks, want,
                                      err_msg=f"request {c.index}")


# ---------------------------------------------------------------------------
# Scheduler safety: no page / slot double-booking (seeded property)
# ---------------------------------------------------------------------------

def _audit_partition(caches, slot_req):
    """Every layer's pool: active slots' held pages are disjoint, never
    the parking page, and disjoint from the free stack."""
    def check(node):
        if not isinstance(node, PagedKVState):
            return node
        for period in range(node.k.shape[0]):
            p = jax.tree.map(lambda a: a[period], node)
            pt = np.asarray(p.page_table)
            held_counts = np.asarray(p.pages_held())
            held = []
            for row in range(p.batch):
                held.extend(pt[row, :held_counts[row]].tolist())
            free = set(np.asarray(p.free_stack)[:int(p.free_top)].tolist())
            assert len(set(held)) == len(held), \
                f"page double-booked across slots: {held}"
            assert 0 not in held, "parking page allocated to a sequence"
            assert not (set(held) & free), "held page also on free stack"
            assert int(p.free_top) >= 0, "pool overdrawn"
        return node

    jax.tree.map(check, caches,
                 is_leaf=lambda x: isinstance(x, PagedKVState))
    live = [i for i in slot_req if i is not None]
    assert len(set(live)) == len(live), f"request in two slots: {slot_req}"


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_scheduler_never_double_books_page_or_slot(seed):
    params = _params()
    prng = np.random.default_rng(seed)
    reqs = _trace(8, prng, max_gen=7, spread=4)
    audits = []

    def audit(caches, slot_req):
        audits.append(1)
        _audit_partition(caches, slot_req)

    # page_size 32 -> up to 4 pages per sequence, pool undersized to
    # 3 slots' worth + 1 so admission actually gates on pages
    res = serve_continuous(params, CFG, reqs, slots=3, segment=4,
                           max_len=MAX_LEN, page_size=32,
                           num_pages=3 * 4 + 2, audit=audit)
    assert audits, "audit hook never ran"
    assert len(res.completed) == len(reqs)


def test_serve_small_pages_wide_scratch():
    """page_size < the ring block: the admission scratch ring is
    block-aligned wider than the prompt pad, and adopt must bound the
    *lengths* against the window, not the padded scratch width — long
    prompts spanning several small pages still serve bit-exactly."""
    params = _params()
    prng = np.random.default_rng(9)
    reqs = [ServeRequest(prompt=prng.integers(0, CFG.vocab_size,
                                              130 + 8 * i).astype(np.int32),
                         gen=3, arrival=0) for i in range(3)]
    res = serve_continuous(params, CFG, reqs, slots=2, segment=4,
                           max_len=192, page_size=64)
    assert len(res.completed) == len(reqs)
    for c in res.completed:
        r = reqs[c.index]
        solo = generate(params, CFG, jnp.asarray(r.prompt)[None], r.gen,
                        max_len=192, paged=True, page_size=64)
        np.testing.assert_array_equal(np.asarray(c.tokens),
                                      np.asarray(solo.tokens)[0],
                                      err_msg=f"request {c.index}")


def test_generate_refuses_undersized_paged_pool():
    """Lockstep generate() has no admission scheduler: a pool that could
    overdraw mid-scan (silent page double-booking) is refused up front."""
    params = _params()
    prompts = jax.random.randint(KEY, (2, 12), 0, CFG.vocab_size)
    with pytest.raises(ValueError, match="num_pages"):
        generate(params, CFG, prompts, 16, max_len=64, paged=True,
                 page_size=16, num_pages=4)
    # adequately provisioned passes (2 seqs x 1 page of 16 for 12+4 tokens)
    res = generate(params, CFG, prompts, 4, max_len=32, paged=True,
                   page_size=16, num_pages=5)
    assert res.tokens.shape == (2, 4)


def test_serve_rejects_unservable_requests_and_configs():
    params = _params()
    big = [ServeRequest(prompt=np.zeros(8, np.int32), gen=500, arrival=0)]
    with pytest.raises(ValueError, match="pages"):
        # pool of 1 allocatable page < the 2 pages one window needs
        serve_continuous(params, CFG, big, slots=2, segment=4,
                         max_len=64, page_size=32, num_pages=2)
    with pytest.raises(ValueError, match="prompt length"):
        serve_continuous(params, CFG,
                         [ServeRequest(prompt=np.zeros(80, np.int32),
                                       gen=2)],
                         slots=2, segment=4, max_len=64, page_size=32)
    softcap_cfg = dataclasses.replace(CFG, attn_softcap=30.0)
    with pytest.raises(ValueError, match="paged decode"):
        serve_continuous(params, softcap_cfg,
                         [ServeRequest(prompt=np.zeros(4, np.int32), gen=2)],
                         slots=2, segment=4, max_len=MAX_LEN)
    rec_cfg = dataclasses.replace(CFG, layer_groups=((("rglru",), 1),))
    with pytest.raises(ValueError, match="attention"):
        serve_continuous(params, rec_cfg,
                         [ServeRequest(prompt=np.zeros(4, np.int32), gen=2)],
                         slots=2, segment=4, max_len=MAX_LEN)


# ---------------------------------------------------------------------------
# Paged generate(): ring parity + caches= validation
# ---------------------------------------------------------------------------

def test_paged_generate_bit_identical_to_ring():
    params = _params()
    prompts = jax.random.randint(KEY, (3, 12), 0, CFG.vocab_size)
    lens = jnp.asarray([5, 12, 9], jnp.int32)
    ring = generate(params, CFG, prompts, 8, max_len=MAX_LEN,
                    prompt_lengths=lens)
    paged = generate(params, CFG, prompts, 8, max_len=MAX_LEN,
                     prompt_lengths=lens, paged=True, page_size=128)
    np.testing.assert_array_equal(np.asarray(ring.tokens),
                                  np.asarray(paged.tokens))


def test_paged_caches_validation_names_fields():
    params = _params()
    prompts = jax.random.randint(KEY, (2, 12), 0, CFG.vocab_size)
    good = init_caches(CFG, 2, max_len=MAX_LEN, paged=True, page_size=64)
    res = generate(params, CFG, prompts, 4, max_len=MAX_LEN, caches=good)
    assert res.tokens.shape == (2, 4)
    # batch mismatch: named explicitly
    with pytest.raises(ValueError, match="batch"):
        generate(params, CFG, prompts, 4, max_len=MAX_LEN,
                 caches=init_caches(CFG, 3, max_len=MAX_LEN, paged=True,
                                    page_size=64))
    # wrong max_len -> page-table width mismatch, leaf named in the error
    with pytest.raises(ValueError, match="page_table"):
        generate(params, CFG, prompts, 4, max_len=MAX_LEN,
                 caches=init_caches(CFG, 2, max_len=MAX_LEN + 64,
                                    paged=True, page_size=64))
    # pool size / page size ride the provided caches (oversubscription is
    # a caller choice): a custom pool passes as long as geometry is
    # self-consistent
    small_pool = init_caches(CFG, 2, max_len=MAX_LEN, paged=True,
                             page_size=64, num_pages=5)
    res = generate(params, CFG, prompts, 4, max_len=MAX_LEN,
                   caches=small_pool)
    assert res.tokens.shape == (2, 4)
