"""The unified attention engine: spec validation, capability registry,
and the cross-backend parity sweep.

Coverage contract (ISSUE 2 acceptance):
- every registered backend's ``supports()`` verdict is exercised in both
  directions (an eligible spec and a rejecting spec with a reason),
- ineligible (spec, backend) pairs raise ``BackendUnsupported`` carrying
  the backend's stated reason,
- the parity sweep across ``list_backends(spec)`` is bit-exact for
  causal, sliding-window, GQA and per-head-scale decode specs.
"""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro import attention as ATT
from repro.kernels.common import resolve_interpret

rng = np.random.default_rng(0)

S_Q, S_OUT = np.float32(0.05), np.float32(0.02)


def _i8(*shape):
    return rng.integers(-128, 128, shape, dtype=np.int8)


# ---------------------------------------------------------------------------
# Spec / scales validation
# ---------------------------------------------------------------------------

def test_spec_rejects_bad_enums_and_combinations():
    with pytest.raises(ValueError, match="mode"):
        ATT.AttentionSpec(mode="predict")
    with pytest.raises(ValueError, match="layout"):
        ATT.AttentionSpec(layout="bhds")
    with pytest.raises(ValueError, match="int8"):
        ATT.AttentionSpec(impl="float", out_dtype="int8")
    with pytest.raises(ValueError, match="GQA"):
        ATT.AttentionSpec(n_heads=6, n_kv_heads=4)
    with pytest.raises(ValueError, match="window"):
        ATT.AttentionSpec(window=-1)


def test_dispatch_validates_shapes_against_spec():
    q = jnp.asarray(_i8(1, 4, 8, 16))                 # bhsd
    k = v = jnp.asarray(_i8(1, 3, 8, 16))             # 3 kv heads !| 4
    spec = ATT.AttentionSpec(mode="prefill", impl="ita", layout="bhsd")
    sc = ATT.QuantScales.per_tensor(S_Q, s_out=S_OUT)
    with pytest.raises(ValueError, match="GQA"):
        ATT.dispatch(q, k, v, spec=spec, scales=sc)
    with pytest.raises(ValueError, match="n_heads"):
        ATT.dispatch(q, jnp.asarray(_i8(1, 2, 8, 16)),
                     jnp.asarray(_i8(1, 2, 8, 16)),
                     spec=spec.replace(n_heads=8), scales=sc)
    with pytest.raises(ValueError, match="QuantScales"):
        ATT.dispatch(q, jnp.asarray(_i8(1, 2, 8, 16)),
                     jnp.asarray(_i8(1, 2, 8, 16)), spec=spec)


def test_quantscales_pytree_and_require():
    sc = ATT.QuantScales.from_params(
        {"s_q": jnp.asarray(0.1), "s_k": jnp.asarray(0.2)})
    assert sc.s_v is None and sc.s_out is None
    import jax
    assert len(jax.tree.leaves(sc)) == 2     # None leaves drop out
    with pytest.raises(ValueError, match="s_out"):
        sc.require("s_q", "s_out")
    assert sc.require("s_q", "s_k") is sc


# ---------------------------------------------------------------------------
# Capability matrix: every backend says yes somewhere, no somewhere (with
# a reason)
# ---------------------------------------------------------------------------

# One eligible spec and one rejected spec per backend.
_ELIGIBLE = {
    "float_xla": dict(mode="prefill", impl="float"),
    "ita_chunked_xla": dict(mode="train", impl="ita", softcap=30.0),
    "ita_onepass_pallas": dict(mode="prefill", impl="ita", layout="bhsd",
                               out_dtype="int8"),
    "ita_twopass_pallas": dict(mode="prefill", impl="ita", layout="bhsd",
                               out_dtype="int8"),
    "ita_decode_pallas": dict(mode="decode", impl="ita", layout="bhsd_bsgd",
                              scale_kind="per_head", out_dtype="int8",
                              q_len=1),
    "ita_direct_xla": dict(mode="decode", impl="ita", softcap=30.0,
                           q_len=16),
    "ibert_xla": dict(mode="decode", impl="ibert", q_len=1),
}

_REJECTED = {
    "float_xla": dict(mode="prefill", impl="ita"),
    "ita_chunked_xla": dict(mode="decode", impl="ita", q_len=1),
    "ita_onepass_pallas": dict(mode="prefill", impl="ita", softcap=30.0),
    "ita_twopass_pallas": dict(mode="decode", impl="ita", q_len=1),
    "ita_decode_pallas": dict(mode="decode", impl="ita", q_len=64),
    "ita_direct_xla": dict(mode="prefill", impl="ita"),
    "ibert_xla": dict(mode="train", impl="ibert"),
}


def test_capability_tables_cover_every_registered_backend():
    names = set(ATT.list_backends())
    assert names == set(_ELIGIBLE) == set(_REJECTED)


@pytest.mark.parametrize("name", sorted(_ELIGIBLE))
def test_supports_verdicts_both_ways(name):
    b = ATT.get_backend(name)
    ok = b.supports(ATT.AttentionSpec(**_ELIGIBLE[name]))
    assert ok is True, f"{name} should accept {_ELIGIBLE[name]}: {ok}"
    no = b.supports(ATT.AttentionSpec(**_REJECTED[name]))
    assert isinstance(no, str) and no, \
        f"{name} should reject {_REJECTED[name]} with a reason"


@pytest.mark.parametrize("name", sorted(_REJECTED))
def test_ineligible_pair_raises_with_stated_reason(name):
    spec = ATT.AttentionSpec(**_REJECTED[name])
    reason = ATT.get_backend(name).supports(spec)
    q = jnp.asarray(_i8(1, 2, 8, 16))
    with pytest.raises(ATT.BackendUnsupported) as exc:
        ATT.dispatch(q, q, q, spec=spec,
                     scales=ATT.QuantScales.per_tensor(S_Q, s_out=S_OUT),
                     backend=name)
    assert name in str(exc.value) and reason in str(exc.value)


def test_dispatch_with_no_eligible_backend_lists_all_verdicts():
    # softcapped per-head decode in kernel layout: kernels refuse the
    # softcap, the XLA fallbacks refuse the layout/scales
    spec = ATT.AttentionSpec(mode="decode", impl="ita", layout="bhsd",
                             scale_kind="per_head", softcap=30.0, q_len=1)
    assert ATT.list_backends(spec) == []
    q = jnp.asarray(_i8(1, 2, 8, 16))
    with pytest.raises(ATT.BackendUnsupported, match="no registered"):
        ATT.dispatch(q, q, q, spec=spec,
                     scales=ATT.QuantScales.per_tensor(S_Q, s_out=S_OUT))


def test_priority_order_and_introspection():
    # model-layout ita prefill: streaming XLA wins, kernels stay eligible
    prefill = ATT.AttentionSpec(mode="prefill", impl="ita")
    assert ATT.list_backends(prefill)[0] == "ita_chunked_xla"
    assert "ita_onepass_pallas" in ATT.list_backends(prefill)
    # engine decode (cache-native layout + per-head scales): fused decode
    decode = ATT.AttentionSpec(mode="decode", impl="ita",
                               layout="bhsd_bsgd", scale_kind="per_head",
                               out_dtype="int8", q_len=1)
    eligible = ATT.list_backends(decode)
    assert eligible[0] == "ita_decode_pallas"
    assert {ATT.get_backend(n).family for n in eligible} == {"ita_fused"}
    # float: exactly the float baseline
    assert ATT.list_backends(
        ATT.AttentionSpec(mode="prefill", impl="float")) == ["float_xla"]
    reasons = ATT.backend_reasons(prefill)
    assert set(reasons) == set(ATT.list_backends())
    assert all(v is True or (isinstance(v, str) and v)
               for v in reasons.values())


def test_register_custom_backend_round_trip():
    calls = []

    def run(q, k, v, spec, scales, **kw):
        calls.append(spec)
        return q

    be = ATT.Backend(name="null_test_backend", family="test",
                     supports=lambda spec: spec.impl == "ita" or "ita only",
                     run=run, description="test stub")
    ATT.register_backend(be)
    try:
        spec = ATT.AttentionSpec(mode="prefill", impl="ita", layout="bhsd")
        assert "null_test_backend" in ATT.list_backends(spec)
        q = jnp.asarray(_i8(1, 2, 8, 16))
        out = ATT.dispatch(q, q, q, spec=spec,
                           scales=ATT.QuantScales.per_tensor(S_Q),
                           backend="null_test_backend")
        assert out is q and len(calls) == 1
    finally:
        from repro.attention import registry
        registry._REGISTRY.pop("null_test_backend", None)


# ---------------------------------------------------------------------------
# Parity sweep: every eligible backend for a decode spec is bit-exact
# ---------------------------------------------------------------------------

PARITY_SPECS = [
    # (hq, hkv, causal, window) — all with per-head scales, the engine's
    # native decode grid; together they cover causal, sliding-window, GQA
    # and per-head-scale decode specs.
    pytest.param(4, 4, True, 0, id="causal"),
    pytest.param(4, 4, True, 48, id="sliding-window"),
    pytest.param(4, 2, True, 0, id="gqa"),
    pytest.param(4, 2, True, 48, id="gqa+window+per-head"),
]


@pytest.mark.parametrize("hq,hkv,causal,window", PARITY_SPECS)
def test_parity_sweep_eligible_backends_bit_exact(hq, hkv, causal, window):
    b, d, skv = 2, 32, 128
    q = jnp.asarray(_i8(b, hq, 1, d))
    k = jnp.asarray(_i8(b, hkv, skv, d))
    v = jnp.asarray(_i8(b, hkv, skv, d))
    sk = jnp.asarray(rng.uniform(0.03, 0.07, (hkv,)).astype(np.float32))
    sv = jnp.asarray(rng.uniform(0.03, 0.07, (hkv,)).astype(np.float32))
    spec = ATT.AttentionSpec(mode="decode", impl="ita", causal=causal,
                             window=window, layout="bhsd",
                             scale_kind="per_head", out_dtype="int8",
                             q_len=1)
    scales = ATT.QuantScales(S_Q, sk, sv, S_OUT)
    eligible = ATT.list_backends(spec)
    assert len(eligible) >= 2, eligible       # a sweep, not a singleton
    families = {ATT.get_backend(n).family for n in eligible}
    assert families == {"ita_fused"}, families

    outs = {name: np.asarray(ATT.dispatch(
        q, k, v, spec=spec, scales=scales, q_offset=skv - 1, kv_len=skv,
        backend=name, block_q=8, block_kv=64)) for name in eligible}
    ref_name, ref = next(iter(outs.items()))
    for name, out in outs.items():
        np.testing.assert_array_equal(
            out, ref, err_msg=f"{name} != {ref_name} for {spec}")


def test_parity_same_family_holds_under_auto_dispatch():
    """Auto dispatch (no override) lands on the first eligible backend and
    matches the explicit sweep."""
    b, hq, hkv, d, skv = 1, 4, 2, 32, 128
    q = jnp.asarray(_i8(b, hq, 1, d))
    k = jnp.asarray(_i8(b, hkv, skv, d))
    v = jnp.asarray(_i8(b, hkv, skv, d))
    spec = ATT.AttentionSpec(mode="decode", impl="ita", window=48,
                             layout="bhsd", scale_kind="per_head",
                             out_dtype="int8", q_len=1)
    scales = ATT.QuantScales(S_Q, jnp.full((hkv,), 0.05, jnp.float32),
                             jnp.full((hkv,), 0.04, jnp.float32), S_OUT)
    auto = ATT.dispatch(q, k, v, spec=spec, scales=scales,
                        q_offset=skv - 1, kv_len=skv, block_kv=64)
    first = ATT.dispatch(q, k, v, spec=spec, scales=scales,
                         q_offset=skv - 1, kv_len=skv, block_kv=64,
                         backend=ATT.list_backends(spec)[0])
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(first))


# ---------------------------------------------------------------------------
# Pallas interpret-mode resolution (satellite: no silent interpret on
# capable hardware)
# ---------------------------------------------------------------------------

def test_resolve_interpret_auto_env_and_explicit(monkeypatch):
    monkeypatch.delenv("ITA_PALLAS_INTERPRET", raising=False)
    import jax
    expected = jax.default_backend() not in ("tpu", "gpu")
    assert resolve_interpret(None) is expected      # auto: platform-driven
    monkeypatch.setenv("ITA_PALLAS_INTERPRET", "1")
    assert resolve_interpret(None) is True
    monkeypatch.setenv("ITA_PALLAS_INTERPRET", "0")
    assert resolve_interpret(None) is False
    monkeypatch.setenv("ITA_PALLAS_INTERPRET", "false")
    assert resolve_interpret(None) is False
    # explicit argument beats the env override
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False


def test_engine_runs_with_env_forced_interpret(monkeypatch):
    """The override reaches the kernels through dispatch (smoke)."""
    monkeypatch.setenv("ITA_PALLAS_INTERPRET", "1")
    assert os.environ["ITA_PALLAS_INTERPRET"] == "1"
    q = jnp.asarray(_i8(1, 2, 1, 32))
    kv = jnp.asarray(_i8(1, 2, 128, 32))
    spec = ATT.AttentionSpec(mode="decode", impl="ita", layout="bhsd",
                             out_dtype="int8", q_len=1)
    out = ATT.dispatch(q, kv, kv, spec=spec,
                       scales=ATT.QuantScales.per_tensor(S_Q, s_out=S_OUT),
                       q_offset=127, kv_len=128,
                       backend="ita_decode_pallas")
    assert out.shape == (1, 2, 1, 32) and out.dtype == jnp.int8
