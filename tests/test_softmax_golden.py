"""Golden-vector regression for the bit-exact ITA softmax.

Checked-in int8 inputs → expected *integer* probabilities (units of
2^-8), locking in the paper's eq. 4/5 semantics with the 15-bit Σ /
16-bit Σ_inv silicon widths:

    k_i   = (max - x_i) >> 5
    Σ     = sat15( Σ_i 256 >> k_i )     (DA; multi-part adds the
                                         Σ >>= Δmax>>5 correction)
    Σ_inv = sat16( 2^16 // Σ )          (DI)
    p_i   = Σ_inv >> k_i                (EN)

Any change to these bit patterns is a silicon-semantics break, not a
refactor — the vectors below must never be regenerated to make a failing
test pass. Row 0 of the 4-part output intentionally differs from the
one-shot output (a late running-max update re-floors already-accumulated
Σ terms): that documented divergence is part of the contract.
"""

import jax.numpy as jnp
import numpy as np

from repro.core import softmax as S

# 4 rows x 32 int8 logits (seeded normal / EPS_MAX, clipped)
X = np.array([
    [-35, -51, -22, -76, 35, -12, -81, -56, 17, 46, 111, 127, 23, -55,
     -118, 15, -45, -23, -34, -8, 59, 9, -9, -57, -93, -27, -3, 98, 7,
     54, -28, -66],
    [-53, -40, 118, -46, 46, -50, 52, 21, -9, -2, -36, 25, -25, -68,
     -71, 10, 87, 9, -7, 16, 72, 12, -23, 61, 24, 85, 10, -68, -76, 91,
     95, -10],
    [-21, 81, -61, -50, 36, -22, 0, -9, 19, 78, 5, 36, -114, -3, -47,
     -68, -49, -19, 51, -73, 2, -27, -18, 56, 30, 74, -9, -39, -12, 13,
     10, -60],
    [5, 13, 127, 104, -47, -16, -81, -33, 17, 67, -40, -36, -119, -9,
     -59, -29, -49, -5, -97, -81, 118, -71, -61, 102, 127, -65, -20, 19,
     96, -55, -14, 43]], np.int8)

# one-shot (num_parts=1) integer probabilities
P_ONESHOT = np.array([
    [1, 1, 2, 0, 11, 2, 0, 1, 5, 11, 47, 47, 5, 1, 0, 5, 1, 2, 1, 2, 11,
     5, 2, 1, 0, 2, 2, 47, 5, 11, 2, 0],
    [0, 1, 31, 0, 7, 0, 7, 3, 3, 3, 1, 7, 1, 0, 0, 3, 31, 3, 3, 3, 15,
     3, 1, 15, 7, 15, 3, 0, 0, 31, 31, 1],
    [3, 24, 1, 1, 12, 3, 6, 6, 12, 24, 6, 12, 0, 6, 1, 1, 1, 3, 24, 1,
     6, 3, 3, 24, 12, 24, 6, 3, 6, 6, 6, 1],
    [4, 4, 32, 32, 1, 2, 0, 1, 4, 16, 1, 1, 0, 2, 1, 2, 1, 2, 0, 0, 32,
     0, 1, 32, 32, 0, 2, 4, 32, 1, 2, 8]], np.int64)

# streamed over 4 parts of 8: row 0 takes a late max update
P_STREAM4 = P_ONESHOT.copy()
P_STREAM4[0] = [1, 1, 2, 0, 11, 2, 0, 1, 5, 11, 45, 45, 5, 1, 0, 5, 1,
                2, 1, 2, 11, 5, 2, 1, 0, 2, 2, 45, 5, 11, 2, 0]

SIGMA = np.array([1386, 2084, 2676, 2036], np.int64)   # one-shot Σ (wide)
ROW_MAX = np.array([127, 118, 81, 127], np.int64)


def _int_probs(p_float):
    p = np.asarray(p_float) * 256.0
    pi = np.rint(p).astype(np.int64)
    np.testing.assert_allclose(p, pi, atol=1e-6)   # exact multiples of 2^-8
    return pi


def test_bitexact_oneshot_golden():
    pi = _int_probs(S.ita_softmax_bitexact(jnp.asarray(X), num_parts=1))
    np.testing.assert_array_equal(pi, P_ONESHOT)


def test_bitexact_streaming_golden():
    pi = _int_probs(S.ita_softmax_bitexact(jnp.asarray(X), num_parts=4))
    np.testing.assert_array_equal(pi, P_STREAM4)


def test_oneshot_int_stats_golden():
    p, sigma, row_max = S.ita_softmax_int(jnp.asarray(X))
    np.testing.assert_array_equal(np.asarray(p), P_ONESHOT)
    np.testing.assert_array_equal(np.asarray(sigma)[:, 0], SIGMA)
    np.testing.assert_array_equal(np.asarray(row_max)[:, 0], ROW_MAX)


def test_golden_consistent_with_eq5():
    """Independent numpy re-derivation of eq. 4/5 over the golden inputs
    (guards the vectors themselves against bit-rot)."""
    x = X.astype(np.int64)
    k = (x.max(-1, keepdims=True) - x) >> 5
    sigma = (256 >> k).sum(-1)
    np.testing.assert_array_equal(sigma, SIGMA)
    sigma_inv = np.minimum((1 << 16) // np.minimum(sigma, (1 << 15) - 1),
                           (1 << 16) - 1)
    np.testing.assert_array_equal(sigma_inv[:, None] >> k, P_ONESHOT)
