"""Paged KV pool: ring-equivalence, allocator correctness, kernel parity.

The acceptance property (ISSUE 4): the paged decode path — one shared
``(num_pages, page_size, G, hd)`` arena consumed through page-table
index maps — is **bit-identical** to the contiguous ring path on the
``s_out`` output grid, across every backend that serves the paged spec
(the ``ita_fused`` family invariant extended to the ``bhsd_paged``
layout). On top of that, the allocator itself is property-checked: no
physical page is ever double-booked, released pages return to the free
stack, and realloc reuses them without leaking state.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import attention as ATT
from repro.attention import KVCacheState, PagedKVState
from repro.kernels.common import MIN_BLOCK_KV
from repro.runtime import kv_cache as KV

rng = np.random.default_rng(0)

S_Q, S_OUT = np.float32(0.05), np.float32(0.02)


def _i8(*shape):
    return rng.integers(-128, 128, shape, dtype=np.int8)


def _paged_from_logical(k_log, v_log, page, *, shuffle_seed=1):
    """Scatter (B, C, G, hd) logical KV into a shuffled arena + table."""
    b, c, g, hd = k_log.shape
    npps = c // page
    total = b * npps + 1
    perm = np.random.default_rng(shuffle_seed).permutation(
        np.arange(1, total))
    pt = perm.reshape(b, npps).astype(np.int32)
    k_pool = np.zeros((total, page, g, hd), np.int8)
    v_pool = np.zeros((total, page, g, hd), np.int8)
    for bb in range(b):
        for j in range(npps):
            k_pool[pt[bb, j]] = k_log[bb, j * page:(j + 1) * page]
            v_pool[pt[bb, j]] = v_log[bb, j * page:(j + 1) * page]
    return k_pool, v_pool, pt


# ---------------------------------------------------------------------------
# Kernel parity: paged ≡ ring, every eligible backend
# ---------------------------------------------------------------------------

PARITY_SPECS = [
    # (hq, hkv, window, per_head) — causal, sliding-window, GQA and
    # per-head-scale decode specs, as in the ring parity sweep
    pytest.param(4, 4, 0, False, id="causal"),
    pytest.param(4, 4, 80, True, id="sliding-window+per-head"),
    pytest.param(4, 2, 0, True, id="gqa+per-head"),
    pytest.param(4, 2, 80, False, id="gqa+window"),
]


@pytest.mark.parametrize("hq,hkv,window,per_head", PARITY_SPECS)
def test_paged_parity_sweep_across_backends(hq, hkv, window, per_head):
    """Every backend eligible for the paged decode spec is bit-identical
    to the ring-buffer path at block_kv == page_size, mixed (ragged)
    valid prefixes included."""
    b, d, page, npps = 2, 32, 64, 3
    cap = page * npps
    q = _i8(b, hq, 1, d)
    k_log = _i8(b, cap, hkv, d)
    v_log = _i8(b, cap, hkv, d)
    if per_head:
        sk = jnp.asarray(rng.uniform(0.03, 0.07, (hkv,)).astype(np.float32))
        sv = jnp.asarray(rng.uniform(0.03, 0.07, (hkv,)).astype(np.float32))
    else:
        sk = sv = jnp.asarray(np.float32(0.04))
    scales = ATT.QuantScales(S_Q, sk, sv, S_OUT)
    kv_lens = jnp.asarray([150, cap])              # row 1 fully wrapped
    offs = kv_lens - 1

    ring_spec = ATT.AttentionSpec(
        mode="decode", impl="ita", window=window, layout="bhsd_bsgd",
        scale_kind="per_head" if per_head else "per_tensor",
        out_dtype="int8", q_len=1)
    ring = ATT.dispatch(jnp.asarray(q), jnp.asarray(k_log),
                        jnp.asarray(v_log), spec=ring_spec, scales=scales,
                        q_offset=offs, kv_len=kv_lens,
                        backend="ita_decode_pallas", block_kv=page)

    k_pool, v_pool, pt = _paged_from_logical(k_log, v_log, page)
    spec = ring_spec.replace(layout="bhsd_paged")
    eligible = ATT.list_backends(spec)
    assert len(eligible) >= 2, eligible            # a sweep, not a singleton
    assert {ATT.get_backend(n).family for n in eligible} == {"ita_fused"}
    for name in eligible:
        out = ATT.dispatch(jnp.asarray(q), jnp.asarray(k_pool),
                           jnp.asarray(v_pool), spec=spec, scales=scales,
                           q_offset=offs, kv_len=kv_lens,
                           page_table=jnp.asarray(pt), backend=name)
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(ring),
            err_msg=f"{name} (paged) != ring path for {spec}")


def test_paged_layout_capability_matrix():
    """bhsd_paged is served by exactly the fused decode/onepass kernels;
    everything else declines with a reason, and dispatch enforces the
    page_table handshake."""
    spec = ATT.AttentionSpec(mode="decode", impl="ita", layout="bhsd_paged",
                             out_dtype="int8", q_len=1)
    assert ATT.list_backends(spec) == ["ita_decode_pallas",
                                       "ita_onepass_pallas"]
    for name, verdict in ATT.backend_reasons(spec).items():
        if name not in ("ita_decode_pallas", "ita_onepass_pallas"):
            assert isinstance(verdict, str) and verdict, name
    q = jnp.asarray(_i8(1, 2, 1, 32))
    pool = jnp.asarray(_i8(3, 64, 2, 32))
    sc = ATT.QuantScales.per_tensor(S_Q, s_out=S_OUT)
    with pytest.raises(ValueError, match="page_table"):
        ATT.dispatch(q, pool, pool, spec=spec, scales=sc)
    with pytest.raises(ValueError, match="page_table"):
        ATT.dispatch(q, q, q, spec=spec.replace(layout="bhsd"), scales=sc,
                     page_table=jnp.zeros((1, 1), jnp.int32))


# ---------------------------------------------------------------------------
# State: logical ring equivalence + allocator properties
# ---------------------------------------------------------------------------

def _logical_view(p: PagedKVState):
    pt = np.asarray(p.page_table)
    g, hd = p.k.shape[2], p.k.shape[3]
    return np.asarray(p.k)[pt].reshape(p.batch, p.capacity, g, hd)


def test_paged_state_matches_ring_through_wrap():
    """Ragged prefill + appends past the wrap: the pool's logical view
    (pages gathered through the table) equals the ring byte-for-byte on
    every valid slot, and pos/valid_len/q_offset agree."""
    b, g, hd, page, cap = 3, 2, 4, 8, 32
    toks = _i8(b, 40, g, hd)
    lens = jnp.asarray([5, 12, 9], jnp.int32)
    ring = KVCacheState.init(b, cap, g, hd).prefill_write(
        jnp.asarray(toks[:, :12]), jnp.asarray(toks[:, :12]), lengths=lens)
    paged = PagedKVState.init(b, cap, g, hd, page_size=page).prefill_write(
        jnp.asarray(toks[:, :12]), jnp.asarray(toks[:, :12]), lengths=lens)
    # lazy allocation: a 5-token row holds 1 page, not the full window
    np.testing.assert_array_equal(np.asarray(paged.pages_held()), [1, 2, 2])

    for t in range(12, 40):
        ring = ring.decode_append(jnp.asarray(toks[:, t:t + 1]),
                                  jnp.asarray(toks[:, t:t + 1]))
        paged = paged.decode_append(jnp.asarray(toks[:, t:t + 1]),
                                    jnp.asarray(toks[:, t:t + 1]))
    np.testing.assert_array_equal(np.asarray(ring.pos),
                                  np.asarray(paged.pos))
    np.testing.assert_array_equal(np.asarray(ring.valid_len()),
                                  np.asarray(paged.valid_len()))
    np.testing.assert_array_equal(np.asarray(ring.q_offset(1)),
                                  np.asarray(paged.q_offset(1)))
    lv, rv = _logical_view(paged), np.asarray(ring.k)
    for row in range(b):
        n, pos = int(ring.valid_len()[row]), int(ring.pos[row])
        for t in range(pos - n, pos):
            np.testing.assert_array_equal(
                lv[row, t % cap], rv[row, t % cap],
                err_msg=f"row {row} token {t}")


def _partition_ok(p: PagedKVState, pins=None, shared=False):
    """Invariant: {parking} ∪ free stack ∪ referenced pages partition the
    arena — no double-booking, no leaks — and every page's refcount
    equals its table references plus pins (``check_invariants``). With
    ``shared=False`` additionally requires exclusively-held pages (no
    page in two rows), the pre-sharing partition property."""
    pt = np.asarray(p.page_table)
    held_counts = np.asarray(p.pages_held())
    held = []
    for row in range(p.batch):
        held.extend(pt[row, :held_counts[row]].tolist())
    free = np.asarray(p.free_stack)[:int(p.free_top)].tolist()
    try:
        p.check_invariants(pins=pins)
    except AssertionError:
        return False
    if not shared and len(set(held)) != len(held):  # a page in two rows
        return False
    if 0 in held or 0 in free:                     # parking page leaked
        return False
    if pins:
        held.extend(pg for pg, c in pins.items() for _ in range(c))
    return set(held) | set(free) | {0} == set(range(p.num_pages))


def test_page_free_and_realloc_reuse():
    """Released pages return to the stack and are handed out again; the
    re-admitted row's bytes are exactly the new prompt (no stale state
    from the page's previous owner)."""
    b, g, hd, page, cap = 2, 2, 4, 8, 16
    p = PagedKVState.init(b, cap, g, hd, page_size=page)
    total_free = int(p.free_top)
    a = _i8(b, 12, g, hd)
    p = p.prefill_write(jnp.asarray(a), jnp.asarray(a))
    assert int(p.free_top) == total_free - 4
    assert _partition_ok(p)

    p = p.release(jnp.asarray([True, False]))
    assert int(p.free_top) == total_free - 2
    assert int(p.pos[0]) == 0 and int(p.pos[1]) == 12
    assert _partition_ok(p)

    # re-admit row 0 with a fresh prompt into the recycled pages
    fresh = _i8(1, 9, g, hd)
    p = p.write_prompts(jnp.asarray(fresh), jnp.asarray(fresh),
                        lengths=jnp.asarray([9]),
                        slots=jnp.asarray([0]))
    assert int(p.pos[0]) == 9 and _partition_ok(p)
    np.testing.assert_array_equal(_logical_view(p)[0, :9], fresh[0])
    # row 1 untouched by the realloc
    np.testing.assert_array_equal(_logical_view(p)[1, :12], a[1])


def test_allocator_partition_property_seeded():
    """Seeded property test: a random interleaving of admissions (into
    released rows), appends (with random live masks) and releases —
    including repeated and overlapping release masks — never
    double-books a page: the partition + refcount invariant holds at
    every step and re-releasing a released row moves nothing."""
    b, g, hd, page, cap = 4, 1, 4, 4, 16
    prng = np.random.default_rng(7)
    p = PagedKVState.init(b, cap, g, hd, page_size=page,
                          num_pages=b * (cap // page) + 1)
    active = np.zeros(b, bool)
    for op in range(120):
        kind = prng.integers(0, 4)
        if kind == 0:                              # admit into a free row
            free = np.flatnonzero(~active)
            if free.size:
                row = int(prng.choice(free))
                ln = int(prng.integers(1, cap + 1))
                tok = _i8(1, ln, g, hd)
                p = p.write_prompts(jnp.asarray(tok), jnp.asarray(tok),
                                    lengths=jnp.asarray([ln]),
                                    slots=jnp.asarray([row]))
                active[row] = True
        elif kind == 1 and active.any():           # masked decode append
            live = active & (prng.random(b) < 0.8)
            tok = _i8(b, 1, g, hd)
            p = p.decode_append(jnp.asarray(tok), jnp.asarray(tok),
                                live=jnp.asarray(live))
        elif kind == 2 and active.any():           # release some rows
            fin = active & (prng.random(b) < 0.4)
            if fin.any():
                p = p.release(jnp.asarray(fin))
                active &= ~fin
        elif kind == 3 and active.any():           # repeated + overlapping
            fin = active & (prng.random(b) < 0.4)
            if fin.any():
                p = p.release(jnp.asarray(fin))
                active &= ~fin
                top_before = int(p.free_top)
                # same mask again, then a superset that only adds rows
                # already released / never admitted: both no-ops
                p = p.release(jnp.asarray(fin))
                over = fin | (~active & (prng.random(b) < 0.5))
                p = p.release(jnp.asarray(over))
                assert int(p.free_top) == top_before, \
                    f"op {op}: double release pushed pages again"
        assert not bool(p.oversubscribed()), f"op {op}: pool overdrawn"
        assert _partition_ok(p), f"op {op}: partition violated"


def test_burst_and_overlong_append_match_ring():
    """Multi-token bursts (page-crossing, ring-wrapping, over-capacity)
    keep the paged pool's logical bytes equal to the ring's."""
    b, g, hd, page, cap = 1, 2, 4, 8, 16
    toks = _i8(b, 41, g, hd)
    ring = KVCacheState.init(b, cap, g, hd).prefill_write(
        jnp.asarray(toks[:, :15]), jnp.asarray(toks[:, :15]))
    paged = PagedKVState.init(b, cap, g, hd, page_size=page).prefill_write(
        jnp.asarray(toks[:, :15]), jnp.asarray(toks[:, :15]))
    for lo, hi in ((15, 19), (19, 21), (21, 41)):  # wraps; last > capacity
        ring = ring.decode_append(jnp.asarray(toks[:, lo:hi]),
                                  jnp.asarray(toks[:, lo:hi]))
        paged = paged.decode_append(jnp.asarray(toks[:, lo:hi]),
                                    jnp.asarray(toks[:, lo:hi]))
        np.testing.assert_array_equal(np.asarray(ring.pos),
                                      np.asarray(paged.pos))
        lv, rv = _logical_view(paged), np.asarray(ring.k)
        pos, n = int(ring.pos[0]), int(ring.valid_len()[0])
        for t in range(pos - n, pos):
            np.testing.assert_array_equal(lv[0, t % cap], rv[0, t % cap],
                                          err_msg=f"token {t} after "
                                                  f"burst [{lo},{hi})")


def test_paged_state_is_pytree_and_jit_safe():
    p = PagedKVState.init(2, 16, 2, 4, page_size=8, per_head_scales=True)
    leaves = jax.tree.leaves(p)
    assert len(leaves) == 9                        # + ref_count
    shp = jax.eval_shape(lambda: PagedKVState.init(2, 16, 2, 4, page_size=8))
    assert isinstance(shp, PagedKVState) and shp.k_scale is None

    @jax.jit
    def step(c, t):
        return c.decode_append(t, t)

    out = step(p, jnp.ones((2, 1, 2, 4), jnp.int8))
    assert isinstance(out, PagedKVState)
    np.testing.assert_array_equal(np.asarray(out.pos), [1, 1])
    np.testing.assert_array_equal(np.asarray(out.pages_held()), [1, 1])


# ---------------------------------------------------------------------------
# Allocator bugfixes: scatter determinism + parking-page hygiene (ISSUE 6)
# ---------------------------------------------------------------------------

def _state_equal(a: PagedKVState, b: PagedKVState, msg=""):
    for f in ("k", "v", "page_table", "pos", "free_stack", "free_top",
              "ref_count"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"{msg}{f}")


def test_allocator_ops_bit_deterministic_under_jit():
    """The duplicate-scatter regression: ragged prefill, an
    over-capacity burst ``decode_append`` under a live mask, a ragged
    ``append_chunk`` and a double ``release`` produce **bit-identical**
    state eager vs jit vs a second jit run. Masked/pad writes scatter to
    an out-of-bounds index and are dropped — with no duplicate targets
    (the old parking-page sink), nothing depends on an unspecified
    duplicate-scatter winner, and the parking page's bytes stay zero."""
    b, g, hd, page, cap = 3, 2, 4, 8, 16
    prng = np.random.default_rng(13)
    pre = prng.integers(-128, 128, (b, 10, g, hd)).astype(np.int8)
    burst = prng.integers(-128, 128, (b, cap + 5, g, hd)).astype(np.int8)
    chunk = prng.integers(-128, 128, (b, 6, g, hd)).astype(np.int8)
    lens = jnp.asarray([10, 4, 0], jnp.int32)
    live = jnp.asarray([True, False, True])
    n_new = jnp.asarray([2, 6, 0], jnp.int32)

    def run(p, k_pre, k_burst, k_chunk):
        p = p.write_prompts(k_pre, k_pre, lengths=lens)
        p = p.decode_append(k_burst, k_burst, live=live)   # > capacity
        p = p.append_chunk(k_chunk, k_chunk, n_new)
        p = p.release(jnp.asarray([True, False, False]))
        p = p.release(jnp.asarray([True, True, False]))    # overlapping
        return p

    def init():
        return PagedKVState.init(b, cap, g, hd, page_size=page)

    args = (jnp.asarray(pre), jnp.asarray(burst), jnp.asarray(chunk))
    eager = run(init(), *args)
    jitted = jax.jit(run)
    j1 = jitted(init(), *args)
    j2 = jitted(init(), *args)
    _state_equal(eager, j1, "eager vs jit: ")
    _state_equal(j1, j2, "jit run 1 vs 2: ")
    assert not np.asarray(j1.k[0]).any() and not np.asarray(j1.v[0]).any(), \
        "parking page bytes were written"
    assert _partition_ok(j1)


def test_write_prompts_dummy_rows_keep_parking_pristine():
    """Fixed-width admission dispatch: negative ``slots`` entries are
    dummy rows whose bytes must go *nowhere* — no page allocated, no
    byte written (the parking page stays all-zero), untargeted rows
    untouched — and no live row's table ever points at page 0."""
    b, g, hd, page, cap = 3, 2, 4, 8, 16
    p = PagedKVState.init(b, cap, g, hd, page_size=page)
    a = _i8(2, 12, g, hd)
    p = p.write_prompts(jnp.asarray(a), jnp.asarray(a),
                        lengths=jnp.asarray([12, 7]),
                        slots=jnp.asarray([0, 2]))
    snap_k = np.asarray(p.k).copy()
    dummy = _i8(2, 12, g, hd)
    p2 = p.write_prompts(jnp.asarray(dummy), jnp.asarray(dummy),
                         lengths=jnp.asarray([12, 9]),
                         slots=jnp.asarray([-1, -1]))
    np.testing.assert_array_equal(np.asarray(p2.k), snap_k,
                                  err_msg="dummy admission wrote bytes")
    np.testing.assert_array_equal(np.asarray(p2.pos), np.asarray(p.pos))
    assert int(p2.free_top) == int(p.free_top), "dummy row leaked a page"
    assert not np.asarray(p2.k[0]).any(), "parking page written"
    p2.check_invariants()
    pt = np.asarray(p2.page_table)
    held = np.asarray(p2.pages_held())
    for row in range(b):
        assert 0 not in pt[row, :held[row]].tolist(), \
            f"live row {row} points at the parking page"


# ---------------------------------------------------------------------------
# Prefix sharing: adopt_prefix + copy-on-write (state level, ISSUE 6)
# ---------------------------------------------------------------------------

def test_append_chunk_straddling_pages_during_neighbor_cow():
    """One ragged ``append_chunk`` whose row-0 chunk straddles three page
    boundaries and wraps onto its *shared* prefix pages, while the
    neighbor row copy-on-writes the same shared pages in the same call:
    logical bytes match (a) the identical tokens applied as sequential
    masked ``decode_append`` steps and (b) an unshared pool fed each
    row's full stream — and a shared page abandoned by *both* diverging
    rows at once returns to the free stack exactly once."""
    b, g, hd, page, npps = 2, 2, 4, 4, 4
    cap = page * npps                              # 16
    prng = np.random.default_rng(21)
    P = 2 * npps + 3                               # COW pop headroom

    def mk():
        return PagedKVState.init(b, cap, g, hd, page_size=page,
                                 num_pages=P)

    pre = prng.integers(-128, 128, (1, 8, g, hd)).astype(np.int8)
    shared = mk().write_prompts(jnp.asarray(pre), jnp.asarray(pre),
                                lengths=jnp.asarray([8]),
                                slots=jnp.asarray([0]))
    donor_pages = np.asarray(shared.page_table)[0, :2]
    shared = shared.adopt_prefix(jnp.asarray([1]),
                                 jnp.asarray(donor_pages[None, :]),
                                 jnp.asarray([2]), jnp.asarray([8]))
    np.testing.assert_array_equal(
        np.asarray(shared.ref_count)[donor_pages], [2, 2])
    assert _partition_ok(shared, shared=True)

    s = 13
    toks = prng.integers(-128, 128, (b, s, g, hd)).astype(np.int8)
    n_new = np.asarray([13, 9], np.int32)
    # row 0: slots 8..20 -> page boundaries at 12, 16 (the wrap) and 20,
    # landing on shared logical pages 0 and 1 -> COW both; row 1: slots
    # 8..16 -> COWs shared logical page 0 in the same dispatch. Both rows
    # abandon the donor copy of logical page 0 simultaneously.
    chunked = shared.append_chunk(jnp.asarray(toks), jnp.asarray(toks),
                                  jnp.asarray(n_new))
    assert _partition_ok(chunked)                  # fully diverged again

    # (a) sequential masked single-token appends from the same shared state
    ref = shared
    for t in range(s):
        ref = ref.decode_append(jnp.asarray(toks[:, t:t + 1]),
                                jnp.asarray(toks[:, t:t + 1]),
                                live=jnp.asarray(t < n_new))
    np.testing.assert_array_equal(np.asarray(chunked.pos),
                                  np.asarray(ref.pos))
    np.testing.assert_array_equal(np.asarray(chunked.pages_held()),
                                  np.asarray(ref.pages_held()))
    assert int(chunked.free_top) == int(ref.free_top)

    # (b) the unshared path: a fresh pool where each row owns its prefix
    prompts = np.broadcast_to(pre, (b, 8, g, hd))
    unshared = mk().write_prompts(jnp.asarray(prompts), jnp.asarray(prompts))
    unshared = unshared.append_chunk(jnp.asarray(toks), jnp.asarray(toks),
                                     jnp.asarray(n_new))
    lv_c, lv_r, lv_u = (_logical_view(x) for x in (chunked, ref, unshared))
    for row in range(b):
        n = int(chunked.valid_len()[row])
        pos = int(chunked.pos[row])
        for t in range(pos - n, pos):
            np.testing.assert_array_equal(
                lv_c[row, t % cap], lv_r[row, t % cap],
                err_msg=f"row {row} token {t}: chunked vs sequential")
            np.testing.assert_array_equal(
                lv_c[row, t % cap], lv_u[row, t % cap],
                err_msg=f"row {row} token {t}: shared vs unshared")


def test_shared_refcount_partition_property_seeded():
    """Seeded property test over admit / adopt / pin / unpin / ragged
    append (arming copy-on-write on wrap) / repeated-release cycles:
    after every op each page is on the free stack XOR referenced, each
    refcount equals its page-table references plus pins, the parking
    page stays untouched, and a stray decref of an already-free page is
    a guarded no-op."""
    b, g, hd, page, npps = 3, 1, 4, 4, 3
    cap = page * npps
    max_pins = 4
    P = b * npps + max_pins + 2
    prng = np.random.default_rng(17)
    p = PagedKVState.init(b, cap, g, hd, page_size=page, num_pages=P)
    active = np.zeros(b, bool)
    pins: dict = {}
    for op in range(160):
        kind = prng.integers(0, 6)
        if kind == 0:                              # admit a fresh row
            free = np.flatnonzero(~active)
            if free.size:
                row = int(prng.choice(free))
                ln = int(prng.integers(1, cap + 1))
                tok = _i8(1, ln, g, hd)
                p = p.write_prompts(jnp.asarray(tok), jnp.asarray(tok),
                                    lengths=jnp.asarray([ln]),
                                    slots=jnp.asarray([row]))
                active[row] = True
        elif kind == 1:                            # adopt a donor's prefix
            free = np.flatnonzero(~active)
            donors = [r for r in np.flatnonzero(active)
                      if int(np.asarray(p.pos)[r]) >= page]
            if free.size and donors:
                row = int(prng.choice(free))
                donor = int(prng.choice(donors))
                full = min(int(np.asarray(p.pos)[donor]) // page, npps)
                n_pg = int(prng.integers(1, full + 1))
                pages = np.asarray(p.page_table)[donor, :n_pg]
                p = p.adopt_prefix(jnp.asarray([row]),
                                   jnp.asarray(pages[None, :]),
                                   jnp.asarray([n_pg]),
                                   jnp.asarray([n_pg * page]))
                active[row] = True
        elif kind == 2 and active.any():           # ragged append, may COW
            live = active & (prng.random(b) < 0.8)
            width = int(prng.integers(1, page + 2))
            n_new = np.where(live, prng.integers(0, width + 1, b),
                             0).astype(np.int32)
            tok = _i8(b, width, g, hd)
            p = p.append_chunk(jnp.asarray(tok), jnp.asarray(tok),
                               jnp.asarray(n_new))
        elif kind == 3 and active.any():           # release, maybe twice
            fin = active & (prng.random(b) < 0.4)
            if fin.any():
                p = p.release(jnp.asarray(fin))
                active &= ~fin
                if prng.random() < 0.5:
                    p = p.release(jnp.asarray(fin))    # idempotent
        elif kind == 4 and len(pins) < max_pins:   # pin a held page
            cand: set = set()
            pt = np.asarray(p.page_table)
            held = np.asarray(p.pages_held())
            for r in np.flatnonzero(active):
                cand.update(pt[r, :held[r]].tolist())
            cand -= set(pins)
            if cand:
                pg = int(prng.choice(sorted(cand)))
                p = p.incref_pages(jnp.asarray([pg]))
                pins[pg] = 1
        elif kind == 5 and pins:                   # unpin (+ stray decref)
            pg = int(prng.choice(sorted(pins)))
            p = p.decref_pages(jnp.asarray([pg]))
            del pins[pg]
            if int(np.asarray(p.ref_count)[pg]) == 0 \
                    and prng.random() < 0.5:
                p = p.decref_pages(jnp.asarray([pg]))  # stray: guarded
        assert not bool(p.oversubscribed()), f"op {op}: pool overdrawn"
        try:
            p.check_invariants(pins=pins)
        except AssertionError as e:
            raise AssertionError(f"op {op}: {e}") from e


def test_prefix_index_lookup_register_evict():
    """PrefixIndex host semantics: chain-hashed page-granular lookup
    returns the longest registered prefix (partial pages never match),
    registration skips known chunks and halts on conflicts or the
    parking page, and LRU eviction respects the protected set while
    orphaned chain tails stay evictable."""
    from repro.attention import PrefixIndex
    idx = PrefixIndex(page_size=4)
    a = np.arange(12, dtype=np.int32)              # 3 full chunks
    assert idx.register(a, [5, 6, 7]) == [5, 6, 7]
    assert len(idx) == 3
    assert idx.lookup(a) == [5, 6, 7]
    assert idx.lookup(a[:11]) == [5, 6]            # partial page 3: no hit
    assert idx.lookup(a, max_tokens=9) == [5, 6]   # cap binds
    b2 = np.concatenate([a[:8], 90 + np.arange(4)]).astype(np.int32)
    assert idx.lookup(b2) == [5, 6]                # diverges at chunk 2
    c = np.concatenate([[99], a[1:]]).astype(np.int32)
    assert idx.lookup(c) == []                     # position-0 mismatch
    assert idx.register(a, [5, 6, 7]) == []        # all known: no new pins
    assert idx.register(b2, [5, 6, 9]) == [9]      # only the new tail
    assert idx.register(c, [0, 11]) == []          # parking page halts
    idx.lookup(b2)                                 # LRU-touch 5, 6, 9
    ev = idx.evict_lru(2, protected={7})
    assert ev == [5, 6] and 7 not in ev
    assert idx.lookup(b2) == []                    # chain head evicted
    assert 9 in idx.pinned_pages                   # orphaned tail ...
    assert sorted(idx.evict_lru(5)) == [7, 9]      # ... still evictable
    assert len(idx) == 0 and idx.pinned_pages == []


# ---------------------------------------------------------------------------
# Engine level: decode_attend over a paged cache
# ---------------------------------------------------------------------------

def test_paged_decode_attend_matches_ring_engine():
    """The float-in/int8-out engine path over a paged cache is
    bit-identical to the ring cache engine at block_kv == page_size."""
    b, hq, hkv, d, page, cap = 2, 4, 2, 32, 64, 128
    s, prefill = cap, 96
    qf = rng.normal(0, 1, (b, hq, s, d)).astype(np.float32)
    kf = rng.normal(0, 1, (b, s, hkv, d)).astype(np.float32)
    vf = rng.normal(0, 1, (b, s, hkv, d)).astype(np.float32)
    q8 = KV.quantize_with_scale(jnp.asarray(qf), S_Q)

    ring = KV.init_cache(b, cap, hkv, d, per_head_scales=True)
    paged = KV.init_paged_cache(b, cap, hkv, d, per_head_scales=True,
                                page_size=page)
    _, ring = KV.prefill_attend(ring, q8[:, :, :prefill],
                                jnp.asarray(kf[:, :prefill]),
                                jnp.asarray(vf[:, :prefill]), S_Q, S_OUT,
                                block_q=32, block_kv=page)
    _, paged = KV.prefill_attend(paged, q8[:, :, :prefill],
                                 jnp.asarray(kf[:, :prefill]),
                                 jnp.asarray(vf[:, :prefill]), S_Q, S_OUT,
                                 block_q=32, block_kv=page)
    for t in range(prefill, s):
        o_r, ring = KV.decode_attend(ring, q8[:, :, t:t + 1],
                                     jnp.asarray(kf[:, t:t + 1]),
                                     jnp.asarray(vf[:, t:t + 1]),
                                     S_Q, S_OUT, block_kv=page)
        o_p, paged = KV.decode_attend(paged, q8[:, :, t:t + 1],
                                      jnp.asarray(kf[:, t:t + 1]),
                                      jnp.asarray(vf[:, t:t + 1]),
                                      S_Q, S_OUT, block_kv=page)
        np.testing.assert_array_equal(np.asarray(o_r), np.asarray(o_p),
                                      err_msg=f"decode step t={t}")


# ---------------------------------------------------------------------------
# Satellite: ring block-alignment kills the decode pad-copy
# ---------------------------------------------------------------------------

def test_ring_capacity_block_aligned_at_init():
    assert KVCacheState.init(1, 144, 2, 4).capacity == MIN_BLOCK_KV * 2
    assert KVCacheState.init(1, 128, 2, 4).capacity == 128
    assert KVCacheState.init(1, 96, 2, 4).capacity == 96   # <= one block
    p = PagedKVState.init(1, 144, 2, 4, page_size=64)
    assert p.capacity == 192                               # page multiple


def test_decode_pad_copy_statically_forbidden():
    """A decode dispatch over a non-block-multiple ring above one block
    raises instead of silently pad-copying the ring every step."""
    b, h, d, cap = 1, 2, 32, 192
    q = jnp.asarray(_i8(b, h, 1, d))
    kv = jnp.asarray(_i8(b, h, cap, d))
    spec = ATT.AttentionSpec(mode="decode", impl="ita", layout="bhsd",
                             out_dtype="int8", q_len=1)
    sc = ATT.QuantScales.per_tensor(S_Q, s_out=S_OUT)
    with pytest.raises(ValueError, match="block_kv"):
        ATT.dispatch(q, kv, kv, spec=spec, scales=sc, q_offset=cap - 1,
                     kv_len=cap, backend="ita_decode_pallas", block_kv=80)
    # block-multiple capacities dispatch fine (the init-aligned case)
    out = ATT.dispatch(q, kv, kv, spec=spec, scales=sc, q_offset=cap - 1,
                       kv_len=cap, backend="ita_decode_pallas", block_kv=64)
    assert out.shape == (b, h, 1, d)


def test_block_defaults_recorded():
    from repro.kernels.common import (BLOCK_DEFAULTS, default_blocks,
                                      default_matmul_blocks)
    for name in ("ita_onepass_pallas", "ita_twopass_pallas",
                 "ita_decode_pallas"):
        assert name in BLOCK_DEFAULTS
        bq, bkv = default_blocks(name)
        assert bkv in (64, 128, 256)
    assert default_blocks("ita_decode_pallas")[0] is None  # no q tiling
    # the matmul entry is 3-wide and fenced off from default_blocks()
    assert len(default_matmul_blocks()) == 3
    with pytest.raises(AssertionError, match="default_matmul_blocks"):
        default_blocks("int8_matmul")


# ---------------------------------------------------------------------------
# Chunked prefill: append_chunk + ragged q_len mixed calls (ISSUE 5)
# ---------------------------------------------------------------------------

def test_append_chunk_equals_sequential_appends():
    """A ragged ``append_chunk`` (per-row n_new, one dispatch) is
    state-identical to applying the same tokens as single-token
    ``decode_append`` steps with live masks: same bytes, same pos, same
    pages held, allocator partition intact — including rows whose chunk
    crosses a page boundary and dead rows (n_new = 0)."""
    b, g, hd, page, cap = 3, 2, 4, 8, 32
    base = PagedKVState.init(b, cap, g, hd, page_size=page)
    pre = _i8(b, 6, g, hd)
    base = base.prefill_write(jnp.asarray(pre), jnp.asarray(pre),
                              lengths=jnp.asarray([6, 3, 0]))
    s = 12
    toks = _i8(b, s, g, hd)
    n_new = np.asarray([1, 12, 0], np.int32)       # decode / chunk / dead

    chunked = base.append_chunk(jnp.asarray(toks), jnp.asarray(toks),
                                jnp.asarray(n_new))
    ref = base
    for t in range(s):
        live = jnp.asarray(t < n_new)
        ref = ref.decode_append(jnp.asarray(toks[:, t:t + 1]),
                                jnp.asarray(toks[:, t:t + 1]), live=live)
    np.testing.assert_array_equal(np.asarray(chunked.pos),
                                  np.asarray(ref.pos))
    np.testing.assert_array_equal(np.asarray(chunked.pages_held()),
                                  np.asarray(ref.pages_held()))
    assert _partition_ok(chunked)
    lv_c, lv_r = _logical_view(chunked), _logical_view(ref)
    for row in range(b):
        n = int(chunked.valid_len()[row])
        pos = int(chunked.pos[row])
        for t in range(pos - n, pos):
            np.testing.assert_array_equal(
                lv_c[row, t % cap], lv_r[row, t % cap],
                err_msg=f"row {row} token {t}")
    with pytest.raises(ValueError, match="append_chunk width"):
        wide = _i8(b, cap + 1, g, hd)
        base.append_chunk(jnp.asarray(wide), jnp.asarray(wide),
                          jnp.asarray([1, 1, 1]))


def test_ragged_qlens_mixed_call_matches_pure_paths():
    """One ragged-q paged call carrying a decode row (q_len 1), a prefill
    chunk row (q_len = chunk) and a dead row (q_len 0) matches the pure
    decode kernel / one-shot onepass on the same streams; the dead row
    emits zeros."""
    b, g, hq, hd, page, npages = 3, 2, 4, 16, 32, 16
    scales = ATT.QuantScales.per_tensor(S_Q, s_out=S_OUT)
    pool = PagedKVState.init(b, 128, g, hd, page_size=page,
                             num_pages=npages)
    pre = _i8(b, 40, g, hd)
    pool = pool.prefill_write(jnp.asarray(pre), jnp.asarray(pre),
                              lengths=jnp.asarray([40, 17, 0]))
    chunk = 12
    kc = _i8(b, chunk, g, hd)
    n_new = jnp.asarray([1, chunk, 0])
    pool2 = pool.append_chunk(jnp.asarray(kc), jnp.asarray(kc), n_new)

    q = _i8(b, hq, chunk, hd)
    spec = ATT.AttentionSpec(mode="decode", impl="ita", layout="bhsd_paged",
                             out_dtype="int8", q_len=chunk, ragged_q=True)
    assert ATT.list_backends(spec) == ["ita_onepass_pallas"]
    out = ATT.dispatch(jnp.asarray(q), pool2.k, pool2.v, spec=spec,
                       scales=scales, q_offset=pool2.q_offset(n_new),
                       kv_len=pool2.valid_len(),
                       page_table=pool2.page_table, q_lens=n_new)

    # row 0 (decode): equals the single-query decode kernel on the pool
    dec_spec = spec.replace(q_len=1, ragged_q=False)
    dec = ATT.dispatch(jnp.asarray(q[:, :, :1]), pool2.k, pool2.v,
                       spec=dec_spec, scales=scales,
                       q_offset=pool2.q_offset(1), kv_len=pool2.valid_len(),
                       page_table=pool2.page_table,
                       backend="ita_decode_pallas")
    np.testing.assert_array_equal(np.asarray(out[0, :, 0]),
                                  np.asarray(dec[0, :, 0]))
    # row 2 (dead, q_len 0): all-zero output
    assert not np.asarray(out[2]).any()
    # row 1 (chunk): equals a one-shot onepass over the same stream
    full = np.concatenate([pre[1:2, :17], kc[1:2]], axis=1)
    solo = PagedKVState.init(1, 128, g, hd, page_size=page,
                             num_pages=npages)
    solo = solo.prefill_write(jnp.asarray(full), jnp.asarray(full))
    one_spec = spec.replace(ragged_q=False)
    one = ATT.dispatch(jnp.asarray(q[1:2]), solo.k, solo.v, spec=one_spec,
                       scales=scales, q_offset=17, kv_len=solo.valid_len(),
                       page_table=solo.page_table,
                       backend="ita_onepass_pallas")
    np.testing.assert_array_equal(np.asarray(out[1]), np.asarray(one[0]))

    # dispatch handshake: q_lens required by exactly ragged_q specs
    with pytest.raises(ValueError, match="q_lens"):
        ATT.dispatch(jnp.asarray(q), pool2.k, pool2.v, spec=spec,
                     scales=scales, q_offset=pool2.q_offset(n_new),
                     kv_len=pool2.valid_len(), page_table=pool2.page_table)
    with pytest.raises(ValueError, match="q_lens"):
        ATT.dispatch(jnp.asarray(q), pool2.k, pool2.v, spec=one_spec,
                     scales=scales, q_offset=pool2.q_offset(n_new),
                     kv_len=pool2.valid_len(), page_table=pool2.page_table,
                     q_lens=n_new)


def test_ragged_q_capability_verdicts():
    """ragged_q is a capability of exactly the fused one-pass kernels:
    everything else declines with a reason, on serve specs it could
    otherwise run."""
    base = ATT.AttentionSpec(mode="decode", impl="ita", layout="bhsd_paged",
                             out_dtype="int8", q_len=16)
    assert ATT.list_backends(base.replace(ragged_q=True)) == \
        ["ita_onepass_pallas"]
    for impl, layout in (("ita", "bshd"), ("ibert", "bshd")):
        spec = ATT.AttentionSpec(mode="decode", impl=impl, layout=layout,
                                 q_len=4, ragged_q=True)
        for name, verdict in ATT.backend_reasons(spec).items():
            if name != "ita_onepass_pallas":
                assert verdict is not True, (name, impl)


# ---------------------------------------------------------------------------
# Preemption / prefix-sharing seam (ISSUE 8): release-decrefs-not-frees
# ---------------------------------------------------------------------------

def test_preempt_readmit_evict_cycles_keep_invariants_seeded():
    """Seeded property test over the serve loop's preemption cycle at
    state level: admit (adopting registered prefixes), register + pin
    full prompt pages, preempt (release a victim whose pages are pinned
    — must decref, never free), ragged decode appends, re-admit adopting
    the victim's pages back, and LRU-evict + unpin. After *every* op the
    refcount partition holds (``check_invariants(pins)``) and no pinned
    page sits on the free stack."""
    from repro.attention import PrefixIndex

    b, g, hd, page, cap = 3, 1, 4, 4, 16
    prng = np.random.default_rng(13)
    p = PagedKVState.init(b, cap, g, hd, page_size=page, num_pages=11)
    index = PrefixIndex(page)
    pins = {}
    # three 2-page prompt families: adoption + re-adoption actually hit
    fams = [prng.integers(0, 100, 2 * page).astype(np.int32)
            for _ in range(3)]
    tokens = [None] * b                  # host stream per row (like
    adopted = [[] for _ in range(b)]     # slot_prompt / slot_shared)

    def rand_kv(s):
        return jnp.asarray(prng.integers(-127, 128, (b, s, g, hd)),
                           jnp.int8)

    def checked(op):
        assert not bool(p.oversubscribed()), f"op {op}: pool overdrawn"
        p.check_invariants(pins=pins)
        free = set(np.asarray(p.free_stack)[:int(p.free_top)].tolist())
        assert not free & set(pins), \
            f"op {op}: pinned page on the free stack: {free & set(pins)}"

    for op in range(160):
        kind = int(prng.integers(0, 5))
        live = [r for r in range(b) if tokens[r] is not None]
        if kind == 0:                              # admit, adopting hits
            free_rows = [r for r in range(b) if tokens[r] is None]
            if not free_rows:
                continue
            row = int(prng.choice(free_rows))
            fam = fams[int(prng.integers(len(fams)))]
            tail = prng.integers(0, 100,
                                 int(prng.integers(1, 8))).astype(np.int32)
            stream = np.concatenate([fam, tail])
            sh = index.lookup(stream, max_tokens=stream.size - 1)
            rest = stream.size - len(sh) * page
            need = -(-stream.size // page) - len(sh)
            if need > int(p.free_top):
                continue                           # admission would gate
            if sh:
                pad = np.full((1, p.pages_per_seq), -1, np.int32)
                pad[0, :len(sh)] = sh
                p = p.adopt_prefix(jnp.asarray([row]), jnp.asarray(pad),
                                   jnp.asarray([len(sh)]),
                                   jnp.asarray([len(sh) * page]))
            n_new = np.zeros(b, np.int32)
            n_new[row] = rest
            p = p.append_chunk(rand_kv(rest), rand_kv(rest),
                               jnp.asarray(n_new))
            tokens[row], adopted[row] = stream, list(sh)
        elif kind == 1 and live:                   # register + pin
            row = int(prng.choice(live))
            full = int(np.asarray(p.pos)[row]) // page
            table = np.asarray(p.page_table)[row, :full]
            got = index.register(tokens[row], table)
            if got:
                pins.update((pg, 1) for pg in got)
                p = p.incref_pages(jnp.asarray(got, jnp.int32))
        elif kind == 2 and live:                   # preempt a victim
            row = int(prng.choice(live))
            mask = np.zeros(b, bool)
            mask[row] = True
            p = p.release(jnp.asarray(mask))
            tokens[row], adopted[row] = None, []
        elif kind == 3 and live:                   # ragged decode append
            row = int(prng.choice(live))
            ln = int(np.asarray(p.pos)[row])
            if ln >= cap or (ln % page == 0 and int(p.free_top) < 1):
                continue
            n_new = np.zeros(b, np.int32)
            n_new[row] = 1
            p = p.append_chunk(rand_kv(1), rand_kv(1), jnp.asarray(n_new))
            tokens[row] = np.concatenate(
                [tokens[row], prng.integers(0, 100, 1).astype(np.int32)])
        elif kind == 4 and len(index):             # LRU evict + unpin
            protected = {pg for lst in adopted for pg in lst}
            evicted = index.evict_lru(int(prng.integers(1, 3)), protected)
            for pg in evicted:
                pins.pop(pg, None)
            if evicted:
                p = p.decref_pages(jnp.asarray(evicted, jnp.int32))
        checked(op)
    # drain: release everything, evict every pin -> the pool is whole
    p = p.release(jnp.asarray([tokens[r] is not None for r in range(b)]))
    tokens, adopted = [None] * b, [[] for _ in range(b)]
    evicted = index.evict_lru(len(index))
    for pg in evicted:
        pins.pop(pg, None)
    if evicted:
        p = p.decref_pages(jnp.asarray(evicted, jnp.int32))
    checked("drain")
    assert not pins and int(p.free_top) == 10, \
        "pages leaked through the preempt/pin cycle"
