"""Paged KV pool: ring-equivalence, allocator correctness, kernel parity.

The acceptance property (ISSUE 4): the paged decode path — one shared
``(num_pages, page_size, G, hd)`` arena consumed through page-table
index maps — is **bit-identical** to the contiguous ring path on the
``s_out`` output grid, across every backend that serves the paged spec
(the ``ita_fused`` family invariant extended to the ``bhsd_paged``
layout). On top of that, the allocator itself is property-checked: no
physical page is ever double-booked, released pages return to the free
stack, and realloc reuses them without leaking state.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import attention as ATT
from repro.attention import KVCacheState, PagedKVState
from repro.kernels.common import MIN_BLOCK_KV
from repro.runtime import kv_cache as KV

rng = np.random.default_rng(0)

S_Q, S_OUT = np.float32(0.05), np.float32(0.02)


def _i8(*shape):
    return rng.integers(-128, 128, shape, dtype=np.int8)


def _paged_from_logical(k_log, v_log, page, *, shuffle_seed=1):
    """Scatter (B, C, G, hd) logical KV into a shuffled arena + table."""
    b, c, g, hd = k_log.shape
    npps = c // page
    total = b * npps + 1
    perm = np.random.default_rng(shuffle_seed).permutation(
        np.arange(1, total))
    pt = perm.reshape(b, npps).astype(np.int32)
    k_pool = np.zeros((total, page, g, hd), np.int8)
    v_pool = np.zeros((total, page, g, hd), np.int8)
    for bb in range(b):
        for j in range(npps):
            k_pool[pt[bb, j]] = k_log[bb, j * page:(j + 1) * page]
            v_pool[pt[bb, j]] = v_log[bb, j * page:(j + 1) * page]
    return k_pool, v_pool, pt


# ---------------------------------------------------------------------------
# Kernel parity: paged ≡ ring, every eligible backend
# ---------------------------------------------------------------------------

PARITY_SPECS = [
    # (hq, hkv, window, per_head) — causal, sliding-window, GQA and
    # per-head-scale decode specs, as in the ring parity sweep
    pytest.param(4, 4, 0, False, id="causal"),
    pytest.param(4, 4, 80, True, id="sliding-window+per-head"),
    pytest.param(4, 2, 0, True, id="gqa+per-head"),
    pytest.param(4, 2, 80, False, id="gqa+window"),
]


@pytest.mark.parametrize("hq,hkv,window,per_head", PARITY_SPECS)
def test_paged_parity_sweep_across_backends(hq, hkv, window, per_head):
    """Every backend eligible for the paged decode spec is bit-identical
    to the ring-buffer path at block_kv == page_size, mixed (ragged)
    valid prefixes included."""
    b, d, page, npps = 2, 32, 64, 3
    cap = page * npps
    q = _i8(b, hq, 1, d)
    k_log = _i8(b, cap, hkv, d)
    v_log = _i8(b, cap, hkv, d)
    if per_head:
        sk = jnp.asarray(rng.uniform(0.03, 0.07, (hkv,)).astype(np.float32))
        sv = jnp.asarray(rng.uniform(0.03, 0.07, (hkv,)).astype(np.float32))
    else:
        sk = sv = jnp.asarray(np.float32(0.04))
    scales = ATT.QuantScales(S_Q, sk, sv, S_OUT)
    kv_lens = jnp.asarray([150, cap])              # row 1 fully wrapped
    offs = kv_lens - 1

    ring_spec = ATT.AttentionSpec(
        mode="decode", impl="ita", window=window, layout="bhsd_bsgd",
        scale_kind="per_head" if per_head else "per_tensor",
        out_dtype="int8", q_len=1)
    ring = ATT.dispatch(jnp.asarray(q), jnp.asarray(k_log),
                        jnp.asarray(v_log), spec=ring_spec, scales=scales,
                        q_offset=offs, kv_len=kv_lens,
                        backend="ita_decode_pallas", block_kv=page)

    k_pool, v_pool, pt = _paged_from_logical(k_log, v_log, page)
    spec = ring_spec.replace(layout="bhsd_paged")
    eligible = ATT.list_backends(spec)
    assert len(eligible) >= 2, eligible            # a sweep, not a singleton
    assert {ATT.get_backend(n).family for n in eligible} == {"ita_fused"}
    for name in eligible:
        out = ATT.dispatch(jnp.asarray(q), jnp.asarray(k_pool),
                           jnp.asarray(v_pool), spec=spec, scales=scales,
                           q_offset=offs, kv_len=kv_lens,
                           page_table=jnp.asarray(pt), backend=name)
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(ring),
            err_msg=f"{name} (paged) != ring path for {spec}")


def test_paged_layout_capability_matrix():
    """bhsd_paged is served by exactly the fused decode/onepass kernels;
    everything else declines with a reason, and dispatch enforces the
    page_table handshake."""
    spec = ATT.AttentionSpec(mode="decode", impl="ita", layout="bhsd_paged",
                             out_dtype="int8", q_len=1)
    assert ATT.list_backends(spec) == ["ita_decode_pallas",
                                       "ita_onepass_pallas"]
    for name, verdict in ATT.backend_reasons(spec).items():
        if name not in ("ita_decode_pallas", "ita_onepass_pallas"):
            assert isinstance(verdict, str) and verdict, name
    q = jnp.asarray(_i8(1, 2, 1, 32))
    pool = jnp.asarray(_i8(3, 64, 2, 32))
    sc = ATT.QuantScales.per_tensor(S_Q, s_out=S_OUT)
    with pytest.raises(ValueError, match="page_table"):
        ATT.dispatch(q, pool, pool, spec=spec, scales=sc)
    with pytest.raises(ValueError, match="page_table"):
        ATT.dispatch(q, q, q, spec=spec.replace(layout="bhsd"), scales=sc,
                     page_table=jnp.zeros((1, 1), jnp.int32))


# ---------------------------------------------------------------------------
# State: logical ring equivalence + allocator properties
# ---------------------------------------------------------------------------

def _logical_view(p: PagedKVState):
    pt = np.asarray(p.page_table)
    g, hd = p.k.shape[2], p.k.shape[3]
    return np.asarray(p.k)[pt].reshape(p.batch, p.capacity, g, hd)


def test_paged_state_matches_ring_through_wrap():
    """Ragged prefill + appends past the wrap: the pool's logical view
    (pages gathered through the table) equals the ring byte-for-byte on
    every valid slot, and pos/valid_len/q_offset agree."""
    b, g, hd, page, cap = 3, 2, 4, 8, 32
    toks = _i8(b, 40, g, hd)
    lens = jnp.asarray([5, 12, 9], jnp.int32)
    ring = KVCacheState.init(b, cap, g, hd).prefill_write(
        jnp.asarray(toks[:, :12]), jnp.asarray(toks[:, :12]), lengths=lens)
    paged = PagedKVState.init(b, cap, g, hd, page_size=page).prefill_write(
        jnp.asarray(toks[:, :12]), jnp.asarray(toks[:, :12]), lengths=lens)
    # lazy allocation: a 5-token row holds 1 page, not the full window
    np.testing.assert_array_equal(np.asarray(paged.pages_held()), [1, 2, 2])

    for t in range(12, 40):
        ring = ring.decode_append(jnp.asarray(toks[:, t:t + 1]),
                                  jnp.asarray(toks[:, t:t + 1]))
        paged = paged.decode_append(jnp.asarray(toks[:, t:t + 1]),
                                    jnp.asarray(toks[:, t:t + 1]))
    np.testing.assert_array_equal(np.asarray(ring.pos),
                                  np.asarray(paged.pos))
    np.testing.assert_array_equal(np.asarray(ring.valid_len()),
                                  np.asarray(paged.valid_len()))
    np.testing.assert_array_equal(np.asarray(ring.q_offset(1)),
                                  np.asarray(paged.q_offset(1)))
    lv, rv = _logical_view(paged), np.asarray(ring.k)
    for row in range(b):
        n, pos = int(ring.valid_len()[row]), int(ring.pos[row])
        for t in range(pos - n, pos):
            np.testing.assert_array_equal(
                lv[row, t % cap], rv[row, t % cap],
                err_msg=f"row {row} token {t}")


def _partition_ok(p: PagedKVState):
    """Invariant: {parking} ∪ free stack ∪ held pages partition the
    arena — no double-booking, no leaks."""
    pt = np.asarray(p.page_table)
    held_counts = np.asarray(p.pages_held())
    held = []
    for row in range(p.batch):
        held.extend(pt[row, :held_counts[row]].tolist())
    free = np.asarray(p.free_stack)[:int(p.free_top)].tolist()
    if len(set(held)) != len(held):                # a page in two rows
        return False
    if set(held) & set(free):                      # held page marked free
        return False
    if 0 in held or 0 in free:                     # parking page leaked
        return False
    return set(held) | set(free) | {0} == set(range(p.num_pages))


def test_page_free_and_realloc_reuse():
    """Released pages return to the stack and are handed out again; the
    re-admitted row's bytes are exactly the new prompt (no stale state
    from the page's previous owner)."""
    b, g, hd, page, cap = 2, 2, 4, 8, 16
    p = PagedKVState.init(b, cap, g, hd, page_size=page)
    total_free = int(p.free_top)
    a = _i8(b, 12, g, hd)
    p = p.prefill_write(jnp.asarray(a), jnp.asarray(a))
    assert int(p.free_top) == total_free - 4
    assert _partition_ok(p)

    p = p.release(jnp.asarray([True, False]))
    assert int(p.free_top) == total_free - 2
    assert int(p.pos[0]) == 0 and int(p.pos[1]) == 12
    assert _partition_ok(p)

    # re-admit row 0 with a fresh prompt into the recycled pages
    fresh = _i8(1, 9, g, hd)
    p = p.write_prompts(jnp.asarray(fresh), jnp.asarray(fresh),
                        lengths=jnp.asarray([9]),
                        slots=jnp.asarray([0]))
    assert int(p.pos[0]) == 9 and _partition_ok(p)
    np.testing.assert_array_equal(_logical_view(p)[0, :9], fresh[0])
    # row 1 untouched by the realloc
    np.testing.assert_array_equal(_logical_view(p)[1, :12], a[1])


def test_allocator_partition_property_seeded():
    """Seeded property test: a random interleaving of admissions (into
    released rows), appends (with random live masks) and releases never
    double-books a page — the partition invariant holds at every step."""
    b, g, hd, page, cap = 4, 1, 4, 4, 16
    prng = np.random.default_rng(7)
    p = PagedKVState.init(b, cap, g, hd, page_size=page,
                          num_pages=b * (cap // page) + 1)
    active = np.zeros(b, bool)
    for op in range(120):
        kind = prng.integers(0, 3)
        if kind == 0:                              # admit into a free row
            free = np.flatnonzero(~active)
            if free.size:
                row = int(prng.choice(free))
                ln = int(prng.integers(1, cap + 1))
                tok = _i8(1, ln, g, hd)
                p = p.write_prompts(jnp.asarray(tok), jnp.asarray(tok),
                                    lengths=jnp.asarray([ln]),
                                    slots=jnp.asarray([row]))
                active[row] = True
        elif kind == 1 and active.any():           # masked decode append
            live = active & (prng.random(b) < 0.8)
            tok = _i8(b, 1, g, hd)
            p = p.decode_append(jnp.asarray(tok), jnp.asarray(tok),
                                live=jnp.asarray(live))
        elif kind == 2 and active.any():           # release some rows
            fin = active & (prng.random(b) < 0.4)
            if fin.any():
                p = p.release(jnp.asarray(fin))
                active &= ~fin
        assert not bool(p.oversubscribed()), f"op {op}: pool overdrawn"
        assert _partition_ok(p), f"op {op}: partition violated"


def test_burst_and_overlong_append_match_ring():
    """Multi-token bursts (page-crossing, ring-wrapping, over-capacity)
    keep the paged pool's logical bytes equal to the ring's."""
    b, g, hd, page, cap = 1, 2, 4, 8, 16
    toks = _i8(b, 41, g, hd)
    ring = KVCacheState.init(b, cap, g, hd).prefill_write(
        jnp.asarray(toks[:, :15]), jnp.asarray(toks[:, :15]))
    paged = PagedKVState.init(b, cap, g, hd, page_size=page).prefill_write(
        jnp.asarray(toks[:, :15]), jnp.asarray(toks[:, :15]))
    for lo, hi in ((15, 19), (19, 21), (21, 41)):  # wraps; last > capacity
        ring = ring.decode_append(jnp.asarray(toks[:, lo:hi]),
                                  jnp.asarray(toks[:, lo:hi]))
        paged = paged.decode_append(jnp.asarray(toks[:, lo:hi]),
                                    jnp.asarray(toks[:, lo:hi]))
        np.testing.assert_array_equal(np.asarray(ring.pos),
                                      np.asarray(paged.pos))
        lv, rv = _logical_view(paged), np.asarray(ring.k)
        pos, n = int(ring.pos[0]), int(ring.valid_len()[0])
        for t in range(pos - n, pos):
            np.testing.assert_array_equal(lv[0, t % cap], rv[0, t % cap],
                                          err_msg=f"token {t} after "
                                                  f"burst [{lo},{hi})")


def test_paged_state_is_pytree_and_jit_safe():
    p = PagedKVState.init(2, 16, 2, 4, page_size=8, per_head_scales=True)
    leaves = jax.tree.leaves(p)
    assert len(leaves) == 8
    shp = jax.eval_shape(lambda: PagedKVState.init(2, 16, 2, 4, page_size=8))
    assert isinstance(shp, PagedKVState) and shp.k_scale is None

    @jax.jit
    def step(c, t):
        return c.decode_append(t, t)

    out = step(p, jnp.ones((2, 1, 2, 4), jnp.int8))
    assert isinstance(out, PagedKVState)
    np.testing.assert_array_equal(np.asarray(out.pos), [1, 1])
    np.testing.assert_array_equal(np.asarray(out.pages_held()), [1, 1])


# ---------------------------------------------------------------------------
# Engine level: decode_attend over a paged cache
# ---------------------------------------------------------------------------

def test_paged_decode_attend_matches_ring_engine():
    """The float-in/int8-out engine path over a paged cache is
    bit-identical to the ring cache engine at block_kv == page_size."""
    b, hq, hkv, d, page, cap = 2, 4, 2, 32, 64, 128
    s, prefill = cap, 96
    qf = rng.normal(0, 1, (b, hq, s, d)).astype(np.float32)
    kf = rng.normal(0, 1, (b, s, hkv, d)).astype(np.float32)
    vf = rng.normal(0, 1, (b, s, hkv, d)).astype(np.float32)
    q8 = KV.quantize_with_scale(jnp.asarray(qf), S_Q)

    ring = KV.init_cache(b, cap, hkv, d, per_head_scales=True)
    paged = KV.init_paged_cache(b, cap, hkv, d, per_head_scales=True,
                                page_size=page)
    _, ring = KV.prefill_attend(ring, q8[:, :, :prefill],
                                jnp.asarray(kf[:, :prefill]),
                                jnp.asarray(vf[:, :prefill]), S_Q, S_OUT,
                                block_q=32, block_kv=page)
    _, paged = KV.prefill_attend(paged, q8[:, :, :prefill],
                                 jnp.asarray(kf[:, :prefill]),
                                 jnp.asarray(vf[:, :prefill]), S_Q, S_OUT,
                                 block_q=32, block_kv=page)
    for t in range(prefill, s):
        o_r, ring = KV.decode_attend(ring, q8[:, :, t:t + 1],
                                     jnp.asarray(kf[:, t:t + 1]),
                                     jnp.asarray(vf[:, t:t + 1]),
                                     S_Q, S_OUT, block_kv=page)
        o_p, paged = KV.decode_attend(paged, q8[:, :, t:t + 1],
                                      jnp.asarray(kf[:, t:t + 1]),
                                      jnp.asarray(vf[:, t:t + 1]),
                                      S_Q, S_OUT, block_kv=page)
        np.testing.assert_array_equal(np.asarray(o_r), np.asarray(o_p),
                                      err_msg=f"decode step t={t}")


# ---------------------------------------------------------------------------
# Satellite: ring block-alignment kills the decode pad-copy
# ---------------------------------------------------------------------------

def test_ring_capacity_block_aligned_at_init():
    assert KVCacheState.init(1, 144, 2, 4).capacity == MIN_BLOCK_KV * 2
    assert KVCacheState.init(1, 128, 2, 4).capacity == 128
    assert KVCacheState.init(1, 96, 2, 4).capacity == 96   # <= one block
    p = PagedKVState.init(1, 144, 2, 4, page_size=64)
    assert p.capacity == 192                               # page multiple


def test_decode_pad_copy_statically_forbidden():
    """A decode dispatch over a non-block-multiple ring above one block
    raises instead of silently pad-copying the ring every step."""
    b, h, d, cap = 1, 2, 32, 192
    q = jnp.asarray(_i8(b, h, 1, d))
    kv = jnp.asarray(_i8(b, h, cap, d))
    spec = ATT.AttentionSpec(mode="decode", impl="ita", layout="bhsd",
                             out_dtype="int8", q_len=1)
    sc = ATT.QuantScales.per_tensor(S_Q, s_out=S_OUT)
    with pytest.raises(ValueError, match="block_kv"):
        ATT.dispatch(q, kv, kv, spec=spec, scales=sc, q_offset=cap - 1,
                     kv_len=cap, backend="ita_decode_pallas", block_kv=80)
    # block-multiple capacities dispatch fine (the init-aligned case)
    out = ATT.dispatch(q, kv, kv, spec=spec, scales=sc, q_offset=cap - 1,
                       kv_len=cap, backend="ita_decode_pallas", block_kv=64)
    assert out.shape == (b, h, 1, d)


def test_block_defaults_recorded():
    from repro.kernels.common import (BLOCK_DEFAULTS, default_blocks,
                                      default_matmul_blocks)
    for name in ("ita_onepass_pallas", "ita_twopass_pallas",
                 "ita_decode_pallas"):
        assert name in BLOCK_DEFAULTS
        bq, bkv = default_blocks(name)
        assert bkv in (64, 128, 256)
    assert default_blocks("ita_decode_pallas")[0] is None  # no q tiling
    # the matmul entry is 3-wide and fenced off from default_blocks()
    assert len(default_matmul_blocks()) == 3
    with pytest.raises(AssertionError, match="default_matmul_blocks"):
        default_blocks("int8_matmul")


# ---------------------------------------------------------------------------
# Chunked prefill: append_chunk + ragged q_len mixed calls (ISSUE 5)
# ---------------------------------------------------------------------------

def test_append_chunk_equals_sequential_appends():
    """A ragged ``append_chunk`` (per-row n_new, one dispatch) is
    state-identical to applying the same tokens as single-token
    ``decode_append`` steps with live masks: same bytes, same pos, same
    pages held, allocator partition intact — including rows whose chunk
    crosses a page boundary and dead rows (n_new = 0)."""
    b, g, hd, page, cap = 3, 2, 4, 8, 32
    base = PagedKVState.init(b, cap, g, hd, page_size=page)
    pre = _i8(b, 6, g, hd)
    base = base.prefill_write(jnp.asarray(pre), jnp.asarray(pre),
                              lengths=jnp.asarray([6, 3, 0]))
    s = 12
    toks = _i8(b, s, g, hd)
    n_new = np.asarray([1, 12, 0], np.int32)       # decode / chunk / dead

    chunked = base.append_chunk(jnp.asarray(toks), jnp.asarray(toks),
                                jnp.asarray(n_new))
    ref = base
    for t in range(s):
        live = jnp.asarray(t < n_new)
        ref = ref.decode_append(jnp.asarray(toks[:, t:t + 1]),
                                jnp.asarray(toks[:, t:t + 1]), live=live)
    np.testing.assert_array_equal(np.asarray(chunked.pos),
                                  np.asarray(ref.pos))
    np.testing.assert_array_equal(np.asarray(chunked.pages_held()),
                                  np.asarray(ref.pages_held()))
    assert _partition_ok(chunked)
    lv_c, lv_r = _logical_view(chunked), _logical_view(ref)
    for row in range(b):
        n = int(chunked.valid_len()[row])
        pos = int(chunked.pos[row])
        for t in range(pos - n, pos):
            np.testing.assert_array_equal(
                lv_c[row, t % cap], lv_r[row, t % cap],
                err_msg=f"row {row} token {t}")
    with pytest.raises(ValueError, match="append_chunk width"):
        wide = _i8(b, cap + 1, g, hd)
        base.append_chunk(jnp.asarray(wide), jnp.asarray(wide),
                          jnp.asarray([1, 1, 1]))


def test_ragged_qlens_mixed_call_matches_pure_paths():
    """One ragged-q paged call carrying a decode row (q_len 1), a prefill
    chunk row (q_len = chunk) and a dead row (q_len 0) matches the pure
    decode kernel / one-shot onepass on the same streams; the dead row
    emits zeros."""
    b, g, hq, hd, page, npages = 3, 2, 4, 16, 32, 16
    scales = ATT.QuantScales.per_tensor(S_Q, s_out=S_OUT)
    pool = PagedKVState.init(b, 128, g, hd, page_size=page,
                             num_pages=npages)
    pre = _i8(b, 40, g, hd)
    pool = pool.prefill_write(jnp.asarray(pre), jnp.asarray(pre),
                              lengths=jnp.asarray([40, 17, 0]))
    chunk = 12
    kc = _i8(b, chunk, g, hd)
    n_new = jnp.asarray([1, chunk, 0])
    pool2 = pool.append_chunk(jnp.asarray(kc), jnp.asarray(kc), n_new)

    q = _i8(b, hq, chunk, hd)
    spec = ATT.AttentionSpec(mode="decode", impl="ita", layout="bhsd_paged",
                             out_dtype="int8", q_len=chunk, ragged_q=True)
    assert ATT.list_backends(spec) == ["ita_onepass_pallas"]
    out = ATT.dispatch(jnp.asarray(q), pool2.k, pool2.v, spec=spec,
                       scales=scales, q_offset=pool2.q_offset(n_new),
                       kv_len=pool2.valid_len(),
                       page_table=pool2.page_table, q_lens=n_new)

    # row 0 (decode): equals the single-query decode kernel on the pool
    dec_spec = spec.replace(q_len=1, ragged_q=False)
    dec = ATT.dispatch(jnp.asarray(q[:, :, :1]), pool2.k, pool2.v,
                       spec=dec_spec, scales=scales,
                       q_offset=pool2.q_offset(1), kv_len=pool2.valid_len(),
                       page_table=pool2.page_table,
                       backend="ita_decode_pallas")
    np.testing.assert_array_equal(np.asarray(out[0, :, 0]),
                                  np.asarray(dec[0, :, 0]))
    # row 2 (dead, q_len 0): all-zero output
    assert not np.asarray(out[2]).any()
    # row 1 (chunk): equals a one-shot onepass over the same stream
    full = np.concatenate([pre[1:2, :17], kc[1:2]], axis=1)
    solo = PagedKVState.init(1, 128, g, hd, page_size=page,
                             num_pages=npages)
    solo = solo.prefill_write(jnp.asarray(full), jnp.asarray(full))
    one_spec = spec.replace(ragged_q=False)
    one = ATT.dispatch(jnp.asarray(q[1:2]), solo.k, solo.v, spec=one_spec,
                       scales=scales, q_offset=17, kv_len=solo.valid_len(),
                       page_table=solo.page_table,
                       backend="ita_onepass_pallas")
    np.testing.assert_array_equal(np.asarray(out[1]), np.asarray(one[0]))

    # dispatch handshake: q_lens required by exactly ragged_q specs
    with pytest.raises(ValueError, match="q_lens"):
        ATT.dispatch(jnp.asarray(q), pool2.k, pool2.v, spec=spec,
                     scales=scales, q_offset=pool2.q_offset(n_new),
                     kv_len=pool2.valid_len(), page_table=pool2.page_table)
    with pytest.raises(ValueError, match="q_lens"):
        ATT.dispatch(jnp.asarray(q), pool2.k, pool2.v, spec=one_spec,
                     scales=scales, q_offset=pool2.q_offset(n_new),
                     kv_len=pool2.valid_len(), page_table=pool2.page_table,
                     q_lens=n_new)


def test_ragged_q_capability_verdicts():
    """ragged_q is a capability of exactly the fused one-pass kernels:
    everything else declines with a reason, on serve specs it could
    otherwise run."""
    base = ATT.AttentionSpec(mode="decode", impl="ita", layout="bhsd_paged",
                             out_dtype="int8", q_len=16)
    assert ATT.list_backends(base.replace(ragged_q=True)) == \
        ["ita_onepass_pallas"]
    for impl, layout in (("ita", "bshd"), ("ibert", "bshd")):
        spec = ATT.AttentionSpec(mode="decode", impl=impl, layout=layout,
                                 q_len=4, ragged_q=True)
        for name, verdict in ATT.backend_reasons(spec).items():
            if name != "ita_onepass_pallas":
                assert verdict is not True, (name, impl)
