"""Quantization / requantization unit + property tests."""

import jax
import jax.numpy as jnp
import numpy as np
from compat_hypothesis import given, settings, st

from repro.core import quant as Q


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.floats(0.05, 50.0))
def test_quant_roundtrip_error_bounded(seed, spread):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, spread, (64,)).astype(np.float32))
    qt = Q.quantize_tensor(x)
    err = jnp.abs(qt.dequantize() - x)
    # in-range values: error <= scale/2; clipped values can exceed
    in_range = jnp.abs(x) <= qt.scale * 127
    assert float(jnp.max(jnp.where(in_range, err, 0))) <= float(qt.scale) / 2 + 1e-6


def test_requant_matches_fixed_point_oracle():
    """TPU f32-multiply requant vs the ASIC fixed-point multiplier+shift:
    agree within 1 LSB (ties can round differently)."""
    rng = np.random.default_rng(1)
    acc = rng.integers(-2 ** 23, 2 ** 23, (4096,), dtype=np.int32)
    for ratio in (0.00037, 0.0121, 0.49, 0.97):
        a = np.asarray(Q.requantize(jnp.asarray(acc), ratio)).astype(np.int32)
        b = Q.requantize_fixedpoint_np(acc, ratio).astype(np.int32)
        assert np.max(np.abs(a - b)) <= 1
        assert (a != b).mean() < 0.02


def test_quantize_multiplier_decomposition():
    for r in (1e-4, 0.3, 0.999, 1.7):
        m, shift = Q.quantize_multiplier(r)
        assert 2 ** 30 <= m < 2 ** 31
        np.testing.assert_allclose(m * 2.0 ** -shift, r, rtol=1e-8)


def test_fake_quant_ste():
    x = jnp.asarray([-10.0, -0.2, 0.0, 0.3, 10.0])
    scale = jnp.asarray(0.05)  # clip at +-6.35
    y = Q.fake_quant(x, scale)
    np.testing.assert_allclose(np.asarray(y),
                               [-6.4, -0.2, 0.0, 0.3, 6.35], atol=1e-6)
    g = jax.grad(lambda v: Q.fake_quant(v, scale).sum())(x)
    np.testing.assert_array_equal(np.asarray(g), [0, 1, 1, 1, 0])


def test_int8_matmul_ref_bias_semantics():
    rng = np.random.default_rng(2)
    x = rng.integers(-128, 128, (8, 16), dtype=np.int8)
    w = rng.integers(-128, 128, (16, 4), dtype=np.int8)
    b = rng.integers(-100, 100, (4,), dtype=np.int32)
    acc = Q.int8_matmul_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b))
    ref = x.astype(np.int32) @ w.astype(np.int32) + b
    np.testing.assert_array_equal(np.asarray(acc), ref)


def test_quantized_linear_end_to_end():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(0, 1, (32, 64)).astype(np.float32))
    w = jnp.asarray(rng.normal(0, 0.05, (64, 32)).astype(np.float32))
    wq = Q.quantize_tensor(w)
    out, acc = Q.quantized_linear(x, wq)
    y_ref = np.asarray(x) @ np.asarray(w)
    y_hat = np.asarray(out.dequantize())
    rel = np.abs(y_hat - y_ref).mean() / (np.abs(y_ref).mean() + 1e-9)
    assert rel < 0.05
