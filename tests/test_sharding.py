"""Sharding rules + multi-axis lower/compile smoke (the dry-run proper
runs via repro.launch.dryrun on 512 host devices; here: a tiny mesh)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig
from repro.configs.registry import ARCH_IDS, get_config
from repro.launch import sharding as SH
from repro.launch.steps import input_specs, lower_cell, params_shape

N_DEV = len(jax.devices())


def _mesh():
    if N_DEV >= 8:
        return jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    return jax.make_mesh((1, 1, 1), ("pod", "data", "model"))


def test_param_specs_divisibility():
    """Every assigned spec must divide the dim it shards."""
    mesh = _mesh()
    for arch in ARCH_IDS:
        cfg = get_config(arch, smoke=True)
        ps = params_shape(cfg)
        shardings = SH.param_shardings(ps, mesh)

        def check(leaf, sh):
            spec = sh.spec
            for dim, ax in zip(leaf.shape, spec, strict=False):
                if ax is None:
                    continue
                axes = (ax,) if isinstance(ax, str) else ax
                size = int(np.prod([mesh.shape[a] for a in axes]))
                assert dim % size == 0, (arch, leaf.shape, spec)

        jax.tree.map(check, ps, shardings)


@pytest.mark.skipif(N_DEV < 8, reason="needs 8 host devices")
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_lower_compile_all_kinds(arch):
    mesh = _mesh()
    cfg = get_config(arch, smoke=True)
    for shape in (ShapeConfig("t", 64, 8, "train"),
                  ShapeConfig("p", 64, 8, "prefill"),
                  ShapeConfig("d", 64, 8, "decode"),
                  ShapeConfig("d1", 128, 1, "decode")):
        lower_cell(cfg, shape, mesh).compile()


def test_hints_noop_without_mesh():
    from repro.launch.hints import constrain, heads_shardable
    x = jnp.ones((4, 4))
    assert constrain(x, "batch", None) is x
    assert not heads_shardable(8)


def test_input_specs_shapes():
    cfg = get_config("qwen2-7b")
    from repro.configs.base import SHAPES
    sp = input_specs(cfg, SHAPES["train_4k"])
    assert sp["batch"]["tokens"].shape == (256, 4097)
    sp = input_specs(cfg, SHAPES["decode_32k"])
    assert sp["tokens"].shape == (128, 1)
    # KV cache leaves sized to the 32k context
    kv = [l for l in jax.tree.leaves(sp["caches"]) if l.ndim == 5]
    assert all(l.shape[2] == 32768 for l in kv)
