"""Fused on-device generation loop: parity, EOS semantics, ragged decode.

The acceptance property: ``generate(loop="fused")`` — one jitted
``lax.scan`` dispatch for all decode steps, on-device sampling — is
**bit-identical** to ``loop="stepwise"`` (the legacy one-dispatch-per-
token host loop), greedy and seeded-temperature, across causal /
sliding-window / GQA configs; the ``while_loop`` EOS early-exit variant
matches the scan; and ragged batches (per-sequence prompt lengths)
decode through the same loop with per-row positions.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import forward, init_caches, init_model
from repro.runtime.generate import generate

KEY = jax.random.PRNGKey(0)

CFG = ModelConfig(name="genloop-smoke", family="dense", d_model=64,
                  n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=128, layer_groups=((("attn",), 2),),
                  dtype="float32", attention_impl="ita")
CFG_SWA = dataclasses.replace(CFG, name="genloop-swa", window=8,
                              layer_groups=((("swa",), 2),))
B, PROMPT, GEN = 2, 12, 8


def _prompts(b=B, s=PROMPT, vocab=CFG.vocab_size):
    return jax.random.randint(KEY, (b, s), 0, vocab)


def _gen(cfg, loop, **kw):
    return generate(init_model(KEY, cfg), cfg, _prompts(), GEN, loop=loop,
                    max_len=PROMPT + GEN, **kw)


@pytest.mark.parametrize("cfg", [CFG, CFG_SWA],
                         ids=["causal_gqa", "sliding_window"])
def test_fused_scan_bit_identical_to_stepwise_greedy(cfg):
    a = _gen(cfg, "fused")
    b = _gen(cfg, "stepwise")
    np.testing.assert_array_equal(np.asarray(a.tokens), np.asarray(b.tokens))
    assert a.n_decode_tokens == b.n_decode_tokens == B * (GEN - 1)
    assert a.decode_steps == b.decode_steps == GEN - 1


def test_fused_scan_bit_identical_to_stepwise_sampled():
    """Seeded temperature sampling: the scan threads the PRNG through the
    carry with the exact split schedule of the host loop."""
    key = jax.random.PRNGKey(7)
    a = _gen(CFG, "fused", temperature=0.8, key=key)
    b = _gen(CFG, "stepwise", temperature=0.8, key=key)
    np.testing.assert_array_equal(np.asarray(a.tokens), np.asarray(b.tokens))
    # a different seed actually changes the draw (sampling is live)
    c = _gen(CFG, "fused", temperature=0.8, key=jax.random.PRNGKey(8))
    assert not np.array_equal(np.asarray(a.tokens), np.asarray(c.tokens))


def test_eos_masking_and_live_token_accounting():
    """Post-EOS positions are pad; n_decode_tokens counts only live
    sequences (the honest decode_tok_s denominator); fused == stepwise."""
    base = _gen(CFG, "fused")
    eos = int(base.tokens[0, 2])               # row 0 emits this by step 2
    pad = CFG.vocab_size - 1                   # distinguishable from eos
    a = _gen(CFG, "fused", eos_id=eos, pad_id=pad)
    b = _gen(CFG, "stepwise", eos_id=eos, pad_id=pad)
    np.testing.assert_array_equal(np.asarray(a.tokens), np.asarray(b.tokens))
    assert a.n_decode_tokens == b.n_decode_tokens

    toks = np.asarray(a.tokens)
    expected_live = 0
    for row in toks:
        hits = np.flatnonzero(row == eos)
        end = hits[0] if hits.size else GEN - 1
        assert np.all(row[end + 1:] == pad), row   # pads after first EOS
        # decode step i is live iff no EOS among outputs 0..i
        expected_live += int(np.sum([not np.any(row[:i + 1] == eos)
                                     for i in range(GEN - 1)]))
    assert a.n_decode_tokens == expected_live
    assert a.n_decode_tokens < B * (GEN - 1)       # row 0 finished early
    assert a.decode_tok_s == a.n_decode_tokens / max(a.decode_s, 1e-9)


def test_while_loop_early_exit_matches_scan():
    base = _gen(CFG, "fused")
    eos = int(base.tokens[0, 2])
    a = _gen(CFG, "fused", eos_id=eos)
    b = _gen(CFG, "fused", eos_id=eos, early_exit=True)
    np.testing.assert_array_equal(np.asarray(a.tokens), np.asarray(b.tokens))
    assert a.n_decode_tokens == b.n_decode_tokens
    # stepwise honors early_exit too (host check per step), same outputs
    c = _gen(CFG, "stepwise", eos_id=eos, early_exit=True)
    np.testing.assert_array_equal(np.asarray(a.tokens), np.asarray(c.tokens))
    assert a.n_decode_tokens == c.n_decode_tokens
    # decode_steps reports steps actually run, identically for both
    assert b.decode_steps == c.decode_steps <= GEN - 1
    with pytest.raises(ValueError, match="early_exit"):
        _gen(CFG, "fused", early_exit=True)        # needs an eos_id


def test_reused_caches_validated():
    """A reused caches= arg must match this call's batch/max_len —
    silently decoding into wrong-size rings was the PR-3 hardening bug."""
    params = init_model(KEY, CFG)
    prompts = _prompts()
    good = init_caches(CFG, B, max_len=PROMPT + GEN)
    res = generate(params, CFG, prompts, GEN, max_len=PROMPT + GEN,
                   caches=good)
    assert res.tokens.shape == (B, GEN)
    with pytest.raises(ValueError, match="max_len"):
        generate(params, CFG, prompts, GEN, max_len=PROMPT + GEN,
                 caches=init_caches(CFG, B, max_len=PROMPT + GEN + 4))
    with pytest.raises(ValueError, match="max_len"):
        generate(params, CFG, prompts, GEN, max_len=PROMPT + GEN,
                 caches=init_caches(CFG, B + 1, max_len=PROMPT + GEN))


def test_ragged_prefill_matches_unpadded_forward():
    """Ragged prefill of a right-padded batch: every sequence's
    next-token logits and first decode step match running it unpadded."""
    cfg = CFG
    params = init_model(KEY, cfg)
    b, pad = 3, PROMPT
    lens = [5, 12, 9]
    tokens = _prompts(b, pad + 1)
    caches = init_caches(cfg, b, max_len=pad + 4)
    lengths = jnp.asarray(lens, jnp.int32)
    lp, caches, _ = forward(params, tokens[:, :pad], cfg, mode="prefill",
                            caches=caches, lengths=lengths)
    # decode one step at per-sequence positions
    nxt = jnp.take_along_axis(tokens, lengths[:, None], axis=1)
    ld, _, _ = forward(params, nxt, cfg, mode="decode", caches=caches,
                       pos0=lengths)

    for row, ln in enumerate(lens):
        solo = init_caches(cfg, 1, max_len=pad + 4)
        lp1, solo, _ = forward(params, tokens[row:row + 1, :ln], cfg,
                               mode="prefill", caches=solo)
        np.testing.assert_allclose(np.asarray(lp[row, ln - 1]),
                                   np.asarray(lp1[0, -1]), atol=2e-3,
                                   err_msg=f"prefill row {row}")
        ld1, _, _ = forward(params, tokens[row:row + 1, ln:ln + 1], cfg,
                            mode="decode", caches=solo, pos0=ln)
        np.testing.assert_allclose(np.asarray(ld[row, 0]),
                                   np.asarray(ld1[0, 0]), atol=2e-3,
                                   err_msg=f"decode row {row}")


def test_ragged_generate_fused_matches_stepwise():
    """Mixed prompt lengths through generate(): fused == stepwise
    bit-for-bit, and the loop runs at per-sequence positions (wrap-free
    sanity via valid token ids)."""
    params = init_model(KEY, CFG)
    prompts = _prompts(3, PROMPT)
    lens = jnp.asarray([5, 12, 9], jnp.int32)
    a = generate(params, CFG, prompts, GEN, prompt_lengths=lens,
                 max_len=PROMPT + GEN)
    b = generate(params, CFG, prompts, GEN, prompt_lengths=lens,
                 max_len=PROMPT + GEN, loop="stepwise")
    np.testing.assert_array_equal(np.asarray(a.tokens), np.asarray(b.tokens))
    assert bool(jnp.all((a.tokens >= 0) & (a.tokens < CFG.vocab_size)))
    with pytest.raises(ValueError, match="prompt_lengths"):
        generate(params, CFG, prompts, GEN,
                 prompt_lengths=jnp.asarray([0, 12, 9]))
