"""Fault tolerance, checkpointing, data pipeline, optimizer, compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointing import Checkpointer
from repro.configs.registry import get_config
from repro.data.pipeline import DataPipeline, SyntheticSource
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models import init_model
from repro.optim.optimizer import (AdamWConfig, adamw_update,
                                   clip_by_global_norm, init_opt_state)
from repro.runtime.compression import (ef_compress, ef_decompress,
                                       init_ef_state)
from repro.runtime.fault_tolerance import FTConfig, TrainDriver

KEY = jax.random.PRNGKey(0)


def _setup(tmp, arch="phi3-mini-3.8b", steps_cfg=None):
    cfg = get_config(arch, smoke=True)
    mesh = make_host_mesh()
    params = init_model(KEY, cfg)
    opt_cfg = steps_cfg or AdamWConfig(lr=1e-2, total_steps=100,
                                       warmup_steps=2)
    opt_state = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    pipe = DataPipeline(SyntheticSource(cfg.vocab_size), batch=2,
                        seq_len=16, mesh=mesh)
    return cfg, mesh, params, opt_state, step, pipe


def test_training_reduces_loss(tmp_path):
    cfg, mesh, params, opt_state, step, pipe = _setup(tmp_path)
    losses = []
    for _ in range(8):
        batch = pipe.next()
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    # synthetic random tokens -> per-batch loss is noisy; the model can
    # still learn the (uniform) marginal, so compare window means, not
    # endpoints (endpoint compare was flaky at the seed).
    assert np.mean(losses[-3:]) < np.mean(losses[:3]) - 0.05, losses
    assert np.isfinite(losses).all()


@pytest.mark.slow
def test_checkpoint_resume_bitwise_identical(tmp_path):
    """6 straight steps == 3 steps + checkpoint + restore + 3 steps."""
    def run(n, ckdir, restore=False):
        cfg, mesh, params, opt_state, step, pipe = _setup(tmp_path)
        drv = TrainDriver(FTConfig(ckpt_dir=str(tmp_path / ckdir),
                                   ckpt_every=3, keep=2),
                          step, params, opt_state, pipe)
        if restore:
            assert drv.maybe_restore()
            assert drv.step == 3
        drv.run(n, log_every=0)
        return drv.params

    p6 = run(6, "ck_straight")
    run(3, "ck_resume")             # writes ckpt at step 3
    p_resumed = run(6, "ck_resume", restore=True)
    for a, b in zip(jax.tree.leaves(p6), jax.tree.leaves(p_resumed),
                    strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_failure_injection_and_restart(tmp_path):
    cfg, mesh, params, opt_state, step, pipe = _setup(tmp_path)
    ft = FTConfig(ckpt_dir=str(tmp_path / "ck2"), ckpt_every=2,
                  inject_failure_at=5)
    drv = TrainDriver(ft, step, params, opt_state, pipe)
    with pytest.raises(RuntimeError, match="injected node failure"):
        drv.run(10, log_every=0)
    # restart from the last checkpoint (step 4) and finish
    cfg, mesh, params, opt_state, step, pipe = _setup(tmp_path)
    drv2 = TrainDriver(FTConfig(ckpt_dir=str(tmp_path / "ck2"),
                                ckpt_every=2), step, params, opt_state, pipe)
    assert drv2.maybe_restore() and drv2.step == 4
    drv2.run(6, log_every=0)
    assert drv2.step == 6


def test_elastic_restore_to_different_mesh(tmp_path):
    """Checkpoint on one mesh, restore re-sharded onto another."""
    from repro.launch import sharding as SH
    cfg = get_config("phi3-mini-3.8b", smoke=True)
    params = init_model(KEY, cfg)
    ck = Checkpointer(str(tmp_path / "ck3"))
    ck.save(0, {"params": params}, blocking=True)

    n = len(jax.devices())
    mesh2 = jax.make_mesh((1, n), ("data", "model"))
    p_sh = SH.param_shardings(jax.eval_shape(lambda: params), mesh2)
    restored, meta = ck.restore({"params": params},
                                shardings={"params": p_sh})
    for a, b in zip(jax.tree.leaves(params),
                    jax.tree.leaves(restored["params"]), strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_atomicity(tmp_path):
    ck = Checkpointer(str(tmp_path / "ck4"), keep=2)
    for s in range(5):
        ck.save(s, {"x": jnp.ones((4,)) * s}, blocking=True)
    assert ck.all_steps() == [3, 4]
    assert not any(n.endswith(".tmp") for n in os.listdir(ck.dir))


def test_checkpoint_prefix_namespaces_rotate_independently(tmp_path):
    """Two checkpoint families (train steps + serve snapshots) share a
    directory but list and GC independently via ``prefix``."""
    train = Checkpointer(str(tmp_path / "ck"), keep=2)
    serve = Checkpointer(str(tmp_path / "ck"), keep=2, prefix="serve")
    for s in range(4):
        train.save(s, {"x": jnp.ones((2,)) * s}, blocking=True)
    serve.save(0, {"x": jnp.zeros((2,))}, blocking=True)
    assert train.all_steps() == [2, 3]
    assert serve.all_steps() == [0]
    restored, meta = serve.restore({"x": jnp.zeros((2,))})
    assert meta["step"] == 0
    np.testing.assert_array_equal(np.asarray(restored["x"]), [0.0, 0.0])


def test_checkpoint_corruption_detected(tmp_path):
    """A bit-flipped leaf fails its recorded crc32 and restore raises
    ``CheckpointCorrupt`` instead of handing back wrong bytes (the serve
    snapshot path catches it and cold-starts from the journal)."""
    from repro.checkpoint.checkpointing import CheckpointCorrupt
    ck = Checkpointer(str(tmp_path / "ck5"))
    tmpl = {"x": jnp.arange(8, dtype=jnp.float32)}
    ck.save(1, tmpl, blocking=True)
    restored, _ = ck.restore(tmpl)          # intact round trip first
    np.testing.assert_array_equal(np.asarray(restored["x"]),
                                  np.arange(8, dtype=np.float32))
    leaf = os.path.join(ck.dir, "step_00000001", "leaf_00000.npy")
    raw = bytearray(open(leaf, "rb").read())
    raw[-1] ^= 0xFF
    open(leaf, "wb").write(bytes(raw))
    with pytest.raises(CheckpointCorrupt):
        ck.restore(tmpl)
    with pytest.raises(CheckpointCorrupt):  # truncation too
        open(leaf, "wb").write(bytes(raw[: len(raw) // 2]))
        ck.restore(tmpl)


def test_pipeline_determinism_and_resume():
    cfg = get_config("phi3-mini-3.8b", smoke=True)
    mesh = make_host_mesh()
    p1 = DataPipeline(SyntheticSource(cfg.vocab_size), 2, 16, mesh)
    b0, b1, b2 = p1.next(), p1.next(), p1.next()
    p2 = DataPipeline(SyntheticSource(cfg.vocab_size), 2, 16, mesh)
    p2.load_state_dict({"step": 2})
    np.testing.assert_array_equal(np.asarray(b2["tokens"]),
                                  np.asarray(p2.next()["tokens"]))
    assert not np.array_equal(np.asarray(b0["tokens"]),
                              np.asarray(b1["tokens"]))


def test_adamw_math():
    params = {"w": jnp.asarray([1.0, -2.0])}
    grads = {"w": jnp.asarray([0.1, 0.1])}
    cfg = AdamWConfig(lr=0.1, warmup_steps=1, total_steps=10,
                      weight_decay=0.0, clip_norm=1e9)
    st = init_opt_state(params)
    new_p, st, stats = adamw_update(grads, st, params, cfg)
    # first step: mhat = g, vhat = g^2 -> step ~= lr * sign(g)
    np.testing.assert_allclose(np.asarray(new_p["w"]),
                               [1.0 - 0.1, -2.0 - 0.1], atol=1e-3)


def test_adamw_no_decay_on_scalar_scales():
    """Quant scales / gates (0-d leaves) get zero grad by design
    (calibration-updated); weight decay must not silently shrink them."""
    params = {"w": jnp.ones((2,)), "s_out": jnp.asarray(0.05)}
    grads = {"w": jnp.ones((2,)), "s_out": jnp.zeros(())}
    cfg = AdamWConfig(lr=0.1, warmup_steps=1, total_steps=10,
                      weight_decay=0.5, clip_norm=1e9)
    st = init_opt_state(params)
    new_p, st, _ = adamw_update(grads, st, params, cfg)
    assert float(new_p["s_out"]) == float(np.float32(0.05))   # no decay
    assert float(new_p["w"][0]) < 1.0             # vector still decays


def test_grad_clipping():
    g = {"w": jnp.asarray([3.0, 4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 5.0) < 1e-6
    np.testing.assert_allclose(np.asarray(clipped["w"]), [0.6, 0.8],
                               rtol=1e-6)


def test_error_feedback_compression_unbiased():
    """EF: accumulated compressed updates converge to the true sum."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(0, 1, (256,)).astype(np.float32))
    ef = init_ef_state({"g": g_true})
    total = np.zeros(256, np.float32)
    for _ in range(50):
        q, scales, ef_err = ef_compress({"g": g_true}, ef)
        ef = {"g": ef_err["g"]}
        total += np.asarray(ef_decompress(q, scales)["g"])
    np.testing.assert_allclose(total / 50, np.asarray(g_true), atol=0.02)


def test_compressed_psum_close_to_exact():
    import jax
    from repro.runtime.compression import compressed_psum
    n = len(jax.devices())
    if n < 2:
        pytest.skip("needs >1 device")
    mesh = jax.make_mesh((n,), ("pod",))
    from jax.sharding import PartitionSpec as P
    x = jnp.arange(n * 8, dtype=jnp.float32).reshape(n, 8) / 7.0
    out = jax.shard_map(lambda v: compressed_psum(v[0], "pod")[None],
                        mesh=mesh, in_specs=P("pod"), out_specs=P("pod"))(x)
    ref = x.sum(0)
    np.testing.assert_allclose(np.asarray(out)[0], np.asarray(ref),
                               rtol=0.02, atol=0.05)


# ---------------------------------------------------------------------------
# Straggler watchdog: extracted detector + TrainDriver delegation (ISSUE 8)
# ---------------------------------------------------------------------------

def test_straggler_watchdog_trigger_semantics():
    """The extracted detector keeps the TrainDriver seed's exact trigger
    points: silent through warmup (even for a huge outlier), reference =
    median of the window *excluding* the newest sample, persistent at
    ``streak_threshold`` consecutive flags with the streak reset after."""
    from repro.runtime.watchdog import StragglerWatchdog

    wd = StragglerWatchdog(factor=2.0, window=8, min_samples=4,
                           streak_threshold=3)
    # warmup: < min_samples observations flag nothing, median reads 0
    v = wd.observe(100.0)
    assert (v.straggler, v.persistent, v.median) == (False, False, 0.0)
    wd = StragglerWatchdog(factor=2.0, window=8, min_samples=4,
                           streak_threshold=3)
    for _ in range(6):
        v = wd.observe(1.0)
        assert not v.straggler
    assert v.median == 1.0 and wd.events == 0
    # 10x the median: flagged, persistent only on the 3rd consecutive
    from repro.runtime.watchdog import WatchdogVerdict
    assert wd.observe(10.0) == WatchdogVerdict(True, False, 1.0)
    v = wd.observe(10.0)
    assert v.straggler and not v.persistent
    v = wd.observe(10.0)
    assert v.straggler and v.persistent          # streak hits 3 -> fires
    v = wd.observe(10.0)
    assert v.straggler and not v.persistent      # streak was reset
    assert wd.events == 4
    # a normal sample resets the streak entirely
    assert not wd.observe(1.0).straggler
    v = wd.observe(50.0)
    assert v.straggler and not v.persistent


def test_straggler_watchdog_rejects_degenerate_history():
    from repro.runtime.watchdog import StragglerWatchdog

    with pytest.raises(ValueError, match="history"):
        StragglerWatchdog(window=1)
    with pytest.raises(ValueError, match="history"):
        StragglerWatchdog(min_samples=1)


def test_train_driver_delegates_to_shared_watchdog(tmp_path):
    """TrainDriver's step timing is the shared StragglerWatchdog — same
    list object (``step_times``), same event counter — so the serve
    loop's segment watchdog and the train watchdog cannot drift apart."""
    from repro.runtime.watchdog import StragglerWatchdog

    drv = TrainDriver(FTConfig(ckpt_dir=str(tmp_path / "wd"),
                               straggler_factor=3.0),
                      None, None, None, None)
    assert isinstance(drv.wd, StragglerWatchdog)
    assert drv.wd.factor == 3.0
    assert drv.step_times is drv.wd.times        # shared in place
    for _ in range(8):
        drv._watchdog(0.01)
    assert drv.straggler_events == 0
    drv._watchdog(1.0)           # 100x median: one event, streak 1 only
    assert drv.straggler_events == 1
    assert len(drv.step_times) == 9
