"""Streaming chunked attention vs direct attention equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.kernels.ita_attention.ref import float_attention_ref
from repro.models.chunked_attention import streaming_attention

KEY = jax.random.PRNGKey(0)
rng = np.random.default_rng(0)


def _cfg(**kw):
    return get_config("phi3-mini-3.8b", smoke=True, **kw)


@pytest.mark.parametrize("sq,skv,causal,window", [
    (128, 128, True, 0), (96, 96, False, 0), (128, 128, True, 48),
    (100, 100, True, 0),                      # non-multiple -> padding
])
def test_float_streaming_matches_direct(sq, skv, causal, window):
    b, h, g, hd = 2, 4, 2, 32
    q = rng.normal(0, 1, (b, sq, h, hd)).astype(np.float32)
    k = rng.normal(0, 1, (b, skv, g, hd)).astype(np.float32)
    v = rng.normal(0, 1, (b, skv, g, hd)).astype(np.float32)
    cfg = _cfg()
    out = streaming_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                              impl="float", cfg=cfg, scale=hd ** -0.5,
                              causal=causal, window=window, q_chunk=32,
                              kv_chunk=32)
    # direct reference with KV head broadcast
    kr = np.repeat(k, h // g, axis=2).transpose(0, 2, 1, 3).reshape(b * h, skv, hd)
    vr = np.repeat(v, h // g, axis=2).transpose(0, 2, 1, 3).reshape(b * h, skv, hd)
    qr = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    ref = float_attention_ref(jnp.asarray(qr), jnp.asarray(kr),
                              jnp.asarray(vr), causal=causal, window=window)
    out_r = np.asarray(out).transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    np.testing.assert_allclose(out_r, np.asarray(ref), atol=2e-5)


def test_ita_int_streaming_matches_model_direct():
    """ita_int chunked result ~= the direct integer attention used by the
    decode path (same adaptive DI; streaming corrections differ by the
    documented floor interaction only)."""
    from repro.models.attention import attention_core
    cfg = _cfg(attention_impl="ita")
    b, s, h, g, hd = 1, 64, 4, 4, 16
    params = {"s_q": jnp.asarray(0.05), "s_k": jnp.asarray(0.05),
              "s_v": jnp.asarray(0.05)}
    q = rng.normal(0, 0.5, (b, s, h, hd)).astype(np.float32)
    k = rng.normal(0, 0.5, (b, s, g, hd)).astype(np.float32)
    v = rng.normal(0, 0.5, (b, s, g, hd)).astype(np.float32)
    out_chunk = attention_core(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                               cfg=cfg, params=params, causal=True, window=0,
                               mode="prefill")
    # direct (decode-style) path on the same inputs
    out_direct = attention_core(jnp.asarray(q), jnp.asarray(k),
                                jnp.asarray(v), cfg=cfg, params=params,
                                causal=True, window=0, mode="decode")
    a, b_ = np.asarray(out_chunk, np.float32), np.asarray(out_direct,
                                                          np.float32)
    rel = np.abs(a - b_).mean() / (np.abs(b_).mean() + 1e-9)
    assert rel < 0.08, rel


def test_scan_unroll_equivalence():
    cfg_r = _cfg()
    cfg_u = _cfg(scan_unroll=True)
    b, s, h, hd = 1, 64, 2, 16
    q = rng.normal(0, 1, (b, s, h, hd)).astype(np.float32)
    k = rng.normal(0, 1, (b, s, h, hd)).astype(np.float32)
    v = rng.normal(0, 1, (b, s, h, hd)).astype(np.float32)
    o1 = streaming_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                             impl="float", cfg=cfg_r, scale=0.25,
                             causal=True, q_chunk=16, kv_chunk=16)
    o2 = streaming_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                             impl="float", cfg=cfg_u, scale=0.25,
                             causal=True, q_chunk=16, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)
