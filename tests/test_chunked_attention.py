"""Streaming chunked attention vs direct attention equivalence, driven
through the unified engine where a backend choice is being compared."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import attention as ATT
from repro.attention.chunked import streaming_attention
from repro.kernels.ita_attention.ref import float_attention_ref

KEY = jax.random.PRNGKey(0)
rng = np.random.default_rng(0)


@pytest.mark.parametrize("sq,skv,causal,window", [
    (128, 128, True, 0), (96, 96, False, 0), (128, 128, True, 48),
    (100, 100, True, 0),                      # non-multiple -> padding
])
def test_float_streaming_matches_direct(sq, skv, causal, window):
    b, h, g, hd = 2, 4, 2, 32
    q = rng.normal(0, 1, (b, sq, h, hd)).astype(np.float32)
    k = rng.normal(0, 1, (b, skv, g, hd)).astype(np.float32)
    v = rng.normal(0, 1, (b, skv, g, hd)).astype(np.float32)
    out = streaming_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                              impl="float", scale=hd ** -0.5,
                              causal=causal, window=window, q_chunk=32,
                              kv_chunk=32)
    # direct reference with KV head broadcast
    kr = np.repeat(k, h // g, axis=2).transpose(0, 2, 1, 3).reshape(b * h, skv, hd)
    vr = np.repeat(v, h // g, axis=2).transpose(0, 2, 1, 3).reshape(b * h, skv, hd)
    qr = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    ref = float_attention_ref(jnp.asarray(qr), jnp.asarray(kr),
                              jnp.asarray(vr), causal=causal, window=window)
    out_r = np.asarray(out).transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    np.testing.assert_allclose(out_r, np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("softcap", [0.0, 2.0])
def test_ita_int_streaming_matches_direct_backend(softcap):
    """ita_chunked_xla result ~= ita_direct_xla on the same inputs (same
    adaptive DI; streaming corrections differ by the documented floor
    interaction only) — both driven through the registry by name. The
    softcapped case pins the chunked int branch's tanh-before-requant
    against the direct path's (the gemma2-ita semantics)."""
    b, s, h, g, hd = 1, 64, 4, 4, 16
    scales = ATT.QuantScales.per_tensor(jnp.asarray(0.05))
    q = rng.normal(0, 0.5, (b, s, h, hd)).astype(np.float32)
    k = rng.normal(0, 0.5, (b, s, g, hd)).astype(np.float32)
    v = rng.normal(0, 0.5, (b, s, g, hd)).astype(np.float32)
    out_chunk = ATT.dispatch(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        spec=ATT.AttentionSpec(mode="prefill", impl="ita", q_len=s,
                               softcap=softcap),
        scales=scales, backend="ita_chunked_xla")
    out_direct = ATT.dispatch(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        spec=ATT.AttentionSpec(mode="decode", impl="ita", q_len=s,
                               softcap=softcap),
        scales=scales, backend="ita_direct_xla")
    a, b_ = np.asarray(out_chunk, np.float32), np.asarray(out_direct,
                                                          np.float32)
    rel = np.abs(a - b_).mean() / (np.abs(b_).mean() + 1e-9)
    assert rel < 0.08, rel
    if softcap:
        # the cap actually bites: capped and uncapped logit grids differ
        out_nocap = ATT.dispatch(
            jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
            spec=ATT.AttentionSpec(mode="prefill", impl="ita", q_len=s),
            scales=scales, backend="ita_chunked_xla")
        assert np.abs(a - np.asarray(out_nocap, np.float32)).max() > 0


def test_scan_unroll_equivalence():
    b, s, h, hd = 1, 64, 2, 16
    q = rng.normal(0, 1, (b, s, h, hd)).astype(np.float32)
    k = rng.normal(0, 1, (b, s, h, hd)).astype(np.float32)
    v = rng.normal(0, 1, (b, s, h, hd)).astype(np.float32)
    o1 = streaming_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                             impl="float", scale=0.25,
                             causal=True, q_chunk=16, kv_chunk=16)
    o2 = streaming_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                             impl="float", scale=0.25, scan_unroll=True,
                             causal=True, q_chunk=16, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)
