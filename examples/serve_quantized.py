"""End-to-end serving driver (the paper is an *inference* accelerator, so
serving is the canonical e2e example): a small LM served with batched
requests through the ITA integer pipeline — int8 KV cache, integer
streaming softmax at prefill, direct integer attention at decode — and a
side-by-side float-attention run for output comparison.

    PYTHONPATH=src python examples/serve_quantized.py
"""

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import init_model
from repro.runtime.generate import generate

CFG_BASE = dict(
    name="serve-demo", family="dense",
    d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
    d_ff=1024, vocab_size=2048,
    layer_groups=((("attn",), 4),),
    tie_embeddings=True, dtype="float32",
)

BATCH, PROMPT, GEN = 8, 48, 24


def serve(cfg, params, prompts):
    res = generate(params, cfg, prompts, GEN)
    return res.tokens, res.prefill_s + res.decode_s


def main():
    key = jax.random.PRNGKey(0)
    cfg_f = ModelConfig(**CFG_BASE)
    cfg_q = ModelConfig(**{**CFG_BASE, "attention_impl": "ita"})
    params = init_model(key, cfg_f)
    params_q = init_model(key, cfg_q)      # same weights + quant scales

    prompts = jax.random.randint(key, (BATCH, PROMPT), 0, cfg_f.vocab_size)
    out_f, t_f = serve(cfg_f, params, prompts)
    out_q, t_q = serve(cfg_q, params_q, prompts)

    agree = float((out_f == out_q).mean())
    kv_bytes_f = PROMPT * cfg_f.n_kv_heads * cfg_f.head_dim * 2 * 4
    kv_bytes_q = PROMPT * cfg_f.n_kv_heads * cfg_f.head_dim * 2 * 1
    print(f"[serve] batch={BATCH} prompt={PROMPT} gen={GEN}")
    print(f"[serve] float attention: {t_f*1e3:.0f} ms; "
          f"ITA integer attention: {t_q*1e3:.0f} ms (CPU, indicative)")
    print(f"[serve] greedy-token agreement float vs ITA-int8: {agree:.2%} "
          "(random weights -> near-uniform logits; QAT-trained models "
          "agree far more, see examples/train_qat_lm.py)")
    print(f"[serve] KV cache bytes/token/layer: float32 {kv_bytes_f} "
          f"-> int8 {kv_bytes_q} (4x smaller)")
    print("[serve] sample (ITA):", np.asarray(out_q)[0, :12].tolist())


if __name__ == "__main__":
    main()
