"""End-to-end serving driver (the paper is an *inference* accelerator, so
serving is the canonical e2e example): a small LM served with batched
requests through the ITA integer pipeline — int8 KV cache, integer
streaming softmax at prefill, direct integer attention at decode — and a
side-by-side float-attention run for output comparison.

    PYTHONPATH=src python examples/serve_quantized.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import forward, init_caches, init_model

CFG_BASE = dict(
    name="serve-demo", family="dense",
    d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
    d_ff=1024, vocab_size=2048,
    layer_groups=((("attn",), 4),),
    tie_embeddings=True, dtype="float32",
)

BATCH, PROMPT, GEN = 8, 48, 24


def serve(cfg, params, prompts):
    prefill = jax.jit(lambda p, t, c: forward(p, t, cfg, mode="prefill",
                                              caches=c)[:2])
    decode = jax.jit(lambda p, t, c, pos: forward(p, t, cfg, mode="decode",
                                                  caches=c, pos0=pos)[:2],
                     donate_argnums=(2,))
    caches = init_caches(cfg, BATCH, max_len=PROMPT + GEN)
    t0 = time.time()
    logits, caches = prefill(params, prompts, caches)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    toks = [tok]
    for i in range(GEN - 1):
        logits, caches = decode(params, tok, caches,
                                jnp.asarray(PROMPT + i))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        toks.append(tok)
    out = jnp.concatenate(toks, 1)
    jax.block_until_ready(out)
    return out, time.time() - t0


def main():
    key = jax.random.PRNGKey(0)
    cfg_f = ModelConfig(**CFG_BASE)
    cfg_q = ModelConfig(**{**CFG_BASE, "attention_impl": "ita"})
    params = init_model(key, cfg_f)
    params_q = init_model(key, cfg_q)      # same weights + quant scales

    prompts = jax.random.randint(key, (BATCH, PROMPT), 0, cfg_f.vocab_size)
    out_f, t_f = serve(cfg_f, params, prompts)
    out_q, t_q = serve(cfg_q, params_q, prompts)

    agree = float((out_f == out_q).mean())
    kv_bytes_f = PROMPT * cfg_f.n_kv_heads * cfg_f.head_dim * 2 * 4
    kv_bytes_q = PROMPT * cfg_f.n_kv_heads * cfg_f.head_dim * 2 * 1
    print(f"[serve] batch={BATCH} prompt={PROMPT} gen={GEN}")
    print(f"[serve] float attention: {t_f*1e3:.0f} ms; "
          f"ITA integer attention: {t_q*1e3:.0f} ms (CPU, indicative)")
    print(f"[serve] greedy-token agreement float vs ITA-int8: {agree:.2%} "
          "(random weights -> near-uniform logits; QAT-trained models "
          "agree far more, see examples/train_qat_lm.py)")
    print(f"[serve] KV cache bytes/token/layer: float32 {kv_bytes_f} "
          f"-> int8 {kv_bytes_q} (4x smaller)")
    print("[serve] sample (ITA):", np.asarray(out_q)[0, :12].tolist())


if __name__ == "__main__":
    main()
