"""QAT training example — the paper's "clipping threshold obtained from
quantization-aware training that incorporates our softmax implementation".

Trains a small causal LM on synthetic data twice: once with float
attention, once with the ITA QAT forward (STE-floored base-2 softmax +
fake-quantized Q/K/V). Then serves both through the *integer* path and
reports the loss gap: QAT training aligns the model with the deployed
integer semantics.

    PYTHONPATH=src python examples/train_qat_lm.py [--steps 200]

(Sizes chosen to finish on CPU; scale d_model/layers for a ~100M run on
real hardware — the code path is identical.)
"""

import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.data.pipeline import DataPipeline, SyntheticSource
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models import forward, init_model, loss_fn
from repro.optim.optimizer import AdamWConfig, init_opt_state

BASE = dict(
    name="qat-demo", family="dense",
    d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=512, vocab_size=512,
    layer_groups=((("attn",), 2),),
    tie_embeddings=True, dtype="float32",
)


def train(cfg, steps, seed=0):
    mesh = make_host_mesh()
    params = init_model(jax.random.PRNGKey(seed), cfg)
    opt_cfg = AdamWConfig(lr=1e-3, total_steps=steps, warmup_steps=20)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    pipe = DataPipeline(SyntheticSource(cfg.vocab_size, seed=1), batch=8,
                        seq_len=64, mesh=mesh)
    loss = None
    for i in range(steps):
        params, opt, m = step(params, opt, pipe.next())
        if (i + 1) % 50 == 0:
            print(f"  step {i+1}: loss {float(m['loss']):.4f}")
        loss = float(m["loss"])
    return params, loss, pipe


def eval_integer_path(cfg_trained, params, pipe):
    """Evaluate the trained weights through the int8 serve pipeline
    (requires quant-scale params, i.e. an ita-trained model)."""
    import dataclasses
    cfg_int = dataclasses.replace(cfg_trained, attention_impl="ita")
    batch = pipe.next()
    # integer prefill loss (teacher forced through serve mode)
    from repro.models import init_caches
    toks = batch["tokens"]
    caches = init_caches(cfg_int, toks.shape[0], max_len=toks.shape[1])
    logits, _, _ = forward(params, toks[:, :-1], cfg_int, mode="prefill",
                           caches=caches)
    targets = toks[:, 1:]
    logz = jax.nn.logsumexp(logits, axis=-1)
    vidx = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    gold = jnp.sum(jnp.where(vidx == targets[..., None], logits, 0.0), -1)
    return float((logz - gold).mean())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    print("[qat] training with ITA QAT attention (STE integer semantics)")
    cfg_q = ModelConfig(**{**BASE, "attention_impl": "ita"})
    params_q, loss_q, pipe = train(cfg_q, args.steps)
    int_loss_q = eval_integer_path(cfg_q, params_q, pipe)

    print("[qat] training with float attention (baseline)")
    cfg_f = ModelConfig(**BASE)
    params_f, loss_f, pipe_f = train(cfg_f, args.steps)

    print(f"[qat] float-trained train loss:   {loss_f:.4f}")
    print(f"[qat] QAT-trained train loss:     {loss_q:.4f}")
    print(f"[qat] QAT model on INT serve path: {int_loss_q:.4f} "
          f"(gap {int_loss_q - loss_q:+.4f})")
    print("[qat] QAT keeps the integer-deployment gap small — the paper's "
          "trained clipping in action.")


if __name__ == "__main__":
    main()
