"""Quickstart: the ITA integer softmax and the unified attention engine
in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro import attention as ATT
from repro.core import softmax as S
from repro.core.quant import EPS_MAX

rng = np.random.default_rng(0)

# --- 1. the paper's softmax: shift-only, integer, streaming ---------------
logits = rng.normal(0, 1.0, (4, 256))
lq = jnp.asarray(np.clip(np.round(logits / EPS_MAX), -128, 127), jnp.int8)

p_float = S.softmax_float(lq)                 # float oracle
p_ita = S.ita_softmax(lq)                     # paper semantics
p_adaptive = S.ita_softmax_adaptive(lq)       # beyond-paper per-row scale

print("ITA softmax MAE vs float:     %.4f" %
      float(jnp.abs(p_ita - p_float).mean()))
print("adaptive softmax MAE vs float: %.4f" %
      float(jnp.abs(p_adaptive - p_float).mean()))

# --- 2. the attention engine: one spec, capability-dispatched backends ----
B, H, S_, D = 1, 4, 256, 64
q = jnp.asarray(rng.integers(-128, 128, (B, H, S_, D), dtype=np.int8))
k = jnp.asarray(rng.integers(-128, 128, (B, H, S_, D), dtype=np.int8))
v = jnp.asarray(rng.integers(-128, 128, (B, H, S_, D), dtype=np.int8))

spec = ATT.AttentionSpec(mode="prefill", impl="ita", causal=True,
                         layout="bhsd", out_dtype="int8")
scales = ATT.QuantScales.per_tensor(np.float32(0.04),
                                    s_out=np.float32(0.02))

print("eligible backends:", ATT.list_backends(spec))

out = ATT.dispatch(q, k, v, spec=spec, scales=scales)   # first eligible
print("fused attention out:", out.shape, out.dtype,
      "sample:", np.asarray(out)[0, 0, 0, :4].tolist())

# explicit override: the paper-faithful two-pass dataflow (A matrix in HBM)
out2 = ATT.dispatch(q, k, v, spec=spec, scales=scales,
                    backend="ita_twopass_pallas")
agree = float((out == out2).mean())
print(f"onepass vs twopass int8 agreement: {agree:.3f} "
      "(different EN semantics, same algorithm)")

# capability negotiation: a softcapped decode spec can't ride the fused
# kernels — the registry says why, and who serves it instead
cap_spec = spec.replace(mode="decode", softcap=30.0, layout="bshd",
                        q_len=1)
print("softcap decode verdicts:")
for name, verdict in ATT.backend_reasons(cap_spec).items():
    print(f"  {name:20s} {'OK' if verdict is True else verdict}")
