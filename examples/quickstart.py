"""Quickstart: the ITA integer softmax and fused attention kernel in 60
seconds.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import softmax as S
from repro.core.quant import EPS_MAX
from repro.kernels.ita_attention.ops import ita_attention

rng = np.random.default_rng(0)

# --- 1. the paper's softmax: shift-only, integer, streaming ---------------
logits = rng.normal(0, 1.0, (4, 256))
lq = jnp.asarray(np.clip(np.round(logits / EPS_MAX), -128, 127), jnp.int8)

p_float = S.softmax_float(lq)                 # float oracle
p_ita = S.ita_softmax(lq)                     # paper semantics
p_adaptive = S.ita_softmax_adaptive(lq)       # beyond-paper per-row scale

print("ITA softmax MAE vs float:     %.4f" %
      float(jnp.abs(p_ita - p_float).mean()))
print("adaptive softmax MAE vs float: %.4f" %
      float(jnp.abs(p_adaptive - p_float).mean()))

# --- 2. fused int8 attention (Pallas kernel, interpret mode on CPU) -------
B, H, S_, D = 1, 4, 256, 64
q = rng.integers(-128, 128, (B, H, S_, D), dtype=np.int8)
k = rng.integers(-128, 128, (B, H, S_, D), dtype=np.int8)
v = rng.integers(-128, 128, (B, H, S_, D), dtype=np.int8)
scale = np.float32(0.04)

out = ita_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                    scale, scale, scale, np.float32(0.02),
                    causal=True, mode="onepass")      # flash-style, int8
print("fused attention out:", out.shape, out.dtype,
      "sample:", np.asarray(out)[0, 0, 0, :4].tolist())

out2, = (ita_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                       scale, scale, scale, np.float32(0.02),
                       causal=True, mode="twopass"),)  # paper dataflow
agree = float((out == out2).mean())
print(f"onepass vs twopass int8 agreement: {agree:.3f} "
      "(different EN semantics, same algorithm)")
