"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads the JSONL written by ``repro.launch.dryrun --all --out <file>`` and
prints per-cell rows; with no file present prints a short notice (the
dry-run is a separate long-running step).
"""

import json
import os

DEFAULT_PATHS = ("results/dryrun_single.jsonl", "/tmp/dryrun_single.jsonl")


def load(path=None):
    paths = [path] if path else DEFAULT_PATHS
    for p in paths:
        if p and os.path.exists(p):
            with open(p) as f:
                return [json.loads(l) for l in f if l.strip()]
    return []


def main():
    recs = load(os.environ.get("REPRO_DRYRUN_JSONL"))
    if not recs:
        print("roofline/no_dryrun_artifacts_found,0,0")
        return
    for r in recs:
        if r.get("status") != "ok":
            print(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},0,FAIL")
            continue
        ro = r["roofline"]
        step = max(ro["compute_s"], ro["memory_s"], ro["collective_s"])
        mfu = ro["model_flops"] / (256 * 197e12 * step) if step else 0
        print(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
              f",{step*1e6:.0f}"
              f",bottleneck={ro['bottleneck']}"
              f";compute_s={ro['compute_s']:.3f}"
              f";memory_s={ro['memory_s']:.3f}"
              f";collective_s={ro['collective_s']:.3f}"
              f";useful={ro['useful_ratio']:.2f}"
              f";roofline_mfu={mfu:.3f}")


if __name__ == "__main__":
    main()
