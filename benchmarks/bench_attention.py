"""Paper §V-D + Table I analogue: quantized-attention efficiency.

The paper reports 6× speedup / 45× energy vs a 256-core RISC-V software
baseline, and 16.9 TOPS/W / 1.02 TOPS at 1024 MACs. Silicon numbers do
not transfer; the TPU-transferable claims are:

- int8 vs bf16 *compute-term* ratio on the MXU (v5e: 394 vs 197 TOPS) —
  the quantization lever,
- HBM bytes for the attention pipeline: fused streaming softmax (A never
  re-read; stats on the fly) vs unfused (A written + read for max, sum,
  normalize passes) — the data-movement lever,
- measured wall-clock of the jnp integer path vs float path on this host
  (CPU; indicative only, the deploy target is the Pallas kernel).
"""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline.analysis import HW

_ITERS = 2 if os.environ.get("ITA_BENCH_SMOKE") else 10


def roofline_rows(s=4096, h=32, hd=128, b=8):
    att_flops = 2 * 2 * b * h * s * s * hd / 2          # causal QK+AV
    a_bytes = b * h * s * s                              # int8 A matrix
    rows = []
    t_bf16 = att_flops / HW["peak_bf16"]
    t_int8 = att_flops / HW["peak_int8"]
    rows.append(("attention/compute_s_bf16", t_bf16))
    rows.append(("attention/compute_s_int8", t_int8))
    rows.append(("attention/int8_speedup", t_bf16 / t_int8))
    # softmax passes over A: unfused = write A + read(max) + read(sum+exp)
    # + read(normalize) + write P + read P for AV  => 6x A bytes.
    # ITA fused: A stays in VMEM (onepass) => 0x; paper twopass: write+read.
    for name, factor in [("unfused", 6), ("ita_twopass", 2),
                         ("ita_onepass", 0)]:
        t_mem = factor * a_bytes / HW["hbm_bw"]
        rows.append((f"attention/softmax_hbm_s_{name}", t_mem))
    rows.append(("attention/fused_bytes_saving_vs_unfused",
                 6 * a_bytes / max(2 * a_bytes, 1)))
    return rows


def timed_rows():
    """CPU wall-clock of the jnp reference paths (indicative)."""
    from repro.kernels.ita_attention.ref import (float_attention_ref,
                                                 ita_attention_ref)
    rng = np.random.default_rng(0)
    b, s, d = 4, 256, 64
    q8 = jnp.asarray(rng.integers(-128, 128, (b, s, d), dtype=np.int8))
    k8 = jnp.asarray(rng.integers(-128, 128, (b, s, d), dtype=np.int8))
    v8 = jnp.asarray(rng.integers(-128, 128, (b, s, d), dtype=np.int8))
    qf, kf, vf = (x.astype(jnp.float32) * 0.05 for x in (q8, k8, v8))

    int_fn = jax.jit(lambda a, b_, c: ita_attention_ref(
        a, b_, c, 0.001, 1.0, s, causal=True)[0])
    flt_fn = jax.jit(lambda a, b_, c: float_attention_ref(
        a, b_, c, causal=True))

    def timeit(fn, *args):
        fn(*args)[0].block_until_ready()
        t0 = time.perf_counter()
        for _ in range(_ITERS):
            out = fn(*args)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / _ITERS * 1e6

    t_int = timeit(int_fn, q8, k8, v8)
    t_flt = timeit(flt_fn, qf, kf, vf)
    return [("attention/cpu_us_int_path", t_int),
            ("attention/cpu_us_float_path", t_flt)]


def main():
    for name, val in roofline_rows():
        print(f"{name},0,{val:.6g}")
    for name, val in timed_rows():
        print(f"{name},{val:.1f},{val:.6g}")


if __name__ == "__main__":
    main()
