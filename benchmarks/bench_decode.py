"""Incremental int8 KV-cache decode vs full-context recompute.

Serving cost model: without a KV cache every generated token re-runs
attention over the whole context (O(S²) per token); with the int8 ring
buffer each token is one decode-shaped kernel call over the valid prefix
(O(S) per token) and the cache bytes are 4x smaller than f32. Reports
tokens/s for both at a fixed context length (CPU interpret mode —
indicative; the structure, not the silicon, is the claim) plus the
analytic FLOP/byte ratios that do transfer.
"""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime import kv_cache as KV

B, HQ, HKV, D = 2, 4, 2, 64
CTX = 128                      # context at which decode cost is measured
BLOCK_KV = 64
S_Q, S_OUT = np.float32(0.05), np.float32(0.02)


def _setup():
    rng = np.random.default_rng(0)
    kf = rng.normal(0, 1, (B, CTX, HKV, D)).astype(np.float32)
    vf = rng.normal(0, 1, (B, CTX, HKV, D)).astype(np.float32)
    q8 = rng.integers(-128, 128, (B, HQ, CTX, D), dtype=np.int8)
    cache = KV.init_cache(B, CTX, HKV, D, per_head_scales=True)
    # occupy all but the final slot so the timed step decodes at full context
    _, cache = KV.prefill_attend(cache, jnp.asarray(q8[:, :, :CTX - 1]),
                                 jnp.asarray(kf[:, :CTX - 1]),
                                 jnp.asarray(vf[:, :CTX - 1]),
                                 S_Q, S_OUT, block_kv=BLOCK_KV)
    return cache, q8, kf, vf


def _time(fn, iters=20):
    jax.block_until_ready(fn())               # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def main():
    from repro import attention as ATT
    cache, q8, kf, vf = _setup()
    q_last = jnp.asarray(q8[:, :, CTX - 1:])
    k_last, v_last = jnp.asarray(kf[:, CTX - 1:]), jnp.asarray(vf[:, CTX - 1:])
    smoke = bool(int(os.environ.get("ITA_BENCH_SMOKE", "0")))

    def cached_step():
        out, _ = KV.decode_attend(cache, q_last, k_last, v_last, S_Q, S_OUT,
                                  block_kv=BLOCK_KV)
        return out

    k8_full = KV.quantize_with_scale(
        jnp.asarray(kf), cache.k_scale[None, None, :, None]
    ).transpose(0, 2, 1, 3)
    v8_full = KV.quantize_with_scale(
        jnp.asarray(vf), cache.v_scale[None, None, :, None]
    ).transpose(0, 2, 1, 3)
    spec = ATT.AttentionSpec(mode="prefill", impl="ita", layout="bhsd",
                             scale_kind="per_head", out_dtype="int8")
    scales = ATT.QuantScales(S_Q, cache.k_scale, cache.v_scale, S_OUT)

    def recompute_step():
        # no-cache serving: re-run full-context attention, keep the new row
        out = ATT.dispatch(jnp.asarray(q8), k8_full, v8_full, spec=spec,
                           scales=scales, backend="ita_onepass_pallas",
                           block_q=BLOCK_KV, block_kv=BLOCK_KV)
        return out[:, :, -1:]

    iters = 3 if smoke else 20
    us_cached = _time(cached_step, iters)
    us_recomp = _time(recompute_step, iters)
    tok_s_cached = B / (us_cached * 1e-6)
    tok_s_recomp = B / (us_recomp * 1e-6)
    print(f"decode/cached_us_per_step,{us_cached:.1f},{tok_s_cached:.6g}")
    print(f"decode/recompute_us_per_step,{us_recomp:.1f},{tok_s_recomp:.6g}")
    print(f"decode/cached_speedup,0,{us_recomp / us_cached:.6g}")
    # transferable ratios: per-token attention FLOPs and cache bytes
    flops_cached = 2 * 2 * B * HQ * CTX * D
    flops_recomp = 2 * 2 * B * HQ * CTX * CTX * D / 2
    print(f"decode/flops_ratio_recompute_vs_cached,0,"
          f"{flops_recomp / flops_cached:.6g}")
    bytes_f32 = CTX * HKV * D * 2 * 4
    bytes_i8 = CTX * HKV * D * 2 * 1 + 2 * HKV * 4
    print(f"decode/kv_bytes_f32_vs_int8_per_layer,0,"
          f"{bytes_f32 / bytes_i8:.6g}")


if __name__ == "__main__":
    main()
