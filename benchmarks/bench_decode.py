"""Decode serving benchmarks: kernel-level cache reuse + the fused
generation loop.

Three claims, measured on the CI (CPU/interpret) configuration —
indicative structure, not silicon numbers:

1. **Cache vs recompute** (paper serving cost model): with the int8 ring
   buffer each token is one decode-shaped kernel call over the valid
   prefix (O(S)); without it, full-context recompute (O(S²)).
2. **Fused loop vs per-step host loop**: one jitted ``lax.scan`` over
   all decode steps vs one dispatch per token — the host round-trip is
   the serving bottleneck the fused loop deletes (ISSUE 3 acceptance:
   >= 2x tok/s at B=8, gen=128).
3. **Ragged batch**: mixed prompt lengths decode in the same fused loop
   through per-row kernel meta, no padding to the longest prompt.

Writes ``BENCH_decode.json`` (env ``ITA_BENCH_OUT`` overrides the path):
scenario rows plus a tok/s-vs-gen trajectory, schema-checked on every
run so the CI ``benchmarks/run.py --smoke`` step keeps it from rotting.
"""

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import init_model
from repro.runtime import kv_cache as KV
from repro.runtime.generate import generate

B, HQ, HKV, D = 2, 4, 2, 64
CTX = 128                      # context at which decode cost is measured
BLOCK_KV = 64
S_Q, S_OUT = np.float32(0.05), np.float32(0.02)

# The fused-loop acceptance scenario (ISSUE 3): B=8, gen=128. The model
# is deliberately small and the ring is one KV block (max_len=128,
# window-evicting): the quantity under test is *loop overhead* — what
# one host dispatch per token costs vs one scan for all of them — not
# kernel compute, which the cache-vs-recompute scenarios above measure.
GEN_CFG = ModelConfig(
    name="bench-decode", family="dense", d_model=32, n_heads=1,
    n_kv_heads=1, head_dim=32, d_ff=64, vocab_size=64,
    layer_groups=((("attn",), 1),), dtype="float32", attention_impl="ita")
GEN_BATCH, GEN_PROMPT, GEN_STEPS, GEN_MAX_LEN = 8, 16, 128, 128

SCHEMA_KEYS = {"schema_version", "config", "scenarios", "trajectory"}
SCENARIO_KEYS = {"name", "loop", "batch", "gen", "ragged", "decode_s",
                 "tok_s"}


def _setup():
    rng = np.random.default_rng(0)
    kf = rng.normal(0, 1, (B, CTX, HKV, D)).astype(np.float32)
    vf = rng.normal(0, 1, (B, CTX, HKV, D)).astype(np.float32)
    q8 = rng.integers(-128, 128, (B, HQ, CTX, D), dtype=np.int8)
    cache = KV.init_cache(B, CTX, HKV, D, per_head_scales=True)
    # occupy all but the final slot so the timed step decodes at full context
    _, cache = KV.prefill_attend(cache, jnp.asarray(q8[:, :, :CTX - 1]),
                                 jnp.asarray(kf[:, :CTX - 1]),
                                 jnp.asarray(vf[:, :CTX - 1]),
                                 S_Q, S_OUT, block_kv=BLOCK_KV)
    return cache, q8, kf, vf


def _time(fn, iters=20):
    jax.block_until_ready(fn())               # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def _kernel_scenarios(smoke):
    from repro import attention as ATT
    cache, q8, kf, vf = _setup()
    q_last = jnp.asarray(q8[:, :, CTX - 1:])
    k_last, v_last = jnp.asarray(kf[:, CTX - 1:]), jnp.asarray(vf[:, CTX - 1:])

    def cached_step():
        out, _ = KV.decode_attend(cache, q_last, k_last, v_last, S_Q, S_OUT,
                                  block_kv=BLOCK_KV)
        return out

    k8_full = KV.quantize_with_scale(
        jnp.asarray(kf), cache.k_scale[None, None, :, None]
    ).transpose(0, 2, 1, 3)
    v8_full = KV.quantize_with_scale(
        jnp.asarray(vf), cache.v_scale[None, None, :, None]
    ).transpose(0, 2, 1, 3)
    spec = ATT.AttentionSpec(mode="prefill", impl="ita", layout="bhsd",
                             scale_kind="per_head", out_dtype="int8")
    scales = ATT.QuantScales(S_Q, cache.k_scale, cache.v_scale, S_OUT)

    def recompute_step():
        # no-cache serving: re-run full-context attention, keep the new row
        out = ATT.dispatch(jnp.asarray(q8), k8_full, v8_full, spec=spec,
                           scales=scales, backend="ita_onepass_pallas",
                           block_q=BLOCK_KV, block_kv=BLOCK_KV)
        return out[:, :, -1:]

    iters = 3 if smoke else 20
    us_cached = _time(cached_step, iters)
    us_recomp = _time(recompute_step, iters)
    tok_s_cached = B / (us_cached * 1e-6)
    tok_s_recomp = B / (us_recomp * 1e-6)
    print(f"decode/cached_us_per_step,{us_cached:.1f},{tok_s_cached:.6g}")
    print(f"decode/recompute_us_per_step,{us_recomp:.1f},{tok_s_recomp:.6g}")
    print(f"decode/cached_speedup,0,{us_recomp / us_cached:.6g}")
    # transferable ratios: per-token attention FLOPs and cache bytes
    flops_cached = 2 * 2 * B * HQ * CTX * D
    flops_recomp = 2 * 2 * B * HQ * CTX * CTX * D / 2
    print(f"decode/flops_ratio_recompute_vs_cached,0,"
          f"{flops_recomp / flops_cached:.6g}")
    bytes_f32 = CTX * HKV * D * 2 * 4
    bytes_i8 = CTX * HKV * D * 2 * 1 + 2 * HKV * 4
    print(f"decode/kv_bytes_f32_vs_int8_per_layer,0,"
          f"{bytes_f32 / bytes_i8:.6g}")


def _gen_scenario(params, prompts, *, name, loop, gen, lengths=None,
                  iters=1):
    """Run generate() ``iters + 1`` times (first warms the compile) and
    report the best decode wall-clock."""
    best = None
    for _ in range(iters + 1):
        res = generate(params, GEN_CFG, prompts, gen, max_len=GEN_MAX_LEN,
                       prompt_lengths=lengths, loop=loop)
        if best is None or res.decode_s < best.decode_s:
            best = res
    row = {"name": name, "loop": loop, "batch": int(prompts.shape[0]),
           "gen": int(gen), "ragged": lengths is not None,
           "decode_s": round(best.decode_s, 6),
           "tok_s": round(best.decode_tok_s, 3)}
    print(f"decode/{name},{best.decode_s / max(gen - 1, 1) * 1e6:.1f},"
          f"{best.decode_tok_s:.6g}")
    return row, best


def _generation_scenarios(smoke):
    key = jax.random.PRNGKey(0)
    params = init_model(key, GEN_CFG)
    prompts = jax.random.randint(key, (GEN_BATCH, GEN_PROMPT), 0,
                                 GEN_CFG.vocab_size)
    iters = 2 if smoke else 4          # best-of; this container is noisy
    scenarios = []

    # acceptance pair: per-step host loop vs one fused scan dispatch
    row_step, res_step = _gen_scenario(
        params, prompts, name="loop_stepwise_b8_g128", loop="stepwise",
        gen=GEN_STEPS, iters=iters)
    row_fused, res_fused = _gen_scenario(
        params, prompts, name="loop_fused_b8_g128", loop="fused",
        gen=GEN_STEPS, iters=iters)
    speedup = res_step.decode_s / max(res_fused.decode_s, 1e-9)
    row_fused["speedup_vs_stepwise"] = round(speedup, 3)
    print(f"decode/fused_loop_speedup,0,{speedup:.6g}")
    assert np.array_equal(np.asarray(res_step.tokens),
                          np.asarray(res_fused.tokens)), \
        "fused scan loop must be bit-identical to the per-step loop"
    scenarios += [row_step, row_fused]

    # ragged: mixed prompt lengths, one fused loop, per-row kernel meta
    lengths = jnp.asarray(
        np.random.default_rng(1).integers(GEN_PROMPT // 2, GEN_PROMPT + 1,
                                          GEN_BATCH), jnp.int32)
    row_ragged, _ = _gen_scenario(
        params, prompts, name="loop_fused_ragged_b8_g128", loop="fused",
        gen=GEN_STEPS, lengths=lengths, iters=iters)
    scenarios.append(row_ragged)

    # tok/s trajectory over generation length (fused loop)
    trajectory = []
    for g in ([32] if smoke else [16, 32, 64, 128]):
        _, res = _gen_scenario(params, prompts,
                               name=f"loop_fused_b8_g{g}", loop="fused",
                               gen=g, iters=1)
        trajectory.append({"gen": int(g),
                           "tok_s": round(res.decode_tok_s, 3)})
    return scenarios, trajectory


def _validate_schema(payload):
    assert set(payload) == SCHEMA_KEYS, set(payload)
    assert payload["schema_version"] == 1
    assert payload["scenarios"], "no scenarios recorded"
    for row in payload["scenarios"]:
        missing = SCENARIO_KEYS - set(row)
        assert not missing, f"scenario {row.get('name')} missing {missing}"
        assert row["tok_s"] > 0, row
    assert all({"gen", "tok_s"} <= set(p) for p in payload["trajectory"])


def main():
    smoke = bool(int(os.environ.get("ITA_BENCH_SMOKE", "0")))
    _kernel_scenarios(smoke)
    scenarios, trajectory = _generation_scenarios(smoke)
    payload = {
        "schema_version": 1,
        "config": {"arch": GEN_CFG.name, "d_model": GEN_CFG.d_model,
                   "n_layers": GEN_CFG.n_layers, "batch": GEN_BATCH,
                   "prompt_len": GEN_PROMPT, "gen": GEN_STEPS,
                   "max_len": GEN_MAX_LEN,
                   "backend": jax.default_backend(), "smoke": smoke},
        "scenarios": scenarios,
        "trajectory": trajectory,
    }
    out_path = os.environ.get("ITA_BENCH_OUT", "BENCH_decode.json")
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    with open(out_path) as f:          # round-trip: the rot guard
        _validate_schema(json.load(f))
    print(f"decode/artifact,0,{out_path}")


if __name__ == "__main__":
    main()
