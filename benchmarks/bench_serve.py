"""Continuous-batching serving benchmark: sustained tok/s over an
arrival trace, continuous (paged pool + admission scheduler) vs static
ragged batching.

The claim under test (ISSUE 4 acceptance): with mixed generation lengths
arriving over time, **continuous batching sustains higher aggregate
tok/s than static batching on the same trace** — a static batch decodes
until its *longest* member finishes (short requests strand their slots
and the queue waits), while the continuous scheduler releases a finished
sequence's pages and admits queued work between fused scan segments.
Measured on the CI (CPU/interpret) configuration: indicative structure,
not silicon numbers, but the step-count arithmetic it demonstrates
(static: sum over batches of max-gen; continuous: ~sum(gen)/slots) is
hardware-independent.

Writes ``BENCH_serve.json`` (env ``ITA_BENCH_OUT_SERVE`` overrides the
path): per-mode sustained tok/s, p50/p95 request latency and page-pool
utilization, schema-checked on every run; the smoke run (CI) asserts the
continuous > static ordering.
"""

import json
import os

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import init_model
from repro.runtime.generate import ServeRequest, generate, serve_continuous

# Sized so a decode step's compute is non-trivial next to the per-
# dispatch overhead of the CPU-interpret CI config: the quantity under
# test is the *step count* continuous batching saves (static decodes
# every batch to its longest member), and that signal needs steps to
# cost more than the host glue around them.
CFG = ModelConfig(
    name="bench-serve", family="dense", d_model=64, n_heads=2,
    n_kv_heads=2, head_dim=32, d_ff=128, vocab_size=64,
    layer_groups=((("attn",), 1),), dtype="float32", attention_impl="ita")

SLOTS = 8
PROMPT_PAD = 16
# page == the per-slot window, so a paged decode step streams exactly as
# many KV tiles as the static baseline's ring (one) — the benchmark then
# isolates *scheduling* (slot/page reuse), not per-step tile count
PAGE = 96
SEGMENT = 12
MAX_LEN = 96                    # per-slot window: 1 page

SCHEMA_KEYS = {"schema_version", "config", "continuous", "static",
               "speedup"}
MODE_KEYS = {"tok_s", "wall_s", "tokens", "requests"}


def make_trace(n_requests, rng):
    """Mixed gen lengths (one long straggler per SLOTS requests, so every
    static batch contains exactly one) arriving a few steps apart — the
    shape static batching is worst at: each batch decodes ~80 steps for a
    mean useful budget of ~19 tokens/slot while the queue waits."""
    reqs = []
    step = 0
    for i in range(n_requests):
        gen = 80 if i % SLOTS == 0 else int(rng.integers(6, 14))
        plen = int(rng.integers(PROMPT_PAD // 2, PROMPT_PAD + 1))
        reqs.append(ServeRequest(
            prompt=rng.integers(0, CFG.vocab_size, plen).astype(np.int32),
            gen=gen, arrival=step))
        step += int(rng.integers(0, 4))
    return reqs


def run_continuous_once(params, reqs):
    res = serve_continuous(params, CFG, reqs, slots=SLOTS, segment=SEGMENT,
                           max_len=MAX_LEN, page_size=PAGE)
    assert len(res.completed) == len(reqs), "trace not fully served"
    return res


def summarize_continuous(best):
    util = [u for _, u in best.page_util]
    return {
        "tok_s": round(best.tok_s, 3),
        "wall_s": round(best.wall_s, 6),
        "tokens": best.total_tokens,
        "requests": len(best.completed),
        "steps": best.steps,
        "segments": best.segments,
        "admission_rounds": best.admission_rounds,
        "latency_p50_s": round(best.latency_quantile(0.5), 6),
        "latency_p95_s": round(best.latency_quantile(0.95), 6),
        "page_util_peak": round(max(util, default=0.0), 4),
        "page_util_mean": round(float(np.mean(util)) if util else 0.0, 4),
    }


def run_static_once(params, reqs):
    """Static ragged batching baseline on the same trace: requests in
    arrival order, batches of SLOTS, each batch generates to its longest
    member's budget before the next batch starts (the pre-paged serving
    loop). Useful tokens counted identically (each request's own gen).
    Returns (wall_s, total_tokens)."""
    wall = 0.0
    total_tokens = 0
    for i in range(0, len(reqs), SLOTS):
        batch = reqs[i:i + SLOTS]
        lens = [int(np.asarray(r.prompt).size) for r in batch]
        prompts = np.zeros((len(batch), PROMPT_PAD), np.int32)
        for row, r in enumerate(batch):
            prompts[row, :lens[row]] = np.asarray(r.prompt)
        res = generate(params, CFG, jax.numpy.asarray(prompts),
                       max(r.gen for r in batch), max_len=MAX_LEN,
                       prompt_lengths=jax.numpy.asarray(lens))
        wall += res.prefill_s + res.decode_s
        total_tokens += sum(r.gen for r in batch)
    return wall, total_tokens


def _validate_schema(payload):
    assert SCHEMA_KEYS <= set(payload), set(payload)
    assert payload["schema_version"] == 1
    for mode in ("continuous", "static"):
        missing = MODE_KEYS - set(payload[mode])
        assert not missing, f"{mode} missing {missing}"
        assert payload[mode]["tok_s"] > 0, payload[mode]
    assert {"latency_p50_s", "latency_p95_s", "page_util_peak",
            "page_util_mean"} <= set(payload["continuous"])


def main():
    smoke = bool(int(os.environ.get("ITA_BENCH_SMOKE", "0")))
    rng = np.random.default_rng(0)
    params = init_model(jax.random.PRNGKey(0), CFG)
    reqs = make_trace(16 if smoke else 32, rng)

    # warm the compile caches (prefill, segment scan, adopt/release, the
    # static fused loop) so both modes time steady-state serving
    run_continuous_once(params, reqs)
    run_static_once(params, reqs)

    # this container's noise comes in multi-second bursts, so the two
    # modes are *interleaved* (every iteration runs both back to back)
    # and each takes its best wall — a burst then degrades both sides
    # rather than whichever mode happened to be on the clock
    iters = 2 if smoke else 3
    best_cont, best_static, static_tokens = None, None, 0
    for _ in range(iters):
        res = run_continuous_once(params, reqs)
        if best_cont is None or res.wall_s < best_cont.wall_s:
            best_cont = res
        wall, static_tokens = run_static_once(params, reqs)
        if best_static is None or wall < best_static:
            best_static = wall
    cont = summarize_continuous(best_cont)
    stat = {
        "tok_s": round(static_tokens / max(best_static, 1e-9), 3),
        "wall_s": round(best_static, 6),
        "tokens": static_tokens,
        "requests": len(reqs),
    }
    speedup = cont["tok_s"] / max(stat["tok_s"], 1e-9)

    print(f"serve/continuous_tok_s,0,{cont['tok_s']:.6g}")
    print(f"serve/static_tok_s,0,{stat['tok_s']:.6g}")
    print(f"serve/continuous_vs_static,0,{speedup:.6g}")
    print(f"serve/latency_p50_ms,0,{cont['latency_p50_s'] * 1e3:.6g}")
    print(f"serve/latency_p95_ms,0,{cont['latency_p95_s'] * 1e3:.6g}")
    print(f"serve/page_util_peak,0,{cont['page_util_peak']:.6g}")

    # ISSUE 4 acceptance: continuous batching must sustain higher
    # aggregate tok/s than static ragged batching on the same trace
    assert speedup > 1.0, (
        f"continuous batching ({cont['tok_s']} tok/s) did not beat static "
        f"ragged batching ({stat['tok_s']} tok/s) on the arrival trace")

    payload = {
        "schema_version": 1,
        "config": {"arch": CFG.name, "slots": SLOTS, "segment": SEGMENT,
                   "page_size": PAGE, "max_len": MAX_LEN,
                   "prompt_pad": PROMPT_PAD, "requests": len(reqs),
                   "backend": jax.default_backend(), "smoke": smoke},
        "continuous": cont,
        "static": stat,
        "speedup": round(speedup, 3),
    }
    out_path = os.environ.get("ITA_BENCH_OUT_SERVE", "BENCH_serve.json")
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    with open(out_path) as f:          # round-trip: the rot guard
        _validate_schema(json.load(f))
    print(f"serve/artifact,0,{out_path}")


if __name__ == "__main__":
    main()
