"""Continuous-batching serving benchmark: sustained tok/s, request
latency and TTFT over an arrival trace — chunked-prefill admission vs
stop-the-world (``stall``) admission vs static ragged batching.

Claims under test:

- (ISSUE 4) continuous batching sustains higher aggregate tok/s than
  static batching on the same trace — a static batch decodes until its
  *longest* member finishes, while the continuous scheduler releases a
  finished sequence's pages and admits queued work between segments.
- (ISSUE 5) **chunked** admission beats **stall** admission on sustained
  tok/s and strictly on p95 TTFT for a straggler-heavy trace with long
  prompts: stall admission stops every decode slot to run a padded
  full-prompt prefill into a ring scratch and bytes-copy it into pages,
  so decode throughput craters whenever a prompt arrives; chunked
  admission interleaves prompt chunks with decode steps inside the fused
  segments (page-native writes), so the decode stream never stops and
  queue waits — the p95 TTFT driver under load — stay short. The
  stop-the-world cost is reported directly as ``prefill_stall_frac``
  (fraction of wall time inside the admission prefill dispatches; 0
  under chunked admission by construction).
- (ISSUE 6) **prefix sharing** on a shared-system-prompt trace (every
  request opens with the same full page of tokens) strictly reduces
  prefilled tokens vs the unshared path at **bit-identical** outputs:
  later requests adopt the registered prefix pages (+1 refcount)
  instead of re-prefilling them, their page reservations shrink by the
  adopted pages, and peak page-pool occupancy never exceeds the
  unshared run's.

Measured on the CI (CPU/interpret) configuration: indicative structure,
not silicon numbers, but the step-count arithmetic (static: sum of
per-batch max-gen; stall: decode frozen for every admission prefill;
chunked: decode-maximal every step; prefix: shared pages never
re-prefilled) is hardware-independent.

- (ISSUE 8) **overload survival**: on a trace whose arrival rate exceeds
  the service rate, with two SLO classes over a deliberately undersized
  page pool, page-pressure preemption keeps the high class's p95
  admission delay (the deterministic, virtual-time TTFT) bounded by the
  configured SLO while every low-class request still completes (no
  starvation) — at tokens bit-identical to serving the same trace on an
  unpressured pool, with the allocator invariants host-checked after
  every admission round.

- (ISSUE 9) **crash recovery**: killing the journaled serve mid-trace
  (round boundary and torn mid-segment) and restarting from the journal
  + snapshot yields bit-identical tokens; a corrupt snapshot degrades to
  a cold start from the journal (still bit-identical); and write-ahead
  journaling costs at most 3% of the journal-off sustained tok/s,
  measured as the **floor of paired back-to-back on/off ratios** with
  alternating order — noise (compute bursts, host IO pressure) can only
  inflate a pair's apparent overhead, so the minimum estimates the true
  cost, the same logic as the best-of wall-time protocol.

Writes ``BENCH_serve.json`` (env ``ITA_BENCH_OUT_SERVE`` overrides the
path): per-mode sustained tok/s, p50/p95 request latency, p50/p95 TTFT,
prefill-stall fraction, page-pool utilization, (v3) prefix-sharing
counters — ``prefix_hit_rate``, prefilled/adopted token counts,
``prefill_tokens_saved`` — (v4) the overload section's preemption
count and per-class admission delays — and (v5) the recovery section:
``recovery_time_s``, ``replayed_tokens``, ``snapshot_bytes``, the
journal-on/off tok/s pair and ``journal_overhead_frac``, plus the
``cold_start_fallback`` flag from the corrupt-snapshot fixture —
schema-checked on every run; the smoke run (CI,
``benchmarks/run.py --smoke``) asserts every ordering including the
strict prefill-token reduction, the overload SLO bound, crash-recovery
parity and the journal-overhead gate.
"""

import json
import os

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import init_model
from repro.runtime.generate import ServeRequest, generate, serve_continuous

# Sized so a decode step's compute is non-trivial next to the per-
# dispatch overhead of the CPU-interpret CI config: the quantities under
# test are step counts (static strands slots; stall freezes decode per
# admission round) and those signals need steps to cost more than the
# host glue around them.
CFG = ModelConfig(
    name="bench-serve", family="dense", d_model=128, n_heads=2,
    n_kv_heads=2, head_dim=32, d_ff=256, vocab_size=64,
    layer_groups=((("attn",), 1),), dtype="float32", attention_impl="ita")

SLOTS = 4
PROMPT_PAD = 128                # the padded width every stall round pays
CHUNK = 48
# page == the fused kernels' KV block (the bit-parity tile schedule), so
# a paged decode step tile-skips to the same occupied prefix the static
# baseline's ring streams — the benchmark then isolates *scheduling*
# (slot/page reuse, admission policy), not per-step tile count
PAGE = 128
SEGMENT = 6
MAX_LEN = 256                   # per-slot window: 2 pages

SYS_LEN = PAGE                  # shared system prompt: one full page

# overload: every request spans 2 pages; the pool allocates 7, so at
# most 3 requests hold pages concurrently across 4 slots — page-bound,
# arrival-rate ~2/step vs service-rate well under 1/step. The SLO the
# smoke gate enforces for the high class is 4 segments of admission
# delay (virtual steps — deterministic, machine-independent).
OVERLOAD_POOL = 8
OVERLOAD_SLO_STEPS = 4 * SEGMENT

SCHEMA_KEYS = {"schema_version", "config", "chunked", "stall", "static",
               "prefix", "prefix_off", "prefill_tokens_saved",
               "speedup_chunked_vs_stall", "speedup_continuous_vs_static",
               "overload", "recovery"}
MODE_KEYS = {"tok_s", "wall_s", "tokens", "requests"}
OVERLOAD_KEYS = MODE_KEYS | {"preemptions", "slo_steps", "hi_requests",
                             "hi_p95_admit_delay_steps",
                             "lo_p95_admit_delay_steps", "hi_p95_ttft_s"}
RECOVERY_KEYS = {"crashes", "recovery_time_s", "replayed_tokens",
                 "snapshot_bytes", "restored_from_snapshot",
                 "cold_start_fallback", "journal_tok_s",
                 "journal_off_tok_s", "journal_overhead_frac"}
JOURNAL_OVERHEAD_MAX = 0.03     # WAL cost gate: <= 3% of journal-off tok/s
SERVE_KEYS = MODE_KEYS | {"latency_p50_s", "latency_p95_s", "ttft_p50_s",
                          "ttft_p95_s", "prefill_stall_frac",
                          "page_util_peak", "page_util_mean",
                          "prefill_tokens", "shared_prefix_tokens",
                          "prefix_hits", "prefix_hit_rate"}


def make_trace(n_requests, rng):
    """Straggler-heavy, queue-pressured, mostly-short prompts with a long
    one mixed in: one long-gen straggler per SLOTS requests pins its slot
    (every static batch contains exactly one; the continuous pool always
    has long-lived decodes for admission to stall), arrivals land 0-1
    steps apart so requests queue behind the stragglers, and most prompts
    are far shorter than PROMPT_PAD — the shape stop-the-world admission
    is worst at: nearly every arriving prompt triggers its own admission
    round, each one a full (slots x PROMPT_PAD) *padded* prefill that
    freezes the stragglers' decode, while chunked admission prefills only
    the actual prompt tokens, in-band, with decode never pausing. Queue
    waits — the p95 TTFT driver — then track sustained throughput."""
    reqs = []
    step = 0
    for i in range(n_requests):
        gen = 120 if i % SLOTS == 0 else int(rng.integers(6, 15))
        plen = int(rng.integers(3 * PROMPT_PAD // 4, PROMPT_PAD + 1)) \
            if i % 5 == 4 else int(rng.integers(16, PROMPT_PAD // 2 * 3 // 4))
        reqs.append(ServeRequest(
            prompt=rng.integers(0, CFG.vocab_size, plen).astype(np.int32),
            gen=gen, arrival=step))
        step += int(rng.integers(0, 2))
    return reqs


def make_shared_trace(n_requests, rng):
    """The prefix-sharing trace: every request opens with the *same*
    ``SYS_LEN``-token system prompt (one full page) followed by a short
    unique tail, and every request fits its window without wrapping
    (``plen + gen <= MAX_LEN``) so admission is allowed to share.
    Arrivals are spread a few steps apart so the first request's prefix
    registers before its followers admit — the steady-state shape of a
    production system prompt, not an adversarial race."""
    system = rng.integers(0, CFG.vocab_size, SYS_LEN).astype(np.int32)
    reqs = []
    step = 0
    for _ in range(n_requests):
        tail = rng.integers(0, CFG.vocab_size,
                            int(rng.integers(8, 33))).astype(np.int32)
        reqs.append(ServeRequest(
            prompt=np.concatenate([system, tail]),
            gen=int(rng.integers(8, 25)), arrival=step))
        step += int(rng.integers(4, 9))
    return reqs


def make_overload_trace(n_requests, rng):
    """Arrival rate > service rate with two SLO classes: every request
    spans two pages (prompt 110-140 + gen 24-33 over 128-token pages),
    arrivals land two per step, and every fourth request is high
    priority. On the undersized OVERLOAD_POOL only ~3 requests hold
    pages at once, so the high class can only meet its SLO by preempting
    low-class victims — the trace make_trace's queue pressure never
    creates because there every request fits one page."""
    reqs = []
    for i in range(n_requests):
        plen = int(rng.integers(110, 141))
        reqs.append(ServeRequest(
            prompt=rng.integers(0, CFG.vocab_size, plen).astype(np.int32),
            gen=int(rng.integers(24, 34)), arrival=i // 2,
            priority=1 if i % 4 == 0 else 0))
    return reqs


def run_serve_once(params, reqs, admission, prefix_sharing=False,
                   journal_dir=None):
    res = serve_continuous(params, CFG, reqs, slots=SLOTS, segment=SEGMENT,
                           max_len=MAX_LEN, page_size=PAGE,
                           admission=admission, chunk_size=CHUNK,
                           prefix_sharing=prefix_sharing,
                           journal_dir=journal_dir)
    assert len(res.completed) == len(reqs), "trace not fully served"
    return res


def summarize_serve(best):
    util = [u for _, u in best.page_util]
    return {
        "tok_s": round(best.tok_s, 3),
        "wall_s": round(best.wall_s, 6),
        "tokens": best.total_tokens,
        "requests": len(best.completed),
        "steps": best.steps,
        "segments": best.segments,
        "admission_rounds": best.admission_rounds,
        "latency_p50_s": round(best.latency_quantile(0.5), 6),
        "latency_p95_s": round(best.latency_quantile(0.95), 6),
        "ttft_p50_s": round(best.ttft_quantile(0.5), 6),
        "ttft_p95_s": round(best.ttft_quantile(0.95), 6),
        "prefill_stall_frac": round(best.prefill_stall_frac, 4),
        "page_util_peak": round(max(util, default=0.0), 4),
        "page_util_mean": round(float(np.mean(util)) if util else 0.0, 4),
        "prefill_tokens": best.prefill_tokens,
        "shared_prefix_tokens": best.shared_prefix_tokens,
        "prefix_hits": best.prefix_hits,
        "prefix_hit_rate": round(best.prefix_hit_rate, 4),
    }


def summarize_overload(res):
    cs = res.class_summary()
    hi = cs.get(1, {})
    lo = cs.get(0, {})
    return {
        "tok_s": round(res.tok_s, 3),
        "wall_s": round(res.wall_s, 6),
        "tokens": res.total_tokens,
        "requests": len(res.completed),
        "preemptions": res.preemptions,
        "slo_steps": OVERLOAD_SLO_STEPS,
        "hi_requests": hi.get("n", 0),
        "hi_p95_admit_delay_steps": hi.get("p95_admit_delay_steps", 0),
        "lo_p95_admit_delay_steps": lo.get("p95_admit_delay_steps", 0),
        "hi_p95_ttft_s": round(hi.get("p95_ttft_s", 0.0), 6),
    }


def run_static_once(params, reqs):
    """Static ragged batching baseline on the same trace: requests in
    arrival order, batches of SLOTS, each batch generates to its longest
    member's budget before the next batch starts (the pre-paged serving
    loop). Useful tokens counted identically (each request's own gen).
    Returns (wall_s, total_tokens)."""
    wall = 0.0
    total_tokens = 0
    for i in range(0, len(reqs), SLOTS):
        batch = reqs[i:i + SLOTS]
        lens = [int(np.asarray(r.prompt).size) for r in batch]
        prompts = np.zeros((len(batch), PROMPT_PAD), np.int32)
        for row, r in enumerate(batch):
            prompts[row, :lens[row]] = np.asarray(r.prompt)
        res = generate(params, CFG, jax.numpy.asarray(prompts),
                       max(r.gen for r in batch), max_len=MAX_LEN,
                       prompt_lengths=jax.numpy.asarray(lens))
        wall += res.prefill_s + res.decode_s
        total_tokens += sum(r.gen for r in batch)
    return wall, total_tokens


def _validate_schema(payload):
    assert SCHEMA_KEYS <= set(payload), set(payload)
    assert payload["schema_version"] == 5
    for mode in ("chunked", "stall", "prefix", "prefix_off"):
        missing = SERVE_KEYS - set(payload[mode])
        assert not missing, f"{mode} missing {missing}"
        assert payload[mode]["tok_s"] > 0, payload[mode]
    assert payload["chunked"]["prefill_stall_frac"] == 0.0
    # ISSUE 8: the overload trace preempts, bounds the high class's
    # admission delay by the SLO, and starves nobody
    over = payload["overload"]
    missing = OVERLOAD_KEYS - set(over)
    assert not missing, f"overload missing {missing}"
    assert over["preemptions"] >= 1, over
    assert over["hi_p95_admit_delay_steps"] <= over["slo_steps"], over
    assert over["hi_p95_admit_delay_steps"] \
        < over["lo_p95_admit_delay_steps"], over
    assert over["requests"] == payload["config"]["overload_requests"], over
    # ISSUE 6: sharing strictly reduces prefilled tokens on the shared
    # trace, hits at least one prefix, and never inflates pool occupancy
    assert payload["prefix"]["prefill_tokens"] \
        < payload["prefix_off"]["prefill_tokens"], (
        payload["prefix"]["prefill_tokens"],
        payload["prefix_off"]["prefill_tokens"])
    assert payload["prefix"]["prefix_hit_rate"] > 0.0
    assert payload["prefix_off"]["shared_prefix_tokens"] == 0
    assert payload["prefill_tokens_saved"] > 0
    missing = MODE_KEYS - set(payload["static"])
    assert not missing, f"static missing {missing}"
    assert payload["static"]["tok_s"] > 0
    # ISSUE 9: recovery happened (crashes fired, tokens replayed), the
    # corrupt-snapshot fixture exercised the cold-start fallback, and
    # journaling stayed under its overhead gate
    rec = payload["recovery"]
    missing = RECOVERY_KEYS - set(rec)
    assert not missing, f"recovery missing {missing}"
    assert rec["crashes"] >= 2, rec
    assert rec["replayed_tokens"] > 0, rec
    assert rec["snapshot_bytes"] > 0, rec
    assert rec["restored_from_snapshot"] is True, rec
    assert rec["cold_start_fallback"] is True, rec
    assert rec["journal_overhead_frac"] <= JOURNAL_OVERHEAD_MAX, rec


def main():
    smoke = bool(int(os.environ.get("ITA_BENCH_SMOKE", "0")))
    rng = np.random.default_rng(0)
    params = init_model(jax.random.PRNGKey(0), CFG)
    reqs = make_trace(20 if smoke else 36, rng)
    shared_reqs = make_shared_trace(8 if smoke else 14, rng)

    # warm the compile caches (chunked + stall segments, admission
    # dispatches, the static fused loop) so every mode times steady state
    run_serve_once(params, reqs, "chunked")
    run_serve_once(params, reqs, "stall")
    run_static_once(params, reqs)

    # prefix sharing on the shared-system-prompt trace: counters and
    # tokens are deterministic for a fixed trace, so one pass per mode
    # settles the ISSUE-6 claims; tok_s still takes the interleaved best
    pfx_on = run_serve_once(params, shared_reqs, "chunked",
                            prefix_sharing=True)
    pfx_off = run_serve_once(params, shared_reqs, "chunked")
    toks_on = {c.index: np.asarray(c.tokens) for c in pfx_on.completed}
    toks_off = {c.index: np.asarray(c.tokens) for c in pfx_off.completed}
    for i in toks_off:
        np.testing.assert_array_equal(
            toks_on[i], toks_off[i],
            err_msg=f"prefix sharing changed request {i}'s tokens")

    # (ISSUE 8) overload: two SLO classes over the undersized pool, with
    # the allocator invariants host-checked after every admission round;
    # tokens must match the same trace served on an unpressured pool
    # (counters and admission delays are deterministic — one pass each)
    over_reqs = make_overload_trace(10 if smoke else 12, rng)
    over = serve_continuous(
        params, CFG, over_reqs, slots=SLOTS, segment=SEGMENT,
        max_len=MAX_LEN, page_size=PAGE, num_pages=OVERLOAD_POOL,
        admission="chunked", chunk_size=CHUNK, preemption=True,
        debug_invariants=True)
    assert len(over.completed) == len(over_reqs), "overload starved"
    calm = run_serve_once(params, over_reqs, "chunked")
    toks_over = {c.index: np.asarray(c.tokens) for c in over.completed}
    for c in calm.completed:
        np.testing.assert_array_equal(
            toks_over[c.index], np.asarray(c.tokens),
            err_msg=f"preemption changed request {c.index}'s tokens")
    overload = summarize_overload(over)

    # (ISSUE 9) crash recovery: kill the journaled + snapshotted serve
    # at a round boundary, then again torn mid-segment, restart from the
    # journal each time, and require the final token streams to be
    # bit-identical to the calm prefix run above; then corrupt the
    # newest snapshot and require the resume to degrade to a cold start
    # from the journal — still bit-identical
    import shutil
    import tempfile

    from repro.runtime.fault_tolerance import (ServeFaultPlan,
                                               SimulatedCrash)
    from repro.runtime.journal import serve_with_recovery
    crash_at = max(2 * SEGMENT, (pfx_on.steps // (2 * SEGMENT)) * SEGMENT)
    rec_dir = tempfile.mkdtemp(prefix="bench-serve-journal-")
    try:
        rec, crashes = serve_with_recovery(
            params, CFG, shared_reqs,
            journal_dir=os.path.join(rec_dir, "rec"), snapshot_every=1,
            plans=(ServeFaultPlan(crash_steps=(crash_at,)),
                   ServeFaultPlan(crash_after_steps=(crash_at,))),
            slots=SLOTS, segment=SEGMENT, max_len=MAX_LEN, page_size=PAGE,
            chunk_size=CHUNK, prefix_sharing=True)
        assert crashes == 2, f"crash injection fired {crashes}x, want 2"
        assert rec.restored_from_snapshot, \
            "recovery never warm-started from a snapshot"
        for c in rec.completed:
            np.testing.assert_array_equal(
                np.asarray(c.tokens), toks_on[c.index],
                err_msg=f"crash recovery changed request {c.index}")
        # corrupt-snapshot fixture: flip a byte in the newest snapshot's
        # first leaf; the checksum must catch it and the resume must
        # cold-start from the journal with the same tokens
        cor_dir = os.path.join(rec_dir, "cor")
        try:
            serve_continuous(
                params, CFG, shared_reqs, journal_dir=cor_dir,
                snapshot_every=1,
                faults=ServeFaultPlan(crash_steps=(crash_at,)),
                slots=SLOTS, segment=SEGMENT, max_len=MAX_LEN,
                page_size=PAGE, chunk_size=CHUNK, prefix_sharing=True)
            raise AssertionError("injected crash never fired")
        except SimulatedCrash:
            pass
        snaps = sorted(os.listdir(os.path.join(cor_dir, "snapshots")))
        leaf = os.path.join(cor_dir, "snapshots", snaps[-1],
                            "leaf_00000.npy")
        raw = bytearray(open(leaf, "rb").read())
        raw[-1] ^= 0xFF
        open(leaf, "wb").write(bytes(raw))
        cold = serve_continuous(
            params, CFG, shared_reqs, journal_dir=cor_dir, resume=True,
            snapshot_every=1, slots=SLOTS, segment=SEGMENT,
            max_len=MAX_LEN, page_size=PAGE, chunk_size=CHUNK,
            prefix_sharing=True)
        assert cold.recovered and not cold.restored_from_snapshot, \
            "corrupt snapshot was not rejected"
        for c in cold.completed:
            np.testing.assert_array_equal(
                np.asarray(c.tokens), toks_on[c.index],
                err_msg=f"cold-start recovery changed request {c.index}")
    finally:
        shutil.rmtree(rec_dir, ignore_errors=True)

    # this container's noise comes in multi-second bursts, so the modes
    # are *interleaved* (every iteration runs all of them back to back)
    # and every metric takes its own per-iteration best — a burst then
    # degrades every side rather than whichever mode (or metric) happened
    # to be on the clock; step/segment/round counts and page util are
    # deterministic for a fixed trace, so mixing iterations is sound
    iters = 4
    runs = {"chunked": [], "stall": [], "prefix": [], "prefix_off": []}
    best_static, static_tokens = None, 0
    for _ in range(iters):
        for mode in ("chunked", "stall"):
            runs[mode].append(summarize_serve(
                run_serve_once(params, reqs, mode)))
        runs["prefix"].append(summarize_serve(
            run_serve_once(params, shared_reqs, "chunked",
                           prefix_sharing=True)))
        runs["prefix_off"].append(summarize_serve(
            run_serve_once(params, shared_reqs, "chunked")))
        wall, static_tokens = run_static_once(params, reqs)
        if best_static is None or wall < best_static:
            best_static = wall

    # journal-overhead gate: the WAL's intrinsic cost is ~1%, well below
    # this box's per-run noise, so an unpaired best-of compare would
    # gate on noise. Instead run back-to-back on/off *pairs*
    # (alternating order so warm-up drift cancels) and take the MINIMUM
    # of the paired ratios: noise — compute bursts and, worse, host IO
    # pressure that hits only the syscall-bearing journaled half — can
    # only inflate a pair's apparent overhead, never deflate it, so the
    # floor estimates the true cost exactly like the best-of wall times
    # above. Fresh journal per journaled run (resume=False truncates).
    jdir = tempfile.mkdtemp(prefix="bench-serve-overhead-")
    j_pairs = []                       # (off_tok_s, on_tok_s)
    journaled = None
    try:
        for i in range(5 if smoke else 7):
            if i % 2 == 0:
                off = summarize_serve(run_serve_once(params, reqs, "chunked"))
                on = summarize_serve(run_serve_once(
                    params, reqs, "chunked", journal_dir=jdir))
            else:
                on = summarize_serve(run_serve_once(
                    params, reqs, "chunked", journal_dir=jdir))
                off = summarize_serve(run_serve_once(params, reqs, "chunked"))
            j_pairs.append((off["tok_s"], on["tok_s"]))
            if journaled is None or on["tok_s"] > journaled["tok_s"]:
                journaled = on
    finally:
        shutil.rmtree(jdir, ignore_errors=True)
    paired_overhead = min(1.0 - on / max(off, 1e-9)
                          for off, on in j_pairs)

    def best_of(summaries):
        out = dict(summaries[0])
        for key in ("wall_s", "latency_p50_s", "latency_p95_s",
                    "ttft_p50_s", "ttft_p95_s", "prefill_stall_frac"):
            out[key] = min(r[key] for r in summaries)
        out["tok_s"] = max(r["tok_s"] for r in summaries)
        return out

    chunked = best_of(runs["chunked"])
    stall = best_of(runs["stall"])
    prefix = best_of(runs["prefix"])
    prefix_off = best_of(runs["prefix_off"])
    tokens_saved = prefix_off["prefill_tokens"] - prefix["prefill_tokens"]
    recovery = {
        "crashes": 2,
        "recovery_time_s": round(rec.recovery_s, 6),
        "replayed_tokens": rec.replayed_tokens,
        "snapshot_bytes": rec.snapshot_bytes,
        "restored_from_snapshot": rec.restored_from_snapshot,
        "cold_start_fallback": bool(cold.recovered
                                    and not cold.restored_from_snapshot),
        "journal_tok_s": journaled["tok_s"],
        "journal_off_tok_s": max(off for off, _ in j_pairs),
        "journal_overhead_frac": round(max(0.0, paired_overhead), 4),
    }
    stat = {
        "tok_s": round(static_tokens / max(best_static, 1e-9), 3),
        "wall_s": round(best_static, 6),
        "tokens": static_tokens,
        "requests": len(reqs),
    }
    vs_stall = chunked["tok_s"] / max(stall["tok_s"], 1e-9)
    vs_static = chunked["tok_s"] / max(stat["tok_s"], 1e-9)

    print(f"serve/chunked_tok_s,0,{chunked['tok_s']:.6g}")
    print(f"serve/stall_tok_s,0,{stall['tok_s']:.6g}")
    print(f"serve/static_tok_s,0,{stat['tok_s']:.6g}")
    print(f"serve/chunked_vs_stall,0,{vs_stall:.6g}")
    print(f"serve/continuous_vs_static,0,{vs_static:.6g}")
    print(f"serve/chunked_ttft_p95_ms,0,{chunked['ttft_p95_s'] * 1e3:.6g}")
    print(f"serve/stall_ttft_p95_ms,0,{stall['ttft_p95_s'] * 1e3:.6g}")
    print(f"serve/stall_prefill_frac,0,{stall['prefill_stall_frac']:.6g}")
    print(f"serve/latency_p95_ms,0,{chunked['latency_p95_s'] * 1e3:.6g}")
    print(f"serve/page_util_peak,0,{chunked['page_util_peak']:.6g}")
    print(f"serve/prefix_hit_rate,0,{prefix['prefix_hit_rate']:.6g}")
    print(f"serve/prefix_prefill_tokens,0,{prefix['prefill_tokens']}")
    print(f"serve/prefix_off_prefill_tokens,0,"
          f"{prefix_off['prefill_tokens']}")
    print(f"serve/prefill_tokens_saved,0,{tokens_saved}")
    print(f"serve/prefix_page_util_peak,0,{prefix['page_util_peak']:.6g}")
    print(f"serve/overload_preemptions,0,{overload['preemptions']}")
    print(f"serve/overload_hi_admit_delay_p95_steps,0,"
          f"{overload['hi_p95_admit_delay_steps']}")
    print(f"serve/overload_lo_admit_delay_p95_steps,0,"
          f"{overload['lo_p95_admit_delay_steps']}")
    print(f"serve/overload_hi_ttft_p95_ms,0,"
          f"{overload['hi_p95_ttft_s'] * 1e3:.6g}")
    print(f"serve/recovery_time_ms,0,"
          f"{recovery['recovery_time_s'] * 1e3:.6g}")
    print(f"serve/recovery_replayed_tokens,0,"
          f"{recovery['replayed_tokens']}")
    print(f"serve/recovery_snapshot_bytes,0,{recovery['snapshot_bytes']}")
    print(f"serve/journal_tok_s,0,{recovery['journal_tok_s']:.6g}")
    print(f"serve/journal_overhead_frac,0,"
          f"{recovery['journal_overhead_frac']:.6g}")

    # ISSUE 4 acceptance: continuous batching must sustain higher
    # aggregate tok/s than static ragged batching on the same trace
    assert vs_static > 1.0, (
        f"continuous batching ({chunked['tok_s']} tok/s) did not beat "
        f"static ragged batching ({stat['tok_s']} tok/s) on the trace")
    # ISSUE 5 acceptance: chunked admission >= stall admission on
    # sustained tok/s, strictly better p95 TTFT on the straggler trace
    assert vs_stall >= 1.0, (
        f"chunked admission ({chunked['tok_s']} tok/s) fell behind stall "
        f"admission ({stall['tok_s']} tok/s)")
    assert chunked["ttft_p95_s"] < stall["ttft_p95_s"], (
        f"chunked admission p95 TTFT {chunked['ttft_p95_s']} s not "
        f"better than stall {stall['ttft_p95_s']} s")
    # ISSUE 6 acceptance: sharing strictly reduces prefilled tokens on
    # the shared-system-prompt trace (outputs already asserted
    # bit-identical above) and never inflates peak pool occupancy —
    # adopters reserve fewer pages, so concurrent capacity only grows
    assert tokens_saved > 0, (
        f"prefix sharing prefilled {prefix['prefill_tokens']} tokens, "
        f"not fewer than unshared {prefix_off['prefill_tokens']}")
    assert prefix["prefix_hit_rate"] > 0.0, "no request hit the prefix"
    assert prefix["page_util_peak"] <= prefix_off["page_util_peak"], (
        f"sharing raised peak page occupancy: "
        f"{prefix['page_util_peak']} > {prefix_off['page_util_peak']}")
    # ISSUE 8 acceptance: preemption fired, the high class met its
    # (virtual-step) SLO and beat the low class, nobody starved
    assert overload["preemptions"] >= 1, "overload trace never preempted"
    assert overload["hi_p95_admit_delay_steps"] <= OVERLOAD_SLO_STEPS, (
        f"high-priority p95 admission delay "
        f"{overload['hi_p95_admit_delay_steps']} steps blew the "
        f"{OVERLOAD_SLO_STEPS}-step SLO under overload")
    assert overload["hi_p95_admit_delay_steps"] \
        < overload["lo_p95_admit_delay_steps"], (
        f"priority classes did not separate: hi "
        f"{overload['hi_p95_admit_delay_steps']} vs lo "
        f"{overload['lo_p95_admit_delay_steps']} admission-delay steps")
    # ISSUE 9 acceptance: recovery parity already asserted above (bit-
    # identical tokens across two crash kinds + corrupt-snapshot cold
    # start); the WAL's throughput cost stays under the gate
    assert recovery["journal_overhead_frac"] <= JOURNAL_OVERHEAD_MAX, (
        f"journaling cost {recovery['journal_overhead_frac']:.1%} of "
        f"sustained tok/s ({recovery['journal_tok_s']} vs "
        f"{recovery['journal_off_tok_s']} journal-off), gate "
        f"{JOURNAL_OVERHEAD_MAX:.0%}")
    assert recovery["cold_start_fallback"], \
        "corrupt snapshot did not fall back to cold start"

    payload = {
        "schema_version": 5,
        "config": {"arch": CFG.name, "slots": SLOTS, "segment": SEGMENT,
                   "page_size": PAGE, "max_len": MAX_LEN,
                   "prompt_pad": PROMPT_PAD, "chunk_size": CHUNK,
                   "requests": len(reqs),
                   "shared_requests": len(shared_reqs),
                   "system_prompt_len": SYS_LEN,
                   "overload_requests": len(over_reqs),
                   "overload_pool": OVERLOAD_POOL,
                   "backend": jax.default_backend(), "smoke": smoke},
        "chunked": chunked,
        "stall": stall,
        "static": stat,
        "prefix": prefix,
        "prefix_off": prefix_off,
        "overload": overload,
        "recovery": recovery,
        "prefill_tokens_saved": tokens_saved,
        "speedup_chunked_vs_stall": round(vs_stall, 3),
        "speedup_continuous_vs_static": round(vs_static, 3),
    }
    out_path = os.environ.get("ITA_BENCH_OUT_SERVE", "BENCH_serve.json")
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    with open(out_path) as f:          # round-trip: the rot guard
        _validate_schema(json.load(f))
    print(f"serve/artifact,0,{out_path}")


if __name__ == "__main__":
    main()
