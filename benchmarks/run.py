"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--smoke]

``--smoke`` (also env ``ITA_BENCH_SMOKE=1``) runs every module with
reduced iteration counts — the CI guard that keeps the benchmark entry
points importable and runnable as the APIs underneath them move.

| module            | paper reference                          |
|-------------------|------------------------------------------|
| bench_softmax_mae | §V-C softmax MAE (ITA vs I-BERT)         |
| bench_attention   | §V-D speedup + Table I (int8/bf16, bytes)|
| bench_dataflow    | §III weight-stationary bandwidth eq.     |
| bench_kernels     | kernel VMEM/traffic structure + checks   |
| bench_decode      | int8 KV-cache decode vs full recompute   |
| bench_serve       | continuous batching vs static (tok/s)    |
| bench_roofline    | §Roofline table from dry-run artifacts   |
"""

import argparse
import os
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced iteration counts (CI rot guard)")
    args = ap.parse_args()
    if args.smoke:
        os.environ["ITA_BENCH_SMOKE"] = "1"

    from benchmarks import (bench_attention, bench_dataflow, bench_decode,
                            bench_kernels, bench_roofline, bench_serve,
                            bench_softmax_mae)
    print("name,us_per_call,derived")
    for mod in (bench_softmax_mae, bench_dataflow, bench_attention,
                bench_kernels, bench_decode, bench_serve, bench_roofline):
        try:
            mod.main()
        except Exception as e:  # noqa: BLE001
            print(f"{mod.__name__}/ERROR,0,{type(e).__name__}:{e}",
                  file=sys.stderr)
            traceback.print_exc()
            raise


if __name__ == '__main__':
    main()
