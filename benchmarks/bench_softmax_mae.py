"""Paper §V-C (softmax accuracy): MAE of the integer softmax variants vs
the float oracle. Reproduces the ITA-vs-I-BERT comparison (paper: ITA
0.46%, I-BERT 0.35% on Compact-Transformer activations) and extends it
with the bit-exact silicon mode, the streaming mode, and the beyond-paper
adaptive mode across row lengths and logit spreads.
"""

import jax.numpy as jnp
import numpy as np

from repro.core import softmax as S
from repro.core.quant import EPS_MAX


def _quantize(x):
    return np.clip(np.round(x / EPS_MAX), -128, 127).astype(np.int8)


def _mae(p, ref):
    return float(np.abs(np.asarray(p) - ref).mean())


def rows():
    rng = np.random.default_rng(0)
    out = []
    for n, sigma in [(64, 1.0), (256, 1.0), (256, 2.5), (1024, 1.0),
                     (4096, 0.6)]:
        x = rng.normal(0.0, sigma, (256, n))
        xq = _quantize(x)
        xj = jnp.asarray(xq)
        ref = np.asarray(S.softmax_float(xj))
        variants = {
            "ita": S.ita_softmax(xj),
            "ita_streaming(parts=8)": S.ita_softmax_streaming(xj, 8),
            "ita_bitexact_15b": S.ita_softmax_bitexact(xj, num_parts=8),
            "ita_adaptive(beyond-paper)": S.ita_softmax_adaptive(xj),
            "ibert": S.ibert_softmax_np(xq),
            "softermax": S.softermax(xj),
        }
        for name, p in variants.items():
            out.append((f"softmax_mae/{name}/n{n}/sigma{sigma}",
                        _mae(p, ref)))
    return out


def main():
    for name, mae in rows():
        print(f"{name},0,{mae:.6f}")


if __name__ == "__main__":
    main()
